"""Mesh topology descriptor threaded through model code.

Model code never touches ``jax.devices()`` directly; it receives a
:class:`Topology` that says which mesh axes exist and how logical roles
(data/expert/tensor/pipeline) map onto them.  ``topology=None`` (or
``ep_size == 1``) selects the single-device code paths, which is what unit
tests exercise; the dry-run and multi-device tests build real meshes.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh


@dataclass(frozen=True)
class Topology:
    mesh: Optional[Mesh] = None
    data_axes: Tuple[str, ...] = ("data",)  # batch-sharding axes ("pod","data")
    model_axis: Optional[str] = "model"  # TP / EP axis
    pipeline_axis: Optional[str] = None  # PP over pods, if enabled
    fsdp: bool = True  # shard params/opt over the data axes (ZeRO-3)
    # Sequence-parallel attention: the residual stream is S-sharded over the
    # model axis; attention gathers only the (small, GQA) K/V heads and the
    # MoE dispatch consumes pre-sharded tokens.  Valid for attention-pure
    # stacks (no SSM layers — their scan crosses the shard boundary).
    seq_parallel_attn: bool = False
    # Per-EP-shard hardware capability mask support (HL-GGN eq. 2-4): when a
    # heterogeneous fleet is declared, shard i may only evaluate experts whose
    # complexity fits its capability; see repro.core.hardware.
    heterogeneous: bool = False

    @property
    def dp_size(self) -> int:
        if self.mesh is None:
            return 1
        out = 1
        for a in self.data_axes:
            out *= self.mesh.shape[a]
        return out

    @property
    def ep_size(self) -> int:
        if self.mesh is None or self.model_axis is None:
            return 1
        return self.mesh.shape[self.model_axis]

    @property
    def tp_size(self) -> int:
        return self.ep_size

    @property
    def pp_size(self) -> int:
        if self.mesh is None or self.pipeline_axis is None:
            return 1
        return self.mesh.shape[self.pipeline_axis]

    @property
    def num_devices(self) -> int:
        return 1 if self.mesh is None else self.mesh.size

    @property
    def use_shard_map_moe(self) -> bool:
        return self.mesh is not None and self.ep_size > 1


def single_device_topology() -> Topology:
    return Topology(mesh=None)
