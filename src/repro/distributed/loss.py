"""Vocab-sharded cross-entropy.

The lm_head output dim is sharded over the model axis, so logits arrive as
[B, S, V/tp] per shard.  Computing CE naively (take_along_axis over a
sharded dim) would force XLA to all-gather [B, S, V] — catastrophic at
vocab 150k+.  Instead a shard_map computes local max / sum-exp / label hit
and combines with psum: bytes on the wire are O(B*S), not O(B*S*V).
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.topology import Topology
from repro.models.layers import cross_entropy_loss


def _sharded_ce_body(
    logits: jax.Array,  # [b, S, V_loc]
    labels: jax.Array,  # [b, S] (global vocab ids)
    topo: Topology,
    z_weight: float,
):
    axis = topo.model_axis
    V_loc = logits.shape[-1]
    me = jax.lax.axis_index(axis)
    lo = me * V_loc
    logits = logits.astype(jnp.float32)

    # The max subtraction is numerical-stability only; stop_gradient keeps
    # pmax out of the backward pass (it has no AD rule and needs none here).
    local_max = jax.lax.stop_gradient(logits.max(-1))
    gmax = jax.lax.pmax(local_max, axis)  # [b, S]
    sumexp = jnp.sum(jnp.exp(logits - gmax[..., None]), -1)
    gsum = jax.lax.psum(sumexp, axis)
    lse = gmax + jnp.log(gsum)

    mask = labels >= 0
    lab = jnp.clip(labels - lo, 0, V_loc - 1)
    hit = (labels >= lo) & (labels < lo + V_loc) & mask
    ll_local = jnp.where(
        hit, jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0], 0.0
    )
    ll = jax.lax.psum(ll_local, axis)  # [b, S]

    maskf = mask.astype(jnp.float32)
    denom_local = maskf.sum()
    nll = ((lse - ll) * maskf).sum()
    z = (jnp.square(lse) * maskf).sum()
    # reduce over data axes too so every device returns the global scalar
    names = tuple(topo.data_axes) + (axis,)
    tot_nll = jax.lax.psum(nll, names[:-1]) if topo.data_axes else nll
    tot_z = jax.lax.psum(z, names[:-1]) if topo.data_axes else z
    tot_den = jax.lax.psum(denom_local, names[:-1]) if topo.data_axes else denom_local
    denom = jnp.maximum(tot_den, 1.0)
    loss = tot_nll / denom + z_weight * tot_z / denom
    return loss, tot_nll / denom, tot_den


def sharded_cross_entropy(
    logits: jax.Array,  # [B, S, V] (V sharded over model under pjit)
    labels: jax.Array,  # [B, S]
    topo: Optional[Topology],
    z_weight: float = 1e-4,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    if topo is None or topo.mesh is None or topo.model_axis is None:
        return cross_entropy_loss(logits, labels, z_weight)
    from repro.distributed.sharding import fit_batch_axes

    B = labels.shape[0]
    # partial-prefix batch sharding: a global batch smaller than the full dp
    # degree must still shard (full-batch logits per device would be tens of
    # GiB at 150k vocab)
    bspec = fit_batch_axes(B, topo)
    fn = jax.shard_map(
        functools.partial(_sharded_ce_body, topo=topo, z_weight=z_weight),
        mesh=topo.mesh,
        in_specs=(P(bspec, None, topo.model_axis), P(bspec, None)),
        out_specs=(P(), P(), P()),
        check_vma=False,
    )
    loss, ce, tokens = fn(logits, labels)
    return loss, {"ce_loss": ce, "z_loss": loss - ce, "tokens": tokens}
