"""Fault tolerance & elasticity.

At thousands of nodes, the failure model is: a step either completes
everywhere or the job is restarted from the last checkpoint on a possibly
*smaller* mesh.  This module provides the pieces the trainer composes:

  * ``StepGuard``        — detects bad steps (NaN/inf loss, runaway grad
                           norm, injected failures) so the trainer can
                           restore-and-continue instead of corrupting state.
  * ``FailureInjector``  — deterministic chaos for tests (fail step k).
  * ``elastic_topology`` — rebuild a (possibly smaller) mesh from surviving
                           devices, preserving the model axis (experts must
                           keep their EP layout; data parallelism absorbs
                           the loss).
  * ``StragglerMitigator`` — per-step timing watchdog: flags slow steps and
                           recommends action (re-shard / drop a data shard),
                           the DP-level analogue of the paper's route-aware
                           re-allocation.
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import List, Optional, Sequence

import jax
import numpy as np

from repro.distributed.topology import Topology


@dataclass
class FailureInjector:
    """Deterministically fail specific steps (tests / drills).  One-shot:
    after a restore replays past the step, it does not re-fire (the 'node'
    was replaced)."""

    fail_steps: Sequence[int] = ()
    kind: str = "nan_loss"  # nan_loss | exception
    _fired: set = field(default_factory=set)

    def maybe_fail(self, step: int, loss: float) -> float:
        if step in self.fail_steps and step not in self._fired:
            self._fired.add(step)
            if self.kind == "exception":
                raise RuntimeError(f"injected device failure at step {step}")
            return float("nan")
        return loss


@dataclass
class StepGuard:
    max_grad_norm: float = 1e4
    consecutive_bad_limit: int = 3
    bad_count: int = 0

    def check(self, loss: float, grad_norm: Optional[float] = None) -> bool:
        """True = step is good; False = restore from checkpoint."""
        bad = not math.isfinite(loss)
        if grad_norm is not None and (
            not math.isfinite(grad_norm) or grad_norm > self.max_grad_norm
        ):
            bad = True
        if bad:
            self.bad_count += 1
            if self.bad_count > self.consecutive_bad_limit:
                raise RuntimeError(
                    f"{self.bad_count} consecutive bad steps — refusing to "
                    "continue (checkpoint likely also bad)"
                )
            return False
        self.bad_count = 0
        return True


def elastic_topology(
    n_available: int,
    *,
    model_axis_size: int,
    axis_names=("data", "model"),
) -> Topology:
    """Largest mesh with the model axis preserved and data parallelism
    shrunk to what survives.  Experts/TP shards must stay intact (their
    weights are sharded along 'model'); losing nodes costs DP width only."""
    if n_available < model_axis_size:
        raise RuntimeError(
            f"cannot keep model axis: {n_available} devices < "
            f"{model_axis_size}-way model parallelism"
        )
    dp = n_available // model_axis_size
    devices = np.array(jax.devices()[: dp * model_axis_size]).reshape(
        dp, model_axis_size
    )
    mesh = jax.sharding.Mesh(devices, axis_names)
    return Topology(mesh=mesh, data_axes=(axis_names[0],), model_axis=axis_names[1])


@dataclass
class StragglerMitigator:
    """Rolling step-time watchdog.  On real fleets the signal feeds the
    scheduler (re-shard around the slow host); here it records decisions so
    tests can assert on them."""

    window: int = 20
    threshold: float = 2.0  # step counts as straggling at 2x rolling median
    times: List[float] = field(default_factory=list)
    flagged: List[int] = field(default_factory=list)

    def record(self, step: int, dt: float) -> Optional[str]:
        self.times.append(dt)
        if len(self.times) > self.window:
            self.times.pop(0)
        if len(self.times) >= 5:
            med = sorted(self.times)[len(self.times) // 2]
            if dt > self.threshold * med:
                self.flagged.append(step)
                return "reshard_recommended"
        return None
