from repro.distributed.topology import Topology, single_device_topology

__all__ = ["Topology", "single_device_topology"]
