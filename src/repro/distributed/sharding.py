"""Partition rules: parameter / optimizer-state / batch / cache shardings.

Strategy (v5e-oriented):
  * TP/EP on the ``model`` axis: attention heads, FFN hidden, expert dim.
  * FSDP on the data axes: every large matrix additionally shards one
    non-model dim across ("pod","data"), so parameters AND optimizer state
    scale down with the full device count (ZeRO-3 semantics; XLA inserts
    the per-layer all-gathers inside the scan).
  * Divisibility-aware: any proposed axis that doesn't divide the dim is
    dropped (e.g. whisper's 8 heads on a 16-way model axis -> replicated
    heads, FSDP still applies on d_model).

Rules are matched on the parameter path (e.g. "blocks/pos0/attn/wq").
"""

from __future__ import annotations

import re
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed.topology import Topology


def _axis_size(topo: Topology, axes) -> int:
    if axes is None or topo.mesh is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= topo.mesh.shape[a]
    return n


def _fit(dim: int, axes, topo: Topology):
    """Return the largest prefix of ``axes`` that evenly divides dim (a
    3840-wide dim still FSDP-shards over 32 of 512 devices instead of
    replicating), else None."""
    if axes is None:
        return None
    if isinstance(axes, str):
        return axes if dim % _axis_size(topo, axes) == 0 else None
    t = tuple(axes)
    while t:
        if dim % _axis_size(topo, t) == 0:
            return t
        t = t[:-1]
    return None


def param_partition_spec(path: str, shape: Tuple[int, ...], topo: Topology) -> P:
    """Rule table.  ``path`` uses '/' separators; leading 'blocks/posN' etc."""
    if topo.mesh is None:
        return P()
    dp = tuple(topo.data_axes) if topo.fsdp else None
    tp = topo.model_axis
    name = path.split("/")[-1]
    in_moe = "/moe/" in path or path.endswith("moe")
    in_attn = "/attn/" in path or "/cross/" in path

    def spec(*entries):
        fitted = [
            _fit(shape[i], ax, topo) if ax is not None else None
            for i, ax in enumerate(entries)
        ]
        return P(*fitted)

    nd = len(shape)
    if name == "embed":  # [V, d]
        return spec(tp, dp)
    if name == "lm_head":  # [d, V]
        return spec(dp, tp)
    # Under sequence-parallel attention, activations carry the model axis
    # (S-sharded); non-expert weights must not (they'd force per-layer ARs).
    wtp = None if topo.seq_parallel_attn else tp
    if name in ("wq", "wk", "wv") and in_attn:  # [R, d, H|KV, hd]
        return spec(None, dp, wtp, None) if nd == 4 else spec(dp, wtp, None)
    if name == "wo" and in_attn:  # [R, H, hd, d]
        return spec(None, wtp, None, dp) if nd == 4 else spec(wtp, None, dp)
    if name in ("wi", "wg") and in_moe and nd == 4:  # [R, E, d, f]
        return spec(None, tp, dp, None)
    if name == "wo" and in_moe and nd == 4:  # [R, E, f, d]
        return spec(None, tp, None, dp)
    if name in ("wi", "wg"):  # dense/shared FFN [R, d, f] or [d, f]
        return spec(None, dp, wtp) if nd == 3 else spec(dp, wtp)
    if name == "wo":  # [R, f, d] or [f, d]
        return spec(None, wtp, dp) if nd == 3 else spec(wtp, dp)
    if name == "in_proj":  # [R, d, proj]
        return spec(None, dp, wtp)
    if name == "out_proj":  # [R, d_in, d]
        return spec(None, wtp, dp)
    # SSM split projections (head-sharded TP; see models/ssm.py)
    if name in ("w_z", "w_x", "w_dt"):  # [R, d, d_in|H]
        return spec(None, dp, wtp)
    if name == "w_bc":  # [R, d, 2gn] — shared across heads
        return spec(None, dp, None)
    if name == "conv_x":  # [R, W, d_in]
        return spec(None, None, wtp)
    if name == "conv_x_b":  # [R, d_in]
        return spec(None, wtp)
    if name in ("A_log", "D", "dt_bias") and nd == 2:  # [R, H]
        return spec(None, wtp)
    if name == "norm_w" and nd == 2:  # [R, d_in]
        return spec(None, wtp)
    if name == "w_local" and nd == 4:  # gate [R, K, d, Mk]
        return spec(None, None, dp, None)
    # everything else (norms, biases, conv, A_log, dt_bias, gate globals,
    # codecs) is small: replicate.
    return P()


def _path_str(kp) -> str:
    parts = []
    for k in kp:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_specs(params_shape: Any, topo: Topology):
    """Pytree of PartitionSpec matching a params (or ShapeDtypeStruct) tree."""
    return jax.tree_util.tree_map_with_path(
        lambda kp, leaf: param_partition_spec(_path_str(kp), leaf.shape, topo),
        params_shape,
    )


def opt_state_specs(opt_shape: Any, params_shape: Any, topo: Topology):
    """Optimizer-state shardings: adam m/v mirror the param spec; adafactor
    factored stats drop the reduced dim; scalars replicate."""
    pspecs = param_specs(params_shape, topo)

    def resolve(kp, leaf):
        path = _path_str(kp)
        if leaf.ndim == 0:
            return P()
        m = re.match(r"^(m|v|stats)/(.*?)(/vr|/vc|/v)?$", path)
        if not m:
            return P()
        sub = m.group(2)
        node = pspecs
        for part in sub.split("/"):
            if isinstance(node, dict) and part in node:
                node = node[part]
            else:
                return P()
        base = node if isinstance(node, P) else P()
        suffix = m.group(3)
        if suffix in ("/vr", "/vc"):
            # pad the spec out to the parent param's rank (leaf.ndim + 1),
            # then drop the reduced dim (vr: last; vc: second-to-last).
            ent = tuple(base) + (None,) * (leaf.ndim + 1 - len(tuple(base)))
            ent = ent[:-1] if suffix == "/vr" else ent[:-2] + ent[-1:]
            return P(*ent)
        return base

    return jax.tree_util.tree_map_with_path(resolve, opt_shape)


def fit_batch_axes(B: int, topo: Topology):
    """Largest prefix of the data axes that evenly divides B (a batch smaller
    than the full dp degree still shards over part of the mesh instead of
    replicating the compute)."""
    if topo.mesh is None:
        return None
    axes = tuple(topo.data_axes)
    while axes:
        if B % _axis_size(topo, axes) == 0:
            return axes
        axes = axes[:-1]
    return None


def batch_specs(batch_shape: Any, topo: Topology):
    """Input-batch shardings: batch dim over (a prefix of) the data axes;
    decode caches shard sequence over model (and data when batch can't)."""
    if topo.mesh is None:
        return jax.tree.map(lambda _: P(), batch_shape)
    dp = tuple(topo.data_axes)
    tp = topo.model_axis
    dp_n = _axis_size(topo, dp)

    def resolve(kp, leaf):
        path = _path_str(kp)
        name = path.split("/")[-1]
        shape = leaf.shape
        if "cache" in path or name in ("k", "v", "xk", "xv", "ssm", "conv_x",
                                       "conv_bc"):
            b_ok = shape[1] % dp_n == 0 if len(shape) > 1 else False
            all_axes = dp + ((tp,) if tp else ())
            if name in ("k", "v", "xk", "xv"):  # [R, B, W, KV, hd]
                seq_ax = (
                    _fit(shape[2], tp, topo)
                    if b_ok
                    else _fit(shape[2], all_axes, topo) or _fit(shape[2], tp, topo)
                )
                return P(None, dp if b_ok else None, seq_ax, None, None)
            if name == "ssm":  # [R, B, H, P, N]
                return P(None, dp if b_ok else None, _fit(shape[2], tp, topo), None, None)
            if name in ("conv_x", "conv_bc"):  # [R, B, W-1, ch]
                ch_ax = _fit(shape[3], tp, topo) if name == "conv_x" else None
                return P(None, dp if b_ok else None, None, ch_ax)
            if name == "lengths":
                return P(_fit(shape[0], dp, topo)) if shape else P()
        if name == "lengths":
            return P(_fit(shape[0], dp, topo)) if len(shape) == 1 else P()
        if len(shape) >= 1:
            bx = fit_batch_axes(shape[0], topo)
            if bx:
                return P(bx, *([None] * (len(shape) - 1)))
        return P()

    return jax.tree_util.tree_map_with_path(resolve, batch_shape)


def named(tree_specs, topo: Topology):
    if topo.mesh is None:
        return tree_specs
    return jax.tree.map(
        lambda s: NamedSharding(topo.mesh, s),
        tree_specs,
        is_leaf=lambda s: isinstance(s, P),
    )


# ---------------------------------------------------------------------------
# Fleet-aware cloud expert sharding (serving-time, registry-driven)
# ---------------------------------------------------------------------------


def fleet_expert_shards(
    expert_load: Sequence[float], num_servers: int
) -> list:
    """Partition experts across the multi-server cloud tier, balanced by
    *measured* load — the fleet expert registry's ``cloud_expert_load``
    (each expert weighted by the share of fleet traffic whose misses drain
    to the cloud; fleet-resident experts weigh ~0, so the hot cloud
    experts are exactly the ones no end lane holds).

    Greedy LPT: heaviest expert to the least-loaded server, expert id as
    the deterministic tie-break.  Returns ``num_servers`` sorted expert-id
    lists covering every expert exactly once — the serving-time analogue
    of the mesh-time ``[R, E, d, f] -> tp`` expert-dim rule above, but
    load-balanced instead of uniform."""
    if num_servers < 1:
        raise ValueError(f"num_servers={num_servers}")
    load = [float(x) for x in expert_load]
    shards: list = [[] for _ in range(num_servers)]
    totals = [0.0] * num_servers
    for e in sorted(range(len(load)), key=lambda e: (-load[e], e)):
        s = min(range(num_servers), key=lambda s: (totals[s], s))
        shards[s].append(e)
        totals[s] += load[e]
    return [sorted(s) for s in shards]


def shard_expert_stacks(moe_params: Dict, shards: Sequence[Sequence[int]]) -> list:
    """Slice a dense stacked expert subtree ``{"wi": [R, E, d, f], ...}``
    into per-server subtrees along the expert dim per
    :func:`fleet_expert_shards` (each server holds only its experts'
    rows).  Gate parameters stay replicated — routing needs every
    expert's logit everywhere."""
    out = []
    for shard in shards:
        idx = jnp.asarray(list(shard), jnp.int32)
        out.append(jax.tree.map(lambda leaf: leaf[:, idx], dict(moe_params)))
    return out
