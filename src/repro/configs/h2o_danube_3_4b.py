"""H2O-Danube-3-4B — dense llama+mistral mix with sliding-window attention.

[arXiv:2401.16818; unverified]  24L, d_model=3840, 32 heads (GQA kv=8),
d_ff=10240, vocab=32000.  SWA window 4096 (mistral-style), which makes the
long_500k decode cell applicable (window-bounded KV cache).
"""

from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    num_layers=24,
    d_model=3840,
    num_heads=32,
    num_kv_heads=8,
    d_ff=10240,
    vocab_size=32000,
    layer_pattern=(LayerSpec(kind="attn"),),
    sliding_window=4096,
    rope_theta=500000.0,
    mesh_policy="fsdp",
    serve_mesh_policy="serve_tp",
)
