"""Qwen2-VL-2B — VLM backbone with M-RoPE; vision frontend stubbed.

[arXiv:2409.12191; hf]  28L, d_model=1536, 12 heads (GQA kv=2,
head_dim=128), d_ff=8960, vocab=151936.  M-RoPE splits each rotary
half-dimension into (temporal, height, width) = (16, 24, 24) sections.
The ViT frontend + dynamic-resolution merger is a STUB: ``input_specs()``
provides precomputed patch embeddings [B, P, d] that are spliced in front
of the token embeddings, with per-position 3D M-RoPE indices.
"""

from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    layer_pattern=(LayerSpec(kind="attn"),),
    mrope_sections=(16, 24, 24),
    vision_patches=256,
    rope_theta=1000000.0,
    mesh_policy="fsdp",
    serve_mesh_policy="serve_tp",
)
