"""Jamba-1.5-Large (398B) — hybrid Mamba + attention 1:7 interleave, MoE.

[arXiv:2403.19887; hf]  72L, d_model=8192, 64 heads (GQA kv=8), d_ff=24576,
vocab=65536, MoE 16 experts top-2 every other layer.  The attention layer
sits at position 4 of each 8-layer block (Jamba's l=8, a=1 layout); MoE FFNs
occupy the odd positions (e=2).

Jamba uses Mamba-1 layers (d_state=16); we realize them with the unified SSD
layer (see DESIGN.md §Hardware-adaptation: SSD expresses the same recurrence
as matmul-friendly chunked scans, which is the TPU-native formulation).
"""

from repro.configs.base import LayerSpec, ModelConfig, MoEConfig, SSMConfig

_PATTERN = tuple(
    LayerSpec(kind=("attn" if i == 4 else "ssm"), moe=(i % 2 == 1))
    for i in range(8)
)

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    layer_pattern=_PATTERN,
    moe=MoEConfig(
        num_experts=16,
        top_k=2,
        d_ff_expert=24576,
        num_groups=4,
        capacity_factor=1.25,
    ),
    ssm=SSMConfig(d_state=16, expand=2, head_dim=64, chunk_size=256),
    rope_theta=10000.0,
    optimizer="adafactor",
    grad_accum=1,
)
