"""InternLM2-20B — dense GQA model.

[arXiv:2403.17297; hf]  48L, d_model=6144, 48 heads (GQA kv=8), d_ff=16384,
vocab=92544.
"""

from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="internlm2-20b",
    family="dense",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=92544,
    layer_pattern=(LayerSpec(kind="attn"),),
    rope_theta=1000000.0,
    mesh_policy="fsdp",
    serve_mesh_policy="serve_tp",
)
