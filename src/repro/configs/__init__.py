"""Architecture registry.

Each assigned architecture has a module ``repro.configs.<id>`` exposing
``CONFIG``.  ``get_config(name)`` returns the full (paper-scale) config;
``smoke_config(cfg)`` shrinks any config to a CPU-runnable size for tests.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.configs.base import (
    CompressionConfig,
    LayerSpec,
    ModelConfig,
    MoEConfig,
    SHAPE_BY_NAME,
    SHAPES,
    ShapeCell,
    SSMConfig,
    shape_applicable,
)

# Import order = canonical arch order used in reports.
from repro.configs import (  # noqa: E402
    jamba_1_5_large_398b,
    h2o_danube_3_4b,
    tinyllama_1_1b,
    internlm2_20b,
    qwen3_14b,
    llama4_scout_17b_16e,
    qwen3_moe_235b_a22b,
    whisper_base,
    qwen2_vl_2b,
    mamba2_130m,
    switch_base,
)

ARCHS: Dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        jamba_1_5_large_398b,
        h2o_danube_3_4b,
        tinyllama_1_1b,
        internlm2_20b,
        qwen3_14b,
        llama4_scout_17b_16e,
        qwen3_moe_235b_a22b,
        whisper_base,
        qwen2_vl_2b,
        mamba2_130m,
        switch_base,
    )
}

ASSIGNED_ARCHS = tuple(n for n in ARCHS if n != "switch-base")


def get_config(name: str, **overrides) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    cfg = ARCHS[name]
    return cfg.replace(**overrides) if overrides else cfg


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Shrink a config to a CPU-runnable size, preserving its *structure*
    (layer pattern, MoE grouping, SSM-ness, enc-dec-ness)."""
    kw = dict(
        num_layers=len(cfg.layer_pattern),
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        head_dim=32,
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=512,
        attn_chunk_q=64,
        attn_chunk_kv=64,
        sliding_window=96 if cfg.sliding_window else None,
    )
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe,
            num_experts=8,
            num_groups=min(cfg.moe.num_groups, 4),
            top_k=min(cfg.moe.top_k, 2),
            d_ff_expert=128,
            capacity_factor=2.0,
        )
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(
            cfg.ssm, d_state=16, head_dim=32, chunk_size=32
        )
    if cfg.encoder_decoder:
        kw["encoder_layers"] = 2
        kw["encoder_seq_len"] = 64
    if cfg.vision_patches:
        kw["vision_patches"] = 16
    if cfg.mrope_sections is not None:
        kw["mrope_sections"] = (4, 6, 6)  # sums to head_dim/2 = 16
    if cfg.compression is not None and cfg.compression.rank > 0:
        kw["compression"] = dataclasses.replace(
            cfg.compression, rank=min(cfg.compression.rank, 128 // 2)
        )
    return cfg.replace(**kw)


__all__ = [
    "ARCHS",
    "ASSIGNED_ARCHS",
    "CompressionConfig",
    "LayerSpec",
    "ModelConfig",
    "MoEConfig",
    "SHAPES",
    "SHAPE_BY_NAME",
    "ShapeCell",
    "SSMConfig",
    "get_config",
    "shape_applicable",
    "smoke_config",
]
