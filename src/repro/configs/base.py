"""Configuration dataclasses for the EC2MoE framework.

Every architecture in ``repro.configs`` is expressed as a :class:`ModelConfig`.
A model is a stack of ``block_repeat`` copies of ``layer_pattern`` (a tuple of
:class:`LayerSpec`).  Homogeneous models use a pattern of length one; hybrids
(e.g. Jamba's 1-attention : 7-mamba interleave) use a longer pattern.  The
stacked-block structure is what lets the model be lowered with a single
``jax.lax.scan`` over block parameters, keeping HLO size (and therefore
compile time at 512 devices) small.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-Experts + HL-GGN (group gate) configuration.

    ``num_groups`` is K in the paper (eq. 5-7): experts are split into K
    groups, each with its own lightweight softmax gate; a global K-way gate
    picks groups and the final probability is the product of the two stages.
    When the expert-parallel degree divides ``num_groups`` (or vice versa),
    group selection doubles as *shard* selection, which is what makes the
    dispatch all-to-all hierarchical (the TPU-native reading of the paper's
    end-cloud split).
    """

    num_experts: int
    top_k: int
    d_ff_expert: int
    num_groups: int = 1
    # Stage-1 hard group restriction: 0 = soft (paper-faithful eq. 7: final
    # probability is the product of the two stages, top-k taken globally);
    # g > 0 = only experts in the top-g groups are eligible (dispatch-locality
    # optimization, see EXPERIMENTS.md §Perf).
    group_top_k: int = 0
    shared_experts: int = 0  # always-on experts (llama4-style)
    capacity_factor: float = 1.25
    eval_capacity_factor: float = 1.0
    router_aux_weight: float = 0.01  # load-balance loss weight
    router_z_weight: float = 1e-3  # router z-loss weight
    # HL-GGN hardware-aware local selection (eq. 2-4): at most this fraction
    # of experts may be evaluated on a capability-limited device.
    local_selection_cap: float = 0.4

    def __post_init__(self):
        if self.num_experts % self.num_groups != 0:
            raise ValueError(
                f"num_experts={self.num_experts} not divisible by "
                f"num_groups={self.num_groups}"
            )

    @property
    def experts_per_group(self) -> int:
        return self.num_experts // self.num_groups


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) configuration."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk_size: int = 128  # SSD chunk length (intra-chunk quadratic)
    n_groups: int = 1  # B/C groups (Mamba-2 "G")
    head_block: int = 8  # heads processed per step (bounds the [Q,Q,hb] buffer)


@dataclass(frozen=True)
class LayerSpec:
    """One layer of the repeating block pattern."""

    kind: str = "attn"  # "attn" | "ssm"
    moe: bool = False  # FFN is a (group-gated) MoE instead of dense
    # attn-only extras
    cross_attn: bool = False  # decoder cross-attention (enc-dec models)


@dataclass(frozen=True)
class CompressionConfig:
    """PO-ECC low-rank compression (eq. 8) applied to cross-boundary traffic.

    ``rank`` is r; the encoder projects the model dimension d -> r before a
    pipeline/pod or expert-dispatch boundary and the decoder reconstructs on
    the other side.  ``boundaries`` selects which traffic is compressed.
    """

    rank: int = 0  # 0 = disabled
    boundaries: Tuple[str, ...] = ("pipeline",)  # subset of {"pipeline", "dispatch"}
    recon_weight: float = 1.0  # ||X - X_hat||^2 weight (joint training, eq. 8)
    task_weight: float = 1.0  # lambda * L_task


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    layer_pattern: Tuple[LayerSpec, ...] = (LayerSpec(),)
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    compression: Optional[CompressionConfig] = None

    # Attention details
    qk_norm: bool = False
    sliding_window: Optional[int] = None  # SWA width (tokens), None = full
    rope_theta: float = 10000.0
    mrope_sections: Optional[Tuple[int, ...]] = None  # qwen2-vl M-RoPE

    # Encoder-decoder (whisper)
    encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq_len: int = 0  # frontend-stub sequence length (e.g. 1500 frames)

    # VLM frontend stub
    vision_patches: int = 0  # precomputed patch embeddings per sample

    norm_eps: float = 1e-6
    act: str = "silu"  # silu | gelu
    ffn_gated: bool = True  # GLU-style FFN (llama family); False = 2-matrix MLP
    tie_embeddings: bool = False
    logit_softcap: float = 0.0

    # numerics
    dtype: str = "bfloat16"  # activation dtype
    param_dtype: str = "float32"

    # attention implementation
    attn_chunk_q: int = 512  # flash q-block
    attn_chunk_kv: int = 512  # flash kv-block

    # MoE implementation: "auto" | "naive" | "sorted" | "a2a" | "tp"
    #   a2a  = paper-faithful hierarchical dispatch (tokens all-to-all to
    #          expert shards; group gate stage-1 == shard selection)
    #   tp   = replicated-activation EP (local select + psum), beyond-paper
    moe_impl: str = "auto"

    # Training-step knobs (consumed by launch/steps.py and the trainer).
    optimizer: str = "adamw"  # "adamw" | "adafactor" (factored state for 100B+)
    grad_accum: int = 1  # microbatches per step (activation-memory relief)
    # Megatron-style sequence parallelism: residual stream sharded over the
    # model axis between blocks (RS+AG instead of AR; see §Perf iteration 2).
    seq_parallel: bool = False
    # Mesh-axis policy: "tp" keeps the model axis for tensor/expert
    # parallelism; "fsdp" folds the model axis into data parallelism
    # (pure ZeRO-3) — optimal for dense architectures whose sharded
    # optimizer state fits without TP; "dp" replicates params (tiny models);
    # "seqp" = TP/EP + sequence-parallel attention (attention-pure stacks).
    # Training and serving get separate policies: training wants optimizer
    # state spread (fsdp), serving wants weights resident (tp/dp).
    mesh_policy: str = "tp"
    serve_mesh_policy: str = "tp"

    def __post_init__(self):
        if self.num_layers % len(self.layer_pattern) != 0:
            raise ValueError(
                f"{self.name}: num_layers={self.num_layers} not a multiple of "
                f"pattern length {len(self.layer_pattern)}"
            )
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.num_heads, 1))
        if any(s.moe for s in self.layer_pattern) and self.moe is None:
            raise ValueError(f"{self.name}: pattern has MoE layers but moe=None")

    # -- derived -----------------------------------------------------------

    @property
    def padded_vocab_size(self) -> int:
        """Vocab rounded up so embedding/lm_head shard over model x fsdp
        axes (512 = 16 model x 32 data); the tail columns are masked to
        -inf in lm_logits and never hit by labels."""
        pad = 512
        return -(-self.vocab_size // pad) * pad

    @property
    def block_repeat(self) -> int:
        return self.num_layers // len(self.layer_pattern)

    @property
    def attn_free(self) -> bool:
        return all(s.kind != "attn" for s in self.layer_pattern)

    @property
    def sub_quadratic(self) -> bool:
        """True if long-context (500k) decode is supported: the model is
        attention-free, hybrid-SSM, or uses sliding-window attention."""
        if self.attn_free:
            return True
        if self.sliding_window is not None:
            return True
        # hybrid: any ssm layer present means the attention share is bounded
        return any(s.kind == "ssm" for s in self.layer_pattern)

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, hd = self.d_model, self.head_dim
        n = self.vocab_size * d  # embed
        if not self.tie_embeddings:
            n += d * self.vocab_size  # lm head
        n += d  # final norm

        def attn_params() -> int:
            q = d * self.num_heads * hd
            kv = 2 * d * self.num_kv_heads * hd
            o = self.num_heads * hd * d
            qk = 2 * hd if self.qk_norm else 0
            return q + kv + o + qk

        n_mats = 3 if self.ffn_gated else 2

        def dense_ffn() -> int:
            return n_mats * d * self.d_ff

        def moe_ffn() -> int:
            m = self.moe
            e = m.num_experts * n_mats * d * m.d_ff_expert
            e += m.shared_experts * n_mats * d * m.d_ff_expert
            # group gate: K group gates (M_k x d each) + global gate (K x d)
            e += m.num_experts * d + m.num_groups * d
            return e

        def ssm_params() -> int:
            s = self.ssm
            d_in = s.expand * d
            nheads = d_in // s.head_dim
            # in_proj -> [z, x, B, C, dt], conv, A, D, norm, out_proj
            zxbcdt = d * (2 * d_in + 2 * s.n_groups * s.d_state + nheads)
            conv = (d_in + 2 * s.n_groups * s.d_state) * s.d_conv
            return zxbcdt + conv + 2 * nheads + d_in + d_in * d

        per_pattern = 0
        for spec in self.layer_pattern:
            per_pattern += 2 * d  # two norms
            if spec.kind == "attn":
                per_pattern += attn_params()
                if spec.cross_attn:
                    per_pattern += attn_params() + d
            else:
                per_pattern += ssm_params()
            if spec.kind != "ssm":  # ssm blocks subsume the FFN (d_ff=0 models)
                per_pattern += moe_ffn() if spec.moe else (dense_ffn() if self.d_ff else 0)
            elif spec.moe:
                per_pattern += moe_ffn()
            elif self.d_ff:
                per_pattern += dense_ffn()
        n += per_pattern * self.block_repeat
        if self.encoder_decoder:
            n += self.encoder_layers * (2 * d + attn_params() + dense_ffn())
        return n

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed experts)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        inactive_frac = 1.0 - (m.top_k + m.shared_experts) / (
            m.num_experts + m.shared_experts
        )
        n_mats = 3 if self.ffn_gated else 2
        expert_params = m.num_experts * n_mats * self.d_model * m.d_ff_expert
        n_moe_layers = sum(1 for s in self.layer_pattern if s.moe) * self.block_repeat
        return self.param_count() - int(
            n_moe_layers * expert_params * inactive_frac
        )

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input shapes (the 4 assigned shape cells)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # "train" | "prefill" | "decode"


SHAPES: Tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", 4096, 256, "train"),
    ShapeCell("prefill_32k", 32768, 32, "prefill"),
    ShapeCell("decode_32k", 32768, 128, "decode"),
    ShapeCell("long_500k", 524288, 1, "decode"),
)

SHAPE_BY_NAME = {s.name: s for s in SHAPES}


def shape_applicable(cfg: ModelConfig, cell: ShapeCell) -> Tuple[bool, str]:
    """Whether a shape cell applies to an architecture (and why not)."""
    if cell.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch: 500k context needs sub-quadratic attention"
    return True, ""
