"""Whisper-base — encoder-decoder with a (stubbed) conv audio frontend.

[arXiv:2212.04356; unverified]  6 decoder layers (self + cross attention)
over a 6-layer bidirectional encoder; d_model=512, 8 heads (MHA), d_ff=2048,
vocab=51865.  The conv frontend is a STUB: ``input_specs()`` provides
precomputed frame embeddings [B, 1500, d] (see assignment note).  Whisper
uses non-gated GELU FFNs and learned positions; we keep GELU + RoPE-free
sinusoidal-equivalent (learned) positions for the backbone.
"""

from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    layer_pattern=(LayerSpec(kind="attn", cross_attn=True),),
    encoder_decoder=True,
    encoder_layers=6,
    encoder_seq_len=1500,
    act="gelu",
    ffn_gated=False,
    rope_theta=10000.0,
    mesh_policy="dp",
    serve_mesh_policy="dp",
)
