"""TinyLlama-1.1B — llama2-architecture small model.

[arXiv:2401.02385; hf]  22L, d_model=2048, 32 heads (GQA kv=4), d_ff=5632,
vocab=32000.
"""

from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="tinyllama-1.1b",
    family="dense",
    num_layers=22,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    d_ff=5632,
    vocab_size=32000,
    layer_pattern=(LayerSpec(kind="attn"),),
    rope_theta=10000.0,
    mesh_policy="fsdp",
    serve_mesh_policy="serve_tp",
)
