"""Mamba2-130M — attention-free SSM with SSD (state-space duality).

[arXiv:2405.21060; unverified]  24L, d_model=768, vocab=50280,
ssm_state=128, expand=2 (d_inner=1536, 24 heads of dim 64), no FFN.
Long-context decode (500k) is the native regime: constant-size recurrent
state instead of a KV cache.
"""

from repro.configs.base import LayerSpec, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=1,  # unused (attention-free)
    num_kv_heads=1,
    d_ff=0,
    vocab_size=50280,
    layer_pattern=(LayerSpec(kind="ssm"),),
    ssm=SSMConfig(d_state=128, expand=2, head_dim=64, chunk_size=256),
    tie_embeddings=True,
    mesh_policy="dp",
    serve_mesh_policy="dp",
)
