"""Qwen3-MoE-235B-A22B — 128-expert top-8 MoE with QK-norm.

[hf:Qwen/Qwen3-30B-A3B; hf]  94L, d_model=4096, 64 heads (GQA kv=4,
head_dim=128), expert d_ff=1536, vocab=151936, 128 experts top-8, no shared
expert, every layer MoE.

This is the arch most representative of HL-GGN: 128 experts split into
K=16 groups of 8 maps groups one-to-one onto a 16-way expert-parallel axis,
so stage-1 (group) routing doubles as dispatch-shard selection.
"""

from repro.configs.base import CompressionConfig, LayerSpec, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=0,  # no dense FFN layers; all layers MoE
    vocab_size=151936,
    layer_pattern=(LayerSpec(kind="attn", moe=True),),
    moe=MoEConfig(
        num_experts=128,
        top_k=8,
        d_ff_expert=1536,
        num_groups=16,
        capacity_factor=1.25,
    ),
    qk_norm=True,
    rope_theta=1000000.0,
    optimizer="adafactor",
    grad_accum=2,
    mesh_policy="seqp",
    serve_mesh_policy="seqp",
    # PO-ECC low-rank compression on the EP dispatch boundary (eq. 8):
    # rank d/4 quarters the all-to-all wire bytes; trained jointly.
    compression=CompressionConfig(rank=1024, boundaries=("dispatch",), recon_weight=0.05),
)
