"""Llama-4-Scout-17B-16E — MoE with 16 experts top-1 + shared expert.

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]  48L, d_model=5120,
40 heads (GQA kv=8), expert d_ff=8192, vocab=202048.  Every layer is MoE
(Scout's interleave step = 1) with one always-on shared expert ("early
fusion" refers to the multimodal frontend, stubbed per the assignment).
"""

from repro.configs.base import LayerSpec, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    layer_pattern=(LayerSpec(kind="attn", moe=True),),
    moe=MoEConfig(
        num_experts=16,
        top_k=1,
        d_ff_expert=8192,
        num_groups=4,
        shared_experts=1,
        capacity_factor=1.25,
    ),
    rope_theta=500000.0,
    optimizer="adafactor",
    mesh_policy="seqp",
    serve_mesh_policy="seqp",
)
