"""Qwen3-14B — dense GQA model with QK-norm.

[hf:Qwen/Qwen3-8B; hf]  40L, d_model=5120, 40 heads (GQA kv=8, head_dim=128),
d_ff=17408, vocab=151936.
"""

from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=17408,
    vocab_size=151936,
    layer_pattern=(LayerSpec(kind="attn"),),
    qk_norm=True,
    rope_theta=1000000.0,
    mesh_policy="fsdp",
    serve_mesh_policy="serve_tp",
)
