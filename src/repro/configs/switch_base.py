"""Switch-Base — the paper's own evaluation model (Switch Transformer).

[arXiv:2101.03961]  EC2MoE evaluates on Switch-Base with 8/16/32/64 experts,
top-1 routing, seq_len 256, batch 4.  We keep the canonical Switch-Base
dims (12L, d_model=768, 12H, d_ff=3072) as a decoder-only stack with MoE on
every other FFN (Switch's layout).  ``num_experts`` is varied by the
benchmark harness via ``get_config("switch-base").replace(moe=...)``.
"""

from repro.configs.base import LayerSpec, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="switch-base",
    family="moe",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=32128,
    layer_pattern=(LayerSpec(kind="attn"), LayerSpec(kind="attn", moe=True)),
    moe=MoEConfig(
        num_experts=8,
        top_k=1,
        d_ff_expert=3072,
        num_groups=4,
        capacity_factor=1.25,
    ),
    act="gelu",
    ffn_gated=False,
    rope_theta=10000.0,
)


def with_experts(num_experts: int, num_groups: int = 0) -> ModelConfig:
    """Switch-Base variant with a different expert count (paper sweeps
    8/16/32/64)."""
    import dataclasses

    if num_groups == 0:
        num_groups = max(2, num_experts // 4)
    return CONFIG.replace(
        moe=dataclasses.replace(
            CONFIG.moe, num_experts=num_experts, num_groups=num_groups
        )
    )
