"""Fault-tolerant training loop.

Composes: jitted train step (launch.steps) + data pipeline + checkpointer
(atomic/async) + StepGuard (NaN/overflow -> restore) + straggler watchdog +
elastic restart (restore the same checkpoint onto a smaller mesh, keeping
the model/EP axis intact).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import ModelConfig
from repro.distributed import sharding
from repro.distributed.fault import FailureInjector, StepGuard, StragglerMitigator
from repro.distributed.topology import Topology, single_device_topology
from repro.launch import steps as steps_mod
from repro.models.model import Model, build_model
from repro.training import optimizer as opt_mod


@dataclass
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 20
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    async_checkpoint: bool = True
    log_every: int = 10


class Trainer:
    def __init__(
        self,
        cfg: ModelConfig,
        data_iter: Iterator[Dict[str, np.ndarray]],
        *,
        topo: Optional[Topology] = None,
        trainer_cfg: Optional[TrainerConfig] = None,
        opt_cfg: Optional[opt_mod.OptimizerConfig] = None,
        failure_injector: Optional[FailureInjector] = None,
        seed: int = 0,
    ):
        self.cfg = cfg
        self.topo = topo or single_device_topology()
        self.tc = trainer_cfg or TrainerConfig()
        self.opt_cfg = opt_cfg or opt_mod.OptimizerConfig(name=cfg.optimizer)
        self.data_iter = data_iter
        self.model = build_model(cfg, self.topo)
        self.ckpt = Checkpointer(self.tc.checkpoint_dir, keep=self.tc.keep_checkpoints)
        self.guard = StepGuard()
        self.straggler = StragglerMitigator()
        self.injector = failure_injector
        self.metrics_log: list = []

        self._step_fn = None
        self._seed = seed
        self.step = 0
        self.params = None
        self.opt_state = None

    # -- state ----------------------------------------------------------------

    def _placements(self):
        params_sds, opt_sds = steps_mod.abstract_state(self.model, self.opt_cfg)
        pspec = sharding.param_specs(params_sds, self.topo)
        ospec = sharding.opt_state_specs(opt_sds, params_sds, self.topo)
        return (
            (params_sds, opt_sds),
            (sharding.named(pspec, self.topo), sharding.named(ospec, self.topo)),
        )

    def initialize(self, resume: bool = True):
        (params_sds, opt_sds), (pshard, oshard) = self._placements()
        if resume and self.ckpt.latest_step() is not None:
            self.step, (self.params, self.opt_state) = self.ckpt.restore(
                (params_sds, opt_sds),
                shardings=(pshard, oshard) if self.topo.mesh is not None else None,
            )
            if self.topo.mesh is None:
                self.params, self.opt_state = jax.tree.map(
                    jnp.asarray, (self.params, self.opt_state)
                )
        else:
            init = jax.jit(self.model.init, out_shardings=pshard if self.topo.mesh is not None else None)
            self.params = init(jax.random.PRNGKey(self._seed))
            self.opt_state = jax.jit(
                lambda p: opt_mod.init_optimizer(self.cfg.optimizer, p),
                out_shardings=oshard if self.topo.mesh is not None else None,
            )(self.params)
            self.step = 0
        return self

    def _compile_step(self, batch):
        batch_sds = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch
        )
        step = steps_mod.make_train_step(self.model, self.opt_cfg)
        if self.topo.mesh is not None:
            bspec = sharding.batch_specs(batch_sds, self.topo)
            (params_sds, opt_sds), (pshard, oshard) = self._placements()
            self._step_fn = jax.jit(
                step,
                in_shardings=(pshard, oshard, sharding.named(bspec, self.topo)),
                out_shardings=(pshard, oshard, None),
            )
        else:
            self._step_fn = jax.jit(step)

    # -- loop -----------------------------------------------------------------

    def run(self) -> Dict[str, Any]:
        restores = 0
        while self.step < self.tc.total_steps:
            batch_np = next(self.data_iter)
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            if self._step_fn is None:
                self._compile_step(batch)
            t0 = time.perf_counter()
            new_params, new_opt, metrics = self._step_fn(
                self.params, self.opt_state, batch
            )
            loss = float(metrics["loss"])
            gnorm = float(metrics.get("grad_norm", 0.0))
            if self.injector is not None:
                loss = self.injector.maybe_fail(self.step, loss)
            dt = time.perf_counter() - t0
            self.straggler.record(self.step, dt)

            if not self.guard.check(loss, gnorm):
                # bad step: drop the update, restore last good checkpoint
                restores += 1
                last = self.ckpt.latest_step()
                if last is not None:
                    self.ckpt.wait()
                    (params_sds, opt_sds), (pshard, oshard) = self._placements()
                    self.step, (self.params, self.opt_state) = self.ckpt.restore(
                        (params_sds, opt_sds),
                        shardings=(pshard, oshard)
                        if self.topo.mesh is not None
                        else None,
                    )
                    if self.topo.mesh is None:
                        self.params, self.opt_state = jax.tree.map(
                            jnp.asarray, (self.params, self.opt_state)
                        )
                continue

            self.params, self.opt_state = new_params, new_opt
            self.step += 1
            if self.step % self.tc.log_every == 0:
                self.metrics_log.append(
                    {"step": self.step, "loss": loss, "grad_norm": gnorm,
                     "step_time_s": dt}
                )
            if self.step % self.tc.checkpoint_every == 0:
                save = (
                    self.ckpt.async_save if self.tc.async_checkpoint else self.ckpt.save
                )
                save(self.step, (self.params, self.opt_state),
                     {"loss": loss, "arch": self.cfg.name})
        self.ckpt.wait()
        self.ckpt.save(self.step, (self.params, self.opt_state), {"final": True})
        return {
            "final_step": self.step,
            "restores": restores,
            "stragglers": list(self.straggler.flagged),
            "log": self.metrics_log,
        }
