"""Optimizers: AdamW and Adafactor (factored second moment), pure pytree
implementations so optimizer state inherits parameter shardings under pjit.

Adafactor is the production choice for the 100B+ architectures: its factored
second-moment statistics shrink optimizer state from 2x to ~0x parameter
size, which is what lets jamba-398B / qwen3-moe-235B train steps fit v5e HBM
at 256-512 chips (see EXPERIMENTS.md §Dry-run memory table).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"  # adamw | adafactor
    lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95  # adamw; adafactor uses decay = 1 - step^-0.8
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0


def lr_schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog)
    )
    return cfg.lr * jnp.minimum(warm, cos)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def adamw_init(params) -> Dict:
    zeros = lambda p: jnp.zeros_like(p, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(cfg: OptimizerConfig, grads, state, params):
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / (1 - b1 ** step.astype(jnp.float32))
        vh = v / (1 - b2 ** step.astype(jnp.float32))
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat = jax.tree.map(upd, grads, state["m"], state["v"], params)
    new_params = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat, is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, lr


# ---------------------------------------------------------------------------
# Adafactor (Shazeer & Stern 2018), simplified: factored 2nd moment for
# rank>=2 leaves, full for vectors; no 1st moment (beta1=0, PaLM-style).
# ---------------------------------------------------------------------------


def _factored(p) -> bool:
    return p.ndim >= 2 and p.shape[-1] >= 8 and p.shape[-2] >= 8


def adafactor_init(params) -> Dict:
    def stat(p):
        if _factored(p):
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),  # row stats
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"v": jnp.zeros_like(p, jnp.float32)}

    return {
        "stats": jax.tree.map(stat, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adafactor_update(cfg: OptimizerConfig, grads, state, params):
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)
    decay = 1.0 - jnp.power(step.astype(jnp.float32), -0.8)
    eps = 1e-30

    def upd(g, s, p):
        g = g.astype(jnp.float32)
        g2 = jnp.square(g) + eps
        if "vr" in s:
            vr = decay * s["vr"] + (1 - decay) * g2.mean(-1)
            vc = decay * s["vc"] + (1 - decay) * g2.mean(-2)
            denom = (
                vr[..., None]
                * vc[..., None, :]
                / jnp.maximum(vr.mean(-1)[..., None, None], eps)
            )
            new_s = {"vr": vr, "vc": vc}
        else:
            denom = decay * s["v"] + (1 - decay) * g2
            new_s = {"v": denom}
        delta = g * jax.lax.rsqrt(denom + eps)
        # update clipping (RMS <= 1), as in the paper
        rms = jnp.sqrt(jnp.mean(jnp.square(delta)) + eps)
        delta = delta / jnp.maximum(1.0, rms)
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), new_s

    # stats has an extra dict level per leaf; align via flatten_up_to.
    leaves_g, treedef = jax.tree.flatten(grads)
    leaves_s = treedef.flatten_up_to(state["stats"])
    leaves_p = treedef.flatten_up_to(params)
    out = [upd(g, s, p) for g, s, p in zip(leaves_g, leaves_s, leaves_p)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_stats = treedef.unflatten([o[1] for o in out])
    return new_params, {"stats": new_stats, "step": step}, lr


# ---------------------------------------------------------------------------
# Facade
# ---------------------------------------------------------------------------


def init_optimizer(name: str, params):
    if name == "adamw":
        return adamw_init(params)
    if name == "adafactor":
        return adafactor_init(params)
    raise ValueError(name)


def apply_optimizer(name: str, cfg: OptimizerConfig, grads, state, params):
    if name == "adamw":
        return adamw_update(cfg, grads, state, params)
    if name == "adafactor":
        return adafactor_update(cfg, grads, state, params)
    raise ValueError(name)
