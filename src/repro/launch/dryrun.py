"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST set the placeholder device count before any other import touches jax.
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from typing import Dict, List, Optional  # noqa: E402

import jax  # noqa: E402

from repro.configs import (  # noqa: E402
    ASSIGNED_ARCHS,
    SHAPES,
    get_config,
    shape_applicable,
)
from repro.launch.mesh import make_production_mesh, make_topology  # noqa: E402
from repro.launch import steps as steps_mod  # noqa: E402
from repro.models.model import build_model, input_specs  # noqa: E402

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# Per-device wire-bytes multiplier for a ring/N-group collective of size n
# applied to the parsed buffer size b:
#   all-gather (b = output): (n-1)/n        reduce-scatter (b = input): (n-1)/n
#   all-reduce (b = buffer): 2 (n-1)/n       all-to-all (b = buffer): (n-1)/n
#   collective-permute: 1


def _shape_bytes(type_str: str) -> int:
    m = re.match(r"([a-z0-9]+)\[([0-9,]*)\]", type_str)
    if not m:
        return 0
    dt, dims = m.group(1), m.group(2)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


def _group_size(line: str, default: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([0-9, ]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    return default


def _split_computations(hlo_text: str) -> Dict[str, List[str]]:
    """computation name -> its op lines (ENTRY included as 'ENTRY')."""
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"(%[\w.\-]+|ENTRY\s+%?[\w.\-]+)\s*\(.*\{\s*$", s)
        if m:
            name = m.group(1)
            cur = "ENTRY" if name.startswith("ENTRY") else name
            comps[cur] = []
            continue
        if s == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(s)
    return comps


def _computation_multipliers(comps: Dict[str, List[str]]) -> Dict[str, float]:
    """Execution count of each computation, accounting for while-loop trip
    counts (XLA's cost_analysis counts loop bodies once; so would a naive
    text scan).  Trip count = the s32 constant in the loop condition."""
    # call edges: computation -> [(callee, multiplier)]
    edges: Dict[str, List] = {c: [] for c in comps}
    const_re = re.compile(r"constant\((\d+)\)")
    for cname, lines in comps.items():
        for ls in lines:
            mw = re.search(r"while\(.*condition=(%[\w.\-]+), body=(%[\w.\-]+)", ls)
            if mw:
                cond, body = mw.group(1), mw.group(2)
                trip = 1
                for cl in comps.get(cond, []):
                    for c in const_re.findall(cl):
                        trip = max(trip, int(c))
                edges[cname].append((body, float(trip)))
                edges[cname].append((cond, float(trip) + 1))
                continue
            for callee in re.findall(r"(?:calls|to_apply|body|condition|branch_computations)=\{?(%[\w.\-]+)", ls):
                if callee in comps:
                    edges[cname].append((callee, 1.0))

    mult: Dict[str, float] = {c: 0.0 for c in comps}
    mult["ENTRY"] = 1.0
    # propagate in topological-ish order (iterate to fixpoint; DAG, small)
    for _ in range(len(comps)):
        changed = False
        for cname, outs in edges.items():
            if mult.get(cname, 0.0) <= 0:
                continue
            for callee, k in outs:
                want = mult[cname] * k
                if mult.get(callee, 0.0) < want:
                    mult[callee] = want
                    changed = True
        if not changed:
            break
    return mult


def parse_collectives(hlo_text: str, default_group: int,
                      detail: bool = False) -> Dict:
    """Sum estimated per-device wire bytes of every collective op, scaled by
    the execution count of its enclosing computation (while-trip corrected)."""
    comps = _split_computations(hlo_text)
    mult = _computation_multipliers(comps)
    items: List = []
    per_kind: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    counts: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    for cname, lines in comps.items():
        w = mult.get(cname, 1.0)
        if w <= 0:
            continue
        for ls in lines:
            m = re.match(
                r"%?[\w.\-]+ = ((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)) ([a-z\-]+)",
                ls,
            )
            if not m:
                continue
            kind = m.group(2)
            if kind.endswith("-start"):
                kind = kind[: -len("-start")]
            if kind not in _COLLECTIVES:
                continue
            tstr = m.group(1)
            if tstr.startswith("("):  # tuple result: sum elements
                b = sum(
                    _shape_bytes(t)
                    for t in re.findall(r"[a-z0-9]+\[[0-9,]*\]", tstr)
                )
            else:
                b = _shape_bytes(tstr)
            n = _group_size(ls, default_group)
            if n <= 1:
                continue
            frac = (n - 1) / n
            if kind == "all-reduce":
                wire = 2 * b * frac
            elif kind == "collective-permute":
                wire = b
            else:
                wire = b * frac
            per_kind[kind] += wire * w
            counts[kind] += w
            if detail:
                items.append((wire * w, kind, cname, w, b, ls[:160]))
    total = sum(per_kind.values())
    # XLA:CPU upcasts every bf16 dot/collective to f32 (no native bf16
    # kernels); the TPU target keeps them bf16.  Report a bf16-equivalent
    # number (f32 buffers halved) alongside the raw parse — the roofline
    # uses the bf16-equivalent (see EXPERIMENTS.md §Roofline-methodology).
    out = {
        "bytes_by_kind": per_kind,
        "counts": counts,
        "total_wire_bytes": total,
        "total_wire_bytes_bf16eq": total / 2.0,
    }
    if detail:
        items.sort(reverse=True)
        out["top_ops"] = [
            {"wire_bytes": it[0], "kind": it[1], "comp": it[2], "mult": it[3],
             "buf_bytes": it[4], "line": it[5]}
            for it in items[:40]
        ]
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool, verbose: bool = True):
    cfg = get_config(arch)
    cell = next(s for s in SHAPES if s.name == shape_name)
    ok, why = shape_applicable(cfg, cell)
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "mode": cell.mode,
    }
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = why
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    policy = cfg.mesh_policy if cell.mode == "train" else cfg.serve_mesh_policy
    if cell.mode != "train":
        cfg = cfg.replace(param_dtype="bfloat16")  # serving weights are bf16
    topo = make_topology(mesh, policy=policy)
    model = build_model(cfg, topo)
    specs = input_specs(cfg, cell)

    t0 = time.time()
    with jax.set_mesh(mesh):
        if cell.mode == "train":
            jitted, (params_sds, opt_sds) = steps_mod.jit_train_step(model, specs)
            lowered = jitted.lower(params_sds, opt_sds, specs)
        elif cell.mode == "prefill":
            jitted, params_sds = steps_mod.jit_prefill_step(model, specs)
            lowered = jitted.lower(params_sds, specs)
        else:  # decode
            jitted, params_sds = steps_mod.jit_decode_step(model, specs)
            lowered = jitted.lower(params_sds, specs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = parse_collectives(hlo, default_group=16)

    rec.update(
        status="ok",
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        devices=mesh.size,
        flops=float(ca.get("flops", 0.0)),
        hbm_bytes=float(ca.get("bytes accessed", 0.0)),
        collectives=coll,
        memory={
            "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
            "output_bytes": getattr(ma, "output_size_in_bytes", None),
            "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
            "peak_bytes": getattr(ma, "peak_memory_in_bytes", None),
        },
        param_count=cfg.param_count(),
        active_param_count=cfg.active_param_count(),
        global_batch=cell.global_batch,
        seq_len=cell.seq_len,
    )
    if verbose:
        mem = rec["memory"]["argument_bytes"]
        print(
            f"[dryrun] {arch} x {shape_name} x {rec['mesh']}: OK "
            f"flops/dev={rec['flops']:.3e} args/dev={(mem or 0)/2**30:.2f}GiB "
            f"coll={coll['total_wire_bytes']/2**20:.1f}MiB "
            f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)",
            flush=True,
        )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--append", action="store_true")
    args = ap.parse_args()

    archs = list(ASSIGNED_ARCHS) if args.arch == "all" else args.arch.split(",")
    shapes = (
        [s.name for s in SHAPES] if args.shape == "all" else args.shape.split(",")
    )
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    results: List[Dict] = []
    if args.append and os.path.exists(args.out):
        # keep prior successes/skips; retry error cells
        results = [
            r for r in json.load(open(args.out)) if r["status"] != "error"
        ]
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results}

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                key = (arch, shape, "multi" if mp else "single")
                if key in done:
                    continue
                try:
                    rec = run_cell(arch, shape, mp)
                except Exception as e:  # noqa: BLE001
                    rec = {
                        "arch": arch,
                        "shape": shape,
                        "mesh": "multi" if mp else "single",
                        "status": "error",
                        "error": f"{type(e).__name__}: {e}",
                        "trace": traceback.format_exc()[-2000:],
                    }
                    print(f"[dryrun] {arch} x {shape} x {rec['mesh']}: "
                          f"ERROR {rec['error']}", flush=True)
                results.append(rec)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)

    n_ok = sum(1 for r in results if r["status"] == "ok")
    n_skip = sum(1 for r in results if r["status"] == "skipped")
    n_err = sum(1 for r in results if r["status"] == "error")
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_err} errors "
          f"-> {args.out}", flush=True)
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
