"""Production meshes.

Single pod: (16, 16) = 256 v5e chips, axes (data, model).
Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model); the pod axis
extends data parallelism across DCN (or hosts pipeline stages when the
PO-ECC pipeline planner is enabled — see repro.distributed.pipeline_pp).

Defined as functions so importing this module never touches jax device
state (device count is locked at first jax init; the dry-run must set
XLA_FLAGS before anything else).
"""

from __future__ import annotations

from typing import Optional

import jax

from repro.distributed.topology import Topology


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_topology(mesh=None, *, multi_pod: bool = False,
                  policy: str = "tp") -> Topology:
    """policy="tp": (pod, data) are batch axes, "model" is TP/EP.
    policy="fsdp": every axis is a batch axis (pure ZeRO-3, no TP) — the
    right choice for dense architectures small enough that sharded optimizer
    state fits, since it eliminates all per-layer TP all-reduces."""
    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    axes = mesh.axis_names
    if policy == "fsdp":
        return Topology(mesh=mesh, data_axes=tuple(axes), model_axis=None)
    if policy == "dp":
        # pure data parallelism: params/optimizer replicated, grads
        # all-reduced — right for models whose full state fits one chip.
        return Topology(
            mesh=mesh, data_axes=tuple(axes), model_axis=None, fsdp=False
        )
    data_axes = tuple(a for a in axes if a in ("pod", "data"))
    if policy == "serve_tp":
        # weights resident (model-sharded, NOT dp-sharded): no per-layer
        # FSDP gathers at decode; right for serving models whose bf16
        # weights fit at 1/tp per chip.
        return Topology(
            mesh=mesh, data_axes=data_axes, model_axis="model", fsdp=False
        )
    if policy == "seqp":
        # model axis = EP for experts + sequence sharding for activations;
        # attention/dense weights replicate over model (FSDP over data).
        return Topology(
            mesh=mesh, data_axes=data_axes, model_axis="model",
            seq_parallel_attn=True,
        )
    if policy == "serve_seqp":
        # seqp with weights resident (no FSDP): serving models whose bf16
        # weights fit at 1/ep per chip.
        return Topology(
            mesh=mesh, data_axes=data_axes, model_axis="model",
            seq_parallel_attn=True, fsdp=False,
        )
    return Topology(mesh=mesh, data_axes=data_axes, model_axis="model")


def make_test_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh for multi-device unit tests (8 host devices)."""
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )
