"""Step builders: jit-able train / prefill / decode steps with full sharding
annotations.  Shared by the trainer, the serving engine, and the dry-run.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import sharding
from repro.distributed.loss import sharded_cross_entropy
from repro.distributed.topology import Topology, single_device_topology
from repro.models.model import Model, build_model
from repro.training import optimizer as opt_mod


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def make_loss_fn(model: Model):
    cfg = model.cfg
    compute_dtype = jnp.dtype(cfg.dtype)

    def cast_for_compute(p):
        # Cast big matrices to the compute dtype ONCE at step entry: FSDP
        # all-gathers then move bf16, not the f32 master copies (2x wire
        # bytes saved; grads still flow back in f32 to the optimizer).
        # Router/gate params stay f32 (see repro.core.gating).
        def cast(kp, leaf):
            path = "/".join(str(getattr(k, "key", k)) for k in kp)
            if "gate" in path or "codec" in path:
                return leaf
            if leaf.ndim >= 2 and leaf.dtype == jnp.float32:
                return leaf.astype(compute_dtype)
            return leaf

        return jax.tree_util.tree_map_with_path(cast, p)

    def loss_fn(params, batch, expert_mask=None):
        params = cast_for_compute(params)
        logits, aux = model.train_logits(params, batch, expert_mask=expert_mask)
        loss, metrics = sharded_cross_entropy(logits, batch["labels"], model.topo)
        total = loss + aux.get("aux_loss", jnp.zeros((), jnp.float32))
        metrics = dict(metrics)
        for k, v in aux.items():
            metrics[k] = v
        metrics["loss"] = total
        return total, metrics

    return loss_fn


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------


def make_train_step(
    model: Model, opt_cfg: Optional[opt_mod.OptimizerConfig] = None
):
    cfg = model.cfg
    opt_cfg = opt_cfg or opt_mod.OptimizerConfig(name=cfg.optimizer)
    loss_fn = make_loss_fn(model)
    accum = max(1, cfg.grad_accum)
    topo = model.topo

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)

    def pin_like_params(g):
        # Pin accumulated grads to the (FSDP-sharded) param layout so each
        # microbatch contributes via reduce-scatter into the shard instead
        # of a full all-reduce per microbatch (§Perf jamba iteration 2).
        if topo.mesh is None:
            return g
        from jax.sharding import NamedSharding

        specs = sharding.param_specs(g, topo)
        return jax.tree.map(
            lambda l, s: jax.lax.with_sharding_constraint(
                l, NamedSharding(topo.mesh, s)
            ),
            g,
            specs,
        )

    def train_step(params, opt_state, batch):
        if accum > 1:
            # Split batch into microbatches along dim 0 and scan, averaging
            # grads (keeps activation memory ~1/accum; dp sharding is on the
            # per-microbatch leading dim which stays divisible).
            micro = jax.tree.map(
                lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]),
                batch,
            )

            def mb_step(acc, mb):
                (l, metrics), g = grads_of(params, mb)
                g = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32) / accum, acc[0], g
                )
                g = pin_like_params(g)
                return (g, acc[1] + l / accum), metrics

            zero = pin_like_params(
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            )
            (grads, loss), metrics_stack = jax.lax.scan(
                mb_step, (zero, jnp.zeros((), jnp.float32)), micro
            )
            # mean over the microbatch axis ONLY: vector gate statistics
            # (expert_frac [E] / group_frac [K]) must keep their shape
            metrics = jax.tree.map(lambda m: m.mean(axis=0), metrics_stack)
        else:
            (loss, metrics), grads = grads_of(params, batch)

        grads, gnorm = opt_mod.clip_by_global_norm(grads, opt_cfg.grad_clip)
        params, opt_state, lr = opt_mod.apply_optimizer(
            cfg.optimizer, opt_cfg, grads, opt_state, params
        )
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        metrics["lr"] = lr
        return params, opt_state, metrics

    return train_step


# ---------------------------------------------------------------------------
# Serve steps
# ---------------------------------------------------------------------------


def make_prefill_step(model: Model, max_len: int = 0):
    def prefill_step(params, batch):
        return model.prefill(params, batch, max_len=max_len)

    return prefill_step


def make_decode_step(model: Model):
    def decode_step(params, batch):
        return model.decode_step(params, batch["tokens"], batch["cache"])

    return decode_step


# ---------------------------------------------------------------------------
# Shape/sharding helpers (used by trainer + dry-run)
# ---------------------------------------------------------------------------


def abstract_state(model: Model, opt_cfg=None, rng=None):
    """ShapeDtypeStructs for (params, opt_state) without allocation."""
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    params_sds = jax.eval_shape(model.init, rng)
    opt_sds = jax.eval_shape(
        functools.partial(opt_mod.init_optimizer, model.cfg.optimizer), params_sds
    )
    return params_sds, opt_sds


def jit_train_step(model: Model, batch_sds, opt_cfg=None):
    """jit(train_step) with in/out shardings derived from partition rules."""
    topo = model.topo
    params_sds, opt_sds = abstract_state(model, opt_cfg)
    pspec = sharding.param_specs(params_sds, topo)
    ospec = sharding.opt_state_specs(opt_sds, params_sds, topo)
    bspec = sharding.batch_specs(batch_sds, topo)
    step = make_train_step(model, opt_cfg)
    jitted = jax.jit(
        step,
        in_shardings=(
            sharding.named(pspec, topo),
            sharding.named(ospec, topo),
            sharding.named(bspec, topo),
        ),
        out_shardings=(
            sharding.named(pspec, topo),
            sharding.named(ospec, topo),
            None,
        ),
        donate_argnums=(0, 1),
    )
    return jitted, (params_sds, opt_sds)


def jit_prefill_step(model: Model, batch_sds, max_len: int = 0):
    topo = model.topo
    params_sds, _ = abstract_state(model)
    pspec = sharding.param_specs(params_sds, topo)
    bspec = sharding.batch_specs(batch_sds, topo)
    step = make_prefill_step(model, max_len)
    jitted = jax.jit(
        step,
        in_shardings=(sharding.named(pspec, topo), sharding.named(bspec, topo)),
    )
    return jitted, params_sds


def jit_decode_step(model: Model, batch_sds):
    topo = model.topo
    params_sds, _ = abstract_state(model)
    pspec = sharding.param_specs(params_sds, topo)
    bspec = sharding.batch_specs(batch_sds, topo)
    step = make_decode_step(model)
    out_cache_spec = bspec["cache"]
    jitted = jax.jit(
        step,
        in_shardings=(sharding.named(pspec, topo), sharding.named(bspec, topo)),
        out_shardings=(None, sharding.named(out_cache_spec, topo)),
        donate_argnums=(1,),
    )
    return jitted, params_sds
