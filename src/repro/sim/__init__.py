from repro.sim.simulator import Link, Resource, SimRequest, Stage, simulate
from repro.sim.policies import POLICIES, PolicyConfig, build_request_stages

__all__ = [
    "Link",
    "Resource",
    "SimRequest",
    "Stage",
    "simulate",
    "POLICIES",
    "PolicyConfig",
    "build_request_stages",
]
