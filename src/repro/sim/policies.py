"""Serving policies under simulation: EC2MoE and the paper's baselines.

Each policy converts one inference request (switch-base, seq 256, batch 4 —
the paper's setting) into simulator stages.  The EC2MoE policy calls the
REAL scheduling code: ``plan_pipeline_split`` (eq. 9-11) for the layer
split, ``end_mask_for`` (eq. 2-4) for local expert selection, and the eq. 8
compression ratio for boundary bytes.

Baselines:
  * BrownoutServe (cloud-based): raw input up, logits down, all compute on
    the cloud; "united experts" cut expert compute by ~30% under load.
  * EdgeMoE (end-only): all compute on the end; experts past the in-memory
    working set page in from storage (the bimodal IO cost the paper
    describes), which is what makes it collapse as E grows.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.compression import compression_ratio
from repro.core.gating import gate_flop_count
from repro.core.hardware import (
    PROFILES,
    Capability,
    DeviceProfile,
    DeviceState,
    capability,
)
from repro.core.pipeline import plan_fleet_splits, plan_pipeline_split
from repro.core.selection import end_mask_for
from repro.sim.simulator import SimRequest, Stage


@dataclass
class PolicyConfig:
    seq_len: int = 256
    batch: int = 4
    compression_rank: int = 64
    end_profile: DeviceProfile = field(default_factory=lambda: PROFILES["xeon-4214r"])
    cloud_profile: DeviceProfile = field(default_factory=lambda: PROFILES["a100"])
    end_state: DeviceState = field(default_factory=DeviceState)
    # Deployment shape: a fleet of end devices shares the cloud GPUs (the
    # paper's aggregate-throughput setting).
    n_end_devices: int = 10
    n_cloud_gpus: int = 2
    # Heterogeneous fleet (ec2moe-fleet policy): one profile per end device;
    # None -> n_end_devices copies of end_profile.
    fleet_profiles: Optional[List[DeviceProfile]] = None
    # effective fraction of peak realized at serving batch sizes
    end_efficiency: float = 0.30
    cloud_efficiency: float = 0.004  # batch-4 seq-256 MoE serving: launch-bound
    edge_mem_experts: int = 0  # 0 -> derived from the 40% selection cap
    disk_gbs: float = 1.2  # end-tier NVMe read bandwidth (EdgeMoE paging)
    brownout_saving: float = 0.30  # united-expert compute reduction
    alpha: float = 0.5
    # Jitter sensitivity of the cloud path (timeouts / head-of-line under
    # bandwidth instability).  EC2MoE's asynchronous transmission and local
    # fallback make it much less sensitive (paper §Dynamic Network).
    jitter_sensitivity: Dict[str, float] = field(
        default_factory=lambda: {"ec2moe": 0.3, "brownoutserve": 1.0, "edgemoe": 0.0}
    )


def _tokens(pc: PolicyConfig) -> int:
    return pc.seq_len * pc.batch


def _fwd_gflops(cfg: ModelConfig, pc: PolicyConfig) -> float:
    return 2.0 * cfg.active_param_count() * _tokens(pc) * 1e-9


def _expert_bytes(cfg: ModelConfig) -> float:
    mats = 3 if cfg.ffn_gated else 2
    return mats * cfg.d_model * cfg.moe.d_ff_expert * 2.0


def _eff_cap(profile: DeviceProfile, state: DeviceState, eff: float) -> Capability:
    c = capability(profile, state)
    # capability() already bakes a 30% realization; rescale to policy eff.
    return Capability(
        gflop_budget=profile.peak_gflops * eff * 1e-3 * state.cpu_free,
        mem_budget_gb=c.mem_budget_gb,
        net_gbps=c.net_gbps,
    )


def ec2moe_stages(
    cfg: ModelConfig, pc: PolicyConfig, offered_rps: float = 0.0
) -> List[Stage]:
    """Route-aware stage plan (eq. 9-11).

    Load-adaptive ("dynamically allocates inference stages ... according to
    workload", paper §PO-ECC): among splits whose fleet capacity covers
    1.3x the offered rate, pick the latency-minimal one; with no load signal
    (offered_rps = 0 -> saturation benchmark) pick the throughput-optimal
    split.
    """
    end_cap = _eff_cap(pc.end_profile, pc.end_state, pc.end_efficiency)
    cloud_cap = _eff_cap(pc.cloud_profile, DeviceState(), pc.cloud_efficiency)
    tokens = _tokens(pc)

    total = _fwd_gflops(cfg, pc)
    per_layer = total / cfg.num_layers
    # HL-GGN gate saving on the end tier (flat -> grouped, eq. 5-7).
    gf = gate_flop_count(
        cfg.d_model, cfg.moe.num_experts, cfg.moe.num_groups, cfg.moe.group_top_k
    )
    n_moe_layers = sum(1 for s in cfg.layer_pattern if s.moe) * cfg.block_repeat
    gate_saving = (gf["flat"] - gf["grouped"]) * tokens * n_moe_layers * 1e-9

    boundary_bytes = tokens * cfg.d_model * 2.0
    ratio = compression_ratio(cfg.d_model, pc.compression_rank)
    j = pc.jitter_sensitivity.get("ec2moe", 0.3)
    end_rate = end_cap.gflop_budget * 1e3
    cloud_rate = cloud_cap.gflop_budget * 1e3
    rtt_half = 0.020

    best = None
    for split in range(0, cfg.num_layers + 1):
        end_g = max(per_layer * split - min(gate_saving, per_layer * split / 2), 0.0)
        cloud_g = per_layer * (cfg.num_layers - split)
        end_t = end_g / end_rate
        cloud_t = cloud_g / cloud_rate * (1 + j * 0.2 * 2)
        wire = boundary_bytes * ratio if 0 < split < cfg.num_layers else (
            tokens * 4.0 if split == 0 else 0.0
        )
        comm_t = (rtt_half + wire * 8 / (end_cap.net_gbps * 1e9)) if (
            split < cfg.num_layers
        ) else 0.0
        latency = end_t + comm_t + cloud_t
        cap = min(
            pc.n_end_devices / end_t if end_t > 0 else float("inf"),
            pc.n_cloud_gpus / cloud_t if cloud_t > 0 else float("inf"),
            1.0 / comm_t if comm_t > 0 else float("inf"),
        )
        feasible = offered_rps <= 0 or cap >= 1.3 * offered_rps
        score = (-cap, latency) if offered_rps <= 0 else (not feasible, latency)
        if best is None or score < best[0]:
            best = (score, split, end_t, cloud_t, wire)

    _, split, end_t, cloud_t, wire = best
    stages: List[Stage] = []
    if split > 0:
        stages.append(Stage("end", end_t))
    if split < cfg.num_layers:
        stages.append(Stage("link", payload_bytes=wire))
        stages.append(Stage("cloud", cloud_t / (1 + j * 0.2 * 2), jitter=j))
        stages.append(Stage("link", payload_bytes=pc.batch * 4.0 * 16))  # result
    return stages


def brownout_stages(cfg: ModelConfig, pc: PolicyConfig) -> List[Stage]:
    cloud_cap = _eff_cap(pc.cloud_profile, DeviceState(), pc.cloud_efficiency)
    tokens = _tokens(pc)
    gflops = _fwd_gflops(cfg, pc) * (1.0 - pc.brownout_saving)
    j = pc.jitter_sensitivity.get("brownoutserve", 1.0)
    return [
        Stage("link", payload_bytes=tokens * 4.0),  # raw token ids up
        Stage("cloud", gflops / (cloud_cap.gflop_budget * 1e3), jitter=j),
        Stage("link", payload_bytes=pc.batch * 4.0 * 16),  # labels/logits down
    ]


def edgemoe_stages(cfg: ModelConfig, pc: PolicyConfig) -> List[Stage]:
    end_cap = _eff_cap(pc.end_profile, pc.end_state, pc.end_efficiency)
    gflops = _fwd_gflops(cfg, pc)
    E = cfg.moe.num_experts
    # In-memory expert working set (EdgeMoE's storage hierarchy).
    resident = pc.edge_mem_experts or max(
        1, int(np.floor(cfg.moe.local_selection_cap * E))
    )
    n_moe_layers = sum(1 for s in cfg.layer_pattern if s.moe) * cfg.block_repeat
    # Expected distinct experts activated per MoE layer for the batch:
    # coupon-collector-ish; top-1 over 1024 tokens touches most experts.
    distinct = E * (1.0 - np.exp(-_tokens(pc) * cfg.moe.top_k / E))
    misses = max(0.0, distinct - resident)
    page_in_s = n_moe_layers * misses * _expert_bytes(cfg) / (pc.disk_gbs * 1e9)
    return [Stage("end", gflops / (end_cap.gflop_budget * 1e3) + page_in_s)]


def ec2moe_stream_stages(
    cfg: ModelConfig, pc: PolicyConfig, n_decode_tokens: int = 32
) -> List[Stage]:
    """Token-level decode stages for the streaming end-cloud engine
    (``serving.stream.EndCloudServingEngine``): each decode step is an
    (end, link, cloud) triple — split by the REAL route-aware planner
    (``plan_pipeline_split``), boundary compressed at the eq. 8 ratio — and
    the simulator's resource-occupancy model reproduces the double-buffered
    overlap: steady-state step time approaches max(t_end, t_comm, t_cloud).
    """
    end_cap = _eff_cap(pc.end_profile, pc.end_state, pc.end_efficiency)
    cloud_cap = _eff_cap(pc.cloud_profile, DeviceState(), pc.cloud_efficiency)
    # per decode step the batch advances one token per sequence
    step_tokens = pc.batch
    per_layer = 2.0 * cfg.active_param_count() / cfg.num_layers * step_tokens * 1e-9
    boundary_bytes = step_tokens * cfg.d_model * 2.0
    # rank 0 means codec off (full bytes), matching the engine — not a
    # 0/d "free" ratio
    ratio = (
        compression_ratio(cfg.d_model, pc.compression_rank)
        if pc.compression_rank > 0
        else 1.0
    )
    # edge_boundary matches the engine: the embedding stays on the end and
    # the LM head on the cloud, so an activation crosses the wire at every
    # split (uncompressed at the edges — the codec only applies interior)
    plan = plan_pipeline_split(
        [per_layer] * cfg.num_layers,
        boundary_bytes,
        end_cap,
        cloud_cap,
        compression_ratio=ratio,
        alpha=pc.alpha,
        edge_boundary=True,
    )
    split = plan.split_layer
    end_t = per_layer * split / (end_cap.gflop_budget * 1e3)
    cloud_t = per_layer * (cfg.num_layers - split) / (cloud_cap.gflop_budget * 1e3)
    wire = boundary_bytes * (ratio if plan.compress_boundary else 1.0)
    jitter = pc.jitter_sensitivity.get(
        "ec2moe-stream", pc.jitter_sensitivity.get("ec2moe", 0.3)
    )
    stages: List[Stage] = []
    for _ in range(n_decode_tokens):
        if split > 0:
            stages.append(Stage("end", end_t))
        stages.append(Stage("link", payload_bytes=wire))
        stages.append(Stage("cloud", cloud_t, jitter=jitter))
    return stages


def _fleet_context(cfg: ModelConfig, pc: PolicyConfig):
    """Plan the whole fleet once: per-device caps + splits (each device
    against its ``n_cloud_gpus / n_devices`` cloud share) plus the shared
    per-layer/boundary constants — reused across all of a run's requests."""
    profiles = pc.fleet_profiles or [pc.end_profile] * pc.n_end_devices
    end_caps = [_eff_cap(p, pc.end_state, pc.end_efficiency) for p in profiles]
    cloud_cap = _eff_cap(pc.cloud_profile, DeviceState(), pc.cloud_efficiency)
    step_tokens = pc.batch
    per_layer = 2.0 * cfg.active_param_count() / cfg.num_layers * step_tokens * 1e-9
    boundary_bytes = step_tokens * cfg.d_model * 2.0
    ratio = (
        compression_ratio(cfg.d_model, pc.compression_rank)
        if pc.compression_rank > 0
        else 1.0
    )
    plans = plan_fleet_splits(
        [per_layer] * cfg.num_layers,
        boundary_bytes,
        end_caps,
        cloud_cap,
        cloud_servers=pc.n_cloud_gpus,
        compression_ratio=ratio,
        alpha=pc.alpha,
        edge_boundary=True,
    )
    return profiles, end_caps, cloud_cap, plans, per_layer, boundary_bytes, ratio


def ec2moe_fleet_stages(
    cfg: ModelConfig, pc: PolicyConfig, device: int = 0,
    n_decode_tokens: int = 32, _ctx=None,
) -> List[Stage]:
    """Token-level decode stages for ONE request served by fleet device
    ``device`` (``serving.fleet.FleetServingEngine``'s model): the split
    comes from the REAL fleet planner (``plan_fleet_splits`` — each device
    plans against its ``n_cloud_gpus / n_end_devices`` share of the cloud),
    so a weak device emits short end stages and long cloud stages while a
    strong one keeps more blocks local.  Heterogeneity is carried in the
    per-device service times; the simulator's multi-server ``end`` resource
    then approximates per-device queues FCFS, exactly like the fleet
    engine's shared ``StageTimeline``.  ``_ctx`` is a ``_fleet_context``
    result, so batch callers plan the fleet once, not once per device.
    """
    profiles, end_caps, cloud_cap, plans, per_layer, boundary_bytes, ratio = (
        _ctx if _ctx is not None else _fleet_context(cfg, pc)
    )
    d = device % len(profiles)
    plan, end_cap = plans[d], end_caps[d]
    split = plan.split_layer
    end_t = per_layer * split / (end_cap.gflop_budget * 1e3)
    cloud_t = per_layer * (cfg.num_layers - split) / (cloud_cap.gflop_budget * 1e3)
    wire = boundary_bytes * (ratio if plan.compress_boundary else 1.0)
    jitter = pc.jitter_sensitivity.get(
        "ec2moe-fleet", pc.jitter_sensitivity.get("ec2moe", 0.3)
    )
    stages: List[Stage] = []
    for _ in range(n_decode_tokens):
        if split > 0:
            stages.append(Stage("end", end_t))
        stages.append(Stage("link", payload_bytes=wire))
        stages.append(Stage("cloud", cloud_t, jitter=jitter))
    return stages


POLICIES: Dict[str, Callable[[ModelConfig, PolicyConfig], List[Stage]]] = {
    "ec2moe": ec2moe_stages,
    "ec2moe-stream": ec2moe_stream_stages,
    "ec2moe-fleet": ec2moe_fleet_stages,
    "brownoutserve": brownout_stages,
    "edgemoe": edgemoe_stages,
}


def build_request_stages(
    policy: str, cfg: ModelConfig, pc: PolicyConfig, offered_rps: float = 0.0,
    device: int = 0,
) -> List[Stage]:
    if policy == "ec2moe":
        proto = ec2moe_stages(cfg, pc, offered_rps=offered_rps)
    elif policy == "ec2moe-fleet":
        proto = ec2moe_fleet_stages(cfg, pc, device=device)
    else:
        proto = POLICIES[policy](cfg, pc)
    return [Stage(s.resource, s.service_s, s.payload_bytes, s.jitter) for s in proto]


def make_requests(
    policy: str,
    cfg: ModelConfig,
    pc: PolicyConfig,
    arrivals: np.ndarray,
    offered_rps: float = 0.0,
) -> List[SimRequest]:
    if policy == "ec2moe-fleet":
        # round-robin placement across the heterogeneous fleet; the fleet
        # is planned once and shared across every per-device proto
        ctx = _fleet_context(cfg, pc)
        protos = [
            ec2moe_fleet_stages(cfg, pc, device=i, _ctx=ctx)
            for i in range(max(len(ctx[0]), 1))
        ]
    else:
        protos = [build_request_stages(policy, cfg, pc, offered_rps)]
    return [
        SimRequest(
            i, float(t),
            [Stage(s.resource, s.service_s, s.payload_bytes, s.jitter)
             for s in protos[i % len(protos)]],
        )
        for i, t in enumerate(arrivals)
    ]
