"""Discrete-event simulator for end-cloud serving (paper figs. 5-8).

The *policies* under test (EC2MoE's route-aware scheduling, hardware-aware
selection, compression decisions) are the real algorithms from repro.core;
only device/link timing is analytic — calibrated from the paper's testbed
profiles (Xeon 4214R end, 2xA100 cloud, 300 Mbps +-20% link).

Model: each request is a sequence of stages, each bound to a resource
(end / cloud / link).  Resources are FIFO servers; a stage starts at
max(previous-stage end, resource free time).  Pipelining across requests
falls out of the queueing model — exactly the overlap PO-ECC exploits.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np


@dataclass
class Stage:
    resource: str  # "end" | "cloud" | "link"
    service_s: float = 0.0  # fixed compute time (end/cloud)
    payload_bytes: float = 0.0  # for link stages: bytes on the wire
    # Sensitivity of this stage to link jitter (timeouts / head-of-line on
    # synchronous cloud paths).  Applied as service * (1 + j * fluct * 2).
    jitter: float = 0.0


@dataclass
class SimRequest:
    request_id: int
    arrival_s: float
    stages: List[Stage]
    stage_end_s: List[float] = field(default_factory=list)

    @property
    def finish_s(self) -> float:
        return self.stage_end_s[-1] if self.stage_end_s else math.inf

    @property
    def latency_s(self) -> float:
        return self.finish_s - self.arrival_s


class Resource:
    def __init__(self, name: str, servers: int = 1):
        self.name = name
        self.free_at = [0.0] * servers

    def serve(self, ready_s: float, service_s: float) -> float:
        i = int(np.argmin(self.free_at))
        start = max(ready_s, self.free_at[i])
        end = start + service_s
        self.free_at[i] = end
        return end


class Link(Resource):
    """Shared link with RTT and time-varying bandwidth.

    bandwidth(t) = nominal * (1 + fluctuation * s(t)), s in [-1, 1] from a
    seeded low-frequency random walk — the paper's "Linux TC +-20%" setup.
    """

    def __init__(
        self,
        gbps: float,
        rtt_s: float = 0.040,
        fluctuation: float = 0.2,
        seed: int = 0,
        period_s: float = 2.0,
    ):
        super().__init__("link", servers=1)
        self.gbps = gbps
        self.rtt_s = rtt_s
        self.fluctuation = fluctuation
        rng = np.random.default_rng(seed)
        self._phase = rng.uniform(0, 2 * math.pi, size=3)
        self._weights = rng.dirichlet(np.ones(3))
        self.period_s = period_s

    def bandwidth(self, t: float) -> float:
        s = sum(
            w * math.sin(2 * math.pi * t / (self.period_s * (i + 1)) + p)
            for i, (w, p) in enumerate(zip(self._weights, self._phase))
        )
        return self.gbps * max(1.0 + self.fluctuation * s, 0.05)

    def serve_bytes(self, ready_s: float, nbytes: float) -> float:
        start = max(ready_s, self.free_at[0])
        bw = self.bandwidth(start)
        service = self.rtt_s / 2 + nbytes * 8.0 / (bw * 1e9)
        end = start + service
        self.free_at[0] = end
        return end


def simulate(
    requests: Sequence[SimRequest],
    *,
    end_servers: int = 1,
    cloud_servers: int = 2,
    link: Optional[Link] = None,
) -> Dict[str, float]:
    """Run all requests (event-driven, FCFS-by-ready-time per resource);
    returns throughput/latency metrics."""
    import heapq

    end = Resource("end", end_servers)
    cloud = Resource("cloud", cloud_servers)
    link = link or Link(0.3)
    resources = {"end": end, "cloud": cloud, "link": link}

    reqs = list(requests)
    for r in reqs:
        r.stage_end_s = [0.0] * len(r.stages)
    heap = [(r.arrival_s, i, 0) for i, r in enumerate(reqs)]
    heapq.heapify(heap)
    while heap:
        ready, i, si = heapq.heappop(heap)
        req = reqs[i]
        st = req.stages[si]
        if st.resource == "link":
            t = link.serve_bytes(ready, st.payload_bytes)
        else:
            service = st.service_s * (1.0 + st.jitter * link.fluctuation * 2.0)
            t = resources[st.resource].serve(ready, service)
        req.stage_end_s[si] = t
        if si + 1 < len(req.stages):
            heapq.heappush(heap, (t, i, si + 1))

    lat = np.array([r.latency_s for r in requests])
    makespan = max(r.finish_s for r in requests) - min(
        r.arrival_s for r in requests
    )
    return {
        "n_requests": len(requests),
        "throughput_rps": len(requests) / max(makespan, 1e-9),
        "latency_mean_s": float(lat.mean()),
        "latency_p50_s": float(np.percentile(lat, 50)),
        "latency_p95_s": float(np.percentile(lat, 95)),
        "makespan_s": float(makespan),
    }


def poisson_arrivals(rate_rps: float, n: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_rps, size=n)
    return np.cumsum(gaps)
