"""Hardware-aware capability model (paper eq. 2-3).

The paper reads a real-time device state vector

    S_device = {C_cpu, M_mem, P_power, B_bandwidth}            (eq. 2)

and predicts an inference-capability threshold T = H(S)       (eq. 3).

Here H is a calibrated linear capability model producing a *compute budget*
(GFLOP per token) and a *memory budget* (bytes of residently-evaluable
expert weights).  Two deployment readings coexist:

  * End-cloud serving (paper-faithful): each end device has a profile; the
    budget caps which/how many experts are scored locally (selection.py).
  * TPU fleet (adaptation): a heterogeneous mesh declares one profile per
    expert-parallel shard; per-shard expert masks bound what each shard
    may host/evaluate, and the group gate routes around weak shards.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class DeviceProfile:
    """Static description of a device class."""

    name: str
    peak_gflops: float  # achievable dense-matmul throughput
    mem_gb: float  # memory capacity available to weights
    mem_bw_gbs: float  # memory bandwidth
    net_gbps: float  # link bandwidth to the other tier
    power_w: float = 100.0  # power budget


# Calibration anchors (public spec sheets; used by the simulator too).
PROFILES: Dict[str, DeviceProfile] = {
    # The paper's testbed: Xeon Silver 4214R ends + A100 cloud, 300 Mbps link.
    "xeon-4214r": DeviceProfile("xeon-4214r", 1300.0, 64.0, 94.0, 0.3),
    "a100": DeviceProfile("a100", 312000.0, 80.0, 2039.0, 0.3),
    # Edge-class devices for heterogeneity sweeps.
    "jetson-orin": DeviceProfile("jetson-orin", 10000.0, 16.0, 102.0, 0.1),
    "phone-soc": DeviceProfile("phone-soc", 2000.0, 6.0, 51.0, 0.05, power_w=8.0),
    # TPU v5e chip (the dry-run target; roofline constants).
    "tpu-v5e": DeviceProfile("tpu-v5e", 197000.0, 16.0, 819.0, 50.0),
}


@dataclass(frozen=True)
class DeviceState:
    """Real-time state vector S_device (eq. 2), as utilization fractions."""

    cpu_free: float = 1.0  # C_cpu   — fraction of compute currently free
    mem_free: float = 1.0  # M_mem   — fraction of memory currently free
    power_free: float = 1.0  # P_power — fraction of power budget available
    bandwidth_free: float = 1.0  # B_bw — fraction of nominal link available

    def as_vector(self) -> np.ndarray:
        return np.array(
            [self.cpu_free, self.mem_free, self.power_free, self.bandwidth_free],
            np.float64,
        )


@dataclass(frozen=True)
class Capability:
    """T_capability (eq. 3): budgets the selection mechanism checks against."""

    gflop_budget: float  # per-token compute budget
    mem_budget_gb: float  # resident expert-weight budget
    net_gbps: float  # effective uplink


# H(.) weights: how strongly each state component modulates each budget.
# Calibrated so that a fully-free device realizes ~30% of peak per token
# batch (matmul efficiency at small batch) and power throttling is linear.
_H_COMPUTE = np.array([0.30, 0.00, 0.70, 0.00])  # cpu, mem, power, bw
_H_MEMORY = np.array([0.00, 1.00, 0.00, 0.00])


def capability(profile: DeviceProfile, state: DeviceState) -> Capability:
    """T = H(S_device)  (eq. 3)."""
    s = state.as_vector()
    compute_scale = float(_H_COMPUTE @ s)  # in [0, 1]
    mem_scale = float(_H_MEMORY @ s)
    return Capability(
        gflop_budget=profile.peak_gflops * 0.30 * compute_scale * 1e-3,
        mem_budget_gb=profile.mem_gb * mem_scale,
        net_gbps=profile.net_gbps * state.bandwidth_free,
    )


@dataclass(frozen=True)
class ExpertComplexity:
    """V_expert (paper): per-expert complexity characteristics."""

    gflop_per_token: float
    weight_bytes: int


def expert_complexity(d_model: int, d_ff: int, gated: bool = True) -> ExpertComplexity:
    mats = 3 if gated else 2
    return ExpertComplexity(
        gflop_per_token=2.0 * mats * d_model * d_ff * 1e-9,
        weight_bytes=mats * d_model * d_ff * 2,  # bf16
    )


def complexity_match(v: ExpertComplexity, t: Capability, n_resident: int) -> float:
    """f(V_expert, T_capability) (eq. 4): a scalar 'overload' score.  <= eps
    means the expert can join the locally-evaluated set given ``n_resident``
    experts already selected."""
    compute_load = v.gflop_per_token / max(t.gflop_budget, 1e-12)
    mem_load = (n_resident + 1) * v.weight_bytes / max(
        t.mem_budget_gb * 1e9, 1.0
    )
    return max(compute_load, mem_load)
