"""PO-ECC low-rank compression encoder/decoder (paper eq. 8).

Faithful 2-D form (feature maps X in R^{h x w x c}):

    Z = U^T X V,   X_hat = U_hat Z V_hat^T,
    L_rec = ||X - X_hat||^2 + lambda * L_task(X_hat)

TPU adaptation: transformer traffic is token tensors [T, d], so the framework
mostly uses the 1-D factorized variant (Z = X E, X_hat = Z D with E in
R^{d x r}) applied at communication boundaries:

  * pipeline boundary (end->cloud / pod->pod collective-permute),
  * MoE dispatch boundary (the EP all-to-all payload),

cutting transmitted bytes by r/d in each direction.  Both variants are
trained jointly with the task loss exactly as eq. 8 prescribes.

An int8 range-quantization codec is provided as a beyond-paper alternative
(2x over bf16 instead of d/r, but zero quality coupling); the route-aware
scheduler may pick either per boundary.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import truncated_normal_init


# ---------------------------------------------------------------------------
# 2-D faithful form (eq. 8 verbatim)
# ---------------------------------------------------------------------------


def init_lowrank_2d(key, h: int, w: int, r: int, dtype=jnp.float32) -> Dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    # Orthonormal-ish init so the identity is recoverable when r = min(h, w).
    u = jnp.linalg.qr(jax.random.normal(k1, (h, r)))[0]
    v = jnp.linalg.qr(jax.random.normal(k2, (w, r)))[0]
    return {
        "U": u.astype(dtype),
        "V": v.astype(dtype),
        "U_hat": u.astype(dtype),  # decoder starts as transpose-inverse of encoder
        "V_hat": v.astype(dtype),
    }


def encode_2d(params: Dict, x: jax.Array) -> jax.Array:
    """x: [..., h, w, c] -> z: [..., r, r, c]  (Z = U^T X V, per channel)."""
    return jnp.einsum(
        "hr,...hwc,ws->...rsc", params["U"].astype(x.dtype), x,
        params["V"].astype(x.dtype),
    )


def decode_2d(params: Dict, z: jax.Array) -> jax.Array:
    """z: [..., r, r, c] -> x_hat: [..., h, w, c]  (X_hat = U_hat Z V_hat^T)."""
    return jnp.einsum(
        "hr,...rsc,ws->...hwc", params["U_hat"].astype(z.dtype), z,
        params["V_hat"].astype(z.dtype),
    )


# ---------------------------------------------------------------------------
# 1-D token-tensor form (communication boundaries)
# ---------------------------------------------------------------------------


def init_lowrank_1d(key, d: int, r: int, dtype=jnp.float32) -> Dict:
    k1, _ = jax.random.split(key)
    e = jnp.linalg.qr(jax.random.normal(k1, (d, r)))[0]
    return {"enc": e.astype(dtype), "dec": e.T.astype(dtype)}


def encode_1d(params: Dict, x: jax.Array) -> jax.Array:
    return x @ params["enc"].astype(x.dtype)


def decode_1d(params: Dict, z: jax.Array) -> jax.Array:
    return z @ params["dec"].astype(z.dtype)


def roundtrip_1d(params: Dict, x: jax.Array) -> jax.Array:
    return decode_1d(params, encode_1d(params, x))


def recon_loss(x: jax.Array, x_hat: jax.Array) -> jax.Array:
    """||X - X_hat||_2^2 (mean over elements, fp32)."""
    d = (x.astype(jnp.float32) - x_hat.astype(jnp.float32))
    return jnp.mean(jnp.square(d))


def joint_loss(
    x: jax.Array,
    x_hat: jax.Array,
    task_loss: jax.Array,
    recon_weight: float = 1.0,
    task_weight: float = 1.0,
) -> jax.Array:
    """L_rec = ||X - X_hat||^2 + lambda * L_task  (eq. 8)."""
    return recon_weight * recon_loss(x, x_hat) + task_weight * task_loss


# ---------------------------------------------------------------------------
# int8 range codec (beyond-paper alternative)
# ---------------------------------------------------------------------------


def quantize_int8(x: jax.Array, axis: int = -1) -> Tuple[jax.Array, jax.Array]:
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=axis, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


# -- boundary payload quantization (second codec stage, after encode_1d) ----

BOUNDARY_SCALE_DTYPE = jnp.float16  # f16 keeps the quantized row <= 0.55x


def quantize_boundary(z: jax.Array):
    """Composable second codec stage for a pipeline-boundary payload
    ``[..., r]`` (already low-rank encoded, or raw when no codec is
    configured): symmetric int8 per row with one *f16* scale, so a row
    costs ``r + 2`` bytes on the wire instead of ``2r`` (bf16).  The scale
    is rounded to f16 *before* quantizing, making dequantization with the
    stored scale the exact inverse."""
    xf = z.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.maximum(amax / 127.0, 1e-8).astype(BOUNDARY_SCALE_DTYPE)
    q = jnp.clip(
        jnp.round(xf / scale.astype(jnp.float32)), -127, 127
    ).astype(jnp.int8)
    return q, scale


def dequantize_boundary(q: jax.Array, scale: jax.Array,
                        dtype=jnp.bfloat16) -> jax.Array:
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)).astype(dtype)


def compression_ratio(d: int, r: int, in_bits: int = 16, codec: str = "lowrank"):
    """Bytes-on-wire ratio used by the route-aware scheduler's comm model."""
    if codec == "lowrank":
        return r / d
    if codec == "int8":
        return 8 / in_bits
    if codec == "none":
        return 1.0
    raise ValueError(codec)
