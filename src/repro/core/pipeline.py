"""PO-ECC route-aware heuristic pipeline scheduling (paper eq. 9-11).

The scheduling problem: tasks t_1..t_N (inference sub-stages of in-flight
requests), each assignable to End or Cloud, with computational complexity
C(t_i) and communication cost Comm(t_i).  Objective (eq. 9):

    min sum_i [ alpha * ExecTime(t_i) + (1 - alpha) * Comm(t_i) ]

Greedy heuristic: priority P(t_i) = C(t_i) / (Comm(t_i) + eps) (eq. 10);
high-priority (compute-heavy, cheap-to-keep-local) tasks run on the end when
it has headroom (eq. 11), everything else goes to the cloud.

Two consumers:
  * the end-cloud serving engine / simulator (benchmarks fig. 5-8), where
    tasks are per-request layer-ranges;
  * the TPU pipeline planner, where "End" is the first pod (stage 0) and
    "Cloud" the rest — the same heuristic picks the layer split point and
    whether the boundary activations are compressed.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.hardware import Capability, DeviceProfile, DeviceState, capability


@dataclass(frozen=True)
class Task:
    """One schedulable inference sub-stage."""

    task_id: int
    gflops: float  # C(t_i): compute complexity
    comm_bytes: float  # Comm(t_i) input that must move if offloaded
    request_id: int = -1
    stage: str = ""  # human-readable ("gate", "experts[0:4]", "layers[8:24]")
    priority_class: int = 0  # request SLO class (0 = interactive; see serving)


@dataclass
class SchedulerConfig:
    alpha: float = 0.5  # eq. 9 compute/comm trade-off
    beta: float = 1.0  # eq. 11 priority threshold for local execution
    eps: float = 1e-6  # eq. 10 division guard
    t_end: float = 50.0  # eq. 11 max tolerable end load (GFLOP in flight)


@dataclass(frozen=True)
class Placement:
    task: Task
    location: str  # "end" | "cloud"
    exec_time_s: float
    comm_time_s: float
    priority: float


def priority(task: Task, comm_time_s: float, eps: float) -> float:
    """P(t_i) = C(t_i) / (Comm(t_i) + eps)  (eq. 10), with Comm expressed in
    seconds so the ratio is bandwidth-aware (route-awareness)."""
    return task.gflops / (comm_time_s + eps)


def exec_time(task: Task, cap: Capability) -> float:
    return task.gflops / max(cap.gflop_budget * 1e3, 1e-9)  # budget is per-ms-ish


def comm_time(task: Task, net_gbps: float, compression: float = 1.0) -> float:
    return task.comm_bytes * compression * 8.0 / max(net_gbps * 1e9, 1e-9)


def peer_link_gbps(
    gbps_a: float, gbps_b: float, *, lan_gbps: Optional[float] = None
) -> float:
    """Modeled end<->end link rate between two fleet devices.

    With a declared fleet LAN (``lan_gbps``: the devices sit behind one
    switch — the deployment where a peer slab fetch beats the cloud path)
    the LAN rate applies.  Without one, a peer transfer rides both
    devices' WAN uplinks and is bottlenecked by the slower — it can then
    never beat the direct cloud path, so cost-based source selection
    (``expertpool.FleetExpertRegistry.pick_source``) keeps the cloud."""
    if lan_gbps is not None:
        return lan_gbps
    return min(gbps_a, gbps_b)


def peer_comm_time(
    nbytes: float,
    gbps_a: float,
    gbps_b: float,
    *,
    lan_gbps: Optional[float] = None,
) -> float:
    """Wire seconds for ``nbytes`` over the modeled end<->end link."""
    rate = peer_link_gbps(gbps_a, gbps_b, lan_gbps=lan_gbps)
    return nbytes * 8.0 / max(rate * 1e9, 1e-9)


def schedule(
    tasks: Sequence[Task],
    end_cap: Capability,
    cloud_cap: Capability,
    cfg: SchedulerConfig,
    *,
    end_load: float = 0.0,
    cloud_load: float = 0.0,
    compression: float = 1.0,
) -> Tuple[List[Placement], Dict[str, float]]:
    """Greedy route-aware placement (eq. 11).

    Returns placements plus the achieved objective value (eq. 9).
    """
    placements: List[Placement] = []
    obj = 0.0
    e_load, c_load = end_load, cloud_load
    # Highest-priority first: those gain most from staying local.
    ranked = sorted(
        tasks,
        key=lambda t: -priority(t, comm_time(t, end_cap.net_gbps, compression), cfg.eps),
    )
    for t in ranked:
        ct = comm_time(t, end_cap.net_gbps, compression)
        p = priority(t, ct, cfg.eps)
        local_exec = exec_time(t, end_cap)
        remote_exec = exec_time(t, cloud_cap)
        if e_load + t.gflops <= cfg.t_end and p >= cfg.beta:
            loc, ex, cm = "end", local_exec, 0.0
            e_load += t.gflops
        else:
            loc, ex, cm = "cloud", remote_exec, ct
            c_load += t.gflops
        placements.append(Placement(t, loc, ex, cm, p))
        obj += cfg.alpha * ex + (1.0 - cfg.alpha) * cm
    stats = {
        "objective": obj,
        "end_load": e_load,
        "cloud_load": c_load,
        "n_end": sum(1 for p in placements if p.location == "end"),
        "n_cloud": sum(1 for p in placements if p.location == "cloud"),
    }
    return placements, stats


# ---------------------------------------------------------------------------
# Pipeline split planning (layer ranges -> tiers / pods)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PipelinePlan:
    """Where each layer runs and what crosses the boundary."""

    split_layer: int  # layers [0, split) on end/stage-0, rest on cloud
    compress_boundary: bool
    est_end_time_s: float
    est_cloud_time_s: float
    est_comm_time_s: float

    @property
    def est_step_time_s(self) -> float:
        # Steady-state pipelined throughput is bounded by the slowest stage.
        return max(self.est_end_time_s, self.est_cloud_time_s, self.est_comm_time_s)

    @property
    def est_latency_s(self) -> float:
        return self.est_end_time_s + self.est_comm_time_s + self.est_cloud_time_s


def plan_pipeline_split(
    layer_gflops: Sequence[float],
    boundary_bytes: float,
    end_cap: Capability,
    cloud_cap: Capability,
    *,
    compression_ratio: float = 1.0,
    alpha: float = 0.5,
    end_servers: int = 1,
    cloud_servers: int = 1,
    edge_boundary: bool = False,
    pin_split: Optional[int] = None,
    pin_compress: Optional[bool] = None,
) -> PipelinePlan:
    """Pick the layer split (and whether to compress the boundary) that
    minimizes the eq. 9 objective in its pipeline reading: weighted sum of
    bottleneck stage time (throughput) and boundary comm (latency).

    Fleet-aware extension (beyond paper): with N end devices sharing one
    cloud, the throughput bottleneck compares *per-fleet* stage rates
    (end_t / end_servers vs cloud_t / cloud_servers) while latency still
    uses per-request times.

    ``edge_boundary=True`` models executors whose edge splits still ship an
    activation (the streaming/one-shot engines keep the embedding on the end
    and the LM head on the cloud, so d_model bytes cross the wire even at
    split 0 or n — uncompressed, since the codec only applies interior).
    ``pin_split`` / ``pin_compress`` restrict the search to one split /
    compress choice (forced-split ablations, parity tests, and re-evaluating
    an incumbent plan under measured conditions) so the estimates come from
    the same formulas as the free search.
    """
    n = len(layer_gflops)
    if pin_split is not None and not 0 <= pin_split <= n:
        raise ValueError(f"pin_split={pin_split} outside [0, {n}]")
    best: Optional[PipelinePlan] = None
    best_score = None
    splits = range(0, n + 1) if pin_split is None else (pin_split,)
    compress_opts = (False, True) if pin_compress is None else (pin_compress,)
    for compress in compress_opts:
        for split in splits:
            interior = 0 < split < n
            ratio = compression_ratio if (compress and interior) else 1.0
            ct = boundary_bytes * ratio * 8.0 / max(end_cap.net_gbps * 1e9, 1e-9)
            end_t = sum(layer_gflops[:split]) / max(end_cap.gflop_budget * 1e3, 1e-9)
            cloud_t = sum(layer_gflops[split:]) / max(
                cloud_cap.gflop_budget * 1e3, 1e-9
            )
            comm = ct if (interior or edge_boundary) else 0.0
            plan = PipelinePlan(split, compress and interior, end_t, cloud_t, comm)
            bottleneck = max(
                end_t / max(end_servers, 1),
                cloud_t / max(cloud_servers, 1),
                comm,
            )
            score = alpha * bottleneck + (1 - alpha) * (comm + 0.01 * plan.est_latency_s)
            if best is None or score < best_score:
                best, best_score = plan, score
    assert best is not None
    return best


# ---------------------------------------------------------------------------
# Fleet planning (N heterogeneous end devices sharing one cloud tier)
# ---------------------------------------------------------------------------


def fleet_cloud_share(
    cloud_cap: Capability, cloud_servers: int, n_devices: int
) -> Capability:
    """Per-device view of a shared cloud tier: ``cloud_servers`` servers
    split across ``n_devices`` end devices.  Scaling the capability (rather
    than passing fractional server counts) keeps every downstream formula —
    split search, replan hysteresis, est_step_time — in per-device units."""
    share = cloud_servers / max(n_devices, 1)
    return replace(cloud_cap, gflop_budget=cloud_cap.gflop_budget * share)


def plan_fleet_splits(
    layer_gflops: Sequence[float],
    boundary_bytes: float,
    end_caps: Sequence[Capability],
    cloud_cap: Capability,
    *,
    cloud_servers: int = 1,
    compression_ratio: float = 1.0,
    alpha: float = 0.5,
    edge_boundary: bool = False,
    pin_splits: Optional[Sequence[Optional[int]]] = None,
) -> List[PipelinePlan]:
    """Route-aware split per end device (eq. 9-11), fleet reading: every
    device plans against its *share* of the cloud tier, so a weak device
    (whose end stage would bottleneck) offloads more layers while a strong
    one keeps more local — the per-device cost model the fleet engine's
    replanning re-runs when that device's link or state drifts."""
    share_cap = fleet_cloud_share(cloud_cap, cloud_servers, len(end_caps))
    plans = []
    for i, end_cap in enumerate(end_caps):
        plans.append(
            plan_pipeline_split(
                layer_gflops,
                boundary_bytes,
                end_cap,
                share_cap,
                compression_ratio=compression_ratio,
                alpha=alpha,
                edge_boundary=edge_boundary,
                pin_split=pin_splits[i] if pin_splits is not None else None,
            )
        )
    return plans


def place_fleet(
    tasks: Sequence[Task],
    end_caps: Sequence[Capability],
    cfg: SchedulerConfig,
    *,
    loads: Optional[Sequence[float]] = None,
    measured_gbps: Optional[Sequence[float]] = None,
    capacity: Optional[Sequence[int]] = None,
    max_spill: Optional[float] = None,
    order: Optional[Sequence[int]] = None,
    expert_cost: Optional[Sequence[float]] = None,
) -> Tuple[List[int], Dict[str, float]]:
    """Route-aware request placement across N end devices — ``schedule``'s
    eq. 10/11 greedy generalized from the binary end/cloud choice to a
    device fleet.

    Tasks are ranked by their best-case eq. 10 priority (compute-heavy,
    cheap-to-ship first — those gain most from a good pick) unless the
    caller passes an explicit ``order`` (task indices, used verbatim —
    serving frontends rank by (SLO class, arrival) instead: the eq. 10
    ratio reorders equal-priority requests by size, which breaks FIFO
    fairness within a class), then each goes to the device minimizing the
    eq. 9 marginal cost

        alpha * (load_d + C) / rate_d + (1 - alpha) * Comm_d

    over devices with admission ``capacity`` left, preferring devices whose
    load stays under the eq. 11 threshold ``cfg.t_end``.  ``loads`` seeds
    per-device in-flight GFLOPs, ``measured_gbps`` overrides each device's
    nominal uplink with its measured rate.  ``max_spill`` is the
    late-binding guard: when the cheapest *open* device is more than
    ``max_spill`` times worse than the fleet-wide best (which may merely be
    out of slots right now), the task is left unplaced rather than dumped
    on a straggler — a queued request can still take a good device next
    tick, a placed one cannot.  ``expert_cost`` is a per-device residency
    surcharge in seconds per task GFLOP (the fleet expert registry's
    expected expert-miss wire time, normalized by per-token compute) added
    to the marginal — request placement then sees the same fleet-wide
    residency map as the gate's group priority, steering requests toward
    lanes whose resident experts already match their traffic.  Returns one
    device index per task (-1 = leave it queued) plus stats.
    """
    n = len(end_caps)
    load = list(loads) if loads is not None else [0.0] * n
    cap_left = list(capacity) if capacity is not None else [len(tasks)] * n
    gbps = [
        (measured_gbps[d] if measured_gbps is not None else end_caps[d].net_gbps)
        for d in range(n)
    ]
    ecost = list(expert_cost) if expert_cost is not None else [0.0] * n
    if len(ecost) != n:
        raise ValueError(
            f"expert_cost has {len(ecost)} entries for {n} devices"
        )

    def marginal(t: Task, d: int) -> float:
        ex = (load[d] + t.gflops) / max(end_caps[d].gflop_budget * 1e3, 1e-9)
        cm = t.comm_bytes * 8.0 / max(gbps[d] * 1e9, 1e-9)
        return cfg.alpha * ex + (1.0 - cfg.alpha) * cm + ecost[d] * t.gflops

    if order is None:
        order = sorted(
            range(len(tasks)),
            key=lambda i: -max(
                priority(tasks[i], comm_time(tasks[i], g), cfg.eps)
                for g in gbps
            ),
        )
    elif sorted(order) != list(range(len(tasks))):
        raise ValueError("order must be a permutation of the task indices")
    assignment = [-1] * len(tasks)
    obj = 0.0
    for i in order:
        t = tasks[i]
        open_d = [d for d in range(n) if cap_left[d] > 0]
        if not open_d:
            continue
        # eq. 11 reading: devices with headroom are first-class targets;
        # only spill past the t_end threshold when every device is loaded.
        headroom = [d for d in open_d if load[d] + t.gflops <= cfg.t_end]
        best = min(headroom or open_d, key=lambda d: marginal(t, d))
        if max_spill is not None:
            best_any = min(marginal(t, d) for d in range(n))
            if marginal(t, best) > max_spill * best_any:
                # the headroom-preferred pick is poor; before waiting, fall
                # back to the cheapest open device regardless of headroom
                # (eq. 11 spills past t_end when every option is loaded)
                best = min(open_d, key=lambda d: marginal(t, d))
                if marginal(t, best) > max_spill * best_any:
                    continue  # wait for a better device to free a slot
        obj += marginal(t, best)
        assignment[i] = best
        load[best] += t.gflops
        cap_left[best] -= 1
    stats = {
        "objective": obj,
        "n_unplaced": sum(1 for a in assignment if a < 0),
        **{f"load_dev{d}": load[d] for d in range(n)},
    }
    return assignment, stats


# ---------------------------------------------------------------------------
# Replanning (dynamic load and network — paper figs. 7-8)
# ---------------------------------------------------------------------------


@dataclass
class BandwidthEstimator:
    """EWMA estimate of the effective end<->cloud link rate.  Feed it
    observed transfers (``observe(bytes, seconds)`` — the real-deployment
    path, where wire times are measurable) or direct probe readings
    (``observe_rate``, the in-process path the streaming engine's
    ``observe_bandwidth`` uses); consumers replan when the estimate drifts
    from the bandwidth the current plan was computed against."""

    nominal_gbps: float
    ewma: float = 0.3  # weight of the newest sample
    _estimate: Optional[float] = None

    def observe(self, nbytes: float, seconds: float) -> float:
        if seconds > 0 and nbytes > 0:
            return self.observe_rate(nbytes * 8.0 / seconds / 1e9)
        return self.gbps

    def observe_rate(self, gbps: float) -> float:
        """Direct rate observation (e.g. from an external link probe)."""
        if self._estimate is None:
            self._estimate = gbps
        else:
            self._estimate = (1 - self.ewma) * self._estimate + self.ewma * gbps
        return self.gbps

    def set_rate(self, gbps: float) -> float:
        """Hard rate assignment, bypassing the EWMA.  A *declared* link
        event (a blackout beginning or ending, chaos injection) is a fact,
        not a noisy sample — one EWMA observation would move the estimate
        only ``ewma`` of the way there and leave the replanner chasing the
        tail of the old rate for many ticks."""
        self._estimate = gbps
        return self.gbps

    @property
    def gbps(self) -> float:
        return self._estimate if self._estimate is not None else self.nominal_gbps

    def drift(self) -> float:
        """Relative deviation of the estimate from nominal, in [0, inf)."""
        return abs(self.gbps - self.nominal_gbps) / max(self.nominal_gbps, 1e-12)


def should_replan(
    current: PipelinePlan,
    proposed: PipelinePlan,
    *,
    rel_threshold: float = 0.15,
) -> bool:
    """True when switching plans is worth a pipeline drain: the proposed
    steady-state step time beats the current estimate by more than
    ``rel_threshold``.  The threshold applies to split moves too — it is the
    hysteresis that stops a noisy bandwidth estimate near a split tie from
    thrashing the pipeline (every adoption costs a drain plus re-jit)."""
    cur = max(current.est_step_time_s, 1e-12)
    return (cur - proposed.est_step_time_s) / cur > rel_threshold


def replan_pipeline(
    current: PipelinePlan,
    layer_gflops: Sequence[float],
    boundary_bytes: float,
    end_cap: Capability,
    cloud_cap: Capability,
    *,
    measured_gbps: Optional[float] = None,
    compression_ratio: float = 1.0,
    alpha: float = 0.5,
    rel_threshold: float = 0.15,
    edge_boundary: bool = False,
    end_servers: int = 1,
    cloud_servers: int = 1,
) -> Tuple[PipelinePlan, bool]:
    """Re-run the split search against measured link/device conditions.

    The incumbent is first *re-evaluated* under the same measured conditions
    with its split AND compress choice pinned, so stale estimates computed
    under old bandwidth never bias the comparison, and a compress toggle
    must clear the hysteresis threshold exactly like a split move (both
    cost a pipeline drain + re-jit).  Returns ``(plan, changed)``:
    ``changed`` means adopt ``plan``; when False, ``plan`` is trace-identical
    to the incumbent (same split, same compress flag) with refreshed
    estimates.  ``measured_gbps`` overrides the capability's nominal
    uplink — the measured-bandwidth feedback path.  ``end_servers`` /
    ``cloud_servers`` carry the fleet bottleneck into the split search
    (alternatively pre-scale ``cloud_cap`` via ``fleet_cloud_share``).
    """
    if measured_gbps is not None:
        end_cap = replace(end_cap, net_gbps=measured_gbps)
    kwargs = dict(
        compression_ratio=compression_ratio,
        alpha=alpha,
        edge_boundary=edge_boundary,
        end_servers=end_servers,
        cloud_servers=cloud_servers,
    )
    refreshed = plan_pipeline_split(
        layer_gflops, boundary_bytes, end_cap, cloud_cap,
        pin_split=current.split_layer,
        pin_compress=current.compress_boundary,
        **kwargs,
    )
    proposed = plan_pipeline_split(
        layer_gflops, boundary_bytes, end_cap, cloud_cap, **kwargs
    )
    if should_replan(refreshed, proposed, rel_threshold=rel_threshold):
        return proposed, True
    return refreshed, False


# ---------------------------------------------------------------------------
# Speculative decode planning (draft-k choice from measured link conditions)
# ---------------------------------------------------------------------------


def plan_spec_k(
    layer_gflops: Sequence[float],
    boundary_bytes: float,
    end_cap: Capability,
    cloud_cap: Capability,
    *,
    split: int,
    link_rtt_s: float = 0.0,
    measured_gbps: Optional[float] = None,
    compression_ratio: float = 1.0,
    acceptance: float = 0.7,
    k_max: int = 8,
    min_gain: float = 1.1,
) -> int:
    """Choose the speculative draft length k for the current plan, or 1 to
    disable speculation entirely.

    A non-speculative decode round pays end-chunk + RTT + boundary transfer
    + cloud-chunk for ONE token.  A speculative round additionally pays k
    full-model draft steps on the end tier (the end device re-runs every
    block under its resident-expert mask, so a draft token costs the whole
    stack at end-tier rate), then amortizes the round trip over the
    expected ``1 + acceptance * (k - 1)`` committed tokens.  Speculation
    only wins when the per-round fixed cost (RTT + launch) dominates the
    per-token compute — i.e. the link-bound regime.  When compute-bound
    (drafting k tokens costs more than the round trip it saves) every k > 1
    rate falls below ``min_gain`` times the k=1 rate and we return 1, which
    callers treat as "no speculative machinery at all" — zero overhead.

    Candidate k are powers of two up to ``k_max`` (matching the chunked
    verify step's jit shapes).  ``acceptance`` is the expected draft
    acceptance probability per position (the runtime feeds back an EMA).
    """
    n = len(layer_gflops)
    if not 0 <= split <= n:
        raise ValueError(f"split={split} outside [0, {n}]")
    gbps = measured_gbps if measured_gbps is not None else end_cap.net_gbps
    end_rate = max(end_cap.gflop_budget * 1e3, 1e-9)
    cloud_rate = max(cloud_cap.gflop_budget * 1e3, 1e-9)
    draft_s = sum(layer_gflops) / end_rate
    end_tok_s = sum(layer_gflops[:split]) / end_rate
    cloud_tok_s = sum(layer_gflops[split:]) / cloud_rate
    wire_s_per_tok = (
        boundary_bytes * compression_ratio * 8.0 / max(gbps * 1e9, 1e-9)
    )

    def round_s(k: int) -> float:
        # k=1 is the plain decode round: no draft pass at all.
        draft = k * draft_s if k > 1 else 0.0
        return (
            draft
            + k * end_tok_s
            + link_rtt_s
            + k * wire_s_per_tok
            + k * cloud_tok_s
        )

    base_rate = 1.0 / max(round_s(1), 1e-12)
    best_k, best_rate = 1, base_rate
    k = 2
    while k <= k_max:
        tokens = 1.0 + acceptance * (k - 1)
        rate = tokens / max(round_s(k), 1e-12)
        if rate > best_rate:
            best_k, best_rate = k, rate
        k *= 2
    if best_k > 1 and best_rate < min_gain * base_rate:
        return 1
    return best_k
