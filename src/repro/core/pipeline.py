"""PO-ECC route-aware heuristic pipeline scheduling (paper eq. 9-11).

The scheduling problem: tasks t_1..t_N (inference sub-stages of in-flight
requests), each assignable to End or Cloud, with computational complexity
C(t_i) and communication cost Comm(t_i).  Objective (eq. 9):

    min sum_i [ alpha * ExecTime(t_i) + (1 - alpha) * Comm(t_i) ]

Greedy heuristic: priority P(t_i) = C(t_i) / (Comm(t_i) + eps) (eq. 10);
high-priority (compute-heavy, cheap-to-keep-local) tasks run on the end when
it has headroom (eq. 11), everything else goes to the cloud.

Two consumers:
  * the end-cloud serving engine / simulator (benchmarks fig. 5-8), where
    tasks are per-request layer-ranges;
  * the TPU pipeline planner, where "End" is the first pod (stage 0) and
    "Cloud" the rest — the same heuristic picks the layer split point and
    whether the boundary activations are compressed.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.hardware import Capability, DeviceProfile, DeviceState, capability


@dataclass(frozen=True)
class Task:
    """One schedulable inference sub-stage."""

    task_id: int
    gflops: float  # C(t_i): compute complexity
    comm_bytes: float  # Comm(t_i) input that must move if offloaded
    request_id: int = -1
    stage: str = ""  # human-readable ("gate", "experts[0:4]", "layers[8:24]")


@dataclass
class SchedulerConfig:
    alpha: float = 0.5  # eq. 9 compute/comm trade-off
    beta: float = 1.0  # eq. 11 priority threshold for local execution
    eps: float = 1e-6  # eq. 10 division guard
    t_end: float = 50.0  # eq. 11 max tolerable end load (GFLOP in flight)


@dataclass(frozen=True)
class Placement:
    task: Task
    location: str  # "end" | "cloud"
    exec_time_s: float
    comm_time_s: float
    priority: float


def priority(task: Task, comm_time_s: float, eps: float) -> float:
    """P(t_i) = C(t_i) / (Comm(t_i) + eps)  (eq. 10), with Comm expressed in
    seconds so the ratio is bandwidth-aware (route-awareness)."""
    return task.gflops / (comm_time_s + eps)


def exec_time(task: Task, cap: Capability) -> float:
    return task.gflops / max(cap.gflop_budget * 1e3, 1e-9)  # budget is per-ms-ish


def comm_time(task: Task, net_gbps: float, compression: float = 1.0) -> float:
    return task.comm_bytes * compression * 8.0 / max(net_gbps * 1e9, 1e-9)


def schedule(
    tasks: Sequence[Task],
    end_cap: Capability,
    cloud_cap: Capability,
    cfg: SchedulerConfig,
    *,
    end_load: float = 0.0,
    cloud_load: float = 0.0,
    compression: float = 1.0,
) -> Tuple[List[Placement], Dict[str, float]]:
    """Greedy route-aware placement (eq. 11).

    Returns placements plus the achieved objective value (eq. 9).
    """
    placements: List[Placement] = []
    obj = 0.0
    e_load, c_load = end_load, cloud_load
    # Highest-priority first: those gain most from staying local.
    ranked = sorted(
        tasks,
        key=lambda t: -priority(t, comm_time(t, end_cap.net_gbps, compression), cfg.eps),
    )
    for t in ranked:
        ct = comm_time(t, end_cap.net_gbps, compression)
        p = priority(t, ct, cfg.eps)
        local_exec = exec_time(t, end_cap)
        remote_exec = exec_time(t, cloud_cap)
        if e_load + t.gflops <= cfg.t_end and p >= cfg.beta:
            loc, ex, cm = "end", local_exec, 0.0
            e_load += t.gflops
        else:
            loc, ex, cm = "cloud", remote_exec, ct
            c_load += t.gflops
        placements.append(Placement(t, loc, ex, cm, p))
        obj += cfg.alpha * ex + (1.0 - cfg.alpha) * cm
    stats = {
        "objective": obj,
        "end_load": e_load,
        "cloud_load": c_load,
        "n_end": sum(1 for p in placements if p.location == "end"),
        "n_cloud": sum(1 for p in placements if p.location == "cloud"),
    }
    return placements, stats


# ---------------------------------------------------------------------------
# Pipeline split planning (layer ranges -> tiers / pods)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PipelinePlan:
    """Where each layer runs and what crosses the boundary."""

    split_layer: int  # layers [0, split) on end/stage-0, rest on cloud
    compress_boundary: bool
    est_end_time_s: float
    est_cloud_time_s: float
    est_comm_time_s: float

    @property
    def est_step_time_s(self) -> float:
        # Steady-state pipelined throughput is bounded by the slowest stage.
        return max(self.est_end_time_s, self.est_cloud_time_s, self.est_comm_time_s)

    @property
    def est_latency_s(self) -> float:
        return self.est_end_time_s + self.est_comm_time_s + self.est_cloud_time_s


def plan_pipeline_split(
    layer_gflops: Sequence[float],
    boundary_bytes: float,
    end_cap: Capability,
    cloud_cap: Capability,
    *,
    compression_ratio: float = 1.0,
    alpha: float = 0.5,
    end_servers: int = 1,
    cloud_servers: int = 1,
) -> PipelinePlan:
    """Pick the layer split (and whether to compress the boundary) that
    minimizes the eq. 9 objective in its pipeline reading: weighted sum of
    bottleneck stage time (throughput) and boundary comm (latency).

    Fleet-aware extension (beyond paper): with N end devices sharing one
    cloud, the throughput bottleneck compares *per-fleet* stage rates
    (end_t / end_servers vs cloud_t / cloud_servers) while latency still
    uses per-request times.
    """
    n = len(layer_gflops)
    best: Optional[PipelinePlan] = None
    best_score = None
    for compress in (False, True):
        ratio = compression_ratio if compress else 1.0
        ct = boundary_bytes * ratio * 8.0 / max(end_cap.net_gbps * 1e9, 1e-9)
        for split in range(0, n + 1):
            end_t = sum(layer_gflops[:split]) / max(end_cap.gflop_budget * 1e3, 1e-9)
            cloud_t = sum(layer_gflops[split:]) / max(
                cloud_cap.gflop_budget * 1e3, 1e-9
            )
            comm = ct if 0 < split < n else 0.0
            plan = PipelinePlan(split, compress and 0 < split < n, end_t, cloud_t, comm)
            bottleneck = max(
                end_t / max(end_servers, 1),
                cloud_t / max(cloud_servers, 1),
                comm,
            )
            score = alpha * bottleneck + (1 - alpha) * (comm + 0.01 * plan.est_latency_s)
            if best is None or score < best_score:
                best, best_score = plan, score
    assert best is not None
    return best
