"""Hardware-aware local expert selection (paper eq. 4).

    E_local = { e_i | f(V_expert_i, T_capability) <= eps }

capped at ``local_selection_cap`` (the paper uses 40%) of the expert set.
Experts are admitted greedily *by whole groups* so the selected set stays
aligned with the HL-GGN group structure (and, on TPU, with expert-parallel
shards — selecting whole groups keeps dispatch local).

Masks are plain boolean arrays consumed by ``core.gating`` (masked experts
get -inf gate logits) and by the serving engine (masked experts are never
evaluated on the end tier).
"""

from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro.core.hardware import (
    Capability,
    DeviceProfile,
    DeviceState,
    ExpertComplexity,
    capability,
    complexity_match,
    expert_complexity,
)


def local_expert_mask(
    v: ExpertComplexity,
    cap: Capability,
    num_experts: int,
    num_groups: int,
    *,
    eps: float = 1.0,
    selection_cap: float = 0.4,
    group_priority: Optional[Sequence[int]] = None,
) -> np.ndarray:
    """Boolean [E] mask of experts admitted for local (end-side) evaluation.

    ``group_priority``: group indices in decreasing preference (e.g. from
    historical routing frequency); defaults to natural order.
    """
    E, K = num_experts, num_groups
    Mk = E // K
    max_local = int(np.floor(selection_cap * E))
    mask = np.zeros((E,), bool)
    order = list(group_priority) if group_priority is not None else list(range(K))
    n_resident = 0
    for g in order:
        for j in range(Mk):
            if n_resident >= max_local:
                return mask
            if complexity_match(v, cap, n_resident) <= eps:
                mask[g * Mk + j] = True
                n_resident += 1
            else:
                return mask
    return mask


def end_mask_for(
    profile: DeviceProfile,
    state: DeviceState,
    d_model: int,
    d_ff_expert: int,
    num_experts: int,
    num_groups: int,
    *,
    gated: bool = True,
    eps: float = 1.0,
    selection_cap: float = 0.4,
    group_priority: Optional[Sequence[int]] = None,
) -> np.ndarray:
    """Convenience: profile+state -> expert mask (the full eq. 2-4 path)."""
    cap = capability(profile, state)
    v = expert_complexity(d_model, d_ff_expert, gated)
    return local_expert_mask(
        v,
        cap,
        num_experts,
        num_groups,
        eps=eps,
        selection_cap=selection_cap,
        group_priority=group_priority,
    )


def group_priority_from_freq(
    group_freq: Optional[np.ndarray],
    num_groups: int,
    group_cost: Optional[np.ndarray] = None,
) -> Sequence[int]:
    """Group order for the eq. 4 greedy admit from *measured* stage-1
    routing frequencies (the gate's ``group_frac`` statistic, EMA'd by the
    serving engines): most-routed group first, stable natural order on
    ties — and exactly natural order when nothing has been measured yet,
    so cold engines behave as before.

    ``group_cost`` (optional, [K] >= 0) is a per-group *placement* cost —
    the fleet expert registry's modeled wire seconds to make the group's
    experts resident (0 for already-resident groups).  Both signals are
    normalized to sum 1 and the score is ``freq - 0.5 * cost``: among
    similarly-routed groups the cheap-to-place (already resident, or
    peer-servable) ones are admitted first, so routing sees the same
    fleet residency map as request placement.  All-zero costs (everything
    resident) leave the pure-frequency order unchanged."""
    if group_freq is None:
        return list(range(num_groups))
    f = np.asarray(group_freq, np.float64)
    if f.shape != (num_groups,) or not np.isfinite(f).all():
        return list(range(num_groups))
    score = f / s if (s := float(f.sum())) > 0 else f
    if group_cost is not None:
        c = np.asarray(group_cost, np.float64)
        if c.shape == (num_groups,) and np.isfinite(c).all() and c.sum() > 0:
            score = score - 0.5 * c / float(c.sum())
    return [int(g) for g in np.argsort(-score, kind="stable")]


def validate_expert_mask(
    mask,
    num_experts: Optional[int] = None,
    *,
    where: str = "end tier",
):
    """Reject an expert target mask that selects no experts — loudly and
    identically on every engine path.

    An all-False mask is silently pathological either way it is consumed:
    a dense engine hands the gate all ``-inf`` logits and the softmax
    *renormalizes to uniform* weights over the very experts the mask
    excluded, while a pooled engine routes every token to the zero garbage
    slab and emits garbage activations.  Neither is the configuration
    anyone asked for, and the two paths silently diverge — so both
    validate here at the engine boundary instead.  ``None`` (dense model /
    no masking) passes through."""
    if mask is None:
        return None
    m = np.asarray(mask)
    if m.ndim != 1:
        raise ValueError(
            f"{where}: expert mask must be 1-D [E], got shape {m.shape}"
        )
    if num_experts is not None and m.shape[0] != num_experts:
        raise ValueError(
            f"{where}: expert mask has {m.shape[0]} entries for "
            f"{num_experts} experts"
        )
    if not m.astype(bool).any():
        raise ValueError(
            f"{where}: expert mask selects no experts — a dense gate would "
            "silently renormalize to uniform weights over the excluded "
            "experts while a pooled end tier routes every token to the "
            "zero garbage slab; widen selection_eps, fix the device state, "
            "or drop the mask entirely"
        )
    return mask


def residency_target(
    mask: np.ndarray,
    resident: np.ndarray,
) -> np.ndarray:
    """Effective routing mask of a pooled end tier: the eq. 4 mask is the
    *target set*; only its resident subset may actually be routed to (the
    jitted path computes the same thing in-trace from the resident slot
    tables — this host-side form exists for planning and tests)."""
    return np.asarray(mask, bool) & np.asarray(resident, bool)


def fleet_device_mask(
    profile: DeviceProfile,
    state: DeviceState,
    d_model: int,
    d_ff_expert: int,
    num_experts: int,
    num_groups: int,
    **kw,
) -> np.ndarray:
    """One device's slice of the fleet mask: the eq. 2-4 hardware mask with
    the fleet's never-empty guarantee — a device whose budget admits no
    expert still exposes its first one (the runtime re-balances via the
    group gate's load-balance loss).  The fleet serving engine re-derives
    masks through this on per-device state updates so they stay consistent
    with ``shard_masks_for_fleet``."""
    m = end_mask_for(
        profile, state, d_model, d_ff_expert, num_experts, num_groups, **kw
    )
    if not m.any():
        m = m.copy()
        m[0] = True
    return m


def shard_masks_for_fleet(
    profiles: Sequence[DeviceProfile],
    states: Sequence[DeviceState],
    d_model: int,
    d_ff_expert: int,
    num_experts: int,
    num_groups: int,
    **kw,
) -> np.ndarray:
    """Heterogeneous-mesh adaptation: one mask per expert-parallel shard /
    fleet device, [n_shards, E]."""
    return np.stack(
        [
            fleet_device_mask(
                p, s, d_model, d_ff_expert, num_experts, num_groups, **kw
            )
            for p, s in zip(profiles, states)
        ]
    )
