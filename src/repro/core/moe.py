"""Group-gated Mixture-of-Experts layer (HL-GGN routing + EC2MoE dispatch).

Four execution paths, selected by ``cfg.moe_impl`` (or automatically):

  * ``naive``  — loop over experts, mask-and-sum.  O(E) compute; the oracle.
  * ``sorted`` — single-shard dropless grouped GEMM: argsort assignments by
                 expert, ``jax.lax.ragged_dot``, scatter-combine.
  * ``a2a``    — paper-faithful expert parallelism: tokens are de-replicated
                 across the model axis, assignments are packed into fixed
                 per-destination capacity buffers, exchanged with
                 ``all_to_all`` (optionally LOW-RANK COMPRESSED, eq. 8),
                 computed by the owning shard, and returned.  Stage-1 of the
                 group gate selects groups == shards, so ``group_top_k``
                 directly bounds dispatch fan-out — the end-cloud insight
                 mapped onto the ICI.
  * ``tp``     — replicated-activation EP: every model shard selects the
                 assignments that hit its local experts from the (model-axis
                 replicated) activations, computes, and psums.  No all-to-all;
                 comm is one [t, d] all-reduce like a Megatron TP FFN.

All paths share the same parameters and the same HL-GGN gate, and agree
numerically when no tokens are dropped (property-tested).
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import compression as comp
from repro.core import gating
from repro.distributed.topology import Topology
from repro.models.layers import ACTIVATIONS, truncated_normal_init


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def init_moe(key, cfg, dtype=None) -> Dict:
    m = cfg.moe
    dtype = dtype or jnp.dtype(cfg.param_dtype)
    d, f, E = cfg.d_model, m.d_ff_expert, m.num_experts
    kg, ki, kgt, ko, ks, kc = jax.random.split(key, 6)
    p = {
        "gate": gating.init_group_gate(kg, d, m, jnp.float32),
        "wi": truncated_normal_init(ki, (E, d, f), dtype, 1.0),
        "wo": truncated_normal_init(ko, (E, f, d), dtype, 1.0),
    }
    if cfg.ffn_gated:
        p["wg"] = truncated_normal_init(kgt, (E, d, f), dtype, 1.0)
    if m.shared_experts:
        from repro.models.layers import init_mlp

        p["shared"] = init_mlp(
            ks, d, m.shared_experts * f, dtype, gated=cfg.ffn_gated
        )
    if _dispatch_compressed(cfg):
        p["codec"] = comp.init_lowrank_1d(kc, d, cfg.compression.rank, jnp.float32)
    return p


def _dispatch_compressed(cfg) -> bool:
    c = cfg.compression
    return c is not None and c.rank > 0 and "dispatch" in c.boundaries


def _capacity(n_assign: int, buckets: int, factor: float) -> int:
    c = int(-(-n_assign * factor // buckets))  # ceil
    return max(8, -(-c // 8) * 8)  # round up to a multiple of 8


# ---------------------------------------------------------------------------
# Expert FFN on grouped (sorted) tokens
# ---------------------------------------------------------------------------


def _grouped_mlp(
    xs: jax.Array,  # [n, d] sorted by expert
    group_sizes: jax.Array,  # [E] int32
    wi: jax.Array,  # [E, d, f]
    wg: Optional[jax.Array],
    wo: jax.Array,  # [E, f, d]
    act: str,
) -> jax.Array:
    a = ACTIVATIONS[act]
    h = jax.lax.ragged_dot(xs, wi.astype(xs.dtype), group_sizes)
    if wg is not None:
        h = a(h) * jax.lax.ragged_dot(xs, wg.astype(xs.dtype), group_sizes)
    else:
        h = a(h)
    return jax.lax.ragged_dot(h, wo.astype(xs.dtype), group_sizes)


def _sorted_expert_ffn(
    x_rows: jax.Array,  # [n, d] unsorted assignment payloads
    eid: jax.Array,  # [n] int32 expert of each row
    num_experts: int,
    params: Dict,
    act: str,
) -> jax.Array:
    """Sort rows by expert, grouped-GEMM, unsort.  Returns [n, d]."""
    order = jnp.argsort(eid)
    gs = jnp.bincount(eid, length=num_experts).astype(jnp.int32)
    y_sorted = _grouped_mlp(
        x_rows[order], gs, params["wi"], params.get("wg"), params["wo"], act
    )
    return jnp.zeros_like(y_sorted).at[order].set(y_sorted)


# ---------------------------------------------------------------------------
# naive / sorted single-shard paths
# ---------------------------------------------------------------------------


def moe_naive(params: Dict, x: jax.Array, cfg, expert_mask=None):
    """Oracle: every expert evaluates every token; combine by gate weight."""
    m = cfg.moe
    T = x.shape[0]
    out = gating.gate(params["gate"], x, m, expert_mask)
    cw = jnp.zeros((T, m.num_experts), jnp.float32)
    cw = cw.at[jnp.arange(T)[:, None], out.topk_idx].set(
        out.topk_weight.astype(jnp.float32)
    )
    y = jnp.zeros_like(x, dtype=jnp.float32)
    for e in range(m.num_experts):
        pe = {
            "wi": params["wi"][e],
            "wo": params["wo"][e],
        }
        h = x @ pe["wi"].astype(x.dtype)
        a = ACTIVATIONS[cfg.act]
        if "wg" in params:
            h = a(h) * (x @ params["wg"][e].astype(x.dtype))
        else:
            h = a(h)
        ye = h @ pe["wo"].astype(x.dtype)
        y = y + cw[:, e : e + 1] * ye.astype(jnp.float32)
    return y.astype(x.dtype), out.aux


def moe_sorted(params: Dict, x: jax.Array, cfg, expert_mask=None):
    """Single-shard dropless path (also the oracle for the EP paths).

    When a dispatch codec is configured, the payload goes through the same
    encode -> (wire) -> decode roundtrip the EP path would apply, so the
    compression's quality effect is observable on one device and the eq. 8
    reconstruction term lands in ``aux["recon_loss"]`` for joint training.
    """
    m = cfg.moe
    T, d = x.shape
    k = m.top_k
    out = gating.gate(params["gate"], x, m, expert_mask)
    flat_e = out.topk_idx.reshape(-1)  # [T*k]
    tok = jnp.arange(T * k) // k
    rows = x[tok]
    aux = dict(out.aux)
    codec = params.get("codec")
    if codec is not None:
        sent = comp.roundtrip_1d(codec, rows).astype(x.dtype)
        aux["recon_loss"] = comp.recon_loss(rows, sent)
        rows = sent
    y_rows = _sorted_expert_ffn(rows, flat_e, m.num_experts, params, cfg.act)
    if codec is not None:
        back = comp.roundtrip_1d(codec, y_rows).astype(y_rows.dtype)
        aux["recon_loss"] = aux["recon_loss"] + comp.recon_loss(y_rows, back)
        y_rows = back
        c = cfg.compression
        aux["aux_loss"] = aux["aux_loss"] + c.recon_weight * aux["recon_loss"]
    w = out.topk_weight.reshape(-1, 1).astype(y_rows.dtype)
    y = jax.ops.segment_sum(y_rows * w, tok, num_segments=T)
    return y.astype(x.dtype), aux


def moe_resident(params: Dict, x: jax.Array, cfg, expert_mask=None):
    """Pooled end-tier path: sorted dispatch over the *resident* sub-table.

    ``params["resident"]`` carries the expert pool's device view
    (``core.expertpool``): ``store`` — slab storage ``[N + 1, ...]`` per
    weight matrix (last row = zero garbage slab), ``ids [S + 1]`` — the
    layer's resident slot -> physical slab gather index, ``slot [E]`` —
    expert id -> resident slot with non-residents mapped to the garbage
    slot ``S``.  The effective routing mask is computed in-trace as
    ``expert_mask AND (slot < S)``, so non-resident experts are routed
    away exactly as eq. 4-masked experts are on the dense path, and the
    weight gather reads only resident slab rows: compute and HBM traffic
    scale with residents, not ``E``.  For any resident superset of the
    routed experts this is bit-identical to ``moe_sorted`` under the same
    mask (greedy-parity-tested through the serving engines)."""
    m = cfg.moe
    T, d = x.shape
    k = m.top_k
    res = params["resident"]
    ids, slot_of = res["ids"], res["slot"]
    S = ids.shape[0] - 1
    resident_ok = slot_of < S  # [E] in-trace residency mask
    if expert_mask is not None:
        eff_mask = jnp.logical_and(jnp.asarray(expert_mask, bool), resident_ok)
    else:
        eff_mask = resident_ok
    out = gating.gate(params["gate"], x, m, eff_mask)
    flat_e = out.topk_idx.reshape(-1)  # [T*k]
    slots = slot_of[flat_e]  # [T*k] -> garbage slot S for non-residents
    tok = jnp.arange(T * k) // k
    rows = x[tok]
    aux = dict(out.aux)
    codec = params.get("codec")
    if codec is not None:
        sent = comp.roundtrip_1d(codec, rows).astype(x.dtype)
        aux["recon_loss"] = comp.recon_loss(rows, sent)
        rows = sent
    # gather ONLY the resident slabs (plus the shared zero garbage row)
    store = res["store"]
    wi = store["wi"][ids]  # [S+1, d, f]
    wg = store["wg"][ids] if "wg" in store else None
    wo = store["wo"][ids]  # [S+1, f, d]
    if "wi_scale" in store:
        # int8 slab store: the HBM gather reads int8 codes; dequantize just
        # the S+1 gathered slabs (per-output-column fp32 scales, exact
        # modulo the int8 grid) before the grouped GEMM
        wi = wi.astype(jnp.float32) * store["wi_scale"][ids][:, None, :]
        wo = wo.astype(jnp.float32) * store["wo_scale"][ids][:, None, :]
        if wg is not None:
            wg = wg.astype(jnp.float32) * store["wg_scale"][ids][:, None, :]
    order = jnp.argsort(slots)
    gs = jnp.bincount(slots, length=S + 1).astype(jnp.int32)
    y_sorted = _grouped_mlp(rows[order], gs, wi, wg, wo, cfg.act)
    y_rows = jnp.zeros_like(y_sorted).at[order].set(y_sorted)
    if codec is not None:
        back = comp.roundtrip_1d(codec, y_rows).astype(y_rows.dtype)
        aux["recon_loss"] = aux["recon_loss"] + comp.recon_loss(y_rows, back)
        y_rows = back
        c = cfg.compression
        aux["aux_loss"] = aux["aux_loss"] + c.recon_weight * aux["recon_loss"]
    w = out.topk_weight.reshape(-1, 1).astype(y_rows.dtype)
    # non-resident dispatches hit the zero garbage slab; zero their combine
    # weight too so renormalized ties can never leak garbage-slab output
    w = jnp.where((slots < S)[:, None], w, 0.0)
    y = jax.ops.segment_sum(y_rows * w, tok, num_segments=T)
    return y.astype(x.dtype), aux


# ---------------------------------------------------------------------------
# Expert-parallel paths (inside shard_map)
# ---------------------------------------------------------------------------


def _scatter_to_buckets(payload, dst, slot, capacity, n_buckets):
    """payload [n, d]; dst/slot [n] -> [n_buckets, capacity, d] with
    out-of-capacity rows dropped."""
    slot_c = jnp.minimum(slot, capacity)  # overflow parked in pad row
    buf = jnp.zeros((n_buckets, capacity + 1, payload.shape[-1]), payload.dtype)
    buf = buf.at[dst, slot_c].set(payload)
    return buf[:, :capacity]


def _scatter_meta(meta, dst, slot, capacity, n_buckets, fill=0):
    slot_c = jnp.minimum(slot, capacity)
    buf = jnp.full((n_buckets, capacity + 1), fill, meta.dtype)
    buf = buf.at[dst, slot_c].set(meta)
    return buf[:, :capacity]


def _rank_in_bucket(dst: jax.Array, n_buckets: int) -> jax.Array:
    """dst: [n] -> rank of each element among those with the same dst."""
    oh = jax.nn.one_hot(dst, n_buckets, dtype=jnp.int32)
    return (jnp.cumsum(oh, axis=0) - 1)[jnp.arange(dst.shape[0]), dst]


def _moe_a2a_body(
    x: jax.Array,  # [t, d] dp-local, model-replicated
    experts: Dict,  # {"wi": [E_loc, d, f], ("wg"), "wo"} — LOCAL shard slices
    gate_params: Dict,  # replicated
    codec: Optional[Dict],  # replicated (or None)
    cfg,
    topo: Topology,
    expert_mask,
    capacity_factor: float,
    pre_sharded: bool = False,
):
    m = cfg.moe
    ep = topo.ep_size
    axis = topo.model_axis
    E_loc = m.num_experts // ep
    t, d = x.shape
    me = jax.lax.axis_index(axis)
    k = m.top_k

    if pre_sharded:
        # tokens already S-sharded over the model axis (sequence-parallel
        # residual stream): every local row is ours.
        ts = t
        xs = x
    else:
        # De-replicate: this shard owns tokens [me*ts, (me+1)*ts).
        ts = t // ep
        xs = jax.lax.dynamic_slice_in_dim(x, me * ts, ts, 0)
    out = gating.gate(gate_params, xs, m, expert_mask)
    eid = out.topk_idx.reshape(-1)  # [ts*k]
    w = out.topk_weight.reshape(-1)
    dst = eid // E_loc
    tok = jnp.arange(ts * k) // k
    slot = _rank_in_bucket(dst, ep)
    C = _capacity(ts * k, ep, capacity_factor)
    keep = slot < C
    dropped = 1.0 - keep.mean()

    payload = xs[tok]  # [ts*k, d]
    if codec is not None:
        payload = comp.encode_1d(codec, payload).astype(x.dtype)
    send = _scatter_to_buckets(payload, dst, slot, C, ep)
    send_eid = _scatter_meta((eid % E_loc).astype(jnp.int32), dst, slot, C, ep)

    recv = jax.lax.all_to_all(send, axis, 0, 0, tiled=True)  # [ep, C, dpay]
    recv_eid = jax.lax.all_to_all(send_eid, axis, 0, 0, tiled=True)

    rows = recv.reshape(ep * C, -1)
    if codec is not None:
        rows = comp.decode_1d(codec, rows).astype(x.dtype)
    y_rows = _sorted_expert_ffn(rows, recv_eid.reshape(-1), E_loc, experts, cfg.act)
    if codec is not None:
        y_rows = comp.encode_1d(codec, y_rows).astype(x.dtype)
    back = jax.lax.all_to_all(y_rows.reshape(ep, C, -1), axis, 0, 0, tiled=True)

    got = back[dst, jnp.minimum(slot, C - 1)]  # [ts*k, dpay]
    if codec is not None:
        got = comp.decode_1d(codec, got).astype(x.dtype)
    got = jnp.where(keep[:, None], got * w[:, None].astype(got.dtype), 0.0)
    y = jax.ops.segment_sum(got, tok, num_segments=ts).astype(x.dtype)

    if not pre_sharded:
        y = jax.lax.all_gather(y, axis, axis=0, tiled=True)  # [t, d]
    aux = {kk: _pmean_all(vv, topo) for kk, vv in out.aux.items()}
    aux["dropped_frac"] = _pmean_all(dropped, topo)
    return y, aux


def _moe_tp_body(
    x: jax.Array,  # [t, d] dp-local, model-replicated
    experts: Dict,  # local expert slices
    gate_params: Dict,
    codec: Optional[Dict],
    cfg,
    topo: Topology,
    expert_mask,
    capacity_factor: float,
):
    m = cfg.moe
    ep = topo.ep_size
    axis = topo.model_axis
    E_loc = m.num_experts // ep
    t, d = x.shape
    k = m.top_k
    me = jax.lax.axis_index(axis)

    out = gating.gate(gate_params, x, m, expert_mask)  # replicated compute
    eid = out.topk_idx.reshape(-1)  # [t*k]
    w = out.topk_weight.reshape(-1)
    tok = jnp.arange(t * k) // k
    mine = (eid // E_loc) == me
    # Rank among my local assignments.
    slot = jnp.cumsum(mine.astype(jnp.int32)) - 1
    C = _capacity(t * k, ep, capacity_factor)
    keep = mine & (slot < C)
    dropped = 1.0 - _pmean_all(keep.sum() / (t * k), topo) * ep

    idx = jnp.where(keep, slot, C)  # pad row
    sel_tok = jnp.full((C + 1,), 0, jnp.int32).at[idx].set(tok.astype(jnp.int32))
    sel_eid = jnp.full((C + 1,), 0, jnp.int32).at[idx].set(
        (eid % E_loc).astype(jnp.int32)
    )
    sel_w = jnp.zeros((C + 1,), jnp.float32).at[idx].set(
        jnp.where(keep, w, 0.0).astype(jnp.float32)
    )
    sel_tok, sel_eid, sel_w = sel_tok[:C], sel_eid[:C], sel_w[:C]

    xs = x[sel_tok]  # [C, d] local gather
    y_rows = _sorted_expert_ffn(xs, sel_eid, E_loc, experts, cfg.act)
    y = jax.ops.segment_sum(
        y_rows * sel_w[:, None].astype(y_rows.dtype), sel_tok, num_segments=t
    )
    if codec is not None:
        # Compressed all-reduce: the codec is linear, so summing in the
        # low-rank space commutes with decoding — psum bytes shrink by r/d.
        y = comp.decode_1d(codec, jax.lax.psum(comp.encode_1d(codec, y), axis))
        y = y.astype(x.dtype)
    else:
        y = jax.lax.psum(y.astype(jnp.float32), axis).astype(x.dtype)
    aux = {kk: _pmean_all(vv, topo) for kk, vv in out.aux.items()}
    aux["dropped_frac"] = dropped
    return y, aux


def _pmean_all(v, topo: Topology):
    names = tuple(topo.data_axes) + ((topo.model_axis,) if topo.model_axis else ())
    return jax.lax.pmean(v, names)


# ---------------------------------------------------------------------------
# Public entry point
# ---------------------------------------------------------------------------


def apply_moe(
    params: Dict,
    x: jax.Array,  # [B, S, d] (or [T, d])
    cfg,
    topo: Optional[Topology] = None,
    *,
    expert_mask: Optional[jax.Array] = None,
    train: bool = True,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    m = cfg.moe
    impl = cfg.moe_impl
    if impl == "auto":
        impl = "a2a" if (topo is not None and topo.use_shard_map_moe) else "sorted"
    cf = m.capacity_factor if train else m.eval_capacity_factor

    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    T = x2.shape[0]

    if "resident" in params:
        # pooled end tier (paged expert weights): single-shard dispatch over
        # the resident slab sub-table, non-residents masked in-trace
        y, aux = moe_resident(params, x2, cfg, expert_mask)
        if m.shared_experts and "shared" in params:
            from repro.models.layers import apply_mlp

            y = y + apply_mlp(params["shared"], x2, cfg.act)
        return y.reshape(shape), aux

    if impl in ("a2a", "tp") and topo is not None and topo.use_shard_map_moe:
        # Decode-shape degeneracies: tiny token counts can't be de-replicated
        # across the model axis (a2a) or even sharded across data (both).
        dp, ep = topo.dp_size, topo.ep_size
        batch_shardable = T % dp == 0
        t_loc = T // dp if batch_shardable else T
        if impl == "a2a" and t_loc % ep != 0:
            impl = "tp"
        # Sequence-parallel residuals: tokens arrive S-sharded over the
        # model axis -> a2a dispatch without de-replication or output AG.
        pre_sharded = (
            topo.seq_parallel_attn
            and batch_shardable
            and t_loc % ep == 0
            and impl == "a2a"
        )
        body = _moe_a2a_body if impl == "a2a" else _moe_tp_body
        if pre_sharded:
            dp_spec = P(tuple(topo.data_axes) + (topo.model_axis,), None)
        else:
            dp_spec = (
                P(tuple(topo.data_axes), None) if batch_shardable else P(None, None)
            )
        experts = {kk: params[kk] for kk in ("wi", "wg", "wo") if kk in params}
        ep_spec = jax.tree.map(lambda _: P(topo.model_axis), experts)
        kwargs = dict(
            cfg=cfg, topo=topo, expert_mask=expert_mask, capacity_factor=cf
        )
        if impl == "a2a":
            kwargs["pre_sharded"] = pre_sharded
        body_p = functools.partial(body, **kwargs)
        if pre_sharded and len(shape) == 3:
            # Keep [B, S, d] into the shard_map (a global [B*S] flatten
            # would break the nested (dp, model) sharding contiguity and
            # force a full-residual regather per layer); flatten locally.
            sharded3 = P(tuple(topo.data_axes), topo.model_axis, None)

            def body3d(x3, experts_, gate_, codec_):
                bl, sl, dd = x3.shape
                y2, aux2 = body_p(x3.reshape(bl * sl, dd), experts_, gate_, codec_)
                return y2.reshape(bl, sl, dd), aux2

            fn = jax.shard_map(
                body3d,
                mesh=topo.mesh,
                in_specs=(sharded3, ep_spec, P(), P()),
                out_specs=(sharded3, P()),
                check_vma=False,
            )
            y, aux = fn(x, experts, params["gate"], params.get("codec"))
            # stay 3D: a global [B*S] flatten would break the nested
            # (dp, model) sharding again on the way out
            if m.shared_experts and "shared" in params:
                from repro.models.layers import apply_mlp

                y = y + apply_mlp(params["shared"], x, cfg.act)
            return y, aux
        else:
            fn = jax.shard_map(
                body_p,
                mesh=topo.mesh,
                in_specs=(dp_spec, ep_spec, P(), P()),
                out_specs=(dp_spec, P()),
                check_vma=False,
            )
            # Flatten batch/seq into tokens but KEEP the dp-sharded leading
            # dim: [B, S, d] -> [B*S, d] preserves dim-0 sharding.
            y, aux = fn(x2, experts, params["gate"], params.get("codec"))
    elif impl == "sorted":
        y, aux = moe_sorted(params, x2, cfg, expert_mask)
    elif impl == "naive":
        y, aux = moe_naive(params, x2, cfg, expert_mask)
    else:
        raise ValueError(f"unknown moe impl {impl!r} (topology={topo})")

    if m.shared_experts and "shared" in params:
        from repro.models.layers import apply_mlp

        y = y + apply_mlp(params["shared"], x2, cfg.act)
    return y.reshape(shape), aux
