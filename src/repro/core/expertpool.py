"""Paged expert-weight pool for the end tier (the expert analogue of
``models.kvcache.PagePool``).

The hardware-aware mask (eq. 2-4) decides which experts the end tier *may*
route to — but in the dense layout every tier still holds the full
``[E, d_model, d_ff]`` expert tensors, so a device-state change moves zero
bytes of expert weight and a shrinking memory budget cannot actually shed
experts.  This module makes expert *placement* first-class:

  * End-tier expert weights live in a fixed-capacity pool of expert-weight
    **slabs** — one slab is one expert's ``wi``/``wg``/``wo`` rows for one
    layer.  Device storage is ``[num_slabs + 1, ...]`` per weight matrix;
    the extra last row is the **garbage slab** (all zeros, never
    allocated): tokens whose expert is not resident dispatch to it and
    contribute exactly zero, mirroring the KV pool's garbage page.
  * :class:`ExpertSlabPool` is the host-side allocator: a per-layer
    resident table ``[n_layers, E] -> physical slab | -1`` plus a free
    list, with the eq. 4 mask as the *target set* and an LRU /
    route-frequency policy (:meth:`plan`) deciding which experts to
    prefetch and which residents to evict when the slab budget shrinks.
  * The serving engine gathers only resident slab rows at execute time
    (``core.moe.moe_resident``), so end-tier expert compute and HBM
    traffic scale with residents, not ``E``; non-resident experts are
    routed away exactly as eq. 4-masked experts are today (the effective
    routing mask is ``target AND resident``, computed in-trace from the
    resident tables).

The allocator is pure NumPy bookkeeping between engine ticks; the jitted
stage functions take the device-side resident tables (built by
:func:`device_resident_tables`) as runtime arguments, so compiled traces
depend only on the static resident-slot count, never on which experts are
resident.
"""

from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pipeline import peer_comm_time


SLAB_SCALE_DTYPE = jnp.float32  # per-output-column scale sidecar
SLAB_SCALE_FLOOR = 1e-8


def expert_slab_bytes(cfg, *, quantized: bool = False) -> int:
    """Bytes one expert's ``wi``/``wg``/``wo`` rows occupy for one layer
    (the unit expert-pool budgets and ``expert_bytes_*`` metrics are
    denominated in).  With ``quantized=True`` the weights are int8 plus one
    fp32 scale per output column — the *stored* size, which is also what
    crosses the wire on a prefetch or peer fetch."""
    mats = 3 if cfg.ffn_gated else 2
    d, f = cfg.d_model, cfg.moe.d_ff_expert
    if quantized:
        scales = (2 * f if cfg.ffn_gated else f) + d
        return mats * d * f + scales * jnp.dtype(SLAB_SCALE_DTYPE).itemsize
    itemsize = jnp.dtype(cfg.param_dtype).itemsize
    return mats * d * f * itemsize


def init_slab_store(cfg, num_slabs: int, dtype=None, *,
                    quantized: bool = False) -> Dict[str, jax.Array]:
    """Device-side slab storage: per weight matrix ``[num_slabs + 1, ...]``
    with the last row the all-zeros garbage slab.

    With ``quantized=True`` the weight leaves hold int8 codes and per
    matrix a ``*_scale`` sidecar holds one fp32 scale per *output* column
    (``wi_scale``/``wg_scale [N+1, f]`` over the d contraction,
    ``wo_scale [N+1, d]`` over the f contraction) — folding the scale
    after the matmul is then exact, which is what the fused consumers do.
    """
    dtype = jnp.int8 if quantized else (dtype or jnp.dtype(cfg.param_dtype))
    d, f = cfg.d_model, cfg.moe.d_ff_expert
    store = {
        "wi": jnp.zeros((num_slabs + 1, d, f), dtype),
        "wo": jnp.zeros((num_slabs + 1, f, d), dtype),
    }
    if cfg.ffn_gated:
        store["wg"] = jnp.zeros((num_slabs + 1, d, f), dtype)
    if quantized:
        store["wi_scale"] = jnp.zeros((num_slabs + 1, f), SLAB_SCALE_DTYPE)
        store["wo_scale"] = jnp.zeros((num_slabs + 1, d), SLAB_SCALE_DTYPE)
        if cfg.ffn_gated:
            store["wg_scale"] = jnp.zeros((num_slabs + 1, f), SLAB_SCALE_DTYPE)
    return store


def quantize_slab(w: jax.Array):
    """``[..., c, n] -> (q int8, scale fp32 [..., n])``: symmetric int8
    with one scale per output column (axis ``n``), so
    ``(x @ q) * scale == x @ w`` up to the int8 grid error."""
    wf = w.astype(jnp.float32)
    amax = jnp.max(jnp.abs(wf), axis=-2)
    scale = jnp.maximum(amax / 127.0, SLAB_SCALE_FLOOR).astype(SLAB_SCALE_DTYPE)
    q = jnp.clip(
        jnp.round(wf / scale[..., None, :].astype(jnp.float32)), -127, 127
    ).astype(jnp.int8)
    return q, scale


def write_slabs(
    store: Dict[str, jax.Array],
    full_moe_params: Dict[str, jax.Array],  # {"wi": [R, E, d, f], ...}
    assignments: Sequence[Tuple[int, int, int]],  # (slab, block, expert)
) -> Dict[str, jax.Array]:
    """Copy expert weights ``(block, expert)`` from the full stacked params
    into physical slab rows (one batched scatter per weight matrix).  A
    quantized store (``wi_scale`` present) quantizes on write — the dense
    params never hit the pool or the wire at full precision."""
    if not assignments:
        return store
    slabs = jnp.asarray([a[0] for a in assignments])
    bs = jnp.asarray([a[1] for a in assignments])
    es = jnp.asarray([a[2] for a in assignments])
    out = dict(store)
    quantized = "wi_scale" in store
    for k in ("wi", "wg", "wo"):
        if k not in store:
            continue
        src = full_moe_params[k][bs, es]
        if quantized:
            q, s = quantize_slab(src)
            out[k] = store[k].at[slabs].set(q)
            out[f"{k}_scale"] = store[f"{k}_scale"].at[slabs].set(s)
        else:
            out[k] = store[k].at[slabs].set(src.astype(store[k].dtype))
    return out


class ExpertSlabPool:
    """Host-side slab allocator for one end tier's expert-weight pool.

    Physical slabs ``0..num_slabs-1`` index the first axis of the device
    slab store; row ``num_slabs`` is the garbage slab and is never
    allocated.  ``table[layer, e]`` maps each (layer, expert) to its slab
    (``-1`` = non-resident).  ``capacity`` is a *soft* limit (it may be
    lowered below ``num_slabs`` when the device's memory budget shrinks —
    the replan path evicts down to it at the next safe point); the
    physical store never reallocates.  At most ``max_per_layer`` experts
    may be resident per layer — the static resident-slot count the jitted
    dispatch is traced for.
    """

    def __init__(self, num_slabs: int, n_layers: int, num_experts: int,
                 max_per_layer: int):
        if num_slabs < 1:
            raise ValueError(f"num_slabs={num_slabs}")
        if max_per_layer < 1:
            raise ValueError(f"max_per_layer={max_per_layer}")
        self.num_slabs = num_slabs
        self.n_layers = n_layers
        self.num_experts = num_experts
        self.max_per_layer = min(max_per_layer, num_experts)
        self.capacity = num_slabs
        self.table = np.full((n_layers, num_experts), -1, np.int64)
        # LIFO free list seeded so pops hand out low indices first
        self._free: List[int] = list(range(num_slabs - 1, -1, -1))
        self.last_used = np.zeros((n_layers, num_experts), np.int64)
        self._tick = 0
        self.peak_in_use = 0

    # -- accounting -----------------------------------------------------------

    @property
    def garbage_slab(self) -> int:
        return self.num_slabs

    @property
    def slabs_in_use(self) -> int:
        return self.num_slabs - len(self._free)

    @property
    def utilization(self) -> float:
        return self.slabs_in_use / max(self.capacity, 1)

    def resident_mask(self, layer: int) -> np.ndarray:
        return self.table[layer] >= 0

    def resident_count(self, layer: int) -> int:
        return int((self.table[layer] >= 0).sum())

    def set_capacity(self, capacity: int):
        """Lower/raise the soft slab budget (never above the physical
        store).  The caller evicts down to it via :meth:`plan` at the next
        safe point."""
        self.capacity = max(1, min(capacity, self.num_slabs))

    # -- slab lifecycle -------------------------------------------------------

    def can_alloc(self) -> bool:
        return bool(self._free) and self.slabs_in_use < self.capacity

    def alloc(self, layer: int, expert: int) -> int:
        if self.table[layer, expert] >= 0:
            raise ValueError(f"({layer}, {expert}) already resident")
        if self.resident_count(layer) >= self.max_per_layer:
            raise ValueError(
                f"layer {layer} already holds max_per_layer="
                f"{self.max_per_layer} residents"
            )
        if not self.can_alloc():
            raise ValueError(
                f"pool exhausted: in_use={self.slabs_in_use} "
                f"capacity={self.capacity}"
            )
        slab = self._free.pop()
        self.table[layer, expert] = slab
        self.last_used[layer, expert] = self._tick
        self.peak_in_use = max(self.peak_in_use, self.slabs_in_use)
        return slab

    def evict(self, layer: int, expert: int) -> int:
        slab = int(self.table[layer, expert])
        if slab < 0:
            raise ValueError(f"({layer}, {expert}) not resident")
        self.table[layer, expert] = -1
        self._free.append(slab)
        return slab

    def free_layer(self, layer: int) -> List[int]:
        """Release every slab a layer holds (the layer left the end tier
        at a split replan).  Returns the freed physical slabs."""
        freed = []
        for e in np.nonzero(self.table[layer] >= 0)[0]:
            freed.append(self.evict(layer, int(e)))
        return freed

    def touch(self, layers: Sequence[int], target: np.ndarray):
        """LRU stamp: residents inside the applied routing set count as
        used this tick (non-target residents age out)."""
        self._tick += 1
        for layer in layers:
            used = (self.table[layer] >= 0) & target
            self.last_used[layer, used] = self._tick

    # -- residency policy -----------------------------------------------------

    def plan(
        self,
        active_layers: Sequence[int],
        target: np.ndarray,  # bool [E]: the eq. 4 mask (shared across layers)
        freq: Optional[np.ndarray] = None,  # [E] measured routing frequency
    ) -> Tuple[List[Tuple[int, int]], List[Tuple[int, int]]]:
        """Decide residency updates toward ``target`` on ``active_layers``.

        Returns ``(wanted, evictions)`` as (layer, expert) lists:

          * ``evictions`` — residents of inactive layers, then residents
            the budget can no longer carry, least-valuable first
            (non-target before target, then lowest route frequency, then
            least-recently-used); a layer's last target resident is only
            taken when the overflow leaves no other choice.
          * ``wanted`` — target experts not yet resident, ordered so every
            active layer gets its most-routed expert before any layer gets
            its second (no layer is starved to zero residents), then by
            measured route frequency, highest first.

        Non-target residents are kept as a warm cache while the budget has
        room — they are only evicted to make space or to fit a shrunk
        capacity.
        """
        E = self.num_experts
        freq = np.zeros((E,)) if freq is None else np.asarray(freq, np.float64)
        active = set(int(x) for x in active_layers)

        evictions: List[Tuple[int, int]] = []
        for layer in range(self.n_layers):
            if layer not in active:
                for e in np.nonzero(self.table[layer] >= 0)[0]:
                    evictions.append((layer, int(e)))

        # wanted: round-robin by per-layer rank so each active layer gets
        # its top expert first, frequency-desc within a rank
        per_layer: List[List[Tuple[int, int]]] = []
        for layer in sorted(active):
            missing = [
                int(e) for e in np.argsort(-freq, kind="stable")
                if target[e] and self.table[layer, e] < 0
            ]
            # per-layer slot room counts target residents only: non-target
            # residents are evictable to make space for target experts
            n_target_res = int((self.table[layer][target] >= 0).sum())
            room = self.max_per_layer - n_target_res
            per_layer.append([(layer, e) for e in missing[:max(room, 0)]])
        wanted: List[Tuple[int, int]] = []
        rank = 0
        while any(rank < len(lst) for lst in per_layer):
            for lst in per_layer:
                if rank < len(lst):
                    wanted.append(lst[rank])
            rank += 1

        # per-layer slot pressure: a layer whose resident slots are full of
        # stale non-target experts must shed them so its wanted target
        # experts have somewhere to land (lowest-frequency, LRU first)
        wanted_per_layer: Dict[int, int] = {}
        for layer, e in wanted:
            wanted_per_layer[layer] = wanted_per_layer.get(layer, 0) + 1
        for layer in sorted(active):
            over = (self.resident_count(layer)
                    + wanted_per_layer.get(layer, 0) - self.max_per_layer)
            if over <= 0:
                continue
            stale = sorted(
                (int(e) for e in np.nonzero(self.table[layer] >= 0)[0]
                 if not target[e]),
                key=lambda e: (freq[e], self.last_used[layer, e], e),
            )
            evictions.extend((layer, e) for e in stale[:over])

        # evictions beyond that: fit global capacity + make room
        in_use_after = self.slabs_in_use - len(evictions)
        overflow = max(0, in_use_after - self.capacity)
        room = max(0, self.capacity - in_use_after)
        need = overflow + max(0, len(wanted) - room)
        if need > 0:
            already = set(evictions)
            n_target_res = {
                layer: int((self.table[layer][target] >= 0).sum())
                for layer in sorted(active)
            }
            cands: List[Tuple[Tuple, Tuple[int, int]]] = []
            for layer in sorted(active):
                for e in np.nonzero(self.table[layer] >= 0)[0]:
                    e = int(e)
                    if (layer, e) in already:
                        continue
                    cands.append((
                        (1 if target[e] else 0, freq[e],
                         self.last_used[layer, e], e),
                        (layer, e),
                    ))
            cands.sort(key=lambda c: c[0])
            taken = set()
            # pass 1: non-target residents serve any need; target residents
            # are evicted ONLY under capacity overflow (never to make room
            # for another layer's wanted expert — that would thrash: evict
            # here, prefetch there, forever), and never a layer's last one
            for key, (layer, e) in cands:
                if need <= 0:
                    break
                if key[0] == 1:
                    if overflow <= 0 or n_target_res[layer] <= 1:
                        continue
                    n_target_res[layer] -= 1
                evictions.append((layer, e))
                taken.add((layer, e))
                need -= 1
                overflow = max(0, overflow - 1)
            # pass 2: a capacity overflow that cannot be satisfied otherwise
            # may zero layers (shrinking budgets beat starving the pool) —
            # but growth never does
            if need > 0 and overflow > 0:
                for key, (layer, e) in cands:
                    if need <= 0 or overflow <= 0:
                        break
                    if (layer, e) in taken:
                        continue
                    evictions.append((layer, e))
                    need -= 1
                    overflow -= 1
        return wanted, evictions


class FleetExpertRegistry:
    """Location-aware fleet-wide expert store: residency *planning* split
    from per-device *storage*.

    Each fleet lane keeps its :class:`ExpertSlabPool` as the storage
    backend (same slab/table device format, garbage slab, resident
    kernel); the registry owns the fleet-wide map
    ``(layer, expert) -> {lane: slab, freq, last_use}`` (see
    :meth:`fleet_map`) and layers three policies on top:

      * **De-duplication** (:meth:`plan_lane`) — a lane fetches its own
        copy of an expert some peer already holds only when the lane's
        *measured* route frequency justifies the slab
        (``freq[e] >= dedup_min_freq``, default the uniform share
        ``1/E``); colder duplicates are served over the peer link
        instead.  Unmeasured lanes always replicate — cold fleets behave
        exactly like PR 5's isolated pools, which is what keeps greedy
        decode parity.
      * **Source choice** (:meth:`pick_source`) — each queued slab
        transfer picks peer-lane vs. cloud by modeled link cost at
        *transfer* time (holders are read live, so a peer that evicted
        meanwhile falls back to the cloud path).  Without a declared
        fleet LAN a peer path rides both WAN uplinks and can never beat
        the direct cloud fetch (see ``pipeline.peer_link_gbps``), so the
        default fleet is cloud-only — exactly the isolated behavior.
      * **Placement cost** (:meth:`lane_miss_cost_s`,
        :meth:`group_fetch_costs`) — expected wire seconds to repair a
        lane's misses, fed into ``place_fleet`` (request placement) and
        ``selection.group_priority_from_freq`` (the eq. 4 group admit),
        so routing and request placement see the same residency map.

    The registry is pure host-side bookkeeping: it never touches device
    storage and books peer wire time through per-lane callbacks onto the
    fleet's shared ``StageTimeline`` link resources (both ends of a peer
    transfer are occupied, so peer traffic overlaps decode exactly like
    cloud prefetches).
    """

    def __init__(
        self,
        n_layers: int,
        num_experts: int,
        slab_bytes: int,
        *,
        lan_gbps: Optional[float] = None,
        dedup_min_freq: Optional[float] = None,
    ):
        self.n_layers = n_layers
        self.num_experts = num_experts
        self.slab_bytes = slab_bytes
        self.lan_gbps = lan_gbps
        self.dedup_min_freq = (
            1.0 / num_experts if dedup_min_freq is None else dedup_min_freq
        )
        self._pools: List[ExpertSlabPool] = []
        self._link_gbps: List[Callable[[], float]] = []
        self._book_link: List[Callable[[float, float], float]] = []
        self._freq: List[Optional[np.ndarray]] = []
        self._alive: List[bool] = []
        self.peer_fetches = 0
        self.peer_bytes = 0
        # (src_lane, dst_lane, wire_seconds) per peer transfer booked
        self.peer_bookings: List[Tuple[int, int, float]] = []
        # chaos injection: pending peer-fetch failures + fallback counter
        self._peer_faults = 0
        self.peer_fault_fallbacks = 0

    # -- lanes ----------------------------------------------------------------

    @property
    def n_lanes(self) -> int:
        return len(self._pools)

    def register_lane(
        self,
        pool: ExpertSlabPool,
        *,
        link_gbps: Callable[[], float],
        book_link: Callable[[float, float], float],
    ) -> int:
        """Attach one lane's slab pool as a storage backend.  ``link_gbps``
        reports the lane's measured uplink; ``book_link`` occupies the
        lane's link resource on the fleet timeline (``(ready_s,
        seconds) -> end_s``).  Returns the lane id (registration order —
        the fleet registers lanes in device order)."""
        if pool.n_layers != self.n_layers or (
            pool.num_experts != self.num_experts
        ):
            raise ValueError(
                f"pool geometry ({pool.n_layers}, {pool.num_experts}) != "
                f"registry ({self.n_layers}, {self.num_experts})"
            )
        self._pools.append(pool)
        self._link_gbps.append(link_gbps)
        self._book_link.append(book_link)
        self._freq.append(None)
        self._alive.append(True)
        return len(self._pools) - 1

    def set_lane_alive(self, lane: int, alive: bool):
        """Liveness gate for the fleet map: a dead lane's residency is
        invisible to ``holders``/``pick_source``/``fleet_map``/the load
        and dedup views, so no in-flight or future slab fetch can name it
        as a source — transfers picking a source at wire time fall back to
        a surviving peer or the cloud automatically."""
        self._alive[lane] = bool(alive)

    def lane_alive(self, lane: int) -> bool:
        return self._alive[lane]

    def _live_pools(self):
        return (
            (i, p) for i, p in enumerate(self._pools) if self._alive[i]
        )

    def inject_peer_faults(self, count: int):
        """Chaos hook: the next ``count`` peer slab fetches fail."""
        if count < 1:
            raise ValueError(f"count={count} must be >= 1")
        self._peer_faults += count

    def take_peer_fault(self) -> bool:
        """Consume one injected peer-fetch failure (called by the lane at
        transfer time when a peer source was picked): True means this
        fetch fails and the caller must re-source to the cloud — the copy
        that is always authoritative and always reachable."""
        if self._peer_faults > 0:
            self._peer_faults -= 1
            self.peer_fault_fallbacks += 1
            return True
        return False

    def note_freq(self, lane: int, freq: Optional[np.ndarray]):
        """Record a lane's measured route-frequency EMA (the fleet ticks
        this; ``plan_lane`` also notes the freq it plans against)."""
        if freq is not None:
            self._freq[lane] = np.asarray(freq, np.float64).copy()

    # -- the fleet-wide map ---------------------------------------------------

    def holders(self, lid: int, e: int, *, exclude: Optional[int] = None
                ) -> List[int]:
        """*Live* lanes whose pool currently holds ``(layer, expert)`` —
        a crashed lane's residency never appears (see ``set_lane_alive``),
        so a transfer can never pick a dead holder as its source."""
        return [
            i for i, p in self._live_pools()
            if i != exclude and p.table[lid, e] >= 0
        ]

    def fleet_map(self) -> Dict[Tuple[int, int], Dict]:
        """The registry's view: every fleet-resident ``(layer, expert)``
        with its holders' physical slabs, the max measured frequency across
        holders, and the freshest LRU stamp (introspection / tests)."""
        out: Dict[Tuple[int, int], Dict] = {}
        for i, p in self._live_pools():
            for lid, e in zip(*np.nonzero(p.table >= 0)):
                lid, e = int(lid), int(e)
                ent = out.setdefault(
                    (lid, e),
                    {"holders": {}, "freq": 0.0, "last_use": 0},
                )
                ent["holders"][i] = int(p.table[lid, e])
                if self._freq[i] is not None:
                    ent["freq"] = max(ent["freq"], float(self._freq[i][e]))
                ent["last_use"] = max(
                    ent["last_use"], int(p.last_used[lid, e])
                )
        return out

    def unique_residents(self) -> int:
        """Distinct fleet-wide resident ``(layer, expert)`` pairs."""
        if not self._pools:
            return 0
        held = np.zeros((self.n_layers, self.num_experts), bool)
        for _i, p in self._live_pools():
            held |= p.table >= 0
        return int(held.sum())

    def total_residents(self) -> int:
        return sum(p.slabs_in_use for _i, p in self._live_pools())

    def dedup_ratio(self) -> float:
        """Fleet resident slabs over unique resident (layer, expert)
        pairs: 1.0 = fully de-duplicated, ``n_lanes`` = every resident
        replicated everywhere."""
        return self.total_residents() / max(self.unique_residents(), 1)

    # -- link cost model ------------------------------------------------------

    def cloud_fetch_s(self, lane: int) -> float:
        """Modeled wire time of one slab over the lane's cloud uplink."""
        gbps = self._link_gbps[lane]()
        return self.slab_bytes * 8.0 / max(gbps * 1e9, 1e-9)

    def peer_fetch_s(self, lane: int, src: int) -> float:
        """Modeled wire time of one slab over the end<->end link."""
        return peer_comm_time(
            self.slab_bytes,
            self._link_gbps[src](),
            self._link_gbps[lane](),
            lan_gbps=self.lan_gbps,
        )

    def pick_source(self, lane: int, lid: int, e: int
                    ) -> Tuple[Optional[int], float]:
        """Cheapest source for a slab fetch of ``(layer, expert)`` onto
        ``lane``: ``(peer_lane | None, wire_seconds)`` — ``None`` means the
        cloud path.  A peer must be *strictly* cheaper to win (ties keep
        the cloud: its copy is always authoritative)."""
        best_src: Optional[int] = None
        best_t = self.cloud_fetch_s(lane)
        for j in self.holders(lid, e, exclude=lane):
            t = self.peer_fetch_s(lane, j)
            if t < best_t:
                best_src, best_t = j, t
        return best_src, best_t

    def book_peer(self, src: int, dst: int, ready_s: float, seconds: float
                  ) -> float:
        """Occupy the *source* lane's link resource for a peer transfer
        (the destination books its own link itself — both ends of the
        transfer appear on the fleet timeline and overlap decode)."""
        done = self._book_link[src](ready_s, seconds)
        self.peer_fetches += 1
        self.peer_bytes += self.slab_bytes
        self.peer_bookings.append((src, dst, seconds))
        return done

    # -- residency planning ---------------------------------------------------

    def _replicate_justified(
        self, lane: int, lid: int, e: int, freq: Optional[np.ndarray]
    ) -> bool:
        if not self.holders(lid, e, exclude=lane):
            return True  # sole fleet copy: always place it
        if freq is None:
            return True  # unmeasured lane: no evidence to dedup on
        return float(freq[e]) >= self.dedup_min_freq

    def plan_lane(
        self,
        lane: int,
        active_layers: Sequence[int],
        target: np.ndarray,
        freq: Optional[np.ndarray] = None,
    ) -> Tuple[List[Tuple[int, int]], List[Tuple[int, int]]]:
        """One lane's :meth:`ExpertSlabPool.plan` with the fleet
        de-duplication rule applied to the want list: a duplicate of an
        expert already resident on a peer is only fetched when this lane's
        measured frequency clears ``dedup_min_freq`` — otherwise the lane
        serves it over the peer link (or the cloud) on miss.  Evictions
        are the pool's own (de-dup never forces an eviction: an existing
        duplicate is trimmed only by normal capacity pressure, so a
        registry-attached lane's residency is always a subset of the
        isolated pool's — the greedy-parity superset property)."""
        self.note_freq(lane, freq)
        wanted, evictions = self._pools[lane].plan(active_layers, target, freq)
        wanted = [
            (lid, e) for lid, e in wanted
            if self._replicate_justified(lane, lid, e, freq)
        ]
        return wanted, evictions

    # -- placement cost feeds -------------------------------------------------

    def _f_eff(self, lane: int) -> np.ndarray:
        """Measured frequency EMA plus the uniform ``1/E`` prior (matching
        the engines' hit-rate weighting: just-admitted experts register)."""
        E = self.num_experts
        f = self._freq[lane]
        return (np.zeros((E,)) if f is None else f) + 1.0 / E

    def expert_fetch_costs(
        self, lane: int, active_layers: Sequence[int]
    ) -> np.ndarray:
        """Per-expert modeled wire seconds to make the expert resident on
        the lane's active end layers (0 where already resident), averaged
        over layers — the per-expert placement cost the group priority
        consumes."""
        E = self.num_experts
        cost = np.zeros((E,))
        active = list(active_layers)
        if not active:
            return cost
        pool = self._pools[lane]
        for e in range(E):
            c = 0.0
            for lid in active:
                if pool.table[lid, e] < 0:
                    c += self.pick_source(lane, lid, e)[1]
            cost[e] = c / len(active)
        return cost

    def group_fetch_costs(
        self, lane: int, active_layers: Sequence[int], num_groups: int
    ) -> np.ndarray:
        """Expert fetch costs folded to HL-GGN groups (mean over each
        group's experts) for ``selection.group_priority_from_freq``."""
        per_expert = self.expert_fetch_costs(lane, active_layers)
        return per_expert.reshape(num_groups, -1).mean(-1)

    def lane_miss_cost_s(
        self,
        lane: int,
        active_layers: Sequence[int],
        target: np.ndarray,
    ) -> float:
        """Expected extra wire seconds per routed token on this lane: each
        active layer's non-resident target experts weighted by measured
        routing probability times their cheapest fetch time.  A heuristic
        placement *signal* (misses amortize over many tokens), not a
        latency prediction — ``place_fleet`` uses it to steer requests
        toward lanes whose residency already matches their traffic."""
        f = self._f_eff(lane)
        target = np.asarray(target, bool)
        pool = self._pools[lane]
        cost = 0.0
        for lid in active_layers:
            for e in np.nonzero(target & (pool.table[lid] < 0))[0]:
                e = int(e)
                cost += float(f[e]) * self.pick_source(lane, lid, e)[1]
        return cost

    # -- cloud-side view ------------------------------------------------------

    def cloud_expert_load(self) -> np.ndarray:
        """Per-expert share of fleet traffic that drains to the *cloud*
        tier: each lane's effective frequency counts where the lane holds
        no layer's copy of the expert (misses route to the cloud's dense
        stacks).  This is the weight ``distributed.sharding``'s
        fleet-aware expert sharding balances across cloud servers."""
        E = self.num_experts
        load = np.zeros((E,))
        for i, p in self._live_pools():
            any_resident = (p.table >= 0).any(axis=0)  # [E]
            load += self._f_eff(i) * (~any_resident)
        return load


def device_resident_tables(
    pool: ExpertSlabPool,
    layer_ids: Sequence[int],  # pool layer id per end-tier block, in order
    s_cap: int,
) -> Dict[str, jax.Array]:
    """Device view of the resident tables for one MoE pattern position:

      * ``ids [n_blocks, s_cap + 1]`` — physical slab row of each resident
        slot (ascending expert id; unused slots and the sentinel last slot
        map to the garbage slab), the gather index ``moe_resident`` reads
        weights through;
      * ``slot [n_blocks, E]`` — expert id -> resident slot, with
        non-resident experts mapped to the garbage slot ``s_cap`` (which
        is how the in-trace effective routing mask ``slot < s_cap`` and
        the zero-contribution dispatch fall out).
    """
    n = len(layer_ids)
    ids = np.full((n, s_cap + 1), pool.garbage_slab, np.int64)
    slot = np.full((n, pool.num_experts), s_cap, np.int64)
    for b, lid in enumerate(layer_ids):
        res = np.nonzero(pool.table[lid] >= 0)[0]
        for s_i, e in enumerate(res[:s_cap]):
            ids[b, s_i] = pool.table[lid, e]
            slot[b, e] = s_i
    return {
        "ids": jnp.asarray(ids, jnp.int32),
        "slot": jnp.asarray(slot, jnp.int32),
    }
