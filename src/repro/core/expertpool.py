"""Paged expert-weight pool for the end tier (the expert analogue of
``models.kvcache.PagePool``).

The hardware-aware mask (eq. 2-4) decides which experts the end tier *may*
route to — but in the dense layout every tier still holds the full
``[E, d_model, d_ff]`` expert tensors, so a device-state change moves zero
bytes of expert weight and a shrinking memory budget cannot actually shed
experts.  This module makes expert *placement* first-class:

  * End-tier expert weights live in a fixed-capacity pool of expert-weight
    **slabs** — one slab is one expert's ``wi``/``wg``/``wo`` rows for one
    layer.  Device storage is ``[num_slabs + 1, ...]`` per weight matrix;
    the extra last row is the **garbage slab** (all zeros, never
    allocated): tokens whose expert is not resident dispatch to it and
    contribute exactly zero, mirroring the KV pool's garbage page.
  * :class:`ExpertSlabPool` is the host-side allocator: a per-layer
    resident table ``[n_layers, E] -> physical slab | -1`` plus a free
    list, with the eq. 4 mask as the *target set* and an LRU /
    route-frequency policy (:meth:`plan`) deciding which experts to
    prefetch and which residents to evict when the slab budget shrinks.
  * The serving engine gathers only resident slab rows at execute time
    (``core.moe.moe_resident``), so end-tier expert compute and HBM
    traffic scale with residents, not ``E``; non-resident experts are
    routed away exactly as eq. 4-masked experts are today (the effective
    routing mask is ``target AND resident``, computed in-trace from the
    resident tables).

The allocator is pure NumPy bookkeeping between engine ticks; the jitted
stage functions take the device-side resident tables (built by
:func:`device_resident_tables`) as runtime arguments, so compiled traces
depend only on the static resident-slot count, never on which experts are
resident.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def expert_slab_bytes(cfg) -> int:
    """Bytes one expert's ``wi``/``wg``/``wo`` rows occupy for one layer
    (the unit expert-pool budgets and ``expert_bytes_*`` metrics are
    denominated in)."""
    mats = 3 if cfg.ffn_gated else 2
    itemsize = jnp.dtype(cfg.param_dtype).itemsize
    return mats * cfg.d_model * cfg.moe.d_ff_expert * itemsize


def init_slab_store(cfg, num_slabs: int, dtype=None) -> Dict[str, jax.Array]:
    """Device-side slab storage: per weight matrix ``[num_slabs + 1, ...]``
    with the last row the all-zeros garbage slab."""
    dtype = dtype or jnp.dtype(cfg.param_dtype)
    d, f = cfg.d_model, cfg.moe.d_ff_expert
    store = {
        "wi": jnp.zeros((num_slabs + 1, d, f), dtype),
        "wo": jnp.zeros((num_slabs + 1, f, d), dtype),
    }
    if cfg.ffn_gated:
        store["wg"] = jnp.zeros((num_slabs + 1, d, f), dtype)
    return store


def write_slabs(
    store: Dict[str, jax.Array],
    full_moe_params: Dict[str, jax.Array],  # {"wi": [R, E, d, f], ...}
    assignments: Sequence[Tuple[int, int, int]],  # (slab, block, expert)
) -> Dict[str, jax.Array]:
    """Copy expert weights ``(block, expert)`` from the full stacked params
    into physical slab rows (one batched scatter per weight matrix)."""
    if not assignments:
        return store
    slabs = jnp.asarray([a[0] for a in assignments])
    bs = jnp.asarray([a[1] for a in assignments])
    es = jnp.asarray([a[2] for a in assignments])
    out = dict(store)
    for k in store:
        src = full_moe_params[k][bs, es].astype(store[k].dtype)
        out[k] = store[k].at[slabs].set(src)
    return out


class ExpertSlabPool:
    """Host-side slab allocator for one end tier's expert-weight pool.

    Physical slabs ``0..num_slabs-1`` index the first axis of the device
    slab store; row ``num_slabs`` is the garbage slab and is never
    allocated.  ``table[layer, e]`` maps each (layer, expert) to its slab
    (``-1`` = non-resident).  ``capacity`` is a *soft* limit (it may be
    lowered below ``num_slabs`` when the device's memory budget shrinks —
    the replan path evicts down to it at the next safe point); the
    physical store never reallocates.  At most ``max_per_layer`` experts
    may be resident per layer — the static resident-slot count the jitted
    dispatch is traced for.
    """

    def __init__(self, num_slabs: int, n_layers: int, num_experts: int,
                 max_per_layer: int):
        if num_slabs < 1:
            raise ValueError(f"num_slabs={num_slabs}")
        if max_per_layer < 1:
            raise ValueError(f"max_per_layer={max_per_layer}")
        self.num_slabs = num_slabs
        self.n_layers = n_layers
        self.num_experts = num_experts
        self.max_per_layer = min(max_per_layer, num_experts)
        self.capacity = num_slabs
        self.table = np.full((n_layers, num_experts), -1, np.int64)
        # LIFO free list seeded so pops hand out low indices first
        self._free: List[int] = list(range(num_slabs - 1, -1, -1))
        self.last_used = np.zeros((n_layers, num_experts), np.int64)
        self._tick = 0
        self.peak_in_use = 0

    # -- accounting -----------------------------------------------------------

    @property
    def garbage_slab(self) -> int:
        return self.num_slabs

    @property
    def slabs_in_use(self) -> int:
        return self.num_slabs - len(self._free)

    @property
    def utilization(self) -> float:
        return self.slabs_in_use / max(self.capacity, 1)

    def resident_mask(self, layer: int) -> np.ndarray:
        return self.table[layer] >= 0

    def resident_count(self, layer: int) -> int:
        return int((self.table[layer] >= 0).sum())

    def set_capacity(self, capacity: int):
        """Lower/raise the soft slab budget (never above the physical
        store).  The caller evicts down to it via :meth:`plan` at the next
        safe point."""
        self.capacity = max(1, min(capacity, self.num_slabs))

    # -- slab lifecycle -------------------------------------------------------

    def can_alloc(self) -> bool:
        return bool(self._free) and self.slabs_in_use < self.capacity

    def alloc(self, layer: int, expert: int) -> int:
        if self.table[layer, expert] >= 0:
            raise ValueError(f"({layer}, {expert}) already resident")
        if self.resident_count(layer) >= self.max_per_layer:
            raise ValueError(
                f"layer {layer} already holds max_per_layer="
                f"{self.max_per_layer} residents"
            )
        if not self.can_alloc():
            raise ValueError(
                f"pool exhausted: in_use={self.slabs_in_use} "
                f"capacity={self.capacity}"
            )
        slab = self._free.pop()
        self.table[layer, expert] = slab
        self.last_used[layer, expert] = self._tick
        self.peak_in_use = max(self.peak_in_use, self.slabs_in_use)
        return slab

    def evict(self, layer: int, expert: int) -> int:
        slab = int(self.table[layer, expert])
        if slab < 0:
            raise ValueError(f"({layer}, {expert}) not resident")
        self.table[layer, expert] = -1
        self._free.append(slab)
        return slab

    def free_layer(self, layer: int) -> List[int]:
        """Release every slab a layer holds (the layer left the end tier
        at a split replan).  Returns the freed physical slabs."""
        freed = []
        for e in np.nonzero(self.table[layer] >= 0)[0]:
            freed.append(self.evict(layer, int(e)))
        return freed

    def touch(self, layers: Sequence[int], target: np.ndarray):
        """LRU stamp: residents inside the applied routing set count as
        used this tick (non-target residents age out)."""
        self._tick += 1
        for layer in layers:
            used = (self.table[layer] >= 0) & target
            self.last_used[layer, used] = self._tick

    # -- residency policy -----------------------------------------------------

    def plan(
        self,
        active_layers: Sequence[int],
        target: np.ndarray,  # bool [E]: the eq. 4 mask (shared across layers)
        freq: Optional[np.ndarray] = None,  # [E] measured routing frequency
    ) -> Tuple[List[Tuple[int, int]], List[Tuple[int, int]]]:
        """Decide residency updates toward ``target`` on ``active_layers``.

        Returns ``(wanted, evictions)`` as (layer, expert) lists:

          * ``evictions`` — residents of inactive layers, then residents
            the budget can no longer carry, least-valuable first
            (non-target before target, then lowest route frequency, then
            least-recently-used); a layer's last target resident is only
            taken when the overflow leaves no other choice.
          * ``wanted`` — target experts not yet resident, ordered so every
            active layer gets its most-routed expert before any layer gets
            its second (no layer is starved to zero residents), then by
            measured route frequency, highest first.

        Non-target residents are kept as a warm cache while the budget has
        room — they are only evicted to make space or to fit a shrunk
        capacity.
        """
        E = self.num_experts
        freq = np.zeros((E,)) if freq is None else np.asarray(freq, np.float64)
        active = set(int(x) for x in active_layers)

        evictions: List[Tuple[int, int]] = []
        for layer in range(self.n_layers):
            if layer not in active:
                for e in np.nonzero(self.table[layer] >= 0)[0]:
                    evictions.append((layer, int(e)))

        # wanted: round-robin by per-layer rank so each active layer gets
        # its top expert first, frequency-desc within a rank
        per_layer: List[List[Tuple[int, int]]] = []
        for layer in sorted(active):
            missing = [
                int(e) for e in np.argsort(-freq, kind="stable")
                if target[e] and self.table[layer, e] < 0
            ]
            # per-layer slot room counts target residents only: non-target
            # residents are evictable to make space for target experts
            n_target_res = int((self.table[layer][target] >= 0).sum())
            room = self.max_per_layer - n_target_res
            per_layer.append([(layer, e) for e in missing[:max(room, 0)]])
        wanted: List[Tuple[int, int]] = []
        rank = 0
        while any(rank < len(lst) for lst in per_layer):
            for lst in per_layer:
                if rank < len(lst):
                    wanted.append(lst[rank])
            rank += 1

        # per-layer slot pressure: a layer whose resident slots are full of
        # stale non-target experts must shed them so its wanted target
        # experts have somewhere to land (lowest-frequency, LRU first)
        wanted_per_layer: Dict[int, int] = {}
        for layer, e in wanted:
            wanted_per_layer[layer] = wanted_per_layer.get(layer, 0) + 1
        for layer in sorted(active):
            over = (self.resident_count(layer)
                    + wanted_per_layer.get(layer, 0) - self.max_per_layer)
            if over <= 0:
                continue
            stale = sorted(
                (int(e) for e in np.nonzero(self.table[layer] >= 0)[0]
                 if not target[e]),
                key=lambda e: (freq[e], self.last_used[layer, e], e),
            )
            evictions.extend((layer, e) for e in stale[:over])

        # evictions beyond that: fit global capacity + make room
        in_use_after = self.slabs_in_use - len(evictions)
        overflow = max(0, in_use_after - self.capacity)
        room = max(0, self.capacity - in_use_after)
        need = overflow + max(0, len(wanted) - room)
        if need > 0:
            already = set(evictions)
            n_target_res = {
                layer: int((self.table[layer][target] >= 0).sum())
                for layer in sorted(active)
            }
            cands: List[Tuple[Tuple, Tuple[int, int]]] = []
            for layer in sorted(active):
                for e in np.nonzero(self.table[layer] >= 0)[0]:
                    e = int(e)
                    if (layer, e) in already:
                        continue
                    cands.append((
                        (1 if target[e] else 0, freq[e],
                         self.last_used[layer, e], e),
                        (layer, e),
                    ))
            cands.sort(key=lambda c: c[0])
            taken = set()
            # pass 1: non-target residents serve any need; target residents
            # are evicted ONLY under capacity overflow (never to make room
            # for another layer's wanted expert — that would thrash: evict
            # here, prefetch there, forever), and never a layer's last one
            for key, (layer, e) in cands:
                if need <= 0:
                    break
                if key[0] == 1:
                    if overflow <= 0 or n_target_res[layer] <= 1:
                        continue
                    n_target_res[layer] -= 1
                evictions.append((layer, e))
                taken.add((layer, e))
                need -= 1
                overflow = max(0, overflow - 1)
            # pass 2: a capacity overflow that cannot be satisfied otherwise
            # may zero layers (shrinking budgets beat starving the pool) —
            # but growth never does
            if need > 0 and overflow > 0:
                for key, (layer, e) in cands:
                    if need <= 0 or overflow <= 0:
                        break
                    if (layer, e) in taken:
                        continue
                    evictions.append((layer, e))
                    need -= 1
                    overflow -= 1
        return wanted, evictions


def device_resident_tables(
    pool: ExpertSlabPool,
    layer_ids: Sequence[int],  # pool layer id per end-tier block, in order
    s_cap: int,
) -> Dict[str, jax.Array]:
    """Device view of the resident tables for one MoE pattern position:

      * ``ids [n_blocks, s_cap + 1]`` — physical slab row of each resident
        slot (ascending expert id; unused slots and the sentinel last slot
        map to the garbage slab), the gather index ``moe_resident`` reads
        weights through;
      * ``slot [n_blocks, E]`` — expert id -> resident slot, with
        non-resident experts mapped to the garbage slot ``s_cap`` (which
        is how the in-trace effective routing mask ``slot < s_cap`` and
        the zero-contribution dispatch fall out).
    """
    n = len(layer_ids)
    ids = np.full((n, s_cap + 1), pool.garbage_slab, np.int64)
    slot = np.full((n, pool.num_experts), s_cap, np.int64)
    for b, lid in enumerate(layer_ids):
        res = np.nonzero(pool.table[lid] >= 0)[0]
        for s_i, e in enumerate(res[:s_cap]):
            ids[b, s_i] = pool.table[lid, e]
            slot[b, e] = s_i
    return {
        "ids": jnp.asarray(ids, jnp.int32),
        "slot": jnp.asarray(slot, jnp.int32),
    }
