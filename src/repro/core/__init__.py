"""EC2MoE core: the paper's contributions as composable JAX modules.

  * :mod:`repro.core.gating`      — HL-GGN lightweight group gate (eq. 5-7)
  * :mod:`repro.core.hardware`    — device profiles + capability model (eq. 2-3)
  * :mod:`repro.core.selection`   — hardware-aware local expert selection (eq. 4)
  * :mod:`repro.core.compression` — low-rank encoder/decoder (eq. 8)
  * :mod:`repro.core.moe`         — group-gated MoE layer (dense / sorted / EP all-to-all)
  * :mod:`repro.core.pipeline`    — route-aware heuristic pipeline scheduler (eq. 9-11)
"""
