"""HL-GGN: Hardware-aware Lightweight Group Gate Network (paper eq. 5-7).

The M experts are split into K groups.  Stage 1 is a K-way global gate
(eq. 6); stage 2 is a per-group M_k-way gate (eq. 5).  The final selection
probability is the product of the stages (eq. 7):

    g_i(x) = p_group^{(k)}(x) * p_local,i^{(k)}(x),   i in group k

which is a valid distribution over all M experts by construction.  Compared
with a flat M-way gate the parameter count drops from M*d to M*d/K * K = M*d
for the locals... the *compute* win is that stage 1 is K-way and stage 2 runs
only for selected groups when ``group_top_k`` restriction is on; the
*quality* win (per the paper) is the group-structured factorization.

TPU-native reading: when K == expert-parallel degree and experts are laid out
contiguously, stage-1 routing IS dispatch-shard routing, so restricting to
``group_top_k`` groups directly caps all-to-all fan-out per token.
"""

from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import truncated_normal_init

NEG_INF = -1e30


class GateOutput(NamedTuple):
    probs: jax.Array  # [T, E] combined probabilities (eq. 7)
    topk_idx: jax.Array  # [T, k] selected experts
    topk_weight: jax.Array  # [T, k] combine weights (renormalized)
    p_group: jax.Array  # [T, K] stage-1 probabilities
    aux: Dict[str, jax.Array]  # load-balance metrics / losses


def init_group_gate(key, d_model: int, moe_cfg, dtype=jnp.float32) -> Dict:
    K = moe_cfg.num_groups
    Mk = moe_cfg.experts_per_group
    kl, kg = jax.random.split(key)
    return {
        # K per-group gates, stacked: [K, d, M_k]  (eq. 5)
        "w_local": truncated_normal_init(kl, (K, d_model, Mk), dtype, 1.0),
        "b_local": jnp.zeros((K, Mk), dtype),
        # global K-way gate: [d, K]  (eq. 6)
        "w_global": truncated_normal_init(kg, (d_model, K), dtype, 1.0),
        "b_global": jnp.zeros((K,), dtype),
    }


def group_gate_logits(params: Dict, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: [T, d] -> (local_logits [T, K, M_k], global_logits [T, K]).
    Router math always runs in fp32."""
    xf = x.astype(jnp.float32)
    local = (
        jnp.einsum("td,kdm->tkm", xf, params["w_local"].astype(jnp.float32))
        + params["b_local"].astype(jnp.float32)[None]
    )
    glob = xf @ params["w_global"].astype(jnp.float32) + params["b_global"].astype(
        jnp.float32
    )
    return local, glob


def group_gate_probs(
    params: Dict,
    x: jax.Array,  # [T, d]
    moe_cfg,
    expert_mask: Optional[jax.Array] = None,  # bool [E] or [T, E]; True = allowed
) -> Tuple[jax.Array, jax.Array, Dict[str, jax.Array]]:
    """Two-stage gate (eq. 5-7).  Returns (probs [T,E], p_group [T,K], aux)."""
    K, Mk = moe_cfg.num_groups, moe_cfg.experts_per_group
    T = x.shape[0]
    local, glob = group_gate_logits(params, x)

    # z-losses regularize the *router* logit scale, so they are computed on
    # the pre-mask logits: a hardware mask (eq. 4) turning off experts must
    # not inject logsumexp(NEG_INF)^2 ~ 1e60 into the loss whenever it
    # disables a whole group.
    z_global = jnp.mean(jax.nn.logsumexp(glob, axis=-1) ** 2)
    z_local = jnp.mean(jax.nn.logsumexp(local, axis=-1) ** 2)

    if expert_mask is not None:
        em = expert_mask.reshape((-1, K, Mk)) if expert_mask.ndim == 2 else (
            expert_mask.reshape((K, Mk))[None]
        )
        local = jnp.where(em, local, NEG_INF)
        # a fully-masked group must get zero stage-1 probability
        group_ok = em.any(axis=-1)  # [*, K]
        glob = jnp.where(group_ok, glob, NEG_INF)

    p_local = jax.nn.softmax(local, axis=-1)  # [T, K, M_k] (eq. 5)
    p_group = jax.nn.softmax(glob, axis=-1)  # [T, K]      (eq. 6)

    if moe_cfg.group_top_k and moe_cfg.group_top_k < K:
        # Hard locality restriction: keep only the top-g groups, renormalize.
        # Selection is by top-k *indices* scattered back to a keep mask: a
        # probability threshold would keep every tied group (e.g. uniform
        # post-mask probs) and break the dispatch fan-out bound group_top_k
        # guarantees on the a2a path.  top_k tie-breaks by lowest index, so
        # exactly g groups survive.
        g = moe_cfg.group_top_k
        _, top_groups = jax.lax.top_k(p_group, g)  # [T, g]
        keep = jnp.any(jax.nn.one_hot(top_groups, K, dtype=jnp.bool_), axis=-2)
        p_group = jnp.where(keep, p_group, 0.0)
        p_group = p_group / jnp.maximum(p_group.sum(-1, keepdims=True), 1e-9)

    probs = (p_group[:, :, None] * p_local).reshape(T, K * Mk)  # (eq. 7)

    aux = {"router_z": z_global + z_local}
    return probs, p_group, aux


def select_topk(
    probs: jax.Array, top_k: int, renormalize: bool = True
) -> Tuple[jax.Array, jax.Array]:
    w, idx = jax.lax.top_k(probs, top_k)
    if renormalize:
        w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    return idx, w


def load_balance_loss(
    probs: jax.Array,  # [T, E]
    topk_idx: jax.Array,  # [T, k]
    num_experts: int,
    num_groups: int,
) -> Dict[str, jax.Array]:
    """Switch/GShard auxiliary loss at expert AND group granularity.

    f_e = fraction of assignments routed to e; P_e = mean router prob.
    L = E * sum_e f_e P_e  (=1 at perfect balance).
    The group-level variant is the HL-GGN analogue: it drives the stage-1
    gate toward balanced *shard* load, which is what bounds the all-to-all.
    """
    T, k = topk_idx.shape
    E, K = num_experts, num_groups
    Mk = E // K
    assign = jax.nn.one_hot(topk_idx.reshape(-1), E, dtype=jnp.float32)
    f = assign.mean(0)  # [E] fraction per assignment slot
    P = probs.astype(jnp.float32).mean(0)  # [E]
    expert_loss = E * jnp.sum(f * P)
    fg = f.reshape(K, Mk).sum(-1)
    Pg = P.reshape(K, Mk).sum(-1)
    group_loss = K * jnp.sum(fg * Pg)
    return {
        "lb_expert": expert_loss,
        "lb_group": group_loss,
        "expert_frac": f,
        "group_frac": fg,
    }


def gate(
    params: Dict,
    x: jax.Array,  # [T, d]
    moe_cfg,
    expert_mask: Optional[jax.Array] = None,
) -> GateOutput:
    """Full HL-GGN gate: probabilities, top-k selection, aux losses."""
    probs, p_group, aux = group_gate_probs(params, x, moe_cfg, expert_mask)
    topk_idx, topk_w = select_topk(probs, moe_cfg.top_k)
    lb = load_balance_loss(probs, topk_idx, moe_cfg.num_experts, moe_cfg.num_groups)
    aux = dict(aux)
    aux.update({k: v for k, v in lb.items() if k.startswith("lb_")})
    # measured routing statistics (assignment fractions per expert/group):
    # the serving engines EMA these to order the eq. 4 greedy admit and to
    # drive the expert pool's prefetch/evict policy
    aux["expert_frac"] = lb["expert_frac"]
    aux["group_frac"] = lb["group_frac"]
    aux["aux_loss"] = (
        moe_cfg.router_aux_weight * (lb["lb_expert"] + lb["lb_group"])
        + moe_cfg.router_z_weight * aux["router_z"]
    )
    return GateOutput(probs, topk_idx, topk_w, p_group, aux)


def init_flat_gate(key, d_model: int, num_experts: int, dtype=jnp.float32) -> Dict:
    """Baseline: traditional single-FC gate (the paper's strawman)."""
    return {
        "w": truncated_normal_init(key, (d_model, num_experts), dtype, 1.0),
        "b": jnp.zeros((num_experts,), dtype),
    }


def flat_gate_probs(params: Dict, x: jax.Array) -> jax.Array:
    logits = x.astype(jnp.float32) @ params["w"].astype(jnp.float32) + params[
        "b"
    ].astype(jnp.float32)
    return jax.nn.softmax(logits, axis=-1)


def gate_flop_count(d_model: int, num_experts: int, num_groups: int, group_top_k: int = 0):
    """Analytic per-token gate FLOPs: flat vs. grouped (paper's table talking
    point; also used by the route-aware scheduler's cost model)."""
    flat = 2 * d_model * num_experts
    K = num_groups
    Mk = num_experts // K
    g = group_top_k if group_top_k else K
    grouped = 2 * d_model * K + g * 2 * d_model * Mk
    return {"flat": flat, "grouped": grouped}
