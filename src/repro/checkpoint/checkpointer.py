"""Checkpointing: atomic, resumable, optionally async.

Layout: <dir>/step_<N>/  containing
  arrays.npz   — every leaf, keyed by its '/'-joined tree path
  meta.json    — step, timestamp, user metadata, tree manifest

Writes go to ``step_<N>.tmp`` and are ``os.replace``d into place, so a
crash mid-write can never corrupt the latest checkpoint — the restore path
simply ignores ``*.tmp``.  ``keep`` bounds disk usage; ``async_save``
snapshots to host memory synchronously (correctness) and writes on a
background thread (doesn't stall the step loop).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for kp, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in kp
        )
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten(template, flat: Dict[str, np.ndarray]):
    leaves_kp, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for kp, ref in leaves_kp:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in kp
        )
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs model {ref.shape}"
            )
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class Checkpointer:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- write ---------------------------------------------------------------

    def save(self, step: int, state: Any, metadata: Optional[Dict] = None):
        host_state = jax.tree.map(np.asarray, jax.device_get(state))
        self._write(step, host_state, metadata or {})

    def async_save(self, step: int, state: Any, metadata: Optional[Dict] = None):
        """Snapshot synchronously (device_get), write in the background."""
        self.wait()
        host_state = jax.tree.map(np.asarray, jax.device_get(state))
        self._thread = threading.Thread(
            target=self._write, args=(step, host_state, metadata or {}), daemon=True
        )
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_state, metadata: Dict):
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        flat = _flatten(host_state)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(
                {"step": step, "time": time.time(), "n_leaves": len(flat),
                 **metadata},
                f,
            )
        shutil.rmtree(final, ignore_errors=True)
        os.replace(tmp, final)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(
                os.path.join(self.dir, f"step_{s:08d}"), ignore_errors=True
            )

    # -- read ----------------------------------------------------------------

    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name.split("_")[1]))
                except (IndexError, ValueError):
                    continue
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Any = None) -> Tuple[int, Any]:
        """Restore into the structure of ``template``; optionally place onto
        ``shardings`` (a pytree of NamedSharding — elastic re-mesh path)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}", "arrays.npz")
        with np.load(path) as z:
            flat = {k: z[k] for k in z.files}
        state = _unflatten(template, flat)
        if shardings is not None:
            state = jax.tree.map(
                lambda x, s: jax.device_put(x, s), state, shardings
            )
        return step, state

    def metadata(self, step: Optional[int] = None) -> Dict:
        step = step if step is not None else self.latest_step()
        with open(os.path.join(self.dir, f"step_{step:08d}", "meta.json")) as f:
            return json.load(f)
