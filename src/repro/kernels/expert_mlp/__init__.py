from repro.kernels.expert_mlp.ops import expert_mlp

__all__ = ["expert_mlp"]
