"""Capacity-buffered batched expert FFN Pallas kernel.

Computes y[e] = act(x[e] @ wi[e]) * (x[e] @ wg[e]) @ wo[e] for every expert's
fixed-capacity token buffer — the compute stage right after the EC2MoE
all-to-all dispatch.

Grid: (experts, token-blocks, ff-tiles).  The ff dimension is the
minor-most grid axis so each (e, c) output block stays resident in VMEM
while partial products over ff tiles accumulate into it (fp32), then is
written once.  This keeps the [C, f] hidden activation entirely on-chip:
the XLA fallback writes h to HBM (C x f x 2B per expert) and reads it back,
which at qwen3-moe scale (C=4k, f=1.5k) is ~25 MB of HBM traffic per expert
per layer that the kernel never spends.

**Resident variant** (``expert_mlp_resident_pallas``): the paged
expert-weight pool's execution shape.  ``x`` holds one capacity buffer per
*resident slot* (S of them, S = the end tier's resident-slot count), the
weights are the slab store ``[num_slabs + 1, ...]``, and a per-slot
``resident_ids [S]`` operand — a *scalar-prefetch* operand, exactly like
the paged-attention page table — drives the weight BlockSpec index maps,
so each grid step DMAs tiles of exactly one resident slab.  The grid is
``(S, token-blocks, ff-tiles)``: compute AND weight HBM traffic scale with
residents, not the full expert count E.

VMEM per step (d=4096, f-tile=512, C-block=256, bf16 weights):
  x 256x4096x2 = 2 MiB, wi/wg tiles 2x4096x512x2 = 8 MiB,
  wo tile 512x4096x2 = 4 MiB (streamed), h 256x512x4 = 0.5 MiB,
  acc 256x4096x4 = 4 MiB -> ~14.5 MiB; ops.py shrinks tiles for big d.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

ACTS = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}


def _body(x_ref, wi_ref, wis_ref, wg_ref, wgs_ref, wo_ref, wos_ref, o_ref,
          *, act: str):
    j = pl.program_id(2)  # ff tile (minor-most: sequential accumulation)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[0].astype(jnp.float32)  # [bc, d]
    wi = wi_ref[0].astype(jnp.float32)  # [d, bf]
    h = jax.lax.dot_general(
        x, wi, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    if wis_ref is not None:
        # int8 slab tile: fold the per-output-column scale AFTER the dot —
        # exact, and the MXU sees the raw int8-coded tile
        h = h * wis_ref[0].astype(jnp.float32)[None, :]
    a = ACTS[act]
    if wg_ref is not None:
        wg = wg_ref[0].astype(jnp.float32)
        g = jax.lax.dot_general(
            x, wg, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        if wgs_ref is not None:
            g = g * wgs_ref[0].astype(jnp.float32)[None, :]
        h = a(h) * g
    else:
        h = a(h)
    wo = wo_ref[0].astype(jnp.float32)  # [bf, d]
    y = jax.lax.dot_general(
        h, wo, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    if wos_ref is not None:
        y = y * wos_ref[0].astype(jnp.float32)[None, :]
    o_ref[0] += y.astype(o_ref.dtype)


def _kernel(x_ref, wi_ref, wg_ref, wo_ref, o_ref, *, act: str):
    _body(x_ref, wi_ref, None, wg_ref, None, wo_ref, None, o_ref, act=act)


def expert_mlp_pallas(
    x, wi, wg, wo, *, act="silu", block_c=256, block_f=512, interpret=False
):
    E, C, d = x.shape
    f = wi.shape[2]
    bc = min(block_c, C)
    bf = min(block_f, f)
    assert C % bc == 0 and f % bf == 0, (C, bc, f, bf)
    grid = (E, C // bc, f // bf)

    in_specs = [
        pl.BlockSpec((1, bc, d), lambda e, c, j: (e, c, 0)),
        pl.BlockSpec((1, d, bf), lambda e, c, j: (e, 0, j)),
    ]
    args = [x, wi]
    if wg is not None:
        in_specs.append(pl.BlockSpec((1, d, bf), lambda e, c, j: (e, 0, j)))
        args.append(wg)
    in_specs.append(pl.BlockSpec((1, bf, d), lambda e, c, j: (e, j, 0)))
    args.append(wo)

    kernel = functools.partial(
        _kernel if wg is not None else _kernel_nogate, act=act
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bc, d), lambda e, c, j: (e, c, 0)),
        out_shape=jax.ShapeDtypeStruct((E, C, d), jnp.float32),
        interpret=interpret,
    )(*args)


def _kernel_nogate(x_ref, wi_ref, wo_ref, o_ref, *, act: str):
    _kernel(x_ref, wi_ref, None, wo_ref, o_ref, act=act)


def _kernel_resident(ids_ref, x_ref, wi_ref, wg_ref, wo_ref, o_ref, *, act):
    # the resident indirection lives entirely in the BlockSpec index maps
    # (ids_ref is the scalar-prefetch operand); the compute body is shared
    _kernel(x_ref, wi_ref, wg_ref, wo_ref, o_ref, act=act)


def _kernel_resident_nogate(ids_ref, x_ref, wi_ref, wo_ref, o_ref, *, act):
    _kernel(x_ref, wi_ref, None, wo_ref, o_ref, act=act)


def _kernel_resident_quant(ids_ref, x_ref, wi_ref, wis_ref, wg_ref, wgs_ref,
                           wo_ref, wos_ref, o_ref, *, act):
    _body(x_ref, wi_ref, wis_ref, wg_ref, wgs_ref, wo_ref, wos_ref, o_ref,
          act=act)


def _kernel_resident_quant_nogate(ids_ref, x_ref, wi_ref, wis_ref,
                                  wo_ref, wos_ref, o_ref, *, act):
    _body(x_ref, wi_ref, wis_ref, None, None, wo_ref, wos_ref, o_ref, act=act)


def expert_mlp_resident_pallas(
    x,  # [S, C, d] one capacity buffer per resident slot
    wi,  # [N, d, f] slab store (N = num_slabs, possibly + garbage row)
    wg,  # [N, d, f] | None
    wo,  # [N, f, d]
    resident_ids,  # [S] int32: resident slot -> physical slab row
    *,
    wi_scale=None,  # [N, f] fp32 per-output-column scales (int8 store)
    wg_scale=None,  # [N, f] | None
    wo_scale=None,  # [N, d]
    act="silu",
    block_c=256,
    block_f=512,
    interpret=False,
):
    """Resident-sub-table expert FFN: grid (resident-slot, token-block,
    ff-tile) with ``resident_ids`` scalar-prefetched so the weight
    BlockSpecs DMA tiles of exactly the slot's slab — HBM weight traffic
    is S slabs, never the whole store.

    With ``*_scale`` sidecars the store holds int8 codes: each weight tile
    is DMA'd at int8 width (quarter the fp32 slab traffic) and its
    per-output-column scale row is folded into the partial product in VMEM
    right after the dot — the dequantized tile never exists in HBM."""
    S, C, d = x.shape
    f = wi.shape[2]
    bc = min(block_c, C)
    bf = min(block_f, f)
    assert C % bc == 0 and f % bf == 0, (C, bc, f, bf)
    grid = (S, C // bc, f // bf)
    quantized = wi_scale is not None

    in_specs = [
        pl.BlockSpec((1, bc, d), lambda s, c, j, ids: (s, c, 0)),
        pl.BlockSpec((1, d, bf), lambda s, c, j, ids: (ids[s], 0, j)),
    ]
    args = [x, wi]
    if quantized:
        in_specs.append(pl.BlockSpec((1, bf), lambda s, c, j, ids: (ids[s], j)))
        args.append(wi_scale)
    if wg is not None:
        in_specs.append(pl.BlockSpec((1, d, bf), lambda s, c, j, ids: (ids[s], 0, j)))
        args.append(wg)
        if quantized:
            in_specs.append(
                pl.BlockSpec((1, bf), lambda s, c, j, ids: (ids[s], j))
            )
            args.append(wg_scale)
    in_specs.append(pl.BlockSpec((1, bf, d), lambda s, c, j, ids: (ids[s], j, 0)))
    args.append(wo)
    if quantized:
        in_specs.append(pl.BlockSpec((1, d), lambda s, c, j, ids: (ids[s], 0)))
        args.append(wo_scale)

    if quantized:
        kernel = functools.partial(
            _kernel_resident_quant if wg is not None
            else _kernel_resident_quant_nogate,
            act=act,
        )
    else:
        kernel = functools.partial(
            _kernel_resident if wg is not None else _kernel_resident_nogate,
            act=act,
        )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bc, d), lambda s, c, j, ids: (s, c, 0)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((S, C, d), jnp.float32),
        interpret=interpret,
    )(resident_ids.astype(jnp.int32), *args)
