"""Capacity-buffered batched expert FFN Pallas kernel.

Computes y[e] = act(x[e] @ wi[e]) * (x[e] @ wg[e]) @ wo[e] for every expert's
fixed-capacity token buffer — the compute stage right after the EC2MoE
all-to-all dispatch.

Grid: (experts, token-blocks, ff-tiles).  The ff dimension is the
minor-most grid axis so each (e, c) output block stays resident in VMEM
while partial products over ff tiles accumulate into it (fp32), then is
written once.  This keeps the [C, f] hidden activation entirely on-chip:
the XLA fallback writes h to HBM (C x f x 2B per expert) and reads it back,
which at qwen3-moe scale (C=4k, f=1.5k) is ~25 MB of HBM traffic per expert
per layer that the kernel never spends.

VMEM per step (d=4096, f-tile=512, C-block=256, bf16 weights):
  x 256x4096x2 = 2 MiB, wi/wg tiles 2x4096x512x2 = 8 MiB,
  wo tile 512x4096x2 = 4 MiB (streamed), h 256x512x4 = 0.5 MiB,
  acc 256x4096x4 = 4 MiB -> ~14.5 MiB; ops.py shrinks tiles for big d.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ACTS = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}


def _kernel(x_ref, wi_ref, wg_ref, wo_ref, o_ref, *, act: str):
    j = pl.program_id(2)  # ff tile (minor-most: sequential accumulation)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[0].astype(jnp.float32)  # [bc, d]
    wi = wi_ref[0].astype(jnp.float32)  # [d, bf]
    h = jax.lax.dot_general(
        x, wi, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    a = ACTS[act]
    if wg_ref is not None:
        wg = wg_ref[0].astype(jnp.float32)
        g = jax.lax.dot_general(
            x, wg, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        h = a(h) * g
    else:
        h = a(h)
    wo = wo_ref[0].astype(jnp.float32)  # [bf, d]
    y = jax.lax.dot_general(
        h, wo, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    o_ref[0] += y.astype(o_ref.dtype)


def expert_mlp_pallas(
    x, wi, wg, wo, *, act="silu", block_c=256, block_f=512, interpret=False
):
    E, C, d = x.shape
    f = wi.shape[2]
    bc = min(block_c, C)
    bf = min(block_f, f)
    assert C % bc == 0 and f % bf == 0, (C, bc, f, bf)
    grid = (E, C // bc, f // bf)

    in_specs = [
        pl.BlockSpec((1, bc, d), lambda e, c, j: (e, c, 0)),
        pl.BlockSpec((1, d, bf), lambda e, c, j: (e, 0, j)),
    ]
    args = [x, wi]
    if wg is not None:
        in_specs.append(pl.BlockSpec((1, d, bf), lambda e, c, j: (e, 0, j)))
        args.append(wg)
    in_specs.append(pl.BlockSpec((1, bf, d), lambda e, c, j: (e, j, 0)))
    args.append(wo)

    kernel = functools.partial(
        _kernel if wg is not None else _kernel_nogate, act=act
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bc, d), lambda e, c, j: (e, c, 0)),
        out_shape=jax.ShapeDtypeStruct((E, C, d), jnp.float32),
        interpret=interpret,
    )(*args)


def _kernel_nogate(x_ref, wi_ref, wo_ref, o_ref, *, act: str):
    _kernel(x_ref, wi_ref, None, wo_ref, o_ref, act=act)
