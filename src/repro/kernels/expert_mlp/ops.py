"""Public wrapper for the batched expert FFN kernel.

``interpret=None`` (the default) resolves per backend: compiled on TPU,
interpreted elsewhere (CPU validation) — an explicit bool forces it, so
the kernel is never silently interpreted on TPU.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import resolve_interpret
from repro.kernels.expert_mlp.kernel import (
    expert_mlp_pallas,
    expert_mlp_resident_pallas,
)


def _pick_tiles(C: int, d: int, f: int):
    bc, bf = 256, 512
    # shrink until x + 2 gate tiles + wo tile + acc fit ~12 MiB fp32-equiv
    def vmem(bc, bf):
        return (bc * d * 2 + 2 * d * bf * 2 + bf * d * 2 + bc * bf * 4 + bc * d * 4)

    while vmem(bc, bf) > 12 * 2**20 and bf > 128:
        bf //= 2
    while vmem(bc, bf) > 12 * 2**20 and bc > 32:
        bc //= 2
    while C % bc:
        bc //= 2
    while f % bf:
        bf //= 2
    return max(bc, 1), max(bf, 1)


@functools.partial(jax.jit, static_argnames=("act", "interpret"))
def expert_mlp(
    x: jax.Array,  # [E, C, d] — or [S, C, d] with resident_ids
    wi: jax.Array,  # [E, d, f] — or the slab store [N, d, f]
    wg: Optional[jax.Array],  # same layout as wi | None
    wo: jax.Array,  # [E, f, d] — or [N, f, d]
    *,
    resident_ids: Optional[jax.Array] = None,  # [S] slot -> slab row
    wi_scale: Optional[jax.Array] = None,  # [N, f] fp32 (int8 slab store)
    wg_scale: Optional[jax.Array] = None,  # [N, f] | None
    wo_scale: Optional[jax.Array] = None,  # [N, d]
    act: str = "silu",
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Batched expert FFN.  With ``resident_ids`` (the paged expert-weight
    pool's execution shape) the leading axis of ``x`` is the *resident
    slot*, the weights are the slab store, and the scalar-prefetched ids
    drive the weight DMA — compute and weight HBM traffic scale with the
    resident count, not the expert count.  ``*_scale`` sidecars mark an
    int8 slab store: tiles are DMA'd at int8 width and dequantized in VMEM
    right after each dot (resident variant only)."""
    interpret = resolve_interpret(interpret)
    E, C, d = x.shape
    f = wi.shape[2]
    bc, bf = _pick_tiles(C, d, f)
    if resident_ids is not None:
        y = expert_mlp_resident_pallas(
            x, wi, wg, wo, resident_ids,
            wi_scale=wi_scale, wg_scale=wg_scale, wo_scale=wo_scale,
            act=act, block_c=bc, block_f=bf, interpret=interpret,
        )
    else:
        y = expert_mlp_pallas(
            x, wi, wg, wo, act=act, block_c=bc, block_f=bf, interpret=interpret
        )
    return y.astype(x.dtype)
