"""Pure-jnp oracle for the capacity-buffered batched expert FFN."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

ACTS = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}


def expert_mlp_ref(
    x: jax.Array,  # [E, C, d] per-expert capacity buffers
    wi: jax.Array,  # [E, d, f]
    wg: Optional[jax.Array],  # [E, d, f] or None
    wo: jax.Array,  # [E, f, d]
    act: str = "silu",
) -> jax.Array:
    a = ACTS[act]
    h = jnp.einsum(
        "ecd,edf->ecf", x.astype(jnp.float32), wi.astype(jnp.float32)
    )
    if wg is not None:
        h = a(h) * jnp.einsum(
            "ecd,edf->ecf", x.astype(jnp.float32), wg.astype(jnp.float32)
        )
    else:
        h = a(h)
    y = jnp.einsum("ecf,efd->ecd", h, wo.astype(jnp.float32))
    return y.astype(x.dtype)


def expert_mlp_resident_ref(
    x: jax.Array,  # [S, C, d] per-resident-slot capacity buffers
    wi: jax.Array,  # [N, d, f] slab store
    wg,  # [N, d, f] or None
    wo: jax.Array,  # [N, f, d]
    resident_ids: jax.Array,  # [S] slot -> physical slab row
    act: str = "silu",
) -> jax.Array:
    """Oracle for the resident variant: gather the S resident slabs, then
    the dense batched FFN over them."""
    return expert_mlp_ref(
        x,
        wi[resident_ids],
        None if wg is None else wg[resident_ids],
        wo[resident_ids],
        act,
    )


def expert_mlp_resident_quant_ref(
    x: jax.Array,  # [S, C, d]
    wi: jax.Array,  # [N, d, f] int8 slab store
    wg,  # [N, d, f] int8 or None
    wo: jax.Array,  # [N, f, d] int8
    wi_scale: jax.Array,  # [N, f] fp32 per-output-column scales
    wg_scale,  # [N, f] or None
    wo_scale: jax.Array,  # [N, d]
    resident_ids: jax.Array,  # [S] slot -> physical slab row
    act: str = "silu",
) -> jax.Array:
    """Oracle for the int8-store resident variant: dequantize the gathered
    slabs (per-output-column scales — exact modulo the int8 grid) and run
    the dense batched FFN."""
    ids = resident_ids
    wi_d = wi[ids].astype(jnp.float32) * wi_scale[ids][:, None, :]
    wo_d = wo[ids].astype(jnp.float32) * wo_scale[ids][:, None, :]
    wg_d = None
    if wg is not None:
        wg_d = wg[ids].astype(jnp.float32) * wg_scale[ids][:, None, :]
    return expert_mlp_ref(x, wi_d, wg_d, wo_d, act)
