"""Pure-jnp oracle for the capacity-buffered batched expert FFN."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

ACTS = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}


def expert_mlp_ref(
    x: jax.Array,  # [E, C, d] per-expert capacity buffers
    wi: jax.Array,  # [E, d, f]
    wg: Optional[jax.Array],  # [E, d, f] or None
    wo: jax.Array,  # [E, f, d]
    act: str = "silu",
) -> jax.Array:
    a = ACTS[act]
    h = jnp.einsum(
        "ecd,edf->ecf", x.astype(jnp.float32), wi.astype(jnp.float32)
    )
    if wg is not None:
        h = a(h) * jnp.einsum(
            "ecd,edf->ecf", x.astype(jnp.float32), wg.astype(jnp.float32)
        )
    else:
        h = a(h)
    y = jnp.einsum("ecf,efd->ecd", h, wo.astype(jnp.float32))
    return y.astype(x.dtype)
