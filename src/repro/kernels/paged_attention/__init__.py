"""Fused paged decode/chunk attention: page-table lookup + ring-position
masking + online-softmax attention in one pass over the KV page pool."""
