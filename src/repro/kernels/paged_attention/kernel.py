"""Fused paged decode/chunk-attention Pallas kernel (decode hot path).

Grid: (batch, kv-head, page-entry) with the page sweep minor-most.  The
per-slot page table is a *scalar-prefetch* operand, so the K/V BlockSpec
index maps resolve ``table[b, j]`` before each grid step and DMA exactly
one physical page of the pool — ``[page_size, hd]`` for head ``h`` — from
HBM to VMEM.  No dense ``[B, pps*ps, KV, hd]`` ring view is ever
materialized: HBM traffic per (batch, head) is the slot's mapped pages,
not ``max_len``.

The online-softmax state (m, l) and the output accumulator live in VMEM
scratch and persist across the page sweep for a fixed (b, h), exactly like
the flash kernel; the output block is written once when the sweep flushes.

Page-skip rule: a page is *dead* when its table entry is garbage-routed
(unmapped entry or inactive slot — the engines map those to the pool's
last row) or when every (query, ring-position) pair it holds is masked
(positions not yet written on this lap, or wholly outside the sliding
window).  Dead pages are skipped with ``pl.when``: no MXU flops, no
softmax update.  All garbage entries map to the *same* physical row, so
Pallas's block-index pipelining elides their repeated fetches; a mapped
but window-dead page still costs its (single) fetch but no compute.

GQA is handled in the index maps (kv blocks are fetched once per KV head)
and in the row layout: the wrapper flattens (C queries x G query heads
per KV head) into ``rows = C*G`` q rows per grid cell, ``row = c*G + g``.

Masking matches ``kvcache.ring_key_positions`` + ``chunk_attention``: ring
slot ``s = j*ps + i`` holds position ``kp = ln - ((ln - s) mod W)`` where
``ln`` is the slot's last written position and ``W = pps*ps``; a key is
visible iff ``0 <= kp <= qpos`` (and ``kp > qpos - window``).  Per-slot
``ln`` and per-query ``qpos`` arrive as one int32 operand
``posinfo[B, 1+C, 1]`` (column 0 = ln, rest = qpos) so the trace depends
only on shapes.

Quantized pools: with ``pool_ks``/``pool_vs`` sidecars the k/v pools hold
int8 codes and each fetched page is dequantized *in VMEM* inside the
online-softmax sweep — the per-token f16 scale row rides the same
``tab[b, j]`` scalar-prefetched indirection as the page itself, so HBM
traffic per page is the int8 bytes plus a [ps] f16 row (~0.52x the bf16
page) and no dequantized copy ever exists outside VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _pa_body(
    table_ref,  # [B, pps] int32 (scalar prefetch, SMEM)
    q_ref,  # [1, 1, rows, hd]
    k_ref,  # [1, ps, 1, hd] one physical page, one kv head
    v_ref,  # [1, ps, 1, hd]
    ks_ref,  # [1, ps] f16 per-token scale sidecar | None (dense pool)
    vs_ref,  # [1, ps] | None
    pos_ref,  # [1, 1+C, 1] int32 (ln, then C query positions)
    o_ref,  # [1, 1, rows, hd]
    m_scr,  # [rows, 1] fp32
    l_scr,  # [rows, 1] fp32
    acc_scr,  # [rows, hd] fp32
    *,
    scale: float,
    ps: int,
    pps: int,
    C: int,
    G: int,
    window,
    garbage: int,
):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    phys = table_ref[b, j]
    ln = pos_ref[0, 0, 0]
    qpos = pos_ref[0, 1:, :]  # [C, 1]
    W = pps * ps
    slot = j * ps + jax.lax.broadcasted_iota(jnp.int32, (1, ps), 1)
    kp = ln - jnp.mod(ln - slot, W)  # [1, ps] ring position per key row
    valid = kp <= qpos  # [C, ps]
    if window is not None:
        valid = jnp.logical_and(valid, kp > qpos - window)
    valid = jnp.logical_and(valid, kp >= 0)
    live = jnp.logical_and(phys != garbage, jnp.any(valid))

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0]  # [rows, hd]
        k = k_ref[0, :, 0, :]  # [ps, hd]
        v = v_ref[0, :, 0, :]
        if ks_ref is not None:
            # quantized pool: dequantize the fetched page in VMEM — the
            # per-token f16 sidecar broadcasts over the head dim
            q = q.astype(jnp.float32)
            k = k.astype(jnp.float32) * ks_ref[0].astype(jnp.float32)[:, None]
            v = v.astype(jnp.float32) * vs_ref[0].astype(jnp.float32)[:, None]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # [rows, ps]
        mask = jnp.broadcast_to(valid[:, None, :], (C, G, ps)).reshape(C * G, ps)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]  # [rows, 1]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new

    @pl.when(j == pps - 1)
    def _flush():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def _pa_kernel(table_ref, q_ref, k_ref, v_ref, pos_ref, o_ref,
               m_scr, l_scr, acc_scr, **kw):
    _pa_body(table_ref, q_ref, k_ref, v_ref, None, None, pos_ref, o_ref,
             m_scr, l_scr, acc_scr, **kw)


def _pa_kernel_quant(table_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref,
                     pos_ref, o_ref, m_scr, l_scr, acc_scr, **kw):
    _pa_body(table_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref, pos_ref, o_ref,
             m_scr, l_scr, acc_scr, **kw)


def paged_attention_pallas(
    q_r,  # [B, KV, rows, hd] with rows = C*G, row = c*G + g
    pool_k,  # [P+1, ps, KV, hd] (row P = garbage page)
    pool_v,
    table,  # [B, pps] int32
    posinfo,  # [B, 1+C, 1] int32
    *,
    pool_ks=None,  # [P+1, ps] f16 per-token scale sidecar (quantized pool)
    pool_vs=None,
    window=None,
    interpret=False,
):
    B, KV, rows, hd = q_r.shape
    ps = pool_k.shape[1]
    pps = table.shape[1]
    C = posinfo.shape[1] - 1
    G = rows // C
    garbage = pool_k.shape[0] - 1
    scale = 1.0 / (hd ** 0.5)
    quantized = pool_ks is not None

    kernel = functools.partial(
        _pa_kernel_quant if quantized else _pa_kernel,
        scale=scale, ps=ps, pps=pps, C=C, G=G,
        window=window, garbage=garbage,
    )
    in_specs = [
        pl.BlockSpec((1, 1, rows, hd), lambda b, h, j, tab: (b, h, 0, 0)),
        pl.BlockSpec((1, ps, 1, hd), lambda b, h, j, tab: (tab[b, j], 0, h, 0)),
        pl.BlockSpec((1, ps, 1, hd), lambda b, h, j, tab: (tab[b, j], 0, h, 0)),
    ]
    args = [q_r, pool_k, pool_v]
    if quantized:
        # the sidecars ride the same scalar-prefetched page indirection as
        # the pools: one [ps] f16 row per fetched page
        in_specs.append(pl.BlockSpec((1, ps), lambda b, h, j, tab: (tab[b, j], 0)))
        in_specs.append(pl.BlockSpec((1, ps), lambda b, h, j, tab: (tab[b, j], 0)))
        args += [pool_ks, pool_vs]
    in_specs.append(pl.BlockSpec((1, C + 1, 1), lambda b, h, j, tab: (b, 0, 0)))
    args.append(posinfo)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, KV, pps),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, rows, hd), lambda b, h, j, tab: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rows, 1), jnp.float32),
            pltpu.VMEM((rows, 1), jnp.float32),
            pltpu.VMEM((rows, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, rows, hd), q_r.dtype),
        interpret=interpret,
    )(table.astype(jnp.int32), *args)
