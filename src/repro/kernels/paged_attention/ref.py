"""Oracle: gather-free paged decode/chunk attention in pure JAX.

The parity target for the Pallas kernel in ``kernel.py`` — and the
implementation the models layer dispatches to off-TPU.  Instead of
materializing a dense ``[B, pps*ps, KV, hd]`` ring view of the page pool
(``kvcache.paged_gather``) and sweeping all of it, the softmax loop scans
the page *table*: each step indexes ``pool[table[:, e]]`` — one physical
page per slot — masks the page's ring positions against the queries, and
folds it into an online-softmax accumulator.  KV traffic per step is one
page per (slot, entry) instead of the whole ring, and nothing is ever
written back to HBM between the pool and the output.

Page-skip rule (shared with the kernel, so the two are numerically
identical even on rows whose output is garbage-and-discarded): a (slot,
entry) page contributes nothing when its table entry is garbage-routed
(unmapped entry / inactive slot) or when every (query, position) pair in
it is masked.  Live rows always keep their exact softmax — a skipped
page's keys would have carried zero probability anyway — and rows with no
valid key at all come back 0 instead of the dense path's
uniform-over-garbage junk (both are discarded by the engines).

Masking matches ``models.attention.chunk_attention`` bit for bit: ring
entry ``e`` holds positions ``lengths - ((lengths - (e*ps + i)) mod W)``
(``kvcache.ring_key_positions``), a key is visible iff ``0 <= kp <= qpos``
and, with a sliding window, ``kp > qpos - window``.

Quantized pools (``k_scale``/``v_scale`` given): the k/v leaves hold int8
codes and each gathered page is dequantized in registers — one f16 scale
per token row (``kvcache.quantize_kv_tokens``) — before the score and
value einsums, mirroring exactly the fused in-VMEM dequant of the kernel.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def paged_attention_ref(
    q: jax.Array,  # [B, C, H, hd] (C=1 for decode)
    pool_k: jax.Array,  # [P+1, ps, KV, hd] (row P = garbage page)
    pool_v: jax.Array,
    table: jax.Array,  # [B, pps] int32 physical page per ring entry
    q_positions: jax.Array,  # [B, C] int32 absolute position of each query
    lengths: jax.Array,  # [B] int32 ring anchor (position of the last write)
    *,
    window: Optional[int] = None,
    k_scale: Optional[jax.Array] = None,  # [P+1, ps] f16 per-token sidecar
    v_scale: Optional[jax.Array] = None,
) -> jax.Array:
    B, C, H, hd = q.shape
    ps, KV = pool_k.shape[1], pool_k.shape[2]
    pps = table.shape[1]
    W = pps * ps
    G = H // KV
    garbage = pool_k.shape[0] - 1
    scale = 1.0 / (hd ** 0.5)
    qr = q.reshape(B, C, KV, G, hd)
    ln = lengths[:, None].astype(jnp.int32)  # [B, 1]
    qpos = q_positions.astype(jnp.int32)

    def page_step(carry, e):
        m, l, acc = carry
        phys = table[:, e]  # [B]
        k_page = pool_k[phys]  # [B, ps, KV, hd]
        v_page = pool_v[phys]
        if k_scale is not None:
            # quantized pool: dequantize the gathered page in registers —
            # one f16 scale per token row, shared across heads and head dim
            k_page = k_page.astype(jnp.float32) * (
                k_scale[phys].astype(jnp.float32)[:, :, None, None]
            )
            v_page = v_page.astype(jnp.float32) * (
                v_scale[phys].astype(jnp.float32)[:, :, None, None]
            )
        slot = e * ps + jnp.arange(ps, dtype=jnp.int32)[None, :]  # [1, ps]
        kp = ln - jnp.mod(ln - slot, W)  # [B, ps]
        valid = kp[:, None, :] <= qpos[:, :, None]  # [B, C, ps]
        if window is not None:
            valid &= kp[:, None, :] > qpos[:, :, None] - window
        valid &= kp[:, None, :] >= 0
        live = (phys != garbage) & valid.any(axis=(1, 2))  # [B] page skip
        s = jnp.einsum(
            "bcgnd,bkgd->bcgnk", qr, k_page, preferred_element_type=jnp.float32
        ) * scale  # [B, C, KV, G, ps]
        s = jnp.where(valid[:, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        pv = jnp.einsum(
            "bcgnk,bkgd->bcgnd", p.astype(v_page.dtype), v_page,
            preferred_element_type=jnp.float32,
        )
        keep = live[:, None, None, None]
        m = jnp.where(keep, m_new, m)
        l = jnp.where(keep, l * corr + p.sum(axis=-1), l)
        acc = jnp.where(keep[..., None], acc * corr[..., None] + pv, acc)
        return (m, l, acc), None

    init = (
        jnp.full((B, C, KV, G), NEG_INF, jnp.float32),
        jnp.zeros((B, C, KV, G), jnp.float32),
        jnp.zeros((B, C, KV, G, hd), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(
        page_step, init, jnp.arange(pps, dtype=jnp.int32)
    )
    l_safe = jnp.where(l == 0.0, 1.0, l)
    out = acc / l_safe[..., None]
    return out.reshape(B, C, H, hd).astype(q.dtype)
