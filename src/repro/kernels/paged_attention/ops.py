"""Public wrapper for the fused paged-attention kernel.

Layout contract: the models keep ``[B, C, H, hd]`` queries and
``[P+1, page_size, KV, hd]`` page pools; the kernel wants GQA-grouped
query rows ``[B, KV, C*G, hd]`` (all of a KV head's queries stream against
each fetched page) and the (lengths, q_positions) ints packed into one
``[B, 1+C, 1]`` operand.  The wrapper reshapes at the boundary — XLA fuses
the transposes with the surrounding projections on TPU.

``interpret=None`` (the default) resolves per backend: compiled on TPU,
interpreted elsewhere (CPU validation) — an explicit bool forces it, so
the fused path is never silently interpreted on TPU.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import resolve_interpret
from repro.kernels.paged_attention.kernel import paged_attention_pallas


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_attention(
    q: jax.Array,  # [B, C, H, hd] (C=1 for decode)
    pool_k: jax.Array,  # [P+1, ps, KV, hd] (row P = garbage page)
    pool_v: jax.Array,
    table: jax.Array,  # [B, pps] int32
    q_positions: jax.Array,  # [B, C] int32
    lengths: jax.Array,  # [B] int32 ring anchor (last written position)
    *,
    window: Optional[int] = None,
    k_scale: Optional[jax.Array] = None,  # [P+1, ps] f16 (quantized pool)
    v_scale: Optional[jax.Array] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    interpret = resolve_interpret(interpret)
    B, C, H, hd = q.shape
    KV = pool_k.shape[2]
    G = H // KV
    q_r = (
        q.reshape(B, C, KV, G, hd)
        .transpose(0, 2, 1, 3, 4)
        .reshape(B, KV, C * G, hd)
    )
    posinfo = jnp.concatenate(
        [lengths[:, None].astype(jnp.int32), q_positions.astype(jnp.int32)],
        axis=1,
    )[..., None]
    o = paged_attention_pallas(
        q_r, pool_k, pool_v, table, posinfo,
        pool_ks=k_scale, pool_vs=v_scale,
        window=window, interpret=interpret,
    )
    return (
        o.reshape(B, KV, C, G, hd)
        .transpose(0, 2, 1, 3, 4)
        .reshape(B, C, H, hd)
    )
