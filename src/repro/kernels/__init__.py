"""Pallas TPU kernels for the EC2MoE hot spots.

Each kernel package ships three files:
  kernel.py — pl.pallas_call with explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrapper (interpret=True on CPU for validation)
  ref.py    — pure-jnp oracle the tests assert against

Kernels:
  group_gate      — fused HL-GGN two-stage gate (eq. 5-7): one VMEM pass
                    produces combined expert probabilities per token block.
  lowrank         — eq. 8 encoder/decoder: fused X->Z->X_hat roundtrip with
                    on-chip reconstruction-error partial sums.
  expert_mlp      — capacity-buffered batched expert FFN (the post-dispatch
                    compute): grid (expert, token-block, ff-tile) with fp32
                    VMEM accumulation.
  flash_attention — causal GQA flash attention forward for prefill.
"""
