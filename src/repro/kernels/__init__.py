"""Pallas TPU kernels for the EC2MoE hot spots.

Each kernel package ships three files:
  kernel.py — pl.pallas_call with explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrapper; ``interpret=None`` autodetects the
              backend (compiled on TPU, interpreted elsewhere for CPU
              validation), so no kernel is ever silently interpreted on TPU
  ref.py    — pure-jnp oracle the tests assert against

Kernels:
  group_gate      — fused HL-GGN two-stage gate (eq. 5-7): one VMEM pass
                    produces combined expert probabilities per token block.
  lowrank         — eq. 8 encoder/decoder: fused X->Z->X_hat roundtrip with
                    on-chip reconstruction-error partial sums.
  expert_mlp      — capacity-buffered batched expert FFN (the post-dispatch
                    compute): grid (expert, token-block, ff-tile) with fp32
                    VMEM accumulation.
  flash_attention — causal GQA flash attention forward for prefill.
  paged_attention — fused paged decode/chunk attention (the decode hot
                    path): scalar-prefetched page tables drive the K/V
                    index maps, so attention reads mapped KV pages straight
                    from the pool — page lookup, ring-position masking, and
                    online softmax in one pass, no dense ring gather.
  quant           — per-row int8 (and fp8-shaped, int8-storage) quantize/
                    dequantize: the composable second codec stage for KV
                    pages, expert slabs, and boundary payloads; consumers
                    (paged_attention, expert_mlp) fuse the dequant in VMEM.
"""

from typing import Optional

import jax


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """The one place backend autodetection lives: ``None`` resolves to
    compiled on TPU, interpreted elsewhere (CPU validation); an explicit
    bool passes through.  Every kernel ops wrapper routes its ``interpret``
    argument here."""
    return jax.default_backend() != "tpu" if interpret is None else interpret
