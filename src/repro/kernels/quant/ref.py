"""Pure-jnp oracle for the per-row quantize/dequantize primitives.

Two storage modes, both int8 on the wire / in HBM:

  * ``int8`` — symmetric range quantization: ``scale = maxabs / 127`` per
    row, ``q = clip(round(x / scale), -127, 127)``.  The mode the engines
    and the Pallas kernels use.
  * ``fp8``  — fp8-shaped, int8-storage: values are snapped to the
    ``float8_e4m3fn`` grid (``scale = maxabs / 448`` so the row spans the
    fp8 dynamic range) and the fp8 bit pattern is stored via an int8
    bitcast.  Same bytes as ``int8`` but a relative-precision ladder
    instead of a uniform grid — reference/ops only (no Pallas path).

The scale is computed in fp32 and *rounded to the requested storage dtype
before quantizing*, so dequantization with the stored scale is exactly the
inverse the quantizer saw — whatever sidecar dtype a consumer picks (the
KV pool and the boundary codec store ``float16`` sidecars; the kernel
family default is ``float32``).

Error contract (property-tested): for ``scale_dtype=float32`` the
per-element int8 error is at most ``scale / 2`` (round-to-nearest), rows
of zeros roundtrip to exact zeros, and scaling a row by ``c > 0`` scales
its quantization scale by exactly ``c`` modulo fp32 rounding.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

FP8_MAX = 448.0  # float8_e4m3fn finite max
SCALE_FLOOR = 1e-8  # all-zero rows: keep the divide finite, q stays 0


def quantize_rows_ref(
    x: jax.Array,  # [..., n]
    *,
    mode: str = "int8",
    scale_dtype=jnp.float32,
):
    """Per-row quantization over the last axis.

    Returns ``(q int8 [..., n], scale scale_dtype [..., 1])`` such that
    ``dequantize_rows_ref(q, scale, mode=mode)`` reconstructs ``x`` within
    the mode's grid error.
    """
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    div = 127.0 if mode == "int8" else FP8_MAX
    scale = jnp.maximum(amax / div, SCALE_FLOOR).astype(scale_dtype)
    s = scale.astype(jnp.float32)
    if mode == "int8":
        q = jnp.clip(jnp.round(xf / s), -127, 127).astype(jnp.int8)
    elif mode == "fp8":
        f8 = (xf / s).astype(jnp.float8_e4m3fn)
        q = jax.lax.bitcast_convert_type(f8, jnp.int8)
    else:
        raise ValueError(f"unknown quant mode {mode!r}")
    return q, scale


def dequantize_rows_ref(
    q: jax.Array,  # [..., n] int8
    scale: jax.Array,  # [..., 1]
    *,
    mode: str = "int8",
    dtype=jnp.bfloat16,
) -> jax.Array:
    if mode == "int8":
        xf = q.astype(jnp.float32)
    elif mode == "fp8":
        xf = jax.lax.bitcast_convert_type(q, jnp.float8_e4m3fn).astype(
            jnp.float32
        )
    else:
        raise ValueError(f"unknown quant mode {mode!r}")
    return (xf * scale.astype(jnp.float32)).astype(dtype)
