"""Per-row int8 quantize/dequantize Pallas kernels.

Both kernels block over rows only (the full row stays in VMEM — the
reduction axis of the scale is the minor axis, so one block sees one
row's maxabs whole).  Grid: ``(rows / block_rows,)``.

The quantizer emits the int8 codes *and* the fp32 per-row scale in one
pass; the dequantizer is the fused-consumer building block (multiply the
int8 tile by its broadcast scale in VMEM) packaged standalone so parity
tests can pin the exact dequant arithmetic the paged-attention and
expert-MLP kernels inline.

fp8 mode has no kernel: its bitcast snapping is a storage trick, not a
compute shape worth a Pallas body — ``ops.py`` routes it to the ref.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SCALE_FLOOR = 1e-8


def _quantize_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)  # [br, n]
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    s = jnp.maximum(amax / 127.0, SCALE_FLOOR)
    q_ref[...] = jnp.clip(jnp.round(x / s), -127, 127).astype(jnp.int8)
    s_ref[...] = s.astype(s_ref.dtype)


def _dequantize_kernel(q_ref, s_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)  # [br, n]
    s = s_ref[...].astype(jnp.float32)  # [br, 1]
    o_ref[...] = (q * s).astype(o_ref.dtype)


def _row_block(rows: int, block_rows: int) -> int:
    br = min(block_rows, rows)
    while rows % br:
        br -= 1
    return max(br, 1)


def quantize_rows_pallas(
    x: jax.Array,  # [rows, n]
    *,
    block_rows: int = 256,
    interpret: bool = False,
):
    rows, n = x.shape
    br = _row_block(rows, block_rows)
    grid = (rows // br,)
    return pl.pallas_call(
        _quantize_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((br, n), lambda r: (r, 0))],
        out_specs=[
            pl.BlockSpec((br, n), lambda r: (r, 0)),
            pl.BlockSpec((br, 1), lambda r: (r, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, n), jnp.int8),
            jax.ShapeDtypeStruct((rows, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x)


def dequantize_rows_pallas(
    q: jax.Array,  # [rows, n] int8
    scale: jax.Array,  # [rows, 1]
    *,
    dtype=jnp.bfloat16,
    block_rows: int = 256,
    interpret: bool = False,
):
    rows, n = q.shape
    br = _row_block(rows, block_rows)
    grid = (rows // br,)
    return pl.pallas_call(
        _dequantize_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, n), lambda r: (r, 0)),
            pl.BlockSpec((br, 1), lambda r: (r, 0)),
        ],
        out_specs=pl.BlockSpec((br, n), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, n), dtype),
        interpret=interpret,
    )(q, scale)
