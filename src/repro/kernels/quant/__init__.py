from repro.kernels.quant.ops import dequantize_rows, quantize_rows
from repro.kernels.quant.ref import dequantize_rows_ref, quantize_rows_ref

__all__ = [
    "quantize_rows",
    "dequantize_rows",
    "quantize_rows_ref",
    "dequantize_rows_ref",
]
