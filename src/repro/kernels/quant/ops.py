"""Public wrappers for the per-row quantize/dequantize primitives.

``interpret=None`` (the default) resolves per backend: compiled on TPU,
interpreted elsewhere (CPU validation) — an explicit bool forces it.
``int8`` runs the Pallas kernel; ``fp8`` (fp8-shaped, int8-storage) is a
bitcast trick with no kernel body and routes to the jnp ref.

Inputs of any rank are accepted; rows are all leading axes flattened, the
scale comes back ``[..., 1]``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import resolve_interpret
from repro.kernels.quant.kernel import (
    dequantize_rows_pallas,
    quantize_rows_pallas,
)
from repro.kernels.quant.ref import dequantize_rows_ref, quantize_rows_ref


@functools.partial(jax.jit, static_argnames=("mode", "interpret"))
def quantize_rows(
    x: jax.Array,  # [..., n]
    *,
    mode: str = "int8",
    interpret: Optional[bool] = None,
):
    """Per-row quantization: ``(q int8 [..., n], scale fp32 [..., 1])``."""
    interpret = resolve_interpret(interpret)
    shape = x.shape
    if mode != "int8":
        return quantize_rows_ref(x, mode=mode)
    q, s = quantize_rows_pallas(
        x.reshape(-1, shape[-1]), interpret=interpret
    )
    return q.reshape(shape), s.reshape(shape[:-1] + (1,))


@functools.partial(jax.jit, static_argnames=("mode", "dtype", "interpret"))
def dequantize_rows(
    q: jax.Array,  # [..., n] int8
    scale: jax.Array,  # [..., 1]
    *,
    mode: str = "int8",
    dtype=jnp.bfloat16,
    interpret: Optional[bool] = None,
) -> jax.Array:
    interpret = resolve_interpret(interpret)
    shape = q.shape
    if mode != "int8":
        return dequantize_rows_ref(q, scale, mode=mode, dtype=dtype)
    out = dequantize_rows_pallas(
        q.reshape(-1, shape[-1]),
        scale.astype(jnp.float32).reshape(-1, 1),
        dtype=dtype,
        interpret=interpret,
    )
    return out.reshape(shape)
