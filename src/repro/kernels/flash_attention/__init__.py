from repro.kernels.flash_attention.ops import flash_attention_fwd

__all__ = ["flash_attention_fwd"]
