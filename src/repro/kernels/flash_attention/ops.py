"""Public wrapper for the flash-attention forward kernel.

Layout contract: models use [B, S, H, hd]; the kernel wants [B, H, S, hd]
(head-major so each (b, h) streams contiguous sequence blocks).  The
wrapper transposes at the boundary — XLA fuses these with the surrounding
projections on TPU.

``interpret=None`` (the default) resolves per backend: compiled on TPU,
interpreted elsewhere (CPU validation) — an explicit bool forces it, so
the kernel is never silently interpreted on TPU.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import resolve_interpret
from repro.kernels.flash_attention.kernel import flash_attention_pallas


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "block_q", "block_kv", "interpret")
)
def flash_attention_fwd(
    q: jax.Array,  # [B, Sq, H, hd]
    k: jax.Array,  # [B, Skv, KV, hd]
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = 512,
    block_kv: int = 512,
    interpret: Optional[bool] = None,
) -> jax.Array:
    interpret = resolve_interpret(interpret)
    qt = jnp.transpose(q, (0, 2, 1, 3))
    kt = jnp.transpose(k, (0, 2, 1, 3))
    vt = jnp.transpose(v, (0, 2, 1, 3))
    o = flash_attention_pallas(
        qt, kt, vt,
        causal=causal, window=window,
        block_q=block_q, block_kv=block_kv, interpret=interpret,
    )
    return jnp.transpose(o, (0, 2, 1, 3))
