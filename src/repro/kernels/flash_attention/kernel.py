"""Causal GQA flash-attention forward Pallas kernel (prefill hot path).

Grid: (batch, q-head, q-blocks, kv-blocks) with kv minor-most.  The online
softmax state (m, l) and the output accumulator live in VMEM scratch and
persist across the kv sweep for a fixed (b, h, i); the output block is
written once when the sweep finishes.  Causal masking is block-aware: fully
masked kv blocks (j > i) are skipped with pl.when so they cost neither MXU
flops nor VMEM traffic — this is the "causal block skipping" the pure-XLA
scan path cannot express (see EXPERIMENTS.md §Perf).

GQA is handled in the index maps: kv blocks are fetched from head h // G,
so no repeated-KV materialization happens in HBM.

VMEM per step (bq=bk=512, hd=128, bf16 in / fp32 acc):
  q 512x128x2 = 128 KiB, k/v 2x512x128x2 = 256 KiB,
  s 512x512x4 = 1 MiB, acc 512x128x4 = 256 KiB — comfortably resident.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _fa_kernel(
    q_ref,  # [1, 1, bq, hd]
    k_ref,  # [1, 1, bk, hd]
    v_ref,  # [1, 1, bk, hd]
    o_ref,  # [1, 1, bq, hd]
    m_scr,  # [bq, 1] fp32
    l_scr,  # [bq, 1] fp32
    acc_scr,  # [bq, hd] fp32
    *,
    scale: float,
    bq: int,
    bk: int,
    nk: int,
    causal: bool,
    window,
):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Block-level causal/window skip: only touch kv blocks that intersect
    # the visible band for this q block.
    live = jnp.bool_(True)
    if causal:
        live = j <= i
    if window is not None:
        # lowest visible key for this q block = i*bq - window + 1
        live = jnp.logical_and(live, (j + 1) * bk - 1 >= i * bq - window + 1)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # [bq, hd]
        k = k_ref[0, 0].astype(jnp.float32)  # [bk, hd]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # [bq, bk]
        qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.bool_(True)
        if causal:
            mask = qpos >= kpos
        if window is not None:
            mask = jnp.logical_and(mask, qpos - kpos < window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]  # [bq, 1]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new

    @pl.when(j == nk - 1)
    def _flush():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_pallas(
    q,  # [B, H, Sq, hd]
    k,  # [B, KV, Skv, hd]
    v,
    *,
    causal=True,
    window=None,
    block_q=512,
    block_kv=512,
    interpret=False,
):
    B, H, Sq, hd = q.shape
    KV, Skv = k.shape[1], k.shape[2]
    G = H // KV
    bq = min(block_q, Sq)
    bk = min(block_kv, Skv)
    assert Sq % bq == 0 and Skv % bk == 0
    nq, nk = Sq // bq, Skv // bk
    scale = 1.0 / (hd ** 0.5)

    kernel = functools.partial(
        _fa_kernel, scale=scale, bq=bq, bk=bk, nk=nk, causal=causal, window=window
    )
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            _vmem_scratch((bq, 1)),
            _vmem_scratch((bq, 1)),
            _vmem_scratch((bq, hd)),
        ],
        interpret=interpret,
    )(q, k, v)


def _vmem_scratch(shape):
    try:
        from jax.experimental.pallas import tpu as pltpu

        return pltpu.VMEM(shape, jnp.float32)
    except Exception:  # pragma: no cover - CPU interpret fallback
        return pl.VMEM(shape, jnp.float32)
