"""Oracle: the O(S^2)-memory reference attention (shared with models)."""

from repro.models.attention import reference_attention  # noqa: F401
