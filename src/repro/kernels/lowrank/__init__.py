from repro.kernels.lowrank.ops import lowrank_encode, lowrank_decode, lowrank_roundtrip

__all__ = ["lowrank_encode", "lowrank_decode", "lowrank_roundtrip"]
