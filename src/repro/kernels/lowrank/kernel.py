"""Low-rank codec Pallas kernels (eq. 8).

``roundtrip``: fused X -> Z = X E -> X_hat = Z D plus the on-chip partial
sum of ||X - X_hat||^2, all in one VMEM pass per token block.  The unfused
XLA path writes Z and X_hat to HBM and reads X twice (3x d + 2x r words of
HBM traffic per token); the fused kernel streams X once and writes X_hat
once (2x d words) — the reconstruction term of the joint loss comes for
free, which matters because eq. 8 is evaluated on *every* compressed
boundary tensor during joint training.

``encode`` / ``decode``: plain blocked projections used on the dispatch /
pipeline boundaries at serving time.

VMEM per step (fp32): bt x d (x) + d x r (E) + r x d (D) + bt x d (out).
For d=8192, r=128, bt=128: 4 + 4 + 4 + 4 MiB = fits.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dot(a, b):
    return jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


def _encode_kernel(x_ref, enc_ref, z_ref):
    z_ref[...] = _dot(
        x_ref[...].astype(jnp.float32), enc_ref[...].astype(jnp.float32)
    ).astype(z_ref.dtype)


def _decode_kernel(z_ref, dec_ref, x_ref):
    x_ref[...] = _dot(
        z_ref[...].astype(jnp.float32), dec_ref[...].astype(jnp.float32)
    ).astype(x_ref.dtype)


def _roundtrip_kernel(x_ref, enc_ref, dec_ref, xhat_ref, err_ref):
    x = x_ref[...].astype(jnp.float32)
    z = _dot(x, enc_ref[...].astype(jnp.float32))
    x_hat = _dot(z, dec_ref[...].astype(jnp.float32))
    xhat_ref[...] = x_hat.astype(xhat_ref.dtype)
    d = x - x_hat
    err_ref[0, 0] = jnp.sum(d * d)


def encode_pallas(x, enc, *, block_tokens=256, interpret=False):
    T, d = x.shape
    r = enc.shape[1]
    bt = min(block_tokens, T)
    assert T % bt == 0
    return pl.pallas_call(
        _encode_kernel,
        grid=(T // bt,),
        in_specs=[
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
            pl.BlockSpec((d, r), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bt, r), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((T, r), x.dtype),
        interpret=interpret,
    )(x, enc)


def decode_pallas(z, dec, *, block_tokens=256, interpret=False):
    T, r = z.shape
    d = dec.shape[1]
    bt = min(block_tokens, T)
    assert T % bt == 0
    return pl.pallas_call(
        _decode_kernel,
        grid=(T // bt,),
        in_specs=[
            pl.BlockSpec((bt, r), lambda i: (i, 0)),
            pl.BlockSpec((r, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bt, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((T, d), z.dtype),
        interpret=interpret,
    )(z, dec)


def roundtrip_pallas(x, enc, dec, *, block_tokens=128, interpret=False):
    T, d = x.shape
    r = enc.shape[1]
    bt = min(block_tokens, T)
    assert T % bt == 0
    nb = T // bt
    x_hat, err = pl.pallas_call(
        _roundtrip_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
            pl.BlockSpec((d, r), lambda i: (0, 0)),
            pl.BlockSpec((r, d), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, d), x.dtype),
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x, enc, dec)
    return x_hat, err.sum()
