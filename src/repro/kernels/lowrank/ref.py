"""Pure-jnp oracle for the low-rank codec kernels (paper eq. 8, 1-D form)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def encode_ref(x: jax.Array, enc: jax.Array) -> jax.Array:
    return (x.astype(jnp.float32) @ enc.astype(jnp.float32)).astype(x.dtype)


def decode_ref(z: jax.Array, dec: jax.Array) -> jax.Array:
    return (z.astype(jnp.float32) @ dec.astype(jnp.float32)).astype(z.dtype)


def roundtrip_ref(x: jax.Array, enc: jax.Array, dec: jax.Array):
    """Returns (x_hat, sum of squared reconstruction error)."""
    z = x.astype(jnp.float32) @ enc.astype(jnp.float32)
    x_hat = z @ dec.astype(jnp.float32)
    err = jnp.sum(jnp.square(x.astype(jnp.float32) - x_hat))
    return x_hat.astype(x.dtype), err
