"""Public wrappers for the low-rank codec kernels."""

from __future__ import annotations

import functools

import jax

from repro.kernels.lowrank import kernel as K


@functools.partial(jax.jit, static_argnames=("interpret",))
def lowrank_encode(x, enc, *, interpret: bool = True):
    return K.encode_pallas(x, enc, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def lowrank_decode(z, dec, *, interpret: bool = True):
    return K.decode_pallas(z, dec, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def lowrank_roundtrip(x, enc, dec, *, interpret: bool = True):
    """Fused eq. 8 path: returns (x_hat, sum-squared reconstruction error)."""
    return K.roundtrip_pallas(x, enc, dec, interpret=interpret)
