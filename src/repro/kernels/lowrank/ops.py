"""Public wrappers for the low-rank codec kernels.

``interpret=None`` (the default) resolves per backend: compiled on TPU,
interpreted elsewhere (CPU validation) — an explicit bool forces it, so
the kernels are never silently interpreted on TPU.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels import resolve_interpret
from repro.kernels.lowrank import kernel as K


@functools.partial(jax.jit, static_argnames=("interpret",))
def lowrank_encode(x, enc, *, interpret: Optional[bool] = None):
    return K.encode_pallas(x, enc, interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("interpret",))
def lowrank_decode(z, dec, *, interpret: Optional[bool] = None):
    return K.decode_pallas(z, dec, interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("interpret",))
def lowrank_roundtrip(x, enc, dec, *, interpret: Optional[bool] = None):
    """Fused eq. 8 path: returns (x_hat, sum-squared reconstruction error)."""
    return K.roundtrip_pallas(x, enc, dec, interpret=resolve_interpret(interpret))
