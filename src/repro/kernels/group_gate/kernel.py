"""Fused HL-GGN group-gate Pallas kernel.

One grid step processes a block of tokens entirely in VMEM: both gate
matmuls (local E-way, global K-way), the two softmaxes and their product
(eq. 5-7) are fused so the [T, E] logits never round-trip through HBM —
the flat-gate baseline materializes them twice (logits + softmax).

VMEM budget per step (fp32): x block bt x d  +  w_local d x E  +  w_global
d x K  +  probs bt x E.  For qwen3-moe (d=4096, E=128, K=16) at bt=256:
4 MiB + 2 MiB + 0.25 MiB + 0.13 MiB ~ 6.4 MiB — fits v5e's 16 MiB VMEM
with headroom; block sizes are picked by ops.py accordingly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _gate_kernel(
    x_ref,  # [bt, d]
    wl_ref,  # [d, E]
    bl_ref,  # [1, E]
    wg_ref,  # [d, K]
    bg_ref,  # [1, K]
    mask_ref,  # [1, E] additive
    probs_ref,  # out [bt, E]
    pgroup_ref,  # out [bt, K]
    *,
    num_groups: int,
):
    x = x_ref[...].astype(jnp.float32)
    wl = wl_ref[...].astype(jnp.float32)
    wg = wg_ref[...].astype(jnp.float32)
    bl = bl_ref[...].astype(jnp.float32)
    bg = bg_ref[...].astype(jnp.float32)
    mask = mask_ref[...].astype(jnp.float32)

    bt = x.shape[0]
    E = wl.shape[1]
    K = num_groups
    Mk = E // K

    # Stage 2 logits (eq. 5): one MXU matmul for all K group gates at once.
    local = jax.lax.dot_general(
        x, wl, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ) + bl + mask  # [bt, E]
    lg = local.reshape(bt, K, Mk)
    lmax = jnp.max(lg, axis=-1, keepdims=True)
    lexp = jnp.exp(lg - lmax)
    p_local = lexp / jnp.sum(lexp, axis=-1, keepdims=True)

    # Stage 1 logits (eq. 6); fully-masked groups get zero probability.
    glob = jax.lax.dot_general(
        x, wg, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ) + bg  # [bt, K]
    group_dead = jnp.all(mask.reshape(K, Mk) <= NEG_INF / 2, axis=-1)  # [K]
    glob = jnp.where(group_dead[None, :], NEG_INF, glob)
    gmax = jnp.max(glob, axis=-1, keepdims=True)
    gexp = jnp.exp(glob - gmax)
    p_group = gexp / jnp.sum(gexp, axis=-1, keepdims=True)

    # Fusion (eq. 7).
    probs = (p_group[:, :, None] * p_local).reshape(bt, E)
    probs_ref[...] = probs.astype(probs_ref.dtype)
    pgroup_ref[...] = p_group.astype(pgroup_ref.dtype)


def group_gate_pallas(
    x, w_local, b_local, w_global, b_global, mask, *,
    num_groups: int, block_tokens: int = 256, interpret: bool = False,
):
    T, d = x.shape
    E = w_local.shape[1]
    K = num_groups
    bt = min(block_tokens, T)
    assert T % bt == 0, (T, bt)
    grid = (T // bt,)
    kernel = functools.partial(_gate_kernel, num_groups=num_groups)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
            pl.BlockSpec((d, E), lambda i: (0, 0)),
            pl.BlockSpec((1, E), lambda i: (0, 0)),
            pl.BlockSpec((d, K), lambda i: (0, 0)),
            pl.BlockSpec((1, K), lambda i: (0, 0)),
            pl.BlockSpec((1, E), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bt, E), lambda i: (i, 0)),
            pl.BlockSpec((bt, K), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, E), jnp.float32),
            jax.ShapeDtypeStruct((T, K), jnp.float32),
        ],
        interpret=interpret,
    )(x, w_local, b_local[None, :], w_global, b_global[None, :], mask[None, :])
