"""Pure-jnp oracle for the fused HL-GGN group gate (paper eq. 5-7)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def group_gate_ref(
    x: jax.Array,  # [T, d]
    w_local: jax.Array,  # [d, E] column-grouped: expert e = group e//Mk
    b_local: jax.Array,  # [E]
    w_global: jax.Array,  # [d, K]
    b_global: jax.Array,  # [K]
    mask: jax.Array,  # [E] additive fp32 (0 = allowed, -inf = excluded)
    num_groups: int,
):
    T, d = x.shape
    E = w_local.shape[1]
    K = num_groups
    Mk = E // K
    xf = x.astype(jnp.float32)
    local = xf @ w_local.astype(jnp.float32) + b_local.astype(jnp.float32)
    local = local + mask.astype(jnp.float32)
    p_local = jax.nn.softmax(local.reshape(T, K, Mk), axis=-1)  # eq. 5
    glob = xf @ w_global.astype(jnp.float32) + b_global.astype(jnp.float32)
    group_dead = (mask.reshape(K, Mk) <= NEG_INF / 2).all(-1)
    glob = jnp.where(group_dead[None], NEG_INF, glob)
    p_group = jax.nn.softmax(glob, axis=-1)  # eq. 6
    probs = (p_group[:, :, None] * p_local).reshape(T, E)  # eq. 7
    return probs, p_group
