"""Public wrapper for the fused group-gate kernel.

Accepts the same parameter pytree as ``repro.core.gating`` ({"w_local":
[K, d, Mk], "b_local": [K, Mk], "w_global": [d, K], "b_global": [K]}),
re-lays-out the local gates into one column-grouped [d, E] matrix (done
once under jit; XLA folds it), and dispatches to the Pallas kernel —
interpreted on CPU, compiled on TPU (``interpret=None`` autodetects from
the backend, so the fused gate is never silently interpreted on TPU).
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import resolve_interpret
from repro.kernels.group_gate.kernel import group_gate_pallas

NEG_INF = -1e30


def _pick_block(T: int, d: int, E: int) -> int:
    # keep x-block + weights + outputs under ~8 MiB fp32
    budget = 8 * 2**20 / 4 - d * (E + 16)
    bt = max(8, int(budget // max(d + E, 1)))
    bt = 1 << (bt.bit_length() - 1)  # floor pow2
    bt = min(bt, 512)
    while T % bt:
        bt //= 2
    return max(bt, 1)


@functools.partial(jax.jit, static_argnames=("num_groups", "interpret"))
def group_gate_probs(
    params: Dict,
    x: jax.Array,  # [T, d]
    *,
    num_groups: int,
    expert_mask: Optional[jax.Array] = None,  # bool [E]
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Fused eq. 5-7.  Returns (probs [T, E], p_group [T, K]).

    ``interpret=None`` (the default) resolves per backend: compiled on TPU,
    interpreted elsewhere (CPU validation) — an explicit bool forces it."""
    interpret = resolve_interpret(interpret)
    wl = params["w_local"]  # [K, d, Mk]
    K, d, Mk = wl.shape
    E = K * Mk
    w_local = jnp.transpose(wl, (1, 0, 2)).reshape(d, E)
    b_local = params["b_local"].reshape(E)
    mask = (
        jnp.where(expert_mask, 0.0, NEG_INF).astype(jnp.float32)
        if expert_mask is not None
        else jnp.zeros((E,), jnp.float32)
    )
    bt = _pick_block(x.shape[0], d, E)
    probs, p_group = group_gate_pallas(
        x, w_local, b_local, params["w_global"], params["b_global"], mask,
        num_groups=num_groups, block_tokens=bt, interpret=interpret,
    )
    return probs, p_group
