from repro.kernels.group_gate.ops import group_gate_probs

__all__ = ["group_gate_probs"]
