"""Heterogeneous multi-end fleet serving engine (the paper's scalability
setting: many end devices sharing one cloud tier).

``FleetServingEngine`` runs N heterogeneous end devices against one shared
cloud.  Each device is a ``FleetLane`` — the streaming end-cloud engine
(``serving.stream.EndCloudServingEngine``) with

  * its own hardware-aware expert mask carrying the fleet semantics of
    ``selection.shard_masks_for_fleet`` (eq. 2-4 plus the never-empty
    guarantee: a device too weak to host any expert still exposes one);
  * its own route-aware ``PipelinePlan`` computed against the device's
    *share* of the cloud tier (``core.pipeline.fleet_cloud_share``:
    ``cloud_servers / n_devices``), so a weak or badly-connected device
    plans a more cloud-heavy split than a strong one;
  * its own ``BandwidthEstimator`` + ``LinkStats`` — per-device links drift
    independently, and a drift replans *only that device* at its own
    drained safe point (``EndCloudServingEngine._apply_pending_replan``).

The cloud tier is one shared resource: every lane's boundary activations
drain into the same multi-server ``"cloud"`` entry of one fleet-wide
``StageTimeline`` (capacity = ``cloud_servers``), so the modeled schedule
charges cloud contention across devices exactly like ``sim.simulator``'s
FCFS multi-server queue — the fleet's aggregate decode batch is whatever
set of boundaries is in flight at a tick.  Cloud KV *memory* is shared the
same way: all lanes draw pages from one cloud-side
:class:`~repro.models.kvcache.PagePool` (each lane registers its slot
block), so admission anywhere in the fleet is gated on fleet-wide cloud
page availability, while each lane keeps a private end-tier pool.

**Request placement** is route-aware (eq. 9/11 via
``core.pipeline.place_fleet``): waiting requests are taken in a stable
(SLO priority class, arrival) order — not the eq. 10 compute/comm ratio,
which reorders equal-priority requests by size — and each goes to the
device minimizing the eq. 9 marginal cost over per-device *measured*
bandwidth and in-flight load, subject to free-slot capacity.  Placement is
late-binding — requests wait at the fleet frontend, not on a device queue,
so a mid-run bandwidth cut steers subsequent requests away from the
straggler while its in-flight work replans.  Lanes inherit the fleet's
``admission`` policy and ``preemption`` flag (see ``serving.stream``); the
fleet-global submission seq keeps cross-lane arrival order meaningful.
"""

from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional, Sequence

import jax.numpy as jnp

import jax

from repro.core import expertpool
from repro.core.hardware import DeviceProfile, DeviceState
from repro.core.pipeline import SchedulerConfig, Task, place_fleet
from repro.core.selection import fleet_device_mask
from repro.distributed.sharding import fleet_expert_shards
from repro.models import kvcache
from repro.models.kvcache import PagePool
from repro.models.model import Model
from repro.serving.common import Request, StageTimeline
from repro.serving.faults import HealthMonitor, StallGuard
from repro.serving.stream import EndCloudServingEngine, _SpillState

__all__ = ["FleetLane", "FleetServingEngine"]


class FleetLane(EndCloudServingEngine):
    """One end device's streaming engine inside a fleet.  Identical stage
    machinery; only the expert-mask derivation differs — it goes through
    ``selection.fleet_device_mask`` so replan-time state updates keep the
    fleet's never-empty guarantee (matching ``shard_masks_for_fleet``)."""

    def _derive_end_mask(self, end_state: DeviceState):
        cfg = self.cfg
        if cfg.moe is None:
            return None
        mask = fleet_device_mask(
            self.end_profile,
            end_state,
            cfg.d_model,
            cfg.moe.d_ff_expert,
            cfg.moe.num_experts,
            cfg.moe.num_groups,
            gated=cfg.ffn_gated,
            eps=self.selection_eps,
            selection_cap=cfg.moe.local_selection_cap,
            group_priority=self._group_priority(),
        )
        return jnp.asarray(mask)


class FleetServingEngine:
    """N heterogeneous end devices + one shared cloud tier."""

    def __init__(
        self,
        model: Model,
        params: Dict,
        *,
        end_profiles: Sequence[DeviceProfile],
        cloud_profile: DeviceProfile,
        end_states: Optional[Sequence[DeviceState]] = None,
        cloud_servers: int = 1,
        codec_params: Optional[Dict] = None,
        compression_rank: int = 0,
        alpha: float = 0.5,
        selection_eps: float = 1.0,
        max_batch: int = 4,  # decode slots per end device
        max_len: int = 512,
        n_groups: int = 2,
        force_splits: Optional[Sequence[Optional[int]]] = None,
        replan_threshold: float = 0.15,
        scheduler: Optional[SchedulerConfig] = None,
        max_spill: float = 1.5,
        clock: Optional[Callable[[], float]] = None,
        timing: str = "measured",
        page_size: int = 16,
        kv_pages: Optional[int] = None,  # per-lane end-pool capacity
        cloud_kv_pages: Optional[int] = None,  # fleet-shared cloud capacity
        prefill_chunk: int = 16,
        expert_pool: Optional[bool] = None,  # per-lane paged expert weights
        expert_slabs: Optional[int] = None,
        expert_resident_slots: Optional[int] = None,
        expert_mem_frac: float = 0.5,
        expert_prefetch_per_tick: int = 2,
        expert_fleet: bool = True,  # fleet-wide expert registry (vs isolated)
        expert_peer_gbps: Optional[float] = None,  # modeled end<->end LAN rate
        expert_dedup_min_freq: Optional[float] = None,  # default 1/E
        admission: str = "priority",  # "priority" | "fifo" (frontend + lanes)
        preemption: bool = True,  # lanes spill low-priority slots under load
        quantize_kv: bool = False,  # int8 KV pages on every lane
        quantize_experts: bool = False,  # int8 slab stores + quantized wire
        quantize_boundary: bool = False,  # int8 boundary payloads
        spec_k: int = 1,  # speculative draft-length budget per lane (1 = off)
        link_rtt_s: float = 0.0,  # per-transfer round trip on every lane link
    ):
        n = len(end_profiles)
        if n < 1:
            raise ValueError("fleet needs at least one end device")
        states = list(end_states) if end_states is not None else [
            DeviceState() for _ in range(n)
        ]
        if len(states) != n:
            raise ValueError(f"{len(states)} states for {n} profiles")
        if admission not in ("priority", "fifo"):
            raise ValueError(f"admission={admission!r}")
        self.model = model
        self.cfg = model.cfg
        self.n_devices = n
        self.cloud_servers = cloud_servers
        self.clock = clock or time.monotonic
        self.scheduler = scheduler or SchedulerConfig(alpha=alpha)
        self.max_spill = max_spill
        self.admission = admission
        self.waiting: List[Request] = []  # fleet frontend queue (pre-placement)
        self.placed: List[Dict] = []  # placement log: request -> device
        self._submit_seq = 0
        # fault machinery: one health monitor shared by every lane, chaos
        # injector bound by ChaosInjector.bind, per-lane liveness, and the
        # migration park — spill states evacuated off dead lanes waiting to
        # be handed to whichever surviving lane the request lands on
        self.health = HealthMonitor()
        self.chaos = None  # ChaosInjector, when bound
        self.stall_limit = 256
        self.lane_alive: List[bool] = [True] * n
        self._migrating: Dict[str, _SpillState] = {}
        self.lane_failures = 0
        self.lane_recoveries = 0
        self.migrations = 0
        self.migration_spill_bytes = 0
        self.cloud_server_failures = 0

        # One fleet-wide occupancy clock: per-device end/link resources, one
        # shared multi-server cloud resource every lane's boundaries drain to.
        self.timeline = StageTimeline(
            resources=["cloud"], capacity={"cloud": cloud_servers}
        )
        # One fleet-wide cloud page pool: lanes register their slot blocks
        # via PagePool.add_slots, so cloud KV admission is fleet-global.
        # NOTE an in-process artifact: each lane allocates cloud *storage*
        # sized to this shared capacity (indices are fleet-global), so host
        # memory duplicates what a real deployment's single cloud-side
        # storage would hold once; the shared accounting — what admission
        # gates on — is faithful.  Cap it with ``cloud_kv_pages``.
        pps, _ring = kvcache.page_geometry(
            model.cfg, max_len, page_size, chunk_headroom=prefill_chunk
        )
        padded = EndCloudServingEngine.padded_batch(max_batch, n_groups)
        self.cloud_pool = PagePool(
            cloud_kv_pages or n * padded * pps, page_size, pps, n_slots=0
        )
        # Fleet expert store: one location-aware registry owns residency
        # planning across every lane's slab pool — de-duplicated placement,
        # peer-vs-cloud slab sourcing over the modeled end<->end link, and
        # the placement-cost feed `_place` hands to place_fleet.  Lanes
        # register in device order, so registry lane ids == device ids.
        # ``expert_fleet=False`` keeps PR 5's isolated per-lane pools (the
        # dedup/peer ablation baseline).
        pooled = bool(
            (expert_pool if expert_pool is not None else True)
            and model.cfg.moe is not None
            and any(spec.moe for spec in model.cfg.layer_pattern)
        )
        self.expert_registry: Optional[expertpool.FleetExpertRegistry] = None
        if expert_fleet and pooled:
            n_moe = sum(1 for spec in model.cfg.layer_pattern if spec.moe)
            # the registry prices peer/cloud wire costs at the *stored* slab
            # size: a quantized fleet ships int8 slabs, so fetch-vs-dedup
            # decisions and placement surcharges see the cheaper wire
            self.expert_registry = expertpool.FleetExpertRegistry(
                n_moe * model.cfg.block_repeat,
                model.cfg.moe.num_experts,
                expertpool.expert_slab_bytes(
                    model.cfg, quantized=quantize_experts
                ),
                lan_gbps=expert_peer_gbps,
                dedup_min_freq=expert_dedup_min_freq,
            )
        self.lanes: List[FleetLane] = []
        for i in range(n):
            self.lanes.append(
                FleetLane(
                    model,
                    params,
                    end_profile=end_profiles[i],
                    cloud_profile=cloud_profile,
                    end_state=states[i],
                    codec_params=codec_params,
                    compression_rank=compression_rank,
                    alpha=alpha,
                    selection_eps=selection_eps,
                    max_batch=max_batch,
                    max_len=max_len,
                    n_groups=n_groups,
                    force_split=(
                        force_splits[i] if force_splits is not None else None
                    ),
                    replan_threshold=replan_threshold,
                    clock=self.clock,
                    timeline=self.timeline,
                    resources=(f"end{i}", f"link{i}", "cloud"),
                    cloud_share=cloud_servers / n,
                    timing=timing,
                    page_size=page_size,
                    kv_pages=kv_pages,
                    prefill_chunk=prefill_chunk,
                    cloud_pool=self.cloud_pool,
                    expert_pool=expert_pool,
                    expert_slabs=expert_slabs,
                    expert_resident_slots=expert_resident_slots,
                    expert_mem_frac=expert_mem_frac,
                    expert_prefetch_per_tick=expert_prefetch_per_tick,
                    expert_registry=self.expert_registry,
                    admission=admission,
                    preemption=preemption,
                    health=self.health,
                    quantize_kv=quantize_kv,
                    quantize_experts=quantize_experts,
                    quantize_boundary=quantize_boundary,
                    spec_k=spec_k,
                    link_rtt_s=link_rtt_s,
                )
            )

    # -- request lifecycle ----------------------------------------------------

    def submit(self, req: Request):
        self.lanes[0].validate(req)  # all lanes share max_len
        req.submit_time = self.clock()
        req.seq = self._submit_seq  # fleet-global: lanes never re-stamp
        self._submit_seq += 1
        self.waiting.append(req)

    def _request_gflops(self, req: Request) -> float:
        """C(t): total forward GFLOPs this request will cost a device that
        keeps everything local (prefill + decode; the placement cost model's
        compute-complexity term)."""
        tokens = len(req.prompt) + req.max_new_tokens
        return 2.0 * self.cfg.active_param_count() * tokens * 1e-9

    def _lane_load(self, lane: FleetLane) -> float:
        """In-flight GFLOPs on a device: queued plus slotted requests."""
        live = list(lane.waiting) + [r for r in lane.slots if r is not None]
        return sum(self._request_gflops(r) for r in live)

    def _place(self):
        """Route-aware placement of frontend requests onto devices with free
        admission capacity: the eq. 9 marginal-cost device choice over
        measured per-device bandwidth and load, taking requests in a stable
        (priority class, arrival seq) order — NOT the eq. 10 compute/comm
        ranking, which reorders equal-priority requests by size and breaks
        FIFO fairness within a class (``admission="fifo"`` drops the class
        key and places in pure arrival order).  Dispatch preserves that
        order within each lane so a single-device fleet admits exactly like
        a standalone engine."""
        if not self.waiting:
            return
        # Under priority admission a full lane still has *preemptible*
        # capacity for the best waiting class: dispatching into it lets the
        # lane spill a low-priority slot rather than park the interactive
        # request at the frontend behind running batch work.
        p_best = min(r.priority for r in self.waiting)
        capacity = [
            0 if not self.lane_alive[i] else max(
                0,
                lane.free_slots()
                + lane.preemptible_slots(p_best)
                - len(lane.waiting),
            )
            for i, lane in enumerate(self.lanes)
        ]
        if not any(capacity):
            return
        tasks = [
            Task(
                task_id=i,
                gflops=self._request_gflops(r),
                comm_bytes=4.0 * len(r.prompt),  # token ids to the device
                request_id=r.request_id,
                stage="request",
                priority_class=r.priority,
            )
            for i, r in enumerate(self.waiting)
        ]
        if self.admission == "priority":
            order = sorted(
                range(len(self.waiting)),
                key=lambda i: (self.waiting[i].priority, self.waiting[i].seq),
            )
        else:
            order = list(range(len(self.waiting)))
        # A dead lane is priced at infinite load, not just zero capacity:
        # place_fleet's max_spill baseline compares the cheapest *open*
        # device against the fleet-wide best, and a corpse with a healthy
        # link and no load would anchor that baseline forever — every
        # survivor looks "too poor", nothing places, and a frozen modeled
        # clock never reaches the corpse's recovery event (livelock).
        assignment, _ = place_fleet(
            tasks,
            [lane.tiers.end_cap for lane in self.lanes],
            self.scheduler,
            loads=[
                self._lane_load(lane) if self.lane_alive[i] else float("inf")
                for i, lane in enumerate(self.lanes)
            ],
            measured_gbps=[lane.bw.gbps for lane in self.lanes],
            capacity=capacity,
            max_spill=self.max_spill,
            order=order,
            expert_cost=self._expert_placement_cost(),
        )
        # dispatch in placement order so each lane's queue keeps it
        for i in order:
            req = self.waiting[i]
            d = assignment[i]
            if d < 0:
                continue
            # direct dispatch (already validated + stamped at fleet submit;
            # lane.submit would re-stamp submit_time and hide frontend wait)
            if req.request_id in self._migrating:
                # migrated off a dead lane: hand its parked spill state to
                # the destination, which restores it through the ordinary
                # preemption path (page blocks re-split at *its* split)
                self.lanes[d]._spilled[req.request_id] = self._migrating.pop(
                    req.request_id
                )
                self.migrations += 1
            self.lanes[d].waiting.append(req)
            self.placed.append(
                {"request_id": req.request_id, "device": d,
                 "gflops": tasks[i].gflops, "priority": req.priority}
            )
        # the frontend queue itself stays in submission order
        self.waiting = [
            r for i, r in enumerate(self.waiting) if assignment[i] < 0
        ]

    def _expert_placement_cost(self) -> Optional[List[float]]:
        """Per-device residency surcharge for ``place_fleet`` (seconds per
        task GFLOP): the registry's expected expert-miss wire time per
        routed token, normalized by per-token compute so the surcharge
        scales with request size like the other marginal terms.  Zero
        everywhere once every lane's target set is resident — placement
        then reduces exactly to the PR 6 marginal (parity)."""
        if self.expert_registry is None:
            return None
        gpt = 2.0 * self.cfg.active_param_count() * 1e-9  # GFLOPs per token
        return [
            self.expert_registry.lane_miss_cost_s(
                i, lane._active_lids(), lane._target_mask_np()
            ) / max(gpt, 1e-12)
            for i, lane in enumerate(self.lanes)
        ]

    # -- stepping -------------------------------------------------------------

    def step(self) -> int:
        """One fleet tick: place frontend requests, then advance every lane
        (each lane drains its cloud boundaries on the shared resource, admits
        from its own queue, and refills its end tier).  The expert registry
        is ticked first: every lane's measured route-frequency EMA is pushed
        into the fleet map, so de-dup decisions and placement costs this
        tick see fleet-wide measurements."""
        if self.chaos is not None:
            self.chaos.tick()
        now = self.clock()
        for i, lane in enumerate(self.lanes):
            if self.lane_alive[i]:
                self.health.beat(f"lane{i}", now)
        if self.expert_registry is not None:
            for i, lane in enumerate(self.lanes):
                if self.lane_alive[i]:
                    self.expert_registry.note_freq(i, lane._route_freq)
        self._place()
        emitted = 0
        for i, lane in enumerate(self.lanes):
            if self.lane_alive[i]:
                emitted += lane.step()
        return emitted

    def busy(self) -> bool:
        """Anything left to do anywhere in the fleet?  (Frontend queue,
        parked migrations, lane queues, in-flight prefill, or active
        decode.)"""
        return (
            bool(self.waiting)
            or bool(self._migrating)
            or any(lane.busy() for lane in self.lanes)
        )

    def _progress_sig(self) -> tuple:
        # every lane contributes (dead ones too, for a stable tuple shape);
        # placement, migration handoff and fault transitions also count
        sig = (
            len(self.placed),
            len(self.waiting),
            len(self._migrating),
            self.lane_failures,
            self.lane_recoveries,
        )
        for lane in self.lanes:
            sig += lane._progress_sig()
        return sig

    def stall_diagnostic(self) -> str:
        lanes = "; ".join(
            f"lane{i}[{'up' if self.lane_alive[i] else 'DOWN'}] "
            + lane.stall_diagnostic()
            for i, lane in enumerate(self.lanes)
        )
        return (
            f"frontend={len(self.waiting)} migrating={len(self._migrating)} "
            f"cloud_servers={self.cloud_servers} :: {lanes}"
        )

    def run(self, max_steps: int = 10_000) -> List[Request]:
        guard = StallGuard(self.stall_limit)
        for _ in range(max_steps):
            if not self.busy():
                break
            self.step()
            guard.note(self._progress_sig(), self.stall_diagnostic)
        return self.finished

    # -- dynamic conditions (per-device: only that lane replans) --------------

    def observe_bandwidth(self, device: int, gbps: float):
        """Feed one device's link measurement; replans only that lane, at
        its own drained safe point."""
        self.lanes[device].observe_bandwidth(gbps)

    def update_device_state(self, device: int, state: DeviceState):
        """Feed one device's state vector (eq. 2); re-derives that lane's
        fleet expert mask and replan-checks it alone."""
        self.lanes[device].update_device_state(state)

    # -- fault injection & recovery -------------------------------------------

    def fail_lane(self, device: int):
        """Kill one end device: evacuate its in-flight work (decode slots
        spill through the PR 6 preemption path, prefill jobs restart from
        scratch), park the spill states for migration, hand every request
        back to the frontend for re-placement, mark the lane dead so
        ``_place`` never assigns to it, and invalidate its expert residency
        in the registry — an in-flight peer fetch naming this lane re-prices
        against the live map and falls back to the cloud, never a corpse.
        Idempotent: killing a dead lane is a no-op."""
        if not self.lane_alive[device]:
            return
        lane = self.lanes[device]
        reqs, spilled, nbytes = lane.evacuate()
        self._migrating.update(spilled)
        self.migration_spill_bytes += nbytes
        self.waiting.extend(reqs)
        self.waiting.sort(key=lambda r: r.seq)
        self.lane_alive[device] = False
        self.lane_failures += 1
        if self.expert_registry is not None:
            self.expert_registry.set_lane_alive(device, False)
        if lane._expert_pooled:
            # drop the dead device's slab residency: its weights are gone
            # with the device, and a recovered lane re-fetches cold
            for lid in range(lane.expert_pool.table.shape[0]):
                lane.expert_pool.free_layer(lid)
            lane._prefetch_queue = []
            lane._expert_dirty = True

    def recover_lane(self, device: int):
        """Bring a dead end device back: mark it placeable again, restore
        its registry membership, and cold-restart its expert pool (residency
        was dropped at death; the first safe point re-plans and re-fetches).
        Its timeline cursors jump to "now" — a rebooted device cannot have
        been doing work while dead.  Idempotent on a live lane."""
        if self.lane_alive[device]:
            return
        lane = self.lanes[device]
        now = self.clock()
        self.lane_alive[device] = True
        self.lane_recoveries += 1
        self.health.beat(f"lane{device}", now)
        if self.expert_registry is not None:
            self.expert_registry.set_lane_alive(device, True)
        if lane._virtual_time:
            for g in range(lane.n_groups):
                lane._group_ready_s[g] = max(lane._group_ready_s[g], now)
        if lane._expert_pooled:
            lane._expert_ready_s = max(lane._expert_ready_s, now)
            lane._expert_sync()

    def set_link_rate(self, device: int, gbps: float):
        """Declare one device's link rate (chaos event or recovery): a hard
        estimator assignment, entering/leaving the lane's blackout ladder at
        its next safe point."""
        self.lanes[device].observe_bandwidth(gbps, hard=True)

    def inject_peer_faults(self, count: int):
        """Arm ``count`` peer-slab-fetch failures fleet-wide (consumed by
        whichever lanes fetch from peers next; each falls back to cloud
        after one backoff)."""
        if self.expert_registry is None:
            raise RuntimeError("peer faults need the fleet expert registry")
        self.expert_registry.inject_peer_faults(count)

    def inject_transfer_faults(self, device: int, count: int):
        """Arm ``count`` boundary-transfer failures on one device's link."""
        self.lanes[device].inject_transfer_faults(count)

    def fail_cloud_server(self):
        """Lose one cloud server: shrink the shared multi-server resource,
        re-scale every lane's share of the aggregate cloud budget (splits
        may move at each lane's next safe point), and return the re-sharded
        expert layout for the survivors (``cloud_expert_shards``; None for
        dense fleets).  Losing the *last* server is a total outage — raised,
        not degraded: no lane can serve [split, R) without a cloud tier."""
        if self.cloud_servers <= 1:
            raise RuntimeError(
                "cannot fail the last cloud server: the cloud tier hosts "
                "[split, R) + LM head for every lane — total outage, not "
                "graceful degradation"
            )
        self.cloud_servers -= 1
        self.cloud_server_failures += 1
        self.timeline.remove_server("cloud")
        share = self.cloud_servers / self.n_devices
        for lane in self.lanes:
            lane.set_cloud_share(share)
        return self.cloud_expert_shards()

    # -- introspection --------------------------------------------------------

    @property
    def finished(self) -> List[Request]:
        return [r for lane in self.lanes for r in lane.finished]

    @property
    def replan_events(self) -> List[Dict]:
        return [
            {"device": i, **ev}
            for i, lane in enumerate(self.lanes)
            for ev in lane.replan_events
        ]

    @property
    def end_masks(self):
        return [lane.tiers.end_mask for lane in self.lanes]

    def defrag_kv(self):
        """Compact the fleet-shared cloud pool: one permutation, applied to
        every lane's cloud-tier storage (lane-private end pools defrag at
        each lane's own replan safe points)."""
        perm = self.cloud_pool.defrag()
        for lane in self.lanes:
            lane._cloud_pages = jax.tree.map(
                lambda leaf: leaf[:, perm], lane._cloud_pages
            )

    def metrics(self) -> Dict:
        per_device = [lane.metrics() for lane in self.lanes]
        tokens = sum(len(r.generated) for r in self.finished)
        makespan = self.timeline.makespan_s
        end_in_use = sum(lane.end_pool.pages_in_use for lane in self.lanes)
        end_cap = sum(lane.end_pool.num_pages for lane in self.lanes)
        end_peak_bytes = sum(
            lane.end_pool.peak_in_use
            * kvcache.paged_block_bytes(lane._end_pages)
            for lane in self.lanes
        )
        cloud_page_bytes = max(
            (kvcache.paged_block_bytes(lane._cloud_pages) for lane in self.lanes),
            default=0,
        )
        kv_in_use = end_in_use + self.cloud_pool.pages_in_use
        kv_cap = end_cap + self.cloud_pool.num_pages
        return {
            "n_devices": self.n_devices,
            "cloud_servers": self.cloud_servers,
            "splits": [lane.split for lane in self.lanes],
            "tokens": tokens,
            "fleet_makespan_s": makespan,
            # modeled steady-state fleet rate: every device pipelines against
            # the shared cloud on one occupancy timeline
            "aggregate_tokens_per_s": tokens / max(makespan, 1e-12),
            "cloud_busy_s": self.timeline.busy_s.get("cloud", 0.0),
            "replan_events": len(self.replan_events),
            "n_placed": len(self.placed),
            "preemptions": sum(lane.n_preemptions for lane in self.lanes),
            "preempt_restores": sum(
                lane.n_preempt_restores for lane in self.lanes
            ),
            "preempt_spill_bytes": sum(
                lane.preempt_spill_bytes for lane in self.lanes
            ),
            # fault counters (satellite: summed across lanes + fleet-level
            # migration accounting; zero everywhere on a fault-free run)
            "lane_failures": self.lane_failures,
            "lane_recoveries": self.lane_recoveries,
            "migrations": self.migrations,
            "migration_restores": sum(
                lane.n_migration_restores for lane in self.lanes
            ),
            "migration_spill_bytes": self.migration_spill_bytes,
            "transfer_retries": sum(
                lane.transfer_retries for lane in self.lanes
            ),
            "degraded_ticks": sum(lane.degraded_ticks for lane in self.lanes),
            "link_blackout_s": sum(
                lane.blackout_seconds() for lane in self.lanes
            ),
            "cloud_server_failures": self.cloud_server_failures,
            # speculative decode, summed across lanes (acceptance is the
            # drafted-weighted rate — exactly accepted/drafted fleet-wide)
            "spec_rounds": sum(m["spec_rounds"] for m in per_device),
            "spec_drafted": sum(m["spec_drafted"] for m in per_device),
            "spec_accepted": sum(m["spec_accepted"] for m in per_device),
            "spec_acceptance_rate": round(
                sum(m["spec_accepted"] for m in per_device)
                / max(sum(m["spec_drafted"] for m in per_device), 1),
                4,
            ),
            "spec_rollbacks": sum(m["spec_rollbacks"] for m in per_device),
            "n_host_syncs": sum(m["n_host_syncs"] for m in per_device),
            # fleet-wide paged-KV accounting: per-lane end pools plus the
            # one shared cloud pool (admission anywhere gates on the latter)
            "kv_pages_in_use": kv_in_use,
            "kv_pages_capacity": kv_cap,
            "kv_utilization": kv_in_use / max(kv_cap, 1),
            "kv_bytes_peak": (
                end_peak_bytes + self.cloud_pool.peak_in_use * cloud_page_bytes
            ),
            # fused paged attention vs the dense-gather sweep it replaced:
            # per-step KV bytes, summed over lanes (each lane counts its
            # own end pool plus its rows of the shared cloud pool)
            "attn_bytes_paged_step": sum(
                m["attn_bytes_paged_step"] for m in per_device
            ),
            "attn_bytes_dense_step": sum(
                m["attn_bytes_dense_step"] for m in per_device
            ),
            # paged expert weights, summed over pooled lanes (hit rate is
            # the mean — each lane's resident set covers its own mask)
            **self._expert_fleet_metrics(per_device),
            "per_device": per_device,
        }

    def _expert_fleet_metrics(self, per_device: List[Dict]) -> Dict:
        pooled = [m for m in per_device if "expert_resident_slabs" in m]
        if not pooled:
            return {}
        # hit rate weighted by per-lane routed tokens: an idle lane (hit
        # rate 1.0 over zero traffic) must not inflate the fleet number.
        # All-zero weights (nothing decoded yet) fall back to the plain mean.
        weights = [m.get("expert_routed_tokens", 0) for m in pooled]
        total_w = sum(weights)
        if total_w > 0:
            hit = sum(
                m["expert_hit_rate"] * w for m, w in zip(pooled, weights)
            ) / total_w
        else:
            hit = sum(m["expert_hit_rate"] for m in pooled) / len(pooled)
        out = {
            "expert_resident_slabs": sum(
                m["expert_resident_slabs"] for m in pooled
            ),
            "expert_slab_capacity": sum(
                m["expert_slab_capacity"] for m in pooled
            ),
            "expert_hit_rate": hit,
            "expert_bytes_down": sum(m["expert_bytes_down"] for m in pooled),
            "expert_bytes_peer": sum(m["expert_bytes_peer"] for m in pooled),
            "expert_bytes_up": sum(m["expert_bytes_up"] for m in pooled),
            "expert_prefetches": sum(m["expert_prefetches"] for m in pooled),
            "expert_peer_fetches": sum(
                m["expert_peer_fetches"] for m in pooled
            ),
            "expert_evictions": sum(m["expert_evictions"] for m in pooled),
            "expert_routed_tokens": total_w,
        }
        if self.expert_registry is not None:
            # fleet-wide residency map: unique (layer, expert) pairs vs the
            # summed per-lane slabs — how much the de-dup policy is buying
            out["expert_unique_residents"] = (
                self.expert_registry.unique_residents()
            )
            out["expert_fleet_dedup_ratio"] = self.expert_registry.dedup_ratio()
        return out

    def cloud_expert_shards(self) -> Optional[List[List[int]]]:
        """Shard the cloud tier's dense expert stacks across the
        multi-server cloud using the registry map: experts are weighted by
        the share of fleet traffic that actually drains to the cloud (a
        lane's misses — fleet-resident experts are served on the ends) and
        balanced across ``cloud_servers`` (``sharding.fleet_expert_shards``).
        Apply with ``sharding.shard_expert_stacks``.  None when the fleet
        runs isolated pools / dense models."""
        if self.expert_registry is None:
            return None
        return fleet_expert_shards(
            self.expert_registry.cloud_expert_load(), self.cloud_servers
        )
