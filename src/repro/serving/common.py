"""Shared serving infrastructure: requests, slot bookkeeping, link metering.

Both the single-tier continuous-batching engine (``serving.engine``) and the
streaming end-cloud decode engine (``serving.stream``) are slot machines: a
fixed decode batch of ``max_batch`` slots, finished requests free their slot,
waiting requests are prefilled into free slots.  ``SlotEngineBase`` owns that
lifecycle; subclasses provide the actual prefill/decode compute.
"""

from __future__ import annotations

import bisect
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def element_bytes(dtype) -> int:
    """Bytes per element of ``dtype`` (a jnp/np dtype, dtype class, or
    string such as ``"bfloat16"``).  The ONE place serving byte metering
    resolves element widths — no hardcoded ``* 4`` anywhere — so a stream
    carrying bf16 / int8 payloads meters half / a quarter of the f32
    bytes."""
    return jnp.dtype(dtype).itemsize


def payload_nbytes(z) -> int:
    """Total bytes of a boundary payload: a single array or a tuple of
    arrays (the quantized boundary codec ships ``(codes, scales)``)."""
    if isinstance(z, (tuple, list)):
        return sum(int(p.size) * element_bytes(p.dtype) for p in z)
    return int(z.size) * element_bytes(z.dtype)


def payload_block_until_ready(z):
    """``block_until_ready`` on a payload that may be a tuple of arrays."""
    for p in z if isinstance(z, (tuple, list)) else (z,):
        p.block_until_ready()


@dataclass
class Request:
    request_id: int
    prompt: np.ndarray  # [S] int32
    max_new_tokens: int = 16
    eos_id: int = -1  # -1 = never
    # SLO class: lower ``priority`` admits first (0 = interactive).  The
    # per-request latency targets are carried for reporting/accounting —
    # the engine schedules by class, the load harness scores the targets.
    priority: int = 1
    ttft_slo_s: Optional[float] = None
    tpot_slo_s: Optional[float] = None
    # filled by the engine
    generated: List[int] = field(default_factory=list)
    submit_time: float = 0.0
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    seq: int = -1  # submission order stamp (ties within a priority class)
    n_preemptions: int = 0
    n_migrations: int = 0  # lane-death migrations this request survived

    @property
    def done(self) -> bool:
        return self.finish_time is not None

    @property
    def ttft_s(self) -> Optional[float]:
        """Time to first token (None until the first token lands)."""
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.submit_time

    @property
    def tpot_s(self) -> Optional[float]:
        """Mean time per output token after the first (None until finished
        or for single-token generations)."""
        if self.finish_time is None or len(self.generated) < 2:
            return None
        return (self.finish_time - self.first_token_time) / (
            len(self.generated) - 1
        )


class VirtualClock:
    """Callable clock over *modeled* time.

    Engines stamp request lifecycle times (submit / first token / finish)
    with ``self.clock()``; by default that is host wall time.  Handing an
    engine a ``VirtualClock`` switches those stamps onto the engine's
    ``StageTimeline`` axis: the engine detects it and sets ``now`` to the
    modeled completion time of the stage that produced each event, so
    TTFT/TPOT are measured on the same deterministic clock the schedule is
    computed on.  The load harness (``serving.loadgen.drive``) owns the
    submission side: it releases arrivals when ``now`` passes their arrival
    time and advances ``now`` to the timeline makespan after each tick.
    """

    def __init__(self, now: float = 0.0):
        self.now = now

    def __call__(self) -> float:
        return self.now

    def advance_to(self, t: float) -> float:
        """Monotone advance (jumping backwards is reserved for engines
        stamping a specific stage completion)."""
        self.now = max(self.now, t)
        return self.now


@dataclass
class LinkStats:
    """Meter for the end<->cloud link: bytes on the wire in each direction
    plus modeled wire seconds.  In a real two-host deployment the measured
    (bytes, seconds) pairs are what you feed to
    ``core.pipeline.BandwidthEstimator.observe`` for replanning."""

    bytes_up: int = 0
    bytes_down: int = 0
    bytes_peer: int = 0
    transfers: int = 0
    seconds_up: float = 0.0
    seconds_peer: float = 0.0

    def transfer_time(self, nbytes: int, gbps: float) -> float:
        return nbytes * 8.0 / max(gbps * 1e9, 1e-9)

    def record_peer(self, nbytes: int, seconds: float) -> None:
        """Meter an end<->end transfer (peer expert-slab fetch — the wire
        time is modeled by the fleet registry's peer-link cost, so it is
        recorded rather than derived from the cloud uplink rate)."""
        self.bytes_peer += nbytes
        self.seconds_peer += seconds

    def record_up(self, nbytes: int, gbps: float) -> float:
        """Meter an end->cloud transfer; returns its modeled wire time."""
        t = self.transfer_time(nbytes, gbps)
        self.bytes_up += nbytes
        self.transfers += 1
        self.seconds_up += t
        return t

    def record_down(self, nbytes: int) -> None:
        """Meter a cloud->end transfer (token-id feedback — bytes only; at
        ~4 bytes/token its wire time is noise next to the boundary uplink)."""
        self.bytes_down += nbytes

    @property
    def measured_gbps(self) -> float:
        """Average realized uplink rate over everything metered so far."""
        return self.bytes_up * 8.0 / max(self.seconds_up * 1e9, 1e-12)


class StageTimeline:
    """Resource-occupancy clock for the decode pipeline (same queueing model
    as ``sim.simulator``: a stage starts at max(input-ready, resource-free)).

    The streaming engine feeds it measured compute times and modeled link
    times; the resulting makespan is the *pipelined* schedule, while
    ``serial_s`` accumulates the same stages laid end to end — the spread
    between the two is exactly the overlap the double buffer buys.

    A resource may have multiple servers (``capacity``), and each server
    books jobs into *busy intervals*: a job starts in the earliest gap at
    or after its ready time (backfill).  Interval booking — rather than a
    single ratcheting free-time per server — matters because callers may
    arrive out of virtual-time order: fleet lanes advance their own clocks
    at different rates, so a slow lane can book the shared cloud at t=150ms
    before a fast lane asks for t=50ms; the fast lane's job must land in
    the earlier gap, exactly as a real FCFS queue (or ``sim.simulator``'s
    event heap) would serve it.  The fleet engine uses capacity for the
    shared cloud tier (N end devices, ``cloud_servers`` cloud GPUs) and
    registers per-device end/link resources via ``add_resource``.
    """

    def __init__(
        self,
        resources: Sequence[str] = ("end", "link", "cloud"),
        capacity: Optional[Dict[str, int]] = None,
    ):
        capacity = capacity or {}
        # per resource: per server: sorted [start, end) busy intervals
        self._servers: Dict[str, List[List[Tuple[float, float]]]] = {
            r: [[] for _ in range(max(capacity.get(r, 1), 1))]
            for r in resources
        }
        self.busy_s: Dict[str, float] = {r: 0.0 for r in resources}
        self.serial_s: float = 0.0
        self._max_end = 0.0

    def add_resource(self, name: str, capacity: int = 1):
        """Register a resource if absent (idempotent; capacity of an
        existing resource is left untouched)."""
        if name not in self._servers:
            self._servers[name] = [[] for _ in range(max(capacity, 1))]
            self.busy_s[name] = 0.0

    def n_servers(self, name: str) -> int:
        return len(self._servers[name])

    def remove_server(self, name: str):
        """Drop one server from a multi-server resource (fault injection:
        a shared cloud server dies).  Work already booked on it stays in
        ``busy_s``/``makespan_s`` — it happened — but its interval list
        vanishes, so every future booking queues on the survivors.  The
        last server cannot be removed: a resource with no servers makes
        every dependent stage unserveable, which callers must handle as a
        total outage, not a capacity change."""
        servers = self._servers[name]
        if len(servers) <= 1:
            raise ValueError(
                f"resource {name!r} has a single server; removing it is a "
                "total outage, not a capacity reduction"
            )
        servers.pop()

    @staticmethod
    def _earliest_start(
        intervals: List[Tuple[float, float]], ready_s: float, service_s: float
    ) -> float:
        start = ready_s
        for s, e in intervals:
            if start + service_s <= s:
                break  # fits in the gap before this interval
            if e > start:
                start = e
        return start

    @property
    def free_at(self) -> Dict[str, float]:
        """Time each resource's earliest-draining server runs dry."""
        return {
            r: min((ivals[-1][1] if ivals else 0.0) for ivals in servers)
            for r, servers in self._servers.items()
        }

    def occupy(self, resource: str, ready_s: float, service_s: float) -> float:
        servers = self._servers[resource]
        best, best_start = 0, None
        for i, ivals in enumerate(servers):
            start = self._earliest_start(ivals, ready_s, service_s)
            if best_start is None or start < best_start:
                best, best_start = i, start
        end = best_start + service_s
        if service_s > 0:
            ivals = servers[best]
            j = bisect.bisect_left(ivals, (best_start, end))
            # coalesce with touching neighbours — the common booking is
            # contiguous at a server's tail, so lists stay short and the
            # gap scan near-O(1) instead of growing one tuple per step
            s, e = best_start, end
            if j < len(ivals) and ivals[j][0] <= e:
                e = max(e, ivals[j][1])
                del ivals[j]
            if j > 0 and ivals[j - 1][1] >= s:
                s = ivals[j - 1][0]
                e = max(e, ivals[j - 1][1])
                del ivals[j - 1]
                j -= 1
            ivals.insert(j, (s, e))
        self.busy_s[resource] += service_s
        self.serial_s += service_s
        self._max_end = max(self._max_end, end)
        return end

    @property
    def makespan_s(self) -> float:
        return self._max_end

    def summary(self) -> Dict[str, float]:
        return {
            "pipelined_s": self.makespan_s,
            "serial_s": self.serial_s,
            **{f"busy_{r}_s": t for r, t in self.busy_s.items()},
        }


class TraceCounter:
    """Counts distinct argument shape/dtype signatures seen by a jitted
    callable — each distinct signature is one compiled trace, so engines can
    assert their stage-trace count is bounded by chunk/group *shapes* rather
    than by distinct prompt lengths.  ``log`` is a caller-owned set so the
    count survives stage-function rebuilds (each rebuild passes a fresh
    ``generation`` tag: a rebuilt jit re-traces even for seen shapes).

    ``sig_from`` skips leading arguments whose shapes cannot change within
    a build — the engines pass the (large) params pytree first, and any
    params re-split comes with a rebuilt wrapper/new generation — keeping
    the per-call bookkeeping on the decode hot path to a handful of leaves.
    """

    def __init__(self, fn: Callable, log: set, generation: int = 0,
                 sig_from: int = 1):
        self._fn = fn
        self._log = log
        self._gen = generation
        self._sig_from = sig_from

    @staticmethod
    def _sig(tree) -> Tuple:
        return tuple(
            (tuple(leaf.shape), str(getattr(leaf, "dtype", type(leaf))))
            if hasattr(leaf, "shape") else (type(leaf).__name__,)
            for leaf in jax.tree.leaves(tree)
        )

    def __call__(self, *args):
        self._log.add((self._gen, self._sig(args[self._sig_from :])))
        return self._fn(*args)


class SlotEngineBase:
    """Slot lifecycle shared by the serving engines.

    Subclasses implement ``_prefill_into_slot(slot, req) -> (int, payload)``
    (run prefill, return the first generated token plus whatever cache state
    the slot needs) and ``_install_slot(slot, payload)`` (copy that state
    into the batch cache — called only when the request actually continues
    past prefill, so requests that finish on their first token skip the
    copy) and drive decode via ``step``; the base provides admission, token
    harvesting, and the run loop.  ``_release_slot`` is called whenever a
    request leaves its slot (finish at prefill or at decode) so paged
    engines can return the slot's KV pages to the pool.
    """

    def __init__(
        self,
        max_batch: int,
        clock: Optional[Callable[[], float]] = None,
        max_len: Optional[int] = None,
        admission: str = "priority",
    ):
        import time as _time

        if admission not in ("priority", "fifo"):
            raise ValueError(f"admission={admission!r}")
        self.max_batch = max_batch
        self.max_len = max_len
        self.clock = clock or _time.monotonic
        self.admission = admission
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.waiting: List[Request] = []
        self.finished: List[Request] = []
        self._next_token = np.zeros((max_batch, 1), np.int32)
        self._active = np.zeros((max_batch,), bool)
        self._submit_seq = 0
        # livelock guard: busy ticks tolerated with no progress before the
        # run loop raises (see faults.StallGuard; attribute, not ctor arg,
        # so subclasses/tests tune it without threading a kwarg through)
        self.stall_limit = 256

    # -- request lifecycle ---------------------------------------------------

    def validate(self, req: Request):
        """Reject a request that cannot fit the slot's KV ring buffer: past
        ``max_len`` positions the ring wraps and silently corrupts attention,
        so over-long requests must fail loudly at submit time."""
        if len(req.prompt) == 0:
            raise ValueError(f"request {req.request_id}: empty prompt")
        if req.max_new_tokens < 1:
            raise ValueError(
                f"request {req.request_id}: max_new_tokens="
                f"{req.max_new_tokens} (prefill always emits one token)"
            )
        if self.max_len is not None:
            need = len(req.prompt) + req.max_new_tokens
            if need > self.max_len:
                raise ValueError(
                    f"request {req.request_id}: prompt ({len(req.prompt)}) + "
                    f"max_new_tokens ({req.max_new_tokens}) = {need} exceeds "
                    f"max_len={self.max_len}; the KV ring buffer would wrap"
                )
        cap = self._page_capacity()
        if cap is not None:
            pages = self._pages_for(req)
            if pages > cap:
                raise ValueError(
                    f"request {req.request_id}: needs {pages} KV pages but "
                    f"the smallest page pool holds only {cap} (kv_pages too "
                    "small for prompt + max_new_tokens); it could never be "
                    "admitted and would block the FIFO queue forever"
                )

    def _page_capacity(self) -> Optional[int]:
        """Hook: total pages of the engine's most constrained pool, or None
        for dense engines.  Paired with ``_pages_for``; the base validates
        that a request's worst-case reservation can ever be satisfied."""
        return None

    def _pages_for(self, req: Request) -> int:
        raise NotImplementedError

    def submit(self, req: Request):
        self.validate(req)
        req.submit_time = self.clock()
        req.seq = self._submit_seq
        self._submit_seq += 1
        self.waiting.append(req)

    def _slot_usable(self, slot: int) -> bool:
        """Hook: is this slot index eligible to hold requests at all?
        (Engines that pad the batch for equal-sized micro-batch groups mark
        padding slots unusable; slots mid-prefill are unusable too.)"""
        return True

    def _admittable(self, slot: int, req: Request) -> bool:
        """Hook: may ``req`` be admitted into this free slot right now?
        Paged engines check KV page availability here — admission is gated
        on pages, not just on a free slot."""
        return True

    def free_slots(self) -> int:
        """Slots currently able to accept a request (excludes padding slots
        and slots held by an in-flight chunked prefill)."""
        return sum(
            1 for i, s in enumerate(self.slots)
            if s is None and self._slot_usable(i)
        )

    def busy(self) -> bool:
        """Anything left to do?  (Queued, decoding, or mid-prefill.)"""
        return bool(self.waiting) or bool(self._active.any())

    def _admission_order(self) -> List[Request]:
        """The queue view admission scans.  ``"priority"`` (default) is a
        stable sort on (priority class, submission seq): equal-priority
        requests keep FIFO order, and a page-hungry low-priority request at
        the FIFO head can no longer starve interactive traffic — higher
        classes simply sort ahead of it.  ``"fifo"`` is pure submission
        order (the pre-SLO behavior, kept as the ablation baseline).

        Either way the scan *head* blocks its whole order: admitting work
        past a page-blocked head would keep pages occupied and starve it —
        within one class, FIFO fairness is the invariant worth keeping.
        """
        if self.admission == "priority":
            return sorted(self.waiting, key=lambda r: (r.priority, r.seq))
        return list(self.waiting)

    def _admit(self):
        """Prefill waiting requests into free slots, scanning the queue in
        ``_admission_order``.

        A request that finishes at its prefill token (EOS, or
        ``max_new_tokens == 1``) leaves its slot free, so the same slot is
        retried until it is actually occupied or the queue drains — skipping
        ahead would idle the slot for a whole engine tick per short request.
        """
        for slot in range(self.max_batch):
            while self.slots[slot] is None and self._slot_usable(slot):
                queue = self._admission_order()
                if not queue or not self._admittable(slot, queue[0]):
                    break
                req = queue[0]
                self.waiting.remove(req)
                tok, payload = self._prefill_into_slot(slot, req)
                req.generated.append(tok)
                if req.first_token_time is None:
                    req.first_token_time = self.clock()
                if tok == req.eos_id or len(req.generated) >= req.max_new_tokens:
                    req.finish_time = self.clock()
                    self.finished.append(req)
                    self._release_slot(slot)
                    continue  # slot still free: offer it to the next waiter
                self._install_slot(slot, payload)
                self.slots[slot] = req
                self._next_token[slot, 0] = tok
                self._active[slot] = True

    def _prefill_into_slot(self, slot: int, req: Request):
        raise NotImplementedError

    def _install_slot(self, slot: int, payload):
        raise NotImplementedError

    def _release_slot(self, slot: int):
        """Hook: a request left this slot (paged engines free its pages)."""

    def _harvest(self, next_ids: np.ndarray, slot_range=None) -> int:
        """Record one decoded token per active slot; retire finished slots.
        ``next_ids`` is indexed by absolute slot id."""
        n_emitted = 0
        for slot in slot_range if slot_range is not None else range(self.max_batch):
            req = self.slots[slot]
            if req is None:
                continue
            tok = int(next_ids[slot])
            req.generated.append(tok)
            n_emitted += 1
            self._next_token[slot, 0] = tok
            if tok == req.eos_id or len(req.generated) >= req.max_new_tokens:
                req.finish_time = self.clock()
                self.finished.append(req)
                self.slots[slot] = None
                self._active[slot] = False
                self._release_slot(slot)
        return n_emitted

    def _harvest_tokens(self, slot: int, tokens) -> int:
        """Multi-token variant of :meth:`_harvest` for one slot: commit a
        speculative round's accepted tokens in order.  EOS or the
        ``max_new_tokens`` budget can land mid-commit — the remaining
        accepted tokens are discarded (non-speculative decode would never
        have produced them) and the slot retires exactly as in
        :meth:`_harvest`."""
        req = self.slots[slot]
        if req is None or not tokens:
            return 0
        n_emitted = 0
        for tok in tokens:
            tok = int(tok)
            req.generated.append(tok)
            n_emitted += 1
            self._next_token[slot, 0] = tok
            if tok == req.eos_id or len(req.generated) >= req.max_new_tokens:
                req.finish_time = self.clock()
                self.finished.append(req)
                self.slots[slot] = None
                self._active[slot] = False
                self._release_slot(slot)
                break
        return n_emitted

    # -- stepping ------------------------------------------------------------

    def step(self) -> int:
        raise NotImplementedError

    def _progress_sig(self) -> tuple:
        """Progress signature for the livelock guard: admission, decode,
        and completion all move it.  Subclasses extend with their own
        monotone counters (prefill chunks, transfers, retries) so slow but
        real work — a prefetch crawling over a degraded link — never reads
        as a stall."""
        gen = sum(
            len(r.generated) for r in self.slots if r is not None
        )
        return (
            len(self.finished), len(self.waiting),
            int(self._active.sum()), gen,
        )

    def stall_diagnostic(self) -> str:
        """Queue/slot snapshot for the livelock guard's error message
        (``.`` free, ``i`` installed-inactive, ``A`` actively decoding)."""
        slots = "".join(
            "." if r is None else ("A" if self._active[i] else "i")
            for i, r in enumerate(self.slots)
        )
        return (
            f"waiting={len(self.waiting)} finished={len(self.finished)} "
            f"slots=[{slots}]"
        )

    def run(self, max_steps: int = 10_000):
        """Run until all submitted requests finish.  A livelock guard
        watches the progress signature: ``stall_limit`` consecutive busy
        ticks in which nothing was admitted, decoded, transferred, or
        retried raise loudly with a queue/slot diagnostic instead of
        silently spinning to ``max_steps`` and returning partial results
        that look like success."""
        from repro.serving.faults import StallGuard

        guard = StallGuard(self.stall_limit)
        for _ in range(max_steps):
            if not self.busy():
                break
            self.step()
            guard.note(self._progress_sig(), self.stall_diagnostic)
        return self.finished
