"""Deterministic serving-side fault injection and the recovery policy knobs.

The training side has had a failure model since the distributed PRs
(``distributed/fault.py``: step-indexed ``FailureInjector``, ``StepGuard``
timeouts, elastic re-layout).  This module is the *serving* counterpart,
built around the serving stack's own notion of time: every fault is an
event on the engines' ``VirtualClock``/``StageTimeline`` axis, fired by a
:class:`ChaosInjector` the fleet engine ticks, so a chaos run is exactly
as deterministic and replayable as a fault-free one — same seed, same
trace, bit-identical schedule and tokens.

Pieces:

  * :class:`FaultEvent` / :class:`FaultSchedule` — a validated, sorted
    list of timed events (lane crash/recovery, link blackout / severe
    degradation / recovery, cloud-server loss, peer-fetch failures,
    flaky boundary transfers), with a seeded :meth:`FaultSchedule.random`
    generator for property tests.
  * :class:`ChaosInjector` — binds a schedule to a fleet engine and fires
    every event whose time has passed at each engine tick, translating
    event kinds into the engine's recovery entry points (``fail_lane``,
    ``recover_lane``, ``set_link_rate``, ``fail_cloud_server``, ...).
    Keeps a fire log for determinism assertions.
  * :class:`HealthMonitor` — heartbeat bookkeeping, transfer timeouts,
    and the bounded exponential backoff policy retries follow
    (``backoff_s(attempt) = min(base * 2**attempt, cap)``).
  * :class:`StallGuard` — the livelock guard the run loops use: N
    consecutive busy ticks with an unchanged progress signature raise
    loudly with a queue/slot diagnostic instead of silently spinning.

This module is dependency-free (numpy only): the engines import it, never
the other way around.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Union

import numpy as np

__all__ = [
    "FAULT_KINDS",
    "FaultEvent",
    "FaultSchedule",
    "ChaosInjector",
    "HealthMonitor",
    "StallGuard",
]

# The serving fault taxonomy (see docs/architecture.md, "Failure model"):
#   lane_crash        an end device dies: in-flight work must migrate
#   lane_recover      a crashed device rejoins, empty and cold
#   link_blackout     a lane's uplink collapses below the usable floor
#   link_degrade      a lane's uplink drops severely but stays usable
#   link_recover      a lane's uplink returns to the given rate
#   cloud_server_loss one shared cloud server dies (capacity shrinks)
#   peer_fetch_fail   the next N peer slab fetches fail (re-source to cloud)
#   transfer_flaky    the next N boundary transfers on a lane need resends
FAULT_KINDS = (
    "lane_crash",
    "lane_recover",
    "link_blackout",
    "link_degrade",
    "link_recover",
    "cloud_server_loss",
    "peer_fetch_fail",
    "transfer_flaky",
)

_LANE_KINDS = (
    "lane_crash", "lane_recover",
    "link_blackout", "link_degrade", "link_recover",
    "transfer_flaky",
)


@dataclasses.dataclass(frozen=True, order=True)
class FaultEvent:
    """One timed fault.  Frozen and totally ordered so schedules sort
    deterministically (ties broken by kind, then device)."""

    t_s: float  # fire time on the engines' modeled clock
    kind: str
    device: int = -1  # lane id for lane/link events; -1 = not applicable
    gbps: float = 0.0  # link events: the declared post-event rate
    count: int = 1  # peer_fetch_fail / transfer_flaky: injected failures

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; one of {FAULT_KINDS}"
            )
        if self.kind in _LANE_KINDS and self.device < 0:
            raise ValueError(f"{self.kind} event needs a device id")
        if self.kind in ("link_degrade", "link_recover") and self.gbps <= 0:
            raise ValueError(f"{self.kind} event needs a positive gbps")
        if self.count < 1:
            raise ValueError(f"count={self.count} must be >= 1")


class FaultSchedule:
    """A validated, time-sorted fault schedule.

    Build one explicitly from events, or draw a seeded random schedule
    with :meth:`random` (the property tests' generator).  Iterating
    yields events in fire order.
    """

    def __init__(self, events: Sequence[FaultEvent]):
        self.events: List[FaultEvent] = sorted(events)
        crashed: set = set()
        for ev in self.events:
            # a schedule that crashes a crashed lane (or recovers a live
            # one) is almost always a generator bug; the injector would
            # no-op it, hiding the mistake — reject it here instead
            if ev.kind == "lane_crash":
                if ev.device in crashed:
                    raise ValueError(
                        f"lane {ev.device} crashed twice without recovery"
                    )
                crashed.add(ev.device)
            elif ev.kind == "lane_recover":
                if ev.device not in crashed:
                    raise ValueError(
                        f"lane {ev.device} recovered while alive"
                    )
                crashed.discard(ev.device)

    def __iter__(self):
        return iter(self.events)

    def __len__(self) -> int:
        return len(self.events)

    @classmethod
    def random(
        cls,
        seed: int,
        *,
        horizon_s: float,
        n_lanes: int,
        nominal_gbps: float = 1.0,
        n_crashes: int = 1,
        n_blackouts: int = 1,
        n_degrades: int = 0,
        n_peer_faults: int = 0,
        n_transfer_faults: int = 0,
        cloud_losses: int = 0,
        recover_frac: Union[float, Sequence[float]] = (0.1, 0.3),
    ) -> "FaultSchedule":
        """Seeded random schedule over ``[0, horizon_s)``.

        Crashes land in the first 60% of the horizon and always recover
        ``recover_frac`` of the horizon later; blackouts drop a lane's
        link to ``nominal/1000`` (below any sane blackout floor) and
        recover to nominal; degrades drop to 30% of nominal and recover.
        ``n_lanes >= 2`` is required when crashes are drawn — a fleet
        whose only lane is down cannot advance the virtual clock to the
        recovery time (the livelock guard would fire, by design).
        """
        if n_crashes > 0 and n_lanes < 2:
            raise ValueError(
                "crash schedules need >= 2 lanes: with the only lane down "
                "nothing advances the clock to the recovery event"
            )
        rng = np.random.default_rng(seed)
        lo, hi = (
            (recover_frac, recover_frac)
            if np.isscalar(recover_frac) else tuple(recover_frac)
        )
        events: List[FaultEvent] = []

        def _window(kind_down: str, kind_up: str, lane: int, **kw):
            t0 = float(rng.uniform(0.05, 0.6)) * horizon_s
            dt = float(rng.uniform(lo, hi)) * horizon_s
            events.append(FaultEvent(t0, kind_down, device=lane, **kw))
            up_kw = {"gbps": nominal_gbps} if kind_up == "link_recover" else {}
            events.append(FaultEvent(t0 + dt, kind_up, device=lane, **up_kw))

        for _ in range(n_crashes):
            _window("lane_crash", "lane_recover", int(rng.integers(n_lanes)))
        for _ in range(n_blackouts):
            _window(
                "link_blackout", "link_recover", int(rng.integers(n_lanes)),
                gbps=nominal_gbps / 1000.0,
            )
        for _ in range(n_degrades):
            _window(
                "link_degrade", "link_recover", int(rng.integers(n_lanes)),
                gbps=0.3 * nominal_gbps,
            )
        for _ in range(n_peer_faults):
            events.append(FaultEvent(
                float(rng.uniform(0.05, 0.8)) * horizon_s, "peer_fetch_fail",
                count=int(rng.integers(1, 4)),
            ))
        for _ in range(n_transfer_faults):
            events.append(FaultEvent(
                float(rng.uniform(0.05, 0.8)) * horizon_s, "transfer_flaky",
                device=int(rng.integers(n_lanes)),
                count=int(rng.integers(1, 3)),
            ))
        for _ in range(cloud_losses):
            events.append(FaultEvent(
                float(rng.uniform(0.05, 0.8)) * horizon_s,
                "cloud_server_loss",
            ))
        return cls(events)


class ChaosInjector:
    """Fires a :class:`FaultSchedule` against a fleet engine on its clock.

    ``bind(engine)`` attaches the injector (the engine ticks it at the top
    of every ``step``); ``tick`` fires, in order, every not-yet-fired
    event whose ``t_s`` has passed on ``engine.clock``.  Events whose
    lane is already in the requested state no-op (the engine's recovery
    entry points are idempotent), but still land in the fire log — the
    log is the determinism witness chaos benchmarks compare across runs.
    """

    def __init__(self, schedule: FaultSchedule, engine=None):
        self.schedule = schedule
        self.engine = None
        self._next = 0
        self.fired: List[Dict] = []
        if engine is not None:
            self.bind(engine)

    def bind(self, engine) -> "ChaosInjector":
        self.engine = engine
        engine.chaos = self
        return self

    @property
    def pending(self) -> int:
        return len(self.schedule.events) - self._next

    def tick(self):
        if self.engine is None:
            raise RuntimeError("ChaosInjector.tick before bind(engine)")
        now = self.engine.clock()
        while self._next < len(self.schedule.events):
            ev = self.schedule.events[self._next]
            if ev.t_s > now:
                break
            self._next += 1
            self._fire(ev, now)

    def _fire(self, ev: FaultEvent, now: float):
        eng = self.engine
        if ev.kind == "lane_crash":
            eng.fail_lane(ev.device)
        elif ev.kind == "lane_recover":
            eng.recover_lane(ev.device)
        elif ev.kind in ("link_blackout", "link_degrade", "link_recover"):
            # a blackout with no declared rate collapses to ~zero (the
            # floor keeps modeled wire times finite)
            gbps = ev.gbps if ev.gbps > 0 else 1e-4
            eng.set_link_rate(ev.device, gbps)
        elif ev.kind == "cloud_server_loss":
            eng.fail_cloud_server()
        elif ev.kind == "peer_fetch_fail":
            eng.inject_peer_faults(ev.count)
        elif ev.kind == "transfer_flaky":
            eng.inject_transfer_faults(ev.device, ev.count)
        self.fired.append({
            "t_s": ev.t_s,
            "t_fired_s": now,
            "kind": ev.kind,
            "device": ev.device,
            "gbps": ev.gbps,
            "count": ev.count,
        })

    def fire_log(self) -> List[Dict]:
        """The fired events in fire order (copy) — compare across repeat
        runs to assert per-seed determinism."""
        return [dict(d) for d in self.fired]


class HealthMonitor:
    """Fleet health bookkeeping and the shared retry/backoff policy.

    Heartbeats: the fleet beats every live lane each tick (on the modeled
    clock); ``suspect`` flags a lane whose last beat is older than
    ``heartbeat_timeout_s`` — the detection primitive a deployment's
    failure detector would drive ``fail_lane`` from (the chaos injector
    declares crashes directly, so tests can compare declared vs detected).

    Backoff: every retried transfer (flaky boundary payloads, failed peer
    slab fetches) idles ``backoff_s(attempt)`` before resending — bounded
    exponential, capped at ``backoff_cap_s`` so a long fault window can
    never push a single retry's delay unbounded.  ``max_transfer_attempts``
    bounds the attempts themselves; exhausting them raises (a link that
    flaky is a blackout, and blackouts have their own ladder).
    """

    def __init__(
        self,
        *,
        heartbeat_timeout_s: float = 1.0,
        transfer_timeout_s: float = 0.5,
        backoff_base_s: float = 0.01,
        backoff_cap_s: float = 0.25,
        max_transfer_attempts: int = 5,
    ):
        if max_transfer_attempts < 1:
            raise ValueError("max_transfer_attempts must be >= 1")
        self.heartbeat_timeout_s = heartbeat_timeout_s
        self.transfer_timeout_s = transfer_timeout_s
        self.backoff_base_s = backoff_base_s
        self.backoff_cap_s = backoff_cap_s
        self.max_transfer_attempts = max_transfer_attempts
        self._last_beat: Dict[str, float] = {}

    def beat(self, name: str, now: float):
        self._last_beat[name] = now

    def last_beat(self, name: str) -> Optional[float]:
        return self._last_beat.get(name)

    def suspect(self, name: str, now: float) -> bool:
        """True when ``name`` has been seen but is past its heartbeat
        timeout (an unseen name is unknown, not suspect)."""
        last = self._last_beat.get(name)
        return last is not None and now - last > self.heartbeat_timeout_s

    def suspects(self, now: float) -> List[str]:
        return [n for n in self._last_beat if self.suspect(n, now)]

    def backoff_s(self, attempt: int) -> float:
        """Delay before retry ``attempt`` (0-based): bounded exponential."""
        return min(
            self.backoff_base_s * (2.0 ** max(attempt, 0)),
            self.backoff_cap_s,
        )


class StallGuard:
    """Livelock guard for engine run loops.

    Feed it a hashable progress signature once per busy tick; ``limit``
    consecutive identical signatures raise ``RuntimeError`` with the
    engine's diagnostic.  Signatures are built from monotone counters
    (tokens, chunks, transfers, retries, placements), so "no change"
    really means the engine did nothing — an engine spinning its wheels
    fails loudly instead of burning ``max_steps`` and returning an
    incomplete result that looks like success.
    """

    def __init__(self, limit: int = 256):
        if limit < 1:
            raise ValueError("stall limit must be >= 1")
        self.limit = limit
        self._last = None
        self.stalled_ticks = 0

    def reset(self):
        self._last = None
        self.stalled_ticks = 0

    def note(self, sig, diagnostic: Union[str, Callable[[], str]] = ""):
        if sig == self._last:
            self.stalled_ticks += 1
            if self.stalled_ticks >= self.limit:
                detail = diagnostic() if callable(diagnostic) else diagnostic
                raise RuntimeError(
                    f"no progress for {self.stalled_ticks} consecutive busy "
                    f"ticks (livelock): {detail}"
                )
        else:
            self._last = sig
            self.stalled_ticks = 0
