"""End-cloud collaborative inference pipeline (the paper's PO-ECC, executed
for real on the block-stacked model).

The model's ``block_repeat`` blocks are split at ``split`` (chosen by the
route-aware planner, eq. 9-11): blocks [0, split) run on the "end" tier with
the hardware-aware expert mask (eq. 2-4) applied to every MoE layer; the
boundary activation is low-rank compressed (eq. 8), "transmitted" (bytes are
metered against a bandwidth model), decompressed, and blocks [split, R) plus
the LM head run on the "cloud" tier with the full expert set.

Both tiers execute in-process (this container has one device) but through
separate param subtrees and separate jitted functions, so the same code
drives a real two-host deployment by placing each tier's params on its own
jax process.

Two executors share the tier setup built by :func:`plan_tiers`:

  * ``EndCloudPipeline`` (here): one-shot full-sequence batches
    (prefill-style), the paper's fig. 5-6 measurement mode;
  * ``EndCloudServingEngine`` (``serving.stream``): continuous-batching
    token-level decode with the boundary double-buffered and replanned
    under drift — the steady-state serving mode.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, replace
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import compression as comp
from repro.core.hardware import Capability, DeviceProfile, DeviceState, capability
from repro.core.pipeline import PipelinePlan, plan_pipeline_split
from repro.core.selection import end_mask_for, validate_expert_mask
from repro.models import attention as attn_mod
from repro.models import transformer
from repro.models.model import Model
from repro.serving.common import LinkStats

__all__ = [
    "LinkStats",
    "TierPlan",
    "plan_tiers",
    "end_mask_from_state",
    "split_block_params",
    "strip_expert_weights",
    "init_tier_pages",
    "EndCloudPipeline",
]


def end_mask_from_state(
    cfg,
    end_profile: DeviceProfile,
    end_state: DeviceState,
    *,
    selection_eps: float = 1.0,
    group_priority=None,
) -> Optional[jax.Array]:
    """Hardware-aware local expert mask (eq. 2-4) for the end tier; None for
    dense models.  Single derivation shared by the initial tier planning and
    replan-time ``DeviceState`` updates.  ``group_priority`` orders the
    greedy group admit (the engines pass measured stage-1 routing
    frequencies via ``selection.group_priority_from_freq``; default natural
    order)."""
    if cfg.moe is None:
        return None
    mask_np = end_mask_for(
        end_profile,
        end_state,
        cfg.d_model,
        cfg.moe.d_ff_expert,
        cfg.moe.num_experts,
        cfg.moe.num_groups,
        gated=cfg.ffn_gated,
        eps=selection_eps,
        selection_cap=cfg.moe.local_selection_cap,
        group_priority=group_priority,
    )
    return jnp.asarray(mask_np)


def split_block_params(params: Dict, split: int) -> Tuple[Dict, Dict]:
    """Split stacked block params [R, ...] into ([0,split), [split,R)).

    The end tier owns the embedding (it sees raw tokens); the cloud tier
    owns everything else, including the final norm and LM head."""
    end_blocks = jax.tree.map(lambda l: l[:split], params["blocks"])
    cloud_blocks = jax.tree.map(lambda l: l[split:], params["blocks"])
    end = {"embed": params["embed"], "blocks": end_blocks}
    cloud = {k: v for k, v in params.items() if k != "blocks"}
    cloud["blocks"] = cloud_blocks
    return end, cloud


def strip_expert_weights(tier_params: Dict, cfg) -> Dict:
    """Pooled end tier: drop the dense per-expert weight stacks
    (``wi``/``wg``/``wo``, ``[n_blocks, E, ...]``) from a tier's block
    params — resident experts live in the slab store
    (``core.expertpool``) instead, which is the memory the paged
    expert-weight pool actually saves.  Gate, shared-expert, and codec
    params stay (they are always-on and tiny next to the expert stacks)."""
    blocks = {}
    for i, spec in enumerate(cfg.layer_pattern):
        key = f"pos{i}"
        layer = tier_params["blocks"][key]
        if spec.moe and "moe" in layer:
            layer = {
                **layer,
                "moe": {
                    k: v for k, v in layer["moe"].items()
                    if k not in ("wi", "wg", "wo")
                },
            }
        blocks[key] = layer
    return {**tier_params, "blocks": blocks}


def init_tier_pages(
    cfg, split: int, end_pages: int, cloud_pages: int, page_size: int, dtype,
    *, quantized: bool = False,
) -> Tuple[Dict, Dict]:
    """Paged KV storage for the two tiers of a block split: the end pool
    backs blocks ``[0, split)``, the cloud pool ``[split, R)``.  The pools
    may have different capacities (a fleet-shared cloud pool is sized for
    every lane's slots); a replan later moves block rows between the two
    storages via ``kvcache.resplit_paged_blocks``.  ``quantized`` makes
    both tiers int8 pools with f16 scale sidecars
    (``kvcache.init_paged_blocks``)."""
    from repro.models import kvcache

    end = kvcache.init_paged_blocks(
        cfg, split, end_pages, page_size, dtype, quantized=quantized
    )
    cloud = kvcache.init_paged_blocks(
        cfg, cfg.block_repeat - split, cloud_pages, page_size, dtype,
        quantized=quantized,
    )
    return end, cloud


def block_gflops(cfg) -> float:
    """Forward GFLOP per token per *block* — one repeat of the full layer
    pattern, the unit the split search slices at (embedding/head excluded)."""
    n = cfg.active_param_count() - 2 * cfg.vocab_size * cfg.d_model
    per_block = max(n, 1) / max(cfg.block_repeat, 1)
    return 2.0 * per_block * 1e-9


@dataclass
class TierPlan:
    """Everything the split needs beyond raw params: capabilities (eq. 3),
    the end tier's hardware-aware expert mask (eq. 2-4), the boundary codec
    (eq. 8), and the route-aware pipeline plan (eq. 9-11) together with the
    planning inputs it was computed from (so replanning re-runs the search
    with exactly the same cost model)."""

    end_cap: Capability
    cloud_cap: Capability
    end_mask: Optional[jax.Array]
    codec: Optional[Dict]
    plan: PipelinePlan
    alpha: float
    layer_gflops: Tuple[float, ...] = ()
    boundary_bytes: float = 0.0
    compression_ratio: float = 1.0

    @property
    def split(self) -> int:
        return self.plan.split_layer

    @property
    def compress(self) -> bool:
        return self.codec is not None and self.plan.compress_boundary


_DERIVE_MASK = object()  # sentinel: "derive the end mask from the state"


def plan_tiers(
    model: Model,
    *,
    end_profile: DeviceProfile,
    cloud_profile: DeviceProfile,
    end_state: Optional[DeviceState] = None,
    end_mask=_DERIVE_MASK,
    codec_params: Optional[Dict] = None,
    compression_rank: int = 0,
    alpha: float = 0.5,
    selection_eps: float = 1.0,
    force_split: Optional[int] = None,
    cloud_share: float = 1.0,
) -> TierPlan:
    """Build the shared tier context for both end-cloud executors.

    ``force_split`` pins the split point (used by parity tests and
    ablations).  ``end_mask`` overrides the eq. 2-4 derivation (the fleet
    engine passes per-device masks from ``selection.shard_masks_for_fleet``).
    ``cloud_share`` scales the cloud capability to this device's share of a
    fleet-shared cloud tier (``cloud_servers / n_devices``), so the split
    search and every subsequent replan see the fleet bottleneck.
    Measured-bandwidth feedback at replan time goes through
    ``core.pipeline.replan_pipeline(measured_gbps=...)``, not here."""
    cfg = model.cfg
    end_state = end_state or DeviceState()
    end_cap = capability(end_profile, end_state)
    cloud_cap = capability(cloud_profile, DeviceState())
    if cloud_share != 1.0:
        cloud_cap = replace(
            cloud_cap, gflop_budget=cloud_cap.gflop_budget * cloud_share
        )

    if end_mask is _DERIVE_MASK:
        end_mask = end_mask_from_state(
            cfg, end_profile, end_state, selection_eps=selection_eps
        )
    # engine boundary: an all-False mask diverges silently (dense gates
    # renormalize to uniform, pooled tiers route to the garbage slab) —
    # both executor families plan tiers through here, so both reject it
    # identically (selection.validate_expert_mask)
    validate_expert_mask(
        end_mask,
        cfg.moe.num_experts if cfg.moe is not None else None,
        where="plan_tiers(end_mask)",
    )

    # Codec (eq. 8).
    codec = codec_params
    if codec is None and compression_rank > 0:
        codec = comp.init_lowrank_1d(
            jax.random.PRNGKey(7), cfg.d_model, compression_rank
        )
    rank = codec["enc"].shape[1] if codec is not None else 0

    # Route-aware split (eq. 9-11 pipeline reading).  Both executors keep
    # the embedding on the end and the LM head on the cloud, so an
    # activation crosses the wire at every split (edge_boundary).
    boundary_bytes = float(cfg.d_model * 2)  # per token, bf16
    ratio = comp.compression_ratio(cfg.d_model, rank) if codec is not None else 1.0
    layer_gflops = (block_gflops(cfg),) * cfg.block_repeat
    plan = plan_pipeline_split(
        layer_gflops,
        boundary_bytes,
        end_cap,
        cloud_cap,
        compression_ratio=ratio,
        alpha=alpha,
        edge_boundary=True,
        pin_split=force_split,
    )
    return TierPlan(
        end_cap, cloud_cap, end_mask, codec, plan, alpha,
        layer_gflops=layer_gflops,
        boundary_bytes=boundary_bytes,
        compression_ratio=ratio,
    )


class EndCloudPipeline:
    """Runs full-sequence (prefill-style) inference across two tiers."""

    def __init__(
        self,
        model: Model,
        params: Dict,
        *,
        end_profile: DeviceProfile,
        cloud_profile: DeviceProfile,
        end_state: Optional[DeviceState] = None,
        codec_params: Optional[Dict] = None,  # 1-D low-rank codec {"enc","dec"}
        compression_rank: int = 0,
        alpha: float = 0.5,
        selection_eps: float = 1.0,
    ):
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.end_profile = end_profile
        self.cloud_profile = cloud_profile
        self.end_state = end_state or DeviceState()
        self.link = LinkStats()

        self.tiers = plan_tiers(
            model,
            end_profile=end_profile,
            cloud_profile=cloud_profile,
            end_state=self.end_state,
            codec_params=codec_params,
            compression_rank=compression_rank,
            alpha=alpha,
            selection_eps=selection_eps,
        )
        self.end_params, self.cloud_params = split_block_params(params, self.split)
        self._jit_end = jax.jit(self._end_forward)
        self._jit_cloud = jax.jit(self._cloud_forward)

    # -- everything the split derives delegates to self.tiers -----------------

    @property
    def end_cap(self) -> Capability:
        return self.tiers.end_cap

    @property
    def cloud_cap(self) -> Capability:
        return self.tiers.cloud_cap

    @property
    def end_mask(self):
        return self.tiers.end_mask

    @property
    def codec(self) -> Optional[Dict]:
        return self.tiers.codec

    @property
    def plan(self) -> PipelinePlan:
        return self.tiers.plan

    @property
    def split(self) -> int:
        return self.tiers.plan.split_layer

    # -- tier forwards ----------------------------------------------------------

    def _end_forward(self, end_params, tokens):
        cfg = self.cfg
        x = transformer.embed_inputs(end_params, cfg, tokens)
        B, S = x.shape[:2]
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        if cfg.mrope_sections is not None:
            pos = jnp.broadcast_to(pos[:, None], (B, 3, S))
        angles = attn_mod.rope_angles(
            pos, cfg.head_dim, cfg.rope_theta, cfg.mrope_sections
        )

        def block_fn(carry, block_params):
            bx = carry
            for i, spec in enumerate(cfg.layer_pattern):
                bx, _, _ = transformer.apply_layer_full(
                    block_params[f"pos{i}"], bx, spec, cfg, self.model.topo,
                    angles, causal=True, expert_mask=self.end_mask, train=False,
                )
            return bx, None

        if self.split > 0:
            x, _ = jax.lax.scan(block_fn, x, end_params["blocks"])
        if self.tiers.compress:
            x = comp.encode_1d(self.codec, x)
        return x

    def _cloud_forward(self, cloud_params, z, angles_args):
        cfg = self.cfg
        B, S = z.shape[:2]
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        if cfg.mrope_sections is not None:
            pos = jnp.broadcast_to(pos[:, None], (B, 3, S))
        angles = attn_mod.rope_angles(
            pos, cfg.head_dim, cfg.rope_theta, cfg.mrope_sections
        )
        x = comp.decode_1d(self.codec, z) if self.tiers.compress else z
        x = x.astype(jnp.dtype(cfg.dtype))

        def block_fn(carry, block_params):
            bx = carry
            for i, spec in enumerate(cfg.layer_pattern):
                bx, _, _ = transformer.apply_layer_full(
                    block_params[f"pos{i}"], bx, spec, cfg, self.model.topo,
                    angles, causal=True, expert_mask=None, train=False,
                )
            return bx, None

        if self.split < cfg.block_repeat:
            x, _ = jax.lax.scan(block_fn, x, cloud_params["blocks"])
        return transformer.lm_logits(cloud_params, cfg, x)

    # -- public ----------------------------------------------------------------

    def run_batch(self, tokens: jax.Array) -> Tuple[jax.Array, Dict[str, float]]:
        """tokens [B, S] -> (logits [B, S, V], timing/bytes metrics)."""
        t0 = time.monotonic()
        z = self._jit_end(self.end_params, tokens)
        z.block_until_ready()
        t_end = time.monotonic() - t0

        nbytes = z.size * z.dtype.itemsize
        t_comm = self.link.record_up(nbytes, self.end_cap.net_gbps)

        t1 = time.monotonic()
        logits = self._jit_cloud(self.cloud_params, z, None)
        logits.block_until_ready()
        t_cloud = time.monotonic() - t1
        return logits, {
            "t_end_s": t_end,
            "t_comm_s": t_comm,
            "t_cloud_s": t_cloud,
            "boundary_bytes": nbytes,
            "split": self.split,
            "compressed": self.tiers.compress,
        }
