"""End-cloud collaborative inference pipeline (the paper's PO-ECC, executed
for real on the block-stacked model).

The model's ``block_repeat`` blocks are split at ``split`` (chosen by the
route-aware planner, eq. 9-11): blocks [0, split) run on the "end" tier with
the hardware-aware expert mask (eq. 2-4) applied to every MoE layer; the
boundary activation is low-rank compressed (eq. 8), "transmitted" (bytes are
metered against a bandwidth model), decompressed, and blocks [split, R) plus
the LM head run on the "cloud" tier with the full expert set.

Both tiers execute in-process (this container has one device) but through
separate param subtrees and separate jitted functions, so the same code
drives a real two-host deployment by placing each tier's params on its own
jax process.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compression as comp
from repro.core.hardware import Capability, DeviceProfile, DeviceState, capability
from repro.core.pipeline import PipelinePlan, plan_pipeline_split
from repro.core.selection import end_mask_for
from repro.models import attention as attn_mod
from repro.models import transformer
from repro.models.model import Model


def split_block_params(params: Dict, split: int) -> Tuple[Dict, Dict]:
    """Split stacked block params [R, ...] into ([0,split), [split,R))."""
    end_blocks = jax.tree.map(lambda l: l[:split], params["blocks"])
    cloud_blocks = jax.tree.map(lambda l: l[split:], params["blocks"])
    end = {"embed": params["embed"], "blocks": end_blocks}
    cloud = {k: v for k, v in params.items() if k != "blocks"}
    cloud["blocks"] = cloud_blocks
    return end, cloud


@dataclass
class LinkStats:
    bytes_up: int = 0
    bytes_down: int = 0
    transfers: int = 0

    def transfer_time(self, nbytes: int, gbps: float) -> float:
        return nbytes * 8.0 / max(gbps * 1e9, 1e-9)


class EndCloudPipeline:
    """Runs full-sequence (prefill-style) inference across two tiers."""

    def __init__(
        self,
        model: Model,
        params: Dict,
        *,
        end_profile: DeviceProfile,
        cloud_profile: DeviceProfile,
        end_state: Optional[DeviceState] = None,
        codec_params: Optional[Dict] = None,  # 1-D low-rank codec {"enc","dec"}
        compression_rank: int = 0,
        alpha: float = 0.5,
        selection_eps: float = 1.0,
    ):
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.end_profile = end_profile
        self.cloud_profile = cloud_profile
        self.end_state = end_state or DeviceState()
        self.link = LinkStats()

        cfg = self.cfg
        self.end_cap = capability(end_profile, self.end_state)
        self.cloud_cap = capability(cloud_profile, DeviceState())

        # Hardware-aware local expert mask (eq. 2-4) for the end tier.
        self.end_mask = None
        if cfg.moe is not None:
            mask_np = end_mask_for(
                end_profile,
                self.end_state,
                cfg.d_model,
                cfg.moe.d_ff_expert,
                cfg.moe.num_experts,
                cfg.moe.num_groups,
                gated=cfg.ffn_gated,
                eps=selection_eps,
                selection_cap=cfg.moe.local_selection_cap,
            )
            self.end_mask = jnp.asarray(mask_np)

        # Codec (eq. 8).
        self.codec = codec_params
        if self.codec is None and compression_rank > 0:
            self.codec = comp.init_lowrank_1d(
                jax.random.PRNGKey(7), cfg.d_model, compression_rank
            )

        # Route-aware split (eq. 9-11 pipeline reading).
        per_block_gflops = self._block_gflops()
        boundary_bytes = float(cfg.d_model * 2)  # per token, bf16
        ratio = (
            comp.compression_ratio(cfg.d_model, compression_rank)
            if self.codec is not None
            else 1.0
        )
        self.plan: PipelinePlan = plan_pipeline_split(
            [per_block_gflops] * cfg.block_repeat,
            boundary_bytes,
            self.end_cap,
            self.cloud_cap,
            compression_ratio=ratio,
            alpha=alpha,
        )
        self.split = self.plan.split_layer
        self.end_params, self.cloud_params = split_block_params(params, self.split)
        self._jit_end = jax.jit(self._end_forward)
        self._jit_cloud = jax.jit(self._cloud_forward)

    # -- cost model -----------------------------------------------------------

    def _block_gflops(self) -> float:
        cfg = self.cfg
        n = cfg.active_param_count() - 2 * cfg.vocab_size * cfg.d_model
        per_layer = max(n, 1) / max(cfg.num_layers, 1)
        return 2.0 * per_layer * 1e-9  # fwd GFLOP per token per block-layer

    # -- tier forwards ----------------------------------------------------------

    def _end_forward(self, end_params, tokens):
        cfg = self.cfg
        x = transformer.embed_inputs(end_params, cfg, tokens)
        B, S = x.shape[:2]
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        if cfg.mrope_sections is not None:
            pos = jnp.broadcast_to(pos[:, None], (B, 3, S))
        angles = attn_mod.rope_angles(
            pos, cfg.head_dim, cfg.rope_theta, cfg.mrope_sections
        )

        def block_fn(carry, block_params):
            bx = carry
            for i, spec in enumerate(cfg.layer_pattern):
                bx, _, _ = transformer.apply_layer_full(
                    block_params[f"pos{i}"], bx, spec, cfg, self.model.topo,
                    angles, causal=True, expert_mask=self.end_mask, train=False,
                )
            return bx, None

        if self.split > 0:
            x, _ = jax.lax.scan(block_fn, x, end_params["blocks"])
        if self.codec is not None and self.plan.compress_boundary:
            x = comp.encode_1d(self.codec, x)
        return x

    def _cloud_forward(self, cloud_params, z, angles_args):
        cfg = self.cfg
        B, S = z.shape[:2]
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        if cfg.mrope_sections is not None:
            pos = jnp.broadcast_to(pos[:, None], (B, 3, S))
        angles = attn_mod.rope_angles(
            pos, cfg.head_dim, cfg.rope_theta, cfg.mrope_sections
        )
        x = (
            comp.decode_1d(self.codec, z)
            if self.codec is not None and self.plan.compress_boundary
            else z
        )
        x = x.astype(jnp.dtype(cfg.dtype))

        def block_fn(carry, block_params):
            bx = carry
            for i, spec in enumerate(cfg.layer_pattern):
                bx, _, _ = transformer.apply_layer_full(
                    block_params[f"pos{i}"], bx, spec, cfg, self.model.topo,
                    angles, causal=True, expert_mask=None, train=False,
                )
            return bx, None

        if self.split < cfg.block_repeat:
            x, _ = jax.lax.scan(block_fn, x, cloud_params["blocks"])
        return transformer.lm_logits(cloud_params, cfg, x)

    # -- public ----------------------------------------------------------------

    def run_batch(self, tokens: jax.Array) -> Tuple[jax.Array, Dict[str, float]]:
        """tokens [B, S] -> (logits [B, S, V], timing/bytes metrics)."""
        t0 = time.monotonic()
        z = self._jit_end(self.end_params, tokens)
        z.block_until_ready()
        t_end = time.monotonic() - t0

        nbytes = z.size * z.dtype.itemsize
        self.link.bytes_up += nbytes
        self.link.transfers += 1
        t_comm = self.link.transfer_time(nbytes, self.end_cap.net_gbps)

        t1 = time.monotonic()
        logits = self._jit_cloud(self.cloud_params, z, None)
        logits.block_until_ready()
        t_cloud = time.monotonic() - t1
        return logits, {
            "t_end_s": t_end,
            "t_comm_s": t_comm,
            "t_cloud_s": t_cloud,
            "boundary_bytes": nbytes,
            "split": self.split,
            "compressed": bool(self.codec is not None and self.plan.compress_boundary),
        }
