"""Speculative multi-token decode across the end-cloud link.

Every non-speculative decode round ships one boundary activation up the
link and gets one token back — in the link-bound regime (high RTT or thin
uplink) that round trip, not either tier's compute, caps per-request
latency.  Speculative decode amortizes it: the end tier drafts ``k``
tokens with its own cheap forward (the full stack under the resident
expert mask, against a dense per-slot draft cache), ships ONE boundary
chunk of k positions, and the cloud verifies all k in a single C=k
chunked step off the paged KV pool.  The accepted prefix commits its
lazily-mapped pages; the first rejection rolls the page tables back
(``PagePool.rollback`` — pure table surgery, no data ever moves) and the
verify logits at the rejection point emit the corrected token, so greedy
output is bit-identical to non-speculative decode by construction.

This module holds the engine-independent pieces: the greedy accept rule
(:func:`accept_greedy`), and the runtime acceptance feedback loop
(:class:`SpecState`) that tracks a per-engine acceptance EMA and adapts
the effective draft length within the planner's budget.  The plan-time
choice of k itself lives in ``core.pipeline.plan_spec_k`` (it is a
planning decision, made from the same measured bandwidth/stage times the
split search uses); the scheduling integration lives in
``serving.stream``.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import numpy as np


def accept_greedy(drafts: Sequence[int], verify_ids: Sequence[int]) -> Tuple[List[int], int]:
    """Greedy accept rule for one slot's speculative round.

    A C-position verify chunk consumed the inputs ``[x_0, y_1..y_{C-1}]``
    (the pending token plus C-1 draft tokens) at context positions
    ``L..L+C-1``, and row i's argmax ``v_i`` is the model's true next
    token after consuming the row-i input.

    ``drafts``     — the C-1 draft tokens y_1..y_{C-1} (``drafts[i]`` is
                     the input the verify chunk saw at row i+1).
    ``verify_ids`` — the C verify argmaxes v_0..v_{C-1}.

    Returns ``(committed, n_rejected_drafts)`` where ``committed`` is the
    token sequence the round emits: v_0..v_a for the longest prefix with
    ``drafts[i] == verify_ids[i]`` for all i < a.  Row 0's verify id is
    ALWAYS committed (it is the model's real next token after the
    previously-committed context — exactly what non-speculative decode
    would have produced), so every round makes progress even at zero
    acceptance.  At a rejection, v_a itself is the corrected token — the
    model's choice at the first position where the draft diverged — which
    is why greedy parity with non-speculative decode is structural, not
    statistical.
    """
    C = len(verify_ids)
    if len(drafts) != C - 1:
        raise ValueError(
            f"drafts/verify length mismatch: {len(drafts)} vs {C} - 1"
        )
    if C == 0:
        return [], 0
    a = 0
    while a < C - 1 and int(drafts[a]) == int(verify_ids[a]):
        a += 1
    committed = [int(v) for v in verify_ids[: a + 1]]
    # drafts y_1..y_{C-1}: the first a matched; the rest were wasted
    # (rejected at position a+1, or discarded past the first rejection).
    return committed, C - 1 - a


@dataclass
class SpecState:
    """Acceptance feedback for one engine's speculative decode.

    The planner (``plan_spec_k``) fixes the BUDGET ``k_plan`` from
    modeled stage/link times; this state adapts the effective draft
    length ``k_eff`` within it from the measured acceptance EMA —
    halving below ``lo`` (wasted drafts cost end-tier compute), doubling
    back above ``hi``.  ``k_eff`` never falls below 2 while the plan
    allows speculation: dropping to 1 would stop producing acceptance
    observations and freeze the EMA, so full disable (k=1, zero spec
    machinery) is exclusively the planner's decision.
    """

    k_plan: int
    ema: float = 0.3  # weight of the newest sample
    lo: float = 0.5
    hi: float = 0.8
    acceptance: Optional[float] = None
    k_eff: int = field(init=False)
    # cumulative counters (metrics surface)
    rounds: int = 0
    drafted: int = 0
    accepted: int = 0
    rollbacks: int = 0

    def __post_init__(self) -> None:
        self.k_eff = max(2, min_pow2_le(self.k_plan)) if self.k_plan > 1 else 1

    def observe_round(self, n_drafted: int, n_accepted: int, *, rolled_back: bool) -> None:
        """Record one speculative round: ``n_drafted`` draft positions
        offered beyond the guaranteed first token, ``n_accepted`` of them
        accepted, ``rolled_back`` when the round unmapped provisional
        pages (any rejection, or an abort)."""
        self.rounds += 1
        self.drafted += n_drafted
        self.accepted += n_accepted
        if rolled_back:
            self.rollbacks += 1
        if n_drafted > 0:
            obs = n_accepted / n_drafted
            if self.acceptance is None:
                self.acceptance = obs
            else:
                self.acceptance = (1 - self.ema) * self.acceptance + self.ema * obs
            self._adapt()

    def _adapt(self) -> None:
        if self.k_plan <= 1:
            return
        assert self.acceptance is not None
        if self.acceptance < self.lo and self.k_eff > 2:
            self.k_eff //= 2
        elif self.acceptance > self.hi and self.k_eff * 2 <= min_pow2_le(self.k_plan):
            self.k_eff *= 2

    @property
    def acceptance_rate(self) -> float:
        """Lifetime acceptance over drafted positions (0.0 before any)."""
        return self.accepted / self.drafted if self.drafted else 0.0

    def metrics(self) -> dict:
        return {
            "spec_rounds": self.rounds,
            "spec_drafted": self.drafted,
            "spec_accepted": self.accepted,
            "spec_acceptance_rate": round(self.acceptance_rate, 4),
            "spec_rollbacks": self.rollbacks,
        }


def min_pow2_le(k: int) -> int:
    """Largest power of two <= k (k >= 1)."""
    if k < 1:
        raise ValueError(f"k={k} < 1")
    p = 1
    while p * 2 <= k:
        p *= 2
    return p


def rollback_entries(
    new_entries: Sequence[int],
    *,
    base_len: int,
    n_commit: int,
    page_size: int,
    pages_per_slot: int,
) -> List[int]:
    """Which of a round's provisionally-mapped page entries to roll back.

    ``new_entries`` came from ``PagePool.map_tokens(slot, base_len,
    base_len + n_valid)`` before the verify; after ``n_commit`` tokens
    committed (1 <= n_commit <= n_valid) the entries covering positions
    ``[base_len, base_len + n_commit)`` must SURVIVE — they hold accepted
    KV — and the rest unmap.  Ring arithmetic mirrors ``map_tokens``."""
    if n_commit <= 0:
        keep: set = set()
    else:
        keep = {
            (pi % pages_per_slot)
            for pi in range(
                base_len // page_size,
                (base_len + n_commit - 1) // page_size + 1,
            )
        }
    return [e for e in new_entries if e not in keep]


def batched_accept(
    drafts: np.ndarray, verify_ids: np.ndarray, n_valid: np.ndarray
) -> Tuple[List[List[int]], np.ndarray]:
    """Vector form of :func:`accept_greedy` over a group.

    ``drafts``     [B, >=k-1] — row b's draft tokens y_1.. (row b's chunk
                   inputs were ``[x_0, drafts[b, :k-1]]``; a draft scan
                   may produce extra trailing drafts — only the first
                   ``n_valid[b] - 1`` participate).
    ``verify_ids`` [B, k] — per-position verify argmaxes.
    ``n_valid``    [B]    — rows only verified their first ``n_valid[b]``
                   positions (per-row cap near max_new_tokens, or 1 for
                   rows whose draft cache was stale).

    Returns ``(committed_per_row, n_rejected_per_row)``; inactive rows
    (``n_valid`` 0) commit nothing.
    """
    B = verify_ids.shape[0]
    committed: List[List[int]] = []
    n_rejected = np.zeros((B,), np.int64)
    for b in range(B):
        nv = int(n_valid[b])
        if nv <= 0:
            committed.append([])
            continue
        toks, rej = accept_greedy(
            [int(t) for t in drafts[b, : nv - 1]],
            [int(t) for t in verify_ids[b, :nv]],
        )
        committed.append(toks)
        n_rejected[b] = rej
    return committed, n_rejected
