from repro.serving.common import LinkStats, Request
from repro.serving.endcloud import EndCloudPipeline
from repro.serving.engine import ServingEngine
from repro.serving.stream import EndCloudServingEngine

__all__ = [
    "Request",
    "LinkStats",
    "ServingEngine",
    "EndCloudPipeline",
    "EndCloudServingEngine",
]
