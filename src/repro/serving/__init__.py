from repro.serving.common import LinkStats, Request, StageTimeline
from repro.serving.endcloud import EndCloudPipeline
from repro.serving.engine import ServingEngine
from repro.serving.fleet import FleetServingEngine
from repro.serving.stream import EndCloudServingEngine

__all__ = [
    "Request",
    "LinkStats",
    "StageTimeline",
    "ServingEngine",
    "EndCloudPipeline",
    "EndCloudServingEngine",
    "FleetServingEngine",
]
