from repro.serving.common import LinkStats, Request, StageTimeline, VirtualClock
from repro.serving.endcloud import EndCloudPipeline
from repro.serving.engine import ServingEngine
from repro.serving.faults import (
    ChaosInjector,
    FaultEvent,
    FaultSchedule,
    HealthMonitor,
    StallGuard,
)
from repro.serving.fleet import FleetServingEngine
from repro.serving.loadgen import (
    WorkloadClass,
    build_schedule,
    bursty_arrivals,
    drive,
    poisson_arrivals,
    summarize,
)
from repro.serving.stream import EndCloudServingEngine

__all__ = [
    "Request",
    "LinkStats",
    "StageTimeline",
    "VirtualClock",
    "ServingEngine",
    "EndCloudPipeline",
    "EndCloudServingEngine",
    "FleetServingEngine",
    "FaultEvent",
    "FaultSchedule",
    "ChaosInjector",
    "HealthMonitor",
    "StallGuard",
    "WorkloadClass",
    "poisson_arrivals",
    "bursty_arrivals",
    "build_schedule",
    "drive",
    "summarize",
]
