from repro.serving.engine import Request, ServingEngine
from repro.serving.endcloud import EndCloudPipeline

__all__ = ["Request", "ServingEngine", "EndCloudPipeline"]
