"""Batched serving engine with continuous batching.

Slot-based: a fixed decode batch of ``max_batch`` slots; finished requests
free their slot and waiting requests are prefilled into it (their KV
written into the slot's ring-buffer range).  Per-slot lengths come straight
from the cache's ``lengths`` vector, so slots at different positions decode
together — the standard continuous-batching pattern, expressed with one
jitted decode step over the whole cache.

Single-slot prefill keeps the implementation simple (prefill batch = 1 via
padding to the slot's prompt bucket).  Slot admission/harvesting lives in
``serving.common.SlotEngineBase``, shared with the streaming end-cloud
engine (``serving.stream``).
"""

from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import kvcache
from repro.models.model import Model
from repro.serving.common import Request, SlotEngineBase

__all__ = ["Request", "ServingEngine"]


class ServingEngine(SlotEngineBase):
    def __init__(
        self,
        model: Model,
        params,
        *,
        max_batch: int = 8,
        max_len: int = 512,
        expert_mask=None,
        clock: Optional[Callable[[], float]] = None,
    ):
        super().__init__(max_batch, clock, max_len=max_len)
        self.model = model
        self.params = params
        self.expert_mask = expert_mask

        self.cache = kvcache.init_cache(
            model.cfg, max_batch, max_len, jnp.dtype(model.cfg.dtype)
        )

        self._decode = jax.jit(
            lambda p, t, c: model.decode_step(p, t, c, expert_mask=expert_mask)
        )
        self._prefill_one = jax.jit(
            lambda p, b: model.prefill(
                p, b, max_len=max_len, expert_mask=expert_mask
            ),
        )

    # -- request lifecycle ---------------------------------------------------

    def _prefill_into_slot(self, slot: int, req: Request):
        tokens = jnp.asarray(req.prompt, jnp.int32)[None, :]
        logits, pcache = self._prefill_one(self.params, {"tokens": tokens})
        return int(jnp.argmax(logits[0])), pcache

    def _install_slot(self, slot: int, pcache):
        # copy the single-request cache into this slot of the batch cache
        self.cache = kvcache.install_slot(self.cache, slot, pcache)

    # -- stepping -------------------------------------------------------------

    def step(self):
        """One engine iteration: admit waiting requests, then one decode step
        for all active slots."""
        self._admit()
        if not self._active.any():
            return 0
        tokens = jnp.asarray(self._next_token)
        logits, self.cache = self._decode(self.params, tokens, self.cache)
        next_ids = np.asarray(jnp.argmax(logits, -1))
        return self._harvest(next_ids)
