"""Batched serving engine with continuous batching.

Slot-based: a fixed decode batch of ``max_batch`` slots; finished requests
free their slot and waiting requests are prefilled into it (their KV
written into the slot's ring-buffer range).  Per-slot lengths come straight
from the cache's ``lengths`` vector, so slots at different positions decode
together — the standard continuous-batching pattern, expressed with one
jitted decode step over the whole cache.

Single-slot prefill keeps the implementation simple (prefill batch = 1 via
padding to the slot's prompt bucket); the end-cloud pipeline wraps this
engine per tier.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model


@dataclass
class Request:
    request_id: int
    prompt: np.ndarray  # [S] int32
    max_new_tokens: int = 16
    eos_id: int = -1  # -1 = never
    # filled by the engine
    generated: List[int] = field(default_factory=list)
    submit_time: float = 0.0
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None

    @property
    def done(self) -> bool:
        return self.finish_time is not None


class ServingEngine:
    def __init__(
        self,
        model: Model,
        params,
        *,
        max_batch: int = 8,
        max_len: int = 512,
        expert_mask=None,
        clock: Optional[Callable[[], float]] = None,
    ):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.expert_mask = expert_mask
        import time as _time

        self.clock = clock or _time.monotonic

        from repro.models.kvcache import init_cache

        self.cache = init_cache(
            model.cfg, max_batch, max_len, jnp.dtype(model.cfg.dtype)
        )
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.waiting: List[Request] = []
        self.finished: List[Request] = []
        self._next_token = np.zeros((max_batch, 1), np.int32)
        self._active = np.zeros((max_batch,), bool)

        self._decode = jax.jit(
            lambda p, t, c: model.decode_step(p, t, c, expert_mask=expert_mask)
        )
        self._prefill_one = jax.jit(
            lambda p, b: model.prefill(
                p, b, max_len=max_len, expert_mask=expert_mask
            ),
        )

    # -- request lifecycle ---------------------------------------------------

    def submit(self, req: Request):
        req.submit_time = self.clock()
        self.waiting.append(req)

    def _admit(self):
        """Prefill waiting requests into free slots."""
        for slot in range(self.max_batch):
            if self.slots[slot] is not None or not self.waiting:
                continue
            req = self.waiting.pop(0)
            tokens = jnp.asarray(req.prompt, jnp.int32)[None, :]
            logits, pcache = self._prefill_one(self.params, {"tokens": tokens})
            tok = int(jnp.argmax(logits[0]))
            req.generated.append(tok)
            if req.first_token_time is None:
                req.first_token_time = self.clock()
            if tok == req.eos_id or len(req.generated) >= req.max_new_tokens:
                req.finish_time = self.clock()
                self.finished.append(req)
                continue
            # copy the single-request cache into this slot of the batch cache
            self._install_slot(slot, pcache)
            self.slots[slot] = req
            self._next_token[slot, 0] = tok
            self._active[slot] = True

    def _install_slot(self, slot: int, pcache: Dict):
        def copy_leaf(batch_leaf, one_leaf):
            # block-cache leaves are [R, B, ...] (batch at dim 1)
            pad = batch_leaf.shape[2] - one_leaf.shape[2] if batch_leaf.ndim > 2 else 0
            src = one_leaf
            if pad > 0:
                width = [(0, 0)] * src.ndim
                width[2] = (0, pad)
                src = jnp.pad(src, width)
            elif pad < 0:
                src = jax.lax.slice_in_dim(src, 0, batch_leaf.shape[2], axis=2)
            return batch_leaf.at[:, slot].set(src[:, 0])

        self.cache["blocks"] = jax.tree.map(
            copy_leaf, self.cache["blocks"], pcache["blocks"]
        )
        self.cache["lengths"] = self.cache["lengths"].at[slot].set(
            pcache["lengths"][0]
        )

    # -- stepping -------------------------------------------------------------

    def step(self):
        """One engine iteration: admit waiting requests, then one decode step
        for all active slots."""
        self._admit()
        if not self._active.any():
            return 0
        tokens = jnp.asarray(self._next_token)
        logits, self.cache = self._decode(self.params, tokens, self.cache)
        next_ids = np.asarray(jnp.argmax(logits, -1))
        n_emitted = 0
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            tok = int(next_ids[slot])
            req.generated.append(tok)
            n_emitted += 1
            self._next_token[slot, 0] = tok
            hit_eos = tok == req.eos_id
            # +? first token came from prefill; budget counts generated only
            if hit_eos or len(req.generated) >= req.max_new_tokens:
                req.finish_time = self.clock()
                self.finished.append(req)
                self.slots[slot] = None
                self._active[slot] = False
        return n_emitted

    def run(self, max_steps: int = 10_000):
        """Run until all submitted requests finish."""
        for _ in range(max_steps):
            if not self.waiting and not self._active.any():
                break
            self.step()
        return self.finished
