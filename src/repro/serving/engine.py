"""Batched serving engine with continuous batching over a paged KV cache.

Slot-based: a fixed decode batch of ``max_batch`` slots; finished requests
free their slot and waiting requests are prefilled into it.  For
attention-only layer patterns the engine is *paged*: all slots share one
:class:`~repro.models.kvcache.PagePool` of fixed-size KV pages, each slot
owns a bounded page list (ring semantics at page granularity), admission is
gated on page availability (``pages_needed`` reserved up front, mapped
lazily), and prompts are prefilled in fixed-size chunks — one compiled
trace per chunk shape, never one per prompt length.  A skewed batch (one
long prompt among short ones) therefore allocates only the pages it
touches instead of ``max_batch × max_len`` dense rings.

Hybrid patterns (SSM, cross-attention) fall back to the original dense
ring-buffer path — their recurrent prefill state cannot stream through
fixed-shape chunks.

Slot admission/harvesting lives in ``serving.common.SlotEngineBase``,
shared with the streaming end-cloud engine (``serving.stream``).
"""

from __future__ import annotations

from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.selection import validate_expert_mask
from repro.models import kvcache
from repro.models.model import Model
from repro.serving.common import Request, SlotEngineBase, TraceCounter

__all__ = ["Request", "ServingEngine"]


class ServingEngine(SlotEngineBase):
    def __init__(
        self,
        model: Model,
        params,
        *,
        max_batch: int = 8,
        max_len: int = 512,
        expert_mask=None,
        clock: Optional[Callable[[], float]] = None,
        page_size: int = 16,
        kv_pages: Optional[int] = None,
        prefill_chunk: int = 32,
        admission: str = "priority",
    ):
        super().__init__(max_batch, clock, max_len=max_len, admission=admission)
        self.model = model
        self.params = params
        # same boundary check as plan_tiers: a mask selecting no experts
        # would silently renormalize the gate to uniform weights
        validate_expert_mask(
            expert_mask,
            model.cfg.moe.num_experts if model.cfg.moe is not None else None,
            where="ServingEngine(expert_mask)",
        )
        self.expert_mask = expert_mask
        self.paged = kvcache.pattern_is_pageable(model.cfg)
        self._traces: Dict[str, set] = {}

        if self.paged:
            cfg = model.cfg
            self.page_size = page_size
            self.pages_per_slot, ring = kvcache.page_geometry(
                cfg, max_len, page_size, chunk_headroom=prefill_chunk
            )
            if prefill_chunk > ring:
                raise ValueError(
                    f"prefill_chunk={prefill_chunk} exceeds the ring "
                    f"capacity {ring} (a chunk must fit the page list)"
                )
            self.prefill_chunk = prefill_chunk
            self.pool = kvcache.PagePool(
                kv_pages or max_batch * self.pages_per_slot,
                page_size, self.pages_per_slot, n_slots=max_batch,
            )
            self.pages = kvcache.init_paged_blocks(
                cfg, cfg.block_repeat, self.pool.num_pages, page_size,
                jnp.dtype(cfg.dtype),
            )
            self._slot_len = np.zeros((max_batch,), np.int64)
            self._decode = TraceCounter(
                jax.jit(
                    lambda p, t, pg, tab, ln: model.decode_step_paged(
                        p, t, pg, tab, ln,
                        page_size=page_size, expert_mask=expert_mask,
                    )
                ),
                self._traces.setdefault("decode", set()),
            )
            self._prefill_chunk_fn = TraceCounter(
                jax.jit(
                    lambda p, t, pg, tab, s, v: model.prefill_chunk_step(
                        p, t, pg, tab, s, v,
                        page_size=page_size, expert_mask=expert_mask,
                    )
                ),
                self._traces.setdefault("prefill_chunk", set()),
            )
        else:
            self.cache = kvcache.init_cache(
                model.cfg, max_batch, max_len, jnp.dtype(model.cfg.dtype)
            )
            self._decode = jax.jit(
                lambda p, t, c: model.decode_step(p, t, c, expert_mask=expert_mask)
            )
            self._prefill_one = jax.jit(
                lambda p, b: model.prefill(
                    p, b, max_len=max_len, expert_mask=expert_mask
                ),
            )

    # -- request lifecycle ---------------------------------------------------

    def _pages_for(self, req: Request) -> int:
        return kvcache.pages_needed(
            len(req.prompt) + req.max_new_tokens,
            self.page_size, self.pages_per_slot,
        )

    def _page_capacity(self):
        return self.pool.num_pages if self.paged else None

    def _admittable(self, slot: int, req: Request) -> bool:
        # page-aware admission: a free slot alone is not enough — the
        # request's worst-case page count must be reservable now, because
        # there is no preemption once it starts decoding
        if not self.paged:
            return True
        return self.pool.can_reserve(self._pages_for(req))

    def _prefill_into_slot(self, slot: int, req: Request):
        if not self.paged:
            tokens = jnp.asarray(req.prompt, jnp.int32)[None, :]
            logits, pcache = self._prefill_one(self.params, {"tokens": tokens})
            return int(jnp.argmax(logits[0])), pcache
        # chunked prefill straight into the slot's pages (no install copy)
        S = len(req.prompt)
        C = self.prefill_chunk
        self.pool.reserve(slot, self._pages_for(req))
        logits = None
        for p0 in range(0, S, C):
            v = min(C, S - p0)
            self.pool.map_range(slot, p0, p0 + v)
            chunk = np.zeros((C,), np.int32)
            chunk[:v] = req.prompt[p0 : p0 + v]
            logits, self.pages = self._prefill_chunk_fn(
                self.params, jnp.asarray(chunk)[None],
                self.pages, self.pool.device_rows([slot]),
                jnp.asarray([p0], jnp.int32), jnp.asarray([v], jnp.int32),
            )
        return int(jnp.argmax(logits[0])), S

    def _install_slot(self, slot: int, payload):
        if not self.paged:
            self.cache = kvcache.install_slot(self.cache, slot, payload)
        else:
            self._slot_len[slot] = payload  # pages already hold the prompt

    def _release_slot(self, slot: int):
        if self.paged:
            self.pool.free(slot)
            self._slot_len[slot] = 0

    # -- stepping -------------------------------------------------------------

    def step(self):
        """One engine iteration: admit waiting requests, then one decode step
        for all active slots."""
        self._admit()
        if not self._active.any():
            return 0
        tokens = jnp.asarray(self._next_token)
        if not self.paged:
            logits, self.cache = self._decode(self.params, tokens, self.cache)
        else:
            for slot in range(self.max_batch):
                if self._active[slot]:
                    self.pool.append(slot, int(self._slot_len[slot]))
            table = self.pool.device_rows(
                range(self.max_batch), active=self._active
            )
            lengths = jnp.asarray(self._slot_len, jnp.int32)
            logits, self.pages = self._decode(
                self.params, tokens, self.pages, table, lengths
            )
            self._slot_len[self._active] += 1
        next_ids = np.asarray(jnp.argmax(logits, -1))
        return self._harvest(next_ids)

    # -- introspection --------------------------------------------------------

    def stage_trace_counts(self) -> Dict[str, int]:
        """Distinct compiled-trace signatures per stage function (bounded by
        chunk/group shapes, not by distinct prompt lengths)."""
        return {k: len(v) for k, v in self._traces.items()}

    def attn_bytes_step(self) -> Dict[str, int]:
        """KV bytes the attention sweep moves from HBM per decode step,
        across all layers, at the current occupancy.  The fused paged path
        reads only the *mapped* pages; the dense-gather path it replaced
        materialized and swept the full ``max_batch x ring`` view every
        step (counted here as one sweep read — the gather's extra HBM
        write of the same bytes is not charged, so the comparison is
        conservative).  Dense (SSM / cross-attn) engines have no paged
        sweep: both figures read zero."""
        if not self.paged:
            return {"attn_bytes_paged_step": 0, "attn_bytes_dense_step": 0}
        page_bytes = kvcache.paged_block_bytes(self.pages)
        return {
            "attn_bytes_paged_step": self.pool.pages_in_use * page_bytes,
            "attn_bytes_dense_step": (
                self.max_batch * self.pages_per_slot * page_bytes
            ),
        }

    def metrics(self) -> Dict[str, float]:
        m: Dict[str, float] = {
            "requests_finished": len(self.finished),
            "paged": self.paged,
        }
        if self.paged:
            page_bytes = kvcache.paged_block_bytes(self.pages)
            m.update(
                kv_pages_in_use=self.pool.pages_in_use,
                kv_pages_capacity=self.pool.num_pages,
                kv_utilization=self.pool.utilization,
                kv_bytes_peak=self.pool.peak_in_use * page_bytes,
                kv_bytes_dense_equiv=(
                    self.max_batch * self.pages_per_slot * page_bytes
                ),
                **self.attn_bytes_step(),
            )
        return m
