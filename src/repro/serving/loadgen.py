"""Seeded load generator + modeled-clock driver for the serving engines.

The MLPerf-style harness the scaling claims are measured with: a seeded
arrival process (Poisson or bursty/Markov-modulated), a mixed workload of
SLO classes (prompt/output-length distributions + priority + latency
targets), and a driver that releases requests into an engine as modeled
time passes their arrival stamps.  Everything is deterministic given the
seed: identical seeds reproduce identical arrival traces, identical token
streams (greedy decode on a deterministic schedule), and therefore
identical percentile metrics.

The driver runs on the engine's :class:`~repro.serving.common.VirtualClock`
(``timing="modeled"`` engines recommended): per-request TTFT/TPOT are
stamped on the same ``StageTimeline`` axis the schedule is computed on, so
the reported p50/p90/p99 and sustained tok/s are properties of the modeled
deployment, not of this host's wall clock.

Works against any slot engine exposing ``submit / step / busy / timeline /
clock`` — ``EndCloudServingEngine`` and ``FleetServingEngine`` both do.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.common import Request, VirtualClock

__all__ = [
    "WorkloadClass",
    "INTERACTIVE",
    "BATCH",
    "poisson_arrivals",
    "bursty_arrivals",
    "build_schedule",
    "drive",
    "summarize",
]


# ---------------------------------------------------------------------------
# Workload classes
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class WorkloadClass:
    """One SLO class of a mixed workload.

    ``weight`` is the class's share of arrivals; prompt/output lengths are
    drawn uniformly from the inclusive ranges.  ``priority`` is the
    admission class (0 admits first); the SLO targets ride on each
    generated :class:`Request` for scoring."""

    name: str
    priority: int
    weight: float
    prompt_len: Tuple[int, int]
    new_tokens: Tuple[int, int]
    ttft_slo_s: Optional[float] = None
    tpot_slo_s: Optional[float] = None


# The default mix: mostly short interactive traffic, a tail of long
# low-priority batch requests — the head-of-line shape priority admission
# and preemption exist to survive.
INTERACTIVE = WorkloadClass(
    "interactive", priority=0, weight=0.8,
    prompt_len=(4, 16), new_tokens=(2, 6),
)
BATCH = WorkloadClass(
    "batch", priority=2, weight=0.2,
    prompt_len=(40, 90), new_tokens=(8, 24),
)


# ---------------------------------------------------------------------------
# Arrival processes
# ---------------------------------------------------------------------------


def poisson_arrivals(n: int, rate_rps: float, seed: int,
                     start_s: float = 0.0) -> np.ndarray:
    """``n`` arrival times of a homogeneous Poisson process at ``rate_rps``
    requests/second (i.i.d. exponential inter-arrivals), sorted ascending.
    Deterministic given the seed."""
    if rate_rps <= 0:
        raise ValueError(f"rate_rps={rate_rps}")
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_rps, size=n)
    return start_s + np.cumsum(gaps)

def bursty_arrivals(n: int, rate_rps: float, seed: int,
                    burst_factor: float = 8.0, cycle_s: float = 4.0,
                    start_s: float = 0.0) -> np.ndarray:
    """``n`` arrivals of a Markov-modulated (ON/OFF) process: exponential
    ON periods at ``rate_rps * burst_factor``, exponential OFF periods with
    no arrivals, duty cycle ``1/burst_factor`` — so the long-run mean rate
    is ``rate_rps`` but the traffic lands in bursts.  ``cycle_s`` is the
    mean ON+OFF period length.  Deterministic given the seed."""
    if rate_rps <= 0 or burst_factor < 1.0:
        raise ValueError(f"rate_rps={rate_rps}, burst_factor={burst_factor}")
    rng = np.random.default_rng(seed)
    mean_on = cycle_s / burst_factor
    mean_off = cycle_s - mean_on
    on_rate = rate_rps * burst_factor
    out: List[float] = []
    t = start_s
    while len(out) < n:
        on_end = t + rng.exponential(mean_on)
        tt = t + rng.exponential(1.0 / on_rate)
        while tt < on_end and len(out) < n:
            out.append(tt)
            tt += rng.exponential(1.0 / on_rate)
        t = on_end + (rng.exponential(mean_off) if mean_off > 0 else 0.0)
    return np.asarray(out)


# ---------------------------------------------------------------------------
# Schedule synthesis
# ---------------------------------------------------------------------------


def build_schedule(
    arrivals: np.ndarray,
    classes: Sequence[WorkloadClass],
    seed: int,
    vocab: int = 500,
) -> List[Tuple[float, Request]]:
    """Attach one synthetic request per arrival time: class drawn by
    weight, prompt tokens and output budget drawn from the class's ranges —
    all from one seeded stream, so identical seeds reproduce identical
    schedules token-for-token.  Returns ``[(arrival_s, Request), ...]``
    with ``request_id`` in arrival order."""
    if not classes:
        raise ValueError("need at least one workload class")
    rng = np.random.default_rng(seed)
    w = np.asarray([c.weight for c in classes], np.float64)
    if (w <= 0).any():
        raise ValueError("class weights must be positive")
    w = w / w.sum()
    idx = rng.choice(len(classes), size=len(arrivals), p=w)
    schedule: List[Tuple[float, Request]] = []
    for i, (t, ci) in enumerate(zip(arrivals, idx)):
        c = classes[int(ci)]
        s = int(rng.integers(c.prompt_len[0], c.prompt_len[1] + 1))
        m = int(rng.integers(c.new_tokens[0], c.new_tokens[1] + 1))
        prompt = rng.integers(0, vocab, size=s).astype(np.int32)
        schedule.append(
            (
                float(t),
                Request(
                    request_id=i,
                    prompt=prompt,
                    max_new_tokens=m,
                    priority=c.priority,
                    ttft_slo_s=c.ttft_slo_s,
                    tpot_slo_s=c.tpot_slo_s,
                ),
            )
        )
    return schedule


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------


def drive(engine, schedule: Sequence[Tuple[float, Request]],
          max_ticks: int = 1_000_000) -> List[Request]:
    """Replay a schedule through an engine on its virtual clock.

    Each tick: submit every request whose arrival time has passed (batched
    submission — a burst lands in one tick), advance the engine one step,
    then move the clock to the timeline makespan.  When the engine drains
    before the next arrival, the clock jumps straight to it (idle modeled
    time costs nothing to simulate).  Returns the schedule's requests.

    A stall guard (``serving.faults.StallGuard``) watches the engine's
    progress signature *and* the clock: modeled time advancing counts as
    progress (a slowly-draining degraded lane is not a livelock), but a
    frozen clock with a wedged engine raises loudly with the engine's
    queue/slot diagnostic instead of spinning to ``max_ticks``.
    """
    from repro.serving.faults import StallGuard

    clock = engine.clock
    if not isinstance(clock, VirtualClock):
        raise ValueError(
            "drive() needs an engine built with clock=VirtualClock() — "
            "wall-clock request stamps cannot meet a modeled schedule"
        )
    schedule = sorted(schedule, key=lambda p: p[0])
    guard = StallGuard(getattr(engine, "stall_limit", 500))
    i = 0
    for _tick in range(max_ticks):
        if i >= len(schedule) and not engine.busy():
            break
        if not engine.busy() and i < len(schedule):
            clock.advance_to(schedule[i][0])
        while i < len(schedule) and schedule[i][0] <= clock.now:
            t, req = schedule[i]
            engine.submit(req)
            req.submit_time = t  # exact arrival, not the release tick
            i += 1
        engine.step()
        clock.advance_to(engine.timeline.makespan_s)
        guard.note(
            (i, clock.now) + engine._progress_sig(), engine.stall_diagnostic
        )
    else:
        raise RuntimeError(f"drive() hit max_ticks={max_ticks}")
    return [req for _, req in schedule]


# ---------------------------------------------------------------------------
# Reporting
# ---------------------------------------------------------------------------


def _pct(xs: List[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs, np.float64), q)) if xs else 0.0


def summarize(requests: Sequence[Request], warmup_s: float = 0.0,
              priority: Optional[int] = None) -> Dict[str, float]:
    """Latency/throughput report over a driven request set.

    ``warmup_s`` drops requests submitted before that modeled time from
    every statistic (the warmup phase: queues filling, cold caches).
    ``priority`` restricts the report to one SLO class.  Keys:
    ``ttft_p50/p90/p99``, ``tpot_p50/p90/p99`` (seconds),
    ``sustained_tok_s`` (finished tokens over the measured span),
    ``preemptions``, ``migrations``, ``dropped`` (submitted but never
    finished), ``n``,
    and SLO violation counts against each request's own targets."""
    sel = [
        r for r in requests
        if r.submit_time >= warmup_s
        and (priority is None or r.priority == priority)
    ]
    done = [r for r in sel if r.done]
    ttft = [r.ttft_s for r in done if r.ttft_s is not None]
    tpot = [r.tpot_s for r in done if r.tpot_s is not None]
    tokens = sum(len(r.generated) for r in done)
    if done:
        t0 = max(warmup_s, min(r.submit_time for r in done))
        span = max(r.finish_time for r in done) - t0
    else:
        span = 0.0
    return {
        "n": len(sel),
        "finished": len(done),
        "dropped": len(sel) - len(done),
        "preemptions": sum(r.n_preemptions for r in sel),
        "migrations": sum(r.n_migrations for r in sel),
        "ttft_p50": _pct(ttft, 50), "ttft_p90": _pct(ttft, 90),
        "ttft_p99": _pct(ttft, 99),
        "tpot_p50": _pct(tpot, 50), "tpot_p90": _pct(tpot, 90),
        "tpot_p99": _pct(tpot, 99),
        "sustained_tok_s": tokens / span if span > 0 else 0.0,
        "slo_ttft_violations": sum(
            1 for r in done
            if r.ttft_slo_s is not None and r.ttft_s is not None
            and r.ttft_s > r.ttft_slo_s
        ),
        "slo_tpot_violations": sum(
            1 for r in done
            if r.tpot_slo_s is not None and r.tpot_s is not None
            and r.tpot_s > r.tpot_slo_s
        ),
    }
