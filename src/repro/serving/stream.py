"""Streaming end-cloud decode engine (tentpole of the PO-ECC reproduction).

``EndCloudServingEngine`` is the continuous-batching ``ServingEngine``
re-expressed as a *two-tier token pipeline*: each decode step is split at
the route-aware plan's block boundary (eq. 9-11) — blocks ``[0, split)`` and
the embedding run on the end tier (with the hardware-aware expert mask,
eq. 2-4), the boundary activation is low-rank compressed (eq. 8) and metered
through ``LinkStats``, and blocks ``[split, R)`` plus the LM head run on the
cloud tier.

**Paged KV.**  Each tier owns a shared :class:`~repro.models.kvcache.PagePool`
of fixed-size KV pages; every slot holds a bounded page table (ring
semantics at page granularity), so memory scales with the tokens actually
cached, not ``max_batch × max_len``.  The pools' host-side allocators run
between ticks; the jitted stage functions take the device page table as a
runtime argument, so there is exactly one compiled decode trace per group
shape and one prefill trace per chunk shape — never one per prompt length.
In a fleet, lanes keep private end pools while sharing one cloud pool
(fleet-wide cloud-memory admission).

**Chunked prefill.**  Admission is a pipeline stage, not a stop-the-world
event: an admitted prompt is cut into fixed-size chunks that stream through
the same end -> link -> cloud stage functions and ``StageTimeline``
resources as decode, one chunk per engine tick, writing straight into the
slot's pages (no install copy).  In-flight decode groups keep stepping
while a long prompt prefills; the finished request activates its slot at
the group's next drained tick.

**Pipelining.**  The decode batch is partitioned into ``n_groups``
equal-sized interleaved micro-batch groups (the batch is padded up to a
multiple of the group size so one trace serves every group), each with its
own boundary buffer (the double buffer).  A group alternates between two
phases: its end-step writes the boundary buffer, and — one engine tick
later — the cloud-step drains it and feeds the next token back.  While
group A's boundary is in flight / being decoded on the cloud, group B
occupies the end tier, so in steady state every stage is busy every tick
and the per-step time approaches ``max(t_end, t_comm, t_cloud)``
(``PipelinePlan.est_step_time_s``) instead of the serial sum.  Stage
compute times are *measured* on this host, link times are modeled from the
metered bytes and the (possibly drifting) bandwidth, and the overlap is
accounted by ``StageTimeline`` — the same resource-occupancy model as
``sim.simulator``, so the schedule is exactly what a two-host deployment
would realize with these stage times.

**Paged expert weights.**  For MoE models the end tier no longer holds the
full ``[E, d, f]`` expert stacks: expert weights live in a fixed-capacity
pool of per-layer slabs (:class:`~repro.core.expertpool.ExpertSlabPool`,
the expert analogue of the KV ``PagePool``), the eq. 2-4 mask is the
*target set*, and a route-frequency/LRU policy decides which experts are
resident.  The jitted end stages take the target mask and the per-layer
resident tables as *runtime* arguments and route through
``core.moe.moe_resident`` (effective mask = ``target AND resident``,
computed in-trace), so residency changes never retrace; expert compute
and HBM traffic scale with residents, not ``E``.  Slab prefetches are
booked on the same ``StageTimeline`` link resource as boundary traffic —
overlapped with decode ticks — and the swapped-in tables/mask apply only
at replan safe points, so greedy tokens stay bit-identical across the
transfer window; evictions (budget shrinks, mask changes) free slabs that
no applied table references.  Group priority for the eq. 4 greedy admit
comes from *measured* stage-1 gate statistics
(``selection.group_priority_from_freq`` over an EMA of ``group_frac``),
not natural order.

**SLO-aware admission and preemption.**  Requests carry a priority class
(``Request.priority``, 0 = interactive) and optional TTFT/TPOT SLO
targets.  Admission scans the queue in (priority, submission-seq) order —
a stable sort, so equal-priority traffic keeps FIFO fairness while a
page-hungry low-priority head can no longer starve interactive requests —
and the order head blocks its order (``SlotEngineBase._admission_order``).
When the head outranks running work and still cannot be admitted,
preemption evicts the youngest strictly-lower-priority victim at the
drained safe point (every group "ready", the same point replans apply):
an in-flight prefill job is simply cancelled and re-queued, a decoding
slot has its mapped KV pages spilled off both tier pools via the page
tables (``PagePool.spill_slot``) and restored byte-exact on re-admission
(``restore_slot``) — the resumed token stream is bit-identical to an
uninterrupted run, even across a replan in between, because the spill is
stored merged across tiers and re-split at the restore-time boundary.
Handing the engine a ``VirtualClock`` stamps request lifecycle times
(submit / first token / finish) on the modeled ``StageTimeline`` axis, so
the load harness (``serving.loadgen``) measures TTFT/TPOT on the same
deterministic clock the schedule is computed on.

**Replanning.**  Link measurements arrive through ``observe_bandwidth``
and device drift through ``update_device_state``, which also re-derives the
end tier's expert mask from the new state vector (eq. 2-4).  Either trigger
re-runs the split search against measured conditions
(``core.pipeline.replan_pipeline``).  A changed plan or mask is applied at
the next safe point — all boundary buffers drained, both tiers at equal
``lengths`` — by re-splitting params at the new block boundary and moving
the affected blocks' *pages* between the tier pools
(``kvcache.resplit_paged_blocks``: a table-aware row permutation, since the
two pools may map the same (slot, entry) set at different physical rows),
then rebuilding the stage functions.  In-flight generations continue
bit-exactly across a pure re-split (the page move is a relayout; a mask
change intentionally alters routing).  The engine defragments its private
pools at the same safe point.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compression as comp
from repro.core import expertpool
from repro.core.hardware import DeviceProfile, DeviceState, capability
from repro.core.pipeline import (
    BandwidthEstimator,
    PipelinePlan,
    plan_pipeline_split,
    plan_spec_k,
    replan_pipeline,
)
from repro.core.selection import group_priority_from_freq, validate_expert_mask
from repro.models import attention as attn_mod
from repro.models import kvcache, transformer
from repro.models.kvcache import PagePool
from repro.models.model import Model
from repro.serving.common import (
    LinkStats,
    Request,
    SlotEngineBase,
    StageTimeline,
    TraceCounter,
    VirtualClock,
    element_bytes,
    payload_block_until_ready,
)
from repro.serving.endcloud import (
    TierPlan,
    end_mask_from_state,
    init_tier_pages,
    plan_tiers,
    split_block_params,
    strip_expert_weights,
)
from repro.serving.faults import HealthMonitor
from repro.serving.specdecode import (
    SpecState,
    batched_accept,
    min_pow2_le,
    rollback_entries,
)

__all__ = ["EndCloudServingEngine"]

_KEEP = object()  # sentinel: "no pending mask change"


def _masks_equal(a, b) -> bool:
    if a is None or b is None:
        return a is b
    return bool(jnp.array_equal(a, b))


class _PrefillJob:
    """An admitted request streaming its prompt through the pipeline in
    chunks.  The slot is reserved (pages and all) but not active until the
    final chunk lands and the group reaches a drained tick."""

    __slots__ = (
        "req", "slot", "group", "pos", "first_tok", "first_tok_dev", "ready_s",
    )

    def __init__(self, req: Request, slot: int, group: int):
        self.req = req
        self.slot = slot
        self.group = group
        self.pos = 0  # prompt tokens prefilled so far
        self.first_tok: Optional[int] = None  # set by the final chunk
        self.first_tok_dev = None  # device scalar, resolved per-tick batched
        self.ready_s = 0.0  # modeled completion time of the last chunk


class _SpillState:
    """A preempted request's KV state, lifted off the device pools.

    ``blocks`` holds the slot's mapped page rows for ALL block repeats,
    merged across the two tiers in block order ([0, R)): restore re-splits
    at the *restore-time* split, so a replan between spill and restore (the
    page layout, even the tier boundary, may have moved) cannot corrupt the
    stream — ring-entry indices are placement-invariant, and attention
    reads pages through the rebuilt table in entry order."""

    __slots__ = (
        "entries", "blocks", "length", "next_token", "n_pages", "migrated",
    )

    def __init__(self, entries: np.ndarray, blocks: Dict, length: int,
                 next_token: int, n_pages: int):
        self.entries = entries  # mapped ring entries (same for both tiers)
        self.blocks = blocks  # pytree of [R_total, n_entries, ps, KV, hd]
        self.length = length  # _slot_len at the safe point
        self.next_token = next_token  # pending token (KV not yet written)
        self.n_pages = n_pages  # original worst-case reservation
        self.migrated = False  # lane-death migration vs in-lane preemption

    @property
    def nbytes(self) -> int:
        """Spill payload size at the *stored* representation: a quantized
        pool's leaves are the int8 codes plus their scale sidecars, so
        spill/migration byte metering sees the quantized size — spilling
        never silently re-inflates to the dense equivalent."""
        return sum(leaf.nbytes for leaf in jax.tree.leaves(self.blocks))


class EndCloudServingEngine(SlotEngineBase):
    def __init__(
        self,
        model: Model,
        params: Dict,
        *,
        end_profile: DeviceProfile,
        cloud_profile: DeviceProfile,
        end_state: Optional[DeviceState] = None,
        codec_params: Optional[Dict] = None,  # 1-D low-rank codec {"enc","dec"}
        compression_rank: int = 0,
        alpha: float = 0.5,
        selection_eps: float = 1.0,
        max_batch: int = 8,
        max_len: int = 512,
        n_groups: int = 2,
        force_split: Optional[int] = None,
        replan_threshold: float = 0.15,
        clock: Optional[Callable[[], float]] = None,
        timeline: Optional[StageTimeline] = None,
        resources: Tuple[str, str, str] = ("end", "link", "cloud"),
        cloud_share: float = 1.0,
        timing: str = "measured",
        page_size: int = 16,
        kv_pages: Optional[int] = None,
        prefill_chunk: int = 16,
        cloud_pool: Optional[PagePool] = None,  # fleet-shared cloud pages
        expert_pool: Optional[bool] = None,  # None = auto (on for MoE models)
        expert_slabs: Optional[int] = None,  # physical slab-pool size
        expert_resident_slots: Optional[int] = None,  # per-layer slot count
        expert_mem_frac: float = 0.5,  # end mem budget share for slabs
        expert_prefetch_per_tick: int = 2,
        expert_registry=None,  # fleet-shared expertpool.FleetExpertRegistry
        admission: str = "priority",  # "priority" | "fifo" (see SlotEngineBase)
        preemption: bool = True,  # spill lower-priority slots for a blocked head
        quantize_kv: bool = False,  # int8 KV pages + f16 per-token scale sidecars
        quantize_experts: bool = False,  # int8 slab store + per-column scales
        quantize_boundary: bool = False,  # int8 boundary payload + f16 row scales
        health: Optional[HealthMonitor] = None,  # shared retry/backoff policy
        blackout_gbps: Optional[float] = None,  # None = 5% of nominal uplink
        spec_k: int = 1,  # speculative draft-length budget (1 = off)
        link_rtt_s: float = 0.0,  # per-transfer round-trip latency (modeled)
    ):
        if not kvcache.pattern_is_pageable(model.cfg):
            raise NotImplementedError(
                "the streaming end-cloud engine serves attention-only layer "
                "patterns (paged KV + chunked prefill); SSM / cross-attention "
                "patterns are served by the dense single-tier ServingEngine"
            )
        # Equal-sized micro-batch groups: pad the slot count up to a
        # multiple of the group size so one decode trace serves every group
        # (np.linspace remainders used to compile one trace per distinct
        # group size).  Padding slots are never admitted.
        self.n_groups = max(1, min(n_groups, max_batch))
        self._group_size = -(-max_batch // self.n_groups)  # ceil
        padded_batch = self.padded_batch(max_batch, n_groups)
        super().__init__(padded_batch, clock, max_len=max_len,
                         admission=admission)
        self.request_capacity = max_batch  # user-visible slot capacity
        # Preemption only acts under priority admission (the FIFO mode is
        # the pure pre-SLO ablation: nothing jumps, nothing is evicted).
        self.preemption = preemption and admission == "priority"
        self._spilled: Dict[int, _SpillState] = {}  # request_id -> spilled KV
        self.n_preemptions = 0
        self.n_preempt_restores = 0
        self.preempt_spill_bytes = 0
        # a VirtualClock switches request stamps onto the modeled timeline
        self._virtual_time = isinstance(self.clock, VirtualClock)
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.end_profile = end_profile
        self.cloud_profile = cloud_profile
        self.end_state = end_state or DeviceState()
        self.selection_eps = selection_eps
        self.replan_threshold = replan_threshold
        # int8 second-stage codecs (all off by default: the dense path stays
        # the exact oracle; each flag quantizes one byte stream — KV pages,
        # the expert slab store, the pipeline-boundary payload)
        self.quantize_kv = bool(quantize_kv)
        self.quantize_experts = bool(quantize_experts)
        self.quantize_boundary = bool(quantize_boundary)

        # paged expert weights: pooled by default for MoE models — the mask
        # derivation below already reads the measured-frequency group
        # priority, so these attrs must exist before plan_tiers
        self._moe_pos = [
            i for i, spec in enumerate(model.cfg.layer_pattern) if spec.moe
        ]
        self._expert_pooled = bool(
            (expert_pool if expert_pool is not None else True)
            and model.cfg.moe is not None
            and self._moe_pos
        )
        self._route_freq: Optional[np.ndarray] = None  # [E] EMA expert_frac
        self._group_freq: Optional[np.ndarray] = None  # [K] EMA group_frac
        self._freq_decay = 0.9
        # fleet-shared expert registry: residency planning is delegated to
        # it once this lane registers (after the pool exists); the mask
        # derivation below may run before that, so both attrs exist now
        self.expert_registry = expert_registry if self._expert_pooled else None
        self._registry_lane: Optional[int] = None
        # any MoE end tier (pooled or dense-mask) measures routing stats
        self._route_stats_enabled = model.cfg.moe is not None and bool(
            self._moe_pos
        )

        self.tiers: TierPlan = plan_tiers(
            model,
            end_profile=end_profile,
            cloud_profile=cloud_profile,
            end_state=self.end_state,
            end_mask=self._derive_end_mask(self.end_state),
            codec_params=codec_params,
            compression_rank=compression_rank,
            alpha=alpha,
            selection_eps=selection_eps,
            force_split=force_split,
            cloud_share=cloud_share,
        )
        self.end_params, self.cloud_params = split_block_params(params, self.split)
        if self._expert_pooled:
            self.end_params = strip_expert_weights(self.end_params, self.cfg)

        self.link = LinkStats()
        self.bw = BandwidthEstimator(self.tiers.end_cap.net_gbps)
        # -- fault tolerance: transfer retries, link-blackout degradation --
        # (the fleet shares one HealthMonitor across lanes; standalone
        # engines get their own with the default policy)
        self.health = health or HealthMonitor()
        # below this measured rate the link is *blacked out*: the planner's
        # comm estimates stop being meaningful and the lane degrades to a
        # cloud-only plan at the next safe point (see _update_link_health)
        self.blackout_gbps = (
            blackout_gbps if blackout_gbps is not None
            else 0.05 * self.tiers.end_cap.net_gbps
        )
        self.link_degraded = False
        self._blackout_since = 0.0
        self.link_blackout_s = 0.0  # closed windows; see blackout_seconds()
        self.degraded_ticks = 0
        self.transfer_retries = 0
        self._transfer_faults = 0  # injected boundary-transfer failures
        self.n_migration_restores = 0
        # ``timeline``/``resources`` let a fleet share one occupancy clock:
        # each device brings its own end/link resources while every device's
        # cloud stage queues on one shared (possibly multi-server) resource.
        self._res_end, self._res_link, self._res_cloud = resources
        if timeline is None:
            timeline = StageTimeline(resources)
        else:
            for r in resources:
                timeline.add_resource(r)
        self.timeline = timeline
        # ``timing="measured"`` (default) feeds the timeline this host's
        # wall-clock stage times; ``"modeled"`` substitutes the planner's
        # capability cost model (gflops / device budget) — tokens are still
        # computed for real, but the schedule is deterministic and honors
        # the *declared* device speeds, which one host cannot reproduce.
        # Heterogeneous-fleet benchmarks use "modeled".
        if timing not in ("measured", "modeled"):
            raise ValueError(f"timing={timing!r}")
        self.timing = timing
        self._cloud_share = cloud_share
        self.replan_events: List[Dict] = []
        self._pending_plan: Optional[PipelinePlan] = None
        self._pending_mask = _KEEP

        # -- paged KV: one pool per tier, storage split by block range ------
        self.page_size = page_size
        self.pages_per_slot, ring = kvcache.page_geometry(
            self.cfg, max_len, page_size, chunk_headroom=prefill_chunk
        )
        if prefill_chunk > ring:
            raise ValueError(
                f"prefill_chunk={prefill_chunk} exceeds the ring capacity "
                f"{ring} (a chunk must fit the slot's page list)"
            )
        self.prefill_chunk = prefill_chunk
        dense_pages = padded_batch * self.pages_per_slot
        self.end_pool = PagePool(
            kv_pages or dense_pages, page_size, self.pages_per_slot,
            n_slots=padded_batch,
        )
        if cloud_pool is None:
            self.cloud_pool = PagePool(
                kv_pages or dense_pages, page_size, self.pages_per_slot,
                n_slots=padded_batch,
            )
            self._cloud_base = 0
            self._cloud_shared = False
        else:
            if cloud_pool.page_size != page_size or (
                cloud_pool.pages_per_slot != self.pages_per_slot
            ):
                raise ValueError("shared cloud pool geometry mismatch")
            self.cloud_pool = cloud_pool
            self._cloud_base = cloud_pool.add_slots(padded_batch)
            self._cloud_shared = True
        dtype = jnp.dtype(self.cfg.dtype)
        self._end_pages, self._cloud_pages = init_tier_pages(
            self.cfg, self.split,
            self.end_pool.num_pages, self.cloud_pool.num_pages,
            page_size, dtype, quantized=self.quantize_kv,
        )
        self._slot_len = np.zeros((padded_batch,), np.int64)
        self._jobs: Dict[int, _PrefillJob] = {}  # slot -> in-flight prefill

        # Micro-batch groups: interleaved slot ranges, one boundary buffer
        # (the double buffer) per group.
        gsz = self._group_size
        self._group_slices = [
            (g * gsz, (g + 1) * gsz) for g in range(self.n_groups)
        ]
        self._phase = ["ready"] * self.n_groups  # "ready" | "boundary"
        self._boundary: List[Optional[jax.Array]] = [None] * self.n_groups
        self._boundary_ready_s = [0.0] * self.n_groups  # modeled arrival time
        self._group_ready_s = [0.0] * self.n_groups  # modeled token-ready time
        # Decode-only mirror of the occupancy clock: the shared timeline
        # carries decode AND prefill chunks (the honest schedule, what fleet
        # contention and makespan see), while the pipelined-vs-serial decode
        # metric compares steady-state decode against its own serial sum —
        # interleaved prefill occupancy must not pollute that ratio.
        self._metric_clock = StageTimeline(("end", "link", "cloud"))
        self._m_boundary_ready = [0.0] * self.n_groups
        self._m_group_ready = [0.0] * self.n_groups

        # -- paged expert weights: slab pool + device store/tables ----------
        self.expert_pool: Optional[expertpool.ExpertSlabPool] = None
        if self._expert_pooled:
            m = self.cfg.moe
            E = m.num_experts
            s_cap = expert_resident_slots or max(
                1, int(np.floor(m.local_selection_cap * E))
            )
            self._s_cap = min(s_cap, E)
            n_layers = len(self._moe_pos) * self.cfg.block_repeat
            # wire costs, capacity, and metering are all priced at the
            # *stored* slab size — int8 slabs are cheaper to fetch and more
            # of them fit the same memory budget; the dense size survives
            # only as the `_dense` metric baselines
            self._slab_bytes = expertpool.expert_slab_bytes(
                self.cfg, quantized=self.quantize_experts
            )
            self._slab_bytes_dense = expertpool.expert_slab_bytes(self.cfg)
            self._expert_mem_frac = expert_mem_frac
            n_slabs = expert_slabs or n_layers * self._s_cap
            self.expert_pool = expertpool.ExpertSlabPool(
                n_slabs, n_layers, E, self._s_cap
            )
            self._slab_store = expertpool.init_slab_store(
                self.cfg, n_slabs, quantized=self.quantize_experts
            )
            self._expert_prefetch_per_tick = max(1, expert_prefetch_per_tick)
            self._prefetch_queue: List[Tuple[int, int]] = []
            self._expert_ready_s = 0.0  # link-resource cursor for transfers
            self.expert_bytes_down = 0  # runtime slab prefetch traffic (cloud)
            self.expert_bytes_peer = 0  # slab traffic served by peer lanes
            self.expert_bytes_up = 0  # (evictions are drops; cloud keeps all)
            self.n_expert_prefetches = 0
            self.n_expert_peer_fetches = 0
            self.n_expert_evictions = 0
            self.expert_routed_tokens = 0  # decoded tokens through the pool
            self.expert_wire_s = 0.0  # slab wire time booked on own link
            self._expert_dirty = False
            self._applied_target = np.asarray(self.tiers.end_mask, bool)
            if self.expert_registry is not None:
                self._registry_lane = self.expert_registry.register_lane(
                    self.expert_pool,
                    link_gbps=lambda: self.bw.gbps,
                    book_link=lambda ready_s, t: self.timeline.occupy(
                        self._res_link, ready_s, t
                    ),
                )
            # initial residency ships with the deployment: filled instantly,
            # not metered — only *runtime* residency changes ride the link
            self._expert_sync(instant_lids=set(self._active_lids()))

        # -- speculative decode: draft caches, acceptance state, plan-k -----
        # ``spec_k`` is the draft-length BUDGET; the planner (plan_spec_k)
        # picks the effective k from measured bandwidth/RTT/stage times and
        # returns 1 in the compute-bound regime — k=1 means no speculative
        # machinery runs at all (no draft cache, no draft prefill, the
        # plain decode path is byte-for-byte the non-speculative engine).
        if spec_k < 1:
            raise ValueError(f"spec_k must be >= 1, got {spec_k}")
        self.spec_k_max = min(int(spec_k), self.prefill_chunk)
        self.link_rtt_s = float(link_rtt_s)
        self._spec_state: Optional[SpecState] = None
        self._spec_plan_k = 1
        self._spec_fns: Dict[int, Tuple] = {}  # k -> (draft, end, cloud) fns
        self._spec_prefill = None  # jitted draft-cache prefill ([1, max_len])
        # per-group dense draft caches (blocks pytree, leaves
        # [R, gsz, W, KV, hd]); per-slot host lengths + readiness
        self._draft_cache: List[Optional[Dict]] = [None] * self.n_groups
        self._draft_len = np.zeros((padded_batch,), np.int64)
        self._draft_ready = np.zeros((padded_batch,), bool)
        # in-flight speculative round per group (set by the spec end stage,
        # consumed at the cloud drain; aborts roll provisional pages back)
        self._spec_pending: List[Optional[Dict]] = [None] * self.n_groups
        self.n_host_syncs = 0  # device->host transfers (batched per tick)

        self.n_stage_steps = 0  # decode end-steps (== drained cloud-steps)
        self.n_prefill_chunks = 0
        # This engine's own stage seconds (the timeline's busy_s would mix in
        # other lanes' cloud time when the cloud resource is fleet-shared).
        self._stage_busy = {"end": 0.0, "link": 0.0, "cloud": 0.0}
        self._prefill_busy = {"end": 0.0, "link": 0.0, "cloud": 0.0}
        self._traces: Dict[str, set] = {}
        self._build_gen = 0
        self._build_stage_fns()

    @staticmethod
    def padded_batch(max_batch: int, n_groups: int) -> int:
        """Slot count after rounding up to equal-sized micro-batch groups
        (the authoritative grouping rule; the fleet sizes its shared cloud
        pool with it)."""
        g = max(1, min(n_groups, max_batch))
        return -(-max_batch // g) * g

    # -- the active plan lives on self.tiers; everything else delegates ------

    def _derive_end_mask(self, end_state: DeviceState):
        """Hardware-aware expert mask for this end device (eq. 2-4).  One
        derivation shared by initial tier planning and replan-time state
        updates; the fleet lane overrides it with the fleet-mask semantics
        (``selection.shard_masks_for_fleet``'s never-empty guarantee).
        The greedy group admit is ordered by *measured* stage-1 routing
        frequency (EMA of the gate's ``group_frac``), not natural order."""
        return end_mask_from_state(
            self.cfg, self.end_profile, end_state,
            selection_eps=self.selection_eps,
            group_priority=self._group_priority(),
        )

    def _group_priority(self):
        if self.cfg.moe is None:
            return None
        return group_priority_from_freq(
            self._group_freq, self.cfg.moe.num_groups,
            group_cost=self._group_placement_cost(),
        )

    def _group_placement_cost(self):
        """Per-group modeled fetch cost from the fleet expert registry
        (None standalone / before registration): the eq. 4 greedy admit
        then prefers groups whose experts are already fleet-resident or
        cheap to fetch — routing sees the same map request placement
        does."""
        if self.expert_registry is None or self._registry_lane is None:
            return None
        return self.expert_registry.group_fetch_costs(
            self._registry_lane, self._active_lids(), self.cfg.moe.num_groups
        )

    # -- paged expert weights (slab pool; see core.expertpool) ----------------

    def _active_lids(self) -> List[int]:
        """Pool layer ids of the end tier's MoE layers at the current
        split: block ``b`` of pattern position ``self._moe_pos[pi]`` is
        layer ``pi * block_repeat + b``."""
        R = self.cfg.block_repeat
        return [
            pi * R + b
            for pi in range(len(self._moe_pos))
            for b in range(self.split)
        ]

    def _expert_capacity(self) -> int:
        """Slab budget from the end capability's memory term (eq. 3):
        ``expert_mem_frac`` of the budget buys slabs — a shrinking memory
        budget now actually sheds experts (evictions at the next safe
        point) instead of only suppressing routing.  Floor: every active
        end layer keeps at least one resident."""
        budget = self.tiers.end_cap.mem_budget_gb * 1e9 * self._expert_mem_frac
        n = int(budget // self._slab_bytes)
        floor_n = max(1, len(self._active_lids()))
        return max(floor_n, min(n, self.expert_pool.num_slabs))

    def _target_mask_np(self) -> np.ndarray:
        return np.asarray(self.tiers.end_mask, bool)

    def _write_slabs(self, assignments: List[Tuple[int, int, int, int]]):
        """(slab, pos_index, block, expert) -> copy weights into the store
        (one batched scatter per MoE pattern position)."""
        by_pos: Dict[int, List[Tuple[int, int, int]]] = {}
        for slab, pi, b, e in assignments:
            by_pos.setdefault(pi, []).append((slab, b, e))
        for pi, asg in by_pos.items():
            full = self.params["blocks"][f"pos{self._moe_pos[pi]}"]["moe"]
            self._slab_store = expertpool.write_slabs(
                self._slab_store, full, asg
            )

    def _lid_to_pos_block(self, lid: int) -> Tuple[int, int]:
        R = self.cfg.block_repeat
        return lid // R, lid % R

    def _plan_residency(self, active, target):
        """Residency plan for this lane: through the fleet registry when
        attached (pool policy plus the fleet de-dup rule — a duplicate of
        a peer-resident expert is only fetched when this lane's measured
        traffic justifies the slab), the isolated pool policy otherwise."""
        if self.expert_registry is not None and self._registry_lane is not None:
            return self.expert_registry.plan_lane(
                self._registry_lane, active, target, self._route_freq
            )
        return self.expert_pool.plan(active, target, self._route_freq)

    def _expert_sync(self, instant_lids=()):
        """Reconcile pool residency with the current target mask / split /
        memory budget — called at replan safe points only, so the swapped
        tables and routing mask can never change mid-boundary.  Layers in
        ``instant_lids`` (initial fill, blocks entering the end tier at a
        split change — their weights move with the unmetered block
        re-split) materialize immediately; everything else joins the
        prefetch queue and rides the link timeline."""
        pool = self.expert_pool
        target = self._target_mask_np()
        active = self._active_lids()
        pool.set_capacity(self._expert_capacity())
        wanted, evictions = self._plan_residency(active, target)
        for lid, e in evictions:
            pool.evict(lid, e)
            self.n_expert_evictions += 1
        instant_lids = set(instant_lids)
        queue: List[Tuple[int, int]] = []
        writes: List[Tuple[int, int, int, int]] = []
        for lid, e in wanted:
            if lid in instant_lids and pool.can_alloc():
                slab = pool.alloc(lid, e)
                pi, b = self._lid_to_pos_block(lid)
                writes.append((slab, pi, b, e))
            else:
                queue.append((lid, e))
        self._write_slabs(writes)
        self._prefetch_queue = queue
        self._applied_target = target
        self._expert_tables = self._build_expert_tables()
        self._emask_dev = jnp.asarray(target)
        pool.touch(active, target)
        self._expert_dirty = False

    def _build_expert_tables(self) -> Dict[str, Dict[str, jax.Array]]:
        R = self.cfg.block_repeat
        tabs = {}
        for pi, pos in enumerate(self._moe_pos):
            lids = [pi * R + b for b in range(self.split)]
            tabs[f"pos{pos}"] = expertpool.device_resident_tables(
                self.expert_pool, lids, self._s_cap
            )
        return tabs

    def _eres(self) -> Dict:
        """The pooled end stages' runtime operand: slab store + per-layer
        resident tables (shapes depend only on the split and the static
        resident-slot count, so residency changes never retrace)."""
        return {"store": self._slab_store, "tables": self._expert_tables}

    def _advance_expert_prefetch(self):
        """Transfer up to ``expert_prefetch_per_tick`` queued slabs: write
        the weights into the store (unreferenced by any applied table until
        the next safe point, so in-flight decode is untouched) and book the
        wire time on the shared link resource — prefetch overlaps decode
        on the occupancy timeline exactly like boundary traffic.  A queue
        head blocked on capacity waits for safe-point evictions."""
        if not self._expert_pooled or not self._prefetch_queue:
            return
        pool = self.expert_pool
        n = 0
        i = 0
        writes: List[Tuple[int, int, int, int]] = []
        while i < len(self._prefetch_queue) and n < self._expert_prefetch_per_tick:
            lid, e = self._prefetch_queue[i]
            if pool.table[lid, e] >= 0:
                self._prefetch_queue.pop(i)
                continue
            if not pool.can_alloc():
                break  # global budget: nothing can transfer this tick
            if pool.resident_count(lid) >= pool.max_per_layer:
                # this layer's slots wait for safe-point evictions — skip
                # it, other layers' transfers must not head-of-line block
                i += 1
                continue
            self._prefetch_queue.pop(i)
            slab = pool.alloc(lid, e)
            pi, b = self._lid_to_pos_block(lid)
            writes.append((slab, pi, b, e))
            # source pick happens at *transfer* time against the live fleet
            # map: a peer lane holding the slab serves it over the modeled
            # end<->end link when strictly cheaper than the cloud path (a
            # peer that evicted since planning falls back to the cloud)
            src = None
            if self.expert_registry is not None and (
                self._registry_lane is not None
            ):
                src, t_wire = self.expert_registry.pick_source(
                    self._registry_lane, lid, e
                )
            if src is not None and self.expert_registry.take_peer_fault():
                # injected peer-fetch failure: back off once, then re-source
                # from the cloud — the authoritative store, never the flaky
                # peer again for this slab
                self.transfer_retries += 1
                self._expert_ready_s += self.health.backoff_s(0)
                src = None
            if src is None:
                t_wire = self.link.transfer_time(self._slab_bytes, self.bw.gbps)
                self.expert_bytes_down += self._slab_bytes
            else:
                # both ends of the peer transfer ride the fleet timeline:
                # this lane's link here, the source lane's via the registry
                self.expert_registry.book_peer(
                    src, self._registry_lane, self._expert_ready_s, t_wire
                )
                self.link.record_peer(self._slab_bytes, t_wire)
                self.expert_bytes_peer += self._slab_bytes
                self.n_expert_peer_fetches += 1
            self._expert_ready_s = self.timeline.occupy(
                self._res_link, self._expert_ready_s, t_wire
            )
            self.expert_wire_s += t_wire
            self.n_expert_prefetches += 1
            self._expert_dirty = True  # tables swap at the next safe point
            n += 1
        self._write_slabs(writes)

    def _observe_route_stats(self, stats: Dict):
        """EMA the gate's measured routing statistics (summed over the end
        tier's MoE layers by the stack) — they order the eq. 4 group admit
        and the pool's prefetch/evict priorities."""
        n_layers = max(len(self._active_lids()), 1)
        ef = np.asarray(stats["expert_frac"], np.float64) / n_layers
        gf = np.asarray(stats["group_frac"], np.float64) / n_layers
        if not (np.isfinite(ef).all() and np.isfinite(gf).all()):
            return
        d = self._freq_decay
        if self._route_freq is None:
            self._route_freq, self._group_freq = ef, gf
        else:
            self._route_freq = d * self._route_freq + (1 - d) * ef
            self._group_freq = d * self._group_freq + (1 - d) * gf

    @property
    def plan(self) -> PipelinePlan:
        return self.tiers.plan

    @property
    def split(self) -> int:
        return self.tiers.plan.split_layer

    def _cslot(self, slot: int) -> int:
        """A slot's row in the (possibly fleet-shared) cloud pool."""
        return self._cloud_base + slot

    # -- stage functions (rebuilt on every replan so the captured split /
    # -- codec flags can never go stale in a cached trace) --------------------

    def _build_stage_fns(self):
        cfg = self.cfg
        topo = self.model.topo
        tiers = self.tiers
        codec, compress, end_mask = tiers.codec, tiers.compress, tiers.end_mask
        act = jnp.dtype(cfg.dtype)
        ps = self.page_size
        pooled = self._expert_pooled
        qb = self.quantize_boundary

        def wire_encode(z):
            """Second codec stage: int8-quantize the boundary payload (after
            the low-rank encode when one is configured).  The payload
            becomes an ``(codes int8, scale f16)`` tuple — the tuple-aware
            metering/blocking helpers in ``serving.common`` handle it."""
            return comp.quantize_boundary(z) if qb else z

        def wire_decode(z):
            return comp.dequantize_boundary(*z, dtype=act) if qb else z

        def decode_angles(lengths, B):
            pos = lengths[:, None]
            if cfg.mrope_sections is not None:
                pos = jnp.broadcast_to(pos[:, None], (B, 3, 1))
            return attn_mod.rope_angles(
                pos, cfg.head_dim, cfg.rope_theta, cfg.mrope_sections
            )

        def chunk_angles(positions):
            pos = positions
            if cfg.mrope_sections is not None:
                B, C = positions.shape
                pos = jnp.broadcast_to(pos[:, None], (B, 3, C))
            return attn_mod.rope_angles(
                pos, cfg.head_dim, cfg.rope_theta, cfg.mrope_sections
            )

        def end_step(end_params, tokens, pages, table, lengths):
            angles = decode_angles(lengths, tokens.shape[0])
            x = transformer.embed_inputs(end_params, cfg, tokens)
            x, new_pages, aux = transformer.apply_stack_decode(
                end_params, x, cfg, topo, angles, pages, lengths,
                expert_mask=end_mask, page_table=table, page_size=ps,
            )
            z = wire_encode(comp.encode_1d(codec, x) if compress else x)
            if self._route_stats_enabled:
                # dense-mask MoE engines measure routing too: the eq. 4
                # group priority must come from traffic, not natural order
                stats = {
                    "expert_frac": aux["expert_frac"],
                    "group_frac": aux["group_frac"],
                }
                return z, new_pages, stats
            return z, new_pages

        # pooled variants: the target mask and the resident tables/store
        # are RUNTIME operands (residency changes never retrace); the gate's
        # measured routing stats come back for the frequency EMA
        def end_step_pooled(end_params, tokens, pages, table, lengths,
                            emask, eres):
            angles = decode_angles(lengths, tokens.shape[0])
            x = transformer.embed_inputs(end_params, cfg, tokens)
            x, new_pages, aux = transformer.apply_stack_decode(
                end_params, x, cfg, topo, angles, pages, lengths,
                expert_mask=emask, page_table=table, page_size=ps,
                expert_resident=eres,
            )
            z = wire_encode(comp.encode_1d(codec, x) if compress else x)
            stats = {
                "expert_frac": aux["expert_frac"],
                "group_frac": aux["group_frac"],
            }
            return z, new_pages, stats

        def cloud_step(cloud_params, z, pages, table, lengths):
            z = wire_decode(z)
            angles = decode_angles(lengths, z.shape[0])
            x = comp.decode_1d(codec, z) if compress else z
            x = x.astype(act)
            x, new_pages, _ = transformer.apply_stack_decode(
                cloud_params, x, cfg, topo, angles, pages, lengths,
                expert_mask=None, page_table=table, page_size=ps,
            )
            logits = transformer.lm_logits(cloud_params, cfg, x)[:, 0]
            # greedy ids resolved in-trace: one int32 per row crosses to the
            # host (batched per tick) instead of a [B, V] logits row
            return jnp.argmax(logits, -1).astype(jnp.int32), new_pages

        def end_prefill_chunk(end_params, tokens, pages, table, start, n_valid):
            B, C = tokens.shape
            positions = start[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]
            angles = chunk_angles(positions)
            x = transformer.embed_inputs(end_params, cfg, tokens)
            x, new_pages = transformer.apply_stack_prefill_chunk(
                end_params, x, cfg, topo, angles, pages, table,
                positions, n_valid, ps, expert_mask=end_mask,
            )
            z = wire_encode(comp.encode_1d(codec, x) if compress else x)
            return z, new_pages

        def end_prefill_chunk_pooled(end_params, tokens, pages, table, start,
                                     n_valid, emask, eres):
            B, C = tokens.shape
            positions = start[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]
            angles = chunk_angles(positions)
            x = transformer.embed_inputs(end_params, cfg, tokens)
            x, new_pages = transformer.apply_stack_prefill_chunk(
                end_params, x, cfg, topo, angles, pages, table,
                positions, n_valid, ps, expert_mask=emask,
                expert_resident=eres,
            )
            z = wire_encode(comp.encode_1d(codec, x) if compress else x)
            return z, new_pages

        def cloud_prefill_chunk(cloud_params, z, pages, table, start, n_valid):
            z = wire_decode(z)
            B, C = z.shape[:2]
            positions = start[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]
            angles = chunk_angles(positions)
            x = comp.decode_1d(codec, z) if compress else z
            x = x.astype(act)
            x, new_pages = transformer.apply_stack_prefill_chunk(
                cloud_params, x, cfg, topo, angles, pages, table,
                positions, n_valid, ps, expert_mask=None,
            )
            x_last = x[jnp.arange(B), jnp.maximum(n_valid - 1, 0)][:, None]
            logits = transformer.lm_logits(cloud_params, cfg, x_last)[:, 0]
            return jnp.argmax(logits, -1).astype(jnp.int32), new_pages

        self._build_gen += 1
        gen = self._build_gen

        def counted(name, fn):
            return TraceCounter(
                jax.jit(fn), self._traces.setdefault(name, set()), gen
            )

        self._end_step = counted(
            "end_step", end_step_pooled if pooled else end_step
        )
        self._cloud_step = counted("cloud_step", cloud_step)
        self._end_prefill_chunk = counted(
            "end_prefill_chunk",
            end_prefill_chunk_pooled if pooled else end_prefill_chunk,
        )
        self._cloud_prefill_chunk = counted(
            "cloud_prefill_chunk", cloud_prefill_chunk
        )
        # speculative stage fns close over the same codec/mask/split state:
        # drop the per-k cache so they rebuild lazily against the new plan
        self._spec_fns = {}
        self._spec_prefill = None
        self._recompute_spec_plan()
        self._warmup_stage_fns()

    def _warmup_stage_fns(self):
        """Compile the stage functions for the (single) group shape and the
        (single) chunk shape so measured stage times reflect steady-state
        compute, not tracing.  Warmup writes are routed to the garbage page
        (all-garbage table) and the returned storage is discarded."""
        gsz = self._group_size
        inactive = np.zeros((gsz,), bool)
        tokens = jnp.zeros((gsz, 1), jnp.int32)
        lengths = jnp.zeros((gsz,), jnp.int32)
        te = self.end_pool.device_rows(range(gsz), active=inactive)
        tc = self.cloud_pool.device_rows(
            [self._cslot(s) for s in range(gsz)], active=inactive
        )
        eargs = (
            (self._emask_dev, self._eres()) if self._expert_pooled else ()
        )
        z, _, *_ = self._end_step(
            self.end_params, tokens, self._end_pages, te, lengths, *eargs
        )
        ids, _ = self._cloud_step(
            self.cloud_params, z, self._cloud_pages, tc, lengths
        )
        ids.block_until_ready()

        C = self.prefill_chunk
        ctok = jnp.zeros((1, C), jnp.int32)
        start = jnp.zeros((1,), jnp.int32)
        valid = jnp.ones((1,), jnp.int32)
        te1 = self.end_pool.device_rows([0], active=np.zeros((1,), bool))
        tc1 = self.cloud_pool.device_rows(
            [self._cslot(0)], active=np.zeros((1,), bool)
        )
        z, _ = self._end_prefill_chunk(
            self.end_params, ctok, self._end_pages, te1, start, valid, *eargs
        )
        ids, _ = self._cloud_prefill_chunk(
            self.cloud_params, z, self._cloud_pages, tc1, start, valid
        )
        ids.block_until_ready()

    # -- speculative decode: draft on the end tier, verify in one C=k chunk ---
    #
    # A speculative round replaces one single-token pipeline round for a
    # group: the end tier drafts k-1 tokens with a cheap full-stack forward
    # under its expert mask (against a private dense "draft cache"), runs
    # its block range over the k-position chunk [pending, y_1..y_{k-1}],
    # ships ONE boundary payload, and the cloud verifies all k positions in
    # a single chunked step off the paged pool.  The accepted prefix
    # commits; provisional pages past the first rejection are unmapped
    # (pure table surgery — rejected tokens only ever lived in
    # lazily-mapped pages) and the verify argmax at the rejection point is
    # the corrected token, so greedy output matches non-speculative decode
    # by construction.

    def _recompute_spec_plan(self):
        """Re-run the plan-time draft-length choice against measured link
        conditions (safe points and bandwidth observations).  k=1 disables
        every piece of speculative machinery — the engine is then
        byte-for-byte the plain pipeline."""
        if self.spec_k_max <= 1:
            self._spec_plan_k = 1
            return
        acc = 0.7
        if self._spec_state is not None and self._spec_state.acceptance is not None:
            acc = self._spec_state.acceptance
        ratio = self.tiers.compression_ratio if self.tiers.compress else 1.0
        k = plan_spec_k(
            self.tiers.layer_gflops,
            self.tiers.boundary_bytes,
            self.tiers.end_cap,
            self.tiers.cloud_cap,
            split=self.split,
            link_rtt_s=self.link_rtt_s,
            measured_gbps=self.bw.gbps,
            compression_ratio=ratio,
            acceptance=acc,
            k_max=self.spec_k_max,
        )
        self._spec_plan_k = k
        if k > 1:
            if self._spec_state is None:
                self._spec_state = SpecState(k)
            else:
                st = self._spec_state
                st.k_plan = k
                st.k_eff = max(2, min(st.k_eff, min_pow2_le(k)))

    def _spec_emask(self):
        """The draft model's expert mask: the plan's target set.  The
        draft forward runs the FULL stack from ``self.params`` (all blocks
        plus embedding and head) restricted to end-resident experts — the
        cheap self-speculation draft; dense models draft exactly."""
        if self.tiers.end_mask is None:
            return None
        return jnp.asarray(self.tiers.end_mask)

    def _init_draft_cache(self) -> Dict:
        return kvcache.init_cache(
            self.cfg, self._group_size, self.max_len, jnp.dtype(self.cfg.dtype)
        )["blocks"]

    def _draft_prefill_fn(self):
        if self._spec_prefill is None:
            model, max_len = self.model, self.max_len

            def spec_draft_prefill(params, tokens, emask):
                _logits, cache = model.prefill(
                    params, {"tokens": tokens}, max_len=max_len,
                    expert_mask=emask,
                )
                return cache["blocks"]

            self._spec_prefill = TraceCounter(
                jax.jit(spec_draft_prefill),
                self._traces.setdefault("spec_draft_prefill", set()),
                self._build_gen,
            )
        return self._spec_prefill

    def _spec_fns_for_k(self, k: int):
        """Build (lazily, cached per k until the next stage rebuild) the
        three jitted speculative stage functions for chunk size k: the
        end-tier draft scan, the end-tier C=k boundary chunk, and the
        cloud C=k verify chunk returning per-position greedy ids."""
        if k in self._spec_fns:
            return self._spec_fns[k]
        cfg = self.cfg
        topo = self.model.topo
        tiers = self.tiers
        codec, compress, end_mask = tiers.codec, tiers.compress, tiers.end_mask
        act = jnp.dtype(cfg.dtype)
        ps = self.page_size
        qb = self.quantize_boundary

        def wire_encode(z):
            return comp.quantize_boundary(z) if qb else z

        def wire_decode(z):
            return comp.dequantize_boundary(*z, dtype=act) if qb else z

        def decode_angles(lengths, B):
            pos = lengths[:, None]
            if cfg.mrope_sections is not None:
                pos = jnp.broadcast_to(pos[:, None], (B, 3, 1))
            return attn_mod.rope_angles(
                pos, cfg.head_dim, cfg.rope_theta, cfg.mrope_sections
            )

        def chunk_angles(positions):
            pos = positions
            if cfg.mrope_sections is not None:
                B, C = positions.shape
                pos = jnp.broadcast_to(pos[:, None], (B, 3, C))
            return attn_mod.rope_angles(
                pos, cfg.head_dim, cfg.rope_theta, cfg.mrope_sections
            )

        def spec_draft(params, tokens, blocks, lengths, emask):
            # k greedy steps off the dense draft cache in ONE trace.  Step
            # 0 consumes the pending token (writing its draft-KV at the
            # base position); steps 1..k-1 consume their predecessor's
            # argmax.  The k-th output is discarded — only k-1 drafts feed
            # the chunk — but its WRITE keeps the draft cache contiguous
            # through position base+k-1 for the full-accept case.
            B = tokens.shape[0]
            drafts = []
            for _ in range(k):
                angles = decode_angles(lengths, B)
                x = transformer.embed_inputs(params, cfg, tokens)
                x, blocks, _aux = transformer.apply_stack_decode(
                    params, x, cfg, topo, angles, blocks, lengths,
                    expert_mask=emask,
                )
                logits = transformer.lm_logits(params, cfg, x)[:, 0]
                tokens = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
                drafts.append(tokens[:, 0])
                lengths = lengths + 1
            return jnp.stack(drafts, axis=1), blocks

        def spec_end(end_params, tokens, pages, table, start, n_valid):
            positions = start[:, None] + jnp.arange(k, dtype=jnp.int32)[None, :]
            angles = chunk_angles(positions)
            x = transformer.embed_inputs(end_params, cfg, tokens)
            x, new_pages = transformer.apply_stack_prefill_chunk(
                end_params, x, cfg, topo, angles, pages, table,
                positions, n_valid, ps, expert_mask=end_mask,
            )
            z = wire_encode(comp.encode_1d(codec, x) if compress else x)
            return z, new_pages

        def spec_end_pooled(end_params, tokens, pages, table, start, n_valid,
                            emask, eres):
            positions = start[:, None] + jnp.arange(k, dtype=jnp.int32)[None, :]
            angles = chunk_angles(positions)
            x = transformer.embed_inputs(end_params, cfg, tokens)
            x, new_pages = transformer.apply_stack_prefill_chunk(
                end_params, x, cfg, topo, angles, pages, table,
                positions, n_valid, ps, expert_mask=emask,
                expert_resident=eres,
            )
            z = wire_encode(comp.encode_1d(codec, x) if compress else x)
            return z, new_pages

        def spec_cloud(cloud_params, z, pages, table, start, n_valid):
            z = wire_decode(z)
            positions = start[:, None] + jnp.arange(k, dtype=jnp.int32)[None, :]
            angles = chunk_angles(positions)
            x = comp.decode_1d(codec, z) if compress else z
            x = x.astype(act)
            x, new_pages = transformer.apply_stack_prefill_chunk(
                cloud_params, x, cfg, topo, angles, pages, table,
                positions, n_valid, ps, expert_mask=None,
            )
            # per-position greedy ids, resolved in-trace: k int32 per row
            # cross back down the link, never the [B, k, V] logits
            logits = transformer.lm_logits(cloud_params, cfg, x)
            return jnp.argmax(logits, -1).astype(jnp.int32), new_pages

        gen = self._build_gen

        def counted(name, fn):
            return TraceCounter(
                jax.jit(fn), self._traces.setdefault(name, set()), gen
            )

        fns = (
            counted(f"spec_draft_k{k}", spec_draft),
            counted(
                f"spec_end_k{k}",
                spec_end_pooled if self._expert_pooled else spec_end,
            ),
            counted(f"spec_cloud_k{k}", spec_cloud),
        )
        self._spec_fns[k] = fns
        self._warmup_spec_fns(k, fns)
        return fns

    def _warmup_spec_fns(self, k: int, fns):
        """Compile the spec stage functions for the group/chunk shapes
        (garbage-routed tables, discarded storage) so measured round times
        never include tracing."""
        draft_fn, end_fn, cloud_fn = fns
        gsz = self._group_size
        inactive = np.zeros((gsz,), bool)
        tokens = jnp.zeros((gsz, 1), jnp.int32)
        lengths = jnp.zeros((gsz,), jnp.int32)
        drafts, _ = draft_fn(
            self.params, tokens, self._init_draft_cache(), lengths,
            self._spec_emask(),
        )
        te = self.end_pool.device_rows(range(gsz), active=inactive)
        tc = self.cloud_pool.device_rows(
            [self._cslot(s) for s in range(gsz)], active=inactive
        )
        eargs = (
            (self._emask_dev, self._eres()) if self._expert_pooled else ()
        )
        ctok = jnp.zeros((gsz, k), jnp.int32)
        start = jnp.zeros((gsz,), jnp.int32)
        valid = jnp.ones((gsz,), jnp.int32)
        z, _ = end_fn(
            self.end_params, ctok, self._end_pages, te, start, valid, *eargs
        )
        ids, _ = cloud_fn(
            self.cloud_params, z, self._cloud_pages, tc, start, valid
        )
        ids.block_until_ready()

    def _draft_seconds(self, n_tokens: int) -> Optional[float]:
        """Modeled end-tier seconds for ``n_tokens`` through the FULL
        stack (the draft forward runs every block on the end device); None
        in measured mode."""
        if self.timing != "modeled":
            return None
        rate = self.tiers.end_cap.gflop_budget * 1e3
        return n_tokens * sum(self.tiers.layer_gflops) / max(rate, 1e-9)

    def _install_draft(self, slot: int):
        """(Re)build one slot's draft cache by prefilling its committed
        token stream through the draft model — at activation, at restore
        after preemption/migration, and when the plan turns speculation on
        mid-run.  One jitted [1, max_len] trace serves every length; the
        end tier pays the forward on the timeline like any prefill."""
        req = self.slots[slot]
        L = int(self._slot_len[slot])
        stream = list(req.prompt) + list(req.generated)
        padded = np.zeros((self.max_len,), np.int32)
        padded[:L] = np.asarray(stream[:L], np.int32)
        t0 = time.perf_counter()
        blocks = self._draft_prefill_fn()(
            self.params, jnp.asarray(padded)[None], self._spec_emask()
        )
        jax.block_until_ready(blocks)
        td = self._draft_seconds(L)
        if td is None:
            td = time.perf_counter() - t0
        g = self._group_of(slot)
        r = slot - g * self._group_size
        if self._draft_cache[g] is None:
            self._draft_cache[g] = self._init_draft_cache()
        self._draft_cache[g] = jax.tree.map(
            lambda big, one: big.at[:, r].set(one[:, 0].astype(big.dtype)),
            self._draft_cache[g], blocks,
        )
        self._draft_len[slot] = L
        self._draft_ready[slot] = True
        done = self.timeline.occupy(self._res_end, self._group_ready_s[g], td)
        self._prefill_busy["end"] += td
        self._group_ready_s[g] = max(self._group_ready_s[g], done)

    def _spec_refresh_drafts(self):
        """Build draft caches for active slots that lack one (plan turned
        speculation on mid-run, or a restore invalidated the cache) —
        only while the slot's group is drained, so a pending round's
        commit can never clobber the fresh cache."""
        for slot in range(self.max_batch):
            if (
                self._active[slot]
                and not self._draft_ready[slot]
                and self.slots[slot] is not None
                and self._phase[self._group_of(slot)] == "ready"
            ):
                self._install_draft(slot)

    def _spec_round_k(self, g: int) -> int:
        """Draft length for this group's next round: the adaptive k while
        speculation is planned AND some active row has a fresh draft cache
        and at least two tokens of budget left; 1 (the plain path)
        otherwise."""
        if self._spec_plan_k <= 1 or self._spec_state is None:
            return 1
        gs, ge = self._group_slices[g]
        for s in range(gs, ge):
            req = self.slots[s]
            if (
                self._active[s]
                and self._draft_ready[s]
                and req is not None
                and req.max_new_tokens - len(req.generated) >= 2
            ):
                return max(2, self._spec_state.k_eff)
        return 1

    def _run_end_stage_spec(self, g: int, k: int):
        """Speculative end stage: draft scan + C=k boundary chunk.  Pages
        the chunk touches beyond the committed length are mapped
        PROVISIONALLY (``map_tokens`` returns exactly the new entries);
        the commit/rollback happens when the verify ids drain."""
        gs, ge = self._group_slices[g]
        gsz = ge - gs
        active = self._active[gs:ge]
        base_len = self._slot_len[gs:ge].copy()
        draft_fn, end_fn, _ = self._spec_fns_for_k(k)

        # per-row verified positions: full k with a fresh draft and budget,
        # the bare pending token otherwise (stale cache / budget edge);
        # inactive rows verify one garbage-routed padding position, exactly
        # like the warmup path
        n_valid = np.ones((gsz,), np.int64)
        for i, slot in enumerate(range(gs, ge)):
            req = self.slots[slot]
            if req is None or not self._active[slot]:
                continue
            if self._draft_ready[slot]:
                n_valid[i] = max(
                    1, min(k, req.max_new_tokens - len(req.generated))
                )

        # draft scan: k steps off the dense draft cache, one trace
        tokens = jnp.asarray(self._next_token[gs:ge], jnp.int32)
        dlens = jnp.asarray(self._draft_len[gs:ge], jnp.int32)
        dcache = self._draft_cache[g]
        if dcache is None:
            dcache = self._init_draft_cache()
        t0 = time.perf_counter()
        drafts_dev, dcache = draft_fn(
            self.params, tokens, dcache, dlens, self._spec_emask()
        )
        jax.block_until_ready(drafts_dev)
        td = self._draft_seconds(gsz * k)
        if td is None:
            td = time.perf_counter() - t0
        self._draft_cache[g] = dcache

        # provisionally map the chunk's pages in both pools (lockstep)
        new_e: Dict[int, List[int]] = {}
        new_c: Dict[int, List[int]] = {}
        for i, slot in enumerate(range(gs, ge)):
            if not self._active[slot]:
                continue
            L = int(base_len[i])
            ents = self.end_pool.map_tokens(slot, L, L + int(n_valid[i]))
            ents_c = self.cloud_pool.map_tokens(
                self._cslot(slot), L, L + int(n_valid[i])
            )
            if ents != ents_c:
                raise RuntimeError(
                    f"tier pools out of lockstep for slot {slot}: "
                    f"{ents} vs {ents_c}"
                )
            new_e[slot] = ents
            new_c[slot] = ents_c

        # end-tier chunk over [pending, y_1..y_{k-1}]
        tok_chunk = jnp.concatenate([tokens, drafts_dev[:, : k - 1]], axis=1)
        table = self.end_pool.device_rows(range(gs, ge), active=active)
        start = jnp.asarray(base_len, jnp.int32)
        nv_dev = jnp.asarray(n_valid, jnp.int32)
        eargs = (
            (self._emask_dev, self._eres()) if self._expert_pooled else ()
        )
        t1 = time.perf_counter()
        z, self._end_pages = end_fn(
            self.end_params, tok_chunk, self._end_pages, table, start,
            nv_dev, *eargs,
        )
        payload_block_until_ready(z)
        te = self._stage_seconds("end", gsz * k)
        if te is None:
            te = time.perf_counter() - t1

        # boundary metering: per-position bytes x valid positions of
        # active rows (padding rows and positions never cross the wire)
        per_pos = sum(
            int(l.dtype.itemsize * int(np.prod(l.shape[2:])))
            for l in (z if isinstance(z, tuple) else (z,))
        )
        n_tok_active = int(n_valid[active].sum())
        t_comm = self._link_transfer(per_pos * n_tok_active)
        if self._expert_pooled:
            self.expert_routed_tokens += n_tok_active

        done_e = self.timeline.occupy(
            self._res_end, self._group_ready_s[g], td + te
        )
        done_l = self.timeline.occupy(self._res_link, done_e, t_comm)
        m_e = self._metric_clock.occupy("end", self._m_group_ready[g], td + te)
        self._m_boundary_ready[g] = self._metric_clock.occupy(
            "link", m_e, t_comm
        )
        self._stage_busy["end"] += td + te
        self._stage_busy["link"] += t_comm
        self.n_stage_steps += 1

        self._boundary[g] = z
        self._boundary_ready_s[g] = done_l
        self._phase[g] = "boundary"
        self._spec_pending[g] = {
            "k": k,
            "drafts": drafts_dev,
            "base_len": base_len,
            "n_valid": n_valid,
            "new_entries_end": new_e,
            "new_entries_cloud": new_c,
        }

    def _drain_cloud_stage_spec(self, g: int) -> Dict:
        """Cloud half of a speculative round: one C=k verify chunk off the
        paged pool; per-position greedy ids come back down the link.  The
        host-side accept/commit happens in ``_harvest_drained`` so the
        draft/verify device arrays join the tick's single batched
        device->host transfer."""
        pend = self._spec_pending[g]
        gs, ge = self._group_slices[g]
        k = pend["k"]
        _, _, cloud_fn = self._spec_fns_for_k(k)
        z = self._boundary[g]
        table = self.cloud_pool.device_rows(
            [self._cslot(s) for s in range(gs, ge)],
            active=self._active[gs:ge],
        )
        start = jnp.asarray(pend["base_len"], jnp.int32)
        nv = jnp.asarray(pend["n_valid"], jnp.int32)
        t0 = time.perf_counter()
        ids_dev, self._cloud_pages = cloud_fn(
            self.cloud_params, z, self._cloud_pages, table, start, nv
        )
        ids_dev.block_until_ready()
        tc = self._stage_seconds("cloud", (ge - gs) * k)
        if tc is None:
            tc = time.perf_counter() - t0

        done_c = self.timeline.occupy(
            self._res_cloud, self._boundary_ready_s[g], tc
        )
        self._m_group_ready[g] = self._metric_clock.occupy(
            "cloud", self._m_boundary_ready[g], tc
        )
        self._stage_busy["cloud"] += tc
        self._group_ready_s[g] = done_c
        active = self._active[gs:ge]
        n_tok_active = int(pend["n_valid"][active].sum())
        # variable-k downlink: one verify id per valid position of each
        # active row (the plain path's one id per row, scaled by k)
        self.link.record_down(n_tok_active * element_bytes(jnp.int32))

        self._boundary[g] = None
        self._phase[g] = "ready"
        self._spec_pending[g] = None
        return {
            "g": g, "kind": "spec", "done_c": done_c,
            "dev": (pend["drafts"], ids_dev), "pend": pend,
        }

    def _spec_commit(self, rec: Dict, drafts: np.ndarray,
                     verify: np.ndarray) -> int:
        """Host side of a speculative round, after the batched transfer:
        greedy accept per row, roll provisional pages past the committed
        prefix back in BOTH pools (lockstep preserved — the entry lists
        were asserted equal at map time), commit the accepted tokens, and
        feed the acceptance EMA."""
        g = rec["g"]
        pend = rec["pend"]
        gs, ge = self._group_slices[g]
        base_len = pend["base_len"]
        active = self._active[gs:ge]
        nv_eff = np.where(active, pend["n_valid"], 0)
        committed, _nrej = batched_accept(drafts, verify, nv_eff)
        emitted = 0
        n_drafted = n_accepted = 0
        rolled = False
        for i, slot in enumerate(range(gs, ge)):
            if not active[i]:
                continue
            toks = committed[i]
            n_commit = len(toks)  # >= 1: row 0's verify id always commits
            L = int(base_len[i])
            rb = rollback_entries(
                pend["new_entries_end"].get(slot, []),
                base_len=L, n_commit=n_commit,
                page_size=self.page_size,
                pages_per_slot=self.pages_per_slot,
            )
            if rb:
                self.end_pool.rollback(slot, rb)
                self.cloud_pool.rollback(self._cslot(slot), rb)
                rolled = True
            self._slot_len[slot] = L + n_commit
            if self._draft_ready[slot]:
                # the accepted prefix is, by the accept rule, exactly what
                # the draft scan wrote — the draft cache stays aligned
                self._draft_len[slot] = L + n_commit
            n_drafted += int(nv_eff[i]) - 1
            n_accepted += n_commit - 1
            emitted += self._harvest_tokens(slot, toks)
        if self._spec_state is not None:
            self._spec_state.observe_round(
                n_drafted, n_accepted,
                rolled_back=rolled or n_accepted < n_drafted,
            )
        return emitted

    def _spec_abort(self, g: int):
        """Drop an in-flight speculative round (lane death / boundary
        drop): every provisionally-mapped page unmaps, nothing commits.
        The group's slot state is untouched — still at the pre-round token
        boundary, exactly like a dropped plain boundary."""
        pend = self._spec_pending[g]
        if pend is None:
            return
        for slot, ents in pend["new_entries_end"].items():
            if ents:
                self.end_pool.rollback(slot, ents)
        for slot, ents in pend["new_entries_cloud"].items():
            if ents:
                self.cloud_pool.rollback(self._cslot(slot), ents)
        self._spec_pending[g] = None
        gs, ge = self._group_slices[g]
        self._draft_ready[gs:ge] = False
        if self._spec_state is not None:
            self._spec_state.rollbacks += 1

    # -- admission: chunked prefill as a pipeline stage -----------------------

    def _group_of(self, slot: int) -> int:
        return slot // self._group_size

    def _slot_usable(self, slot: int) -> bool:
        # padding slots (batch rounded up to equal groups) never admit;
        # slots mid-prefill are spoken for
        return slot < self.request_capacity and slot not in self._jobs

    def _pages_for(self, req: Request) -> int:
        return kvcache.pages_needed(
            len(req.prompt) + req.max_new_tokens,
            self.page_size, self.pages_per_slot,
        )

    def _page_capacity(self):
        return min(self.end_pool.num_pages, self.cloud_pool.num_pages)

    def _admit(self):
        """Admit waiting requests in ``_admission_order`` (priority class,
        then submission seq — see ``SlotEngineBase``): reserve the
        request's worst-case page count in BOTH tier pools (admission is
        page-aware — a free slot without pages stays idle), then either
        start a chunked-prefill job or, for a previously preempted request,
        restore its spilled KV and resume decode in place.  The order head
        blocks its whole order (admitting past a page-blocked head would
        keep pages occupied and starve it); when the blocked head outranks
        running work and preemption is on, a strictly lower-priority slot
        is spilled to make room and admission retries."""
        while True:
            self._admit_pass()
            if self.preemption and self._try_preempt():
                continue  # a victim was spilled: the head may now admit
            break

    def _admit_pass(self) -> int:
        admitted = 0
        free = [
            s for s in range(self.max_batch)
            if self.slots[s] is None and self._slot_usable(s)
        ]
        for req in self._admission_order():
            spilled = req.request_id in self._spilled
            # restores activate their slot immediately, which is only safe
            # while the slot's group has no boundary in flight (engine
            # ticks admit with every group drained; direct _admit calls
            # may not)
            usable = [
                s for s in free
                if not spilled or self._phase[self._group_of(s)] == "ready"
            ]
            if not usable:
                break
            need = self._pages_for(req)
            if not (
                self.end_pool.can_reserve(need)
                and self.cloud_pool.can_reserve(need)
            ):
                break
            slot = usable[0]
            free.remove(slot)
            self.waiting.remove(req)
            if spilled:
                # PagePool.restore_slot re-reserves internally
                self._restore_into_slot(slot, req)
            else:
                self.end_pool.reserve(slot, need)
                self.cloud_pool.reserve(self._cslot(slot), need)
                job = _PrefillJob(req, slot, self._group_of(slot))
                if self._virtual_time:
                    # prefill cannot start before the request arrived
                    job.ready_s = req.submit_time
                self._jobs[slot] = job
            admitted += 1
        return admitted

    # -- preemption: spill a low-priority slot at the drained safe point ------

    def preemptible_slots(self, priority: int) -> int:
        """How many running victims a request of class ``priority`` could
        evict: active decode slots of strictly lower classes (prefill jobs
        are never preempted — see ``_try_preempt``).  Zero when preemption
        is off.  The fleet frontend adds this to a lane's admission
        capacity so a high-priority request is dispatched into a full lane
        instead of parking behind it."""
        if not self.preemption:
            return 0
        return sum(
            1 for s in range(self.max_batch)
            if self.slots[s] is not None and self.slots[s].priority > priority
        )

    def _try_preempt(self) -> bool:
        """If the admission head outranks running work and cannot be
        admitted, evict one victim — the youngest decoding slot of the
        lowest priority class strictly below the head's, its KV spilled
        via the page tables and restored intact on re-admission.  Only
        *running* (decoding) slots are victims: an in-flight prefill job
        is short and bounded, and cancelling it would discard its finished
        chunks — evicting prefill under sustained interactive pressure
        livelocks the low-priority class (it re-runs the same chunks
        forever) without buying latency.  Returns True iff a victim was
        evicted; ``_admit`` then retries, evicting further victims if one
        was not enough."""
        queue = self._admission_order()
        if not queue:
            return False
        head = queue[0]
        victims = [
            s for s in range(self.max_batch)
            if self.slots[s] is not None
            and self.slots[s].priority > head.priority
        ]
        if not victims:
            return False
        # feasibility: even evicting every candidate must cover the head's
        # page needs in both pools, else the spills are wasted churn
        need = self._pages_for(head)
        e_avail = self.end_pool.pages_available + sum(
            self.end_pool.reserved_pages(s) for s in victims
        )
        c_avail = self.cloud_pool.pages_available + sum(
            self.cloud_pool.reserved_pages(self._cslot(s)) for s in victims
        )
        if e_avail < need or c_avail < need:
            return False
        # victim choice is deterministic: lowest class, youngest arrival
        _, _, victim = max(
            (self.slots[s].priority, self.slots[s].seq, s) for s in victims
        )
        self._preempt_slot(victim)
        return True

    def _spill_slot_state(self, slot: int) -> _SpillState:
        """Spill mechanics shared by in-lane preemption and lane-death
        migration: copy the slot's mapped page rows off both tier storages
        (merged across tiers in block order — see ``_SpillState``), free
        the slot and both reservations.  Only called with the slot's group
        drained, so ``_slot_len``/``_next_token`` are at a token boundary:
        the pending token's KV is not yet written, exactly the state a
        fresh activation leaves behind.  The caller owns the request's
        re-queue and the counter bookkeeping."""
        entries_e, phys_e, n_pages = self.end_pool.spill_slot(slot)
        entries_c, phys_c, _ = self.cloud_pool.spill_slot(self._cslot(slot))
        if not np.array_equal(entries_e, entries_c):
            raise RuntimeError(
                f"tier pools out of lockstep for slot {slot}: "
                f"{entries_e.tolist()} vs {entries_c.tolist()}"
            )
        ie = jnp.asarray(phys_e, jnp.int32)
        ic = jnp.asarray(phys_c, jnp.int32)
        end_part = jax.tree.map(lambda l: np.asarray(l[:, ie]), self._end_pages)
        cloud_part = jax.tree.map(
            lambda l: np.asarray(l[:, ic]), self._cloud_pages
        )
        blocks = jax.tree.map(
            lambda a, b: np.concatenate([a, b], axis=0), end_part, cloud_part
        )
        st = _SpillState(
            entries_e, blocks, int(self._slot_len[slot]),
            int(self._next_token[slot, 0]), n_pages,
        )
        self.slots[slot] = None
        self._active[slot] = False
        self._slot_len[slot] = 0
        self._draft_ready[slot] = False
        return st

    def _preempt_slot(self, slot: int):
        """Spill a decoding slot and re-queue its request with the spilled
        KV parked under its request id for in-lane restoration."""
        req = self.slots[slot]
        st = self._spill_slot_state(slot)
        self._spilled[req.request_id] = st
        self.preempt_spill_bytes += st.nbytes
        req.n_preemptions += 1
        self.n_preemptions += 1
        self.waiting.append(req)

    def _restore_into_slot(self, slot: int, req: Request):
        """Re-admit a preempted request: both pools have re-reserved its
        original page count; map its spilled entries, scatter the saved
        page data into the new physical rows split at the *current* tier
        boundary, and resume decode mid-stream — the token stream continues
        bit-identically because page contents are byte-exact copies and
        attention reads entries, not physical rows."""
        st = self._spilled.pop(req.request_id)
        phys_e = self.end_pool.restore_slot(slot, st.entries, st.n_pages)
        phys_c = self.cloud_pool.restore_slot(
            self._cslot(slot), st.entries, st.n_pages
        )
        s = self.split
        ie = jnp.asarray(phys_e, jnp.int32)
        ic = jnp.asarray(phys_c, jnp.int32)
        self._end_pages = jax.tree.map(
            lambda l, d: l.at[:, ie].set(jnp.asarray(d[:s], l.dtype)),
            self._end_pages, st.blocks,
        )
        self._cloud_pages = jax.tree.map(
            lambda l, d: l.at[:, ic].set(jnp.asarray(d[s:], l.dtype)),
            self._cloud_pages, st.blocks,
        )
        self._slot_len[slot] = st.length
        self.slots[slot] = req
        self._next_token[slot, 0] = st.next_token
        self._active[slot] = True
        # the draft cache did not travel with the spill; rebuild it at the
        # next drained tick (_spec_refresh_drafts) if speculation is on
        self._draft_ready[slot] = False
        if st.migrated:
            self.n_migration_restores += 1
            req.n_migrations += 1
        else:
            self.n_preempt_restores += 1
        if self._virtual_time:
            # the resumed stream cannot decode before "now"
            g = self._group_of(slot)
            self._group_ready_s[g] = max(
                self._group_ready_s[g], self.clock.now
            )

    def evacuate(self) -> Tuple[List[Request], Dict[str, _SpillState], int]:
        """Lane death: spill every in-flight decode slot through the
        preemption path (KV page blocks are placement-invariant, so a
        surviving lane with a *different* split restores them bit-exactly),
        restart in-flight prefill jobs from scratch (their first token is
        never in ``generated`` before activation, so a re-run is
        exactly-once clean), and hand everything back to the fleet for
        re-placement.  In-flight boundaries are dropped — the slot state is
        still at the pre-step token boundary until the cloud stage lands,
        so the migrated lane simply recomputes the lost step.  Returns
        ``(requests in submission order, request_id -> spill state,
        spilled bytes at stored size)``."""
        for g in range(len(self._phase)):
            # an in-flight speculative round must unmap its provisional
            # pages BEFORE the spill walks the page tables — spilling them
            # would smuggle unverified KV into the migrated state
            self._spec_abort(g)
            self._boundary[g] = None
            self._phase[g] = "ready"
        spilled: Dict[str, _SpillState] = {}
        nbytes = 0
        for slot in range(self.max_batch):
            req = self.slots[slot]
            if req is None:
                continue
            st = self._spill_slot_state(slot)
            st.migrated = True
            spilled[req.request_id] = st
            nbytes += st.nbytes
            self.waiting.append(req)
        for slot in sorted(self._jobs):
            job = self._jobs.pop(slot)
            self._release_slot(slot)
            self.waiting.append(job.req)
        for rid, st in self._spilled.items():
            # previously preempted on this lane: its parked KV migrates too
            st.migrated = True
            spilled[rid] = st
            nbytes += st.nbytes
        self._spilled = {}
        reqs = sorted(self.waiting, key=lambda r: r.seq)
        self.waiting = []
        return reqs, spilled, nbytes

    def _advance_prefill(self, job: _PrefillJob):
        """Stream one prompt chunk through end -> link -> cloud, booking the
        same ``StageTimeline`` resources as decode (prefill is pipeline
        occupancy, not a stall)."""
        req, slot = job.req, job.slot
        S = len(req.prompt)
        C = self.prefill_chunk
        p0 = job.pos
        v = min(C, S - p0)
        self.end_pool.map_range(slot, p0, p0 + v)
        self.cloud_pool.map_range(self._cslot(slot), p0, p0 + v)
        chunk = np.zeros((C,), np.int32)
        chunk[:v] = req.prompt[p0 : p0 + v]
        tokens = jnp.asarray(chunk)[None]
        start = jnp.asarray([p0], jnp.int32)
        valid = jnp.asarray([v], jnp.int32)

        eargs = (
            (self._emask_dev, self._eres()) if self._expert_pooled else ()
        )
        t0 = time.perf_counter()
        z, self._end_pages = self._end_prefill_chunk(
            self.end_params, tokens, self._end_pages,
            self.end_pool.device_rows([slot]), start, valid, *eargs,
        )
        payload_block_until_ready(z)
        te = self._stage_seconds("end", v)
        if te is None:
            te = time.perf_counter() - t0

        # meter only the valid rows: padding never crosses the wire.  A
        # quantized boundary is a (codes, scale) tuple — both cross the wire
        nbytes = sum(
            int(l.dtype.itemsize * int(np.prod(l.shape[2:]))) * v
            for l in (z if isinstance(z, tuple) else (z,))
        )
        t_comm = self._link_transfer(nbytes)

        t1 = time.perf_counter()
        ids, self._cloud_pages = self._cloud_prefill_chunk(
            self.cloud_params, z, self._cloud_pages,
            self.cloud_pool.device_rows([self._cslot(slot)]), start, valid,
        )
        ids.block_until_ready()
        tc = self._stage_seconds("cloud", v)
        if tc is None:
            tc = time.perf_counter() - t1

        done_e = self.timeline.occupy(self._res_end, job.ready_s, te)
        done_l = self.timeline.occupy(self._res_link, done_e, t_comm)
        done_c = self.timeline.occupy(self._res_cloud, done_l, tc)
        job.ready_s = done_c
        self._prefill_busy["end"] += te
        self._prefill_busy["link"] += t_comm
        self._prefill_busy["cloud"] += tc
        self.n_prefill_chunks += 1

        job.pos += v
        if job.pos >= S:
            # stash the DEVICE scalar; the tick's single batched
            # device->host transfer resolves it (_resolve_prefill_tokens)
            job.first_tok_dev = ids[0]
            # first token id back to the end tier
            self.link.record_down(element_bytes(jnp.int32))

    def _resolve_prefill_tokens(self):
        """Resolve every finished prefill job's first-token device scalar
        in ONE batched device->host transfer — per-job ``int(...)`` pulls
        were a per-request host sync on the prefill critical path."""
        pend = [
            (slot, job)
            for slot, job in sorted(self._jobs.items())
            if job.first_tok_dev is not None
        ]
        if not pend:
            return
        host = jax.device_get([job.first_tok_dev for _, job in pend])
        self.n_host_syncs += 1
        for (_slot, job), tok in zip(pend, host):
            job.first_tok = int(tok)
            job.first_tok_dev = None

    def _activate_ready_jobs(self):
        """Finished prefill jobs claim their slot at the group's next
        drained tick (never while the group's boundary is in flight: the
        pending cloud-step must see the pre-activation batch state)."""
        for slot in sorted(self._jobs):
            job = self._jobs[slot]
            if job.first_tok is None or self._phase[job.group] != "ready":
                continue
            req, tok = job.req, job.first_tok
            req.generated.append(tok)
            if self._virtual_time:
                # stamp on the modeled axis: the first token exists when
                # the last prefill chunk drains the cloud stage
                self.clock.now = job.ready_s
            if req.first_token_time is None:
                req.first_token_time = self.clock()
            del self._jobs[slot]
            if tok == req.eos_id or len(req.generated) >= req.max_new_tokens:
                req.finish_time = self.clock()
                self.finished.append(req)
                self._release_slot(slot)
                continue
            self._slot_len[slot] = len(req.prompt)
            self.slots[slot] = req
            self._next_token[slot, 0] = tok
            self._active[slot] = True
            if self._spec_plan_k > 1:
                self._install_draft(slot)
            if self._virtual_time:
                # the group's next decode step cannot start before this
                # request's prefill finished feeding it
                self._group_ready_s[job.group] = max(
                    self._group_ready_s[job.group], job.ready_s
                )

    def _release_slot(self, slot: int):
        self.end_pool.free(slot)
        self.cloud_pool.free(self._cslot(slot))
        self._slot_len[slot] = 0
        self._draft_ready[slot] = False

    def busy(self) -> bool:
        return super().busy() or bool(self._jobs)

    def _progress_sig(self) -> tuple:
        # pipeline stages, prefill chunks, spill/restore churn and retries
        # all count as forward progress — only a tick that moves *none* of
        # these is a livelock candidate
        return super()._progress_sig() + (
            self.n_stage_steps,
            self.n_prefill_chunks,
            self.n_preemptions,
            self.n_preempt_restores,
            self.n_migration_restores,
            self.transfer_retries,
            self.n_expert_prefetches if self._expert_pooled else 0,
            self._spec_state.rounds if self._spec_state else 0,
            self._spec_state.rollbacks if self._spec_state else 0,
        )

    def stall_diagnostic(self) -> str:
        return (
            super().stall_diagnostic()
            + f" jobs={sorted(self._jobs)} spilled={len(self._spilled)}"
            + f" phases={list(self._phase)}"
            + f" pages_end={self.end_pool.pages_available}"
            + f" pages_cloud={self.cloud_pool.pages_available}"
            + f" link_degraded={self.link_degraded}"
        )

    # -- pipelined stepping ---------------------------------------------------

    def _group_active(self, g: int) -> bool:
        gs, ge = self._group_slices[g]
        return bool(self._active[gs:ge].any())

    def _stage_seconds(self, stage: str, batch: int) -> Optional[float]:
        """Modeled per-step service time for ``timing="modeled"`` (None in
        measured mode): batch tokens through this tier's block range at the
        device's capability rate.  The cloud rate is un-share-scaled back to
        one server — contention across fleet lanes is the timeline's job
        (multi-server queue), not the service time's."""
        if self.timing != "modeled":
            return None
        lg = self.tiers.layer_gflops
        s = self.split
        if stage == "end":
            gflops = batch * sum(lg[:s])
            rate = self.tiers.end_cap.gflop_budget * 1e3
        else:
            gflops = batch * sum(lg[s:])
            rate = (
                self.tiers.cloud_cap.gflop_budget
                / max(self._cloud_share, 1e-12)
                * 1e3
            )
        return gflops / max(rate, 1e-9)

    def _link_transfer(self, nbytes: int) -> float:
        """Meter one boundary upload, retrying injected transfer failures
        under the health monitor's bounded exponential backoff.  Every
        resend crosses the wire again, so the failed attempts' bytes are
        metered honestly rather than vanishing from the traffic report.
        Raises after ``max_transfer_attempts`` — a link that eats every
        retry is a blackout, and wedging silently here is exactly the
        failure mode the stall guard exists to catch."""
        # the per-transfer round trip (propagation + handshake) rides on
        # every attempt — it is precisely what speculative decode amortizes
        # over k tokens in the link-bound regime
        total = self.link_rtt_s + self.link.record_up(nbytes, self.bw.gbps)
        attempt = 0
        while self._transfer_faults > 0:
            self._transfer_faults -= 1
            if attempt + 1 >= self.health.max_transfer_attempts:
                raise RuntimeError(
                    f"boundary transfer failed {attempt + 1} times "
                    f"(max_transfer_attempts="
                    f"{self.health.max_transfer_attempts}); link presumed dead"
                )
            total += self.health.backoff_s(attempt)
            total += self.link_rtt_s + self.link.record_up(nbytes, self.bw.gbps)
            self.transfer_retries += 1
            attempt += 1
        return total

    def inject_transfer_faults(self, count: int):
        """Arm ``count`` boundary-transfer failures: each upcoming upload
        consumes pending faults one per attempt, retrying with backoff."""
        if count < 1:
            raise ValueError(f"count must be >= 1, got {count}")
        self._transfer_faults += count

    def _run_end_stage(self, g: int):
        k = self._spec_round_k(g)
        if k > 1:
            self._run_end_stage_spec(g, k)
            return
        gs, ge = self._group_slices[g]
        for slot in range(gs, ge):
            if self._active[slot]:
                self.end_pool.append(slot, int(self._slot_len[slot]))
                self.cloud_pool.append(self._cslot(slot), int(self._slot_len[slot]))
        tokens = jnp.asarray(self._next_token[gs:ge])
        table = self.end_pool.device_rows(
            range(gs, ge), active=self._active[gs:ge]
        )
        lengths = jnp.asarray(self._slot_len[gs:ge], jnp.int32)
        t0 = time.perf_counter()
        if self._expert_pooled:
            z, self._end_pages, stats = self._end_step(
                self.end_params, tokens, self._end_pages, table, lengths,
                self._emask_dev, self._eres(),
            )
        elif self._route_stats_enabled:
            z, self._end_pages, stats = self._end_step(
                self.end_params, tokens, self._end_pages, table, lengths
            )
        else:
            z, self._end_pages = self._end_step(
                self.end_params, tokens, self._end_pages, table, lengths
            )
            stats = None
        payload_block_until_ready(z)
        te = self._stage_seconds("end", ge - gs)
        if te is None:
            te = time.perf_counter() - t0
        if stats is not None:
            self._observe_route_stats(stats)

        # meter only active slots' boundary rows: inactive and padding
        # slots' activations never cross the wire (matches the prefill
        # valid-rows metering and the active-only token downlink)
        per_row = sum(
            int(l.size // l.shape[0] * l.dtype.itemsize)
            for l in (z if isinstance(z, tuple) else (z,))
        )
        n_active = int(self._active[gs:ge].sum())
        nbytes = per_row * n_active
        t_comm = self._link_transfer(nbytes)
        if self._expert_pooled:
            # per-lane routed-token weight for the fleet's expert_hit_rate
            # (tokens that actually exercised the pooled end tier)
            self.expert_routed_tokens += n_active

        done_e = self.timeline.occupy(self._res_end, self._group_ready_s[g], te)
        done_l = self.timeline.occupy(self._res_link, done_e, t_comm)
        m_e = self._metric_clock.occupy("end", self._m_group_ready[g], te)
        self._m_boundary_ready[g] = self._metric_clock.occupy("link", m_e, t_comm)
        self._stage_busy["end"] += te
        self._stage_busy["link"] += t_comm
        self.n_stage_steps += 1

        self._boundary[g] = z
        self._boundary_ready_s[g] = done_l
        self._phase[g] = "boundary"

    def _drain_cloud_stage(self, g: int) -> Dict:
        """Run the cloud half of an in-flight boundary and return a drain
        record.  The token ids stay ON DEVICE — ``_harvest_drained``
        resolves every group's ids in one batched transfer per tick, so a
        lane with four groups pays one host sync where it paid four."""
        if self._spec_pending[g] is not None:
            return self._drain_cloud_stage_spec(g)
        gs, ge = self._group_slices[g]
        z = self._boundary[g]
        table = self.cloud_pool.device_rows(
            [self._cslot(s) for s in range(gs, ge)],
            active=self._active[gs:ge],
        )
        lengths = jnp.asarray(self._slot_len[gs:ge], jnp.int32)
        t0 = time.perf_counter()
        ids_dev, self._cloud_pages = self._cloud_step(
            self.cloud_params, z, self._cloud_pages, table, lengths
        )
        ids_dev.block_until_ready()
        tc = self._stage_seconds("cloud", ge - gs)
        if tc is None:
            tc = time.perf_counter() - t0

        done_c = self.timeline.occupy(self._res_cloud, self._boundary_ready_s[g], tc)
        self._m_group_ready[g] = self._metric_clock.occupy(
            "cloud", self._m_boundary_ready[g], tc
        )
        self._stage_busy["cloud"] += tc
        self._group_ready_s[g] = done_c
        n_active = int(self._active[gs:ge].sum())
        # token ids back to the end tier — only slots that actually decoded
        # (inactive slots send nothing; metering them overcharged the link)
        self.link.record_down(n_active * element_bytes(jnp.int32))

        self._boundary[g] = None
        self._phase[g] = "ready"

        active_idx = np.nonzero(self._active[gs:ge])[0] + gs
        self._slot_len[active_idx] += 1
        return {"g": g, "kind": "plain", "done_c": done_c, "dev": (ids_dev,)}

    def _harvest_drained(self, records: List[Dict]) -> int:
        """Host side of the tick's drained boundaries: ONE batched
        device->host transfer for every group's token ids (and, for
        speculative rounds, the draft tokens), then per-group commit in
        drain order — plain groups harvest directly, speculative groups go
        through accept/rollback (:meth:`_spec_commit`)."""
        host = jax.device_get([rec["dev"] for rec in records])
        self.n_host_syncs += 1
        emitted = 0
        for rec, dev in zip(records, host):
            if self._virtual_time:
                # finish stamps for this group land at its cloud completion
                self.clock.now = rec["done_c"]
            if rec["kind"] == "plain":
                gs, ge = self._group_slices[rec["g"]]
                ids = np.zeros((self.max_batch,), np.int64)
                ids[gs:ge] = np.asarray(dev[0])
                emitted += self._harvest(ids, slot_range=range(gs, ge))
            else:
                drafts, verify = (np.asarray(a) for a in dev)
                emitted += self._spec_commit(rec, drafts, verify)
        return emitted

    def _run_cloud_stage(self, g: int) -> int:
        """Drain one group's boundary and harvest immediately — the
        single-group form (tests and targeted drains); ``step`` batches
        all drained groups through one ``_harvest_drained`` call."""
        return self._harvest_drained([self._drain_cloud_stage(g)])

    def step(self) -> int:
        """One engine tick: drain in-flight boundaries on the cloud tier,
        apply a pending replan at the safe point, admit (page-aware), stream
        one prefill chunk per in-flight job, activate finished jobs, then
        refill the end tier — so group A's cloud-step overlaps group B's
        end-step and a long prompt's prefill never stalls other groups'
        decode."""
        emitted = 0
        if self.link_degraded:
            self.degraded_ticks += 1
        drained = [
            self._drain_cloud_stage(g)
            for g in range(self.n_groups)
            if self._phase[g] == "boundary"
        ]
        if drained:
            emitted += self._harvest_drained(drained)
        self._advance_expert_prefetch()
        self._apply_pending_replan()
        self._admit()
        for slot in sorted(self._jobs):
            job = self._jobs[slot]
            if job.first_tok is None and job.first_tok_dev is None:
                self._advance_prefill(job)
        self._resolve_prefill_tokens()
        if self._spec_plan_k > 1:
            self._spec_refresh_drafts()
        self._activate_ready_jobs()
        for g in range(self.n_groups):
            if self._phase[g] == "ready" and self._group_active(g):
                self._run_end_stage(g)
        return emitted

    # -- dynamic replanning ---------------------------------------------------

    def observe_bandwidth(self, gbps: float, *, hard: bool = False):
        """Feed a link measurement (e.g. from a probe or the paper's TC
        setup); triggers a replan check against measured conditions.
        ``hard=True`` bypasses the EWMA — a *declared* link event (chaos
        injection, a blackout beginning or ending) is a fact, not a noisy
        sample, and must take effect at the next safe point rather than
        after the estimator converges."""
        if hard:
            self.bw.set_rate(gbps)
            # the blackout ladder keys on DECLARED rates only: a soft EWMA
            # observation — however low — is a measurement the ordinary
            # replanner answers (e.g. by moving to a compressed interior
            # split; see benchmarks.fleet_throughput phase 2), not a
            # declared wire-down event
            self._update_link_health()
        else:
            self.bw.observe_rate(gbps)
        if not self.link_degraded:
            self._check_replan()
        # the draft-length plan tracks the same measured link conditions:
        # a fattening link turns speculation off (compute-bound), a
        # thinning one turns it on or lengthens the draft
        self._recompute_spec_plan()

    def _update_link_health(self):
        """Degradation ladder, bottom rung: when the estimated link rate
        falls below ``blackout_gbps``, pin the plan to split 0 (cloud-only;
        the boundary payload collapses to token ids) instead of letting the
        planner keep an interior split that would wedge every boundary
        behind a dead wire.  The planner itself would not choose this —
        boundary bytes are split-independent, so it sees no gain — which is
        why the rung is explicit policy, not planning.  On recovery the
        normal replan path resumes and unwinds the pin at the next safe
        point."""
        blacked = self.bw.gbps < self.blackout_gbps
        if blacked and not self.link_degraded:
            self.link_degraded = True
            self._blackout_since = self.clock()
            plan = plan_pipeline_split(
                self.tiers.layer_gflops,
                self.tiers.boundary_bytes,
                dataclasses.replace(self.tiers.end_cap, net_gbps=self.bw.gbps),
                self.tiers.cloud_cap,
                compression_ratio=self.tiers.compression_ratio,
                alpha=self.tiers.alpha,
                edge_boundary=True,
                pin_split=0,
            )
            self._pending_plan = plan
        elif not blacked and self.link_degraded:
            self.link_degraded = False
            self.link_blackout_s += max(0.0, self.clock() - self._blackout_since)
            self._check_replan(force=True)

    def blackout_seconds(self) -> float:
        """Total wall-clock spent under a blacked-out link, including a
        still-open window."""
        open_s = (
            max(0.0, self.clock() - self._blackout_since)
            if self.link_degraded
            else 0.0
        )
        return self.link_blackout_s + open_s

    def set_cloud_share(self, share: float):
        """Re-scale this lane's slice of the total cloud budget (a cloud
        server died or rejoined).  Per-server service time in
        ``_stage_seconds`` is unchanged — budget and share scale together —
        but the planner's view of aggregate cloud capacity shrinks, so the
        split may move at the next safe point."""
        old = max(self._cloud_share, 1e-12)
        self.tiers = dataclasses.replace(
            self.tiers,
            cloud_cap=dataclasses.replace(
                self.tiers.cloud_cap,
                gflop_budget=self.tiers.cloud_cap.gflop_budget * share / old,
            ),
        )
        self._cloud_share = share
        if not self.link_degraded:
            self._check_replan()

    def update_device_state(self, end_state: DeviceState):
        """Feed a new end-device state vector (eq. 2): re-derive the end
        capability AND the hardware-aware expert mask (eq. 2-4), then
        re-check the plan.  Mask changes are applied at the same safe point
        as split changes."""
        new_mask = self._derive_end_mask(end_state)
        # same loud rejection as the construction-time boundary: a state so
        # degraded that eq. 4 admits nothing must not silently become a
        # uniform-renormalized gate (dense) or all-garbage routing (pooled).
        # Validated before any engine state moves, so a rejected update
        # leaves the running plan untouched.
        validate_expert_mask(
            new_mask,
            self.cfg.moe.num_experts if self.cfg.moe is not None else None,
            where="update_device_state(end_mask)",
        )
        self.end_state = end_state
        self.tiers = dataclasses.replace(
            self.tiers, end_cap=capability(self.end_profile, end_state)
        )
        mask_changed = not _masks_equal(new_mask, self.tiers.end_mask)
        if mask_changed:
            self._pending_mask = new_mask
        else:
            # latest state agrees with the applied mask: cancel any pending
            # change from an earlier (now recovered-from) observation
            self._pending_mask = _KEEP
        if self._expert_pooled:
            # the memory budget (slab capacity) may have moved even when the
            # eq. 4 mask did not: reconcile at the next safe point, and start
            # any newly-needed slab transfers NOW so they overlap decode.
            # The capacity is adopted immediately — set_capacity never
            # evicts, so raising it unblocks transfers during the window
            # before the safe point, and lowering it only pauses allocs
            # until the safe-point evictions land
            self._expert_dirty = True
            self.expert_pool.set_capacity(self._expert_capacity())
            target = np.asarray(
                new_mask if mask_changed else self.tiers.end_mask, bool
            )
            wanted, _ev = self._plan_residency(self._active_lids(), target)
            self._prefetch_queue = list(wanted)
        # The state vector's B_bw component is a link observation only when
        # it reports a non-default value; a default-constructed 1.0 means
        # "not measured" and must not overwrite probe readings fed through
        # observe_bandwidth (report recovery explicitly via either channel).
        if end_state.bandwidth_free != 1.0:
            self.bw.observe_rate(self.tiers.end_cap.net_gbps)
        self._check_replan(force=mask_changed)

    def _check_replan(self, force: bool = False):
        if self.link_degraded:
            # the degradation ladder owns the plan while the link is dark:
            # the pinned split-0 plan must not be displaced by a replan
            # computed from a near-zero rate (mask changes still flow
            # through _pending_mask and the safe point as usual)
            return
        # planning inputs come from TierPlan so replanning uses exactly the
        # cost model the initial plan was computed with
        plan, changed = replan_pipeline(
            self.plan,
            self.tiers.layer_gflops,
            self.tiers.boundary_bytes,
            self.tiers.end_cap,
            self.tiers.cloud_cap,
            measured_gbps=self.bw.gbps,
            compression_ratio=self.tiers.compression_ratio,
            alpha=self.tiers.alpha,
            rel_threshold=self.replan_threshold,
            edge_boundary=True,
        )
        trace_changed = (
            plan.split_layer != self.plan.split_layer
            or plan.compress_boundary != self.plan.compress_boundary
        )
        if changed or trace_changed or force:
            # needs the drained safe point (and possibly a re-split/rebuild)
            self._pending_plan = plan
        else:
            # current split/codec stand: drop any stale pending change and
            # adopt the refreshed estimates in place (nothing a trace
            # captures differs, so no rebuild is needed)
            self._pending_plan = None
            self.tiers = dataclasses.replace(self.tiers, plan=plan)

    def _defrag_private_pools(self):
        """Compact the engine-private pools and permute their storage rows
        to match.  A fleet-shared cloud pool is never defragged here — its
        permutation would have to be applied to every lane's storage (see
        ``FleetServingEngine.defrag_kv``)."""
        perm = self.end_pool.defrag()
        self._end_pages = jax.tree.map(
            lambda l: l[:, jnp.asarray(perm)], self._end_pages
        )
        if not self._cloud_shared:
            perm = self.cloud_pool.defrag()
            self._cloud_pages = jax.tree.map(
                lambda l: l[:, jnp.asarray(perm)], self._cloud_pages
            )

    def _apply_pending_replan(self):
        """Adopt a pending plan/mask once no boundary is in flight (both
        tiers at equal ``lengths``): re-split params at the new block
        boundary, move the affected blocks' pages between the tier pools
        (table-aware row permutation), defrag the private pools, and rebuild
        the stage functions — but only when something a trace captures
        (split, codec flag, expert mask) actually changed."""
        if (
            self._pending_plan is None
            and self._pending_mask is _KEEP
            and not (self._expert_pooled and self._expert_dirty)
        ):
            return
        if any(p == "boundary" for p in self._phase):
            return
        had_pending = (
            self._pending_plan is not None or self._pending_mask is not _KEEP
        )
        plan = self._pending_plan or self.plan
        self._pending_plan = None
        old_split = self.split
        old_compress = self.tiers.compress
        mask_changed = self._pending_mask is not _KEEP
        updates: Dict = {"plan": plan}
        if mask_changed:
            updates["end_mask"] = self._pending_mask
            self._pending_mask = _KEEP
        self.tiers = dataclasses.replace(self.tiers, **updates)
        if mask_changed:
            # the draft model speculates under the end mask: a new mask
            # invalidates every draft cache (they hold old-mask KV)
            self._draft_ready[:] = False
        if self.split != old_split:
            self.end_params, self.cloud_params = split_block_params(
                self.params, self.split
            )
            if self._expert_pooled:
                self.end_params = strip_expert_weights(self.end_params, self.cfg)
            cloud_rows = self.cloud_pool.table[
                self._cloud_base : self._cloud_base + self.max_batch
            ]
            e2c = kvcache.page_perm(
                self.end_pool.table, cloud_rows,
                self.end_pool.num_pages, self.cloud_pool.num_pages,
            )
            c2e = kvcache.page_perm(
                cloud_rows, self.end_pool.table,
                self.cloud_pool.num_pages, self.end_pool.num_pages,
            )
            self._end_pages, self._cloud_pages = kvcache.resplit_paged_blocks(
                self._end_pages, self._cloud_pages, old_split, self.split,
                e2c, c2e,
            )
            self._defrag_private_pools()
        if self._expert_pooled:
            # blocks entering the end tier materialize their target
            # residents with the (unmetered) block re-split; every other
            # residency change rides the prefetch queue / eviction plan
            instant = set()
            if self.split > old_split:
                R = self.cfg.block_repeat
                instant = {
                    pi * R + b
                    for pi in range(len(self._moe_pos))
                    for b in range(old_split, self.split)
                }
            self._expert_sync(instant_lids=instant)
        if (
            self.split != old_split
            or self.tiers.compress != old_compress
            or (mask_changed and not self._expert_pooled)
        ):
            # pooled engines take the mask/tables as runtime operands, so a
            # mask-only change needs no rebuild (and no retrace)
            self._build_stage_fns()
        else:
            self._recompute_spec_plan()
        if had_pending:
            self.replan_events.append(
                {
                    "old_split": old_split,
                    "new_split": self.split,
                    "measured_gbps": self.bw.gbps,
                    "compress": self.tiers.compress,
                    "mask_changed": mask_changed,
                }
            )

    # -- metrics --------------------------------------------------------------

    def stage_trace_counts(self) -> Dict[str, int]:
        """Distinct compiled-trace signatures per stage function, summed
        across stage-function rebuilds.  Bounded by chunk/group shapes —
        independent of how many distinct prompt lengths were served."""
        return {k: len(v) for k, v in self._traces.items()}

    def attn_bytes_step(self) -> Dict[str, int]:
        """KV bytes the attention sweep moves from HBM per decode step
        (both tiers, all layers) at the current occupancy.  The fused paged
        path reads only this engine's *mapped* pages; the dense-gather path
        it replaced materialized and swept the full ``slots x ring`` view
        every step (counted as one sweep read — the gather's extra HBM
        write of the same bytes is not charged, so the comparison is
        conservative; the dense baseline uses the user-visible slot count,
        matching ``kv_bytes_dense_equiv``).  The dense baseline is priced at
        the dense page size (``kvcache.dense_page_bytes``) regardless of the
        stored pool's dtype — quantizing the pool must shrink the numerator,
        never the denominator."""
        own_cloud = range(self._cloud_base, self._cloud_base + self.max_batch)
        end_pb = kvcache.paged_block_bytes(self._end_pages)
        cloud_pb = kvcache.paged_block_bytes(self._cloud_pages)
        dense_pb = self._dense_page_bytes()
        return {
            "attn_bytes_paged_step": (
                self.end_pool.pages_in_use * end_pb
                + self.cloud_pool.mapped_for(own_cloud) * cloud_pb
            ),
            "attn_bytes_dense_step": (
                self.request_capacity * self.pages_per_slot * dense_pb
            ),
        }

    def _dense_page_bytes(self) -> int:
        """Per-page bytes across both tiers at the dense KV dtype (the
        stable denominator for the quantized pools' capacity ratio)."""
        R = self.cfg.block_repeat
        return kvcache.dense_page_bytes(
            self.cfg, self.split, self.page_size
        ) + kvcache.dense_page_bytes(
            self.cfg, R - self.split, self.page_size
        )

    def _expert_hit_rate(self) -> float:
        """Route-frequency-weighted residency coverage of the current
        target set: 1.0 once every target expert of every active end layer
        is resident.  Frequencies are the measured EMA plus a uniform
        ``1/E`` prior, so experts the target just admitted (no traffic
        measured yet — they could not be routed to) still register as
        misses until their slab lands."""
        if not self._expert_pooled:
            return 1.0
        E = self.cfg.moe.num_experts
        f = (
            self._route_freq if self._route_freq is not None
            else np.zeros((E,))
        ) + 1.0 / E
        t = self._target_mask_np()
        num = den = 0.0
        for lid in self._active_lids():
            r = self.expert_pool.resident_mask(lid)
            num += float(f[t & r].sum())
            den += float(f[t].sum())
        return 1.0 if den == 0.0 else num / den

    def expert_metrics(self) -> Dict[str, float]:
        """Paged expert-weight accounting: residency, hit rate, transfer
        traffic, and the per-decode-step expert HBM bytes the resident
        gather moves vs the dense ``[E, d, f]`` sweep it replaced (the
        garbage slab — one shared zeros row — is not charged)."""
        if not self._expert_pooled:
            return {}
        pool = self.expert_pool
        active = self._active_lids()
        sb = self._slab_bytes
        sbd = self._slab_bytes_dense
        E = self.cfg.moe.num_experts
        n_res_active = sum(pool.resident_count(lid) for lid in active)
        return {
            "expert_resident_slabs": pool.slabs_in_use,
            "expert_slab_capacity": pool.capacity,
            "expert_hit_rate": self._expert_hit_rate(),
            "expert_bytes_down": self.expert_bytes_down,
            "expert_bytes_peer": self.expert_bytes_peer,
            "expert_bytes_up": self.expert_bytes_up,
            "expert_bytes_resident": pool.slabs_in_use * sb,
            "expert_bytes_step_resident": n_res_active * sb,
            # the dense sweep baseline holds full-precision weights — it
            # must not shrink when the slab store is quantized
            "expert_bytes_step_dense": len(active) * E * sbd,
            "expert_slab_bytes": sb,
            "expert_slab_bytes_dense": sbd,
            # effective capacity: how many stored slabs fit per dense slab
            "expert_capacity_ratio": sbd / sb,
            "expert_quantized": float(self.quantize_experts),
            "expert_prefetches": self.n_expert_prefetches,
            "expert_peer_fetches": self.n_expert_peer_fetches,
            "expert_evictions": self.n_expert_evictions,
            "expert_routed_tokens": self.expert_routed_tokens,
        }

    def kv_metrics(self) -> Dict[str, float]:
        """Paged-KV memory accounting.  With a fleet-shared cloud pool the
        in-use/capacity figures for the cloud tier count only this lane's
        rows; ``kv_bytes_peak`` uses the pools' global peaks (the shared
        pool peaks fleet-wide — that is the number admission gates on)."""
        own_cloud = range(self._cloud_base, self._cloud_base + self.max_batch)
        end_pb = kvcache.paged_block_bytes(self._end_pages)
        cloud_pb = kvcache.paged_block_bytes(self._cloud_pages)
        dense_pb = self._dense_page_bytes()
        in_use = self.end_pool.pages_in_use + self.cloud_pool.mapped_for(own_cloud)
        cap = self.end_pool.num_pages + self.cloud_pool.num_pages
        return {
            **self.attn_bytes_step(),
            "kv_pages_in_use": in_use,
            "kv_pages_capacity": cap,
            "kv_utilization": in_use / cap,
            "kv_bytes_peak": (
                self.end_pool.peak_in_use * end_pb
                + self.cloud_pool.peak_in_use * cloud_pb
            ),
            # the honest pre-refactor baseline: dense rings at the dense
            # dtype for the user-visible slot count (padding slots and the
            # quantized pool layout are this repo's artifacts)
            "kv_bytes_dense_equiv": (
                self.request_capacity * self.pages_per_slot * dense_pb
            ),
            "kv_page_bytes": end_pb + cloud_pb,
            "kv_page_bytes_dense": dense_pb,
            # effective capacity: how many stored pages fit per dense page
            "kv_capacity_ratio": dense_pb / (end_pb + cloud_pb),
            "kv_quantized": float(self.quantize_kv),
        }

    def metrics(self) -> Dict[str, float]:
        n = max(self.n_stage_steps, 1)
        mean = {r: t / n for r, t in self._stage_busy.items()}
        # This engine's own pipelined DECODE span, from the decode-only
        # metric clock: free of other lanes' time when the timeline is
        # fleet-shared, and free of interleaved prefill-chunk occupancy.
        # serial likewise sums only this engine's decode stages.
        pipelined_total = max(self._m_group_ready)
        serial_total = sum(self._stage_busy.values())
        return {
            "split": self.split,
            "compressed": self.tiers.compress,
            "boundary_quantized": float(self.quantize_boundary),
            "n_groups": self.n_groups,
            "bytes_up": self.link.bytes_up,
            "transfers": self.link.transfers,
            "n_stage_steps": self.n_stage_steps,
            "mean_t_end_s": mean["end"],
            "mean_t_comm_s": mean["link"],
            "mean_t_cloud_s": mean["cloud"],
            # serial layout vs the pipelined resource-occupancy schedule
            "serial_step_s": mean["end"] + mean["link"] + mean["cloud"],
            "pipelined_step_s": pipelined_total / n,
            "plan_est_step_s": self.plan.est_step_time_s,
            "pipelined_total_s": pipelined_total,
            "serial_total_s": serial_total,
            "prefill_s": sum(self._prefill_busy.values()),
            "prefill_chunks": self.n_prefill_chunks,
            "preemptions": self.n_preemptions,
            "preempt_restores": self.n_preempt_restores,
            "preempt_spill_bytes": self.preempt_spill_bytes,
            "migration_restores": self.n_migration_restores,
            "transfer_retries": self.transfer_retries,
            "degraded_ticks": self.degraded_ticks,
            "link_blackout_s": self.blackout_seconds(),
            "replan_events": len(self.replan_events),
            "measured_gbps": self.bw.gbps,
            "n_host_syncs": self.n_host_syncs,
            "spec_plan_k": self._spec_plan_k,
            "spec_k_eff": (
                self._spec_state.k_eff if self._spec_state is not None else 1
            ),
            **(
                self._spec_state.metrics()
                if self._spec_state is not None
                else {
                    "spec_rounds": 0,
                    "spec_drafted": 0,
                    "spec_accepted": 0,
                    "spec_acceptance_rate": 0.0,
                    "spec_rollbacks": 0,
                }
            ),
            **self.kv_metrics(),
            **self.expert_metrics(),
        }
