"""Streaming end-cloud decode engine (tentpole of the PO-ECC reproduction).

``EndCloudServingEngine`` is the continuous-batching ``ServingEngine``
re-expressed as a *two-tier token pipeline*: each decode step is split at
the route-aware plan's block boundary (eq. 9-11) — blocks ``[0, split)`` and
the embedding run on the end tier (with the hardware-aware expert mask,
eq. 2-4), the boundary activation is low-rank compressed (eq. 8) and metered
through ``LinkStats``, and blocks ``[split, R)`` plus the LM head run on the
cloud tier.  The per-slot KV cache is split the same way
(``kvcache.split_cache``): the end tier holds the ring buffers of its
blocks, the cloud holds the rest, and each advances its own ``lengths``.

**Pipelining.**  The decode batch is partitioned into ``n_groups``
interleaved micro-batch groups, each with its own boundary buffer (the
double buffer).  A group alternates between two phases: its end-step writes
the boundary buffer, and — one engine tick later — the cloud-step drains it
and feeds the next token back.  While group A's boundary is in flight /
being decoded on the cloud, group B occupies the end tier, so in steady
state every stage is busy every tick and the per-step time approaches
``max(t_end, t_comm, t_cloud)`` (``PipelinePlan.est_step_time_s``) instead
of the serial sum.  Stage compute times are *measured* on this host, link
times are modeled from the metered bytes and the (possibly drifting)
bandwidth, and the overlap is accounted by ``StageTimeline`` — the same
resource-occupancy model as ``sim.simulator``, so the schedule is exactly
what a two-host deployment would realize with these stage times.

**Replanning.**  Link measurements arrive through ``observe_bandwidth``
(an external probe, or — in a real two-host deployment — per-transfer
(bytes, seconds) samples fed to ``BandwidthEstimator.observe``; in-process
the wire is modeled, so there is nothing to self-measure) and device drift
through ``update_device_state``, which also re-derives the end tier's
expert mask from the new state vector (eq. 2-4).  Either trigger re-runs
the split search against measured conditions
(``core.pipeline.replan_pipeline``).  A changed plan or mask is applied at
the next safe point — all boundary buffers drained, both tiers at equal
``lengths`` — by merging the per-tier caches, re-splitting params and
caches at the new block boundary, and rebuilding the stage functions.
In-flight generations continue bit-exactly across a pure re-split (the
merge/re-split is a relayout; a mask change intentionally alters routing).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compression as comp
from repro.core.hardware import DeviceProfile, DeviceState, capability
from repro.core.pipeline import BandwidthEstimator, PipelinePlan, replan_pipeline
from repro.models import attention as attn_mod
from repro.models import kvcache, transformer
from repro.models.model import Model
from repro.serving.common import LinkStats, Request, SlotEngineBase, StageTimeline
from repro.serving.endcloud import (
    TierPlan,
    end_mask_from_state,
    plan_tiers,
    split_block_params,
)

__all__ = ["EndCloudServingEngine"]

_KEEP = object()  # sentinel: "no pending mask change"


def _masks_equal(a, b) -> bool:
    if a is None or b is None:
        return a is b
    return bool(jnp.array_equal(a, b))


class EndCloudServingEngine(SlotEngineBase):
    def __init__(
        self,
        model: Model,
        params: Dict,
        *,
        end_profile: DeviceProfile,
        cloud_profile: DeviceProfile,
        end_state: Optional[DeviceState] = None,
        codec_params: Optional[Dict] = None,  # 1-D low-rank codec {"enc","dec"}
        compression_rank: int = 0,
        alpha: float = 0.5,
        selection_eps: float = 1.0,
        max_batch: int = 8,
        max_len: int = 512,
        n_groups: int = 2,
        force_split: Optional[int] = None,
        replan_threshold: float = 0.15,
        clock: Optional[Callable[[], float]] = None,
        timeline: Optional[StageTimeline] = None,
        resources: Tuple[str, str, str] = ("end", "link", "cloud"),
        cloud_share: float = 1.0,
        timing: str = "measured",
    ):
        super().__init__(max_batch, clock, max_len=max_len)
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.end_profile = end_profile
        self.cloud_profile = cloud_profile
        self.end_state = end_state or DeviceState()
        self.selection_eps = selection_eps
        self.replan_threshold = replan_threshold

        self.tiers: TierPlan = plan_tiers(
            model,
            end_profile=end_profile,
            cloud_profile=cloud_profile,
            end_state=self.end_state,
            end_mask=self._derive_end_mask(self.end_state),
            codec_params=codec_params,
            compression_rank=compression_rank,
            alpha=alpha,
            selection_eps=selection_eps,
            force_split=force_split,
            cloud_share=cloud_share,
        )
        self.end_params, self.cloud_params = split_block_params(params, self.split)

        self.link = LinkStats()
        self.bw = BandwidthEstimator(self.tiers.end_cap.net_gbps)
        # ``timeline``/``resources`` let a fleet share one occupancy clock:
        # each device brings its own end/link resources while every device's
        # cloud stage queues on one shared (possibly multi-server) resource.
        self._res_end, self._res_link, self._res_cloud = resources
        if timeline is None:
            timeline = StageTimeline(resources)
        else:
            for r in resources:
                timeline.add_resource(r)
        self.timeline = timeline
        # ``timing="measured"`` (default) feeds the timeline this host's
        # wall-clock stage times; ``"modeled"`` substitutes the planner's
        # capability cost model (gflops / device budget) — tokens are still
        # computed for real, but the schedule is deterministic and honors
        # the *declared* device speeds, which one host cannot reproduce.
        # Heterogeneous-fleet benchmarks use "modeled".
        if timing not in ("measured", "modeled"):
            raise ValueError(f"timing={timing!r}")
        self.timing = timing
        self._cloud_share = cloud_share
        self.replan_events: List[Dict] = []
        self._pending_plan: Optional[PipelinePlan] = None
        self._pending_mask = _KEEP

        # Micro-batch groups: interleaved slot ranges, one boundary buffer
        # (the double buffer) per group.
        self.n_groups = max(1, min(n_groups, max_batch))
        bounds = np.linspace(0, max_batch, self.n_groups + 1).astype(int)
        self._group_slices = [
            (int(bounds[g]), int(bounds[g + 1])) for g in range(self.n_groups)
        ]
        dtype = jnp.dtype(self.cfg.dtype)
        self._end_cache: List[Dict] = []
        self._cloud_cache: List[Dict] = []
        for gs, ge in self._group_slices:
            full = kvcache.init_cache(self.cfg, ge - gs, max_len, dtype)
            ec, cc = kvcache.split_cache(full, self.split)
            self._end_cache.append(ec)
            self._cloud_cache.append(cc)
        self._phase = ["ready"] * self.n_groups  # "ready" | "boundary"
        self._boundary: List[Optional[jax.Array]] = [None] * self.n_groups
        self._boundary_ready_s = [0.0] * self.n_groups  # modeled arrival time
        self._group_ready_s = [0.0] * self.n_groups  # modeled token-ready time

        self.n_stage_steps = 0  # decode end-steps (== drained cloud-steps)
        # This engine's own stage seconds (the timeline's busy_s would mix in
        # other lanes' cloud time when the cloud resource is fleet-shared).
        self._stage_busy = {"end": 0.0, "link": 0.0, "cloud": 0.0}
        self._prefill_busy = {"end": 0.0, "link": 0.0, "cloud": 0.0}
        self._build_stage_fns()

    # -- the active plan lives on self.tiers; everything else delegates ------

    def _derive_end_mask(self, end_state: DeviceState):
        """Hardware-aware expert mask for this end device (eq. 2-4).  One
        derivation shared by initial tier planning and replan-time state
        updates; the fleet lane overrides it with the fleet-mask semantics
        (``selection.shard_masks_for_fleet``'s never-empty guarantee)."""
        return end_mask_from_state(
            self.cfg, self.end_profile, end_state, selection_eps=self.selection_eps
        )

    @property
    def plan(self) -> PipelinePlan:
        return self.tiers.plan

    @property
    def split(self) -> int:
        return self.tiers.plan.split_layer

    # -- stage functions (rebuilt on every replan so the captured split /
    # -- codec flags can never go stale in a cached trace) --------------------

    def _build_stage_fns(self):
        cfg = self.cfg
        topo = self.model.topo
        tiers = self.tiers
        codec, compress, end_mask = tiers.codec, tiers.compress, tiers.end_mask
        act = jnp.dtype(cfg.dtype)

        def decode_angles(lengths, B):
            pos = lengths[:, None]
            if cfg.mrope_sections is not None:
                pos = jnp.broadcast_to(pos[:, None], (B, 3, 1))
            return attn_mod.rope_angles(
                pos, cfg.head_dim, cfg.rope_theta, cfg.mrope_sections
            )

        def prefill_angles(B, S):
            pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
            if cfg.mrope_sections is not None:
                pos = jnp.broadcast_to(pos[:, None], (B, 3, S))
            return attn_mod.rope_angles(
                pos, cfg.head_dim, cfg.rope_theta, cfg.mrope_sections
            )

        def end_step(end_params, tokens, cache):
            lengths = cache["lengths"]
            angles = decode_angles(lengths, tokens.shape[0])
            x = transformer.embed_inputs(end_params, cfg, tokens)
            x, new_blocks, _ = transformer.apply_stack_decode(
                end_params, x, cfg, topo, angles, cache["blocks"], lengths,
                expert_mask=end_mask,
            )
            z = comp.encode_1d(codec, x) if compress else x
            return z, {"blocks": new_blocks, "lengths": lengths + 1}

        def cloud_step(cloud_params, z, cache):
            lengths = cache["lengths"]
            angles = decode_angles(lengths, z.shape[0])
            x = comp.decode_1d(codec, z) if compress else z
            x = x.astype(act)
            x, new_blocks, _ = transformer.apply_stack_decode(
                cloud_params, x, cfg, topo, angles, cache["blocks"], lengths,
                expert_mask=None,
            )
            logits = transformer.lm_logits(cloud_params, cfg, x)[:, 0]
            return logits, {"blocks": new_blocks, "lengths": lengths + 1}

        def end_prefill(end_params, tokens):
            B, S = tokens.shape
            angles = prefill_angles(B, S)
            x = transformer.embed_inputs(end_params, cfg, tokens)
            x, _, cache_blocks = transformer.apply_stack_full(
                x=x, params=end_params, cfg=cfg, topo=topo, angles=angles,
                causal=True, expert_mask=end_mask, train=False,
                collect_cache=True, max_len=self.max_len,
            )
            z = comp.encode_1d(codec, x) if compress else x
            cache = {
                "blocks": cache_blocks,
                "lengths": jnp.full((B,), S, jnp.int32),
            }
            return z, cache

        def cloud_prefill(cloud_params, z):
            B, S = z.shape[:2]
            angles = prefill_angles(B, S)
            x = comp.decode_1d(codec, z) if compress else z
            x = x.astype(act)
            x, _, cache_blocks = transformer.apply_stack_full(
                x=x, params=cloud_params, cfg=cfg, topo=topo, angles=angles,
                causal=True, expert_mask=None, train=False,
                collect_cache=True, max_len=self.max_len,
            )
            logits = transformer.lm_logits(cloud_params, cfg, x[:, -1:])[:, 0]
            cache = {
                "blocks": cache_blocks,
                "lengths": jnp.full((B,), S, jnp.int32),
            }
            return logits, cache

        self._end_step = jax.jit(end_step)
        self._cloud_step = jax.jit(cloud_step)
        self._end_prefill = jax.jit(end_prefill)
        self._cloud_prefill = jax.jit(cloud_prefill)
        self._warmup_stage_fns()

    def _warmup_stage_fns(self):
        """Compile the decode stage functions for every group shape so
        measured stage times reflect steady-state compute, not tracing."""
        seen = set()
        for g, (gs, ge) in enumerate(self._group_slices):
            if ge - gs in seen:
                continue
            seen.add(ge - gs)
            tokens = jnp.zeros((ge - gs, 1), jnp.int32)
            z, _ = self._end_step(self.end_params, tokens, self._end_cache[g])
            logits, _ = self._cloud_step(self.cloud_params, z, self._cloud_cache[g])
            logits.block_until_ready()

    # -- admission (both tiers prefilled; boundary metered) -------------------

    def _group_of(self, slot: int) -> int:
        for g, (gs, ge) in enumerate(self._group_slices):
            if gs <= slot < ge:
                return g
        raise ValueError(slot)

    def _admittable(self, slot: int) -> bool:
        # Never admit into a group whose boundary is in flight: the pending
        # cloud-step was traced against the pre-admission batch state.
        return self._phase[self._group_of(slot)] == "ready"

    def _prefill_into_slot(self, slot: int, req: Request):
        g = self._group_of(slot)
        tokens = jnp.asarray(req.prompt, jnp.int32)[None, :]

        t0 = time.perf_counter()
        z, end_one = self._end_prefill(self.end_params, tokens)
        z.block_until_ready()
        te = time.perf_counter() - t0

        nbytes = int(z.size * z.dtype.itemsize)
        t_comm = self.link.record_up(nbytes, self.bw.gbps)

        t1 = time.perf_counter()
        logits, cloud_one = self._cloud_prefill(self.cloud_params, z)
        logits.block_until_ready()
        tc = time.perf_counter() - t1

        # Prefill is accounted separately: the StageTimeline tracks only the
        # steady-state decode schedule (prefill wall time includes per-shape
        # tracing, which would drown the overlap signal).
        self._prefill_busy["end"] += te
        self._prefill_busy["link"] += t_comm
        self._prefill_busy["cloud"] += tc
        self.link.record_down(4)  # first token back to the end tier
        return int(jnp.argmax(logits[0])), (g, end_one, cloud_one)

    def _install_slot(self, slot: int, payload):
        g, end_one, cloud_one = payload
        gs, _ = self._group_slices[g]
        self._end_cache[g] = kvcache.install_slot(self._end_cache[g], slot - gs, end_one)
        self._cloud_cache[g] = kvcache.install_slot(
            self._cloud_cache[g], slot - gs, cloud_one
        )

    # -- pipelined stepping ---------------------------------------------------

    def _group_active(self, g: int) -> bool:
        gs, ge = self._group_slices[g]
        return bool(self._active[gs:ge].any())

    def _stage_seconds(self, stage: str, batch: int) -> Optional[float]:
        """Modeled per-step service time for ``timing="modeled"`` (None in
        measured mode): batch tokens through this tier's block range at the
        device's capability rate.  The cloud rate is un-share-scaled back to
        one server — contention across fleet lanes is the timeline's job
        (multi-server queue), not the service time's."""
        if self.timing != "modeled":
            return None
        lg = self.tiers.layer_gflops
        s = self.split
        if stage == "end":
            gflops = batch * sum(lg[:s])
            rate = self.tiers.end_cap.gflop_budget * 1e3
        else:
            gflops = batch * sum(lg[s:])
            rate = (
                self.tiers.cloud_cap.gflop_budget
                / max(self._cloud_share, 1e-12)
                * 1e3
            )
        return gflops / max(rate, 1e-9)

    def _run_end_stage(self, g: int):
        gs, ge = self._group_slices[g]
        tokens = jnp.asarray(self._next_token[gs:ge])
        t0 = time.perf_counter()
        z, self._end_cache[g] = self._end_step(
            self.end_params, tokens, self._end_cache[g]
        )
        z.block_until_ready()
        te = self._stage_seconds("end", ge - gs)
        if te is None:
            te = time.perf_counter() - t0

        nbytes = int(z.size * z.dtype.itemsize)
        t_comm = self.link.record_up(nbytes, self.bw.gbps)

        done_e = self.timeline.occupy(self._res_end, self._group_ready_s[g], te)
        done_l = self.timeline.occupy(self._res_link, done_e, t_comm)
        self._stage_busy["end"] += te
        self._stage_busy["link"] += t_comm
        self.n_stage_steps += 1

        self._boundary[g] = z
        self._boundary_ready_s[g] = done_l
        self._phase[g] = "boundary"

    def _run_cloud_stage(self, g: int) -> int:
        gs, ge = self._group_slices[g]
        z = self._boundary[g]
        t0 = time.perf_counter()
        logits, self._cloud_cache[g] = self._cloud_step(
            self.cloud_params, z, self._cloud_cache[g]
        )
        logits.block_until_ready()
        tc = self._stage_seconds("cloud", ge - gs)
        if tc is None:
            tc = time.perf_counter() - t0

        done_c = self.timeline.occupy(self._res_cloud, self._boundary_ready_s[g], tc)
        self._stage_busy["cloud"] += tc
        self._group_ready_s[g] = done_c
        self.link.record_down((ge - gs) * 4)  # token ids back to the end tier

        self._boundary[g] = None
        self._phase[g] = "ready"

        ids = np.zeros((self.max_batch,), np.int64)
        ids[gs:ge] = np.asarray(jnp.argmax(logits, -1))
        return self._harvest(ids, slot_range=range(gs, ge))

    def step(self) -> int:
        """One engine tick: drain in-flight boundaries on the cloud tier,
        apply a pending replan at the safe point, admit, then refill the end
        tier — so group A's cloud-step overlaps group B's end-step."""
        emitted = 0
        for g in range(self.n_groups):
            if self._phase[g] == "boundary":
                emitted += self._run_cloud_stage(g)
        self._apply_pending_replan()
        self._admit()
        for g in range(self.n_groups):
            if self._phase[g] == "ready" and self._group_active(g):
                self._run_end_stage(g)
        return emitted

    # -- dynamic replanning ---------------------------------------------------

    def observe_bandwidth(self, gbps: float):
        """Feed a link measurement (e.g. from a probe or the paper's TC
        setup); triggers a replan check against measured conditions."""
        self.bw.observe_rate(gbps)
        self._check_replan()

    def update_device_state(self, end_state: DeviceState):
        """Feed a new end-device state vector (eq. 2): re-derive the end
        capability AND the hardware-aware expert mask (eq. 2-4), then
        re-check the plan.  Mask changes are applied at the same safe point
        as split changes."""
        self.end_state = end_state
        self.tiers = dataclasses.replace(
            self.tiers, end_cap=capability(self.end_profile, end_state)
        )
        new_mask = self._derive_end_mask(end_state)
        mask_changed = not _masks_equal(new_mask, self.tiers.end_mask)
        if mask_changed:
            self._pending_mask = new_mask
        else:
            # latest state agrees with the applied mask: cancel any pending
            # change from an earlier (now recovered-from) observation
            self._pending_mask = _KEEP
        # The state vector's B_bw component is a link observation only when
        # it reports a non-default value; a default-constructed 1.0 means
        # "not measured" and must not overwrite probe readings fed through
        # observe_bandwidth (report recovery explicitly via either channel).
        if end_state.bandwidth_free != 1.0:
            self.bw.observe_rate(self.tiers.end_cap.net_gbps)
        self._check_replan(force=mask_changed)

    def _check_replan(self, force: bool = False):
        # planning inputs come from TierPlan so replanning uses exactly the
        # cost model the initial plan was computed with
        plan, changed = replan_pipeline(
            self.plan,
            self.tiers.layer_gflops,
            self.tiers.boundary_bytes,
            self.tiers.end_cap,
            self.tiers.cloud_cap,
            measured_gbps=self.bw.gbps,
            compression_ratio=self.tiers.compression_ratio,
            alpha=self.tiers.alpha,
            rel_threshold=self.replan_threshold,
            edge_boundary=True,
        )
        trace_changed = (
            plan.split_layer != self.plan.split_layer
            or plan.compress_boundary != self.plan.compress_boundary
        )
        if changed or trace_changed or force:
            # needs the drained safe point (and possibly a re-split/rebuild)
            self._pending_plan = plan
        else:
            # current split/codec stand: drop any stale pending change and
            # adopt the refreshed estimates in place (nothing a trace
            # captures differs, so no rebuild is needed)
            self._pending_plan = None
            self.tiers = dataclasses.replace(self.tiers, plan=plan)

    def _apply_pending_replan(self):
        """Adopt a pending plan/mask once no boundary is in flight (both
        tiers at equal ``lengths``): merge the per-tier caches, re-split
        params and caches at the new block boundary, and rebuild the stage
        functions — but only when something a trace captures (split, codec
        flag, expert mask) actually changed."""
        if self._pending_plan is None and self._pending_mask is _KEEP:
            return
        if any(p == "boundary" for p in self._phase):
            return
        plan = self._pending_plan or self.plan
        self._pending_plan = None
        old_split = self.split
        old_compress = self.tiers.compress
        mask_changed = self._pending_mask is not _KEEP
        updates: Dict = {"plan": plan}
        if mask_changed:
            updates["end_mask"] = self._pending_mask
            self._pending_mask = _KEEP
        self.tiers = dataclasses.replace(self.tiers, **updates)
        if self.split != old_split:
            self.end_params, self.cloud_params = split_block_params(
                self.params, self.split
            )
            for g in range(self.n_groups):
                merged = kvcache.merge_cache(self._end_cache[g], self._cloud_cache[g])
                self._end_cache[g], self._cloud_cache[g] = kvcache.split_cache(
                    merged, self.split
                )
        if (
            self.split != old_split
            or self.tiers.compress != old_compress
            or mask_changed
        ):
            self._build_stage_fns()
        self.replan_events.append(
            {
                "old_split": old_split,
                "new_split": self.split,
                "measured_gbps": self.bw.gbps,
                "compress": self.tiers.compress,
                "mask_changed": mask_changed,
            }
        )

    # -- metrics --------------------------------------------------------------

    def metrics(self) -> Dict[str, float]:
        n = max(self.n_stage_steps, 1)
        mean = {r: t / n for r, t in self._stage_busy.items()}
        # This engine's own pipelined span: when the last cloud drain of
        # every group has landed (== the timeline makespan for a private
        # timeline, but free of other lanes' time when the timeline is
        # fleet-shared).  serial likewise sums only this engine's stages.
        pipelined_total = max(self._group_ready_s)
        serial_total = sum(self._stage_busy.values())
        return {
            "split": self.split,
            "compressed": self.tiers.compress,
            "n_groups": self.n_groups,
            "bytes_up": self.link.bytes_up,
            "transfers": self.link.transfers,
            "n_stage_steps": self.n_stage_steps,
            "mean_t_end_s": mean["end"],
            "mean_t_comm_s": mean["link"],
            "mean_t_cloud_s": mean["cloud"],
            # serial layout vs the pipelined resource-occupancy schedule
            "serial_step_s": mean["end"] + mean["link"] + mean["cloud"],
            "pipelined_step_s": pipelined_total / n,
            "plan_est_step_s": self.plan.est_step_time_s,
            "pipelined_total_s": pipelined_total,
            "serial_total_s": serial_total,
            "prefill_s": sum(self._prefill_busy.values()),
            "replan_events": len(self.replan_events),
            "measured_gbps": self.bw.gbps,
        }
