"""Model facade: init / train_logits / prefill / decode_step + input_specs.

``input_specs(cfg, cell)`` produces ShapeDtypeStruct stand-ins for every
model input of an (architecture x shape) cell — the dry-run lowers against
these, so no host memory is ever allocated for the full-size models.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeCell
from repro.distributed.topology import Topology, single_device_topology
from repro.models import attention as attn
from repro.models import kvcache, transformer


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    topo: Topology = field(default_factory=single_device_topology)

    # -- init ---------------------------------------------------------------

    def init(self, rng) -> Dict:
        return transformer.init_params(rng, self.cfg)

    # -- helpers ------------------------------------------------------------

    def _angles(self, positions):
        return attn.rope_angles(
            positions, self.cfg.head_dim, self.cfg.rope_theta, self.cfg.mrope_sections
        )

    def _default_positions(self, B, S):
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        if self.cfg.mrope_sections is not None:
            pos = jnp.broadcast_to(pos[:, None], (B, 3, S))
        return pos

    def _embed(self, params, batch):
        cfg = self.cfg
        x = transformer.embed_inputs(
            params, cfg, batch["tokens"], batch.get("patch_embeds")
        )
        B, S = x.shape[0], x.shape[1]
        positions = batch.get("positions")
        if positions is None:
            positions = self._default_positions(B, S)
        return x, self._angles(positions)

    def _encoder_out(self, params, batch):
        if not self.cfg.encoder_decoder:
            return None
        return transformer.apply_encoder(
            params, batch["frame_embeds"], self.cfg, self.topo
        )

    # -- full-sequence forward (training) ------------------------------------

    def train_logits(
        self, params, batch: Dict, *, expert_mask=None, train: bool = True
    ) -> Tuple[jax.Array, Dict]:
        x, angles = self._embed(params, batch)
        enc_out = self._encoder_out(params, batch)
        x, aux, _ = transformer.apply_stack_full(
            params, x, self.cfg, self.topo, angles,
            causal=True, enc_out=enc_out, expert_mask=expert_mask, train=train,
        )
        return transformer.lm_logits(params, self.cfg, x), aux

    # -- serving ------------------------------------------------------------

    def prefill(
        self, params, batch: Dict, *, max_len: int = 0, expert_mask=None
    ) -> Tuple[jax.Array, Dict]:
        """Returns (logits of the last position [B, V], cache)."""
        cfg = self.cfg
        x, angles = self._embed(params, batch)
        B, S = x.shape[0], x.shape[1]
        max_len = max_len or S
        enc_out = self._encoder_out(params, batch)
        x, aux, cache_blocks = transformer.apply_stack_full(
            params, x, cfg, self.topo, angles,
            causal=True, enc_out=enc_out, expert_mask=expert_mask,
            train=False, collect_cache=True, max_len=max_len,
        )
        logits = transformer.lm_logits(params, cfg, x[:, -1:])[:, 0]
        cache = {
            "blocks": cache_blocks,
            "lengths": jnp.full((B,), S, jnp.int32),
        }
        return logits, cache

    def decode_step(
        self, params, tokens: jax.Array, cache: Dict, *, expert_mask=None
    ) -> Tuple[jax.Array, Dict]:
        """tokens: [B, 1] -> (logits [B, V], new cache)."""
        cfg = self.cfg
        lengths = cache["lengths"]
        B = tokens.shape[0]
        pos = lengths[:, None]  # current position of the new token
        if cfg.mrope_sections is not None:
            pos = jnp.broadcast_to(pos[:, None], (B, 3, 1))
        angles = self._angles(pos)
        x = transformer.embed_inputs(params, cfg, tokens)
        x, new_blocks, aux = transformer.apply_stack_decode(
            params, x, cfg, self.topo, angles, cache["blocks"], lengths,
            expert_mask=expert_mask,
        )
        logits = transformer.lm_logits(params, cfg, x)[:, 0]
        return logits, {"blocks": new_blocks, "lengths": lengths + 1}

    # -- paged serving (page-table-aware decode + chunked prefill) -----------

    def decode_step_paged(
        self, params, tokens: jax.Array, page_blocks: Dict,
        page_table: jax.Array, lengths: jax.Array, *,
        page_size: int, expert_mask=None, expert_resident=None,
    ) -> Tuple[jax.Array, Dict]:
        """tokens [B, 1] against a paged KV cache -> (logits [B, V],
        new page blocks).  Per-slot ``lengths`` advances host-side (the
        engine owns slot offsets) and threads down to the fused paged
        attention, which masks each slot's ring positions against it and
        reads only the mapped pages — no dense ring view is gathered; the
        trace depends only on shapes, never on the page table contents."""
        cfg = self.cfg
        B = tokens.shape[0]
        pos = lengths[:, None]
        if cfg.mrope_sections is not None:
            pos = jnp.broadcast_to(pos[:, None], (B, 3, 1))
        angles = self._angles(pos)
        x = transformer.embed_inputs(params, cfg, tokens)
        x, new_blocks, _ = transformer.apply_stack_decode(
            params, x, cfg, self.topo, angles, page_blocks, lengths,
            expert_mask=expert_mask, page_table=page_table, page_size=page_size,
            expert_resident=expert_resident,
        )
        logits = transformer.lm_logits(params, cfg, x)[:, 0]
        return logits, new_blocks

    def prefill_chunk_step(
        self, params, tokens: jax.Array, page_blocks: Dict,
        page_table: jax.Array, start: jax.Array, n_valid: jax.Array, *,
        page_size: int, expert_mask=None, expert_resident=None,
    ) -> Tuple[jax.Array, Dict]:
        """One fixed-size prompt chunk (tokens [B, C], rows past ``n_valid``
        are padding) written into the paged cache at positions
        ``start + i`` -> (logits of the last valid row [B, V], new page
        blocks).  Per-slot ring anchors (``start + n_valid - 1``) thread
        down to the fused paged chunk attention, which sweeps mapped pages
        directly instead of gathering the ring."""
        cfg = self.cfg
        B, C = tokens.shape
        positions = start[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]
        pos = positions
        if cfg.mrope_sections is not None:
            pos = jnp.broadcast_to(pos[:, None], (B, 3, C))
        angles = self._angles(pos)
        x = transformer.embed_inputs(params, cfg, tokens)
        x, new_blocks = transformer.apply_stack_prefill_chunk(
            params, x, cfg, self.topo, angles, page_blocks, page_table,
            positions, n_valid, page_size, expert_mask=expert_mask,
            expert_resident=expert_resident,
        )
        x_last = x[jnp.arange(B), jnp.maximum(n_valid - 1, 0)][:, None]
        logits = transformer.lm_logits(params, cfg, x_last)[:, 0]
        return logits, new_blocks

    def verify_chunk_step(
        self, params, tokens: jax.Array, page_blocks: Dict,
        page_table: jax.Array, start: jax.Array, n_valid: jax.Array, *,
        page_size: int, expert_mask=None, expert_resident=None,
    ) -> Tuple[jax.Array, Dict]:
        """Speculative-verify chunk: same chunked forward as
        :meth:`prefill_chunk_step` (tokens [B, C] written at ``start + i``,
        rows past ``n_valid`` are padding) but returns the logits of EVERY
        position -> (logits [B, C, V], new page blocks).  Position i's
        logits predict the token at ``start + i + 1``, so the caller can
        compare each drafted token against the model's own next-token
        choice and find the first rejection.  Padding rows carry garbage
        logits — callers mask by ``n_valid``."""
        cfg = self.cfg
        B, C = tokens.shape
        positions = start[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]
        pos = positions
        if cfg.mrope_sections is not None:
            pos = jnp.broadcast_to(pos[:, None], (B, 3, C))
        angles = self._angles(pos)
        x = transformer.embed_inputs(params, cfg, tokens)
        x, new_blocks = transformer.apply_stack_prefill_chunk(
            params, x, cfg, self.topo, angles, page_blocks, page_table,
            positions, n_valid, page_size, expert_mask=expert_mask,
            expert_resident=expert_resident,
        )
        logits = transformer.lm_logits(params, cfg, x)
        return logits, new_blocks


def build_model(cfg: ModelConfig, topo: Optional[Topology] = None) -> Model:
    return Model(cfg, topo or single_device_topology())


# ---------------------------------------------------------------------------
# Input specs (dry-run stand-ins) and dummy batches (smoke tests)
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, cell: ShapeCell) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of a shape cell.

    train/prefill: {"tokens", "labels"?, modality extras}
    decode:        {"tokens" [B,1], "cache": <pytree of SDS>}
    """
    B, S = cell.global_batch, cell.seq_len
    act = jnp.dtype(cfg.dtype)
    specs: Dict[str, Any] = {}
    if cell.mode in ("train", "prefill"):
        S_text = S
        if cfg.vision_patches:
            P = cfg.vision_patches
            S_text = S - P
            specs["patch_embeds"] = _sds((B, P, cfg.d_model), act)
            specs["positions"] = _sds((B, 3, S), jnp.int32)
        specs["tokens"] = _sds((B, S_text), jnp.int32)
        if cfg.encoder_decoder:
            specs["frame_embeds"] = _sds((B, cfg.encoder_seq_len, cfg.d_model), act)
        if cell.mode == "train":
            specs["labels"] = _sds((B, S), jnp.int32)
    else:  # decode: one new token against a cache of length S
        specs["tokens"] = _sds((B, 1), jnp.int32)
        specs["cache"] = jax.eval_shape(
            lambda: kvcache.init_cache(cfg, B, S, act)
        )
    return specs


def make_dummy_batch(cfg: ModelConfig, rng, batch: int, seq: int) -> Dict[str, Any]:
    """Concrete random batch for smoke tests (reduced configs)."""
    k1, k2, k3 = jax.random.split(rng, 3)
    act = jnp.dtype(cfg.dtype)
    out: Dict[str, Any] = {}
    S_text = seq
    if cfg.vision_patches:
        P = cfg.vision_patches
        S_text = seq - P
        out["patch_embeds"] = jax.random.normal(k3, (batch, P, cfg.d_model), act)
        pos = jnp.broadcast_to(jnp.arange(seq)[None, None], (batch, 3, seq))
        out["positions"] = pos.astype(jnp.int32)
    out["tokens"] = jax.random.randint(k1, (batch, S_text), 0, cfg.vocab_size)
    out["labels"] = jax.random.randint(k2, (batch, seq), 0, cfg.vocab_size)
    if cfg.encoder_decoder:
        out["frame_embeds"] = jax.random.normal(
            k3, (batch, cfg.encoder_seq_len, cfg.d_model), act
        )
    return out
