"""Decode caches.

Per pattern-position caches are stacked along a leading ``block_repeat`` axis
so the decode step can ``lax.scan`` over blocks.  Attention caches are ring
buffers: slot ``p % W`` holds position ``p``, so a full-attention cache sized
W behaves exactly like sliding-window attention with window W once it wraps
(the serving engine sizes W = max_len + headroom; the decode dry-run cells
size W = seq_len per the assignment).

``lengths`` is per-slot (continuous batching: every request in the batch has
its own offset).
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.ssm import ssm_dims


def attn_cache_len(cfg, max_len: int) -> int:
    if cfg.sliding_window is not None:
        return min(cfg.sliding_window, max_len)
    return max_len


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16) -> Dict:
    """Allocate an empty decode cache pytree (zeros; also usable as a
    ShapeDtypeStruct template via jax.eval_shape)."""
    R = cfg.block_repeat
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    blocks: Dict[str, Dict] = {}
    for i, spec in enumerate(cfg.layer_pattern):
        if spec.kind == "attn":
            W = attn_cache_len(cfg, max_len)
            c = {
                "k": jnp.zeros((R, batch, W, KV, hd), dtype),
                "v": jnp.zeros((R, batch, W, KV, hd), dtype),
            }
            if spec.cross_attn:
                c["xk"] = jnp.zeros((R, batch, cfg.encoder_seq_len, KV, hd), dtype)
                c["xv"] = jnp.zeros((R, batch, cfg.encoder_seq_len, KV, hd), dtype)
        else:
            s = cfg.ssm
            d_in, H, conv_ch = ssm_dims(cfg)
            gn = s.n_groups * s.d_state
            c = {
                "conv_x": jnp.zeros((R, batch, s.d_conv - 1, d_in), dtype),
                "conv_bc": jnp.zeros((R, batch, s.d_conv - 1, 2 * gn), dtype),
                "ssm": jnp.zeros((R, batch, H, s.head_dim, s.d_state), jnp.float32),
            }
        blocks[f"pos{i}"] = c
    return {
        "blocks": blocks,
        "lengths": jnp.zeros((batch,), jnp.int32),
    }


def split_cache(cache: Dict, split: int) -> tuple:
    """Split a stacked block cache by layer range: blocks ``[0, split)`` for
    the end tier, ``[split, R)`` for the cloud tier (the streaming end-cloud
    engine holds one sub-cache per tier).  Each side gets its own ``lengths``
    vector — the tiers advance them independently as the pipeline steps.
    """
    end = {
        "blocks": jax.tree.map(lambda l: l[:split], cache["blocks"]),
        "lengths": cache["lengths"],
    }
    cloud = {
        "blocks": jax.tree.map(lambda l: l[split:], cache["blocks"]),
        "lengths": cache["lengths"],
    }
    return end, cloud


def merge_cache(end_cache: Dict, cloud_cache: Dict) -> Dict:
    """Inverse of :func:`split_cache`: re-stack the per-tier block caches
    along the leading block axis (used at replan boundaries, when both tiers
    are at the same ``lengths``)."""
    blocks = jax.tree.map(
        lambda a, b: jnp.concatenate([a, b], axis=0),
        end_cache["blocks"],
        cloud_cache["blocks"],
    )
    return {"blocks": blocks, "lengths": end_cache["lengths"]}


def install_slot(batch_cache: Dict, slot: int, one_cache: Dict) -> Dict:
    """Copy a single-request cache (batch dim 1) into slot ``slot`` of a
    batched cache.  Block leaves are [R, B, W, ...]; the ring-buffer axis
    (dim 2) is padded/truncated to the destination window."""

    def copy_leaf(batch_leaf, one_leaf):
        pad = batch_leaf.shape[2] - one_leaf.shape[2] if batch_leaf.ndim > 2 else 0
        src = one_leaf
        if pad > 0:
            width = [(0, 0)] * src.ndim
            width[2] = (0, pad)
            src = jnp.pad(src, width)
        elif pad < 0:
            src = jax.lax.slice_in_dim(src, 0, batch_leaf.shape[2], axis=2)
        return batch_leaf.at[:, slot].set(src[:, 0])

    return {
        "blocks": jax.tree.map(copy_leaf, batch_cache["blocks"], one_cache["blocks"]),
        "lengths": batch_cache["lengths"].at[slot].set(one_cache["lengths"][0]),
    }


def ring_key_positions(lengths: jax.Array, W: int) -> jax.Array:
    """Position held by each ring slot AFTER the token at ``lengths`` (the
    current query) has been written.  lengths: [B] -> [B, W]."""
    s = jnp.arange(W)[None, :]
    ln = lengths[:, None]
    return ln - jnp.mod(ln - s, W)


def ring_write(kcache: jax.Array, vcache: jax.Array, k, v, lengths):
    """Write one new token's k/v ([B, 1, KV, hd]) at slot lengths % W."""
    W = kcache.shape[1]
    b = jnp.arange(kcache.shape[0])
    slot = jnp.mod(lengths, W)
    kcache = kcache.at[b, slot].set(k[:, 0].astype(kcache.dtype))
    vcache = vcache.at[b, slot].set(v[:, 0].astype(vcache.dtype))
    return kcache, vcache


def prefill_write(kcache: jax.Array, vcache: jax.Array, k, v):
    """Write a full prefix [B, S, KV, hd] into a fresh cache (ring layout).

    If S > W only the last W tokens are kept; their slots are pos % W.
    """
    B, S = k.shape[0], k.shape[1]
    W = kcache.shape[1]
    if S >= W:
        tail_k, tail_v = k[:, S - W :], v[:, S - W :]
        pos = jnp.arange(S - W, S)
        slot = jnp.mod(pos, W)
        kcache = kcache.at[:, slot].set(tail_k.astype(kcache.dtype))
        vcache = vcache.at[:, slot].set(tail_v.astype(vcache.dtype))
    else:
        kcache = jax.lax.dynamic_update_slice_in_dim(
            kcache, k.astype(kcache.dtype), 0, axis=1
        )
        vcache = jax.lax.dynamic_update_slice_in_dim(
            vcache, v.astype(vcache.dtype), 0, axis=1
        )
    return kcache, vcache
