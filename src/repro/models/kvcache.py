"""Decode caches.

Per pattern-position caches are stacked along a leading ``block_repeat`` axis
so the decode step can ``lax.scan`` over blocks.  Attention caches are ring
buffers: slot ``p % W`` holds position ``p``, so a full-attention cache sized
W behaves exactly like sliding-window attention with window W once it wraps
(the serving engine sizes W = max_len + headroom; the decode dry-run cells
size W = seq_len per the assignment).

``lengths`` is per-slot (continuous batching: every request in the batch has
its own offset).
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.ssm import ssm_dims


def attn_cache_len(cfg, max_len: int) -> int:
    if cfg.sliding_window is not None:
        return min(cfg.sliding_window, max_len)
    return max_len


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16) -> Dict:
    """Allocate an empty decode cache pytree (zeros; also usable as a
    ShapeDtypeStruct template via jax.eval_shape)."""
    R = cfg.block_repeat
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    blocks: Dict[str, Dict] = {}
    for i, spec in enumerate(cfg.layer_pattern):
        if spec.kind == "attn":
            W = attn_cache_len(cfg, max_len)
            c = {
                "k": jnp.zeros((R, batch, W, KV, hd), dtype),
                "v": jnp.zeros((R, batch, W, KV, hd), dtype),
            }
            if spec.cross_attn:
                c["xk"] = jnp.zeros((R, batch, cfg.encoder_seq_len, KV, hd), dtype)
                c["xv"] = jnp.zeros((R, batch, cfg.encoder_seq_len, KV, hd), dtype)
        else:
            s = cfg.ssm
            d_in, H, conv_ch = ssm_dims(cfg)
            gn = s.n_groups * s.d_state
            c = {
                "conv_x": jnp.zeros((R, batch, s.d_conv - 1, d_in), dtype),
                "conv_bc": jnp.zeros((R, batch, s.d_conv - 1, 2 * gn), dtype),
                "ssm": jnp.zeros((R, batch, H, s.head_dim, s.d_state), jnp.float32),
            }
        blocks[f"pos{i}"] = c
    return {
        "blocks": blocks,
        "lengths": jnp.zeros((batch,), jnp.int32),
    }


def ring_key_positions(lengths: jax.Array, W: int) -> jax.Array:
    """Position held by each ring slot AFTER the token at ``lengths`` (the
    current query) has been written.  lengths: [B] -> [B, W]."""
    s = jnp.arange(W)[None, :]
    ln = lengths[:, None]
    return ln - jnp.mod(ln - s, W)


def ring_write(kcache: jax.Array, vcache: jax.Array, k, v, lengths):
    """Write one new token's k/v ([B, 1, KV, hd]) at slot lengths % W."""
    W = kcache.shape[1]
    b = jnp.arange(kcache.shape[0])
    slot = jnp.mod(lengths, W)
    kcache = kcache.at[b, slot].set(k[:, 0].astype(kcache.dtype))
    vcache = vcache.at[b, slot].set(v[:, 0].astype(vcache.dtype))
    return kcache, vcache


def prefill_write(kcache: jax.Array, vcache: jax.Array, k, v):
    """Write a full prefix [B, S, KV, hd] into a fresh cache (ring layout).

    If S > W only the last W tokens are kept; their slots are pos % W.
    """
    B, S = k.shape[0], k.shape[1]
    W = kcache.shape[1]
    if S >= W:
        tail_k, tail_v = k[:, S - W :], v[:, S - W :]
        pos = jnp.arange(S - W, S)
        slot = jnp.mod(pos, W)
        kcache = kcache.at[:, slot].set(tail_k.astype(kcache.dtype))
        vcache = vcache.at[:, slot].set(tail_v.astype(vcache.dtype))
    else:
        kcache = jax.lax.dynamic_update_slice_in_dim(
            kcache, k.astype(kcache.dtype), 0, axis=1
        )
        vcache = jax.lax.dynamic_update_slice_in_dim(
            vcache, v.astype(vcache.dtype), 0, axis=1
        )
    return kcache, vcache
