"""Decode caches: dense ring buffers and the paged KV subsystem.

**Dense caches** (the original layout, still used by ``Model.prefill`` /
``Model.decode_step`` and the dry-run input specs): per pattern-position
caches are stacked along a leading ``block_repeat`` axis so the decode step
can ``lax.scan`` over blocks.  Attention caches are ring buffers: slot
``p % W`` holds position ``p``, so a full-attention cache sized W behaves
exactly like sliding-window attention with window W once it wraps.

**Paged caches** (what the serving engines allocate): instead of a dense
``[R, B, W, KV, hd]`` ring per slot, a tier owns one shared :class:`PagePool`
of ``num_pages`` fixed-size pages — leaves are ``[R, P+1, page_size, KV,
hd]`` (the extra last row is the *garbage page* that absorbs writes routed
away from unmapped or inactive slots) — plus a per-slot *page table*
``[pages_per_slot]`` of physical page indices.  Ring semantics are
preserved at page granularity: position ``p`` lives at table entry
``(p // page_size) % pages_per_slot``, offset ``p % page_size``, so the
gathered per-slot view is *exactly* the dense ring buffer of capacity
``pages_per_slot * page_size`` and the existing ring position math
(:func:`ring_key_positions`) applies unchanged.  A sliding window is just a
bounded page list; ring wrap reuses the slot's own pages in place.

The pool's allocator is host-side (NumPy bookkeeping between engine ticks);
the jitted stage functions take the device page table as a runtime argument,
so compiled traces depend only on chunk/group *shapes*, never on prompt
lengths or allocation state.

``lengths`` is per-slot (continuous batching: every request in the batch has
its own offset).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.ssm import ssm_dims


def attn_cache_len(cfg, max_len: int) -> int:
    if cfg.sliding_window is not None:
        return min(cfg.sliding_window, max_len)
    return max_len


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16) -> Dict:
    """Allocate an empty decode cache pytree (zeros; also usable as a
    ShapeDtypeStruct template via jax.eval_shape)."""
    R = cfg.block_repeat
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    blocks: Dict[str, Dict] = {}
    for i, spec in enumerate(cfg.layer_pattern):
        if spec.kind == "attn":
            W = attn_cache_len(cfg, max_len)
            c = {
                "k": jnp.zeros((R, batch, W, KV, hd), dtype),
                "v": jnp.zeros((R, batch, W, KV, hd), dtype),
            }
            if spec.cross_attn:
                c["xk"] = jnp.zeros((R, batch, cfg.encoder_seq_len, KV, hd), dtype)
                c["xv"] = jnp.zeros((R, batch, cfg.encoder_seq_len, KV, hd), dtype)
        else:
            s = cfg.ssm
            d_in, H, conv_ch = ssm_dims(cfg)
            gn = s.n_groups * s.d_state
            c = {
                "conv_x": jnp.zeros((R, batch, s.d_conv - 1, d_in), dtype),
                "conv_bc": jnp.zeros((R, batch, s.d_conv - 1, 2 * gn), dtype),
                "ssm": jnp.zeros((R, batch, H, s.head_dim, s.d_state), jnp.float32),
            }
        blocks[f"pos{i}"] = c
    return {
        "blocks": blocks,
        "lengths": jnp.zeros((batch,), jnp.int32),
    }


def split_cache(cache: Dict, split: int) -> tuple:
    """Split a stacked block cache by layer range: blocks ``[0, split)`` for
    the end tier, ``[split, R)`` for the cloud tier (the streaming end-cloud
    engine holds one sub-cache per tier).  Each side gets its own ``lengths``
    vector — the tiers advance them independently as the pipeline steps.
    """
    end = {
        "blocks": jax.tree.map(lambda l: l[:split], cache["blocks"]),
        "lengths": cache["lengths"],
    }
    cloud = {
        "blocks": jax.tree.map(lambda l: l[split:], cache["blocks"]),
        "lengths": cache["lengths"],
    }
    return end, cloud


def merge_cache(end_cache: Dict, cloud_cache: Dict) -> Dict:
    """Inverse of :func:`split_cache`: re-stack the per-tier block caches
    along the leading block axis (used at replan boundaries, when both tiers
    are at the same ``lengths``)."""
    blocks = jax.tree.map(
        lambda a, b: jnp.concatenate([a, b], axis=0),
        end_cache["blocks"],
        cloud_cache["blocks"],
    )
    return {"blocks": blocks, "lengths": end_cache["lengths"]}


def install_slot(batch_cache: Dict, slot: int, one_cache: Dict) -> Dict:
    """Copy a single-request cache (batch dim 1) into slot ``slot`` of a
    batched cache.  Block leaves are [R, B, W, ...]; the ring-buffer axis
    (dim 2) is padded/truncated to the destination window."""

    def copy_leaf(batch_leaf, one_leaf):
        pad = batch_leaf.shape[2] - one_leaf.shape[2] if batch_leaf.ndim > 2 else 0
        src = one_leaf
        if pad > 0:
            width = [(0, 0)] * src.ndim
            width[2] = (0, pad)
            src = jnp.pad(src, width)
        elif pad < 0:
            src = jax.lax.slice_in_dim(src, 0, batch_leaf.shape[2], axis=2)
        return batch_leaf.at[:, slot].set(src[:, 0])

    return {
        "blocks": jax.tree.map(copy_leaf, batch_cache["blocks"], one_cache["blocks"]),
        "lengths": batch_cache["lengths"].at[slot].set(one_cache["lengths"][0]),
    }


def ring_key_positions(lengths: jax.Array, W: int) -> jax.Array:
    """Position held by each ring slot AFTER the token at ``lengths`` (the
    current query) has been written.  lengths: [B] -> [B, W]."""
    s = jnp.arange(W)[None, :]
    ln = lengths[:, None]
    return ln - jnp.mod(ln - s, W)


def ring_write(kcache: jax.Array, vcache: jax.Array, k, v, lengths):
    """Write one new token's k/v ([B, 1, KV, hd]) at slot lengths % W."""
    W = kcache.shape[1]
    b = jnp.arange(kcache.shape[0])
    slot = jnp.mod(lengths, W)
    kcache = kcache.at[b, slot].set(k[:, 0].astype(kcache.dtype))
    vcache = vcache.at[b, slot].set(v[:, 0].astype(vcache.dtype))
    return kcache, vcache


def prefill_write(kcache: jax.Array, vcache: jax.Array, k, v):
    """Write a full prefix [B, S, KV, hd] into a fresh cache (ring layout).

    If S > W only the last W tokens are kept; their slots are pos % W.
    """
    B, S = k.shape[0], k.shape[1]
    W = kcache.shape[1]
    if S >= W:
        tail_k, tail_v = k[:, S - W :], v[:, S - W :]
        pos = jnp.arange(S - W, S)
        slot = jnp.mod(pos, W)
        kcache = kcache.at[:, slot].set(tail_k.astype(kcache.dtype))
        vcache = vcache.at[:, slot].set(tail_v.astype(vcache.dtype))
    else:
        kcache = jax.lax.dynamic_update_slice_in_dim(
            kcache, k.astype(kcache.dtype), 0, axis=1
        )
        vcache = jax.lax.dynamic_update_slice_in_dim(
            vcache, v.astype(vcache.dtype), 0, axis=1
        )
    return kcache, vcache


# ---------------------------------------------------------------------------
# Paged KV subsystem
# ---------------------------------------------------------------------------


def pattern_is_pageable(cfg) -> bool:
    """Paged caches cover self-attention KV only: every layer must be a
    non-cross attention layer.  SSM states are O(1) per slot (nothing to
    page) but chunked prefill cannot resume an SSM scan mid-sequence, so
    hybrid patterns stay on the dense path."""
    return all(
        spec.kind == "attn" and not spec.cross_attn for spec in cfg.layer_pattern
    )


def page_geometry(cfg, max_len: int, page_size: int,
                  chunk_headroom: int = 0) -> Tuple[int, int]:
    """(pages_per_slot, ring_capacity_tokens) for a slot's bounded page
    list.  The ring capacity is ``attn_cache_len`` rounded up to whole
    pages, so the gathered per-slot view is a dense ring buffer of at least
    the window the dense layout would have used.

    ``chunk_headroom`` (the engine's prefill chunk size) matters only when
    the ring can actually wrap — a sliding window smaller than ``max_len``:
    chunked prefill writes a whole chunk before its queries attend, so
    without ``ring >= window + chunk - 1`` a chunk's own writes could evict
    keys still inside an early query's attention window.  The extra ring
    tokens are harmless for decode (positions past the window stay
    masked)."""
    W = attn_cache_len(cfg, max_len)
    if W < max_len and chunk_headroom > 1:
        W += chunk_headroom - 1
    pps = -(-W // page_size)  # ceil
    return pps, pps * page_size


def pages_needed(n_tokens: int, page_size: int, pages_per_slot: int) -> int:
    """Distinct table entries positions ``[0, n_tokens)`` ever touch (entry
    indices cycle mod ``pages_per_slot``, so a long request plateaus at the
    ring bound — sliding windows reuse their own pages in place)."""
    return min(pages_per_slot, -(-n_tokens // page_size))


class PagePool:
    """Host-side page allocator for one tier's shared KV page pool.

    Physical pages ``0..num_pages-1`` index the second axis of the tier's
    storage leaves (``[R, num_pages+1, page_size, KV, hd]``; row
    ``num_pages`` is the garbage page and is never allocated).  Per-slot
    page tables map ring entries to physical pages; ``-1`` = unmapped.

    Admission *reserves* a slot's worst-case page count up front (so decode
    can never run out of pages mid-stream — there is no preemption), then
    maps pages lazily as prefill chunks / decode steps first touch each
    ring entry.  ``free`` returns a finished slot's pages; ``defrag``
    compacts mapped pages to the lowest physical indices and returns the
    storage-row permutation to apply device-side.

    In a fleet, one pool instance can be shared across lanes for the cloud
    tier: each lane registers its slot block via :meth:`add_slots`, so page
    accounting (and therefore admission) is fleet-wide.
    """

    def __init__(self, num_pages: int, page_size: int, pages_per_slot: int,
                 n_slots: int = 0):
        if num_pages < 1:
            raise ValueError(f"num_pages={num_pages}")
        self.num_pages = num_pages
        self.page_size = page_size
        self.pages_per_slot = pages_per_slot
        self.table = np.full((n_slots, pages_per_slot), -1, np.int32)
        # LIFO free list, seeded so pops hand out low indices first
        self._free: List[int] = list(range(num_pages - 1, -1, -1))
        self._reserved = np.zeros((n_slots,), np.int64)
        self._mapped = np.zeros((n_slots,), np.int64)
        self.peak_in_use = 0

    # -- capacity accounting --------------------------------------------------

    @property
    def garbage_page(self) -> int:
        return self.num_pages

    @property
    def pages_in_use(self) -> int:
        return self.num_pages - len(self._free)

    @property
    def pages_reserved(self) -> int:
        """Pages promised to admitted slots but not yet mapped."""
        return int(self._reserved.sum() - self._mapped.sum())

    @property
    def pages_available(self) -> int:
        """Pages a new reservation may claim."""
        return len(self._free) - self.pages_reserved

    @property
    def utilization(self) -> float:
        return self.pages_in_use / self.num_pages

    def mapped_for(self, slots) -> int:
        """Pages mapped by a slot subset (a lane's share of a shared pool)."""
        return int(self._mapped[np.asarray(slots)].sum())

    def add_slots(self, n: int) -> int:
        """Register ``n`` more slots (fleet lanes sharing a cloud pool);
        returns the base slot id of the new block."""
        base = self.table.shape[0]
        self.table = np.concatenate(
            [self.table, np.full((n, self.pages_per_slot), -1, np.int32)]
        )
        self._reserved = np.concatenate([self._reserved, np.zeros(n, np.int64)])
        self._mapped = np.concatenate([self._mapped, np.zeros(n, np.int64)])
        return base

    # -- slot lifecycle -------------------------------------------------------

    def can_reserve(self, n_pages: int) -> bool:
        return self.pages_available >= n_pages

    def reserve(self, slot: int, n_pages: int):
        if self._reserved[slot]:
            raise ValueError(f"slot {slot} already holds a reservation")
        if n_pages > self.pages_per_slot:
            raise ValueError(
                f"reservation {n_pages} exceeds pages_per_slot="
                f"{self.pages_per_slot}"
            )
        if not self.can_reserve(n_pages):
            raise ValueError(
                f"pool exhausted: want {n_pages}, available {self.pages_available}"
            )
        self._reserved[slot] = n_pages

    def _map_entry(self, slot: int, entry: int):
        if self.table[slot, entry] >= 0:
            return  # ring reuse: the entry keeps its page across wraps
        if self._mapped[slot] >= self._reserved[slot]:
            raise ValueError(
                f"slot {slot}: mapping beyond its reservation "
                f"({self._reserved[slot]} pages)"
            )
        self.table[slot, entry] = self._free.pop()
        self._mapped[slot] += 1
        self.peak_in_use = max(self.peak_in_use, self.pages_in_use)

    def map_range(self, slot: int, start_pos: int, end_pos: int):
        """Map every ring entry positions ``[start_pos, end_pos)`` touch."""
        if end_pos <= start_pos:
            return
        for pi in range(start_pos // self.page_size,
                        (end_pos - 1) // self.page_size + 1):
            self._map_entry(slot, pi % self.pages_per_slot)

    def append(self, slot: int, pos: int):
        """Ensure the entry for position ``pos`` is mapped (decode write)."""
        self._map_entry(slot, (pos // self.page_size) % self.pages_per_slot)

    # -- speculative decode: provisional maps / rollback ----------------------

    def map_tokens(self, slot: int, start_pos: int, end_pos: int) -> List[int]:
        """Map every ring entry positions ``[start_pos, end_pos)`` touch and
        return the entries that were *newly* mapped by this call.

        Speculative decode maps a draft chunk's pages provisionally through
        here; on a mid-chunk rejection the caller hands the returned entries
        (minus any the accepted prefix still needs) to :meth:`rollback`.
        Ring-reused entries — already mapped from an earlier wrap — are not
        returned: they were never provisional and must survive a rollback."""
        new_entries: List[int] = []
        if end_pos > start_pos:
            for pi in range(start_pos // self.page_size,
                            (end_pos - 1) // self.page_size + 1):
                entry = pi % self.pages_per_slot
                if self.table[slot, entry] < 0:
                    self._map_entry(slot, entry)
                    new_entries.append(entry)
        return new_entries

    def rollback(self, slot: int, entries) -> None:
        """Unmap provisionally-mapped ``entries`` (from :meth:`map_tokens`),
        returning their physical pages to the free list.  No data moves —
        rejected draft tokens only ever lived in lazily-mapped pages, so
        rollback is pure table surgery (the inverse of ``_map_entry``)."""
        for e in entries:
            e = int(e)
            if self.table[slot, e] < 0:
                raise ValueError(f"slot {slot}: rollback of unmapped entry {e}")
            self._free.append(int(self.table[slot, e]))
            self.table[slot, e] = -1
            self._mapped[slot] -= 1

    def free(self, slot: int):
        if not self._reserved[slot]:
            raise ValueError(f"double free of slot {slot}")
        for e in range(self.pages_per_slot):
            if self.table[slot, e] >= 0:
                self._free.append(int(self.table[slot, e]))
                self.table[slot, e] = -1
        self._reserved[slot] = 0
        self._mapped[slot] = 0

    def reserved_pages(self, slot: int) -> int:
        """Pages this slot's reservation holds (0 = no reservation)."""
        return int(self._reserved[slot])

    # -- preemption: spill / restore ------------------------------------------

    def spill_slot(self, slot: int) -> Tuple[np.ndarray, np.ndarray, int]:
        """Evict a live slot for preemption.

        Returns ``(entries, phys, n_reserved)``: the slot's mapped table
        entries, the physical page row each entry occupied, and the page
        count of its reservation.  The slot's pages go back to the free
        list — the caller must copy the storage rows at ``phys`` *before*
        anything else maps (and writes) those pages, then hand
        ``(entries, n_reserved)`` back to :meth:`restore_slot` at
        re-admission."""
        entries = np.nonzero(self.table[slot] >= 0)[0].astype(np.int64)
        phys = self.table[slot, entries].astype(np.int64).copy()
        n_reserved = int(self._reserved[slot])
        self.free(slot)
        return entries, phys, n_reserved

    def restore_slot(self, slot: int, entries: np.ndarray,
                     n_pages: int) -> np.ndarray:
        """Re-admit a spilled slot: reserve ``n_pages`` (the original
        worst-case reservation, so decode still can never run out
        mid-stream) and map exactly the spilled ``entries``.  Returns the
        entries' new physical rows — the caller scatters the saved page
        data there; ring-entry indices are placement-invariant, so reads
        through the rebuilt table see the exact pre-spill cache."""
        self.reserve(slot, n_pages)
        for e in entries:
            self._map_entry(slot, int(e))
        return self.table[slot, np.asarray(entries, np.int64)].astype(
            np.int64
        ).copy()

    # -- device views ---------------------------------------------------------

    def device_rows(self, slots, active=None) -> jax.Array:
        """Device page table for ``slots`` with unmapped entries — and,
        when ``active`` is given, all entries of inactive slots — routed to
        the garbage page, so jitted reads stay in-bounds and jitted writes
        for slots the engine has not activated can never corrupt a live
        page."""
        rows = self.table[np.asarray(slots)]
        rows = np.where(rows < 0, self.garbage_page, rows)
        if active is not None:
            rows = np.where(
                np.asarray(active)[:, None], rows, self.garbage_page
            )
        return jnp.asarray(rows, jnp.int32)

    # -- defrag ---------------------------------------------------------------

    def defrag(self) -> np.ndarray:
        """Compact mapped pages to the lowest physical indices.

        Returns the storage-row permutation ``perm`` (length
        ``num_pages + 1``, garbage row fixed) such that the device update is
        ``new_leaf = leaf[:, perm]``; tables and the free list are updated
        in place."""
        perm = np.empty((self.num_pages + 1,), np.int64)
        nxt = 0
        for s in range(self.table.shape[0]):
            for e in range(self.pages_per_slot):
                old = self.table[s, e]
                if old >= 0:
                    perm[nxt] = old
                    self.table[s, e] = nxt
                    nxt += 1
        leftovers = sorted(
            set(range(self.num_pages)) - set(perm[:nxt].tolist())
        )
        perm[nxt : self.num_pages] = leftovers
        perm[self.num_pages] = self.num_pages  # garbage stays put
        self._free = list(range(self.num_pages - 1, nxt - 1, -1))
        return perm


KV_SCALE_DTYPE = jnp.float16  # per-token sidecar: f16 keeps the page <= 0.55x
KV_SCALE_FLOOR = 1e-8  # all-zero tokens: finite divide, q stays 0


def init_paged_blocks(cfg, n_blocks: int, num_pages: int, page_size: int,
                      dtype=jnp.bfloat16, *, quantized: bool = False) -> Dict:
    """Paged KV storage for ``n_blocks`` stacked block repeats of an
    attention-only pattern: per position, ``k``/``v`` leaves shaped
    ``[n_blocks, num_pages + 1, page_size, KV, hd]`` (last row = garbage
    page).

    With ``quantized=True`` the k/v leaves store int8 codes and each
    position additionally carries ``k_scale``/``v_scale`` sidecar leaves
    ``[n_blocks, num_pages + 1, page_size]`` (float16) — one scale per
    written token, shared across KV heads and head dim.  The sidecars ride
    the same pytree as the pools, so spill/restore, defrag, and tier
    re-splits move them with their pages for free.
    """
    assert pattern_is_pageable(cfg), "paged storage needs an attn-only pattern"
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    if quantized:
        dtype = jnp.int8
    blocks: Dict[str, Dict] = {}
    for i, _spec in enumerate(cfg.layer_pattern):
        entry = {
            "k": jnp.zeros((n_blocks, num_pages + 1, page_size, KV, hd), dtype),
            "v": jnp.zeros((n_blocks, num_pages + 1, page_size, KV, hd), dtype),
        }
        if quantized:
            entry["k_scale"] = jnp.zeros(
                (n_blocks, num_pages + 1, page_size), KV_SCALE_DTYPE
            )
            entry["v_scale"] = jnp.zeros(
                (n_blocks, num_pages + 1, page_size), KV_SCALE_DTYPE
            )
        blocks[f"pos{i}"] = entry
    return blocks


def paged_block_bytes(blocks: Dict) -> int:
    """Bytes one physical page occupies across all of a tier's block leaves
    (the unit ``kv_bytes_*`` metrics are denominated in).  Scale sidecars
    count toward their page, so quantized pools meter honestly."""
    total = 0
    for leaf in jax.tree.leaves(blocks):
        if leaf.ndim >= 2 and leaf.shape[0] > 0:
            total += leaf[:, 0].nbytes
    return total


def dense_page_bytes(cfg, n_blocks: int, page_size: int, dtype=None) -> int:
    """Bytes one physical page would occupy at the *dense* activation dtype
    (``cfg.dtype`` unless overridden), across every pattern position — the
    exact dense counterpart of ``paged_block_bytes`` and the denominator of
    the ``kv_bytes_dense_equiv`` / ``attn_bytes_dense_step`` baselines,
    which must not shrink when the stored pool is quantized."""
    dtype = jnp.dtype(cfg.dtype) if dtype is None else jnp.dtype(dtype)
    return (
        2 * len(cfg.layer_pattern) * n_blocks * page_size
        * cfg.num_kv_heads * cfg.head_dim * dtype.itemsize
    )


# -- per-token KV quantization (pool storage codec) --------------------------


def quantize_kv_tokens(x: jax.Array):
    """``[..., KV, hd] -> (q int8 same shape, scale f16 [...])``: one scale
    per token, shared across KV heads and head dim (the sidecar layout).
    The scale is rounded to the f16 sidecar dtype *before* quantizing, so
    dequantization with the stored sidecar is the exact inverse."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=(-1, -2))
    scale = jnp.maximum(amax / 127.0, KV_SCALE_FLOOR).astype(KV_SCALE_DTYPE)
    s = scale.astype(jnp.float32)[..., None, None]
    q = jnp.clip(jnp.round(xf / s), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_kv_pool(pool: jax.Array, scale: jax.Array,
                       dtype=jnp.bfloat16) -> jax.Array:
    """``pool [..., ps, KV, hd] int8 + scale [..., ps] -> dense-equivalent
    pool`` (test oracle; the serving consumers dequantize in VMEM)."""
    return (
        pool.astype(jnp.float32)
        * scale.astype(jnp.float32)[..., None, None]
    ).astype(dtype)


# -- device-side paged reads/writes (pure; used inside jitted stage fns) -----


def paged_gather(pool: jax.Array, table: jax.Array) -> jax.Array:
    """pool [P+1, ps, KV, hd], table [B, pps] -> dense ring view
    [B, pps*ps, KV, hd].  Garbage-routed entries gather junk that the ring
    position mask (``ring_key_positions`` validity) discards.

    Test oracle only: the serving hot paths attend straight off the pool
    through the table (``attention.paged_decode_attention`` /
    ``paged_chunk_attention`` — O(mapped pages) HBM traffic); this
    materialized O(B x max_len) copy exists so parity tests can rebuild the
    exact dense ring the fused path must reproduce."""
    B, pps = table.shape
    buf = pool[table]  # [B, pps, ps, KV, hd]
    return buf.reshape(B, pps * pool.shape[1], *pool.shape[2:])


def paged_ring_write(pool_k: jax.Array, pool_v: jax.Array, k, v,
                     table: jax.Array, lengths: jax.Array, page_size: int):
    """Write one new token's k/v ([B, 1, KV, hd]) at ring position
    ``lengths`` through the page table (paged analogue of
    :func:`ring_write`)."""
    pps = table.shape[1]
    entry = jnp.mod(lengths // page_size, pps)
    phys = jnp.take_along_axis(table, entry[:, None], axis=1)[:, 0]
    off = jnp.mod(lengths, page_size)
    pool_k = pool_k.at[phys, off].set(k[:, 0].astype(pool_k.dtype))
    pool_v = pool_v.at[phys, off].set(v[:, 0].astype(pool_v.dtype))
    return pool_k, pool_v


def paged_ring_write_quant(pool_k, pool_v, pool_ks, pool_vs, k, v,
                           table: jax.Array, lengths: jax.Array,
                           page_size: int):
    """Quantize-on-write variant of :func:`paged_ring_write`: the token's
    k/v are int8-quantized with one per-token scale each (shared across KV
    heads and head dim) and both the codes and the f16 scale sidecars are
    scattered through the page table."""
    pps = table.shape[1]
    entry = jnp.mod(lengths // page_size, pps)
    phys = jnp.take_along_axis(table, entry[:, None], axis=1)[:, 0]
    off = jnp.mod(lengths, page_size)
    qk, sk = quantize_kv_tokens(k[:, 0])
    qv, sv = quantize_kv_tokens(v[:, 0])
    pool_k = pool_k.at[phys, off].set(qk)
    pool_v = pool_v.at[phys, off].set(qv)
    pool_ks = pool_ks.at[phys, off].set(sk)
    pool_vs = pool_vs.at[phys, off].set(sv)
    return pool_k, pool_v, pool_ks, pool_vs


def paged_write_tokens(pool_k: jax.Array, pool_v: jax.Array, k, v,
                       table: jax.Array, positions: jax.Array,
                       valid: jax.Array, page_size: int):
    """Write a chunk of tokens ([B, C, KV, hd]) at ``positions`` [B, C]
    through the page table; rows where ``valid`` [B, C] is False (prompt
    padding) are routed to the garbage page."""
    pps = table.shape[1]
    garbage = pool_k.shape[0] - 1
    entry = jnp.mod(positions // page_size, pps)
    phys = jnp.take_along_axis(table, entry, axis=1)
    phys = jnp.where(valid, phys, garbage)
    off = jnp.mod(positions, page_size)
    pool_k = pool_k.at[phys, off].set(k.astype(pool_k.dtype))
    pool_v = pool_v.at[phys, off].set(v.astype(pool_v.dtype))
    return pool_k, pool_v


def paged_write_tokens_quant(pool_k, pool_v, pool_ks, pool_vs, k, v,
                             table: jax.Array, positions: jax.Array,
                             valid: jax.Array, page_size: int):
    """Quantize-on-write variant of :func:`paged_write_tokens` (chunked
    prefill): per-token int8 codes plus f16 scale sidecars, padding rows
    routed to the garbage page exactly like the dense-dtype path."""
    pps = table.shape[1]
    garbage = pool_k.shape[0] - 1
    entry = jnp.mod(positions // page_size, pps)
    phys = jnp.take_along_axis(table, entry, axis=1)
    phys = jnp.where(valid, phys, garbage)
    off = jnp.mod(positions, page_size)
    qk, sk = quantize_kv_tokens(k)
    qv, sv = quantize_kv_tokens(v)
    pool_k = pool_k.at[phys, off].set(qk)
    pool_v = pool_v.at[phys, off].set(qv)
    pool_ks = pool_ks.at[phys, off].set(sk)
    pool_vs = pool_vs.at[phys, off].set(sv)
    return pool_k, pool_v, pool_ks, pool_vs


# -- tier re-splits over pages ----------------------------------------------


def page_perm(src_tables: np.ndarray, dst_tables: np.ndarray,
              src_pages: int, dst_pages: int) -> np.ndarray:
    """Physical-row permutation carrying one engine's pages from a source
    pool's index space to a destination pool's (used when a replan moves
    blocks between tiers: the two pools map the same (slot, entry) set —
    allocation is lockstep — but may assign different physical indices;
    with a fleet-shared cloud pool the slot rows are the lane's own block).

    ``src_tables``/``dst_tables`` are aligned ``[n_slots, pps]`` table
    slices.  Returns ``perm`` with ``len == dst_pages + 1`` such that
    ``dst_leaf = src_leaf[:, perm]`` places every mapped page at its
    destination row; unmapped destination rows read arbitrary (dead) data.
    """
    perm = np.zeros((dst_pages + 1,), np.int64)
    perm[dst_pages] = src_pages  # garbage -> garbage
    for src_row, dst_row in zip(np.asarray(src_tables), np.asarray(dst_tables)):
        if not np.array_equal(src_row >= 0, dst_row >= 0):
            raise ValueError(
                f"tier pools out of lockstep "
                f"({src_row.tolist()} vs {dst_row.tolist()})"
            )
        for e in range(len(src_row)):
            if dst_row[e] >= 0:
                perm[dst_row[e]] = src_row[e]
    return perm


def resplit_paged_blocks(end_blocks: Dict, cloud_blocks: Dict,
                         old_split: int, new_split: int,
                         end_to_cloud: np.ndarray,
                         cloud_to_end: np.ndarray) -> Tuple[Dict, Dict]:
    """Move block repeats between the tiers' paged storages at a replan
    safe point (the paged analogue of ``merge_cache`` + ``split_cache``):
    the moved leaves' page rows are permuted from the source pool's index
    space into the destination pool's."""
    if new_split == old_split:
        return end_blocks, cloud_blocks

    if new_split < old_split:  # end -> cloud
        def move(e_leaf, c_leaf):
            moved = e_leaf[new_split:][:, jnp.asarray(end_to_cloud)]
            return e_leaf[:new_split], jnp.concatenate([moved, c_leaf], axis=0)
    else:  # cloud -> end
        def move(e_leaf, c_leaf):
            n = new_split - old_split
            moved = c_leaf[:n][:, jnp.asarray(cloud_to_end)]
            return jnp.concatenate([e_leaf, moved], axis=0), c_leaf[n:]

    pairs = jax.tree.map(move, end_blocks, cloud_blocks)
    end_new = jax.tree.map(lambda p: p[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
    cloud_new = jax.tree.map(lambda p: p[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    return end_new, cloud_new
