"""Mamba-2 (SSD, state-space duality) layer.

TPU adaptation (see DESIGN.md): the SSD *chunked* formulation turns the
selective-scan recurrence into dense matmuls (MXU-friendly) plus one small
associative scan over chunk states — the canonical TPU-native expression of
Mamba.  Heads are processed ``head_block`` at a time so the [Q, Q, hb]
intra-chunk decay buffer stays bounded regardless of head count (Jamba has
256 SSM heads).

Three entry points:
  * ``ssd_chunked``      — full-sequence forward, returns final state (prefill/train)
  * ``ssd_decode_step``  — single-token recurrent update (serving)
  * ``ssd_reference``    — naive O(S) recurrent oracle for tests
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import rms_norm, truncated_normal_init


# ---------------------------------------------------------------------------
# Core SSD math
# ---------------------------------------------------------------------------


def ssd_chunked(
    x: jax.Array,  # [B, S, H, P] (pre-scaled inputs NOT applied; raw x)
    dt: jax.Array,  # [B, S, H] (post-softplus)
    A: jax.Array,  # [H] (negative)
    Bm: jax.Array,  # [B, S, G, N]
    Cm: jax.Array,  # [B, S, G, N]
    *,
    chunk_size: int,
    head_block: int,
    initial_state: Optional[jax.Array] = None,  # [B, H, P, N]
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y [B,S,H,P], final_state [B,H,P,N])."""
    B_, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    Q = min(chunk_size, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q
    hb = min(head_block, H)
    assert H % hb == 0, (H, hb)
    nhb = H // hb
    heads_per_group = H // G

    a = (dt * A).astype(jnp.float32)  # [B, S, H] log-decay
    # u stays in activation dtype (bf16 at scale); accumulation is fp32 via
    # preferred_element_type on every einsum touching it.
    u = dt.astype(x.dtype)[..., None] * x  # [B, S, H, P]

    a_c = a.reshape(B_, nc, Q, H)
    u_c = u.reshape(B_, nc, Q, H, P)
    B_c = Bm.reshape(B_, nc, Q, G, N)
    C_c = Cm.reshape(B_, nc, Q, G, N)

    ca = jnp.cumsum(a_c, axis=2)  # [B, nc, Q, H]
    # Intra-chunk score (shared across heads in a group): C_i . B_j
    scores = jnp.einsum(
        "bcqgn,bckgn->bcgqk", C_c, B_c, preferred_element_type=jnp.float32
    )  # [B, nc, G, Q, Q]
    tri = jnp.tril(jnp.ones((Q, Q), bool))

    # Per-chunk summary state: S_c = sum_j exp(ca_last - ca_j) B_j u_j^T
    decay_last = jnp.exp(ca_c_last(ca) - ca)  # [B, nc, Q, H]
    if G == 1:
        chunk_state = jnp.einsum(
            "bcqh,bcqn,bcqhp->bchpn",
            decay_last,
            B_c[:, :, :, 0],
            u_c,
            preferred_element_type=jnp.float32,
        )  # [B, nc, H, P, N]
    else:
        B_heads = jnp.repeat(B_c, heads_per_group, axis=3)  # [B, nc, Q, H, N]
        chunk_state = jnp.einsum(
            "bcqh,bcqhn,bcqhp->bchpn",
            decay_last,
            B_heads,
            u_c,
            preferred_element_type=jnp.float32,
        )

    # Inter-chunk recurrence over chunk states (associative scan).
    t_c = jnp.exp(ca[:, :, -1, :])  # [B, nc, H] total chunk decay

    def combine(e1, e2):
        t1, s1 = e1
        t2, s2 = e2
        return t1 * t2, t2[..., None, None] * s1 + s2

    t_scan, s_scan = jax.lax.associative_scan(
        combine, (t_c, chunk_state), axis=1
    )
    # State *entering* chunk c = state after chunk c-1 (shifted; chunk 0 sees init)
    init = (
        jnp.zeros((B_, H, P, N), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )
    h_after = s_scan + (t_scan[..., None, None] * init[:, None])
    h_before = jnp.concatenate([init[:, None], h_after[:, :-1]], axis=1)
    final_state = h_after[:, -1]

    # Per-head-block output assembly.
    def hb_slice(arr, i, axis):
        return jax.lax.dynamic_slice_in_dim(arr, i * hb, hb, axis)

    def per_head_block(i):
        ca_h = hb_slice(ca, i, 3)  # [B, nc, Q, hb]
        u_h = hb_slice(u_c, i, 3)  # [B, nc, Q, hb, P]
        h0_h = hb_slice(h_before, i, 2)  # [B, nc, hb, P, N]
        # group index of each head in this block
        g_idx = (i * hb + jnp.arange(hb)) // heads_per_group
        scores_h = jnp.take(scores, g_idx, axis=2)  # [B, nc, hb, Q, Q]
        C_h = jnp.take(C_c, g_idx, axis=3)  # [B, nc, Q, hb, N]
        # decay L[i,j] = exp(ca_i - ca_j) masked lower-triangular
        ca_t = ca_h.transpose(0, 1, 3, 2)  # [B, nc, hb, Q]
        logL = ca_t[..., :, None] - ca_t[..., None, :]  # [B, nc, hb, Q, Q]
        logL = jnp.where(tri[None, None, None], logL, -jnp.inf)
        M = scores_h * jnp.exp(logL)
        y_intra = jnp.einsum(
            "bchqk,bckhp->bcqhp", M.astype(u_h.dtype), u_h,
            preferred_element_type=jnp.float32,
        )
        y_inter = jnp.einsum(
            "bcqhn,bchpn,bcqh->bcqhp",
            C_h.astype(jnp.float32),
            h0_h,
            jnp.exp(ca_h),
        )
        return (y_intra + y_inter).astype(x.dtype)  # [B, nc, Q, hb, P]

    per_head_block = jax.checkpoint(per_head_block)
    y_blocks = jax.lax.map(per_head_block, jnp.arange(nhb))  # [nhb, B, nc, Q, hb, P]
    y = jnp.moveaxis(y_blocks, 0, 3).reshape(B_, nc, Q, H, P)
    return y.reshape(B_, S, H, P).astype(x.dtype), final_state


def ca_c_last(ca: jax.Array) -> jax.Array:
    return ca[:, :, -1:, :]


def ssd_decode_step(
    x: jax.Array,  # [B, H, P]
    dt: jax.Array,  # [B, H]
    A: jax.Array,  # [H]
    Bm: jax.Array,  # [B, G, N]
    Cm: jax.Array,  # [B, G, N]
    state: jax.Array,  # [B, H, P, N] fp32
) -> Tuple[jax.Array, jax.Array]:
    B_, H, P = x.shape
    G = Bm.shape[1]
    heads_per_group = H // G
    decay = jnp.exp((dt * A).astype(jnp.float32))  # [B, H]
    u = (dt[..., None] * x.astype(jnp.float32))  # [B, H, P]
    Bh = jnp.repeat(Bm.astype(jnp.float32), heads_per_group, axis=1)  # [B, H, N]
    Ch = jnp.repeat(Cm.astype(jnp.float32), heads_per_group, axis=1)
    new_state = decay[..., None, None] * state + u[..., None] * Bh[:, :, None, :]
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch)
    return y.astype(x.dtype), new_state


def ssd_reference(x, dt, A, Bm, Cm, initial_state=None):
    """Naive recurrent oracle: scan one token at a time."""
    B_, S, H, P = x.shape
    N = Bm.shape[-1]
    h0 = (
        jnp.zeros((B_, H, P, N), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )

    def step(h, inp):
        xt, dtt, bt, ct = inp
        y, h = ssd_decode_step(xt, dtt, A, bt, ct, h)
        return h, y

    xs = (
        jnp.moveaxis(x, 1, 0),
        jnp.moveaxis(dt, 1, 0),
        jnp.moveaxis(Bm, 1, 0),
        jnp.moveaxis(Cm, 1, 0),
    )
    h_last, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1), h_last


# ---------------------------------------------------------------------------
# Depthwise causal conv1d (pre-SSM mixing of x, B, C)
# ---------------------------------------------------------------------------


def causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """x: [B, S, C]; w: [W, C]; depthwise causal convolution."""
    W = w.shape[0]
    pads = [(0, 0), (W - 1, 0), (0, 0)]
    out = jax.lax.conv_general_dilated(
        x,
        w[:, None, :].astype(x.dtype),  # [W, 1, C]
        window_strides=(1,),
        padding=pads[1:2],
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1],
    )
    return out + b.astype(x.dtype)


def conv1d_decode_step(
    x_t: jax.Array,  # [B, C]
    conv_state: jax.Array,  # [B, W-1, C] (previous inputs)
    w: jax.Array,  # [W, C]
    b: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    W = w.shape[0]
    window = jnp.concatenate([conv_state, x_t[:, None]], axis=1)  # [B, W, C]
    out = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32), w.astype(jnp.float32))
    out = (out + b.astype(jnp.float32)).astype(x_t.dtype)
    new_state = window[:, 1:]
    return out, new_state


# ---------------------------------------------------------------------------
# Full Mamba-2 layer
# ---------------------------------------------------------------------------


def ssm_dims(cfg):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    H = d_in // s.head_dim
    conv_ch = d_in + 2 * s.n_groups * s.d_state
    return d_in, H, conv_ch


def init_ssm(key, cfg, dtype) -> Dict:
    """Projections are stored SPLIT (w_z/w_x head-major, w_bc shared, w_dt
    per-head) instead of one fused in_proj: the head dims then shard cleanly
    over the model axis (Mamba-2's own tensor-parallel formulation), which
    is what lets the TP path run the whole SSD recurrence shard-locally."""
    s = cfg.ssm
    d = cfg.d_model
    d_in, H, conv_ch = ssm_dims(cfg)
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    gn = s.n_groups * s.d_state
    return {
        "w_z": truncated_normal_init(k1, (d, d_in), dtype, 1.0),
        "w_x": truncated_normal_init(k5, (d, d_in), dtype, 1.0),
        "w_bc": truncated_normal_init(k6, (d, 2 * gn), dtype, 1.0),
        "w_dt": truncated_normal_init(k3, (d, H), dtype, 1.0),
        "conv_x": truncated_normal_init(k2, (s.d_conv, d_in), dtype, 1.0),
        "conv_x_b": jnp.zeros((d_in,), dtype),
        "conv_bc": truncated_normal_init(k2, (s.d_conv, 2 * gn), dtype, 1.0),
        "conv_bc_b": jnp.zeros((2 * gn,), dtype),
        "A_log": jnp.log(
            jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)
        ).astype(dtype),
        "D": jnp.ones((H,), dtype),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((H,), 0.01, jnp.float32))).astype(dtype),
        "norm_w": jnp.zeros((d_in,), dtype),
        "out_proj": truncated_normal_init(k4, (d_in, d), dtype, 1.0),
    }


def _conv_with_tail(x_in, w, b, initial, W):
    if initial is not None:
        full = jnp.concatenate([initial.astype(x_in.dtype), x_in], 1)
        return causal_conv1d(full, w, b)[:, W - 1 :]
    return causal_conv1d(x_in, w, b)


def _ssm_core(
    params: Dict,
    x: jax.Array,  # [B, S, d]
    cfg,
    *,
    initial_state,
    initial_conv,  # (conv_x_tail [B,W-1,d_in_loc], conv_bc_tail [B,W-1,2gn]) | None
    head_block: int,
    norm_psum_axis: Optional[str] = None,
):
    """Shared full-sequence body.  All head-indexed params may be LOCAL
    slices (TP path); w_bc/conv_bc are always replicated."""
    s = cfg.ssm
    B_, S, _ = x.shape
    P_ = s.head_dim
    H_loc = params["w_dt"].shape[1]
    d_in_loc = H_loc * P_

    z = x @ params["w_z"].astype(x.dtype)
    xs = x @ params["w_x"].astype(x.dtype)
    bc = x @ params["w_bc"].astype(x.dtype)
    dt = x @ params["w_dt"].astype(x.dtype)

    ic_x = initial_conv[0] if initial_conv is not None else None
    ic_bc = initial_conv[1] if initial_conv is not None else None
    xs_tail, bc_tail = xs[:, -(s.d_conv - 1):], bc[:, -(s.d_conv - 1):]
    xs = jax.nn.silu(
        _conv_with_tail(xs, params["conv_x"], params["conv_x_b"], ic_x, s.d_conv)
    )
    bc = jax.nn.silu(
        _conv_with_tail(bc, params["conv_bc"], params["conv_bc_b"], ic_bc, s.d_conv)
    )
    gn = s.n_groups * s.d_state
    Bf, Cf = bc[..., :gn], bc[..., gn:]
    xh = xs.reshape(B_, S, H_loc, P_)
    Bm = Bf.reshape(B_, S, s.n_groups, s.d_state)
    Cm = Cf.reshape(B_, S, s.n_groups, s.d_state)
    dt = jax.nn.softplus(
        dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    y, final_state = ssd_chunked(
        xh, dt, A, Bm, Cm,
        chunk_size=s.chunk_size,
        head_block=min(head_block, H_loc),
        initial_state=initial_state,
    )
    y = y + params["D"].astype(jnp.float32)[None, None, :, None] * xh.astype(
        jnp.float32
    )
    y = y.reshape(B_, S, d_in_loc).astype(x.dtype)
    g = y * jax.nn.silu(z)
    # gated RMSNorm over the FULL d_in (psum of squares when head-sharded)
    gf = g.astype(jnp.float32)
    ss = jnp.sum(jnp.square(gf), axis=-1, keepdims=True)
    n_tot = d_in_loc
    if norm_psum_axis is not None:
        ss = jax.lax.psum(ss, norm_psum_axis)
        n_tot = d_in_loc * jax.lax.axis_size(norm_psum_axis)
    gn_ = gf * jax.lax.rsqrt(ss / n_tot + cfg.norm_eps)
    gn_ = gn_ * (1.0 + params["norm_w"].astype(jnp.float32))
    out = gn_.astype(x.dtype) @ params["out_proj"].astype(x.dtype)
    if norm_psum_axis is not None:
        out = jax.lax.psum(out.astype(jnp.float32), norm_psum_axis).astype(x.dtype)
    return out, (final_state, (xs_tail, bc_tail))


def apply_ssm(
    params: Dict,
    x: jax.Array,  # [B, S, d]
    cfg,
    *,
    topo=None,
    initial_state: Optional[jax.Array] = None,
    initial_conv=None,
    return_state: bool = False,
):
    """Full-sequence Mamba-2 layer.  With a multi-device topology whose
    model axis divides the head count, runs head-sharded TP via shard_map
    (one output psum per layer; the SSD recurrence is shard-local)."""
    s = cfg.ssm
    d_in, H, _ = ssm_dims(cfg)
    use_tp = (
        topo is not None
        and getattr(topo, "mesh", None) is not None
        and topo.model_axis is not None
        and H % topo.ep_size == 0
        and x.shape[0] % topo.dp_size == 0
        and initial_state is None
        and initial_conv is None
    )
    if not use_tp:
        out, (final_state, conv_tail) = _ssm_core(
            params, x, cfg,
            initial_state=initial_state, initial_conv=initial_conv,
            head_block=s.head_block,
        )
        if return_state:
            return out, (final_state, conv_tail)
        return out

    import functools as _ft

    from jax.sharding import PartitionSpec as P

    axis = topo.model_axis
    dp = tuple(topo.data_axes)
    body = _ft.partial(
        _ssm_core, cfg=cfg, initial_state=None, initial_conv=None,
        head_block=s.head_block, norm_psum_axis=axis,
    )

    def shard_body(params_loc, x_loc):
        out, (fs, tails) = body(params_loc, x_loc)
        return out, (fs, tails)

    pspecs = {
        "w_z": P(None, axis), "w_x": P(None, axis), "w_bc": P(),
        "w_dt": P(None, axis),
        "conv_x": P(None, axis), "conv_x_b": P(axis),
        "conv_bc": P(), "conv_bc_b": P(),
        "A_log": P(axis), "D": P(axis), "dt_bias": P(axis),
        "norm_w": P(axis), "out_proj": P(axis, None),
    }
    fn = jax.shard_map(
        shard_body,
        mesh=topo.mesh,
        in_specs=(pspecs, P(dp, None, None)),
        out_specs=(
            P(dp, None, None),
            (P(dp, axis, None, None), (P(dp, None, axis), P(dp, None, None))),
        ),
        check_vma=False,
    )
    out, (final_state, conv_tail) = fn(params, x)
    if return_state:
        return out, (final_state, conv_tail)
    return out


def apply_ssm_decode(
    params: Dict,
    x: jax.Array,  # [B, 1, d]
    cfg,
    ssm_state: jax.Array,  # [B, H, P, N] fp32
    conv_state,  # (conv_x [B, W-1, d_in], conv_bc [B, W-1, 2gn])
):
    s = cfg.ssm
    d_in, H, _ = ssm_dims(cfg)
    gn = s.n_groups * s.d_state
    B_ = x.shape[0]
    x0 = x[:, 0]
    z = x0 @ params["w_z"].astype(x.dtype)
    xs_t = x0 @ params["w_x"].astype(x.dtype)
    bc_t = x0 @ params["w_bc"].astype(x.dtype)
    dt = x0 @ params["w_dt"].astype(x.dtype)
    cx, cbc = conv_state
    xs, new_cx = conv1d_decode_step(
        xs_t, cx, params["conv_x"], params["conv_x_b"]
    )
    bc, new_cbc = conv1d_decode_step(
        bc_t, cbc, params["conv_bc"], params["conv_bc_b"]
    )
    xs = jax.nn.silu(xs)
    bc = jax.nn.silu(bc)
    Bf, Cf = bc[..., :gn], bc[..., gn:]
    xh = xs.reshape(B_, H, s.head_dim)
    Bm = Bf.reshape(B_, s.n_groups, s.d_state)
    Cm = Cf.reshape(B_, s.n_groups, s.d_state)
    dt = jax.nn.softplus(
        dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    y, new_state = ssd_decode_step(xh, dt, A, Bm, Cm, ssm_state)
    y = y + params["D"].astype(jnp.float32)[None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B_, d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm_w"], cfg.norm_eps)
    out = (y @ params["out_proj"].astype(x.dtype))[:, None]
    return out, (new_state, (new_cx, new_cbc))
