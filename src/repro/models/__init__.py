"""Model substrate.  Import submodules directly (lazy to avoid import
cycles with repro.core, which uses repro.models.layers)."""


def __getattr__(name):
    if name in ("build_model", "Model", "input_specs", "make_dummy_batch"):
        from repro.models import model as _m

        return getattr(_m, name)
    raise AttributeError(name)
