"""Generic transformer stack: dense / MoE / hybrid-SSM / enc-dec / VLM.

A model is ``block_repeat`` copies of ``cfg.layer_pattern`` lowered with a
single ``jax.lax.scan`` over stacked block parameters (HLO stays small at 94
layers and 512 devices).  Heterogeneous caches (attention ring buffers, SSM
states) ride along as scan xs/ys.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.moe import apply_moe, init_moe
from repro.distributed.topology import Topology
from repro.models import attention as attn
from repro.models import kvcache, ssm
from repro.models.layers import (
    apply_mlp,
    init_embedding,
    init_mlp,
    init_norm,
    rms_norm,
    truncated_normal_init,
)


def _has_ffn(spec, cfg) -> bool:
    return bool(spec.moe and cfg.moe) or cfg.d_ff > 0


def _constrain_tokens(
    x: jax.Array, topo: Optional[Topology], seq_shard: bool = False
) -> jax.Array:
    """Pin token-major activations to [B(dp), S, d] between blocks.

    Without this XLA's SPMD propagation may flip the residual stream to a
    batch-replicated / feature-sharded layout through the attention
    reshapes, turning every layer's backward into a full-batch all-reduce
    (measured: 40 x 20 GiB f32 on qwen3-14b train — see EXPERIMENTS.md
    §Perf iteration 1).

    ``seq_shard`` additionally shards S over the model axis at the block
    boundary (Megatron sequence parallelism): the per-layer TP all-reduce
    splits into reduce-scatter + all-gather at half the wire bytes, and
    norms/elementwise work shard too (§Perf iteration 2)."""
    if topo is None or topo.mesh is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.distributed.sharding import fit_batch_axes

    batch_axes = fit_batch_axes(x.shape[0], topo)
    if batch_axes is None:
        return x
    if batch_axes != tuple(topo.data_axes):
        # partial-batch sharding (B < dp degree): pin what divides
        spec = P(batch_axes, *([None] * (x.ndim - 1)))
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(topo.mesh, spec)
        )
    seq_ax = None
    if (
        seq_shard
        and x.ndim >= 3
        and topo.model_axis
        and x.shape[1] % topo.ep_size == 0
    ):
        # the seq-parallel shard_map islands pin [B(dp), S(model), d]
        # themselves; an extra wsc here makes the partitioner flap between
        # layouts (measured: +88 GiB/step of gather-slice pairs)
        return x
    spec = P(tuple(topo.data_axes), seq_ax, *([None] * (x.ndim - 2)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(topo.mesh, spec))


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_layer(key, cfg, spec, dtype) -> Dict:
    keys = jax.random.split(key, 6)
    p: Dict[str, Any] = {"norm1": init_norm(cfg.d_model, dtype)}
    if spec.kind == "attn":
        p["attn"] = attn.init_attention(keys[0], cfg, dtype)
        if spec.cross_attn:
            p["norm_x"] = init_norm(cfg.d_model, dtype)
            p["cross"] = attn.init_attention(keys[1], cfg, dtype)
    else:
        p["ssm"] = ssm.init_ssm(keys[0], cfg, dtype)
    if _has_ffn(spec, cfg):
        p["norm2"] = init_norm(cfg.d_model, dtype)
        if spec.moe:
            p["moe"] = init_moe(keys[2], cfg, dtype)
        else:
            p["ffn"] = init_mlp(keys[3], cfg.d_model, cfg.d_ff, dtype, cfg.ffn_gated)
    return p


def init_params(key, cfg) -> Dict:
    dtype = jnp.dtype(cfg.param_dtype)
    R = cfg.block_repeat
    k_embed, k_blocks, k_head, k_enc = jax.random.split(key, 4)
    params: Dict[str, Any] = {
        "embed": init_embedding(k_embed, cfg.padded_vocab_size, cfg.d_model, dtype),
        "final_norm": init_norm(cfg.d_model, dtype),
    }
    blocks = {}
    pos_keys = jax.random.split(k_blocks, len(cfg.layer_pattern))
    for i, spec in enumerate(cfg.layer_pattern):
        layer_keys = jax.random.split(pos_keys[i], R)
        blocks[f"pos{i}"] = jax.vmap(
            lambda kk, spec=spec: init_layer(kk, cfg, spec, dtype)
        )(layer_keys)
    params["blocks"] = blocks
    if not cfg.tie_embeddings:
        params["lm_head"] = truncated_normal_init(
            k_head, (cfg.d_model, cfg.padded_vocab_size), dtype, 1.0
        )
    if cfg.encoder_decoder:
        from repro.configs.base import LayerSpec

        enc_spec = LayerSpec(kind="attn")
        enc_keys = jax.random.split(k_enc, cfg.encoder_layers)
        params["encoder"] = {
            "blocks": jax.vmap(
                lambda kk: init_layer(kk, cfg, enc_spec, dtype)
            )(enc_keys),
            "norm": init_norm(cfg.d_model, dtype),
        }
    return params


# ---------------------------------------------------------------------------
# Layer application
# ---------------------------------------------------------------------------


def _self_attention_full(p, h, cfg, angles, causal):
    q, k, v = attn.project_qkv(p, h, cfg, angles)
    o = attn.flash_attention(
        q,
        k,
        v,
        causal=causal,
        window=cfg.sliding_window if causal else None,
        q_chunk=cfg.attn_chunk_q,
        kv_chunk=cfg.attn_chunk_kv,
    )
    return attn.output_proj(p, o), (k, v)


def _self_attention_seqp(p, h, cfg, topo, angles, causal):
    """Sequence-parallel self attention (§Perf iteration on qwen3-moe):
    tokens stay S-sharded over the model axis; only the GQA K/V heads are
    all-gathered (KV*hd bytes per token instead of d), eliminating both the
    per-layer TP all-reduce and the MoE-output all-gather."""
    import functools as _ft

    from jax.sharding import PartitionSpec as P

    mesh = topo.mesh
    axis = topo.model_axis
    dp = tuple(topo.data_axes)

    def body(h_loc, angles_loc, params):
        # h_loc: [B_loc, S_loc, d]
        me = jax.lax.axis_index(axis)
        S_loc = h_loc.shape[1]
        q, k, v = attn.project_qkv(params, h_loc, cfg, angles_loc)
        k_full = jax.lax.all_gather(k, axis, axis=1, tiled=True)
        v_full = jax.lax.all_gather(v, axis, axis=1, tiled=True)
        qpos = me * S_loc + jnp.arange(S_loc, dtype=jnp.int32)
        o = attn.flash_attention(
            q, k_full, v_full,
            causal=causal,
            window=cfg.sliding_window if causal else None,
            q_chunk=cfg.attn_chunk_q,
            kv_chunk=cfg.attn_chunk_kv,
            q_positions=qpos,
        )
        return attn.output_proj(params, o), (k_full, v_full)

    sharded = P(dp, axis, None)

    # caches come back S-sharded: each shard emits its LOCAL k/v slice
    def body_kv_local(h_loc, angles_loc, params):
        o, (kf, vf) = body(h_loc, angles_loc, params)
        S_loc = h_loc.shape[1]
        me = jax.lax.axis_index(axis)
        k_loc = jax.lax.dynamic_slice_in_dim(kf, me * S_loc, S_loc, 1)
        v_loc = jax.lax.dynamic_slice_in_dim(vf, me * S_loc, S_loc, 1)
        return o, (k_loc, v_loc)

    fn = jax.shard_map(
        body_kv_local,
        mesh=mesh,
        in_specs=(sharded, P(dp, axis, None), P()),
        out_specs=(sharded, (P(dp, axis, None, None), P(dp, axis, None, None))),
        check_vma=False,
    )
    return fn(h, angles, p)


def _cross_attention_full(p, h, enc_out, cfg):
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"].astype(h.dtype))
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"].astype(h.dtype))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"].astype(h.dtype))
    if cfg.qk_norm:
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    o = attn.flash_attention(
        q, k, v, causal=False,
        q_chunk=cfg.attn_chunk_q, kv_chunk=cfg.attn_chunk_kv,
    )
    return attn.output_proj(p, o), (k, v)


def apply_layer_full(
    p: Dict,
    x: jax.Array,  # [B, S, d]
    spec,
    cfg,
    topo: Optional[Topology],
    angles,
    *,
    causal: bool = True,
    enc_out=None,
    expert_mask=None,
    train: bool = True,
    collect_cache: bool = False,
    max_len: int = 0,
):
    """Full-sequence layer (train / prefill).  Returns (x, aux, cache_entry)."""
    aux: Dict[str, jax.Array] = {}
    cache_entry: Dict[str, jax.Array] = {}
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    if spec.kind == "attn":
        use_seqp = (
            topo is not None
            and topo.mesh is not None
            and topo.seq_parallel_attn
            and not spec.cross_attn
            and x.shape[0] % topo.dp_size == 0
            and x.shape[1] % topo.ep_size == 0
        )
        if use_seqp:
            o, (k, v) = _self_attention_seqp(p["attn"], h, cfg, topo, angles, causal)
        else:
            o, (k, v) = _self_attention_full(p["attn"], h, cfg, angles, causal)
        x = x + o
        if collect_cache:
            W = kvcache.attn_cache_len(cfg, max_len)
            B = x.shape[0]
            kc = jnp.zeros((B, W, cfg.num_kv_heads, cfg.head_dim), k.dtype)
            vc = jnp.zeros_like(kc)
            cache_entry["k"], cache_entry["v"] = kvcache.prefill_write(kc, vc, k, v)
        if spec.cross_attn:
            hx = rms_norm(x, p["norm_x"], cfg.norm_eps)
            ox, (xk, xv) = _cross_attention_full(p["cross"], hx, enc_out, cfg)
            x = x + ox
            if collect_cache:
                cache_entry["xk"], cache_entry["xv"] = xk, xv
    else:
        if collect_cache:
            o, (final_state, (cx, cbc)) = ssm.apply_ssm(
                p["ssm"], h, cfg, topo=topo, return_state=True
            )
            cache_entry["ssm"] = final_state
            cache_entry["conv_x"] = cx
            cache_entry["conv_bc"] = cbc
        else:
            o = ssm.apply_ssm(p["ssm"], h, cfg, topo=topo)
        x = x + o
    if _has_ffn(spec, cfg):
        h = rms_norm(x, p["norm2"], cfg.norm_eps)
        if spec.moe:
            y, aux = apply_moe(
                p["moe"], h, cfg, topo, expert_mask=expert_mask, train=train
            )
        else:
            y = apply_mlp(p["ffn"], h, cfg.act)
        x = x + y
    return x, aux, cache_entry


def apply_layer_decode(
    p: Dict,
    x: jax.Array,  # [B, 1, d]
    spec,
    cfg,
    topo: Optional[Topology],
    angles,  # [B, 1, hd/2]
    cache_entry: Dict,
    lengths: jax.Array,  # [B]
    expert_mask=None,
    page_table: Optional[jax.Array] = None,  # [B, pps] -> paged KV layout
    page_size: int = 0,
    expert_resident: Optional[Dict] = None,  # this layer's resident tables
):
    """Single-token decode layer.  Returns (x, new_cache_entry, aux).

    With ``page_table`` set, attention ``k``/``v`` leaves are page pools
    ``[P+1, page_size, KV, hd]``: the write scatters through the table and
    attention reads the slot's mapped pages *directly from the pool*
    (``attn.paged_decode_attention`` — page lookup, ring masking, and
    online softmax fused; no dense ring view is materialized), so HBM
    traffic scales with mapped pages while greedy decode stays
    token-identical to the dense layout."""
    aux: Dict[str, jax.Array] = {}
    new_entry = dict(cache_entry)
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    if spec.kind == "attn":
        q, k, v = attn.project_qkv(p["attn"], h, cfg, angles)
        if page_table is not None and "k_scale" in cache_entry:
            # int8 page pool: quantize-on-write, fused dequant in attention
            kc, vc, ksc, vsc = kvcache.paged_ring_write_quant(
                cache_entry["k"], cache_entry["v"],
                cache_entry["k_scale"], cache_entry["v_scale"], k, v,
                page_table, lengths, page_size,
            )
            new_entry["k"], new_entry["v"] = kc, vc
            new_entry["k_scale"], new_entry["v_scale"] = ksc, vsc
            o = attn.paged_decode_attention(
                q, kc, vc, page_table, lengths, window=cfg.sliding_window,
                k_scale=ksc, v_scale=vsc,
            )
        elif page_table is not None:
            kc, vc = kvcache.paged_ring_write(
                cache_entry["k"], cache_entry["v"], k, v,
                page_table, lengths, page_size,
            )
            new_entry["k"], new_entry["v"] = kc, vc
            o = attn.paged_decode_attention(
                q, kc, vc, page_table, lengths, window=cfg.sliding_window
            )
        else:
            kc, vc = kvcache.ring_write(
                cache_entry["k"], cache_entry["v"], k, v, lengths
            )
            new_entry["k"], new_entry["v"] = kc, vc
            key_pos = kvcache.ring_key_positions(lengths, kc.shape[1])
            o = attn.decode_attention(
                q, kc, vc, lengths, key_pos, window=cfg.sliding_window
            )
        x = x + attn.output_proj(p["attn"], o)
        if spec.cross_attn:
            hx = rms_norm(x, p["norm_x"], cfg.norm_eps)
            qx = jnp.einsum("bsd,dhk->bshk", hx, p["cross"]["wq"].astype(hx.dtype))
            if cfg.qk_norm:
                qx = rms_norm(qx, p["cross"]["q_norm"], cfg.norm_eps)
            S_enc = cache_entry["xk"].shape[1]
            enc_pos = jnp.full((x.shape[0],), S_enc, jnp.int32)
            key_pos_x = jnp.broadcast_to(
                jnp.arange(S_enc)[None], (x.shape[0], S_enc)
            )
            ox = attn.decode_attention(
                qx, cache_entry["xk"], cache_entry["xv"], enc_pos, key_pos_x
            )
            x = x + attn.output_proj(p["cross"], ox)
    else:
        o, (new_ssm, (new_cx, new_cbc)) = ssm.apply_ssm_decode(
            p["ssm"], h, cfg, cache_entry["ssm"],
            (cache_entry["conv_x"], cache_entry["conv_bc"]),
        )
        new_entry["ssm"] = new_ssm
        new_entry["conv_x"], new_entry["conv_bc"] = new_cx, new_cbc
        x = x + o
    if _has_ffn(spec, cfg):
        h = rms_norm(x, p["norm2"], cfg.norm_eps)
        if spec.moe:
            mp = p["moe"]
            if expert_resident is not None:
                # pooled end tier: the stripped moe params get this layer's
                # resident tables + the shared slab store (core.expertpool)
                mp = {**mp, "resident": expert_resident}
            y, aux = apply_moe(
                mp, h, cfg, topo, expert_mask=expert_mask, train=False
            )
        else:
            y = apply_mlp(p["ffn"], h, cfg.act)
        x = x + y
    return x, new_entry, aux


# ---------------------------------------------------------------------------
# Stacks (scan over blocks)
# ---------------------------------------------------------------------------


def _merge_aux(acc: Dict, aux: Dict) -> Dict:
    for k, v in aux.items():
        acc[k] = acc.get(k, 0.0) + v
    return acc


def apply_stack_full(
    params: Dict,
    x: jax.Array,
    cfg,
    topo,
    angles,
    *,
    causal=True,
    enc_out=None,
    expert_mask=None,
    train=True,
    collect_cache=False,
    max_len=0,
    remat=True,
):
    """Scan the repeated block pattern over the sequence.  Returns
    (x, aux_sums, cache_blocks|None)."""

    def block_fn(carry_x, block_params):
        bx = carry_x
        aux_acc: Dict[str, jax.Array] = {}
        caches = {}
        seqp = cfg.seq_parallel or (topo is not None and topo.seq_parallel_attn)
        for i, spec in enumerate(cfg.layer_pattern):
            bx = _constrain_tokens(bx, topo, seq_shard=seqp)
            bx, aux, ce = apply_layer_full(
                block_params[f"pos{i}"], bx, spec, cfg, topo, angles,
                causal=causal, enc_out=enc_out, expert_mask=expert_mask,
                train=train, collect_cache=collect_cache, max_len=max_len,
            )
            aux_acc = _merge_aux(aux_acc, aux)
            if collect_cache:
                caches[f"pos{i}"] = ce
        bx = _constrain_tokens(bx, topo)
        return bx, (aux_acc, caches)

    fn = jax.checkpoint(block_fn) if (remat and train) else block_fn
    x, (aux_stack, cache_stack) = jax.lax.scan(fn, x, params["blocks"])
    # reduce over the block axis only: scalar aux stays scalar, measured
    # routing statistics (expert_frac [E] / group_frac [K]) keep their shape
    aux = {k: v.sum(axis=0) for k, v in aux_stack.items()}
    return x, aux, (cache_stack if collect_cache else None)


def apply_stack_decode(
    params: Dict,
    x: jax.Array,
    cfg,
    topo,
    angles,
    cache_blocks: Dict,
    lengths: jax.Array,
    expert_mask=None,
    *,
    page_table: Optional[jax.Array] = None,
    page_size: int = 0,
    expert_resident: Optional[Dict] = None,
):
    """``expert_resident`` (pooled end tier) is
    ``{"store": {...}, "tables": {"pos{i}": {"ids": [R, S+1], "slot":
    [R, E]}}}`` from ``core.expertpool``: per-block resident tables ride
    the scan as xs while the slab store is a loop constant, so MoE layers
    gather only resident slab rows (``core.moe.moe_resident``)."""
    tables = expert_resident["tables"] if expert_resident is not None else None
    store = expert_resident["store"] if expert_resident is not None else None
    xs = (params["blocks"], cache_blocks)
    if tables is not None:
        xs = xs + (tables,)

    def block_fn(carry_x, xs_):
        if tables is not None:
            block_params, cache_entry, tab = xs_
        else:
            (block_params, cache_entry), tab = xs_, None
        bx = carry_x
        new_entries = {}
        aux_acc: Dict[str, jax.Array] = {}
        for i, spec in enumerate(cfg.layer_pattern):
            res = None
            if tab is not None and spec.moe:
                res = {**tab[f"pos{i}"], "store": store}
            bx, ne, aux = apply_layer_decode(
                block_params[f"pos{i}"], bx, spec, cfg, topo, angles,
                cache_entry[f"pos{i}"], lengths, expert_mask=expert_mask,
                page_table=page_table, page_size=page_size,
                expert_resident=res,
            )
            new_entries[f"pos{i}"] = ne
            aux_acc = _merge_aux(aux_acc, aux)
        return bx, (new_entries, aux_acc)

    x, (new_cache, aux_stack) = jax.lax.scan(block_fn, x, xs)
    # reduce over the block axis only: scalar aux stays scalar, measured
    # routing statistics (expert_frac [E] / group_frac [K]) keep their shape
    aux = {k: v.sum(axis=0) for k, v in aux_stack.items()}
    return x, new_cache, aux


def apply_stack_prefill_chunk(
    params: Dict,
    x: jax.Array,  # [B, C, d] one fixed-size prompt chunk
    cfg,
    topo,
    angles,  # [B, C, hd/2]
    page_blocks: Dict,  # paged KV storage (attn-only pattern)
    page_table: jax.Array,  # [B, pps]
    positions: jax.Array,  # [B, C] absolute position of every chunk row
    n_valid: jax.Array,  # [B] rows < n_valid are real, the rest padding
    page_size: int,
    expert_mask=None,
    expert_resident: Optional[Dict] = None,
):
    """Chunked prefill over the repeated block pattern (attention-only
    patterns; the serving engines gate on ``kvcache.pattern_is_pageable``).

    Each layer writes the chunk's k/v through the page table first (padding
    rows routed to the garbage page), then attends the chunk's queries
    against the slot's mapped pages directly (``attn.paged_chunk_attention``
    — no gathered ring view) — so a prompt streams through one compiled
    trace per *chunk shape*, never one per prompt length, and the chunk
    leaves exactly the pages a whole-prompt prefill would have left.
    Returns (x [B, C, d], new_page_blocks)."""
    C = x.shape[1]
    valid = jnp.arange(C)[None, :] < n_valid[:, None]  # [B, C]
    last_pos = positions[:, 0] + n_valid - 1  # [B] final real position
    tables = expert_resident["tables"] if expert_resident is not None else None
    store = expert_resident["store"] if expert_resident is not None else None
    xs = (params["blocks"], page_blocks)
    if tables is not None:
        xs = xs + (tables,)

    def block_fn(carry_x, xs_):
        if tables is not None:
            block_params, cache_entry, tab = xs_
        else:
            (block_params, cache_entry), tab = xs_, None
        bx = carry_x
        new_entries = {}
        for i, spec in enumerate(cfg.layer_pattern):
            p = block_params[f"pos{i}"]
            ce = cache_entry[f"pos{i}"]
            h = rms_norm(bx, p["norm1"], cfg.norm_eps)
            q, k, v = attn.project_qkv(p["attn"], h, cfg, angles)
            if "k_scale" in ce:
                # int8 page pool: quantize-on-write + fused dequant
                kc, vc, ksc, vsc = kvcache.paged_write_tokens_quant(
                    ce["k"], ce["v"], ce["k_scale"], ce["v_scale"], k, v,
                    page_table, positions, valid, page_size,
                )
                o = attn.paged_chunk_attention(
                    q, kc, vc, page_table, positions, last_pos,
                    window=cfg.sliding_window, k_scale=ksc, v_scale=vsc,
                )
                entry_out = {"k": kc, "v": vc, "k_scale": ksc, "v_scale": vsc}
            else:
                kc, vc = kvcache.paged_write_tokens(
                    ce["k"], ce["v"], k, v, page_table, positions, valid,
                    page_size,
                )
                o = attn.paged_chunk_attention(
                    q, kc, vc, page_table, positions, last_pos,
                    window=cfg.sliding_window,
                )
                entry_out = {"k": kc, "v": vc}
            bx = bx + attn.output_proj(p["attn"], o)
            if _has_ffn(spec, cfg):
                h = rms_norm(bx, p["norm2"], cfg.norm_eps)
                if spec.moe:
                    mp = p["moe"]
                    if tab is not None:
                        mp = {**mp, "resident": {**tab[f"pos{i}"], "store": store}}
                    y, _ = apply_moe(
                        mp, h, cfg, topo, expert_mask=expert_mask,
                        train=False,
                    )
                else:
                    y = apply_mlp(p["ffn"], h, cfg.act)
                bx = bx + y
            new_entries[f"pos{i}"] = entry_out
        return bx, new_entries

    x, new_blocks = jax.lax.scan(block_fn, x, xs)
    return x, new_blocks


def apply_encoder(params: Dict, frame_embeds: jax.Array, cfg, topo):
    """Whisper-style bidirectional encoder over stub frame embeddings."""
    B, S, _ = frame_embeds.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    angles = attn.rope_angles(positions, cfg.head_dim, cfg.rope_theta)
    x = frame_embeds

    def block_fn(carry_x, block_params):
        bx, _, _ = apply_layer_full(
            block_params, carry_x,
            type(cfg.layer_pattern[0])(kind="attn"),  # plain attn spec
            cfg, topo, angles, causal=False, train=False,
        )
        return bx, None

    x, _ = jax.lax.scan(block_fn, x, params["encoder"]["blocks"])
    return rms_norm(x, params["encoder"]["norm"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------


def embed_inputs(params, cfg, tokens, patch_embeds=None):
    x = params["embed"].astype(jnp.dtype(cfg.dtype))[tokens]
    if patch_embeds is not None:
        x = jnp.concatenate([patch_embeds.astype(x.dtype), x], axis=1)
    return x


def lm_logits(params, cfg, x):
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = (
        params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    ).astype(x.dtype)
    logits = x @ head
    if cfg.logit_softcap > 0:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits / c)
    if cfg.padded_vocab_size != cfg.vocab_size:
        pad_mask = jnp.arange(cfg.padded_vocab_size) < cfg.vocab_size
        logits = jnp.where(pad_mask, logits, -1e30)
    return logits
