"""Attention: GQA projections, RoPE / M-RoPE, flash (blocked, online-softmax)
self-attention with a custom-VJP FlashAttention-2 style backward pass, and
single-token decode attention over (possibly ring-buffered) KV caches.

The flash implementation is pure JAX (scans over q/kv blocks) so it lowers
on any backend; it is also the numerical oracle for the Pallas flash kernel
in ``repro.kernels.flash_attention``.  Memory is O(S · block) instead of
O(S^2), which is what makes the 32k-prefill / 4k-train cells fit HBM in the
dry-run.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import rms_norm, truncated_normal_init

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------


def rope_angles(
    positions: jax.Array,
    head_dim: int,
    theta: float,
    mrope_sections: Optional[Tuple[int, ...]] = None,
) -> jax.Array:
    """positions: [B, S] (standard) or [B, 3, S] (M-RoPE).
    Returns angles [B, S, head_dim // 2] (fp32)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if mrope_sections is None:
        if positions.ndim == 3:
            positions = positions[:, 0]
        return positions[..., None].astype(jnp.float32) * freqs
    assert positions.ndim == 3, "M-RoPE needs [B, 3, S] positions"
    assert sum(mrope_sections) == half, (mrope_sections, half)
    parts = []
    start = 0
    for comp, sec in enumerate(mrope_sections):
        p = positions[:, comp].astype(jnp.float32)  # [B, S]
        parts.append(p[..., None] * freqs[start : start + sec])
        start += sec
    return jnp.concatenate(parts, axis=-1)


def apply_rope(x: jax.Array, angles: jax.Array) -> jax.Array:
    """x: [B, S, n, head_dim]; angles: [B, S, head_dim//2]."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    return jnp.concatenate((x1 * cos - x2 * sin, x1 * sin + x2 * cos), axis=-1).astype(
        dtype
    )


# ---------------------------------------------------------------------------
# Flash attention (blocked, custom VJP)
# ---------------------------------------------------------------------------


def _block_mask(qpos, kpos, causal: bool, window: Optional[int]):
    """qpos: [qc], kpos: [kc] -> bool [qc, kc] (True = attend)."""
    m = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        m &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        m &= qpos[:, None] - kpos[None, :] < window
    return m


def _flash_fwd_inner(q_blk, k_r, v_r, qpos, kpos_all, causal, window, scale, kc):
    """Online-softmax over kv blocks for one q block.

    q_blk: [B, qc, KV, G, hd]; k_r/v_r: [nk, B, kc, KV, hd].
    Returns (out [B, qc, KV, G, hd] fp32, lse [B, qc, KV, G] fp32)."""
    B, qc, KV, G, hd = q_blk.shape
    nk = k_r.shape[0]

    def step(carry, inp):
        m, l, acc = carry
        k_blk, v_blk, j = inp
        s = jnp.einsum(
            "bqgnd,bkgd->bqgnk", q_blk, k_blk, preferred_element_type=jnp.float32
        ) * scale  # [B, qc, KV, G, kc]
        kpos = j * kc + jnp.arange(kc)
        mask = _block_mask(qpos, kpos, causal, window)
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * corr + p.sum(axis=-1)
        pv = jnp.einsum(
            "bqgnk,bkgd->bqgnd",
            p.astype(v_blk.dtype),
            v_blk,
            preferred_element_type=jnp.float32,
        )
        acc = acc * corr[..., None] + pv
        return (m_new, l, acc), None

    init = (
        jnp.full((B, qc, KV, G), NEG_INF, jnp.float32),
        jnp.zeros((B, qc, KV, G), jnp.float32),
        jnp.zeros((B, qc, KV, G, hd), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(step, init, (k_r, v_r, jnp.arange(nk)))
    l_safe = jnp.where(l == 0.0, 1.0, l)
    out = acc / l_safe[..., None]
    lse = m + jnp.log(l_safe)
    return out, lse


def _reshape_qkv(q, k, v):
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    q = q.reshape(B, Sq, KV, G, hd)
    return q, k, v, (B, Sq, H, hd, KV, G)


@functools.lru_cache(maxsize=None)
def make_flash_attention(
    causal: bool,
    window: Optional[int],
    q_chunk: int,
    kv_chunk: int,
    scale: float,
    q_offset: int = 0,
):
    """Build a custom-VJP flash attention fn for static settings.

    Returned fn: (q [B,Sq,H,hd], k [B,Skv,KV,hd], v, qpos [Sq] int32)
    -> out [B,Sq,H,hd].  ``qpos`` carries the (possibly dynamic, e.g.
    sequence-parallel shard-offset) absolute position of every query row;
    key positions are 0..Skv-1.
    """

    @jax.custom_vjp
    def fa(q, k, v, qpos):
        return _fwd(q, k, v, qpos)[0]

    def _fwd(q, k, v, qpos):
        in_dtype = q.dtype
        qr, k_, v_, (B, Sq, H, hd, KV, G) = _reshape_qkv(q, k, v)
        Skv = k.shape[1]
        qc = min(q_chunk, Sq)
        kc = min(kv_chunk, Skv)
        assert Sq % qc == 0 and Skv % kc == 0, (Sq, qc, Skv, kc)
        nq, nk = Sq // qc, Skv // kc
        q_r = qr.reshape(B, nq, qc, KV, G, hd).transpose(1, 0, 2, 3, 4, 5)
        k_r = k_.reshape(B, nk, kc, KV, hd).transpose(1, 0, 2, 3, 4)
        v_r = v_.reshape(B, nk, kc, KV, hd).transpose(1, 0, 2, 3, 4)
        qpos_r = qpos.reshape(nq, qc)

        def per_q_block(qp, q_blk):
            return _flash_fwd_inner(
                q_blk, k_r, v_r, qp, None, causal, window, scale, kc
            )

        out_r, lse_r = jax.lax.map(
            lambda args: per_q_block(*args), (qpos_r, q_r)
        )
        out = (
            out_r.transpose(1, 0, 2, 3, 4, 5)
            .reshape(B, Sq, H, hd)
            .astype(in_dtype)
        )
        lse = lse_r.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H)
        return out, (q, k, v, out, lse, qpos)

    def _bwd(res, do):
        q, k, v, out, lse, qpos = res
        in_dtype = q.dtype
        qr, k_, v_, (B, Sq, H, hd, KV, G) = _reshape_qkv(q, k, v)
        Skv = k.shape[1]
        qc = min(q_chunk, Sq)
        kc = min(kv_chunk, Skv)
        nq, nk = Sq // qc, Skv // kc
        do_f = do.astype(jnp.float32)
        # D_i = rowsum(dO * O)
        delta = jnp.sum(do_f * out.astype(jnp.float32), axis=-1)  # [B, Sq, H]

        q_r = qr.reshape(B, nq, qc, KV, G, hd).transpose(1, 0, 2, 3, 4, 5)
        k_r = k_.reshape(B, nk, kc, KV, hd).transpose(1, 0, 2, 3, 4)
        v_r = v_.reshape(B, nk, kc, KV, hd).transpose(1, 0, 2, 3, 4)
        do_r = (
            do_f.reshape(B, nq, qc, KV, G, hd).transpose(1, 0, 2, 3, 4, 5)
        )
        lse_r = lse.reshape(B, nq, qc, KV, G).transpose(1, 0, 2, 3, 4)
        dl_r = delta.reshape(B, nq, qc, KV, G).transpose(1, 0, 2, 3, 4)
        qpos_r = qpos.reshape(nq, qc)

        def scores(q_blk, k_blk, qp, j):
            s = jnp.einsum(
                "bqgnd,bkgd->bqgnk", q_blk, k_blk, preferred_element_type=jnp.float32
            ) * scale
            kpos = j * kc + jnp.arange(kc)
            mask = _block_mask(qp, kpos, causal, window)
            return jnp.where(mask[None, :, None, None, :], s, NEG_INF)

        # Pass 1: dq (scan over q blocks; inner scan over kv blocks)
        def dq_block(args):
            qp, q_blk, do_blk, lse_blk, dl_blk = args

            def step(dq_acc, inp):
                k_blk, v_blk, j = inp
                s = scores(q_blk, k_blk, qp, j)
                p = jnp.exp(s - lse_blk[..., None])
                dp = jnp.einsum(
                    "bqgnd,bkgd->bqgnk", do_blk, v_blk,
                    preferred_element_type=jnp.float32,
                )
                ds = p * (dp - dl_blk[..., None]) * scale
                dq_acc = dq_acc + jnp.einsum(
                    "bqgnk,bkgd->bqgnd", ds.astype(k_blk.dtype), k_blk,
                    preferred_element_type=jnp.float32,
                )
                return dq_acc, None

            init = jnp.zeros_like(q_blk, jnp.float32)
            dq_blk, _ = jax.lax.scan(step, init, (k_r, v_r, jnp.arange(nk)))
            return dq_blk

        dq_r = jax.lax.map(dq_block, (qpos_r, q_r, do_r, lse_r, dl_r))

        # Pass 2: dk, dv (scan over kv blocks; inner scan over q blocks)
        def dkv_block(args):
            j, k_blk, v_blk = args

            def step(carry, inp):
                dk_acc, dv_acc = carry
                qp, q_blk, do_blk, lse_blk, dl_blk = inp
                s = scores(q_blk, k_blk, qp, j)
                p = jnp.exp(s - lse_blk[..., None])
                dv_acc = dv_acc + jnp.einsum(
                    "bqgnk,bqgnd->bkgd", p.astype(do_blk.dtype), do_blk,
                    preferred_element_type=jnp.float32,
                )
                dp = jnp.einsum(
                    "bqgnd,bkgd->bqgnk", do_blk, v_blk,
                    preferred_element_type=jnp.float32,
                )
                ds = p * (dp - dl_blk[..., None]) * scale
                dk_acc = dk_acc + jnp.einsum(
                    "bqgnk,bqgnd->bkgd", ds, q_blk.astype(jnp.float32),
                    preferred_element_type=jnp.float32,
                )
                return (dk_acc, dv_acc), None

            init = (
                jnp.zeros(k_blk.shape, jnp.float32),
                jnp.zeros(v_blk.shape, jnp.float32),
            )
            (dk_blk, dv_blk), _ = jax.lax.scan(
                step, init, (qpos_r, q_r, do_r, lse_r, dl_r)
            )
            return dk_blk, dv_blk

        dk_r, dv_r = jax.lax.map(dkv_block, (jnp.arange(nk), k_r, v_r))

        dq = (
            dq_r.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, H, hd).astype(in_dtype)
        )
        dk = dk_r.transpose(1, 0, 2, 3, 4).reshape(B, Skv, KV, hd).astype(in_dtype)
        dv = dv_r.transpose(1, 0, 2, 3, 4).reshape(B, Skv, KV, hd).astype(in_dtype)
        import numpy as _np

        dqpos = _np.zeros(qpos.shape, jax.dtypes.float0)
        return dq, dk, dv, dqpos

    fa.defvjp(_fwd, _bwd)
    return fa


def _fit_chunk(S: int, c: int) -> int:
    """Largest divisor of S that is <= c."""
    c = min(c, S)
    while S % c:
        c -= 1
    return c


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_chunk: int = 512,
    kv_chunk: int = 512,
    q_offset: int = 0,
    q_positions: Optional[jax.Array] = None,  # [Sq] (overrides q_offset)
) -> jax.Array:
    scale = 1.0 / (q.shape[-1] ** 0.5)
    q_chunk = _fit_chunk(q.shape[1], q_chunk)
    kv_chunk = _fit_chunk(k.shape[1], kv_chunk)
    fn = make_flash_attention(causal, window, q_chunk, kv_chunk, scale)
    if q_positions is None:
        q_positions = q_offset + jnp.arange(q.shape[1], dtype=jnp.int32)
    return fn(q, k, v, q_positions)


def reference_attention(q, k, v, *, causal=True, window=None, q_offset=0):
    """O(S^2)-memory oracle used by tests."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = 1.0 / (hd ** 0.5)
    qr = q.reshape(B, Sq, KV, G, hd)
    s = jnp.einsum("bqgnd,bkgd->bqgnk", qr.astype(jnp.float32), k.astype(jnp.float32))
    s = s * scale
    qpos = q_offset + jnp.arange(Sq)
    kpos = jnp.arange(k.shape[1])
    mask = _block_mask(qpos, kpos, causal, window)
    s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqgnk,bkgd->bqgnd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Decode attention (single new token vs. cache)
# ---------------------------------------------------------------------------


def decode_attention(
    q: jax.Array,  # [B, 1, H, hd]
    k_cache: jax.Array,  # [B, S, KV, hd]
    v_cache: jax.Array,
    q_positions: jax.Array,  # [B] int32 current position of the query token
    key_positions: jax.Array,  # [B, S] int32 position held by each cache slot
    window: Optional[int] = None,
) -> jax.Array:
    B, _, H, hd = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    scale = 1.0 / (hd ** 0.5)
    qr = q.reshape(B, KV, G, hd)
    s = jnp.einsum(
        "bgnd,bkgd->bgnk", qr, k_cache, preferred_element_type=jnp.float32
    ) * scale  # [B, KV, G, S]
    valid = key_positions <= q_positions[:, None]
    if window is not None:
        valid &= key_positions > (q_positions[:, None] - window)
    valid &= key_positions >= 0
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum(
        "bgnk,bkgd->bgnd", p.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )
    return o.reshape(B, 1, H, hd).astype(q.dtype)


def chunk_attention(
    q: jax.Array,  # [B, C, H, hd] one prefill chunk of queries
    k_cache: jax.Array,  # [B, S, KV, hd] ring view (pages gathered)
    v_cache: jax.Array,
    q_positions: jax.Array,  # [B, C] int32 absolute position of each query
    key_positions: jax.Array,  # [B, S] int32 position held by each cache slot
    window: Optional[int] = None,
) -> jax.Array:
    """Chunked-prefill attention: C queries against a (possibly
    ring-buffered / paged) KV cache that already contains the chunk's own
    k/v.  Per-query causal masking over absolute positions — the C=1 case
    is exactly :func:`decode_attention`.  O(C·S) memory, no materialized
    [S, S] score matrix, which is what lets admission stream a long prompt
    through fixed-shape chunk traces."""
    B, C, H, hd = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    scale = 1.0 / (hd ** 0.5)
    qr = q.reshape(B, C, KV, G, hd)
    s = jnp.einsum(
        "bcgnd,bkgd->bcgnk", qr, k_cache, preferred_element_type=jnp.float32
    ) * scale  # [B, C, KV, G, S]
    valid = key_positions[:, None, :] <= q_positions[:, :, None]  # [B, C, S]
    if window is not None:
        valid &= key_positions[:, None, :] > (q_positions[:, :, None] - window)
    valid &= key_positions[:, None, :] >= 0
    s = jnp.where(valid[:, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum(
        "bcgnk,bkgd->bcgnd", p.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )
    return o.reshape(B, C, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Paged attention (gather-free decode/chunk attention over a KV page pool)
# ---------------------------------------------------------------------------

_PAGED_ATTN_IMPL: Optional[str] = None  # None = auto: kernel on TPU, ref off


def set_paged_attention_impl(impl: Optional[str]):
    """Force the paged-attention implementation: ``"kernel"`` (the fused
    Pallas kernel — interpret-mode off TPU), ``"ref"`` (the pure-JAX
    gather-free oracle), or ``None`` to autodetect (kernel on TPU, ref
    elsewhere).  Read at *trace* time: engines built before a change keep
    their already-compiled stage traces."""
    global _PAGED_ATTN_IMPL
    if impl not in (None, "kernel", "ref"):
        raise ValueError(f"impl={impl!r} (want 'kernel', 'ref', or None)")
    _PAGED_ATTN_IMPL = impl


def paged_chunk_attention(
    q: jax.Array,  # [B, C, H, hd] C queries (decode is the C=1 case)
    pool_k: jax.Array,  # [P+1, ps, KV, hd] page pool (row P = garbage)
    pool_v: jax.Array,
    table: jax.Array,  # [B, pps] int32 physical page per ring entry
    q_positions: jax.Array,  # [B, C] int32 absolute position of each query
    lengths: jax.Array,  # [B] int32 ring anchor (last written position)
    *,
    window: Optional[int] = None,
    k_scale: Optional[jax.Array] = None,  # [P+1, ps] f16 sidecar (int8 pool)
    v_scale: Optional[jax.Array] = None,
) -> jax.Array:
    """Attention straight off the page pool: page-table lookup, ring-position
    masking (``kvcache.ring_key_positions`` semantics), and online-softmax
    attention fused into one sweep over the slot's *mapped* pages — no dense
    ``paged_gather`` ring view is ever materialized.  Numerically the masked
    softmax of :func:`chunk_attention` over the gathered ring (exact in the
    score set; online-softmax reassociation only), which survives as the
    test oracle.  With ``k_scale``/``v_scale`` the pools hold int8 codes and
    both implementations dequantize the fetched pages in place (VMEM /
    registers) — no dense-dtype copy of the pool is ever materialized."""
    impl = _PAGED_ATTN_IMPL or (
        "kernel" if jax.default_backend() == "tpu" else "ref"
    )
    if impl == "kernel":
        from repro.kernels.paged_attention.ops import paged_attention

        return paged_attention(
            q, pool_k, pool_v, table, q_positions, lengths, window=window,
            k_scale=k_scale, v_scale=v_scale,
        )
    from repro.kernels.paged_attention.ref import paged_attention_ref

    return paged_attention_ref(
        q, pool_k, pool_v, table, q_positions, lengths, window=window,
        k_scale=k_scale, v_scale=v_scale,
    )


def paged_decode_attention(
    q: jax.Array,  # [B, 1, H, hd]
    pool_k: jax.Array,
    pool_v: jax.Array,
    table: jax.Array,
    lengths: jax.Array,  # [B] int32 position of the current (just-written) token
    *,
    window: Optional[int] = None,
    k_scale: Optional[jax.Array] = None,
    v_scale: Optional[jax.Array] = None,
) -> jax.Array:
    """Single-token decode against the page pool: the C=1 special case of
    :func:`paged_chunk_attention` (the query sits at ``lengths``, which is
    also the ring anchor)."""
    return paged_chunk_attention(
        q, pool_k, pool_v, table, lengths[:, None], lengths, window=window,
        k_scale=k_scale, v_scale=v_scale,
    )


# ---------------------------------------------------------------------------
# Attention layer (projections + norm + rope + attention + output)
# ---------------------------------------------------------------------------


def init_attention(key, cfg, dtype) -> Dict:
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": truncated_normal_init(kq, (d, H * hd), dtype, 1.0).reshape(d, H, hd),
        "wk": truncated_normal_init(kk, (d, KV * hd), dtype, 1.0).reshape(d, KV, hd),
        "wv": truncated_normal_init(kv, (d, KV * hd), dtype, 1.0).reshape(d, KV, hd),
        "wo": truncated_normal_init(ko, (H * hd, d), dtype, 1.0).reshape(H, hd, d),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def project_qkv(params: Dict, x: jax.Array, cfg, angles: Optional[jax.Array]):
    """x: [B, S, d] -> q [B,S,H,hd], k,v [B,S,KV,hd] (rope+qknorm applied)."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(x.dtype))
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    if angles is not None:
        q = apply_rope(q, angles)
        k = apply_rope(k, angles)
    return q, k, v


def output_proj(params: Dict, o: jax.Array) -> jax.Array:
    return jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(o.dtype))
