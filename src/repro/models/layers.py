"""Basic layers: norms, dense FFNs, embeddings.  Pure-functional (dict
params), so ``jax.eval_shape`` over ``init`` gives allocation-free param
specs for the dry-run."""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np


def truncated_normal_init(key, shape, dtype, scale: float):
    stddev = scale / np.sqrt(max(shape[0], 1))
    return (stddev * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dtype)


def init_norm(d: int, dtype) -> jax.Array:
    # Stored as a zero-centered scale (weight = 1 + w), which keeps
    # initialization at exactly 1.0 and plays nicely with weight decay.
    return jnp.zeros((d,), dtype)


ACTIVATIONS = {
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "relu": jax.nn.relu,
}


def init_mlp(key, d_model: int, d_ff: int, dtype, gated: bool = True) -> Dict:
    ki, kg, ko = jax.random.split(key, 3)
    p = {
        "wi": truncated_normal_init(ki, (d_model, d_ff), dtype, 1.0),
        "wo": truncated_normal_init(ko, (d_ff, d_model), dtype, 1.0),
    }
    if gated:
        p["wg"] = truncated_normal_init(kg, (d_model, d_ff), dtype, 1.0)
    return p


def apply_mlp(params: Dict, x: jax.Array, act: str = "silu") -> jax.Array:
    """(Optionally gated) FFN.  x: [..., d_model]."""
    a = ACTIVATIONS[act]
    h = x @ params["wi"].astype(x.dtype)
    if "wg" in params:
        h = a(h) * (x @ params["wg"].astype(x.dtype))
    else:
        h = a(h)
    return h @ params["wo"].astype(x.dtype)


def init_embedding(key, vocab: int, d_model: int, dtype) -> jax.Array:
    return truncated_normal_init(key, (vocab, d_model), dtype, 1.0)


def cross_entropy_loss(
    logits: jax.Array, labels: jax.Array, z_weight: float = 1e-4
):
    """Token-level CE with logsumexp z-regularization.

    logits: [..., V] (any float dtype; reduced in fp32)
    labels: [...] int32; positions with label < 0 are masked out.
    Returns (mean_loss, metrics_dict).
    """
    logits = logits.astype(jnp.float32)
    mask = (labels >= 0).astype(jnp.float32)
    labels = jnp.maximum(labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (lse - ll) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = nll.sum() / denom
    z_loss = z_weight * jnp.sum(jnp.square(lse) * mask) / denom
    return loss + z_loss, {
        "ce_loss": loss,
        "z_loss": z_loss,
        "tokens": denom,
    }
