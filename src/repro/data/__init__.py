from repro.data.pipeline import (
    DataConfig,
    make_dataset,
    batches,
)

__all__ = ["DataConfig", "make_dataset", "batches"]
