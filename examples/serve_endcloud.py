"""End-to-end driver: train a small group-gated MoE on the latent-task
mixture, then SERVE it through the full EC2MoE stack —

  1. batched continuous-batching engine (repro.serving.engine),
  2. the end-cloud collaborative pipeline (PO-ECC): route-aware layer split
     (eq. 9-11), hardware-aware expert masks on the end tier (eq. 2-4), and
     low-rank boundary compression (eq. 8), and
  3. the streaming end-cloud decode engine (repro.serving.stream): token-level
     two-tier pipeline with a double-buffered boundary and dynamic replanning
     when the link bandwidth drifts.

    PYTHONPATH=src python examples/serve_endcloud.py [--steps 200]
"""

import argparse

import jax.numpy as jnp
import numpy as np

from benchmarks.common import tiny_switch, train_tiny
from repro.core.hardware import PROFILES, DeviceState
from repro.data.pipeline import DataConfig, batches, eval_accuracy
from repro.serving.endcloud import EndCloudPipeline
from repro.serving.engine import Request, ServingEngine
from repro.serving.stream import EndCloudServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()

    # 1) train
    cfg = tiny_switch(8, "ec2moe")
    dcfg = DataConfig(task="lm", vocab_size=512, seq_len=64, n_latent_tasks=4)
    print(f"training {cfg.name} (E={cfg.moe.num_experts}, K={cfg.moe.num_groups}) "
          f"for {args.steps} steps ...")
    model, st = train_tiny(cfg, dcfg, steps=args.steps, seed=0)
    params = st["params"]
    print("final train metrics:", st["metrics"])

    # 2) batched serving engine
    eng = ServingEngine(model, params, max_batch=4, max_len=96)
    rng = np.random.default_rng(0)
    for i in range(8):
        eng.submit(Request(i, rng.integers(0, 500, 24).astype(np.int32),
                           max_new_tokens=8))
    done = eng.run()
    lat = [r.finish_time - r.submit_time for r in done]
    print(f"engine: {len(done)} requests served, "
          f"mean wall latency {np.mean(lat)*1e3:.0f} ms, "
          f"sample output {done[0].generated}")

    # 3) end-cloud pipeline (Xeon end + A100 cloud, paper testbed)
    pipe = EndCloudPipeline(
        model, params,
        end_profile=PROFILES["xeon-4214r"],
        cloud_profile=PROFILES["a100"],
        end_state=DeviceState(cpu_free=0.8, mem_free=0.6),
        compression_rank=cfg.d_model // 2,
    )
    print(f"route-aware plan: split at block {pipe.split}/{cfg.block_repeat}, "
          f"compress={pipe.plan.compress_boundary}, "
          f"end expert mask={None if pipe.end_mask is None else int(pipe.end_mask.sum())} experts")
    b = next(iter(batches(dcfg, 8, 1, seed=3)))
    logits, m = pipe.run_batch(jnp.asarray(b["tokens"]))
    print(f"pipeline metrics: {m}")
    print(f"pipeline accuracy on held-out batch: "
          f"{eval_accuracy(np.asarray(logits), b['labels'])*100:.1f}%")

    # 4) streaming end-cloud decode: continuous batching across the two
    #    tiers, boundary double-buffered, replanned when the link drifts
    seng = EndCloudServingEngine(
        model, params,
        end_profile=PROFILES["xeon-4214r"],
        cloud_profile=PROFILES["a100"],
        end_state=DeviceState(cpu_free=0.8, mem_free=0.6),
        compression_rank=cfg.d_model // 2,
        max_batch=4, max_len=96,
    )
    for i in range(8):
        seng.submit(Request(100 + i, rng.integers(0, 500, 24).astype(np.int32),
                            max_new_tokens=8))
    for _ in range(4):
        seng.step()
    seng.observe_bandwidth(0.03)  # link degrades to 30 Mbps mid-stream
    done = seng.run()
    sm = seng.metrics()
    print(f"streaming engine: {len(done)} requests, split={sm['split']}, "
          f"pipelined step {sm['pipelined_step_s']*1e3:.2f} ms vs serial "
          f"{sm['serial_step_s']*1e3:.2f} ms, boundary bytes {sm['bytes_up']}, "
          f"replans={sm['replan_events']}")


if __name__ == "__main__":
    main()
