"""Joint training of the PO-ECC low-rank codec (paper eq. 8).

Trains the same model twice — once with the dispatch codec in the loop
(joint, eq. 8) and once without (codec bolted on post-hoc) — and compares
accuracy under compressed serving.  Reproduces the paper's claim that joint
training preserves accuracy under compression.

    PYTHONPATH=src python examples/train_compression.py [--rank 16]
"""

import argparse

import jax
import jax.numpy as jnp

from benchmarks.common import eval_tiny, tiny_switch, train_tiny
from repro.configs import CompressionConfig
from repro.data.pipeline import DataConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rank", type=int, default=16)
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()

    dcfg = DataConfig(task="lm", vocab_size=512, seq_len=64, n_latent_tasks=4)

    joint_cfg = tiny_switch(8, "ec2moe").replace(
        compression=CompressionConfig(
            rank=args.rank, boundaries=("dispatch",), recon_weight=0.05
        )
    )
    print(f"joint training with rank-{args.rank} dispatch codec (eq. 8) ...")
    m1, s1 = train_tiny(joint_cfg, dcfg, steps=args.steps, seed=0)
    acc_joint = eval_tiny(m1, s1["params"], dcfg, n_batches=8)
    recon = s1["metrics"].get("recon_loss", float("nan"))
    print(f"  accuracy={acc_joint*100:.2f}%  final recon loss={recon:.4f}")

    print("training WITHOUT codec (baseline) ...")
    base_cfg = joint_cfg.replace(compression=None)
    m2, s2 = train_tiny(base_cfg, dcfg, steps=args.steps, seed=0)
    acc_base = eval_tiny(m2, s2["params"], dcfg, n_batches=8)
    print(f"  uncompressed accuracy={acc_base*100:.2f}%")

    print(f"\n=> joint-compressed model keeps "
          f"{acc_joint/acc_base*100:.1f}% of uncompressed accuracy at "
          f"{args.rank}/{joint_cfg.d_model} = "
          f"{args.rank/joint_cfg.d_model:.0%} boundary bytes")


if __name__ == "__main__":
    main()
