"""Quickstart: build a group-gated MoE (HL-GGN), run a forward pass, and
inspect the two-stage routing (paper eq. 5-7).

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.core.gating import group_gate_probs
from repro.core.hardware import PROFILES, DeviceState
from repro.core.selection import end_mask_for
from repro.models.model import build_model, make_dummy_batch


def main():
    # A reduced qwen3-moe (the HL-GGN flagship arch: 8 experts in 4 groups here)
    cfg = smoke_config(get_config("qwen3-moe-235b-a22b"))
    print(f"arch={cfg.name}  experts={cfg.moe.num_experts} "
          f"groups={cfg.moe.num_groups} top_k={cfg.moe.top_k}")

    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_dummy_batch(cfg, jax.random.PRNGKey(1), batch=2, seq=64)
    logits, aux = model.train_logits(params, batch)
    # scalar losses only — aux also carries the measured per-expert/group
    # routing fractions ([E]/[K]) that feed the serving engines' priorities
    scalars = {k: float(v) for k, v in aux.items() if np.ndim(v) == 0}
    print(f"logits {logits.shape}  aux={scalars}")

    # Peek at the two-stage gate on the embedding of the first tokens.
    x = params["embed"][jnp.asarray(batch["tokens"])].reshape(-1, cfg.d_model)
    gate_params = jax.tree.map(lambda l: l[0], params["blocks"]["pos0"]["moe"]["gate"])
    probs, p_group, _ = group_gate_probs(gate_params, x[:8].astype(jnp.float32), cfg.moe)
    print("stage-1 group probs (first token):", np.round(np.asarray(p_group[0]), 3))
    print("combined expert probs sum:", float(probs.sum(-1)[0]))

    # Hardware-aware local expert selection (eq. 2-4) for a phone-class end
    mask = end_mask_for(
        PROFILES["phone-soc"], DeviceState(mem_free=0.8),
        cfg.d_model, cfg.moe.d_ff_expert,
        cfg.moe.num_experts, cfg.moe.num_groups,
    )
    print(f"end-tier expert mask (≤40% cap): {mask.astype(int)} "
          f"({mask.sum()}/{cfg.moe.num_experts} experts local)")

    # Masked routing: excluded experts get exactly zero probability
    probs_m, _, _ = group_gate_probs(
        gate_params, x[:8].astype(jnp.float32), cfg.moe, jnp.asarray(mask)
    )
    print("masked expert probs (token 0):", np.round(np.asarray(probs_m[0]), 3))


if __name__ == "__main__":
    main()
