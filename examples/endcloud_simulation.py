"""End-cloud fleet simulation: sweep request rate and bandwidth fluctuation
for the three systems (paper figs. 7-8) on full-size Switch-Base.

    PYTHONPATH=src python examples/endcloud_simulation.py
"""

from repro.configs.switch_base import with_experts
from repro.sim.policies import PolicyConfig, make_requests
from repro.sim.simulator import Link, poisson_arrivals, simulate


def main():
    cfg = with_experts(16)
    pc = PolicyConfig()
    print(f"fleet: {pc.n_end_devices}x {pc.end_profile.name} end + "
          f"{pc.n_cloud_gpus}x {pc.cloud_profile.name} cloud, 300 Mbps ±20%\n")

    print("== request-rate sweep (fig. 7) ==")
    for rate in (2, 4, 6, 8, 10):
        row = []
        for system in ("ec2moe", "brownoutserve", "edgemoe"):
            arr = poisson_arrivals(rate, 200, 0)
            m = simulate(
                make_requests(system, cfg, pc, arr, offered_rps=rate),
                link=Link(0.3, fluctuation=0.2, seed=0),
                end_servers=pc.n_end_devices, cloud_servers=pc.n_cloud_gpus,
            )
            row.append(f"{system}: {m['throughput_rps']:5.2f} rps "
                       f"{m['latency_mean_s']*1e3:7.0f} ms")
        print(f"rate {rate:2d} | " + " | ".join(row))

    print("\n== bandwidth-fluctuation sweep (fig. 8) ==")
    for fl in (0.0, 0.2, 0.4):
        row = []
        for system in ("ec2moe", "brownoutserve"):
            arr = poisson_arrivals(6, 200, 1)
            m = simulate(
                make_requests(system, cfg, pc, arr, offered_rps=6),
                link=Link(0.3, fluctuation=fl, seed=1),
                end_servers=pc.n_end_devices, cloud_servers=pc.n_cloud_gpus,
            )
            row.append(f"{system}: {m['latency_mean_s']*1e3:6.0f} ms")
        print(f"fluct {fl:.0%} | " + " | ".join(row))


if __name__ == "__main__":
    main()
