"""Mamba-2 SSD: chunked == recurrent oracle; decode chain == full forward."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep: deterministic fallback grid
    from _hypothesis_compat import given, settings, st

from repro.configs import get_config, smoke_config
from repro.models import ssm


def _inputs(B, S, H, P, G, N, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, G, N))
    Cm = jax.random.normal(ks[4], (B, S, G, N))
    return x, dt, A, Bm, Cm


@settings(max_examples=10, deadline=None)
@given(
    chunk=st.sampled_from([8, 16, 64]),
    hb=st.sampled_from([1, 2, 4]),
    seed=st.integers(0, 3),
)
def test_chunked_matches_recurrent(chunk, hb, seed):
    x, dt, A, Bm, Cm = _inputs(2, 64, 4, 8, 1, 8, seed)
    y1, h1 = ssm.ssd_chunked(x, dt, A, Bm, Cm, chunk_size=chunk, head_block=hb)
    y2, h2 = ssm.ssd_reference(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=1e-4, atol=1e-4)


def test_initial_state_continuation():
    """Running two halves with state carry == one full pass."""
    x, dt, A, Bm, Cm = _inputs(1, 64, 2, 8, 1, 8, 7)
    y_full, h_full = ssm.ssd_chunked(x, dt, A, Bm, Cm, chunk_size=16, head_block=2)
    y1, h1 = ssm.ssd_chunked(
        x[:, :32], dt[:, :32], A, Bm[:, :32], Cm[:, :32],
        chunk_size=16, head_block=2,
    )
    y2, h2 = ssm.ssd_chunked(
        x[:, 32:], dt[:, 32:], A, Bm[:, 32:], Cm[:, 32:],
        chunk_size=16, head_block=2, initial_state=h1,
    )
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full),
                               rtol=1e-4, atol=1e-4)


def test_multi_group_heads():
    x, dt, A, Bm, Cm = _inputs(1, 32, 4, 8, 2, 8, 5)
    y1, h1 = ssm.ssd_chunked(x, dt, A, Bm, Cm, chunk_size=8, head_block=2)
    y2, h2 = ssm.ssd_reference(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)


def test_full_layer_decode_chain_matches_forward():
    """Prefill + decode steps through the full Mamba-2 layer reproduce the
    full-sequence forward exactly (state/conv cache correctness)."""
    cfg = smoke_config(get_config("mamba2-130m"))
    params = ssm.init_ssm(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, cfg.d_model))

    y_full = ssm.apply_ssm(params, x, cfg)

    S_pre = 16
    y_pre, (state, conv) = ssm.apply_ssm(
        params, x[:, :S_pre], cfg, return_state=True
    )
    np.testing.assert_allclose(np.asarray(y_pre), np.asarray(y_full[:, :S_pre]),
                               rtol=2e-4, atol=2e-4)
    ys = [y_pre]
    state = state.astype(jnp.float32)
    for t in range(S_pre, 24):
        y_t, (state, conv) = ssm.apply_ssm_decode(
            params, x[:, t : t + 1], cfg, state, conv
        )
        ys.append(y_t)
    y_chain = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chain), np.asarray(y_full),
                               rtol=3e-4, atol=3e-4)


def test_conv_decode_matches_full():
    from repro.models.ssm import causal_conv1d, conv1d_decode_step

    B, S, C, W = 2, 10, 6, 4
    x = jax.random.normal(jax.random.PRNGKey(0), (B, S, C))
    w = jax.random.normal(jax.random.PRNGKey(1), (W, C))
    b = jax.random.normal(jax.random.PRNGKey(2), (C,))
    full = causal_conv1d(x, w, b)
    state = jnp.zeros((B, W - 1, C))
    outs = []
    for t in range(S):
        o, state = conv1d_decode_step(x[:, t], state, w, b)
        outs.append(o)
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)), np.asarray(full),
                               rtol=1e-4, atol=1e-4)
