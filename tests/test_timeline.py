"""Direct unit tests for StageTimeline's interval-booking occupancy clock.

The serving engines and fleet benchmarks lean on StageTimeline for every
modeled latency number; these tests pin its queueing semantics down
without a model in the loop: multi-server parallel booking, backfill into
earlier gaps (fleet lanes book the shared cloud out of virtual-time
order), FCFS gap reuse, and per-resource isolation.
"""

import pytest

from repro.serving.common import StageTimeline


def test_multi_server_parallel_booking():
    # two cloud servers: two unit jobs at t=0 run in parallel, the third
    # queues behind the earlier-free server
    tl = StageTimeline(resources=["cloud"], capacity={"cloud": 2})
    assert tl.occupy("cloud", 0.0, 1.0) == 1.0
    assert tl.occupy("cloud", 0.0, 1.0) == 1.0
    assert tl.occupy("cloud", 0.0, 1.0) == 2.0
    assert tl.busy_s["cloud"] == pytest.approx(3.0)
    assert tl.makespan_s == pytest.approx(2.0)
    assert tl.serial_s == pytest.approx(3.0)


def test_backfill_into_earlier_gap():
    # a slow lane books the far future first; a fast lane's later request
    # must land in the earlier idle gap, not behind the future booking
    tl = StageTimeline(resources=["cloud"])
    assert tl.occupy("cloud", 100.0, 5.0) == 105.0
    assert tl.occupy("cloud", 10.0, 5.0) == 15.0
    assert tl.makespan_s == pytest.approx(105.0)
    assert tl.busy_s["cloud"] == pytest.approx(10.0)


def test_fcfs_gap_reuse():
    # busy [0,2) and [5,7): a 3s job at ready=0 fits exactly in [2,5);
    # the next 3s job finds every gap too small and queues at the tail
    tl = StageTimeline(resources=["end"])
    tl.occupy("end", 0.0, 2.0)
    tl.occupy("end", 5.0, 2.0)
    assert tl.occupy("end", 0.0, 3.0) == 5.0
    assert tl.occupy("end", 0.0, 3.0) == 10.0
    assert tl.makespan_s == pytest.approx(10.0)


def test_gap_too_small_is_skipped():
    # busy [0,2) and [3,5): a 2s job cannot fit the 1s hole at [2,3)
    tl = StageTimeline(resources=["end"])
    tl.occupy("end", 0.0, 2.0)
    tl.occupy("end", 3.0, 2.0)
    assert tl.occupy("end", 0.0, 2.0) == 7.0


def test_resource_isolation():
    # occupancy on one resource never delays another; busy_s is per-resource
    tl = StageTimeline(resources=["end", "link"])
    assert tl.occupy("end", 0.0, 4.0) == 4.0
    assert tl.occupy("link", 0.0, 1.0) == 1.0
    assert tl.busy_s == {"end": 4.0, "link": 1.0}
    assert tl.free_at["end"] == pytest.approx(4.0)
    assert tl.free_at["link"] == pytest.approx(1.0)
    assert tl.serial_s == pytest.approx(5.0)


def test_add_resource_idempotent():
    tl = StageTimeline(resources=["cloud"], capacity={"cloud": 2})
    tl.occupy("cloud", 0.0, 1.0)
    tl.add_resource("end0")
    assert tl.occupy("end0", 0.0, 2.0) == 2.0
    # re-registering must not wipe existing bookings or shrink capacity
    tl.add_resource("cloud", capacity=1)
    tl.add_resource("end0")
    assert tl.busy_s["cloud"] == pytest.approx(1.0)
    assert tl.busy_s["end0"] == pytest.approx(2.0)
    assert tl.occupy("cloud", 0.0, 1.0) == 1.0  # second server still there


def test_zero_service_books_nothing():
    tl = StageTimeline(resources=["end"])
    assert tl.occupy("end", 3.0, 0.0) == 3.0
    assert tl.busy_s["end"] == 0.0
    # the zero-length job leaves no interval behind to block others
    assert tl.occupy("end", 0.0, 1.0) == 1.0
