"""Per-architecture smoke tests (deliverable f): every assigned arch at a
reduced config runs one forward/train step on CPU with finite outputs and
the right shapes; decode continues prefill consistently."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, ASSIGNED_ARCHS, get_config, smoke_config
from repro.models.model import build_model, make_dummy_batch

SEQ = 64
BATCH = 2


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_forward_and_shapes(arch):
    cfg = smoke_config(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_dummy_batch(cfg, jax.random.PRNGKey(1), BATCH, SEQ)
    logits, aux = model.train_logits(params, batch)
    assert logits.shape == (BATCH, SEQ, cfg.padded_vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"
    if cfg.moe is not None:
        assert "aux_loss" in aux and np.isfinite(float(aux["aux_loss"]))


@pytest.mark.parametrize("arch", sorted(ASSIGNED_ARCHS))
def test_smoke_prefill_decode_consistency(arch):
    """Greedy decode after prefill matches the full-sequence forward's
    next-token logits (cache correctness across all cache types)."""
    cfg = smoke_config(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_dummy_batch(cfg, jax.random.PRNGKey(1), BATCH, SEQ)

    logits_full, _ = model.train_logits(params, batch, train=False)
    lg_pre, cache = model.prefill(params, batch, max_len=SEQ + 8)
    np.testing.assert_allclose(
        np.asarray(lg_pre), np.asarray(logits_full[:, -1]),
        rtol=3e-3, atol=3e-3,
    )
    tok = jnp.argmax(lg_pre, -1).astype(jnp.int32)[:, None]
    lg_dec, cache = model.decode_step(params, tok, cache)
    assert lg_dec.shape == (BATCH, cfg.padded_vocab_size)
    assert bool(jnp.isfinite(lg_dec).all())
    assert int(cache["lengths"][0]) == SEQ + 1


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "qwen3-moe-235b-a22b",
                                  "mamba2-130m", "jamba-1.5-large-398b"])
def test_smoke_train_step_decreases_loss(arch):
    from repro.launch.steps import make_train_step
    from repro.training.optimizer import OptimizerConfig, init_optimizer

    cfg = smoke_config(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = init_optimizer(cfg.optimizer, params)
    step = jax.jit(make_train_step(model, OptimizerConfig(
        name=cfg.optimizer, lr=1e-2, warmup_steps=1, decay_steps=100)))
    batch = {
        k: jnp.asarray(v)
        for k, v in make_dummy_batch(cfg, jax.random.PRNGKey(1), 4, 32).items()
    }
    losses = []
    for _ in range(8):
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0], f"{arch}: loss did not decrease {losses}"


def test_param_count_matches_tensors():
    """Analytic param_count tracks the real tensor count (excluding vocab
    padding) within 2%."""
    for arch in ("tinyllama-1.1b", "qwen3-14b", "mamba2-130m"):
        cfg = get_config(arch)
        scfg = smoke_config(cfg)
        model = build_model(scfg)
        params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        real = sum(np.prod(l.shape) for l in jax.tree.leaves(params))
        pad = (scfg.padded_vocab_size - scfg.vocab_size) * scfg.d_model
        n_embed_mats = 1 if scfg.tie_embeddings else 2
        analytic = scfg.param_count()
        assert abs(real - pad * n_embed_mats - analytic) / analytic < 0.02, arch


def test_full_configs_param_counts():
    """Full-size configs land near their published sizes."""
    expect = {
        "jamba-1.5-large-398b": (330e9, 480e9),
        "tinyllama-1.1b": (0.9e9, 1.3e9),
        "qwen3-14b": (12e9, 17e9),
        "qwen3-moe-235b-a22b": (200e9, 260e9),
        "mamba2-130m": (0.1e9, 0.2e9),
        "internlm2-20b": (17e9, 23e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo < n < hi, f"{arch}: {n/1e9:.1f}B outside [{lo/1e9},{hi/1e9}]"
