"""Multi-device behaviour, each case in a subprocess with 8 host devices
(XLA device count is locked at first jax init, so the main pytest process
must stay single-device)."""

import os
import subprocess
import sys
import textwrap

import jax.sharding
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

# These cases build meshes with explicit axis types (and the trainer /
# dry-run stacks do the same internally): jax < 0.5 has no
# ``jax.sharding.AxisType``, so on such containers they fail on the
# environment, not on this repo's code.  Version-guard rather than mask:
# on a jax that has AxisType they all run.
requires_axis_type = pytest.mark.skipif(
    not hasattr(jax.sharding, "AxisType"),
    reason="container jax lacks jax.sharding.AxisType (needs jax >= 0.5)",
)


def run_py(body: str, timeout=560) -> str:
    code = (
        "import os\n"
        "os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'\n"
        f"import sys; sys.path.insert(0, {SRC!r})\n" + textwrap.dedent(body)
    )
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=timeout,
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    return proc.stdout


@requires_axis_type
def test_ep_paths_match_sorted_oracle():
    out = run_py("""
        import dataclasses, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.core import moe as moe_mod
        from repro.distributed.topology import Topology
        from repro.configs import get_config, smoke_config

        cfg = smoke_config(get_config("qwen3-moe-235b-a22b"))
        # no codec: this test asserts exact path equivalence (the lossy
        # rank-r codec is intentionally non-identical in the tp path)
        cfg = cfg.replace(
            moe=dataclasses.replace(cfg.moe, capacity_factor=8.0),
            compression=None,
        )
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        topo = Topology(mesh=mesh, data_axes=("data",), model_axis="model")
        params = moe_mod.init_moe(jax.random.PRNGKey(3), cfg)
        x = jax.random.normal(jax.random.PRNGKey(4), (4, 16, cfg.d_model))
        y_ref, _ = moe_mod.apply_moe(params, x, cfg.replace(moe_impl="sorted"), None)
        with jax.set_mesh(mesh):
            for impl in ("a2a", "tp"):
                xs = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
                y, aux = jax.jit(lambda p, xx: moe_mod.apply_moe(
                    p, xx, cfg.replace(moe_impl=impl), topo))(params, xs)
                d = float(jnp.abs(y - y_ref).max())
                assert d < 2e-4, (impl, d)
                assert float(aux["dropped_frac"]) == 0.0
        print("EP OK")
    """)
    assert "EP OK" in out


@requires_axis_type
def test_sharded_cross_entropy_matches_plain():
    out = run_py("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.distributed.loss import sharded_cross_entropy
        from repro.distributed.topology import Topology
        from repro.models.layers import cross_entropy_loss

        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        topo = Topology(mesh=mesh, data_axes=("data",), model_axis="model")
        logits = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 32))
        labels = jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0, 32)
        labels = labels.at[0, 0].set(-1)  # masked position
        want, _ = cross_entropy_loss(logits, labels)
        with jax.set_mesh(mesh):
            ls = jax.device_put(logits, NamedSharding(mesh, P("data", None, "model")))
            got, m = jax.jit(lambda l, y: sharded_cross_entropy(l, y, topo))(ls, labels)
        assert abs(float(got) - float(want)) < 1e-4, (float(got), float(want))
        # gradient parity
        g1 = jax.grad(lambda l: cross_entropy_loss(l, labels)[0])(logits)
        with jax.set_mesh(mesh):
            g2 = jax.jit(jax.grad(
                lambda l: sharded_cross_entropy(l, labels, topo)[0]))(ls)
        import numpy as np
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                                   rtol=1e-4, atol=1e-5)
        print("CE OK")
    """)
    assert "CE OK" in out


@requires_axis_type
def test_train_step_on_mesh_and_elastic_restore():
    """Train 3 steps on a (2,4) mesh, checkpoint, resume on a SMALLER (1,4)
    mesh (elastic down-scale preserving the model/EP axis), keep training."""
    out = run_py("""
        import itertools, shutil, jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config, smoke_config
        from repro.data.pipeline import DataConfig, batches
        from repro.distributed.fault import elastic_topology
        from repro.training.trainer import Trainer, TrainerConfig

        shutil.rmtree("/tmp/elastic_ckpt", ignore_errors=True)
        cfg = smoke_config(get_config("qwen3-moe-235b-a22b")).replace(num_layers=1)
        dcfg = DataConfig(task="lm", vocab_size=512, seq_len=32)
        data = itertools.cycle(batches(dcfg, 8, 30))

        topo8 = elastic_topology(8, model_axis_size=4)
        tc = TrainerConfig(total_steps=3, checkpoint_every=3,
                           checkpoint_dir="/tmp/elastic_ckpt",
                           async_checkpoint=False, log_every=1)
        tr = Trainer(cfg, data, topo=topo8, trainer_cfg=tc).initialize()
        out = tr.run()
        l8 = out["log"][-1]["loss"]

        # two 'hosts' lost -> 4 devices remain; EP axis (4) preserved
        topo4 = elastic_topology(4, model_axis_size=4)
        assert topo4.dp_size == 1 and topo4.ep_size == 4
        tc2 = TrainerConfig(total_steps=5, checkpoint_every=5,
                            checkpoint_dir="/tmp/elastic_ckpt",
                            async_checkpoint=False, log_every=1)
        tr2 = Trainer(cfg, data, topo=topo4, trainer_cfg=tc2).initialize()
        assert tr2.step == 3, tr2.step  # resumed from the 8-device ckpt
        out2 = tr2.run()
        assert out2["final_step"] == 5
        assert all(np.isfinite(m["loss"]) for m in out2["log"])
        print("ELASTIC OK", l8)
    """)
    assert "ELASTIC OK" in out


@requires_axis_type
def test_dryrun_single_cell_smokes():
    """The dry-run driver itself (with 512 fake devices) on the smallest
    cell — proves the deliverable-e path end to end."""
    out = run_py("""
        import subprocess, sys, os, json, tempfile
        # dryrun sets its own XLA_FLAGS; run it as a module in a fresh proc
        env = dict(os.environ)
        env.pop("XLA_FLAGS", None)
        env["PYTHONPATH"] = r"%s"
        with tempfile.TemporaryDirectory() as td:
            outp = os.path.join(td, "r.json")
            p = subprocess.run(
                [sys.executable, "-m", "repro.launch.dryrun",
                 "--arch", "whisper-base", "--shape", "decode_32k",
                 "--mesh", "multi", "--out", outp],
                capture_output=True, text=True, env=env, timeout=520)
            assert p.returncode == 0, p.stdout + p.stderr
            rec = json.load(open(outp))[0]
            assert rec["status"] == "ok", rec
            assert rec["devices"] == 512
        print("DRYRUN OK")
    """ % SRC)
    assert "DRYRUN OK" in out
