"""Quantized byte streams: codec properties, fused-dequant kernel parity,
and engine-level greedy parity + byte accounting.

Layers of coverage, mirroring the three quantized streams:

  (a) codec properties (hypothesis-or-grid): int8/fp8 roundtrip error
      bounds, exact zero rows, scale linearity under power-of-two row
      scaling, and the scale-rounded-before-quantize inverse contract;
  (b) kernel-vs-ref parity in interpret mode for the quant ops, the
      paged-attention fused-dequant variant (against the dequantized-pool
      dense oracle), and the int8-slab resident expert FFN;
  (c) engine: greedy decode with all quant flags on stays within the
      documented exact-match tolerance of the f32 path at splits 0/mid/R,
      boundary bytes shrink to <= 0.55x, pools report >= 1.9x effective
      capacity, quantized pages spill/restore bit-identically through
      preemption, and byte metering is dtype-aware end to end
      (``serving.common.element_bytes`` — no hardcoded ``* 4``).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_compat import given, settings, st

from repro.configs import get_config, smoke_config
from repro.core import compression as comp
from repro.core import expertpool
from repro.core.hardware import PROFILES
from repro.kernels.expert_mlp.ops import expert_mlp
from repro.kernels.expert_mlp.ref import expert_mlp_resident_quant_ref
from repro.kernels.paged_attention.ops import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref
from repro.kernels.quant import (
    dequantize_rows,
    dequantize_rows_ref,
    quantize_rows,
    quantize_rows_ref,
)
from repro.models import kvcache
from repro.models.kvcache import PagePool
from repro.models.model import build_model
from repro.serving.common import Request, element_bytes
from repro.serving.stream import EndCloudServingEngine


# ----------------------------------------------------- (a) codec properties


@settings(max_examples=24, deadline=None)
@given(
    mode=st.sampled_from(["int8", "fp8"]),
    rows=st.integers(min_value=1, max_value=17),
    cols=st.integers(min_value=2, max_value=96),
    seed=st.integers(min_value=0, max_value=3),
)
def test_roundtrip_error_bound(mode, rows, cols, seed):
    """int8: per-element error <= scale/2 (round-to-nearest on a uniform
    grid, fp32 scale).  fp8 (e4m3): relative-precision ladder — error <=
    |x| * 2^-4 plus one subnormal step of the scaled grid."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(
        rng.standard_normal((rows, cols)) * 10.0 ** rng.integers(-3, 3),
        jnp.float32,
    )
    q, s = quantize_rows_ref(x, mode=mode)
    assert q.dtype == jnp.int8 and s.shape == (rows, 1)
    xh = dequantize_rows_ref(q, s, mode=mode, dtype=jnp.float32)
    err = jnp.abs(xh - x)
    sf = s.astype(jnp.float32)
    if mode == "int8":
        bound = sf / 2
    else:
        bound = jnp.abs(x) * 2.0 ** -4 + sf * 2.0 ** -9
    assert bool(jnp.all(err <= bound + 1e-12))


@given(mode=st.sampled_from(["int8", "fp8"]))
def test_zero_rows_roundtrip_exact(mode):
    """All-zero rows must come back exactly zero (the scale floor keeps the
    divide finite without polluting the codes)."""
    x = jnp.zeros((5, 33), jnp.float32)
    q, s = quantize_rows_ref(x, mode=mode)
    assert bool(jnp.all(q == 0))
    xh = dequantize_rows_ref(q, s, mode=mode, dtype=jnp.float32)
    assert bool(jnp.all(xh == 0.0))


@given(k=st.sampled_from([-3, -1, 2, 5]))
def test_scale_linearity_power_of_two(k):
    """Scaling a row by 2^k scales its quantization scale by exactly 2^k
    (fp32 scale; power-of-two so the fp mantissa is untouched) and leaves
    the int8 codes bit-identical."""
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.standard_normal((8, 64)), jnp.float32)
    q0, s0 = quantize_rows_ref(x)
    q1, s1 = quantize_rows_ref(x * 2.0 ** k)
    np.testing.assert_array_equal(np.asarray(q0), np.asarray(q1))
    np.testing.assert_array_equal(
        np.asarray(s1), np.asarray(s0) * 2.0 ** k
    )


def test_f16_scale_rounded_before_quantize():
    """The sidecar-dtype contract: with a float16 scale the codes are
    computed against the *rounded* scale, so dequantizing with the stored
    sidecar still satisfies the scale/2 error bound (plus the f16 scale's
    own rounding, bounded by half an f16 ulp of the range)."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((16, 64)), jnp.float32)
    q, s = quantize_rows_ref(x, scale_dtype=jnp.float16)
    assert s.dtype == jnp.float16
    xh = dequantize_rows_ref(q, s, dtype=jnp.float32)
    sf = s.astype(jnp.float32)
    assert bool(jnp.all(jnp.abs(xh - x) <= sf / 2 + 1e-12))
    # the boundary codec and the KV pool use the same rounded-scale rule
    qb, sb = comp.quantize_boundary(x)
    assert sb.dtype == comp.BOUNDARY_SCALE_DTYPE
    zb = comp.dequantize_boundary(qb, sb, dtype=jnp.float32)
    assert bool(jnp.all(jnp.abs(zb - x) <= sb.astype(jnp.float32) / 2 + 1e-12))


# ------------------------------------------------- (b) kernel-vs-ref parity


def test_quant_ops_kernel_matches_ref():
    """The Pallas quantizer/dequantizer against the jnp oracle (interpret
    mode).  Scales may differ by 1 fp32 ulp (XLA divide-vs-reciprocal
    fusion), so parity is tolerance-based; codes differ by at most one grid
    step on ties."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((64, 128)), jnp.float32)
    qr, sr = quantize_rows_ref(x)
    qk, sk = quantize_rows(x, interpret=True)
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sr), rtol=3e-7)
    assert int(np.abs(np.asarray(qk, np.int32) - np.asarray(qr, np.int32)).max()) <= 1
    dr = dequantize_rows_ref(qr, sr, dtype=jnp.float32)
    dk = dequantize_rows(qk, sk, dtype=jnp.float32, interpret=True)
    np.testing.assert_allclose(np.asarray(dk), np.asarray(dr), rtol=3e-7, atol=1e-7)


def _quant_pool_case(lengths, *, ps=4, pps=4, num_pages=14, KV=2, hd=32,
                     seed=0):
    """Dense random pool -> (int8 pool + f16 sidecars, table) through the
    real allocator, plus the dequantized dense-equivalent oracle pool."""
    rng = np.random.default_rng(seed)
    B = len(lengths)
    pool = PagePool(num_pages, ps, pps, n_slots=B)
    for b, ln in enumerate(lengths):
        pool.reserve(b, kvcache.pages_needed(int(ln) + 1, ps, pps))
        pool.map_range(b, 0, int(ln) + 1)
    table = pool.device_rows(range(B))
    kd = jnp.asarray(rng.standard_normal((num_pages + 1, ps, KV, hd)),
                     jnp.float32)
    vd = jnp.asarray(rng.standard_normal((num_pages + 1, ps, KV, hd)),
                     jnp.float32)
    kq, ks = kvcache.quantize_kv_tokens(kd)
    vq, vs = kvcache.quantize_kv_tokens(vd)
    return (kq, ks, vq, vs), table


@pytest.mark.parametrize("window", [None, 7])
def test_paged_attention_quant_kernel_vs_ref(window):
    """Fused in-VMEM dequant (scales ride the page-table indirection as a
    scalar-prefetched sidecar) == attention over the dequantized pool."""
    lengths = np.asarray([1, 5, 9, 15], np.int64)
    (kq, ks, vq, vs), table = _quant_pool_case(lengths)
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((4, 1, 4, 32)), jnp.float32)
    ln = jnp.asarray(lengths, jnp.int32)
    k_deq = kvcache.dequantize_kv_pool(kq, ks, jnp.float32)
    v_deq = kvcache.dequantize_kv_pool(vq, vs, jnp.float32)
    want = paged_attention_ref(
        q, k_deq, v_deq, table, ln[:, None], ln, window=window
    )
    got_ref = paged_attention_ref(
        q, kq, vq, table, ln[:, None], ln, window=window,
        k_scale=ks, v_scale=vs,
    )
    got_kernel = paged_attention(
        q, kq, vq, table, ln[:, None], ln, window=window,
        k_scale=ks, v_scale=vs, interpret=True,
    )
    np.testing.assert_allclose(
        np.asarray(got_ref), np.asarray(want), rtol=2e-5, atol=2e-5
    )
    np.testing.assert_allclose(
        np.asarray(got_kernel), np.asarray(got_ref), rtol=2e-5, atol=2e-5
    )


def test_paged_attention_quant_chunk_kernel_vs_ref():
    """C>1 prefill chunks over a quantized pool (the chunked-prefill read
    path the engines trace)."""
    C = 4
    start = np.asarray([0, 2, 6, 12])
    n_valid = np.asarray([4, 4, 4, 2])
    last = start + n_valid - 1
    (kq, ks, vq, vs), table = _quant_pool_case(last, seed=2)
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.standard_normal((4, C, 4, 32)), jnp.float32)
    positions = jnp.asarray(start[:, None] + np.arange(C)[None, :], jnp.int32)
    ln = jnp.asarray(last, jnp.int32)
    k_deq = kvcache.dequantize_kv_pool(kq, ks, jnp.float32)
    v_deq = kvcache.dequantize_kv_pool(vq, vs, jnp.float32)
    want = paged_attention_ref(q, k_deq, v_deq, table, positions, ln)
    got = paged_attention(
        q, kq, vq, table, positions, ln,
        k_scale=ks, v_scale=vs, interpret=True,
    )
    valid_rows = np.arange(C)[None, :] < n_valid[:, None]
    np.testing.assert_allclose(
        np.asarray(got)[valid_rows], np.asarray(want)[valid_rows],
        rtol=2e-5, atol=2e-5,
    )


def test_expert_mlp_resident_quant_kernel_vs_ref():
    """int8 slab store: the kernel folds the per-output-column scales after
    each dot in VMEM; parity with the gather-dequantize-matmul oracle up to
    fp32 reassociation of the scale fold."""
    rng = np.random.default_rng(5)
    N, S, C, d, f = 6, 3, 8, 32, 64
    wi_q, wi_s = expertpool.quantize_slab(
        jnp.asarray(rng.standard_normal((N, d, f)), jnp.float32))
    wg_q, wg_s = expertpool.quantize_slab(
        jnp.asarray(rng.standard_normal((N, d, f)), jnp.float32))
    wo_q, wo_s = expertpool.quantize_slab(
        jnp.asarray(rng.standard_normal((N, f, d)), jnp.float32))
    x = jnp.asarray(rng.standard_normal((S, C, d)), jnp.float32)
    ids = jnp.asarray([0, 3, 5], jnp.int32)
    want = expert_mlp_resident_quant_ref(
        x, wi_q, wg_q, wo_q, wi_s, wg_s, wo_s, ids
    )
    got = expert_mlp(
        x, wi_q, wg_q, wo_q, resident_ids=ids,
        wi_scale=wi_s, wg_scale=wg_s, wo_scale=wo_s, interpret=True,
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4
    )


def test_write_slabs_quant_roundtrip_error_bound():
    """Writing full-precision expert weights into an int8 store and
    dequantizing with the stored per-output-column scales reconstructs
    them within scale/2 per element."""
    cfg = smoke_config(get_config("llama4-scout-17b-16e")).replace(num_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    moe_pos = [i for i, s in enumerate(cfg.layer_pattern) if s.moe][0]
    full = params["blocks"][f"pos{moe_pos}"]["moe"]
    store = expertpool.init_slab_store(cfg, 4, quantized=True)
    assert store["wi"].dtype == jnp.int8
    store = expertpool.write_slabs(store, full, [(0, 0, 1), (2, 1, 3)])
    for slab, b, e in ((0, 0, 1), (2, 1, 3)):
        for mat in ("wi", "wo"):
            src = np.asarray(full[mat][b, e], np.float32)
            s = np.asarray(store[f"{mat}_scale"][slab], np.float32)
            got = np.asarray(store[mat][slab], np.float32) * s[None, :]
            assert np.all(np.abs(got - src) <= s[None, :] / 2 + 1e-12)


def test_dense_page_bytes_is_exact_unquantized_counterpart():
    """dense_page_bytes == paged_block_bytes for an unquantized pool, and
    the quantized pool's per-page bytes come in under the 0.55x bar (f16
    per-token sidecar shared across KV heads)."""
    cfg = smoke_config(get_config("tinyllama-1.1b")).replace(num_layers=4)
    dense = kvcache.init_paged_blocks(cfg, 2, 8, 4, jnp.dtype(cfg.dtype))
    assert kvcache.paged_block_bytes(dense) == kvcache.dense_page_bytes(
        cfg, 2, 4
    )
    quant = kvcache.init_paged_blocks(
        cfg, 2, 8, 4, jnp.dtype(cfg.dtype), quantized=True
    )
    ratio = kvcache.paged_block_bytes(quant) / kvcache.dense_page_bytes(cfg, 2, 4)
    assert ratio <= 0.55
    assert 1.0 / ratio >= 1.9  # effective page capacity at the same budget


def test_expert_slab_bytes_quantized_ratio():
    """int8 slabs with per-output-column fp32 scales: >= 1.9x slabs per
    byte of budget (the gated smoke shape lands near 3.9x)."""
    cfg = smoke_config(get_config("llama4-scout-17b-16e")).replace(num_layers=2)
    dense = expertpool.expert_slab_bytes(cfg)
    quant = expertpool.expert_slab_bytes(cfg, quantized=True)
    assert quant / dense <= 0.55
    assert dense / quant >= 1.9


# ------------------------------------------------------------- (c) engine


@pytest.fixture(scope="module")
def tiny_model():
    cfg = smoke_config(get_config("tinyllama-1.1b")).replace(num_layers=4)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _prompts(n, seed=0):
    rng = np.random.default_rng(seed)
    return [
        rng.integers(0, 500, size=int(rng.integers(4, 16))).astype(np.int32)
        for _ in range(n)
    ]


def _run_engine(model, params, prompts, max_new_tokens=8, **kw):
    eng = EndCloudServingEngine(
        model, params,
        end_profile=PROFILES["a100"], cloud_profile=PROFILES["a100"],
        max_batch=4, max_len=64, **kw,
    )
    reqs = [Request(i, p, max_new_tokens=max_new_tokens)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    return {r.request_id: list(r.generated) for r in reqs}, eng


@pytest.mark.parametrize("split", [0, 2, 4])
def test_engine_quant_greedy_parity_and_bytes(tiny_model, split):
    """All streams quantized: greedy tokens match the f32-path engine at
    >= 85% exact-match rate (documented tolerance — int8 KV + boundary
    perturb near-tied logits), every request completes full-length, and
    boundary bytes land at <= 0.55x with >= 1.9x KV page capacity."""
    model, params = tiny_model
    prompts = _prompts(6)
    base, eb = _run_engine(model, params, prompts, force_split=split)
    got, eq = _run_engine(
        model, params, prompts, force_split=split,
        quantize_kv=True, quantize_boundary=True, quantize_experts=True,
    )
    assert all(len(got[k]) == len(base[k]) for k in base)
    total = sum(len(v) for v in base.values())
    match = sum(a == b for k in base for a, b in zip(base[k], got[k]))
    assert match / total >= 0.85
    assert eq.link.bytes_up <= 0.55 * eb.link.bytes_up
    mq = eq.metrics()
    assert mq["kv_quantized"] == 1.0 and mq["boundary_quantized"] == 1.0
    assert mq["kv_capacity_ratio"] >= 1.9
    # dense baselines are priced at the dense dtype: identical across runs
    mb = eb.metrics()
    assert mq["kv_bytes_dense_equiv"] == mb["kv_bytes_dense_equiv"]
    assert mq["attn_bytes_dense_step"] == mb["attn_bytes_dense_step"]


def test_engine_quant_off_is_bit_identical(tiny_model):
    """The flags default off and the dense path stays the exact oracle:
    two quant-off runs produce bit-identical token streams and the pools
    carry no sidecar leaves."""
    model, params = tiny_model
    prompts = _prompts(6)
    a, ea = _run_engine(model, params, prompts, force_split=2)
    b, _ = _run_engine(model, params, prompts, force_split=2)
    assert a == b
    assert not any(
        "k_scale" in e for e in jax.tree.leaves(
            ea._end_pages, is_leaf=lambda x: isinstance(x, dict))
        if isinstance(e, dict)
    )


def test_engine_quant_moe_expert_stream(tiny_model):
    """MoE lane with the int8 slab store: decode completes, wire pricing
    and capacity use the stored slab size, dense baselines do not shrink."""
    cfg = smoke_config(get_config("llama4-scout-17b-16e")).replace(num_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = _prompts(4)
    base, eb = _run_engine(model, params, prompts, force_split=1)
    got, eq = _run_engine(
        model, params, prompts, force_split=1,
        quantize_kv=True, quantize_boundary=True, quantize_experts=True,
    )
    assert all(len(got[k]) == len(base[k]) for k in base)
    mb, mq = eb.metrics(), eq.metrics()
    assert mq["expert_quantized"] == 1.0
    assert mq["expert_slab_bytes"] <= 0.55 * mq["expert_slab_bytes_dense"]
    assert mq["expert_capacity_ratio"] >= 1.9
    # the dense-sweep baseline holds full-precision weights in both runs
    assert mq["expert_bytes_step_dense"] == mb["expert_bytes_step_dense"]
    assert mq["expert_slab_bytes_dense"] == mb["expert_slab_bytes_dense"]
    # the store itself is int8 with scale sidecars
    assert eq._slab_store["wi"].dtype == jnp.int8
    assert "wi_scale" in eq._slab_store


def _preempt_scenario_prompts():
    rng = np.random.default_rng(42)
    return [
        rng.integers(0, 500, size=n).astype(np.int32)
        for n in (12, 14, 9)
    ]


def test_quant_spill_restore_bit_identical(tiny_model):
    """A quantized-KV slot preempted mid-decode resumes bit-identically:
    the spilled pytree carries the int8 codes AND the f16 scale sidecars,
    and the restored stream matches an uninterrupted quantized run."""
    model, params = tiny_model
    pa1, pa2, pb = _preempt_scenario_prompts()
    mk = dict(quantize_kv=True, quantize_boundary=True)
    # uninterrupted quantized reference (everything fits, no preemption)
    want, _ = _run_engine(
        model, params, [pa1, pa2, pb], max_new_tokens=12,
        force_split=2, **mk,
    )
    eng = EndCloudServingEngine(
        model, params,
        end_profile=PROFILES["a100"], cloud_profile=PROFILES["a100"],
        max_batch=2, max_len=64, force_split=2,
        admission="priority", preemption=True, **mk,
    )
    a1 = Request(0, pa1, max_new_tokens=12, priority=2)
    a2 = Request(1, pa2, max_new_tokens=12, priority=2)
    b = Request(2, pb, max_new_tokens=12, priority=0)
    eng.submit(a1)
    eng.submit(a2)
    for _ in range(200):
        eng.step()
        if len(a1.generated) >= 3 and len(a2.generated) >= 3:
            break
    assert not a1.done and not a2.done
    eng.submit(b)
    eng.step()
    assert eng.n_preemptions == 1
    # the spill carries the quantized pages and their sidecars byte-exact
    (spill,) = eng._spilled.values()
    dtypes = {l.dtype for l in jax.tree.leaves(spill.blocks)}
    assert jnp.dtype(jnp.int8) in dtypes
    assert jnp.dtype(kvcache.KV_SCALE_DTYPE) in dtypes
    done = eng.run()
    assert len(done) == 3 and eng.n_preempt_restores == 1
    got = {r.request_id: list(r.generated) for r in (a1, a2, b)}
    assert got == want


def test_element_bytes_and_dtype_aware_metering(tiny_model):
    """Satellite regression: serving byte metering resolves element widths
    from dtypes.  Unit: bf16/int8 are half/quarter of f32.  Engine: the
    same workload meters exactly 2x the boundary bytes at f32 vs bf16."""
    assert element_bytes(jnp.float32) == 4
    assert element_bytes("bfloat16") == 2
    assert element_bytes(jnp.int8) == 1
    assert element_bytes(np.float16) == 2
    model16, params16 = tiny_model
    cfg32 = model16.cfg.replace(dtype="float32")
    model32 = build_model(cfg32)
    params32 = model32.init(jax.random.PRNGKey(0))
    prompts = _prompts(4)
    _, e16 = _run_engine(model16, params16, prompts, force_split=2)
    _, e32 = _run_engine(model32, params32, prompts, force_split=2)
    assert e32.link.bytes_up == 2 * e16.link.bytes_up
