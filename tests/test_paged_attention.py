"""Fused paged decode/chunk attention vs the dense ``paged_gather`` oracle.

Three layers of parity, all in interpret mode (the kernel autodetects the
backend, so this file exercises exactly what CI runs on CPU):

  (a) functional: the pure-JAX gather-free ref and the Pallas kernel both
      match ``paged_gather`` + ``decode_attention``/``chunk_attention`` on
      random pools — ragged lengths (including a just-admitted slot holding
      a single token), sliding windows smaller than the ring, ring wrap,
      and C>1 prefill chunks with padding rows;
  (b) page-skip: garbage-routed and wholly-masked pages contribute nothing
      (a corrupted garbage page must not leak into live outputs);
  (c) end-to-end: greedy decode through the serving engines with the
      attention implementation pinned to the Pallas kernel is
      token-identical (f32) to the pre-refactor dense-gather oracle at tier
      splits 0 / mid / R, and through chunked prefill + sliding windows.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_config
from repro.core.hardware import PROFILES
from repro.kernels.paged_attention.ops import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref
from repro.models import attention as attn
from repro.models import kvcache
from repro.models.kvcache import PagePool
from repro.models.model import build_model
from repro.serving.engine import Request, ServingEngine
from repro.serving.stream import EndCloudServingEngine


# ------------------------------------------------------------ (a) functional


def _pool_case(lengths, *, ps=4, pps=4, num_pages=14, KV=2, hd=32, seed=0,
               dtype=jnp.float32):
    """Random pool + tables built through the real allocator: slot b holds
    positions [0, lengths[b]] (its current decode token included), mapped
    exactly as the engines map them; untouched entries stay garbage."""
    rng = np.random.default_rng(seed)
    B = len(lengths)
    pool = PagePool(num_pages, ps, pps, n_slots=B)
    for b, ln in enumerate(lengths):
        pool.reserve(b, kvcache.pages_needed(int(ln) + 1, ps, pps))
        pool.map_range(b, 0, int(ln) + 1)
    table = pool.device_rows(range(B))
    pool_k = jnp.asarray(
        rng.standard_normal((num_pages + 1, ps, KV, hd)), dtype
    )
    pool_v = jnp.asarray(
        rng.standard_normal((num_pages + 1, ps, KV, hd)), dtype
    )
    return pool_k, pool_v, table


def _dense_reference(q, pool_k, pool_v, table, q_positions, lengths, window):
    """The pre-refactor path: materialize the ring via paged_gather, then
    dense masked-softmax attention."""
    W = table.shape[1] * pool_k.shape[1]
    kbuf = kvcache.paged_gather(pool_k, table)
    vbuf = kvcache.paged_gather(pool_v, table)
    key_pos = kvcache.ring_key_positions(lengths, W)
    if q.shape[1] == 1:
        return attn.decode_attention(
            q, kbuf, vbuf, lengths, key_pos, window=window
        )
    return attn.chunk_attention(
        q, kbuf, vbuf, q_positions, key_pos, window=window
    )


@pytest.mark.parametrize("window", [None, 7])
def test_decode_matches_dense_gather_oracle(window):
    """Ragged decode lengths — slot 0 was just admitted and holds exactly
    one token (its prefill token at position 0, decoding position 1)."""
    lengths = np.asarray([1, 5, 9, 15], np.int64)
    pool_k, pool_v, table = _pool_case(lengths)
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((4, 1, 4, 32)), jnp.float32)
    ln = jnp.asarray(lengths, jnp.int32)
    want = _dense_reference(q, pool_k, pool_v, table, ln[:, None], ln, window)
    got_ref = paged_attention_ref(
        q, pool_k, pool_v, table, ln[:, None], ln, window=window
    )
    got_kernel = paged_attention(
        q, pool_k, pool_v, table, ln[:, None], ln,
        window=window, interpret=True,
    )
    np.testing.assert_allclose(
        np.asarray(got_ref), np.asarray(want), rtol=2e-5, atol=2e-5
    )
    np.testing.assert_allclose(
        np.asarray(got_kernel), np.asarray(got_ref), rtol=2e-6, atol=2e-6
    )


@pytest.mark.parametrize("window", [None, 9])
def test_chunk_matches_chunk_attention(window):
    """C>1 prefill chunks at ragged offsets, padding rows included: slot 3's
    chunk holds only 2 valid rows (the engines route its padding writes to
    the garbage page; its padded queries are computed and discarded)."""
    C = 4
    start = np.asarray([0, 2, 6, 12])
    n_valid = np.asarray([4, 4, 4, 2])
    last = start + n_valid - 1
    pool_k, pool_v, table = _pool_case(last, seed=2)
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.standard_normal((4, C, 4, 32)), jnp.float32)
    positions = jnp.asarray(start[:, None] + np.arange(C)[None, :], jnp.int32)
    ln = jnp.asarray(last, jnp.int32)
    want = _dense_reference(q, pool_k, pool_v, table, positions, ln, window)
    got_ref = paged_attention_ref(
        q, pool_k, pool_v, table, positions, ln, window=window
    )
    got_kernel = paged_attention(
        q, pool_k, pool_v, table, positions, ln,
        window=window, interpret=True,
    )
    valid_rows = np.arange(C)[None, :] < n_valid[:, None]  # [B, C]
    for got in (got_ref, got_kernel):
        np.testing.assert_allclose(
            np.asarray(got)[valid_rows], np.asarray(want)[valid_rows],
            rtol=2e-5, atol=2e-5,
        )


def test_ring_wrap_matches_dense_gather_oracle():
    """Positions past the ring capacity reuse the slot's own pages in
    place; the window mask must track the wrapped ring exactly."""
    ps, pps = 4, 4  # ring of 16 tokens
    window = 10
    lengths = np.asarray([21, 37, 16], np.int64)  # all past one wrap
    rng = np.random.default_rng(4)
    B = len(lengths)
    pool = PagePool(12, ps, pps, n_slots=B)
    for b in range(B):
        pool.reserve(b, pps)
        pool.map_range(b, 0, int(lengths[b]) + 1)
    table = pool.device_rows(range(B))
    pool_k = jnp.asarray(rng.standard_normal((13, ps, 2, 32)), jnp.float32)
    pool_v = jnp.asarray(rng.standard_normal((13, ps, 2, 32)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((B, 1, 4, 32)), jnp.float32)
    ln = jnp.asarray(lengths, jnp.int32)
    want = _dense_reference(q, pool_k, pool_v, table, ln[:, None], ln, window)
    got = paged_attention(
        q, pool_k, pool_v, table, ln[:, None], ln,
        window=window, interpret=True,
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5
    )


# ------------------------------------------------------------- (b) page skip


def test_garbage_pages_contribute_nothing():
    """Poisoning the garbage page must not change any live output — the
    kernel skips garbage-routed entries instead of masking post-hoc — and a
    slot whose table is ALL garbage (inactive) comes back exactly zero."""
    lengths = np.asarray([3, 9], np.int64)
    pool_k, pool_v, table = _pool_case(lengths, seed=5)
    rng = np.random.default_rng(6)
    q = jnp.asarray(rng.standard_normal((2, 1, 4, 32)), jnp.float32)
    ln = jnp.asarray(lengths, jnp.int32)
    base = paged_attention(
        q, pool_k, pool_v, table, ln[:, None], ln, interpret=True
    )
    poisoned_k = pool_k.at[-1].set(1e4)
    poisoned_v = pool_v.at[-1].set(1e4)
    got = paged_attention(
        q, poisoned_k, poisoned_v, table, ln[:, None], ln, interpret=True
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(base))

    all_garbage = jnp.full_like(table, pool_k.shape[0] - 1)
    zero = paged_attention(
        q, poisoned_k, poisoned_v, all_garbage, ln[:, None], ln,
        interpret=True,
    )
    np.testing.assert_array_equal(np.asarray(zero), 0.0)


# ------------------------------------------------- (c) end-to-end greedy


@pytest.fixture(scope="module")
def tiny_model_f32():
    cfg = (
        smoke_config(get_config("tinyllama-1.1b"))
        .replace(num_layers=4, dtype="float32")
    )
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


@pytest.fixture()
def kernel_impl():
    """Pin paged attention to the Pallas kernel (interpret mode on CPU) for
    the duration of a test.  Impl choice is read at trace time, so each
    test builds its engines inside the fixture's scope."""
    attn.set_paged_attention_impl("kernel")
    yield
    attn.set_paged_attention_impl(None)


def _prompts(n, seed=0, lo=4, hi=16):
    rng = np.random.default_rng(seed)
    return [
        rng.integers(0, 500, size=int(rng.integers(lo, hi))).astype(np.int32)
        for _ in range(n)
    ]


def _dense_oracle(model, params, prompts, max_new_tokens, max_len=64):
    """Greedy tokens via the pre-refactor dense ring-buffer cache path."""
    out = {}
    for i, prompt in enumerate(prompts):
        lg, cache = model.prefill(
            params, {"tokens": jnp.asarray(prompt)[None]}, max_len=max_len
        )
        toks = [int(jnp.argmax(lg[0]))]
        for _ in range(max_new_tokens - 1):
            lg, cache = model.decode_step(
                params, jnp.asarray([[toks[-1]]], jnp.int32), cache
            )
            toks.append(int(jnp.argmax(lg[0])))
        out[i] = toks
    return out


@pytest.mark.parametrize("split", [0, 2, 4])
def test_greedy_token_parity_kernel_vs_dense_oracle(
    tiny_model_f32, kernel_impl, split
):
    """The acceptance bar: greedy decode through the fused Pallas kernel
    (both tiers, chunked prefill included) is token-identical in f32 to the
    dense paged_gather oracle at splits 0 / mid / R."""
    model, params = tiny_model_f32
    prompts = _prompts(6)
    want = _dense_oracle(model, params, prompts, max_new_tokens=8)
    eng = EndCloudServingEngine(
        model, params,
        end_profile=PROFILES["a100"], cloud_profile=PROFILES["a100"],
        max_batch=4, max_len=64, force_split=split, prefill_chunk=8,
    )
    reqs = [Request(i, p, max_new_tokens=8) for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert len(done) == 6
    assert {r.request_id: r.generated for r in reqs} == want


def test_sliding_window_greedy_parity_kernel(kernel_impl):
    """window < max_len: the ring wraps during prefill AND decode; kernel
    greedy tokens must still match the dense whole-prompt path (f32)."""
    cfg = (
        smoke_config(get_config("tinyllama-1.1b"))
        .replace(num_layers=2, dtype="float32", sliding_window=24)
    )
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, 500, size=s).astype(np.int32)
               for s in (40, 55, 48)]
    want = _dense_oracle(model, params, prompts, max_new_tokens=6, max_len=64)
    eng = ServingEngine(model, params, max_batch=2, max_len=64,
                        prefill_chunk=16)
    reqs = [Request(i, p, max_new_tokens=6) for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert {r.request_id: r.generated for r in reqs} == want


def test_kernel_and_ref_impls_agree_end_to_end(tiny_model_f32):
    """The models-layer dispatcher: 'kernel' and 'ref' impls produce
    identical greedy tokens through the single-tier paged engine."""
    model, params = tiny_model_f32
    prompts = _prompts(5, seed=8)
    tokens = {}
    for impl in ("ref", "kernel"):
        attn.set_paged_attention_impl(impl)
        try:
            eng = ServingEngine(model, params, max_batch=4, max_len=64,
                                prefill_chunk=8)
            reqs = [Request(i, p, max_new_tokens=6)
                    for i, p in enumerate(prompts)]
            for r in reqs:
                eng.submit(r)
            eng.run()
            tokens[impl] = {r.request_id: r.generated for r in reqs}
        finally:
            attn.set_paged_attention_impl(None)
    assert tokens["ref"] == tokens["kernel"]
