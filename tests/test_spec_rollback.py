"""Randomized property test: speculative draft-write -> partial-accept ->
rollback leaves PagePool invariants intact.

Drives a pool through randomized interleavings of the full speculative
lifecycle — reserve, provisional ``map_tokens`` of a draft chunk,
``rollback`` of everything past a random accepted prefix, preemption
``spill_slot``/``restore_slot`` (PR 6 composition), and ``free`` — and
checks after EVERY operation that

  * no physical page is double-mapped (each appears in at most one table
    cell across all slots) and none is simultaneously free and mapped;
  * page conservation: mapped + free == num_pages;
  * ``reserved_pages`` / ``pages_reserved`` stay honest (reserved minus
    mapped, never negative);
  * a draft round's surviving entries are EXACTLY what committing
    ``n_commit`` tokens sequentially would have mapped.

Runs under hypothesis when installed; otherwise the deterministic grid
shim in ``_hypothesis_compat`` sweeps the boundary examples.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_compat import given, settings, st

from repro.models import kvcache
from repro.models.kvcache import PagePool
from repro.serving.specdecode import rollback_entries


def _check_invariants(pool: PagePool):
    # every mapped table cell holds a distinct physical page
    mapped_phys = [
        int(p) for row in pool.table for p in row if p >= 0
    ]
    assert len(mapped_phys) == len(set(mapped_phys)), "double-mapped page"
    # free list and mapped set are disjoint and conserve the pool
    free = set(pool._free)
    assert len(free) == len(pool._free), "duplicate in free list"
    assert free.isdisjoint(mapped_phys), "page both free and mapped"
    assert len(free) + len(mapped_phys) == pool.num_pages
    assert pool.pages_in_use == len(mapped_phys)
    # per-slot mapped counters match the tables; reservations stay honest
    for s in range(pool.table.shape[0]):
        n_mapped = int((pool.table[s] >= 0).sum())
        assert pool._mapped[s] == n_mapped
        assert pool.reserved_pages(s) >= 0
    assert pool.pages_reserved >= 0
    assert pool.pages_available >= 0


def _expected_entries(start_len, n_tokens, page_size, pps):
    """Ring entries a sequential append of n_tokens at start_len touches."""
    if n_tokens <= 0:
        return set()
    return {
        (pi % pps)
        for pi in range(
            start_len // page_size,
            (start_len + n_tokens - 1) // page_size + 1,
        )
    }


@settings(max_examples=40, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=40),
    page_size=st.sampled_from([2, 4]),
    pps=st.sampled_from([3, 4, 6]),
    k=st.integers(min_value=2, max_value=8),
)
def test_draft_rollback_lifecycle_keeps_pool_invariants(seed, page_size, pps, k):
    rng = np.random.default_rng(seed)
    n_slots = 4
    pool = PagePool(
        num_pages=n_slots * pps, page_size=page_size,
        pages_per_slot=pps, n_slots=n_slots,
    )
    lengths = np.zeros(n_slots, np.int64)  # committed tokens per slot
    held = np.zeros(n_slots, bool)
    parked = {}  # slot -> spill state

    for _ in range(60):
        slot = int(rng.integers(n_slots))
        op = rng.choice(["round", "spill", "restore", "free", "admit"])
        if op == "admit" and not held[slot] and slot not in parked:
            need = kvcache.pages_needed(
                int(rng.integers(1, 3 * page_size)), page_size, pps
            )
            if pool.can_reserve(need):
                pool.reserve(slot, need)
                held[slot] = True
                lengths[slot] = 0
        elif op == "round" and held[slot]:
            # speculative round: provisionally map a k-token draft chunk,
            # then roll back everything past a random accepted prefix
            L = int(lengths[slot])
            r = pool.reserved_pages(slot)
            # stay inside the reservation, mirroring the engine's per-row
            # n_valid cap; a full-ring reservation wraps freely
            n_valid = k if r == pps else min(k, r * page_size - L)
            if n_valid < 1:
                continue
            new = pool.map_tokens(slot, L, L + n_valid)
            _check_invariants(pool)
            n_commit = int(rng.integers(1, n_valid + 1))
            rb = rollback_entries(
                new, base_len=L, n_commit=n_commit,
                page_size=page_size, pages_per_slot=pps,
            )
            if rb:
                pool.rollback(slot, rb)
            # every entry the committed window [L, L + n_commit) touches
            # must have survived the rollback
            live = _expected_entries(L, n_commit, page_size, pps)
            got = {e for e in range(pps) if pool.table[slot, e] >= 0}
            assert live <= got, (live, got)
            lengths[slot] = L + n_commit
        elif op == "spill" and held[slot] and pool._mapped[slot] > 0:
            entries, _phys, n_pages = pool.spill_slot(slot)
            parked[slot] = (entries, n_pages, lengths[slot])
            held[slot] = False
            lengths[slot] = 0
        elif op == "restore" and slot in parked and not held[slot]:
            entries, n_pages, length = parked.pop(slot)
            if pool.can_reserve(n_pages):
                pool.restore_slot(slot, entries, n_pages)
                held[slot] = True
                lengths[slot] = length
                assert pool._mapped[slot] == len(entries)
            else:
                parked[slot] = (entries, n_pages, length)
        elif op == "free" and held[slot]:
            pool.free(slot)
            held[slot] = False
            lengths[slot] = 0
        _check_invariants(pool)

    # drain: every held slot frees cleanly, the pool returns whole
    for slot in range(n_slots):
        if held[slot]:
            pool.free(slot)
    _check_invariants(pool)
    assert pool.pages_in_use == 0 or parked, (
        pool.pages_in_use, parked,
    )


def test_rollback_unmapped_entry_raises():
    pool = PagePool(num_pages=8, page_size=4, pages_per_slot=4, n_slots=2)
    pool.reserve(0, 2)
    new = pool.map_tokens(0, 0, 5)
    assert len(new) == 2
    pool.rollback(0, [new[-1]])
    with pytest.raises(ValueError, match="unmapped"):
        pool.rollback(0, [new[-1]])  # double rollback of the same entry


def test_full_rollback_equals_never_mapped():
    pool = PagePool(num_pages=8, page_size=4, pages_per_slot=4, n_slots=2)
    pool.reserve(0, 3)
    before = (pool.pages_in_use, list(sorted(pool._free)))
    new = pool.map_tokens(0, 0, 9)
    rb = rollback_entries(new, base_len=0, n_commit=0,
                          page_size=4, pages_per_slot=4)
    pool.rollback(0, rb)
    assert (pool.pages_in_use, list(sorted(pool._free))) == before
    assert pool.reserved_pages(0) == 3
