"""HL-GGN group gate properties (paper eq. 5-7)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep: deterministic fallback grid
    from _hypothesis_compat import given, settings, st

from repro.configs.base import MoEConfig
from repro.core import gating


def _setup(d, E, K, T=16, seed=0):
    mcfg = MoEConfig(num_experts=E, top_k=min(2, E), d_ff_expert=32, num_groups=K)
    params = gating.init_group_gate(jax.random.PRNGKey(seed), d, mcfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (T, d))
    return mcfg, params, x


@settings(max_examples=20, deadline=None)
@given(
    d=st.sampled_from([8, 32]),
    K=st.sampled_from([1, 2, 4]),
    mk=st.sampled_from([1, 2, 4]),
    seed=st.integers(0, 5),
)
def test_probs_form_distribution(d, K, mk, seed):
    """eq. 7 output is a valid distribution over all E experts."""
    E = K * mk
    mcfg, params, x = _setup(d, E, K, seed=seed)
    probs, p_group, _ = gating.group_gate_probs(params, x, mcfg)
    np.testing.assert_allclose(np.asarray(probs.sum(-1)), 1.0, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(p_group.sum(-1)), 1.0, rtol=1e-5)
    assert (np.asarray(probs) >= 0).all()


def test_eq7_factorization():
    """probs restricted to group k, renormalized == stage-2 softmax."""
    mcfg, params, x = _setup(16, 8, 4)
    probs, p_group, _ = gating.group_gate_probs(params, x, mcfg)
    pr = np.asarray(probs).reshape(-1, 4, 2)
    pg = np.asarray(p_group)
    np.testing.assert_allclose(pr.sum(-1), pg, rtol=1e-5)


def test_single_group_equals_flat_gate():
    """K=1 degenerates to the traditional single-FC gate."""
    d, E = 16, 8
    mcfg, params, x = _setup(d, E, 1)
    probs, _, _ = gating.group_gate_probs(params, x, mcfg)
    # manual flat softmax over the same local weights
    w = params["w_local"][0]
    logits = x @ w + params["b_local"][0]
    # stage-1 softmax over one group is identically 1
    expected = jax.nn.softmax(logits, -1)
    np.testing.assert_allclose(np.asarray(probs), np.asarray(expected), rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(n_masked=st.integers(1, 7), seed=st.integers(0, 5))
def test_masking_zeroes_excluded_experts(n_masked, seed):
    """eq. 4 mask: excluded experts get exactly zero probability; the rest
    renormalize to 1."""
    mcfg, params, x = _setup(16, 8, 4, seed=seed)
    rng = np.random.default_rng(seed)
    mask = np.ones(8, bool)
    mask[rng.choice(8, n_masked, replace=False)] = False
    probs, _, _ = gating.group_gate_probs(params, x, mcfg, jnp.asarray(mask))
    p = np.asarray(probs)
    assert (p[:, ~mask] < 1e-12).all()
    np.testing.assert_allclose(p.sum(-1), 1.0, rtol=1e-4)


def test_topk_selects_allowed_only():
    mcfg, params, x = _setup(16, 8, 4, T=64)
    mask = jnp.asarray(np.array([True, True, False, False] * 2))
    out = gating.gate(params, x, mcfg, mask)
    chosen = np.asarray(out.topk_idx).ravel()
    assert set(chosen) <= {i for i in range(8) if bool(mask[i])}


def test_group_topk_restriction():
    """group_top_k=1 confines selected experts to one group per token."""
    import dataclasses

    mcfg, params, x = _setup(16, 8, 4, T=64)
    mcfg = dataclasses.replace(mcfg, group_top_k=1, top_k=2)
    out = gating.gate(params, x, mcfg)
    idx = np.asarray(out.topk_idx)  # [T, 2]
    groups = idx // 2  # Mk = 2
    assert (groups[:, 0] == groups[:, 1]).all()


def test_group_topk_exact_on_probability_ties():
    """Regression: tied stage-1 probabilities (e.g. uniform logits) must
    still keep exactly ``group_top_k`` groups — a threshold keep would pass
    every tied group and break the a2a dispatch fan-out bound."""
    import dataclasses

    mcfg, params, x = _setup(16, 8, 4, T=8)
    mcfg = dataclasses.replace(mcfg, group_top_k=2)
    params = jax.tree.map(jnp.zeros_like, params)  # all-equal logits: 4-way tie
    probs, p_group, _ = gating.group_gate_probs(params, x, mcfg)
    pg = np.asarray(p_group)
    assert ((pg > 0).sum(-1) == 2).all(), pg
    np.testing.assert_allclose(pg.sum(-1), 1.0, rtol=1e-5)
    # the fan-out bound holds through eq. 7: nonzero expert probability in
    # exactly group_top_k groups per token
    per_group = np.asarray(probs).reshape(-1, 4, 2).sum(-1)
    assert ((per_group > 0).sum(-1) == 2).all()


def test_router_z_finite_under_group_mask():
    """Regression: a hardware mask (eq. 4) that disables a whole group must
    not detonate the z-loss — z is computed on pre-mask logits, so
    logsumexp(NEG_INF)^2 never reaches router_z / aux_loss."""
    mcfg, params, x = _setup(16, 8, 4)
    mask = np.ones(8, bool)
    mask[0:2] = False  # group 0 (Mk = 2) fully masked
    _, _, aux = gating.group_gate_probs(params, x, mcfg, jnp.asarray(mask))
    z = float(aux["router_z"])
    assert np.isfinite(z) and z < 1e6, z
    out = gating.gate(params, x, mcfg, jnp.asarray(mask))
    assert np.isfinite(float(out.aux["aux_loss"]))
    # and the mask itself still works: group 0 gets zero probability
    assert float(np.asarray(out.probs)[:, :2].max()) < 1e-12


def test_load_balance_loss_at_uniform():
    """Perfectly uniform routing gives lb loss == 1 (per Switch)."""
    T, E, K = 128, 8, 4
    probs = jnp.full((T, E), 1.0 / E)
    idx = jnp.tile(jnp.arange(E), T // E * 2)[: T * 1].reshape(T, 1)
    lb = gating.load_balance_loss(probs, idx, E, K)
    np.testing.assert_allclose(float(lb["lb_expert"]), 1.0, rtol=1e-5)
    np.testing.assert_allclose(float(lb["lb_group"]), 1.0, rtol=1e-5)


def test_gate_flop_count_grouped_cheaper():
    g = gating.gate_flop_count(d_model=4096, num_experts=128, num_groups=16,
                               group_top_k=4)
    assert g["grouped"] < g["flat"]
