"""Optional-hypothesis shim.

Test modules import ``given``/``settings``/``st`` through a try/except:

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_compat import given, settings, st

When hypothesis is installed the real library runs the full property-based
search.  When it is not (the container image has no network access), this
shim keeps the suite collectable and runs each ``@given`` test over a small
deterministic grid: every strategy contributes its boundary values plus a
midpoint, and example i of the test takes element ``i % len(examples)`` of
each strategy, so all boundaries are exercised at least once without a
combinatorial blow-up.

Only the strategy constructors this repo's tests actually use are provided
(``sampled_from``, ``integers``, ``floats``, ``booleans``); extend the shim
alongside any test that needs more.
"""

from __future__ import annotations

import inspect
from typing import Any, Callable, List


class _Strategy:
    """A fixed, ordered list of deterministic examples."""

    def __init__(self, examples: List[Any]):
        assert examples, "strategy must yield at least one example"
        self.examples = examples


class st:  # noqa: N801 - mirrors `hypothesis.strategies as st`
    @staticmethod
    def sampled_from(values) -> _Strategy:
        return _Strategy(list(values))

    @staticmethod
    def integers(min_value: int = 0, max_value: int = 10) -> _Strategy:
        mid = (min_value + max_value) // 2
        return _Strategy(sorted({min_value, mid, max_value}))

    @staticmethod
    def floats(min_value: float = 0.0, max_value: float = 1.0) -> _Strategy:
        mid = 0.5 * (min_value + max_value)
        out = [min_value]
        if mid not in out:
            out.append(mid)
        if max_value not in out:
            out.append(max_value)
        return _Strategy(out)

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy([False, True])


def settings(*_args, **_kwargs) -> Callable:
    """No-op stand-in for ``hypothesis.settings``."""

    def deco(fn):
        return fn

    return deco


def given(**strategies: _Strategy) -> Callable:
    """Run the test once per grid example (cycling each strategy's list)."""

    n_examples = max(len(s.examples) for s in strategies.values())

    def deco(fn):
        def wrapper(*args, **kwargs):
            for i in range(n_examples):
                drawn = {
                    name: s.examples[i % len(s.examples)]
                    for name, s in strategies.items()
                }
                fn(*args, **drawn, **kwargs)

        # Hide the strategy-drawn parameters from pytest's fixture resolver
        # (functools.wraps would re-expose them via __wrapped__).
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(
            parameters=[
                p for name, p in sig.parameters.items() if name not in strategies
            ]
        )
        return wrapper

    return deco
