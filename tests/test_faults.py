"""Serving-side fault injection and recovery.

The chaos subsystem's invariants, asserted under both hand-built and
seeded-random fault schedules:

  * every submitted request finishes exactly once — lane death migrates
    in-flight work (PR 6 spill/restore through the page tables) instead of
    losing or duplicating it;
  * greedy tokens are bit-identical chaos-vs-clean (dense model, exact
    boundary): migration, blackout replans and retries change *when*
    tokens are produced, never *which*;
  * the fleet expert registry never names a dead lane as a slab source;
  * retry backoff is bounded (exponential, capped);
  * a wedged engine raises loudly through the stall guard instead of
    silently burning ``max_steps``.
"""

import dataclasses

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_compat import given, settings, st

from repro.configs import get_config, smoke_config
from repro.core import expertpool
from repro.core.hardware import PROFILES, DeviceProfile
from repro.models.model import build_model
from repro.serving.common import Request, VirtualClock
from repro.serving.engine import ServingEngine
from repro.serving.faults import (
    ChaosInjector,
    FaultEvent,
    FaultSchedule,
    HealthMonitor,
    StallGuard,
)
from repro.serving.fleet import FleetServingEngine
from repro.serving.loadgen import (
    WorkloadClass,
    build_schedule,
    drive,
    poisson_arrivals,
)
from repro.serving.stream import EndCloudServingEngine


@pytest.fixture(scope="module")
def tiny_model():
    cfg = smoke_config(get_config("tinyllama-1.1b")).replace(num_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


END_PROFILES = [
    DeviceProfile("end-a", peak_gflops=8.0, mem_gb=16.0,
                  mem_bw_gbs=100.0, net_gbps=2.0),
    DeviceProfile("end-b", peak_gflops=6.0, mem_gb=8.0,
                  mem_bw_gbs=50.0, net_gbps=1.0),
    DeviceProfile("end-c", peak_gflops=4.0, mem_gb=8.0,
                  mem_bw_gbs=50.0, net_gbps=1.0),
]
CLOUD = DeviceProfile("cloud-sim", peak_gflops=4.0, mem_gb=80.0,
                      mem_bw_gbs=500.0, net_gbps=2.0)

CLASSES = (
    WorkloadClass("interactive", priority=0, weight=0.7,
                  prompt_len=(4, 10), new_tokens=(2, 4)),
    WorkloadClass("batch", priority=2, weight=0.3,
                  prompt_len=(16, 40), new_tokens=(4, 8)),
)


def _fleet(tiny_model, n_lanes=2, **kw):
    model, params = tiny_model
    kw.setdefault("compression_rank", 0)  # exact boundary: total parity
    kw.setdefault("max_len", 160)
    return FleetServingEngine(
        model, params,
        end_profiles=END_PROFILES[:n_lanes], cloud_profile=CLOUD,
        cloud_servers=2, max_batch=2,
        timing="modeled", max_spill=1.0, clock=VirtualClock(), **kw,
    )


def _schedule(n=30, rate=300.0, seed=5):
    return build_schedule(
        poisson_arrivals(n, rate, seed), CLASSES, seed=seed + 1
    )


# -- schedule / event validation --------------------------------------------


def test_fault_event_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultEvent(0.1, "meteor_strike")
    with pytest.raises(ValueError, match="needs a device"):
        FaultEvent(0.1, "lane_crash")
    with pytest.raises(ValueError, match="positive gbps"):
        FaultEvent(0.1, "link_recover", device=0)
    with pytest.raises(ValueError, match="count"):
        FaultEvent(0.1, "peer_fetch_fail", count=0)


def test_fault_schedule_sorts_and_validates():
    sched = FaultSchedule([
        FaultEvent(0.5, "lane_recover", device=0),
        FaultEvent(0.1, "lane_crash", device=0),
    ])
    assert [e.kind for e in sched] == ["lane_crash", "lane_recover"]
    with pytest.raises(ValueError, match="crashed twice"):
        FaultSchedule([
            FaultEvent(0.1, "lane_crash", device=0),
            FaultEvent(0.2, "lane_crash", device=0),
        ])
    with pytest.raises(ValueError, match="recovered while alive"):
        FaultSchedule([FaultEvent(0.1, "lane_recover", device=0)])


def test_random_schedule_deterministic_and_guarded():
    a = FaultSchedule.random(7, horizon_s=1.0, n_lanes=3, n_blackouts=2)
    b = FaultSchedule.random(7, horizon_s=1.0, n_lanes=3, n_blackouts=2)
    assert a.events == b.events
    c = FaultSchedule.random(8, horizon_s=1.0, n_lanes=3, n_blackouts=2)
    assert a.events != c.events
    with pytest.raises(ValueError, match=">= 2 lanes"):
        FaultSchedule.random(0, horizon_s=1.0, n_lanes=1, n_crashes=1)


# -- health monitor / stall guard -------------------------------------------


def test_backoff_bounded_exponential():
    h = HealthMonitor(backoff_base_s=0.01, backoff_cap_s=0.25)
    delays = [h.backoff_s(a) for a in range(12)]
    assert delays[0] == pytest.approx(0.01)
    assert delays[1] == pytest.approx(0.02)
    # monotone non-decreasing, capped, and the cap is actually reached
    assert all(b >= a for a, b in zip(delays, delays[1:]))
    assert max(delays) == pytest.approx(0.25)
    assert all(d <= 0.25 for d in delays)


def test_heartbeat_suspects():
    h = HealthMonitor(heartbeat_timeout_s=0.5)
    h.beat("lane0", 1.0)
    h.beat("lane1", 1.4)
    assert not h.suspect("lane0", 1.4)
    assert h.suspect("lane0", 1.6)
    assert h.suspects(1.6) == ["lane0"]
    assert not h.suspect("never-seen", 99.0)


def test_stall_guard_raises_and_resets():
    g = StallGuard(limit=3)
    for _ in range(3):
        g.note((1,), "diag")  # baseline + 2 stalled ticks: under the limit
    g.note((2,), "diag")  # progress resets the count
    g.note((2,), "diag")
    g.note((2,), "diag")
    with pytest.raises(RuntimeError, match="livelock.*diag"):
        g.note((2,), "diag")
    with pytest.raises(ValueError):
        StallGuard(limit=0)


def test_wedged_engine_raises_instead_of_silent_return(tiny_model):
    """Regression: a schedule that can never admit (every page reserved by
    an unkillable squatter) used to spin ``run()`` to ``max_steps`` and
    return an empty result that looked like success."""
    model, params = tiny_model
    eng = EndCloudServingEngine(
        model, params,
        end_profile=PROFILES["a100"], cloud_profile=PROFILES["a100"],
        max_batch=3, max_len=64, force_split=1,
        kv_pages=4,  # exactly one slot's worth of pages in the whole pool
    )
    # max_batch=3 over 2 groups pads to 4 slots; slot 3 is padding and can
    # never admit or release — park the pool's only pages on it forever
    eng.end_pool.reserve(3, eng.end_pool.pages_per_slot)
    eng.submit(Request(0, np.arange(4, dtype=np.int32), max_new_tokens=2))
    eng.stall_limit = 16
    with pytest.raises(RuntimeError, match="livelock"):
        eng.run()


def test_dead_fleet_raises_instead_of_spinning(tiny_model):
    fleet = _fleet(tiny_model, n_lanes=2)
    fleet.fail_lane(0)
    fleet.fail_lane(1)
    fleet.submit(Request(0, np.arange(4, dtype=np.int32), max_new_tokens=2))
    fleet.stall_limit = 16
    with pytest.raises(RuntimeError, match="livelock.*DOWN"):
        fleet.run()


# -- registry liveness -------------------------------------------------------


def test_registry_never_names_dead_holder():
    reg = expertpool.FleetExpertRegistry(2, 4, 1024, lan_gbps=10.0)
    pools = [expertpool.ExpertSlabPool(8, 2, 4, max_per_layer=4)
             for _ in range(2)]
    for p in pools:
        reg.register_lane(
            p, link_gbps=lambda: 1.0, book_link=lambda r, s: r + s
        )
    pools[0].alloc(0, 1)
    pools[1].alloc(0, 1)
    assert sorted(reg.holders(0, 1)) == [0, 1]
    assert reg.pick_source(1, 0, 1)[0] == 0  # peer strictly cheaper
    reg.set_lane_alive(0, False)
    assert reg.holders(0, 1) == [1]
    # the dead lane can no longer be picked as a source by anyone
    src, _t = reg.pick_source(1, 0, 1)
    assert src is None  # its own copy excluded, lane 0 dead -> cloud
    assert reg.total_residents() == 1  # dead residency invisible
    reg.set_lane_alive(0, True)
    assert sorted(reg.holders(0, 1)) == [0, 1]


def test_peer_fault_injection_counts():
    reg = expertpool.FleetExpertRegistry(2, 4, 1024)
    with pytest.raises(ValueError):
        reg.inject_peer_faults(0)
    reg.inject_peer_faults(2)
    assert reg.take_peer_fault() and reg.take_peer_fault()
    assert not reg.take_peer_fault()
    assert reg.peer_fault_fallbacks == 2


# -- migration token parity --------------------------------------------------


def _parity_prompts():
    rng = np.random.default_rng(42)
    return [rng.integers(0, 500, size=n).astype(np.int32)
            for n in (12, 14, 9)]


@pytest.fixture(scope="module")
def oracle_tokens(tiny_model):
    """Uninterrupted greedy tokens from the dense single-tier engine."""
    model, params = tiny_model
    eng = ServingEngine(model, params, max_batch=4, max_len=64)
    reqs = [Request(i, p, max_new_tokens=8)
            for i, p in enumerate(_parity_prompts())]
    for r in reqs:
        eng.submit(r)
    eng.run()
    return {r.request_id: list(r.generated) for r in reqs}


@pytest.mark.parametrize("split", [0, 1, 2])
def test_migration_token_parity_across_splits(
    tiny_model, oracle_tokens, split
):
    """Kill a lane mid-decode: its slots spill, migrate through the fleet
    frontend, and restore on the survivor — greedy tokens bit-identical to
    the single-tier oracle, with the dead lane at all-end / interior /
    all-cloud splits and the survivor at an interior split (the spill
    payload is placement-invariant: merged page blocks re-split at the
    destination's boundary)."""
    fleet = _fleet(tiny_model, n_lanes=2, max_len=64,
                   force_splits=[split, 1])
    reqs = [Request(i, p, max_new_tokens=8)
            for i, p in enumerate(_parity_prompts())]
    for r in reqs:
        fleet.submit(r)
    # run until lane 0 has work decoding, then kill it
    for _ in range(200):
        fleet.step()
        if any(r is not None and len(r.generated) >= 2
               for r in fleet.lanes[0].slots):
            break
    else:
        pytest.skip("placement never used lane 0 for this trace")
    in_flight = [r.request_id for r in fleet.lanes[0].slots if r is not None]
    fleet.fail_lane(0)
    assert fleet._migrating, "in-flight decode must have spill states parked"
    done = fleet.run()
    assert sorted(r.request_id for r in done) == [0, 1, 2]
    m = fleet.metrics()
    assert m["lane_failures"] == 1
    assert m["migrations"] >= 1
    assert m["migration_restores"] == m["migrations"]
    assert m["migration_spill_bytes"] > 0
    got = {r.request_id: list(r.generated) for r in reqs}
    assert got == oracle_tokens
    for rid in in_flight:
        req = next(r for r in reqs if r.request_id == rid)
        assert req.n_migrations >= 1


def test_quantized_migration_parity_and_stored_size(tiny_model):
    """Satellite: migration spill payloads ride the quantized KV codec —
    restore stays bit-identical (the stored int8 codes + scales move
    verbatim) and the metered spill bytes are the *stored* size, ~half the
    dense payload."""
    def run_one(quantize, crash):
        fleet = _fleet(tiny_model, n_lanes=2, max_len=64,
                       force_splits=[1, 1], quantize_kv=quantize)
        reqs = [Request(i, p, max_new_tokens=8)
                for i, p in enumerate(_parity_prompts())]
        for r in reqs:
            fleet.submit(r)
        for _ in range(200):
            fleet.step()
            if any(r is not None and len(r.generated) >= 2
                   for r in fleet.lanes[0].slots):
                break
        else:
            pytest.skip("placement never used lane 0 for this trace")
        if crash:
            fleet.fail_lane(0)
        fleet.run()
        m = fleet.metrics()
        if crash:
            assert m["migrations"] >= 1 and m["migration_spill_bytes"] > 0
        return ({r.request_id: list(r.generated) for r in reqs},
                m["migration_spill_bytes"], m["migrations"])

    # int8 KV is a different (lossy) numeric mode: the oracle for a
    # quantized migration is the quantized run WITHOUT the crash, not the
    # dense tokens
    toks_q_clean, _, _ = run_one(True, crash=False)
    toks_quant, bytes_quant, n_quant = run_one(True, crash=True)
    assert toks_quant == toks_q_clean  # restore bit-identical under int8 KV
    _, bytes_dense, n_dense = run_one(False, crash=True)
    # same schedule, same modeled timing -> same migration set; the
    # quantized pool's stored representation is int8 codes + one float32
    # scale per (page, head): materially smaller than dense fp32 pages
    if n_quant == n_dense:
        assert bytes_quant < 0.7 * bytes_dense


# -- graceful degradation ----------------------------------------------------


def test_blackout_drives_cloud_only_replan(tiny_model):
    """A blacked-out link pins the next safe-point plan to split 0 (token
    ids are the only boundary traffic a dead wire can carry); recovery
    unwinds the pin through the normal replan path."""
    fleet = _fleet(tiny_model, n_lanes=2)
    lane = fleet.lanes[0]
    sched = _schedule(n=16)
    fleet.chaos = None
    # drive manually so we can interleave fault events
    for t, r in sched[:8]:
        fleet.submit(r)
    for _ in range(5):
        fleet.step()
    nominal = lane.bw.gbps
    fleet.set_link_rate(0, nominal / 1000.0)
    assert lane.link_degraded
    for _ in range(30):
        fleet.step()
        if lane.split == 0:
            break
    assert lane.split == 0, "blackout must degrade to cloud-only"
    assert lane.degraded_ticks > 0
    fleet.set_link_rate(0, nominal)
    assert not lane.link_degraded
    assert lane.blackout_seconds() > 0
    for t, r in sched[8:]:
        fleet.submit(r)
    done = fleet.run()
    assert len(done) == len(sched)
    for _, r in sched:
        assert r.done
    assert lane.split > 0, "recovery must unwind the split-0 pin"


def test_cloud_server_loss_and_last_server_guard(tiny_model):
    fleet = _fleet(tiny_model, n_lanes=2)
    assert fleet.timeline.n_servers("cloud") == 2
    old_budget = fleet.lanes[0].tiers.cloud_cap.gflop_budget
    fleet.fail_cloud_server()
    assert fleet.cloud_servers == 1
    assert fleet.timeline.n_servers("cloud") == 1
    assert fleet.cloud_server_failures == 1
    # each lane's share of the aggregate cloud budget halved
    assert fleet.lanes[0].tiers.cloud_cap.gflop_budget == pytest.approx(
        old_budget / 2
    )
    with pytest.raises(RuntimeError, match="last cloud server"):
        fleet.fail_cloud_server()
    # the shrunken fleet still serves
    for t, r in _schedule(n=8):
        fleet.submit(r)
    done = fleet.run()
    assert len(done) == 8


def test_transfer_faults_retry_with_backoff(tiny_model):
    fleet = _fleet(tiny_model, n_lanes=2)
    fleet.inject_transfer_faults(0, 2)
    for t, r in _schedule(n=6):
        fleet.submit(r)
    done = fleet.run()
    assert len(done) == 6
    assert fleet.metrics()["transfer_retries"] == 2


def test_transfer_fault_exhaustion_raises(tiny_model):
    fleet = _fleet(tiny_model, n_lanes=1)
    fleet.health.max_transfer_attempts = 3
    fleet.inject_transfer_faults(0, 50)
    fleet.submit(Request(0, np.arange(6, dtype=np.int32), max_new_tokens=2))
    with pytest.raises(RuntimeError, match="presumed dead"):
        fleet.run()


# -- randomized chaos invariants ---------------------------------------------


@settings(max_examples=6, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=4),
    n_blackouts=st.integers(min_value=0, max_value=1),
    n_crashes=st.integers(min_value=0, max_value=1),
)
def test_random_chaos_exactly_once_and_parity(
    tiny_model, seed, n_blackouts, n_crashes
):
    """Any seeded schedule of crashes + blackouts + flaky transfers:
    every request finishes exactly once, and greedy tokens match the
    fault-free run of the same trace bit-for-bit."""
    sched = _schedule(n=24, rate=400.0, seed=seed)
    clean = _fleet(tiny_model, n_lanes=3)
    drive(clean, sched)
    want = {r.request_id: list(r.generated) for _, r in sched}
    assert all(r.done for _, r in sched)

    sched2 = _schedule(n=24, rate=400.0, seed=seed)
    chaos = _fleet(tiny_model, n_lanes=3)
    horizon = max(t for t, _ in sched2)
    fs = FaultSchedule.random(
        seed + 100, horizon_s=max(horizon, 0.05), n_lanes=3,
        nominal_gbps=2.0, n_crashes=n_crashes, n_blackouts=n_blackouts,
        n_transfer_faults=1,
    )
    inj = ChaosInjector(fs, chaos)
    drive(chaos, sched2)

    ids = [r.request_id for r in chaos.finished]
    assert sorted(ids) == sorted(r.request_id for _, r in sched2)
    assert len(ids) == len(set(ids)), "request finished twice"
    got = {r.request_id: list(r.generated) for _, r in sched2}
    assert got == want, "greedy tokens diverged under chaos"
    m = chaos.metrics()
    assert m["migration_restores"] == m["migrations"]
    # every declared event fired (possibly late, never lost)
    assert inj.pending == 0
    assert len(inj.fire_log()) == len(fs)


def test_chaos_run_seed_deterministic(tiny_model):
    def run():
        sched = _schedule(n=20, rate=400.0, seed=3)
        fleet = _fleet(tiny_model, n_lanes=2)
        fs = FaultSchedule([
            FaultEvent(0.02, "lane_crash", device=1),
            FaultEvent(0.05, "link_blackout", device=0),
            FaultEvent(0.25, "link_recover", device=0, gbps=2.0),
            FaultEvent(0.30, "lane_recover", device=1),
        ])
        inj = ChaosInjector(fs, fleet)
        drive(fleet, sched)
        toks = {r.request_id: list(r.generated) for _, r in sched}
        return toks, inj.fire_log(), fleet.metrics()["migrations"]

    t1, log1, mig1 = run()
    t2, log2, mig2 = run()
    assert t1 == t2
    assert log1 == log2
    assert mig1 == mig2
