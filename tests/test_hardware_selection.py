"""Hardware-aware local expert selection (paper eq. 2-4)."""

import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep: deterministic fallback grid
    from _hypothesis_compat import given, settings, st

from repro.core.hardware import (
    PROFILES,
    DeviceState,
    capability,
    expert_complexity,
)
from repro.core.selection import end_mask_for, local_expert_mask, shard_masks_for_fleet


def test_selection_cap_40pct():
    """Paper setting: at most 40% of experts evaluated on the end."""
    for E in (8, 16, 32, 64):
        mask = end_mask_for(
            PROFILES["a100"], DeviceState(), 768, 3072, E, max(2, E // 4),
            selection_cap=0.4,
        )
        assert mask.sum() <= int(0.4 * E)


@settings(max_examples=20, deadline=None)
@given(
    cpu=st.floats(0.0, 1.0), mem=st.floats(0.0, 1.0), power=st.floats(0.0, 1.0)
)
def test_capability_monotone_in_state(cpu, mem, power):
    p = PROFILES["xeon-4214r"]
    weak = capability(p, DeviceState(cpu_free=cpu, mem_free=mem, power_free=power))
    strong = capability(p, DeviceState())
    assert weak.gflop_budget <= strong.gflop_budget + 1e-12
    assert weak.mem_budget_gb <= strong.mem_budget_gb + 1e-12


def test_mask_monotone_in_memory():
    """A device with more free memory never hosts fewer experts."""
    p = PROFILES["phone-soc"]
    sizes = []
    for mem in (0.1, 0.5, 1.0):
        m = end_mask_for(p, DeviceState(mem_free=mem), 768, 3072, 16, 4)
        sizes.append(int(m.sum()))
    assert sizes == sorted(sizes)


def test_group_aligned_selection():
    """Experts are admitted whole-group-first (dispatch locality)."""
    mask = end_mask_for(
        PROFILES["a100"], DeviceState(), 768, 3072, 16, 4, selection_cap=0.4
    )
    # 40% of 16 = 6 experts = group 0 (4) + half of group 1 (2)
    assert mask[:4].all() and mask[4:6].all() and not mask[6:].any()


def test_priority_order_respected():
    mask = end_mask_for(
        PROFILES["a100"], DeviceState(), 768, 3072, 16, 4,
        selection_cap=0.25, group_priority=[3, 0, 1, 2],
    )
    assert mask[12:16].all() and mask[:12].sum() == 0


def test_fleet_masks_never_empty():
    profs = [PROFILES["phone-soc"], PROFILES["a100"]]
    states = [DeviceState(mem_free=0.0, cpu_free=0.0), DeviceState()]
    masks = shard_masks_for_fleet(profs, states, 768, 3072, 16, 4)
    assert masks.shape == (2, 16)
    assert masks.any(axis=1).all()


def test_expert_complexity_scales():
    a = expert_complexity(768, 3072)
    b = expert_complexity(768, 6144)
    assert b.gflop_per_token > a.gflop_per_token
    assert b.weight_bytes == 2 * a.weight_bytes
