"""Load-generator determinism + preemption spill/restore parity.

The two properties the production load harness stands on:

  * identical seeds reproduce identical arrival traces, schedules, token
    streams, and percentile summaries (the benchmark's numbers are facts
    about the modeled deployment, not run-to-run noise);
  * a preempted request — paged KV spilled through the page tables at a
    safe point and restored on re-admission — emits greedy tokens
    bit-identical to the same request served uninterrupted, at every tier
    split (all-end / interior / all-cloud).
"""

import jax
import numpy as np
import pytest

from repro.configs import get_config, smoke_config
from repro.core.hardware import PROFILES
from repro.models.model import build_model
from repro.serving.common import Request, VirtualClock
from repro.serving.engine import ServingEngine
from repro.serving.loadgen import (
    WorkloadClass,
    build_schedule,
    bursty_arrivals,
    drive,
    poisson_arrivals,
    summarize,
)
from repro.serving.stream import EndCloudServingEngine


@pytest.fixture(scope="module")
def tiny_model():
    cfg = smoke_config(get_config("tinyllama-1.1b")).replace(num_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


CLASSES = (
    WorkloadClass("interactive", priority=0, weight=0.7,
                  prompt_len=(4, 10), new_tokens=(2, 4), ttft_slo_s=1.0),
    WorkloadClass("batch", priority=2, weight=0.3,
                  prompt_len=(16, 40), new_tokens=(4, 8)),
)


# -- arrival processes ------------------------------------------------------


def test_poisson_arrivals_deterministic():
    a = poisson_arrivals(200, 5.0, seed=7)
    b = poisson_arrivals(200, 5.0, seed=7)
    c = poisson_arrivals(200, 5.0, seed=8)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)
    assert np.all(np.diff(a) > 0)
    # LLN: the empirical rate is in the right ballpark for 200 draws
    assert 200 / a[-1] == pytest.approx(5.0, rel=0.35)


def test_bursty_arrivals_deterministic_and_bursty():
    a = bursty_arrivals(400, 10.0, seed=3, burst_factor=8.0)
    b = bursty_arrivals(400, 10.0, seed=3, burst_factor=8.0)
    np.testing.assert_array_equal(a, b)
    assert np.all(np.diff(a) >= 0)
    # ON/OFF modulation: inter-arrival gaps are far more dispersed than a
    # Poisson process of the same mean rate (index of dispersion >> 1)
    gaps = np.diff(a)
    assert gaps.std() / gaps.mean() > 1.5


def test_build_schedule_deterministic():
    arr = poisson_arrivals(100, 20.0, seed=1)
    s1 = build_schedule(arr, CLASSES, seed=2)
    s2 = build_schedule(arr, CLASSES, seed=2)
    assert len(s1) == 100
    for (t1, r1), (t2, r2) in zip(s1, s2):
        assert t1 == t2
        assert r1.priority == r2.priority
        assert r1.max_new_tokens == r2.max_new_tokens
        np.testing.assert_array_equal(r1.prompt, r2.prompt)
    # both classes actually drawn, ids in arrival order
    assert {r.priority for _, r in s1} == {0, 2}
    assert [r.request_id for _, r in s1] == list(range(100))


def test_drive_requires_virtual_clock(tiny_model):
    model, params = tiny_model
    eng = EndCloudServingEngine(
        model, params,
        end_profile=PROFILES["a100"], cloud_profile=PROFILES["a100"],
        max_batch=2, max_len=64, force_split=1, timing="modeled",
    )
    with pytest.raises(ValueError, match="VirtualClock"):
        drive(eng, [])


def test_drive_reproducible_end_to_end(tiny_model):
    """Same seed, fresh engine -> identical tokens AND identical summary
    (the percentile metrics are deterministic, not just the traces)."""
    model, params = tiny_model

    def one_run():
        eng = EndCloudServingEngine(
            model, params,
            end_profile=PROFILES["a100"], cloud_profile=PROFILES["a100"],
            max_batch=4, max_len=64, force_split=1,
            timing="modeled", clock=VirtualClock(),
        )
        arr = poisson_arrivals(24, 50.0, seed=11)
        reqs = drive(eng, build_schedule(arr, CLASSES, seed=12))
        return (
            {r.request_id: list(r.generated) for r in reqs},
            summarize(reqs),
            summarize(reqs, priority=0),
        )

    tokens1, all1, inter1 = one_run()
    tokens2, all2, inter2 = one_run()
    assert tokens1 == tokens2
    assert all1 == all2
    assert inter1 == inter2
    assert all1["dropped"] == 0
    assert all1["finished"] == 24
    assert inter1["n"] < all1["n"]
    # modeled stamps: every finished request has coherent lifecycle times
    for _, r in sorted(tokens1.items()):
        assert len(r) > 0


def test_summarize_warmup_and_priority_filters():
    def req(i, sub, first, fin, prio, n_tok):
        r = Request(i, np.zeros(4, np.int32), priority=prio,
                    ttft_slo_s=0.5)
        r.submit_time, r.first_token_time, r.finish_time = sub, first, fin
        r.generated = list(range(n_tok))
        return r

    rs = [
        req(0, 0.0, 0.1, 1.0, 0, 4),   # warmup: excluded below
        req(1, 2.0, 2.2, 3.0, 0, 5),
        req(2, 2.5, 3.8, 4.0, 2, 3),   # ttft 1.3 > slo... but slo unset? prio 2
    ]
    s = summarize(rs, warmup_s=1.0)
    assert s["n"] == 2 and s["finished"] == 2 and s["dropped"] == 0
    assert s["ttft_p50"] == pytest.approx(np.percentile([0.2, 1.3], 50))
    s0 = summarize(rs, warmup_s=1.0, priority=0)
    assert s0["n"] == 1
    assert s0["ttft_p99"] == pytest.approx(0.2, abs=1e-9)
    assert s0["slo_ttft_violations"] == 0
    # request 2 carries ttft_slo_s=0.5 and misses it
    assert s["slo_ttft_violations"] == 1


# -- preemption parity ------------------------------------------------------


def _scenario_prompts():
    rng = np.random.default_rng(42)
    return [
        rng.integers(0, 500, size=n).astype(np.int32)
        for n in (12, 14, 9)  # A1, A2 (victim), B (interactive)
    ]


@pytest.fixture(scope="module")
def parity_reference(tiny_model):
    """Uninterrupted greedy tokens from the dense single-tier engine."""
    model, params = tiny_model
    pa1, pa2, pb = _scenario_prompts()
    eng = ServingEngine(model, params, max_batch=4, max_len=64)
    reqs = [
        Request(0, pa1, max_new_tokens=12),
        Request(1, pa2, max_new_tokens=12),
        Request(2, pb, max_new_tokens=4),
    ]
    for r in reqs:
        eng.submit(r)
    eng.run()
    return {r.request_id: list(r.generated) for r in reqs}


@pytest.mark.parametrize("split", [0, 1, 2])
def test_preemption_spill_restore_token_parity(
    tiny_model, parity_reference, split
):
    """A low-priority slot evicted mid-decode (paged KV spilled via the
    page tables, restored on re-admission) emits exactly the tokens it
    would have uninterrupted — at all-end, interior, and all-cloud splits."""
    model, params = tiny_model
    pa1, pa2, pb = _scenario_prompts()
    eng = EndCloudServingEngine(
        model, params,
        end_profile=PROFILES["a100"], cloud_profile=PROFILES["a100"],
        max_batch=2, max_len=64, force_split=split,
        admission="priority", preemption=True,
    )
    a1 = Request(0, pa1, max_new_tokens=12, priority=2)
    a2 = Request(1, pa2, max_new_tokens=12, priority=2)
    b = Request(2, pb, max_new_tokens=4, priority=0)
    eng.submit(a1)
    eng.submit(a2)
    # run both low-priority requests into mid-decode
    for _ in range(200):
        eng.step()
        if len(a1.generated) >= 3 and len(a2.generated) >= 3:
            break
    assert not a1.done and not a2.done, "victims must still be running"
    # the interactive request preempts the youngest low-priority slot
    eng.submit(b)
    eng.step()
    assert eng.n_preemptions == 1
    assert a2.n_preemptions == 1 and a1.n_preemptions == 0
    assert eng.metrics()["preempt_spill_bytes"] > 0
    done = eng.run()
    assert len(done) == 3
    assert eng.n_preempt_restores == 1
    got = {r.request_id: list(r.generated) for r in (a1, a2, b)}
    assert got == parity_reference
    # pools drain clean after the spill/restore cycle
    assert eng.metrics()["kv_pages_in_use"] == 0


def test_fifo_mode_never_preempts(tiny_model):
    model, params = tiny_model
    pa1, pa2, pb = _scenario_prompts()
    eng = EndCloudServingEngine(
        model, params,
        end_profile=PROFILES["a100"], cloud_profile=PROFILES["a100"],
        max_batch=2, max_len=64, force_split=1,
        admission="fifo",
    )
    assert eng.preemption is False
    a1 = Request(0, pa1, max_new_tokens=12, priority=2)
    a2 = Request(1, pa2, max_new_tokens=12, priority=2)
    b = Request(2, pb, max_new_tokens=4, priority=0)
    for r in (a1, a2, b):
        eng.submit(r)
    done = eng.run()
    assert len(done) == 3
    assert eng.n_preemptions == 0
    # FIFO: b entered last and waited for a free slot
    assert b.first_token_time >= max(a1.first_token_time,
                                     a2.first_token_time)
