"""Streaming end-cloud decode engine (serving.stream.EndCloudServingEngine).

Covers the tentpole invariants:
  (a) token-identical greedy decode vs the single-tier ServingEngine when
      the boundary codec is off, for splits 0 / mid / R;
  (b) LinkStats boundary bytes shrink by the eq. 8 ratio r/d with the
      codec on;
  (c) a replan event re-splits params/caches at a safe point without
      corrupting in-flight generations;
plus cache split/merge round-trips and the pipelined-vs-serial step
accounting.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_config
from repro.core.hardware import PROFILES, DeviceProfile, DeviceState
from repro.models import kvcache
from repro.models.model import build_model
from repro.serving.engine import Request, ServingEngine
from repro.serving.stream import EndCloudServingEngine


@pytest.fixture(scope="module")
def tiny_model():
    cfg = smoke_config(get_config("tinyllama-1.1b")).replace(num_layers=4)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _prompts(n, seed=0):
    rng = np.random.default_rng(seed)
    return [
        rng.integers(0, 500, size=int(rng.integers(4, 16))).astype(np.int32)
        for _ in range(n)
    ]


def _reference_tokens(model, params, prompts, max_new_tokens):
    eng = ServingEngine(model, params, max_batch=4, max_len=64)
    reqs = [Request(i, p, max_new_tokens=max_new_tokens)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    return {r.request_id: r.generated for r in reqs}


@pytest.fixture(scope="module")
def reference(tiny_model):
    model, params = tiny_model
    return _reference_tokens(model, params, _prompts(6), max_new_tokens=8)


@pytest.mark.parametrize("split", [0, 2, 4])
def test_token_identical_to_single_tier(tiny_model, reference, split):
    """(a) any split, codec off -> exactly the single-tier greedy tokens."""
    model, params = tiny_model
    eng = EndCloudServingEngine(
        model, params,
        end_profile=PROFILES["a100"], cloud_profile=PROFILES["a100"],
        max_batch=4, max_len=64, force_split=split,
    )
    reqs = [Request(i, p, max_new_tokens=8) for i, p in enumerate(_prompts(6))]
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert len(done) == 6
    assert {r.request_id: r.generated for r in reqs} == reference


def test_codec_shrinks_boundary_bytes(tiny_model):
    """(b) bytes on the wire scale by r/d when the low-rank codec is on."""
    model, params = tiny_model
    d = model.cfg.d_model
    rank = d // 4
    bytes_up = {}
    for r in (0, rank):
        eng = EndCloudServingEngine(
            model, params,
            end_profile=PROFILES["a100"], cloud_profile=PROFILES["a100"],
            max_batch=4, max_len=64, force_split=2, compression_rank=r,
        )
        for i, p in enumerate(_prompts(6)):
            eng.submit(Request(i, p, max_new_tokens=8))
        eng.run()
        assert eng.tiers.compress == bool(r)
        bytes_up[r] = eng.link.bytes_up
    assert bytes_up[rank] == pytest.approx(bytes_up[0] * rank / d)


def test_replan_preserves_inflight_generations(tiny_model, reference):
    """(c) a mid-run re-split relayouts params/caches without corrupting
    the streams (codec off -> still token-identical to single tier)."""
    model, params = tiny_model
    # weak end, strong cloud: all-end (the forced split) is ~400x slower
    # than the planner's optimum, so the replan clears the hysteresis
    weak_end = DeviceProfile("weak-end", peak_gflops=2.0, mem_gb=8.0,
                             mem_bw_gbs=50.0, net_gbps=0.3)
    eng = EndCloudServingEngine(
        model, params,
        end_profile=weak_end, cloud_profile=PROFILES["a100"],
        max_batch=4, max_len=64, force_split=model.cfg.block_repeat,
    )
    reqs = [Request(i, p, max_new_tokens=8) for i, p in enumerate(_prompts(6))]
    for r in reqs:
        eng.submit(r)
    for _ in range(3):
        eng.step()
    # any observation re-checks the plan; the forced all-end split is far
    # off-optimal, so a replan event fires and is applied at the next safe
    # (drained) tick
    eng.observe_bandwidth(weak_end.net_gbps)
    eng.run()
    assert len(eng.replan_events) >= 1
    ev = eng.replan_events[0]
    assert ev["old_split"] == model.cfg.block_repeat
    assert ev["new_split"] != ev["old_split"] and eng.split == ev["new_split"]
    assert {r.request_id: r.generated for r in reqs} == reference


def test_device_state_change_updates_end_mask():
    """update_device_state re-derives the eq. 2-4 expert mask; a shrunk
    mask is applied at the replan safe point without breaking the stream."""
    cfg = smoke_config(get_config("llama4-scout-17b-16e")).replace(num_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    moe = cfg.moe
    expert_bytes = (3 if cfg.ffn_gated else 2) * cfg.d_model * moe.d_ff_expert * 2
    cap_n = max(1, int(np.floor(moe.local_selection_cap * moe.num_experts)))
    # memory sized so a fully-free device holds the 40%-cap expert set but a
    # 40%-free one holds fewer (eq. 4's memory term becomes binding)
    prof = DeviceProfile(
        "edge-tiny", peak_gflops=2000.0,
        mem_gb=(cap_n + 1.2) * expert_bytes / 1e9,
        mem_bw_gbs=51.0, net_gbps=0.05,
    )
    eng = EndCloudServingEngine(
        model, params,
        end_profile=prof, cloud_profile=PROFILES["a100"],
        max_batch=4, max_len=64, force_split=1,
    )
    m0 = np.asarray(eng.tiers.end_mask)
    reqs = [Request(i, p, max_new_tokens=8) for i, p in enumerate(_prompts(4))]
    for r in reqs:
        eng.submit(r)
    for _ in range(3):
        eng.step()
    eng.update_device_state(DeviceState(mem_free=0.4))
    done = eng.run()
    m1 = np.asarray(eng.tiers.end_mask)
    assert m1.sum() < m0.sum()
    assert any(ev["mask_changed"] for ev in eng.replan_events)
    assert len(done) == 4 and all(len(r.generated) == 8 for r in done)


def test_stream_rejects_overlong_request(tiny_model):
    """Regression: the streaming engine validates prompt + max_new_tokens
    against max_len at submit — beyond it the per-tier KV ring buffers
    would wrap and corrupt attention mid-stream."""
    model, params = tiny_model
    eng = EndCloudServingEngine(
        model, params,
        end_profile=PROFILES["a100"], cloud_profile=PROFILES["a100"],
        max_batch=2, max_len=64, force_split=2,
    )
    with pytest.raises(ValueError, match="max_len"):
        eng.submit(Request(0, np.arange(60).astype(np.int32), max_new_tokens=8))
    assert not eng.waiting


def test_cache_split_merge_roundtrip(tiny_model):
    model, _ = tiny_model
    cfg = model.cfg
    cache = kvcache.init_cache(cfg, 3, 32, jnp.dtype(cfg.dtype))
    cache["lengths"] = cache["lengths"] + 5
    for split in (0, 2, cfg.block_repeat):
        end, cloud = kvcache.split_cache(cache, split)
        assert jax.tree.leaves(end["blocks"])[0].shape[0] == split
        merged = kvcache.merge_cache(end, cloud)
        for a, b in zip(jax.tree.leaves(merged), jax.tree.leaves(cache)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pipelined_step_beats_serial_sum(tiny_model):
    """Double-buffered overlap: steady-state pipelined step < t_end + t_comm
    + t_cloud, and never below the bottleneck stage."""
    model, params = tiny_model
    eng = EndCloudServingEngine(
        model, params,
        end_profile=PROFILES["a100"], cloud_profile=PROFILES["a100"],
        max_batch=4, max_len=64, force_split=2,
    )
    for i, p in enumerate(_prompts(8, seed=1)):
        eng.submit(Request(i, p, max_new_tokens=16))
    eng.run()
    m = eng.metrics()
    assert m["n_stage_steps"] > 10
    max_stage = max(m["mean_t_end_s"], m["mean_t_comm_s"], m["mean_t_cloud_s"])
    assert m["pipelined_step_s"] < m["serial_step_s"]
    assert m["pipelined_step_s"] >= max_stage - 1e-9
