"""MoE layer path equivalence + dispatch properties."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep: deterministic fallback grid
    from _hypothesis_compat import given, settings, st

from repro.configs import CompressionConfig, get_config, smoke_config
from repro.core import moe as moe_mod


def _cfg(E=8, K=4, top_k=2, gated=True, cf=4.0):
    cfg = smoke_config(get_config("qwen3-moe-235b-a22b"))
    return cfg.replace(
        moe=dataclasses.replace(
            cfg.moe, num_experts=E, num_groups=K, top_k=top_k, capacity_factor=cf
        ),
        ffn_gated=gated,
        compression=None,  # codec paths are tested explicitly below
    )


@settings(max_examples=10, deadline=None)
@given(
    E=st.sampled_from([4, 8]),
    top_k=st.sampled_from([1, 2]),
    gated=st.booleans(),
    seed=st.integers(0, 3),
)
def test_sorted_matches_naive(E, top_k, gated, seed):
    cfg = _cfg(E=E, K=min(4, E), top_k=top_k, gated=gated)
    params = moe_mod.init_moe(jax.random.PRNGKey(seed), cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 9), (24, cfg.d_model))
    y_s, _ = moe_mod.moe_sorted(params, x, cfg)
    y_n, _ = moe_mod.moe_naive(params, x, cfg)
    np.testing.assert_allclose(np.asarray(y_s), np.asarray(y_n),
                               rtol=2e-4, atol=2e-4)


def test_masked_moe_uses_allowed_experts_only():
    cfg = _cfg()
    params = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (16, cfg.d_model))
    mask = jnp.asarray([True] * 4 + [False] * 4)
    # zero out the weights of masked experts: output must be unchanged
    params2 = dict(params)
    for k in ("wi", "wg", "wo"):
        params2[k] = params[k].at[4:].set(0.0)
    y1, _ = moe_mod.moe_sorted(params, x, cfg, expert_mask=mask)
    y2, _ = moe_mod.moe_sorted(params2, x, cfg, expert_mask=mask)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5)


def test_dispatch_codec_recon_tracked():
    """The eq. 8 reconstruction term is measured on the dispatch payload:
    positive for a truncating codec, ~zero at full rank.  (Monotonicity in
    rank is asserted on a fixed tensor in test_compression — here the
    second-hop error depends on the expert outputs, which differ per rank.)"""
    errs = {}
    for rank in (8, 128):
        cfg = _cfg().replace(
            compression=CompressionConfig(rank=rank, boundaries=("dispatch",))
        )
        params = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (32, cfg.d_model))
        _, aux = moe_mod.moe_sorted(params, x, cfg)
        errs[rank] = float(aux["recon_loss"])
    assert errs[128] < 1e-6  # full rank (=d_model) reconstructs exactly
    assert errs[8] > 1e-2  # rank-8 truncation loses real signal


def test_full_rank_codec_identity_output():
    cfg_plain = _cfg()
    cfg_codec = cfg_plain.replace(
        compression=CompressionConfig(rank=cfg_plain.d_model,
                                      boundaries=("dispatch",))
    )
    p = moe_mod.init_moe(jax.random.PRNGKey(0), cfg_codec)
    p_plain = {k: v for k, v in p.items() if k != "codec"}
    x = jax.random.normal(jax.random.PRNGKey(1), (16, cfg_plain.d_model))
    y1, _ = moe_mod.moe_sorted(p_plain, x, cfg_plain)
    y2, _ = moe_mod.moe_sorted(p, x, cfg_codec)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-3, atol=1e-4)


def test_shared_expert_added():
    cfg = _cfg()
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, shared_experts=1))
    params = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 1, cfg.d_model))
    y, _ = moe_mod.apply_moe(params, x, cfg, None)
    assert y.shape == x.shape
    # zeroing the shared expert changes the output
    params2 = dict(params)
    params2["shared"] = jax.tree.map(jnp.zeros_like, params["shared"])
    y2, _ = moe_mod.apply_moe(params2, x, cfg, None)
    assert float(jnp.abs(y - y2).max()) > 1e-6


def test_capacity_helper():
    assert moe_mod._capacity(1024, 16, 1.0) == 64
    assert moe_mod._capacity(1024, 16, 1.25) == 80
    assert moe_mod._capacity(3, 16, 1.0) == 8  # floor + multiple of 8
