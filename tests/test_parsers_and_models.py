"""Units for the dry-run HLO parsers and the analytic roofline cost model —
these feed the §Roofline numbers, so they get their own tests."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep: deterministic fallback grid
    from _hypothesis_compat import given, settings, st

from repro.launch.dryrun import (
    _computation_multipliers,
    _group_size,
    _shape_bytes,
    _split_computations,
    parse_collectives,
)

HLO = """
HloModule jit_step, is_scheduled=true

%body.1 (arg: (s32[], f32[8,64])) -> (s32[], f32[8,64]) {
  %arg = (s32[], f32[8,64]) parameter(0)
  %ag = f32[8,64]{1,0} all-gather(%x), channel_id=1, replica_groups=[16,16]<=[256], dimensions={0}
  %ar = f32[8,64]{1,0} all-reduce(%ag), channel_id=2, replica_groups=[16,16]<=[256]
}

%cond.1 (arg: (s32[], f32[8,64])) -> pred[] {
  %c = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

ENTRY %main (p0: f32[8,64]) -> f32[8,64] {
  %w = (s32[], f32[8,64]) while(%t), condition=%cond.1, body=%body.1
  %ar2 = f32[4,4]{1,0} all-reduce(%z), channel_id=3, replica_groups={{0,1,2,3}}
}
"""


def test_shape_bytes():
    assert _shape_bytes("f32[8,64]") == 8 * 64 * 4
    assert _shape_bytes("bf16[2,3]") == 12
    assert _shape_bytes("s32[]") == 4
    assert _shape_bytes("pred[7]") == 7


def test_group_size_formats():
    assert _group_size("replica_groups=[16,16]<=[256]", 1) == 16
    assert _group_size("replica_groups={{0,1,2,3}}", 1) == 4
    assert _group_size("no groups here", 7) == 7


def test_split_and_multipliers():
    comps = _split_computations(HLO)
    assert set(comps) == {"%body.1", "%cond.1", "ENTRY"}
    mult = _computation_multipliers(comps)
    assert mult["ENTRY"] == 1.0
    assert mult["%body.1"] == 12.0  # while trip count from the condition


def test_parse_collectives_trip_scaled():
    out = parse_collectives(HLO, default_group=16)
    b = 8 * 64 * 4
    frac = 15 / 16
    # in-loop: (AG + 2x AR) x 12 trips; entry: one 4-group AR of 64 bytes
    want = 12 * (b * frac + 2 * b * frac) + 2 * 64 * (3 / 4)
    assert abs(out["total_wire_bytes"] - want) / want < 1e-6
    assert out["total_wire_bytes_bf16eq"] == out["total_wire_bytes"] / 2


# ---------------------------------------------------------------- flops model

from benchmarks.flops_model import cell_cost
from repro.configs import SHAPE_BY_NAME, get_config


def test_flops_model_train_close_to_6nd():
    """For a dense model the analytic total should be within ~2.5x of
    6*N*D (extra = attention square, remat, optimizer)."""
    cfg = get_config("tinyllama-1.1b")
    cell = SHAPE_BY_NAME["train_4k"]
    c = cell_cost(cfg, cell, n_devices=256, dp=256)
    total = c.flops * 256
    assert c.model_flops < total < 4 * c.model_flops


def test_flops_model_modes_ordering():
    """decode << prefill < train per device for the same arch."""
    cfg = get_config("qwen3-14b")
    tr = cell_cost(cfg, SHAPE_BY_NAME["train_4k"], 256, 256).flops
    pf = cell_cost(cfg, SHAPE_BY_NAME["prefill_32k"], 256, 16).flops
    dc = cell_cost(cfg, SHAPE_BY_NAME["decode_32k"], 256, 16).flops
    assert dc < pf < tr


def test_moe_model_flops_uses_active_params():
    cfg = get_config("qwen3-moe-235b-a22b")
    c = cell_cost(cfg, SHAPE_BY_NAME["train_4k"], 256, 16)
    n_active = cfg.active_param_count()
    assert abs(c.model_flops - 6 * n_active * 256 * 4096) / c.model_flops < 1e-6
    assert cfg.active_param_count() < 0.25 * cfg.param_count()


# ------------------------------------------------------------------ data

from repro.data.pipeline import DataConfig, batches, eval_accuracy, make_dataset


@settings(max_examples=10, deadline=None)
@given(task=st.sampled_from(["lm", "glue_proxy", "squad_proxy"]),
       seed=st.integers(0, 100))
def test_data_shapes_and_masking(task, seed):
    cfg = DataConfig(task=task, vocab_size=512, seq_len=64, seed=seed)
    b = next(iter(batches(cfg, 4, 1, seed=seed)))
    assert b["tokens"].shape == (4, 64) and b["labels"].shape == (4, 64)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 512
    labs = b["labels"]
    assert (labs[labs >= 0] < 512).all()
    assert (labs >= 0).any(), "no supervised positions"


def test_lm_task_is_deterministic_per_latent_task():
    cfg = DataConfig(task="lm", vocab_size=512, seq_len=32, n_latent_tasks=2,
                     seed=1)
    sampler = make_dataset(cfg)
    rng = np.random.default_rng(0)
    toks, labs = sampler(rng)
    # next-token labels match the sequence shift
    np.testing.assert_array_equal(labs[1:-1], toks[2:])


def test_eval_accuracy_metric():
    logits = np.zeros((1, 4, 8))
    logits[0, :, 3] = 1.0
    labels = np.array([[3, 3, -1, 5]])
    assert eval_accuracy(logits, labels) == pytest.approx(2 / 3)
