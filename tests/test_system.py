"""End-to-end behaviour of the full EC2MoE system (single device):
train a tiny group-gated MoE on the mixture task, check it learns, serve it
through the end-cloud pipeline, and confirm the paper's eq. 8 joint
compression training improves the compressed model."""

import dataclasses
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import CompressionConfig, get_config, smoke_config
from repro.core.hardware import PROFILES
from repro.data.pipeline import DataConfig, batches, eval_accuracy
from repro.models.model import build_model
from repro.serving.endcloud import EndCloudPipeline
from repro.serving.engine import Request, ServingEngine


@pytest.fixture(scope="module")
def trained_system():
    from benchmarks.common import tiny_switch, train_tiny  # reuse harness

    cfg = tiny_switch(8, "ec2moe")
    dcfg = DataConfig(task="lm", vocab_size=512, seq_len=64, n_latent_tasks=4)
    model, st = train_tiny(cfg, dcfg, steps=120, seed=0)
    return cfg, dcfg, model, st["params"]


def test_learns_the_task(trained_system):
    cfg, dcfg, model, params = trained_system
    accs = []
    for b in batches(dcfg, 32, 4, seed=99):
        logits, _ = model.train_logits(
            params, {"tokens": jnp.asarray(b["tokens"])}, train=False
        )
        accs.append(eval_accuracy(np.asarray(logits), b["labels"]))
    acc = float(np.mean(accs))
    assert acc > 0.5, f"trained accuracy too low: {acc}"


def test_group_routing_is_specialized(trained_system):
    """After training on a latent-task mixture, stage-1 routing concentrates
    per token (load balance keeps the MEAN uniform; specialization shows as
    per-token confidence above the uniform 1/K)."""
    cfg, dcfg, model, params = trained_system
    from repro.core.gating import group_gate_probs

    b = next(iter(batches(dcfg, 16, 1, seed=7)))
    x = jnp.asarray(b["tokens"])
    emb = params["embed"][x].reshape(-1, cfg.d_model)
    gate_params = jax.tree.map(lambda l: l[0], params["blocks"]["pos1"]["moe"]["gate"])
    _, p_group, _ = group_gate_probs(gate_params, emb.astype(jnp.float32), cfg.moe)
    K = cfg.moe.num_groups
    concentration = float(np.asarray(p_group).max(axis=-1).mean())
    # strictly above uniform 1/K (per-token gates see no sequence context,
    # so the latent-task signal is weak but must be present)
    assert concentration > 1.0 / K + 0.005, concentration


def test_serving_trained_model(trained_system):
    cfg, dcfg, model, params = trained_system
    eng = ServingEngine(model, params, max_batch=4, max_len=96)
    rng = np.random.default_rng(0)
    for i in range(6):
        eng.submit(Request(i, rng.integers(0, 500, 24).astype(np.int32),
                           max_new_tokens=4))
    done = eng.run()
    assert len(done) == 6 and all(len(r.generated) == 4 for r in done)


def test_endcloud_pipeline_on_trained_model(trained_system):
    cfg, dcfg, model, params = trained_system
    pipe = EndCloudPipeline(
        model, params,
        end_profile=PROFILES["xeon-4214r"],
        cloud_profile=PROFILES["a100"],
        compression_rank=cfg.d_model // 2,
    )
    b = next(iter(batches(dcfg, 8, 1, seed=3)))
    logits, metrics = pipe.run_batch(jnp.asarray(b["tokens"]))
    acc = eval_accuracy(np.asarray(logits), b["labels"])
    assert acc > 0.35, f"end-cloud accuracy collapsed: {acc}"
    assert metrics["boundary_bytes"] > 0 and pipe.link.transfers == 1


def test_joint_compression_training_beats_posthoc():
    """eq. 8: training WITH the codec in the loop beats bolting the same-
    rank codec onto a model trained without it."""
    from benchmarks.common import tiny_switch, train_tiny, eval_tiny

    dcfg = DataConfig(task="lm", vocab_size=512, seq_len=64, n_latent_tasks=4)
    rank = 16

    joint_cfg = tiny_switch(8, "ec2moe").replace(
        compression=CompressionConfig(rank=rank, boundaries=("dispatch",),
                                      recon_weight=0.05)
    )
    m1, s1 = train_tiny(joint_cfg, dcfg, steps=120, seed=0)
    acc_joint = eval_tiny(m1, s1["params"], dcfg, n_batches=6)

    plain_cfg = tiny_switch(8, "brownoutserve")  # no codec at train
    m2, s2 = train_tiny(plain_cfg, dcfg, steps=120, seed=0)
    # bolt on an untrained codec of the same rank at eval
    import repro.core.compression as comp

    p2 = dict(s2["params"])
    blocks = dict(p2["blocks"])
    moe_p = dict(blocks["pos1"]["moe"])
    codec = comp.init_lowrank_1d(jax.random.PRNGKey(9), plain_cfg.d_model, rank)
    R = m2.cfg.block_repeat
    moe_p["codec"] = jax.tree.map(
        lambda l: jnp.broadcast_to(l, (R,) + l.shape), codec
    )
    blocks["pos1"] = dict(blocks["pos1"], moe=moe_p)
    p2["blocks"] = blocks
    eval_cfg = plain_cfg.replace(
        compression=CompressionConfig(rank=rank, boundaries=("dispatch",))
    )
    m2b = build_model(eval_cfg)
    acc_posthoc = eval_tiny(m2b, p2, dcfg, n_batches=6)
    assert acc_joint > acc_posthoc, (acc_joint, acc_posthoc)
