"""Paged expert-weight pool (core.expertpool + core.moe.moe_resident +
the pooled end tier of serving.stream).

Covers the tentpole invariants:
  (a) pool allocator/policy: alloc/evict/capacity accounting, prefetch
      priority by measured route frequency, capacity shrinks never starve
      a layer while the budget allows one resident;
  (b) moe_resident == moe_sorted under the same mask for any resident
      superset of the routed experts (f32);
  (c) greedy token parity dense-vs-pooled through the serving engines at
      splits 0 / mid / R;
  (d) mask shrink+grow at replan safe points: pooled engine stays
      token-identical to the dense engine fed the same state updates, and
      the grow's slab prefetches are booked on the link timeline;
  (e) eviction never corrupts: poisoning evicted slabs changes nothing
      for resident-routed tokens;
  (f) a shrinking memory budget actually sheds experts (evictions), and
      per-step end-tier expert HBM bytes scale with residents (<= 1/2 of
      dense at the 40% selection cap);
  (g) measured group frequencies reorder the eq. 4 greedy admit.

Plus the fleet expert store (core.expertpool.FleetExpertRegistry +
serving.fleet wiring + distributed.sharding's registry-driven cloud
shards):
  (h) randomized plan() invariants: determinism, budget ceiling after
      evictions, anti-thrash (no active-layer target resident evicted
      while the pool is under capacity);
  (i) registry policies: replicate-vs-dedup rule, peer-vs-cloud source
      choice over the modeled end<->end link, fleet map / dedup ratio,
      peer bookings on the source lane's link;
  (j) an all-False expert mask is rejected loudly and identically on
      every engine boundary (dense and pooled alike);
  (k) fleet engine: greedy token parity registry-vs-isolated at splits
      0/mid/R, peer-fetched misses booked on both lanes' link timelines,
      routed-token-weighted fleet hit rate;
  (l) placement feeds: place_fleet's expert_cost term and the
      load-balanced cloud expert shards.
"""

from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_compat import given, settings, st

from repro.configs import get_config, smoke_config
from repro.core import expertpool as ep
from repro.core import moe as moe_mod
from repro.core.hardware import (
    PROFILES, Capability, DeviceProfile, DeviceState,
)
from repro.core.pipeline import (
    SchedulerConfig, Task, peer_comm_time, peer_link_gbps, place_fleet,
)
from repro.core.selection import (
    group_priority_from_freq, residency_target, validate_expert_mask,
)
from repro.distributed.sharding import fleet_expert_shards, shard_expert_stacks
from repro.models.model import build_model
from repro.serving.common import Request
from repro.serving.endcloud import plan_tiers
from repro.serving.engine import ServingEngine
from repro.serving.fleet import FleetServingEngine
from repro.serving.stream import EndCloudServingEngine


@pytest.fixture(scope="module")
def moe_model():
    cfg = smoke_config(get_config("llama4-scout-17b-16e")).replace(
        num_layers=4, dtype="float32", param_dtype="float32"
    )
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _prompts(n, seed=0):
    rng = np.random.default_rng(seed)
    return [
        rng.integers(0, 500, size=int(rng.integers(4, 16))).astype(np.int32)
        for _ in range(n)
    ]


def _run_engine(model, params, *, expert_pool, split, profile=None,
                updates=(), n_req=5, new_tokens=8, **kw):
    """Run a workload, applying ``updates`` = [(after_steps, DeviceState)]
    at fixed step counts; returns (tokens dict, engine)."""
    eng = EndCloudServingEngine(
        model, params,
        end_profile=profile or PROFILES["a100"],
        cloud_profile=PROFILES["a100"],
        max_batch=4, max_len=64, force_split=split,
        expert_pool=expert_pool, **kw,
    )
    reqs = [Request(i, p, max_new_tokens=new_tokens)
            for i, p in enumerate(_prompts(n_req))]
    for r in reqs:
        eng.submit(r)
    pending = sorted(updates, key=lambda u: u[0])
    steps = 0
    while eng.busy():
        while pending and pending[0][0] <= steps:
            eng.update_device_state(pending[0][1])
            pending.pop(0)
        eng.step()
        steps += 1
        assert steps < 10_000
    return {r.request_id: r.generated for r in reqs}, eng


# ----------------------------------------------------------- pool allocator

def test_pool_alloc_evict_invariants():
    pool = ep.ExpertSlabPool(num_slabs=6, n_layers=2, num_experts=8,
                             max_per_layer=3)
    s0 = pool.alloc(0, 2)
    s1 = pool.alloc(1, 2)
    assert s0 != s1 and pool.slabs_in_use == 2
    assert pool.resident_mask(0)[2] and not pool.resident_mask(0)[3]
    with pytest.raises(ValueError):
        pool.alloc(0, 2)  # double alloc
    pool.alloc(0, 0)
    pool.alloc(0, 1)
    with pytest.raises(ValueError):
        pool.alloc(0, 3)  # beyond max_per_layer
    freed = pool.evict(0, 2)
    assert freed == s0 and pool.slabs_in_use == 3
    with pytest.raises(ValueError):
        pool.evict(0, 2)  # double evict
    assert pool.free_layer(1) == [s1]
    assert pool.slabs_in_use == 2
    assert pool.peak_in_use == 4


def test_pool_plan_orders_by_measured_frequency():
    pool = ep.ExpertSlabPool(num_slabs=8, n_layers=2, num_experts=8,
                             max_per_layer=3)
    target = np.zeros(8, bool)
    target[[0, 1, 2]] = True
    freq = np.array([0.1, 0.5, 0.2, 0, 0, 0, 0, 0])
    wanted, evictions = pool.plan([0, 1], target, freq)
    assert evictions == []
    # round-robin by rank so no layer is starved, freq-desc within a rank
    assert wanted == [(0, 1), (1, 1), (0, 2), (1, 2), (0, 0), (1, 0)]


def test_pool_capacity_shrink_keeps_one_resident_per_layer():
    pool = ep.ExpertSlabPool(num_slabs=6, n_layers=2, num_experts=8,
                             max_per_layer=3)
    target = np.zeros(8, bool)
    target[[0, 1, 2]] = True
    for layer in (0, 1):
        for e in (0, 1, 2):
            pool.alloc(layer, e)
    freq = np.array([0.6, 0.3, 0.1, 0, 0, 0, 0, 0])
    pool.set_capacity(3)
    wanted, evictions = pool.plan([0, 1], target, freq)
    assert wanted == [] and len(evictions) == 3
    for layer, e in evictions:
        pool.evict(layer, e)
    # lowest-frequency residents went first, and no layer went to zero
    assert pool.resident_count(0) >= 1 and pool.resident_count(1) >= 1
    assert pool.slabs_in_use == 3
    assert all(not pool.resident_mask(layer)[2] for layer in (0, 1))


def test_pool_plan_evicts_stale_nontarget_for_room():
    pool = ep.ExpertSlabPool(num_slabs=2, n_layers=1, num_experts=8,
                             max_per_layer=2)
    pool.alloc(0, 6)  # non-target leftover from an old mask
    pool.alloc(0, 7)
    target = np.zeros(8, bool)
    target[[0, 1]] = True
    freq = np.zeros(8)
    freq[6] = 0.5  # 6 is still hot, 7 is stale
    wanted, evictions = pool.plan([0], target, freq)
    assert wanted == [(0, 0), (0, 1)]
    # needs both slots eventually; the stale one goes first
    assert evictions[0] == (0, 7)


# ------------------------------------------------------------ moe_resident

def test_moe_resident_matches_sorted_for_any_superset(moe_model):
    model, _ = moe_model
    cfg = model.cfg
    m = cfg.moe
    E = m.num_experts
    params = moe_mod.init_moe(jax.random.PRNGKey(3), cfg)
    x = jax.random.normal(jax.random.PRNGKey(4), (16, cfg.d_model), jnp.float32)
    mask = np.zeros(E, bool)
    mask[[0, 1, 4]] = True
    y_ref, _ = moe_mod.moe_sorted(params, x, cfg, jnp.asarray(mask))

    full = {k: params[k][None] for k in ("wi", "wg", "wo") if k in params}
    for extra in ([], [6], [2, 6]):  # resident supersets of the mask
        S = 5
        pool = ep.ExpertSlabPool(E, n_layers=1, num_experts=E, max_per_layer=S)
        store = ep.init_slab_store(cfg, E)
        asg = []
        for e in sorted([0, 1, 4] + extra):
            asg.append((pool.alloc(0, e), 0, e))
        store = ep.write_slabs(store, full, asg)
        tabs = ep.device_resident_tables(pool, [0], S)
        rp = {
            "gate": params["gate"],
            "resident": {"ids": tabs["ids"][0], "slot": tabs["slot"][0],
                         "store": store},
        }
        y_res, aux = moe_mod.moe_resident(rp, x, cfg, jnp.asarray(mask))
        # ragged_dot group partitions differ (E groups vs S+1 slots), so
        # accumulation order drifts at f32 epsilon; greedy tokens still
        # match exactly (engine parity tests below)
        np.testing.assert_allclose(
            np.asarray(y_res), np.asarray(y_ref), rtol=1e-4, atol=1e-4
        )
        assert np.isfinite(float(aux["aux_loss"]))
    # host-side form of the in-trace effective mask
    resident = np.zeros(E, bool)
    resident[[0, 1, 4, 6]] = True
    np.testing.assert_array_equal(residency_target(mask, resident), mask)


# ------------------------------------------------- engines: token parity

@pytest.mark.parametrize("split", [0, 2, 4])
def test_engine_token_parity_dense_vs_pooled(moe_model, split):
    model, params = moe_model
    dense, _ = _run_engine(model, params, expert_pool=False, split=split)
    pooled, eng = _run_engine(model, params, expert_pool=True, split=split)
    assert dense == pooled
    m = eng.metrics()
    assert m["expert_hit_rate"] == pytest.approx(1.0)
    # the end tier's dense expert stacks are gone (the memory claim)
    for i, spec in enumerate(model.cfg.layer_pattern):
        if spec.moe:
            moe_p = eng.end_params["blocks"][f"pos{i}"]["moe"]
            assert "wi" not in moe_p and "wo" not in moe_p


def _mask_profile(cfg, cap_n, mem_scale=1.0):
    """Profile whose eq. 4 memory term binds the mask at ``cap_n`` experts
    when fully free (the eq. 4 complexity model prices weights in bf16)."""
    wb = (3 if cfg.ffn_gated else 2) * cfg.d_model * cfg.moe.d_ff_expert * 2
    return DeviceProfile(
        "edge-mask", peak_gflops=2000.0,
        mem_gb=(cap_n + 1.2) * wb * mem_scale / 1e9,
        mem_bw_gbs=51.0, net_gbps=0.05,
    )


def test_mask_shrink_grow_parity_and_prefetch_on_timeline(moe_model):
    """(d) the pooled engine applies mask changes (and the grow's slab
    arrivals) at the same safe points as the dense rebuild — greedy tokens
    identical; the grow's prefetch bytes ride the link timeline."""
    model, params = moe_model
    prof = _mask_profile(model.cfg, cap_n=3)
    updates = [(3, DeviceState(mem_free=0.7)), (7, DeviceState(mem_free=1.0))]
    # resident-slot headroom (+ a high prefetch budget) lets the grow's
    # slabs land before the safe point that applies the mask, so the
    # pooled effective mask flips on the exact tick the dense rebuild
    # does; without headroom the pool legitimately lags one safe point
    # (evict stale residents -> transfer -> apply)
    kw = dict(profile=prof, updates=updates, new_tokens=10,
              expert_mem_frac=8.0, expert_prefetch_per_tick=32,
              expert_resident_slots=model.cfg.moe.num_experts)
    dense, deng = _run_engine(model, params, expert_pool=False, split=2, **kw)
    pooled, peng = _run_engine(model, params, expert_pool=True, split=2, **kw)
    # the state updates actually moved the mask both ways
    assert any(ev["mask_changed"] for ev in deng.replan_events)
    assert dense == pooled
    m = peng.metrics()
    assert m["expert_prefetches"] > 0
    assert m["expert_bytes_down"] == (
        m["expert_prefetches"] * peng._slab_bytes
    )
    # prefetch wire time is booked on the shared link resource, on top of
    # the boundary/prefill traffic the engine's own stage meters carry
    link_busy = peng.timeline.busy_s[peng._res_link]
    own = peng._stage_busy["link"] + peng._prefill_busy["link"]
    assert link_busy > own
    assert m["expert_hit_rate"] == pytest.approx(1.0)


def test_memory_shrink_sheds_experts_and_eviction_never_corrupts(moe_model):
    """(e)+(f) halving the memory budget halves the slab capacity: the
    resident set shrinks via evictions at a safe point, and poisoning the
    evicted slabs' storage rows changes no resident-routed token."""
    model, params = moe_model
    cfg = model.cfg
    slab = ep.expert_slab_bytes(cfg)
    # capacity 6 slabs at full memory (= 2 layers x 3 target experts at
    # split 2), 3 slabs at mem_free=0.5
    prof = DeviceProfile(
        "edge-evict", peak_gflops=2000.0, mem_gb=6 * slab / 1e9,
        mem_bw_gbs=51.0, net_gbps=0.05,
    )
    updates = [(4, DeviceState(mem_free=0.5))]

    def run(poison):
        eng = EndCloudServingEngine(
            model, params, end_profile=prof, cloud_profile=PROFILES["a100"],
            max_batch=4, max_len=64, force_split=2,
            expert_pool=True, expert_mem_frac=1.0,
        )
        reqs = [Request(i, p, max_new_tokens=12)
                for i, p in enumerate(_prompts(5))]
        for r in reqs:
            eng.submit(r)
        pending = list(updates)
        steps = 0
        poisoned = False
        while eng.busy():
            while pending and pending[0][0] <= steps:
                eng.update_device_state(pending[0][1])
                pending.pop(0)
            eng.step()
            steps += 1
            if poison and not poisoned and eng.n_expert_evictions > 0:
                # poison every free (= evicted or never-used) slab row: no
                # applied table references them, so nothing may change
                rows = jnp.asarray(list(eng.expert_pool._free))
                for k in eng._slab_store:
                    eng._slab_store[k] = (
                        eng._slab_store[k].at[rows].set(jnp.nan)
                    )
                poisoned = True
            assert steps < 10_000
        if poison:
            assert poisoned, "no eviction happened to poison"
        return {r.request_id: r.generated for r in reqs}, eng

    clean, ceng = run(poison=False)
    assert ceng.n_expert_evictions > 0
    assert ceng.expert_pool.capacity == 3
    assert ceng.expert_pool.slabs_in_use <= 3
    # every active layer kept at least one resident
    for lid in ceng._active_lids():
        assert ceng.expert_pool.resident_count(lid) >= 1
    poisoned_tokens, _ = run(poison=True)
    assert poisoned_tokens == clean
    # all tokens valid (a NaN leak would argmax to 0 consistently; check
    # streams are finished and full length)
    assert all(len(t) == 12 for t in clean.values())


# ------------------------------------------------- metrics / HBM scaling

def test_expert_metrics_and_step_bytes_scale_with_residents(moe_model):
    model, params = moe_model
    _, eng = _run_engine(model, params, expert_pool=True, split=2)
    m = eng.metrics()
    for key in ("expert_resident_slabs", "expert_slab_capacity",
                "expert_hit_rate", "expert_bytes_down", "expert_bytes_up",
                "expert_bytes_resident", "expert_bytes_step_resident",
                "expert_bytes_step_dense", "expert_prefetches",
                "expert_evictions"):
        assert key in m, key
    # 40% selection cap: per-step expert HBM bytes of the resident gather
    # are at most half the dense [E, d, f] sweep (acceptance criterion)
    assert 0 < m["expert_bytes_step_resident"] <= m["expert_bytes_step_dense"] / 2
    E = model.cfg.moe.num_experts
    n_layers = len(eng._active_lids())
    assert m["expert_bytes_step_dense"] == n_layers * E * eng._slab_bytes


# ------------------------------------------- measured group priority (eq. 4)

def test_group_priority_from_freq_orders_greedy_admit(moe_model):
    assert group_priority_from_freq(None, 4) == [0, 1, 2, 3]
    assert group_priority_from_freq(np.array([0.1, 0.4, 0.2, 0.3]), 4) == \
        [1, 3, 2, 0]
    # ties keep natural order; bad shapes fall back to natural order
    assert group_priority_from_freq(np.zeros(4), 4) == [0, 1, 2, 3]
    assert group_priority_from_freq(np.zeros(3), 4) == [0, 1, 2, 3]

    model, params = moe_model
    cfg = model.cfg
    eng = EndCloudServingEngine(
        model, params,
        end_profile=PROFILES["a100"], cloud_profile=PROFILES["a100"],
        max_batch=2, max_len=64, force_split=2, expert_pool=True,
    )
    # measured traffic says group 2 is hottest: the re-derived mask admits
    # its experts before natural-order group 0 fills up
    gf = np.zeros(cfg.moe.num_groups)
    gf[2] = 1.0
    eng._group_freq = gf
    mask = np.asarray(eng._derive_end_mask(DeviceState()))
    Mk = cfg.moe.num_experts // cfg.moe.num_groups
    assert mask[2 * Mk : 2 * Mk + Mk].all()  # group 2 admitted first
    assert mask.sum() == int(0.4 * cfg.moe.num_experts)


def test_route_stats_are_measured_during_decode(moe_model):
    """The engine's frequency EMA comes from the gate's measured stats —
    it is populated by decoding and sums to ~1 over experts."""
    model, params = moe_model
    _, eng = _run_engine(model, params, expert_pool=True, split=2)
    assert eng._route_freq is not None and eng._group_freq is not None
    assert eng._route_freq.shape == (model.cfg.moe.num_experts,)
    assert eng._group_freq.shape == (model.cfg.moe.num_groups,)
    assert eng._route_freq.sum() == pytest.approx(1.0, rel=0.05)
    assert eng._group_freq.sum() == pytest.approx(1.0, rel=0.05)
    # traffic only flows to masked-in (resident) experts
    target = np.asarray(eng.tiers.end_mask, bool)
    assert eng._route_freq[~target].sum() == pytest.approx(0.0, abs=1e-9)


def test_pooled_engine_rejects_nothing_dense_path_accepts(moe_model):
    """Pooled mode is transparent at the API: same submit/validate
    behaviour, expert_pool=False fully restores the dense path."""
    model, params = moe_model
    eng = EndCloudServingEngine(
        model, params,
        end_profile=PROFILES["a100"], cloud_profile=PROFILES["a100"],
        max_batch=2, max_len=64, force_split=2, expert_pool=False,
    )
    assert eng.expert_pool is None
    assert eng.expert_metrics() == {}
    with pytest.raises(ValueError, match="max_len"):
        eng.submit(Request(0, np.arange(60).astype(np.int32),
                           max_new_tokens=8))


# --------------------------------------------- (h) randomized plan invariants

@settings(max_examples=24, deadline=None)
@given(seed=st.integers(min_value=0, max_value=23))
def test_plan_randomized_invariants(seed):
    """Property test over random residency/traffic/budget sequences: the
    plan is deterministic (same inputs -> same wanted AND eviction order),
    never leaves the pool over budget once its evictions apply, never
    thrashes (an (active-layer, target) resident is only evicted under
    capacity overflow), and its want list is well-formed."""
    rng = np.random.default_rng(seed)
    L, E, S = 3, 8, 4
    pool = ep.ExpertSlabPool(num_slabs=10, n_layers=L, num_experts=E,
                             max_per_layer=S)
    for _round in range(6):
        n_act = int(rng.integers(1, L + 1))
        active = sorted(rng.choice(L, size=n_act, replace=False).tolist())
        target = np.zeros(E, bool)
        target[rng.choice(E, size=int(rng.integers(1, S + 1)),
                          replace=False)] = True
        freq = None
        if rng.random() < 0.7:
            freq = rng.random(E)
            freq /= freq.sum()
        cap = int(rng.integers(1, pool.num_slabs + 1))
        in_use_before = pool.slabs_in_use
        pool.set_capacity(cap)
        plan_a = pool.plan(active, target, freq)
        plan_b = pool.plan(active, target, freq)
        assert plan_a == plan_b, "plan must be a pure, deterministic read"
        wanted, evictions = plan_a

        # well-formed: wanted is (active, target, non-resident), no dups;
        # evictions are current residents, no dups
        assert len(set(wanted)) == len(wanted)
        for lid, e in wanted:
            assert lid in active and target[e] and pool.table[lid, e] < 0
        assert len(set(evictions)) == len(evictions)
        for lid, e in evictions:
            assert pool.table[lid, e] >= 0

        # anti-thrash: while the pool is under budget there is no capacity
        # overflow, so no (active-layer, target) resident may be evicted
        # (evicting here to prefetch there would oscillate forever)
        if in_use_before <= cap:
            assert not any(
                lid in active and target[e] for lid, e in evictions
            )

        # apply the plan the way the engine does
        for lid, e in evictions:
            pool.evict(lid, e)
        # budget ceiling: evictions alone bring the pool under capacity
        assert pool.slabs_in_use <= cap
        for lid, e in wanted:
            if pool.can_alloc() and pool.resident_count(lid) < S:
                pool.alloc(lid, e)
        assert pool.slabs_in_use <= cap
        assert all(pool.resident_count(l) <= S for l in range(L))
        pool.touch(active, target)


# ------------------------------------------------ (i) fleet expert registry

def _mk_registry(nl=2, E=8, slab_bytes=1000, lan_gbps=None,
                 uplinks=(1.0, 1.0), **kw):
    """Registry over real slab pools with fake link callbacks; returns
    (registry, pools, per-lane book_link call logs)."""
    reg = ep.FleetExpertRegistry(nl, E, slab_bytes, lan_gbps=lan_gbps, **kw)
    pools, books = [], []
    for g in uplinks:
        pool = ep.ExpertSlabPool(num_slabs=8, n_layers=nl, num_experts=E,
                                 max_per_layer=4)
        log = []
        reg.register_lane(
            pool,
            link_gbps=lambda g=g: g,
            book_link=lambda r, t, log=log: (log.append((r, t)), r + t)[1],
        )
        pools.append(pool)
        books.append(log)
    return reg, pools, books


def test_registry_rejects_mismatched_pool_geometry():
    reg, _, _ = _mk_registry(nl=2, E=8)
    bad = ep.ExpertSlabPool(num_slabs=4, n_layers=3, num_experts=8,
                            max_per_layer=2)
    with pytest.raises(ValueError, match="geometry"):
        reg.register_lane(bad, link_gbps=lambda: 1.0,
                          book_link=lambda r, t: r + t)


def test_registry_dedup_rule_replicate_vs_peer():
    reg, pools, _ = _mk_registry()
    target = np.zeros(8, bool)
    target[:4] = True
    # unmeasured lane 0: the fleet plan IS the isolated pool plan (cold
    # fleets replicate -- that is what keeps greedy parity)
    iso = ep.ExpertSlabPool(num_slabs=8, n_layers=2, num_experts=8,
                            max_per_layer=4)
    assert reg.plan_lane(0, [0], target, None) == iso.plan([0], target, None)
    w0, _ = reg.plan_lane(0, [0], target, None)
    for lid, e in w0:
        pools[0].alloc(lid, e)
    # unmeasured lane 1 still replicates despite peer copies: no evidence
    w1, _ = reg.plan_lane(1, [0], target, None)
    assert w1 == w0
    # measured lane 1: hot experts (>= 1/E) replicate, cold duplicates are
    # dropped from the want list (served over the peer link on miss)
    freq = np.zeros(8)
    freq[0] = freq[1] = 0.5
    w1, _ = reg.plan_lane(1, [0], target, freq)
    assert w1 == [(0, 0), (0, 1)]
    # a sole fleet copy is always placed, however cold
    pools[0].evict(0, 2)
    w1, _ = reg.plan_lane(1, [0], target, freq)
    assert w1 == [(0, 0), (0, 1), (0, 2)]
    # dedup never forces an eviction: the registry lane's residency stays
    # a subset of what the isolated pool would hold (parity superset rule)
    _, ev = reg.plan_lane(1, [0], target, freq)
    assert ev == []


def test_registry_pick_source_peer_vs_cloud():
    slab = 1000
    # no declared LAN: the peer path rides both WAN uplinks (min rate), so
    # it can never strictly beat the direct cloud fetch -> cloud wins
    reg, pools, _ = _mk_registry(uplinks=(1.0, 0.5), slab_bytes=slab)
    pools[0].alloc(0, 3)
    src, t = reg.pick_source(1, 0, 3)
    assert src is None and t == pytest.approx(reg.cloud_fetch_s(1))
    assert peer_link_gbps(1.0, 0.5) == 0.5
    # declared fleet LAN faster than the uplink: the peer wins
    reg, pools, _ = _mk_registry(uplinks=(1.0, 0.5), lan_gbps=10.0,
                                 slab_bytes=slab)
    pools[0].alloc(0, 3)
    src, t = reg.pick_source(1, 0, 3)
    assert src == 0 and t < reg.cloud_fetch_s(1)
    assert t == pytest.approx(peer_comm_time(slab, 1.0, 0.5, lan_gbps=10.0))
    assert peer_link_gbps(1.0, 0.5, lan_gbps=10.0) == 10.0
    # holders are read live at transfer time: a source that evicted since
    # planning falls back to the cloud path
    pools[0].evict(0, 3)
    src, t = reg.pick_source(1, 0, 3)
    assert src is None and t == pytest.approx(reg.cloud_fetch_s(1))


def test_registry_book_peer_occupies_source_link():
    reg, _, books = _mk_registry(lan_gbps=10.0)
    end = reg.book_peer(0, 1, 2.0, 0.25)
    assert end == pytest.approx(2.25)
    # the SOURCE lane's link carries the booking (the destination books its
    # own link in the engine); counters account the transfer
    assert books[0] == [(2.0, 0.25)] and books[1] == []
    assert reg.peer_fetches == 1 and reg.peer_bytes == 1000
    assert reg.peer_bookings == [(0, 1, 0.25)]


def test_registry_fleet_map_unique_and_dedup_ratio():
    reg, pools, _ = _mk_registry()
    pools[0].alloc(0, 1)
    pools[0].alloc(0, 2)
    pools[1].alloc(0, 1)
    f = np.zeros(8)
    f[1] = 0.9
    reg.note_freq(1, f)
    m = reg.fleet_map()
    assert set(m) == {(0, 1), (0, 2)}
    assert m[(0, 1)]["holders"] == {0: int(pools[0].table[0, 1]),
                                    1: int(pools[1].table[0, 1])}
    assert m[(0, 1)]["freq"] == pytest.approx(0.9)
    assert m[(0, 2)]["holders"] == {0: int(pools[0].table[0, 2])}
    assert reg.holders(0, 1) == [0, 1]
    assert reg.holders(0, 1, exclude=0) == [1]
    assert reg.unique_residents() == 2 and reg.total_residents() == 3
    assert reg.dedup_ratio() == pytest.approx(1.5)


def test_registry_placement_cost_feeds():
    reg, pools, _ = _mk_registry(nl=2, E=8)
    target = np.zeros(8, bool)
    target[:2] = True
    # nothing resident: each missing target expert on each active layer
    # costs one cloud fetch, weighted by the uniform-prior frequency
    f = 1.0 / 8
    assert reg.lane_miss_cost_s(0, [0], target) == pytest.approx(
        2 * f * reg.cloud_fetch_s(0)
    )
    pools[0].alloc(0, 0)
    pools[0].alloc(0, 1)
    assert reg.lane_miss_cost_s(0, [0], target) == 0.0
    # group-folded costs for the eq. 4 admit: the resident group is free
    gc = reg.group_fetch_costs(0, [0], 4)
    assert gc.shape == (4,)
    assert gc[0] == 0.0 and (gc[1:] > 0).all()
    # cloud load: lane 0's traffic for its resident experts drops out of
    # the cloud tier's share; lane 1 (holding nothing) contributes 1/E all
    # over; experts nobody holds carry both lanes' shares
    load = reg.cloud_expert_load()
    assert load[0] == pytest.approx(f)       # lane 1 only
    assert load[2] == pytest.approx(2 * f)   # both lanes miss
    assert load[2] > load[0] > 0


def test_group_priority_cost_breaks_frequency_ties():
    # equal measured traffic everywhere: the placement-cost term must
    # reorder the admit toward the cheapest (already-resident) groups
    gf = np.ones(4) / 4
    cost = np.array([1.0, 0.0, 2.0, 0.0])
    order = group_priority_from_freq(gf, 4, group_cost=cost)
    assert order[:2] == [1, 3] and order[-1] == 2
    # degenerate costs are ignored, never crash the admit
    assert group_priority_from_freq(gf, 4, group_cost=np.zeros(4)) == \
        [0, 1, 2, 3]
    assert group_priority_from_freq(gf, 4, group_cost=np.ones(3)) == \
        [0, 1, 2, 3]


# -------------------------------------- (j) all-False mask engine boundary

def test_all_false_expert_mask_rejected_identically(moe_model):
    model, params = moe_model
    E = model.cfg.moe.num_experts
    empty = np.zeros(E, bool)
    # batch engine (dense gate would renormalize to uniform -- reject)
    with pytest.raises(ValueError, match="selects no experts"):
        ServingEngine(model, params, max_batch=2, max_len=64,
                      expert_mask=empty)
    # tier planner: the one boundary both end-cloud executor families
    # construct through -- pooled and dense reject identically
    with pytest.raises(ValueError, match="selects no experts"):
        plan_tiers(model, end_profile=PROFILES["a100"],
                   cloud_profile=PROFILES["a100"],
                   end_mask=jnp.asarray(empty))
    # shape/length misfits are loud too; None (dense model) passes through
    with pytest.raises(ValueError, match="entries for"):
        validate_expert_mask(np.ones(E + 1, bool), E)
    with pytest.raises(ValueError, match="1-D"):
        validate_expert_mask(np.ones((2, E), bool), E)
    assert validate_expert_mask(None, E) is None
    assert validate_expert_mask(np.ones(E, bool), E).all()


@pytest.mark.parametrize("pooled", [False, True])
def test_degraded_state_deriving_empty_mask_rejected(moe_model, pooled):
    """A device state so weak eq. 4 admits nothing must raise on both the
    pooled and dense stream paths -- identically -- and leave the running
    plan untouched."""
    model, params = moe_model
    dead = DeviceProfile("dead-end", peak_gflops=1e-6, mem_gb=1e-9,
                         mem_bw_gbs=1.0, net_gbps=0.01)
    with pytest.raises(ValueError, match="selects no experts"):
        EndCloudServingEngine(
            model, params, end_profile=dead,
            cloud_profile=PROFILES["a100"], max_batch=2, max_len=64,
            force_split=2, expert_pool=pooled,
        )
    # mid-session: the rejected update leaves the applied mask in place
    eng = EndCloudServingEngine(
        model, params, end_profile=_mask_profile(model.cfg, cap_n=3),
        cloud_profile=PROFILES["a100"], max_batch=2, max_len=64,
        force_split=2, expert_pool=pooled,
    )
    before = np.asarray(eng.tiers.end_mask, bool).copy()
    with pytest.raises(ValueError, match="selects no experts"):
        eng.update_device_state(DeviceState(mem_free=1e-9))
    np.testing.assert_array_equal(
        np.asarray(eng.tiers.end_mask, bool), before
    )


# ------------------------------------------- (k) fleet engine expert store

def _run_fleet(model, params, *, expert_fleet, splits, prompts,
               new_tokens=6, **kw):
    eng = FleetServingEngine(
        model, params,
        end_profiles=[PROFILES["a100"], PROFILES["a100"]],
        cloud_profile=PROFILES["a100"],
        cloud_servers=2, max_batch=2, max_len=64,
        force_splits=splits, expert_fleet=expert_fleet, **kw,
    )
    for i, p in enumerate(prompts):
        eng.submit(Request(i, p, max_new_tokens=new_tokens))
    eng.run()
    return {r.request_id: r.generated for r in eng.finished}, eng


@pytest.mark.parametrize("split", [0, 2, 4])
def test_fleet_registry_token_parity_vs_isolated(moe_model, split):
    """The fleet expert store is a residency/transfer policy, not a model
    change: greedy decode through the registry-attached fleet is
    token-identical to PR 5's isolated per-lane pools at every split."""
    model, params = moe_model
    prompts = _prompts(4, seed=7)
    got, eng = _run_fleet(model, params, expert_fleet=True,
                          splits=[split, split], prompts=prompts,
                          expert_peer_gbps=5.0)
    want, ref = _run_fleet(model, params, expert_fleet=False,
                           splits=[split, split], prompts=prompts)
    assert got == want and len(got) == 4
    assert ref.expert_registry is None
    if split > 0:
        assert eng.expert_registry is not None
        assert eng.expert_registry.n_lanes == 2
        m = eng.metrics()
        assert m["expert_unique_residents"] >= 1
        assert m["expert_fleet_dedup_ratio"] >= 1.0
        assert m["expert_routed_tokens"] > 0
        # identical lanes, identical masks: every resident is replicated
        assert m["expert_resident_slabs"] == \
            2 * m["expert_unique_residents"]


def test_fleet_peer_fetch_books_both_link_timelines(moe_model):
    """A lane's slab miss whose expert a peer holds is served over the
    modeled end<->end link: cheaper than the cloud path, booked on BOTH
    lanes' link resources, and cloud down-bytes strictly below the
    isolated-pools baseline on the same trace."""
    model, params = moe_model
    cfg = model.cfg
    K = cfg.moe.num_groups
    E = cfg.moe.num_experts
    prompts = _prompts(4, seed=11)

    # traffic skew injected as measured routing state: both lanes hot on
    # group 2 (experts 8..11), so lane 1's re-derived mask wants experts
    # lane 0 already fetched -- with route frequency above the 1/E dedup
    # bar, it replicates them, and the transfer source is the peer
    gf = np.zeros(K)
    gf[2] = 1.0
    ef = np.zeros(E)
    ef[2 * (E // K): 3 * (E // K)] = 1.0 / (E // K)

    def drive(expert_fleet):
        eng = FleetServingEngine(
            model, params,
            end_profiles=[PROFILES["a100"], PROFILES["a100"]],
            cloud_profile=PROFILES["a100"],
            cloud_servers=2, max_batch=2, max_len=64,
            force_splits=[2, 2], expert_fleet=expert_fleet,
            expert_peer_gbps=5.0, preemption=False,
        )
        for i, p in enumerate(prompts):
            eng.submit(Request(i, p, max_new_tokens=24))
        for _ in range(2):
            eng.step()
        # lane 0 turns hot first: its mask grows into group 2, slabs come
        # from the cloud (no peer holds them yet)
        eng.lanes[0]._group_freq = gf.copy()
        eng.lanes[0]._route_freq = ef.copy()
        eng.update_device_state(0, DeviceState())
        for _ in range(4):
            eng.step()
        # lane 1 follows: same mask growth, but now lane 0 holds the slabs
        eng.lanes[1]._group_freq = gf.copy()
        eng.lanes[1]._route_freq = ef.copy()
        eng.update_device_state(1, DeviceState())
        eng.run()
        return eng

    fleet = drive(expert_fleet=True)
    iso = drive(expert_fleet=False)
    assert len(fleet.finished) == 4 and len(iso.finished) == 4

    m = fleet.metrics()
    reg = fleet.expert_registry
    assert m["expert_peer_fetches"] >= 1
    assert m["expert_bytes_peer"] == \
        m["expert_peer_fetches"] * fleet.lanes[0]._slab_bytes
    assert reg.peer_fetches == m["expert_peer_fetches"]
    # every peer transfer in this scenario flows lane 0 -> lane 1
    assert reg.peer_bookings and \
        all((src, dst) == (0, 1) for src, dst, _ in reg.peer_bookings)
    # both ends of each transfer ride the fleet timeline: each lane's link
    # busy time is exactly its own boundary/prefill/slab traffic plus the
    # peer seconds it served as a source
    for i, lane in enumerate(fleet.lanes):
        peer_out = sum(s for src, _dst, s in reg.peer_bookings if src == i)
        assert fleet.timeline.busy_s[f"link{i}"] == pytest.approx(
            lane._stage_busy["link"] + lane._prefill_busy["link"]
            + lane.expert_wire_s + peer_out
        )
    assert m["aggregate_tokens_per_s"] > 0
    # the peer-served slabs came off the cloud downlink: strictly fewer
    # cloud bytes than the isolated-pools run of the SAME trace, which
    # fetched every slab from the cloud
    mi = iso.metrics()
    assert mi["expert_peer_fetches"] == 0 and mi["expert_bytes_peer"] == 0
    assert m["expert_bytes_down"] < mi["expert_bytes_down"]
    assert m["expert_bytes_down"] + m["expert_bytes_peer"] == \
        mi["expert_bytes_down"]


def test_fleet_hit_rate_weighted_by_routed_tokens():
    """An idle lane (hit rate 1.0 over zero traffic) must not inflate the
    fleet hit rate: lanes are weighted by their routed-token counts."""
    def lane(hit, tokens):
        return {
            "expert_resident_slabs": 4, "expert_slab_capacity": 8,
            "expert_hit_rate": hit, "expert_bytes_down": 0,
            "expert_bytes_peer": 0, "expert_bytes_up": 0,
            "expert_prefetches": 0, "expert_peer_fetches": 0,
            "expert_evictions": 0, "expert_routed_tokens": tokens,
        }

    fake = SimpleNamespace(expert_registry=None)
    # skewed trace: the busy lane's 0.5 dominates the idle-ish lane's 1.0
    m = FleetServingEngine._expert_fleet_metrics(
        fake, [lane(0.5, 90), lane(1.0, 10)]
    )
    assert m["expert_hit_rate"] == pytest.approx(0.55)
    assert m["expert_hit_rate"] != pytest.approx(0.75)  # unweighted mean
    assert m["expert_routed_tokens"] == 100
    # nothing decoded anywhere yet: fall back to the plain mean
    m = FleetServingEngine._expert_fleet_metrics(
        fake, [lane(0.5, 0), lane(1.0, 0)]
    )
    assert m["expert_hit_rate"] == pytest.approx(0.75)


# ----------------------------- (l) placement + cloud expert shard feeds

def test_place_fleet_expert_cost_steers_placement():
    cfg = SchedulerConfig(alpha=0.5, t_end=1e9)
    caps = [Capability(gflop_budget=1.0, mem_budget_gb=8.0, net_gbps=1.0),
            Capability(gflop_budget=1.0, mem_budget_gb=8.0, net_gbps=1.0)]
    tasks = [Task(i, gflops=1.0, comm_bytes=10.0) for i in range(2)]
    # identical devices, no expert term: load balancing spreads the tasks
    a, _ = place_fleet(tasks, caps, cfg)
    assert sorted(a) == [0, 1]
    # device 0's residency-mismatch surcharge outweighs the load term:
    # both tasks go to the lane whose experts are already in place
    a, _ = place_fleet(tasks, caps, cfg, expert_cost=[10.0, 0.0])
    assert a == [1, 1]
    with pytest.raises(ValueError):
        place_fleet(tasks, caps, cfg, expert_cost=[1.0])


def test_fleet_expert_shards_balance_and_slice():
    load = [5.0, 1.0, 1.0, 1.0, 4.0, 0.0, 0.0, 0.0]
    shards = fleet_expert_shards(load, 2)
    # every expert exactly once, LPT keeps the totals balanced
    assert sorted(e for s in shards for e in s) == list(range(8))
    tot = [sum(load[e] for e in s) for s in shards]
    assert tot[0] == pytest.approx(6.0) and tot[1] == pytest.approx(6.0)
    assert shards == [[0, 2, 5, 6, 7], [1, 3, 4]]
    # deterministic under ties, single server takes everything
    assert fleet_expert_shards(load, 2) == shards
    assert fleet_expert_shards(load, 1) == [list(range(8))]
    with pytest.raises(ValueError):
        fleet_expert_shards(load, 0)
    # slicing dense stacked expert params: each server gets only its rows
    moe_params = {
        "wi": jnp.arange(2 * 8 * 3 * 2, dtype=jnp.float32)
        .reshape(2, 8, 3, 2)
    }
    parts = shard_expert_stacks(moe_params, shards)
    assert parts[0]["wi"].shape == (2, 5, 3, 2)
    assert parts[1]["wi"].shape == (2, 3, 3, 2)
    np.testing.assert_array_equal(
        np.asarray(parts[1]["wi"]),
        np.asarray(moe_params["wi"][:, jnp.asarray([1, 3, 4])]),
    )
