"""Paged expert-weight pool (core.expertpool + core.moe.moe_resident +
the pooled end tier of serving.stream).

Covers the tentpole invariants:
  (a) pool allocator/policy: alloc/evict/capacity accounting, prefetch
      priority by measured route frequency, capacity shrinks never starve
      a layer while the budget allows one resident;
  (b) moe_resident == moe_sorted under the same mask for any resident
      superset of the routed experts (f32);
  (c) greedy token parity dense-vs-pooled through the serving engines at
      splits 0 / mid / R;
  (d) mask shrink+grow at replan safe points: pooled engine stays
      token-identical to the dense engine fed the same state updates, and
      the grow's slab prefetches are booked on the link timeline;
  (e) eviction never corrupts: poisoning evicted slabs changes nothing
      for resident-routed tokens;
  (f) a shrinking memory budget actually sheds experts (evictions), and
      per-step end-tier expert HBM bytes scale with residents (<= 1/2 of
      dense at the 40% selection cap);
  (g) measured group frequencies reorder the eq. 4 greedy admit.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_config
from repro.core import expertpool as ep
from repro.core import moe as moe_mod
from repro.core.hardware import PROFILES, DeviceProfile, DeviceState
from repro.core.selection import group_priority_from_freq, residency_target
from repro.models.model import build_model
from repro.serving.common import Request
from repro.serving.stream import EndCloudServingEngine


@pytest.fixture(scope="module")
def moe_model():
    cfg = smoke_config(get_config("llama4-scout-17b-16e")).replace(
        num_layers=4, dtype="float32", param_dtype="float32"
    )
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _prompts(n, seed=0):
    rng = np.random.default_rng(seed)
    return [
        rng.integers(0, 500, size=int(rng.integers(4, 16))).astype(np.int32)
        for _ in range(n)
    ]


def _run_engine(model, params, *, expert_pool, split, profile=None,
                updates=(), n_req=5, new_tokens=8, **kw):
    """Run a workload, applying ``updates`` = [(after_steps, DeviceState)]
    at fixed step counts; returns (tokens dict, engine)."""
    eng = EndCloudServingEngine(
        model, params,
        end_profile=profile or PROFILES["a100"],
        cloud_profile=PROFILES["a100"],
        max_batch=4, max_len=64, force_split=split,
        expert_pool=expert_pool, **kw,
    )
    reqs = [Request(i, p, max_new_tokens=new_tokens)
            for i, p in enumerate(_prompts(n_req))]
    for r in reqs:
        eng.submit(r)
    pending = sorted(updates, key=lambda u: u[0])
    steps = 0
    while eng.busy():
        while pending and pending[0][0] <= steps:
            eng.update_device_state(pending[0][1])
            pending.pop(0)
        eng.step()
        steps += 1
        assert steps < 10_000
    return {r.request_id: r.generated for r in reqs}, eng


# ----------------------------------------------------------- pool allocator

def test_pool_alloc_evict_invariants():
    pool = ep.ExpertSlabPool(num_slabs=6, n_layers=2, num_experts=8,
                             max_per_layer=3)
    s0 = pool.alloc(0, 2)
    s1 = pool.alloc(1, 2)
    assert s0 != s1 and pool.slabs_in_use == 2
    assert pool.resident_mask(0)[2] and not pool.resident_mask(0)[3]
    with pytest.raises(ValueError):
        pool.alloc(0, 2)  # double alloc
    pool.alloc(0, 0)
    pool.alloc(0, 1)
    with pytest.raises(ValueError):
        pool.alloc(0, 3)  # beyond max_per_layer
    freed = pool.evict(0, 2)
    assert freed == s0 and pool.slabs_in_use == 3
    with pytest.raises(ValueError):
        pool.evict(0, 2)  # double evict
    assert pool.free_layer(1) == [s1]
    assert pool.slabs_in_use == 2
    assert pool.peak_in_use == 4


def test_pool_plan_orders_by_measured_frequency():
    pool = ep.ExpertSlabPool(num_slabs=8, n_layers=2, num_experts=8,
                             max_per_layer=3)
    target = np.zeros(8, bool)
    target[[0, 1, 2]] = True
    freq = np.array([0.1, 0.5, 0.2, 0, 0, 0, 0, 0])
    wanted, evictions = pool.plan([0, 1], target, freq)
    assert evictions == []
    # round-robin by rank so no layer is starved, freq-desc within a rank
    assert wanted == [(0, 1), (1, 1), (0, 2), (1, 2), (0, 0), (1, 0)]


def test_pool_capacity_shrink_keeps_one_resident_per_layer():
    pool = ep.ExpertSlabPool(num_slabs=6, n_layers=2, num_experts=8,
                             max_per_layer=3)
    target = np.zeros(8, bool)
    target[[0, 1, 2]] = True
    for layer in (0, 1):
        for e in (0, 1, 2):
            pool.alloc(layer, e)
    freq = np.array([0.6, 0.3, 0.1, 0, 0, 0, 0, 0])
    pool.set_capacity(3)
    wanted, evictions = pool.plan([0, 1], target, freq)
    assert wanted == [] and len(evictions) == 3
    for layer, e in evictions:
        pool.evict(layer, e)
    # lowest-frequency residents went first, and no layer went to zero
    assert pool.resident_count(0) >= 1 and pool.resident_count(1) >= 1
    assert pool.slabs_in_use == 3
    assert all(not pool.resident_mask(layer)[2] for layer in (0, 1))


def test_pool_plan_evicts_stale_nontarget_for_room():
    pool = ep.ExpertSlabPool(num_slabs=2, n_layers=1, num_experts=8,
                             max_per_layer=2)
    pool.alloc(0, 6)  # non-target leftover from an old mask
    pool.alloc(0, 7)
    target = np.zeros(8, bool)
    target[[0, 1]] = True
    freq = np.zeros(8)
    freq[6] = 0.5  # 6 is still hot, 7 is stale
    wanted, evictions = pool.plan([0], target, freq)
    assert wanted == [(0, 0), (0, 1)]
    # needs both slots eventually; the stale one goes first
    assert evictions[0] == (0, 7)


# ------------------------------------------------------------ moe_resident

def test_moe_resident_matches_sorted_for_any_superset(moe_model):
    model, _ = moe_model
    cfg = model.cfg
    m = cfg.moe
    E = m.num_experts
    params = moe_mod.init_moe(jax.random.PRNGKey(3), cfg)
    x = jax.random.normal(jax.random.PRNGKey(4), (16, cfg.d_model), jnp.float32)
    mask = np.zeros(E, bool)
    mask[[0, 1, 4]] = True
    y_ref, _ = moe_mod.moe_sorted(params, x, cfg, jnp.asarray(mask))

    full = {k: params[k][None] for k in ("wi", "wg", "wo") if k in params}
    for extra in ([], [6], [2, 6]):  # resident supersets of the mask
        S = 5
        pool = ep.ExpertSlabPool(E, n_layers=1, num_experts=E, max_per_layer=S)
        store = ep.init_slab_store(cfg, E)
        asg = []
        for e in sorted([0, 1, 4] + extra):
            asg.append((pool.alloc(0, e), 0, e))
        store = ep.write_slabs(store, full, asg)
        tabs = ep.device_resident_tables(pool, [0], S)
        rp = {
            "gate": params["gate"],
            "resident": {"ids": tabs["ids"][0], "slot": tabs["slot"][0],
                         "store": store},
        }
        y_res, aux = moe_mod.moe_resident(rp, x, cfg, jnp.asarray(mask))
        # ragged_dot group partitions differ (E groups vs S+1 slots), so
        # accumulation order drifts at f32 epsilon; greedy tokens still
        # match exactly (engine parity tests below)
        np.testing.assert_allclose(
            np.asarray(y_res), np.asarray(y_ref), rtol=1e-4, atol=1e-4
        )
        assert np.isfinite(float(aux["aux_loss"]))
    # host-side form of the in-trace effective mask
    resident = np.zeros(E, bool)
    resident[[0, 1, 4, 6]] = True
    np.testing.assert_array_equal(residency_target(mask, resident), mask)


# ------------------------------------------------- engines: token parity

@pytest.mark.parametrize("split", [0, 2, 4])
def test_engine_token_parity_dense_vs_pooled(moe_model, split):
    model, params = moe_model
    dense, _ = _run_engine(model, params, expert_pool=False, split=split)
    pooled, eng = _run_engine(model, params, expert_pool=True, split=split)
    assert dense == pooled
    m = eng.metrics()
    assert m["expert_hit_rate"] == pytest.approx(1.0)
    # the end tier's dense expert stacks are gone (the memory claim)
    for i, spec in enumerate(model.cfg.layer_pattern):
        if spec.moe:
            moe_p = eng.end_params["blocks"][f"pos{i}"]["moe"]
            assert "wi" not in moe_p and "wo" not in moe_p


def _mask_profile(cfg, cap_n, mem_scale=1.0):
    """Profile whose eq. 4 memory term binds the mask at ``cap_n`` experts
    when fully free (the eq. 4 complexity model prices weights in bf16)."""
    wb = (3 if cfg.ffn_gated else 2) * cfg.d_model * cfg.moe.d_ff_expert * 2
    return DeviceProfile(
        "edge-mask", peak_gflops=2000.0,
        mem_gb=(cap_n + 1.2) * wb * mem_scale / 1e9,
        mem_bw_gbs=51.0, net_gbps=0.05,
    )


def test_mask_shrink_grow_parity_and_prefetch_on_timeline(moe_model):
    """(d) the pooled engine applies mask changes (and the grow's slab
    arrivals) at the same safe points as the dense rebuild — greedy tokens
    identical; the grow's prefetch bytes ride the link timeline."""
    model, params = moe_model
    prof = _mask_profile(model.cfg, cap_n=3)
    updates = [(3, DeviceState(mem_free=0.7)), (7, DeviceState(mem_free=1.0))]
    # resident-slot headroom (+ a high prefetch budget) lets the grow's
    # slabs land before the safe point that applies the mask, so the
    # pooled effective mask flips on the exact tick the dense rebuild
    # does; without headroom the pool legitimately lags one safe point
    # (evict stale residents -> transfer -> apply)
    kw = dict(profile=prof, updates=updates, new_tokens=10,
              expert_mem_frac=8.0, expert_prefetch_per_tick=32,
              expert_resident_slots=model.cfg.moe.num_experts)
    dense, deng = _run_engine(model, params, expert_pool=False, split=2, **kw)
    pooled, peng = _run_engine(model, params, expert_pool=True, split=2, **kw)
    # the state updates actually moved the mask both ways
    assert any(ev["mask_changed"] for ev in deng.replan_events)
    assert dense == pooled
    m = peng.metrics()
    assert m["expert_prefetches"] > 0
    assert m["expert_bytes_down"] == (
        m["expert_prefetches"] * peng._slab_bytes
    )
    # prefetch wire time is booked on the shared link resource, on top of
    # the boundary/prefill traffic the engine's own stage meters carry
    link_busy = peng.timeline.busy_s[peng._res_link]
    own = peng._stage_busy["link"] + peng._prefill_busy["link"]
    assert link_busy > own
    assert m["expert_hit_rate"] == pytest.approx(1.0)


def test_memory_shrink_sheds_experts_and_eviction_never_corrupts(moe_model):
    """(e)+(f) halving the memory budget halves the slab capacity: the
    resident set shrinks via evictions at a safe point, and poisoning the
    evicted slabs' storage rows changes no resident-routed token."""
    model, params = moe_model
    cfg = model.cfg
    slab = ep.expert_slab_bytes(cfg)
    # capacity 6 slabs at full memory (= 2 layers x 3 target experts at
    # split 2), 3 slabs at mem_free=0.5
    prof = DeviceProfile(
        "edge-evict", peak_gflops=2000.0, mem_gb=6 * slab / 1e9,
        mem_bw_gbs=51.0, net_gbps=0.05,
    )
    updates = [(4, DeviceState(mem_free=0.5))]

    def run(poison):
        eng = EndCloudServingEngine(
            model, params, end_profile=prof, cloud_profile=PROFILES["a100"],
            max_batch=4, max_len=64, force_split=2,
            expert_pool=True, expert_mem_frac=1.0,
        )
        reqs = [Request(i, p, max_new_tokens=12)
                for i, p in enumerate(_prompts(5))]
        for r in reqs:
            eng.submit(r)
        pending = list(updates)
        steps = 0
        poisoned = False
        while eng.busy():
            while pending and pending[0][0] <= steps:
                eng.update_device_state(pending[0][1])
                pending.pop(0)
            eng.step()
            steps += 1
            if poison and not poisoned and eng.n_expert_evictions > 0:
                # poison every free (= evicted or never-used) slab row: no
                # applied table references them, so nothing may change
                rows = jnp.asarray(list(eng.expert_pool._free))
                for k in eng._slab_store:
                    eng._slab_store[k] = (
                        eng._slab_store[k].at[rows].set(jnp.nan)
                    )
                poisoned = True
            assert steps < 10_000
        if poison:
            assert poisoned, "no eviction happened to poison"
        return {r.request_id: r.generated for r in reqs}, eng

    clean, ceng = run(poison=False)
    assert ceng.n_expert_evictions > 0
    assert ceng.expert_pool.capacity == 3
    assert ceng.expert_pool.slabs_in_use <= 3
    # every active layer kept at least one resident
    for lid in ceng._active_lids():
        assert ceng.expert_pool.resident_count(lid) >= 1
    poisoned_tokens, _ = run(poison=True)
    assert poisoned_tokens == clean
    # all tokens valid (a NaN leak would argmax to 0 consistently; check
    # streams are finished and full length)
    assert all(len(t) == 12 for t in clean.values())


# ------------------------------------------------- metrics / HBM scaling

def test_expert_metrics_and_step_bytes_scale_with_residents(moe_model):
    model, params = moe_model
    _, eng = _run_engine(model, params, expert_pool=True, split=2)
    m = eng.metrics()
    for key in ("expert_resident_slabs", "expert_slab_capacity",
                "expert_hit_rate", "expert_bytes_down", "expert_bytes_up",
                "expert_bytes_resident", "expert_bytes_step_resident",
                "expert_bytes_step_dense", "expert_prefetches",
                "expert_evictions"):
        assert key in m, key
    # 40% selection cap: per-step expert HBM bytes of the resident gather
    # are at most half the dense [E, d, f] sweep (acceptance criterion)
    assert 0 < m["expert_bytes_step_resident"] <= m["expert_bytes_step_dense"] / 2
    E = model.cfg.moe.num_experts
    n_layers = len(eng._active_lids())
    assert m["expert_bytes_step_dense"] == n_layers * E * eng._slab_bytes


# ------------------------------------------- measured group priority (eq. 4)

def test_group_priority_from_freq_orders_greedy_admit(moe_model):
    assert group_priority_from_freq(None, 4) == [0, 1, 2, 3]
    assert group_priority_from_freq(np.array([0.1, 0.4, 0.2, 0.3]), 4) == \
        [1, 3, 2, 0]
    # ties keep natural order; bad shapes fall back to natural order
    assert group_priority_from_freq(np.zeros(4), 4) == [0, 1, 2, 3]
    assert group_priority_from_freq(np.zeros(3), 4) == [0, 1, 2, 3]

    model, params = moe_model
    cfg = model.cfg
    eng = EndCloudServingEngine(
        model, params,
        end_profile=PROFILES["a100"], cloud_profile=PROFILES["a100"],
        max_batch=2, max_len=64, force_split=2, expert_pool=True,
    )
    # measured traffic says group 2 is hottest: the re-derived mask admits
    # its experts before natural-order group 0 fills up
    gf = np.zeros(cfg.moe.num_groups)
    gf[2] = 1.0
    eng._group_freq = gf
    mask = np.asarray(eng._derive_end_mask(DeviceState()))
    Mk = cfg.moe.num_experts // cfg.moe.num_groups
    assert mask[2 * Mk : 2 * Mk + Mk].all()  # group 2 admitted first
    assert mask.sum() == int(0.4 * cfg.moe.num_experts)


def test_route_stats_are_measured_during_decode(moe_model):
    """The engine's frequency EMA comes from the gate's measured stats —
    it is populated by decoding and sums to ~1 over experts."""
    model, params = moe_model
    _, eng = _run_engine(model, params, expert_pool=True, split=2)
    assert eng._route_freq is not None and eng._group_freq is not None
    assert eng._route_freq.shape == (model.cfg.moe.num_experts,)
    assert eng._group_freq.shape == (model.cfg.moe.num_groups,)
    assert eng._route_freq.sum() == pytest.approx(1.0, rel=0.05)
    assert eng._group_freq.sum() == pytest.approx(1.0, rel=0.05)
    # traffic only flows to masked-in (resident) experts
    target = np.asarray(eng.tiers.end_mask, bool)
    assert eng._route_freq[~target].sum() == pytest.approx(0.0, abs=1e-9)


def test_pooled_engine_rejects_nothing_dense_path_accepts(moe_model):
    """Pooled mode is transparent at the API: same submit/validate
    behaviour, expert_pool=False fully restores the dense path."""
    model, params = moe_model
    eng = EndCloudServingEngine(
        model, params,
        end_profile=PROFILES["a100"], cloud_profile=PROFILES["a100"],
        max_batch=2, max_len=64, force_split=2, expert_pool=False,
    )
    assert eng.expert_pool is None
    assert eng.expert_metrics() == {}
    with pytest.raises(ValueError, match="max_len"):
        eng.submit(Request(0, np.arange(60).astype(np.int32),
                           max_new_tokens=8))
