"""Checkpointer atomicity/GC + trainer fault tolerance + optimizers."""

import itertools
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_config, smoke_config
from repro.data.pipeline import DataConfig, batches
from repro.distributed.fault import FailureInjector, StepGuard, StragglerMitigator
from repro.training import optimizer as opt_mod
from repro.training.trainer import Trainer, TrainerConfig


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    state = {"a": jnp.arange(6.0).reshape(2, 3), "b": {"c": jnp.ones(4)}}
    ck.save(7, state, {"note": "x"})
    step, got = ck.restore(jax.tree.map(np.zeros_like, state))
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ck.metadata()["note"] == "x"


def test_checkpoint_gc_and_tmp_ignored(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, {"x": jnp.ones(2) * s})
    assert ck.all_steps() == [3, 4]
    os.makedirs(str(tmp_path / "step_00000099.tmp"))  # crashed write
    assert ck.latest_step() == 4


def test_async_checkpoint(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.async_save(5, {"x": jnp.ones(3)})
    ck.wait()
    assert ck.latest_step() == 5


def test_restore_shape_mismatch_raises(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"x": jnp.ones((2, 2))})
    with pytest.raises(ValueError):
        ck.restore({"x": jnp.ones((3, 3))})


# ------------------------------------------------------------------ trainer

def _data(seq=32, batch=8):
    dcfg = DataConfig(task="lm", vocab_size=512, seq_len=seq)
    return itertools.cycle(batches(dcfg, batch, 40))


def test_trainer_failure_recovery(tmp_path):
    cfg = smoke_config(get_config("tinyllama-1.1b")).replace(num_layers=1)
    tr = Trainer(
        cfg, _data(),
        trainer_cfg=TrainerConfig(total_steps=12, checkpoint_every=4,
                                  checkpoint_dir=str(tmp_path), log_every=4,
                                  async_checkpoint=False),
        failure_injector=FailureInjector(fail_steps=(6,)),
    ).initialize()
    out = tr.run()
    assert out["final_step"] == 12
    assert out["restores"] == 1
    assert all(np.isfinite(m["loss"]) for m in out["log"])


def test_trainer_resume(tmp_path):
    cfg = smoke_config(get_config("tinyllama-1.1b")).replace(num_layers=1)
    tc = TrainerConfig(total_steps=6, checkpoint_every=3,
                       checkpoint_dir=str(tmp_path), async_checkpoint=False)
    Trainer(cfg, _data(), trainer_cfg=tc).initialize().run()
    tr2 = Trainer(cfg, _data(), trainer_cfg=TrainerConfig(
        total_steps=9, checkpoint_every=3, checkpoint_dir=str(tmp_path),
        async_checkpoint=False)).initialize()
    assert tr2.step == 6  # resumed, not restarted
    assert tr2.run()["final_step"] == 9


def test_step_guard_limits():
    g = StepGuard(consecutive_bad_limit=2)
    assert g.check(1.0)
    assert not g.check(float("nan"))
    assert not g.check(float("inf"))
    with pytest.raises(RuntimeError):
        g.check(float("nan"))


def test_straggler_watchdog():
    s = StragglerMitigator(window=10, threshold=2.0)
    for i in range(8):
        assert s.record(i, 0.1) is None
    assert s.record(8, 0.5) == "reshard_recommended"
    assert 8 in s.flagged


# ---------------------------------------------------------------- optimizers

@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_optimizer_reduces_quadratic(name):
    params = {"w": jnp.asarray([3.0, -2.0, 1.5]).reshape(1, 3) * jnp.ones((8, 3))}
    state = opt_mod.init_optimizer(name, params)
    cfg = opt_mod.OptimizerConfig(name=name, lr=0.1, warmup_steps=1,
                                  decay_steps=200, weight_decay=0.0)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    l0 = float(loss(params))
    for _ in range(50):
        grads = jax.grad(loss)(params)
        params, state, _ = opt_mod.apply_optimizer(name, cfg, grads, state, params)
    assert float(loss(params)) < 0.05 * l0


def test_adafactor_state_is_factored():
    params = {"w": jnp.ones((32, 16)), "b": jnp.ones((16,))}
    st = opt_mod.init_optimizer("adafactor", params)
    assert set(st["stats"]["w"].keys()) == {"vr", "vc"}
    assert st["stats"]["w"]["vr"].shape == (32,)
    assert st["stats"]["w"]["vc"].shape == (16,)
    assert set(st["stats"]["b"].keys()) == {"v"}


def test_grad_clip():
    g = {"a": jnp.ones(4) * 10.0}
    clipped, norm = opt_mod.clip_by_global_norm(g, 1.0)
    assert abs(float(opt_mod.global_norm(clipped)) - 1.0) < 1e-5
    assert abs(float(norm) - 20.0) < 1e-4
