"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig
from repro.core.gating import group_gate_probs as core_gate_probs, init_group_gate

from repro.kernels.group_gate.ops import group_gate_probs as kernel_gate
from repro.kernels.group_gate.ref import group_gate_ref
from repro.kernels.lowrank.ops import lowrank_decode, lowrank_encode, lowrank_roundtrip
from repro.kernels.lowrank.ref import roundtrip_ref
from repro.kernels.expert_mlp.ops import expert_mlp
from repro.kernels.expert_mlp.ref import expert_mlp_ref, expert_mlp_resident_ref
from repro.kernels.flash_attention.ops import flash_attention_fwd
from repro.models.attention import reference_attention


# ---------------------------------------------------------------------- gate

@pytest.mark.parametrize("d,E,K,T", [(32, 8, 4, 64), (64, 16, 4, 32),
                                     (128, 32, 8, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_group_gate_kernel_sweep(d, E, K, T, dtype):
    mcfg = MoEConfig(num_experts=E, top_k=1, d_ff_expert=8, num_groups=K)
    params = init_group_gate(jax.random.PRNGKey(0), d, mcfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (T, d)).astype(dtype)
    probs_k, pg_k = kernel_gate(params, x, num_groups=K)
    probs_c, pg_c, _ = core_gate_probs(params, x.astype(jnp.float32), mcfg)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(probs_k), np.asarray(probs_c),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(pg_k), np.asarray(pg_c),
                               rtol=tol, atol=tol)


def test_group_gate_kernel_masked():
    d, E, K = 32, 8, 4
    mcfg = MoEConfig(num_experts=E, top_k=1, d_ff_expert=8, num_groups=K)
    params = init_group_gate(jax.random.PRNGKey(0), d, mcfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (16, d))
    mask = jnp.asarray([True, False] * 4)
    probs_k, _ = kernel_gate(params, x, num_groups=K, expert_mask=mask)
    assert float(np.asarray(probs_k)[:, ~np.asarray(mask)].max()) < 1e-12
    np.testing.assert_allclose(np.asarray(probs_k).sum(-1), 1.0, rtol=1e-5)


# ------------------------------------------------------------------- lowrank

@pytest.mark.parametrize("T,d,r", [(64, 32, 8), (128, 64, 64), (32, 128, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lowrank_kernels_sweep(T, d, r, dtype):
    import repro.core.compression as comp

    p = comp.init_lowrank_1d(jax.random.PRNGKey(0), d, r)
    x = jax.random.normal(jax.random.PRNGKey(1), (T, d)).astype(dtype)
    enc, dec = p["enc"].astype(dtype), p["dec"].astype(dtype)
    z = lowrank_encode(x, enc)
    np.testing.assert_allclose(
        np.asarray(z, np.float32), np.asarray(x @ enc, np.float32),
        rtol=3e-2 if dtype == jnp.bfloat16 else 1e-5, atol=1e-2,
    )
    xh_k, err_k = lowrank_roundtrip(x, enc, dec)
    xh_r, err_r = roundtrip_ref(x, enc, dec)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(xh_k, np.float32),
                               np.asarray(xh_r, np.float32), rtol=tol, atol=tol)
    np.testing.assert_allclose(float(err_k), float(err_r), rtol=1e-2 + tol)


# ---------------------------------------------------------------- expert_mlp

@pytest.mark.parametrize("E,C,d,f", [(4, 32, 64, 128), (8, 64, 32, 64),
                                     (2, 16, 128, 512)])
@pytest.mark.parametrize("gated", [True, False])
def test_expert_mlp_kernel_sweep(E, C, d, f, gated):
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(ks[0], (E, C, d), jnp.float32)
    wi = jax.random.normal(ks[1], (E, d, f)) * 0.05
    wg = jax.random.normal(ks[2], (E, d, f)) * 0.05 if gated else None
    wo = jax.random.normal(ks[3], (E, f, d)) * 0.05
    y_k = expert_mlp(x, wi, wg, wo)
    y_r = expert_mlp_ref(x, wi, wg, wo)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("S,N,C,d,f", [(3, 9, 32, 64, 128), (1, 4, 16, 32, 64),
                                       (4, 4, 64, 128, 512)])
@pytest.mark.parametrize("gated", [True, False])
def test_expert_mlp_resident_sweep(S, N, C, d, f, gated):
    """Resident-index operand (paged expert-weight pool): the grid runs
    over resident slots, the scalar-prefetched ids pick slab rows out of
    the store — including repeated rows (two slots may alias the garbage
    slab) and out-of-natural-order ids."""
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    x = jax.random.normal(ks[0], (S, C, d), jnp.float32)
    wi = jax.random.normal(ks[1], (N, d, f)) * 0.05
    wg = jax.random.normal(ks[2], (N, d, f)) * 0.05 if gated else None
    wo = jax.random.normal(ks[3], (N, f, d)) * 0.05
    ids = jax.random.permutation(ks[4], N)[:S].astype(jnp.int32)
    if S > 1:
        ids = ids.at[S - 1].set(ids[0])  # aliased row
    y_k = expert_mlp(x, wi, wg, wo, resident_ids=ids)
    y_r = expert_mlp_resident_ref(x, wi, wg, wo, ids)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=1e-4, atol=1e-4)


def test_expert_mlp_bf16():
    E, C, d, f = 2, 16, 32, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(ks[0], (E, C, d)).astype(jnp.bfloat16)
    wi = (jax.random.normal(ks[1], (E, d, f)) * 0.05).astype(jnp.bfloat16)
    wg = (jax.random.normal(ks[2], (E, d, f)) * 0.05).astype(jnp.bfloat16)
    wo = (jax.random.normal(ks[3], (E, f, d)) * 0.05).astype(jnp.bfloat16)
    y_k = expert_mlp(x, wi, wg, wo)
    y_r = expert_mlp_ref(x, wi, wg, wo)
    np.testing.assert_allclose(np.asarray(y_k, np.float32),
                               np.asarray(y_r, np.float32),
                               rtol=3e-2, atol=3e-2)


# ----------------------------------------------------------- flash attention

@pytest.mark.parametrize("causal,window", [(True, None), (True, 48),
                                           (False, None)])
@pytest.mark.parametrize("H,KV,S", [(4, 4, 128), (8, 2, 256)])
def test_flash_kernel_sweep(causal, window, H, KV, S):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (2, S, H, 32), jnp.float32)
    k = jax.random.normal(ks[1], (2, S, KV, 32), jnp.float32)
    v = jax.random.normal(ks[2], (2, S, KV, 32), jnp.float32)
    o_k = flash_attention_fwd(q, k, v, causal=causal, window=window,
                              block_q=64, block_kv=64)
    o_r = reference_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                               rtol=2e-5, atol=2e-5)


def test_flash_kernel_bf16():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 128, 4, 32)).astype(jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 128, 2, 32)).astype(jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 128, 2, 32)).astype(jnp.bfloat16)
    o_k = flash_attention_fwd(q, k, v, block_q=64, block_kv=64)
    o_r = reference_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(o_k, np.float32),
                               np.asarray(o_r, np.float32),
                               rtol=3e-2, atol=3e-2)
