"""Shared test fixtures.  NOTE: no XLA_FLAGS here — unit tests run on the
single real CPU device; multi-device tests spawn subprocesses that set
--xla_force_host_platform_device_count themselves."""

import os
import sys

import jax
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
