"""PO-ECC low-rank codec properties (paper eq. 8)."""

import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep: deterministic fallback grid
    from _hypothesis_compat import given, settings, st

from repro.core import compression as comp


@settings(max_examples=15, deadline=None)
@given(d=st.sampled_from([16, 64]), seed=st.integers(0, 10))
def test_full_rank_orthonormal_roundtrip_identity(d, seed):
    params = comp.init_lowrank_1d(jax.random.PRNGKey(seed), d, d)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (32, d))
    np.testing.assert_allclose(
        np.asarray(comp.roundtrip_1d(params, x)), np.asarray(x),
        rtol=1e-4, atol=1e-5,
    )


def test_error_monotone_in_rank():
    d = 64
    x = jax.random.normal(jax.random.PRNGKey(1), (128, d))
    errs = []
    for r in (4, 16, 32, 64):
        p = comp.init_lowrank_1d(jax.random.PRNGKey(0), d, r)
        errs.append(float(comp.recon_loss(x, comp.roundtrip_1d(p, x))))
    assert errs == sorted(errs, reverse=True)
    assert errs[-1] < 1e-8


def test_2d_faithful_form():
    """Z = U^T X V; X_hat = U_hat Z V_hat^T (eq. 8 verbatim)."""
    h, w, c, r = 16, 12, 3, 12
    params = comp.init_lowrank_2d(jax.random.PRNGKey(0), h, w, r)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, h, w, c))
    z = comp.encode_2d(params, x)
    assert z.shape == (2, r, r, c)
    x_hat = comp.decode_2d(params, z)
    assert x_hat.shape == x.shape
    # r == w implies V is square-orthonormal; error bounded by U truncation
    err = comp.recon_loss(x, x_hat)
    assert float(err) < float(comp.recon_loss(x, jnp.zeros_like(x)))


def test_joint_loss_combines():
    x = jnp.ones((4, 8))
    x_hat = jnp.zeros((4, 8))
    task = jnp.asarray(2.0)
    total = comp.joint_loss(x, x_hat, task, recon_weight=1.0, task_weight=0.5)
    np.testing.assert_allclose(float(total), 1.0 + 1.0, rtol=1e-6)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10))
def test_int8_codec_error_bound(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (16, 32))
    q, scale = comp.quantize_int8(x)
    x_hat = comp.dequantize_int8(q, scale, jnp.float32)
    # max error is half an LSB = scale/2 per element
    err = np.abs(np.asarray(x) - np.asarray(x_hat))
    bound = np.asarray(scale) * 0.5 + 1e-6
    assert (err <= bound + 1e-5).all()


def test_compression_ratio_model():
    assert comp.compression_ratio(4096, 256, codec="lowrank") == 256 / 4096
    assert comp.compression_ratio(4096, 0, codec="int8") == 0.5
    assert comp.compression_ratio(4096, 0, codec="none") == 1.0
