"""Serving engine (continuous batching) + end-cloud pipeline."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import CompressionConfig, get_config, smoke_config
from repro.core.hardware import PROFILES, DeviceState
from repro.models.model import build_model
from repro.serving.endcloud import EndCloudPipeline, split_block_params
from repro.serving.engine import Request, ServingEngine


@pytest.fixture(scope="module")
def tiny_model():
    cfg = smoke_config(get_config("tinyllama-1.1b")).replace(num_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def test_engine_completes_all_requests(tiny_model):
    model, params = tiny_model
    eng = ServingEngine(model, params, max_batch=4, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [
        Request(i, rng.integers(0, 500, size=rng.integers(4, 16)).astype(np.int32),
                max_new_tokens=6)
        for i in range(9)
    ]
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert len(done) == 9
    for r in done:
        assert len(r.generated) == 6
        assert r.finish_time >= r.submit_time


def test_engine_matches_sequential_decode(tiny_model):
    """Tokens from the batched engine == tokens from naive prefill+decode."""
    model, params = tiny_model
    prompt = np.arange(10, 22).astype(np.int32)

    lg, cache = model.prefill(params, {"tokens": jnp.asarray(prompt)[None]},
                              max_len=64)
    want = [int(jnp.argmax(lg[0]))]
    for _ in range(4):
        tok = jnp.asarray([[want[-1]]], jnp.int32)
        lg2, cache = model.decode_step(params, tok, cache)
        want.append(int(jnp.argmax(lg2[0])))

    eng = ServingEngine(model, params, max_batch=3, max_len=64)
    req = Request(0, prompt, max_new_tokens=5)
    eng.submit(req)
    # distractor requests sharing the batch
    eng.submit(Request(1, (prompt * 3) % 500, max_new_tokens=5))
    eng.run()
    assert req.generated == want


def test_prefill_finish_frees_slot_same_pass(tiny_model):
    """Regression: a request finishing at its prefill token (here
    max_new_tokens=1) leaves the slot free for the NEXT waiting request in
    the same admission pass — skipping ahead idles the slot a full engine
    tick per short request."""
    model, params = tiny_model
    eng = ServingEngine(model, params, max_batch=1, max_len=64)
    short = Request(0, np.arange(5).astype(np.int32), max_new_tokens=1)
    nxt = Request(1, np.arange(6, 14).astype(np.int32), max_new_tokens=4)
    eng.submit(short)
    eng.submit(nxt)
    eng._admit()  # one admission pass over the single slot
    assert short.done and short in eng.finished
    assert eng.slots[0] is nxt, "freed slot must be offered to the next waiter"
    assert not eng.waiting
    eng.run()
    assert len(nxt.generated) == 4


def test_rejects_overlong_request(tiny_model):
    """Regression: prompt + max_new_tokens beyond max_len used to wrap the
    KV ring buffer silently; submit must fail loudly instead."""
    model, params = tiny_model
    eng = ServingEngine(model, params, max_batch=2, max_len=32)
    with pytest.raises(ValueError, match="max_len"):
        eng.submit(Request(0, np.arange(30).astype(np.int32), max_new_tokens=8))
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(Request(1, np.zeros(0, np.int32), max_new_tokens=8))
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.submit(Request(3, np.arange(4).astype(np.int32), max_new_tokens=0))
    assert not eng.waiting
    # exactly at the bound is admissible
    eng.submit(Request(2, np.arange(16).astype(np.int32), max_new_tokens=16))
    done = eng.run()
    assert len(done) == 1 and len(done[0].generated) == 16


def test_never_reservable_request_fails_loudly(tiny_model):
    """A request whose worst-case page count exceeds the whole pool can
    never be admitted; parking it at the head of the queue would starve
    everything behind it, so submit must reject it."""
    model, params = tiny_model
    eng = ServingEngine(model, params, max_batch=2, max_len=64,
                        page_size=16, kv_pages=2)
    assert eng.paged
    with pytest.raises(ValueError, match="never"):
        eng.submit(Request(0, np.arange(40).astype(np.int32),
                           max_new_tokens=8))
    assert not eng.waiting


@pytest.mark.parametrize("admission", ["priority", "fifo"])
def test_priority_head_does_not_starve_interactive(tiny_model, admission):
    """A page-hungry low-priority request at the head of the FIFO queue
    blocks everyone behind it in ``fifo`` mode; ``priority`` admission
    sorts the interactive request ahead of the blocked head and admits it
    into the free slot.  Both modes eventually finish everything."""
    model, params = tiny_model
    probe = ServingEngine(model, params, max_batch=2, max_len=64,
                          page_size=16)
    running = Request(0, np.arange(24).astype(np.int32), max_new_tokens=8,
                      priority=0)
    hungry = Request(1, np.arange(40).astype(np.int32), max_new_tokens=8,
                     priority=2)
    small = Request(2, np.arange(6).astype(np.int32), max_new_tokens=8,
                    priority=0)
    need = {r.request_id: probe._pages_for(r) for r in (running, hungry, small)}
    # pool sized so: running fits, hungry does NOT fit beside it, small does
    kv_pages = need[0] + need[1] - 1
    assert kv_pages >= need[0] + need[2]
    eng = ServingEngine(model, params, max_batch=2, max_len=64,
                        page_size=16, kv_pages=kv_pages,
                        admission=admission)
    eng.submit(running)
    eng.step()
    assert eng.slots[0] is running
    eng.submit(hungry)
    eng.step()
    assert hungry in eng.waiting, "hungry head must wait for pages"
    eng.submit(small)
    eng.step()
    if admission == "priority":
        # interactive jumps the page-blocked low-priority head
        assert small not in eng.waiting
        assert eng.slots[1] is small
        assert hungry in eng.waiting
    else:
        # pure FIFO: the blocked head blocks the whole queue
        assert small in eng.waiting
        assert eng.slots[1] is None
    done = eng.run()
    assert len(done) == 3
    assert eng.pool.pages_in_use == 0


def test_eos_terminates(tiny_model):
    model, params = tiny_model
    eng = ServingEngine(model, params, max_batch=2, max_len=64)
    prompt = np.arange(5).astype(np.int32)
    lg, _ = model.prefill(params, {"tokens": jnp.asarray(prompt)[None]},
                          max_len=64)
    first = int(jnp.argmax(lg[0]))
    req = Request(0, prompt, max_new_tokens=50, eos_id=first)
    eng.submit(req)
    eng.run()
    assert len(req.generated) == 1 and req.generated[0] == first


def test_split_block_params():
    cfg = smoke_config(get_config("tinyllama-1.1b")).replace(num_layers=4)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    end, cloud = split_block_params(params, 1)
    leaf_e = jax.tree.leaves(end["blocks"])[0]
    leaf_c = jax.tree.leaves(cloud["blocks"])[0]
    assert leaf_e.shape[0] == 1 and leaf_c.shape[0] == 3
    assert "lm_head" in cloud and "embed" in end


@pytest.mark.parametrize("rank", [0, 32])
def test_endcloud_pipeline_runs(rank):
    cfg = smoke_config(get_config("llama4-scout-17b-16e")).replace(num_layers=4)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # a strong end tier entices the planner into an interior split, which is
    # where boundary compression applies (with a weak end it correctly picks
    # split=0 = all-cloud = nothing to compress)
    pipe = EndCloudPipeline(
        model, params,
        end_profile=PROFILES["a100"],
        cloud_profile=PROFILES["a100"],
        compression_rank=rank,
    )
    tokens = jnp.arange(2 * 32, dtype=jnp.int32).reshape(2, 32) % 500
    logits, m = pipe.run_batch(tokens)
    assert logits.shape == (2, 32, cfg.padded_vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert 0 <= m["split"] <= cfg.block_repeat
    # invariant: compression happens iff an interior boundary exists + rank>0
    interior = 0 < m["split"] < cfg.block_repeat
    assert m["compressed"] == bool(rank and interior)
    if m["compressed"]:
        assert m["boundary_bytes"] < 2 * 32 * cfg.d_model * 4
    # end tier must never route to experts outside its hardware mask
    if pipe.end_mask is not None:
        assert int(pipe.end_mask.sum()) <= int(
            cfg.moe.local_selection_cap * cfg.moe.num_experts
        )


def test_endcloud_full_rank_matches_single_tier():
    """With split s and an orthonormal full-rank codec, the two-tier pipeline
    must reproduce the single-tier forward (mask off: plenty capability)."""
    cfg = smoke_config(get_config("tinyllama-1.1b")).replace(num_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    pipe = EndCloudPipeline(
        model, params,
        end_profile=PROFILES["a100"],  # strong end -> no expert masking
        cloud_profile=PROFILES["a100"],
        compression_rank=cfg.d_model,
    )
    tokens = jnp.arange(2 * 16, dtype=jnp.int32).reshape(2, 16) % 500
    logits, _ = pipe.run_batch(tokens)
    want, _ = model.train_logits(params, {"tokens": tokens}, train=False)
    np.testing.assert_allclose(np.asarray(logits, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=5e-1)
