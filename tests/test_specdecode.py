"""Speculative multi-token decode across the end-cloud link.

Covers the tentpole contracts:
  (a) the greedy accept rule: a C-position chunk consumes C-1 drafts, row
      0's verify id always commits, the first rejection emits the
      corrected token (exact-parity-by-construction);
  (b) plan_spec_k: k > 1 only when amortizing the round trip wins (RTT-
      dominated), auto-disable (k = 1) in the compute- or wire-bound
      regimes and under the min-gain gate;
  (c) SpecState: acceptance EMA adapts k_eff within the plan budget,
      floored at 2 while the plan allows speculation;
  (d) rollback_entries: committed positions' pages survive, the rest
      unmap (ring arithmetic mirrors map_tokens);
  (e) engine greedy parity at splits 0 / mid / R with speculation on
      (dense draft == exact → acceptance 1.0, no rollbacks);
  (f) the masked-MoE rejection path: pooled drafts diverge, rollbacks
      fire, parity still holds and every page drains;
  (g) compute-bound auto-disable: zero spec rounds, step count identical
      to the plain engine;
  (h) host-sync batching regression: one device->host transfer per tick
      (not per group / per prefill job), trace counts still bounded.
"""

import jax
import numpy as np
import pytest

from repro.configs import get_config, smoke_config
from repro.core.hardware import Capability, PROFILES
from repro.core.pipeline import plan_spec_k
from repro.models.model import build_model
from repro.serving.common import Request, VirtualClock
from repro.serving.specdecode import (
    SpecState,
    accept_greedy,
    batched_accept,
    min_pow2_le,
    rollback_entries,
)
from repro.serving.stream import EndCloudServingEngine

END_SIM = dict(peak_gflops=2.0, mem_gb=8.0, mem_bw_gbs=50.0, net_gbps=2.0)


@pytest.fixture(scope="module")
def tiny_model_f32():
    cfg = (
        smoke_config(get_config("tinyllama-1.1b"))
        .replace(num_layers=4, dtype="float32")
    )
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


@pytest.fixture(scope="module")
def moe_model_f32():
    cfg = smoke_config(get_config("llama4-scout-17b-16e")).replace(
        num_layers=4, dtype="float32", param_dtype="float32"
    )
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _prompts(n, seed=0, lo=4, hi=16):
    rng = np.random.default_rng(seed)
    return [
        rng.integers(0, 500, size=int(rng.integers(lo, hi))).astype(np.int32)
        for _ in range(n)
    ]


def _drive(model, params, *, spec_k, link_rtt_s, n_req=4, new_tokens=6,
           **kw):
    eng = EndCloudServingEngine(
        model, params,
        end_profile=PROFILES["a100"], cloud_profile=PROFILES["a100"],
        max_batch=4, max_len=64, prefill_chunk=8,
        timing="modeled", clock=VirtualClock(),
        spec_k=spec_k, link_rtt_s=link_rtt_s, **kw,
    )
    reqs = [Request(i, p, max_new_tokens=new_tokens)
            for i, p in enumerate(_prompts(n_req))]
    for r in reqs:
        eng.submit(r)
    eng.run()
    return {r.request_id: r.generated for r in reqs}, eng


# ------------------------------------------------------- (a) accept rule


def test_accept_greedy_full_accept():
    committed, rej = accept_greedy([7, 8, 9], [7, 8, 9, 3])
    assert committed == [7, 8, 9, 3] and rej == 0


def test_accept_greedy_first_rejection_emits_corrected_token():
    # draft y_1=7 matched v_0, y_2=4 != v_1=8: commit v_0, v_1 — the
    # verify argmax at the divergence IS the corrected token
    committed, rej = accept_greedy([7, 4, 9], [7, 8, 5, 3])
    assert committed == [7, 8] and rej == 2


def test_accept_greedy_zero_acceptance_still_progresses():
    committed, rej = accept_greedy([9, 9, 9], [1, 2, 3, 4])
    assert committed == [1] and rej == 3


def test_accept_greedy_length_contract():
    # a C-position chunk consumed [x_0, y_1..y_{C-1}]: exactly C-1 drafts
    with pytest.raises(ValueError, match="mismatch"):
        accept_greedy([1, 2, 3], [1, 2, 3])


def test_batched_accept_respects_n_valid():
    drafts = np.array([[5, 6, 7], [1, 9, 9]])
    verify = np.array([[5, 6, 7, 8], [1, 2, 3, 4]])
    committed, rej = batched_accept(
        drafts, verify, np.array([4, 2, 0])[:2]
    )
    assert committed[0] == [5, 6, 7, 8] and rej[0] == 0
    # row 1 only verified 2 positions: one draft participates, it matched
    assert committed[1] == [1, 2] and rej[1] == 0
    committed, _ = batched_accept(drafts[:1], verify[:1], np.array([0]))
    assert committed[0] == []  # inactive row commits nothing


# ---------------------------------------------------- (b) plan-time choice


def _caps(end_gbps=2.0):
    return (
        Capability(5.0, 4.0, end_gbps),
        Capability(50.0, 64.0, 10.0),
    )


def test_plan_spec_k_rtt_bound_enables():
    end, cloud = _caps(1.0)
    k = plan_spec_k([1.0] * 4, 32768, end, cloud, split=2,
                    link_rtt_s=0.05, k_max=8)
    assert k > 1


def test_plan_spec_k_compute_bound_disables():
    end, cloud = _caps(100.0)
    k = plan_spec_k([1.0] * 4, 32768, end, cloud, split=2,
                    link_rtt_s=0.0, k_max=8)
    assert k == 1


def test_plan_spec_k_wire_bound_disables():
    # wire time scales with k, so a fat payload over a thin pipe gains
    # nothing from speculation even at high RTT
    end, cloud = _caps(0.05)
    k = plan_spec_k([1.0] * 4, 10_000_000, end, cloud, split=2,
                    link_rtt_s=0.05, k_max=8)
    assert k == 1


def test_plan_spec_k_respects_k_max_and_validates():
    end, cloud = _caps(1.0)
    k = plan_spec_k([1.0] * 4, 32768, end, cloud, split=2,
                    link_rtt_s=0.5, k_max=4)
    assert 1 < k <= 4
    with pytest.raises(ValueError):
        plan_spec_k([1.0] * 4, 1.0, end, cloud, split=5)


# --------------------------------------------------- (c) acceptance EMA


def test_spec_state_adapts_within_budget():
    st = SpecState(8)
    assert st.k_eff == 8
    for _ in range(6):
        st.observe_round(7, 0, rolled_back=True)
    assert st.k_eff == 2  # halves on low acceptance, floored at 2
    for _ in range(12):
        st.observe_round(7, 7, rolled_back=False)
    assert st.k_eff == 8  # doubles back up to the plan budget
    assert st.metrics()["spec_rollbacks"] == 6
    assert min_pow2_le(6) == 4 and min_pow2_le(8) == 8


def test_spec_state_disabled_plan():
    st = SpecState(1)
    assert st.k_eff == 1
    st.observe_round(0, 0, rolled_back=False)
    assert st.k_eff == 1 and st.acceptance is None


# ------------------------------------------------- (d) rollback arithmetic


def test_rollback_entries_keeps_committed_pages():
    # page_size 4, base 6: positions 6..9 span entries 1 and 2; committing
    # 2 tokens (6,7) keeps entry 1, rolls entry 2 back
    new = [1, 2]
    assert rollback_entries(new, base_len=6, n_commit=2,
                           page_size=4, pages_per_slot=4) == [2]
    assert rollback_entries(new, base_len=6, n_commit=4,
                           page_size=4, pages_per_slot=4) == []
    assert rollback_entries(new, base_len=6, n_commit=0,
                           page_size=4, pages_per_slot=4) == [1, 2]
    # page-aligned base: commit 1 keeps exactly its own fresh page
    assert rollback_entries([0, 1], base_len=8, n_commit=1,
                           page_size=4, pages_per_slot=2) == [1]


# ------------------------------------- (e) greedy parity with speculation


@pytest.mark.parametrize("split", [0, 2, 4])
def test_spec_parity_at_splits(tiny_model_f32, split):
    model, params = tiny_model_f32
    want, _ = _drive(model, params, spec_k=1, link_rtt_s=0.05,
                     force_split=split)
    got, eng = _drive(model, params, spec_k=4, link_rtt_s=0.05,
                      force_split=split)
    assert got == want
    m = eng.metrics()
    assert m["spec_rounds"] > 0, m
    # dense model: the draft IS the model, so every draft verifies
    assert m["spec_acceptance_rate"] == 1.0 and m["spec_rollbacks"] == 0
    assert eng.end_pool.pages_in_use == 0
    assert eng.cloud_pool.pages_in_use == 0
    assert eng.end_pool.pages_reserved == 0


# --------------------------------------------- (f) masked-MoE rejections


def test_spec_moe_rejection_path_keeps_parity(moe_model_f32):
    model, params = moe_model_f32
    want, _ = _drive(model, params, spec_k=1, link_rtt_s=0.05,
                     force_split=2, expert_pool=True)
    got, eng = _drive(model, params, spec_k=4, link_rtt_s=0.05,
                      force_split=2, expert_pool=True)
    assert got == want
    m = eng.metrics()
    assert m["spec_rounds"] > 0
    # the end-mask draft diverges from the full router: rejections MUST
    # occur, and the rollback-and-correct rule keeps parity exact
    assert m["spec_rollbacks"] > 0, m
    assert m["spec_acceptance_rate"] < 1.0
    assert eng.end_pool.pages_in_use == 0
    assert eng.cloud_pool.pages_in_use == 0
    assert eng.end_pool.pages_reserved == 0
    assert eng.cloud_pool.pages_reserved == 0


# ----------------------------------------------- (g) compute-bound disable


def test_spec_auto_disables_compute_bound(tiny_model_f32):
    model, params = tiny_model_f32
    want, ref = _drive(model, params, spec_k=1, link_rtt_s=0.0,
                       force_split=2)
    got, eng = _drive(model, params, spec_k=8, link_rtt_s=0.0,
                      force_split=2)
    m = eng.metrics()
    assert m["spec_plan_k"] == 1 and m["spec_rounds"] == 0
    assert got == want
    # zero overhead: the engine takes exactly the plain engine's steps
    assert m["n_stage_steps"] == ref.metrics()["n_stage_steps"]


# ------------------------------------------- (h) host-sync batching


def test_host_syncs_batched_per_tick(tiny_model_f32):
    model, params = tiny_model_f32
    toks, eng = _drive(model, params, spec_k=1, link_rtt_s=0.0,
                       force_split=2, n_req=6, new_tokens=8)
    tokens = sum(len(t) for t in toks.values())
    m = eng.metrics()
    # one batched device->host transfer per tick with drained boundaries
    # (plus one per prefill-resolution tick) — far fewer than the per-
    # token / per-group pulls the un-batched path paid
    assert 0 < m["n_host_syncs"] < tokens, m["n_host_syncs"]
    # regression: the in-jit argmax did not add trace churn — stage trace
    # counts stay bounded by chunk/group shapes
    traces = eng.stage_trace_counts()
    assert traces["cloud_step"] == 1 and traces["cloud_prefill_chunk"] == 1
    assert all(c <= eng._build_gen for c in traces.values()), traces


def test_spec_trace_counts_bounded(tiny_model_f32):
    model, params = tiny_model_f32
    _, eng = _drive(model, params, spec_k=4, link_rtt_s=0.05, force_split=2)
    traces = eng.stage_trace_counts()
    ks = {int(n.split("_k")[1]) for n in traces if "_k" in n}
    # one draft/end/cloud trace per distinct chunk size k, never per
    # prompt length or per round
    for k in ks:
        assert traces[f"spec_draft_k{k}"] == 1
        assert traces[f"spec_end_k{k}"] == 1
        assert traces[f"spec_cloud_k{k}"] == 1
