"""Route-aware heuristic scheduler properties (paper eq. 9-11)."""

import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep: deterministic fallback grid
    from _hypothesis_compat import given, settings, st

from repro.core.hardware import Capability
from repro.core.pipeline import (
    PipelinePlan,
    SchedulerConfig,
    Task,
    comm_time,
    plan_pipeline_split,
    priority,
    schedule,
)

END = Capability(gflop_budget=0.4, mem_budget_gb=16, net_gbps=0.3)
CLOUD = Capability(gflop_budget=10.0, mem_budget_gb=80, net_gbps=0.3)


def _tasks(n, seed=0):
    rng = np.random.default_rng(seed)
    return [
        Task(i, gflops=float(rng.uniform(0.1, 30)),
             comm_bytes=float(rng.uniform(1e3, 1e7)))
        for i in range(n)
    ]


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 30), seed=st.integers(0, 10),
       beta=st.floats(0.0, 5.0))
def test_end_load_threshold_respected(n, seed, beta):
    """eq. 11: total end load never exceeds T_end."""
    cfg = SchedulerConfig(beta=beta, t_end=40.0)
    placements, stats = schedule(_tasks(n, seed), END, CLOUD, cfg)
    assert stats["end_load"] <= cfg.t_end + 1e-9
    assert stats["n_end"] + stats["n_cloud"] == n


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 30), seed=st.integers(0, 10))
def test_local_tasks_meet_priority_threshold(n, seed):
    """eq. 11: every task placed on the end has P(t) >= beta."""
    cfg = SchedulerConfig(beta=1.0, t_end=100.0)
    placements, _ = schedule(_tasks(n, seed), END, CLOUD, cfg)
    for p in placements:
        if p.location == "end":
            assert p.priority >= cfg.beta


def test_priority_ratio_eq10():
    t = Task(0, gflops=10.0, comm_bytes=1e6)
    ct = comm_time(t, 0.3)
    assert abs(priority(t, ct, 1e-6) - 10.0 / (ct + 1e-6)) < 1e-6


def test_objective_no_worse_than_all_cloud():
    """The greedy schedule's eq. 9 objective never exceeds the all-cloud
    placement's objective."""
    cfg = SchedulerConfig(beta=0.0, t_end=1e9)
    tasks = _tasks(20, 3)
    _, stats = schedule(tasks, END, CLOUD, cfg)
    all_cloud = sum(
        cfg.alpha * (t.gflops / (CLOUD.gflop_budget * 1e3))
        + (1 - cfg.alpha) * comm_time(t, END.net_gbps)
        for t in tasks
    )
    assert stats["objective"] <= all_cloud + 1e-9


@settings(max_examples=20, deadline=None)
@given(n_layers=st.integers(1, 24), seed=st.integers(0, 5),
       ratio=st.sampled_from([0.1, 0.5, 1.0]))
def test_pipeline_split_bounds(n_layers, seed, ratio):
    rng = np.random.default_rng(seed)
    gfl = list(rng.uniform(0.5, 5.0, n_layers))
    plan = plan_pipeline_split(gfl, 1e6, END, CLOUD, compression_ratio=ratio)
    assert 0 <= plan.split_layer <= n_layers
    assert plan.est_step_time_s <= plan.est_latency_s + 1e-12


def test_compression_never_hurts_comm():
    gfl = [2.0] * 12
    p_raw = plan_pipeline_split(gfl, 1e7, END, CLOUD, compression_ratio=1.0)
    p_cmp = plan_pipeline_split(gfl, 1e7, END, CLOUD, compression_ratio=0.1)
    assert p_cmp.est_comm_time_s <= p_raw.est_comm_time_s + 1e-9
