"""Paged KV-cache subsystem + chunked pipelined prefill.

Covers the tentpole invariants:
  (a) PagePool alloc/free bookkeeping: reservations, ring reuse, double-free
      detection, exhaustion, defrag compaction;
  (b) greedy decode token parity paged-vs-dense (the pre-refactor
      ``Model.prefill``/``decode_step`` path) at splits 0 / mid / R;
  (c) chunked prefill is token-identical to whole-prompt prefill;
  (d) a skewed-length batch allocates measurably fewer KV bytes than the
      dense ``max_batch x max_len`` layout;
  (e) no page leaks across request finish + replan re-split (pages move
      between tier pools by table-aware permutation);
  (f) chunked prefill never stalls in-flight decode groups (admission is a
      pipeline stage, visible as StageTimeline occupancy);
  (g) the number of compiled stage traces is bounded by chunk/group shapes,
      not by distinct prompt lengths;
  (h) download metering charges only active slots (regression);
  (i) micro-batch groups are equal-sized (padded batch), one decode trace.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_config
from repro.core.hardware import PROFILES, DeviceProfile
from repro.models import kvcache
from repro.models.kvcache import PagePool
from repro.models.model import build_model
from repro.serving.engine import Request, ServingEngine
from repro.serving.fleet import FleetServingEngine
from repro.serving.stream import EndCloudServingEngine


@pytest.fixture(scope="module")
def tiny_model():
    cfg = smoke_config(get_config("tinyllama-1.1b")).replace(num_layers=4)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


@pytest.fixture(scope="module")
def tiny_model_f32():
    """Float32 twin for dense-oracle parity: the dense path prefills via
    flash attention (normalizes as ``acc / l``) while the chunked path
    normalizes as ``softmax(s) @ v`` — same math, different low-bit
    rounding, so bf16 greedy argmax can tie-break differently.  In f32 the
    gap is ~1e-7 relative and the comparison is deterministic."""
    cfg = (
        smoke_config(get_config("tinyllama-1.1b"))
        .replace(num_layers=4, dtype="float32")
    )
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _prompts(n, seed=0, lo=4, hi=16):
    rng = np.random.default_rng(seed)
    return [
        rng.integers(0, 500, size=int(rng.integers(lo, hi))).astype(np.int32)
        for _ in range(n)
    ]


def _dense_oracle(model, params, prompts, max_new_tokens, max_len=64):
    """Greedy tokens via the pre-refactor dense ring-buffer cache path."""
    out = {}
    for i, prompt in enumerate(prompts):
        lg, cache = model.prefill(
            params, {"tokens": jnp.asarray(prompt)[None]}, max_len=max_len
        )
        toks = [int(jnp.argmax(lg[0]))]
        for _ in range(max_new_tokens - 1):
            lg, cache = model.decode_step(
                params, jnp.asarray([[toks[-1]]], jnp.int32), cache
            )
            toks.append(int(jnp.argmax(lg[0])))
        out[i] = toks
    return out


# ------------------------------------------------------------- (a) PagePool


def test_page_pool_invariants():
    pool = PagePool(num_pages=8, page_size=4, pages_per_slot=4, n_slots=3)
    assert pool.pages_available == 8

    pool.reserve(0, kvcache.pages_needed(10, 4, 4))  # 3 pages
    pool.map_range(0, 0, 7)
    assert pool.pages_in_use == 2 and pool.pages_reserved == 1
    pool.append(0, 8)
    assert pool.pages_in_use == 3
    with pytest.raises(ValueError, match="reservation"):
        pool.append(0, 12)  # beyond its reservation
    # ring reuse: wrapping positions revisit mapped entries, no new pages
    pool.free(0)
    pool.reserve(0, 4)
    for pos in range(40):
        pool.append(0, pos)
    assert pool.pages_in_use == 4

    with pytest.raises(ValueError, match="already holds"):
        pool.reserve(0, 1)
    pool.reserve(1, 4)
    assert not pool.can_reserve(1)  # 8 pages, 8 spoken for
    with pytest.raises(ValueError, match="exhausted"):
        pool.reserve(2, 1)

    pool.free(0)
    with pytest.raises(ValueError, match="double free"):
        pool.free(0)
    assert pool.pages_in_use == 0 and pool.pages_available == 4

    # defrag: mapped pages compact to the lowest physical rows and the
    # permutation is a bijection fixing the garbage row
    pool.map_range(1, 0, 16)
    before = {
        (1, e): pool.table[1, e] for e in range(4)
    }
    perm = pool.defrag()
    assert sorted(perm[:-1].tolist()) == list(range(8))
    assert perm[-1] == 8
    assert sorted(pool.table[1].tolist()) == [0, 1, 2, 3]
    for e in range(4):
        assert perm[pool.table[1, e]] == before[(1, e)]


def test_page_perm_requires_lockstep():
    a = PagePool(4, 2, 2, n_slots=1)
    b = PagePool(4, 2, 2, n_slots=1)
    a.reserve(0, 2)
    b.reserve(0, 2)
    a.map_range(0, 0, 4)
    b.map_range(0, 0, 2)  # one entry behind
    with pytest.raises(ValueError, match="lockstep"):
        kvcache.page_perm(a.table, b.table, 4, 4)


# ------------------------------------------- (b) paged-vs-dense token parity


@pytest.mark.parametrize("split", [0, 2, 4])
def test_paged_matches_dense_oracle(tiny_model_f32, split):
    model, params = tiny_model_f32
    prompts = _prompts(6)
    want = _dense_oracle(model, params, prompts, max_new_tokens=8)
    eng = EndCloudServingEngine(
        model, params,
        end_profile=PROFILES["a100"], cloud_profile=PROFILES["a100"],
        max_batch=4, max_len=64, force_split=split, prefill_chunk=8,
    )
    reqs = [Request(i, p, max_new_tokens=8) for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert len(done) == 6
    assert {r.request_id: r.generated for r in reqs} == want


def test_sliding_window_chunked_prefill_matches_dense_oracle():
    """Regression: with a sliding window smaller than max_len the ring can
    wrap DURING prefill — a chunk's own writes must never evict keys still
    inside an early chunk query's window.  page_geometry adds one chunk of
    ring headroom for exactly this; greedy tokens must match the dense
    whole-prompt path (f32: the two prefill paths round differently in
    low-order bits)."""
    cfg = (
        smoke_config(get_config("tinyllama-1.1b"))
        .replace(num_layers=2, dtype="float32", sliding_window=24)
    )
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(7)
    # prompts well past the window so prefill wraps the ring
    prompts = [rng.integers(0, 500, size=s).astype(np.int32)
               for s in (40, 55, 48)]
    want = _dense_oracle(model, params, prompts, max_new_tokens=6, max_len=64)
    eng = ServingEngine(model, params, max_batch=2, max_len=64,
                        prefill_chunk=16)
    reqs = [Request(i, p, max_new_tokens=6) for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert {r.request_id: r.generated for r in reqs} == want


def test_over_capacity_request_fails_at_submit(tiny_model):
    """Regression: a request needing more pages than the pool holds could
    never be admitted; it must fail loudly at submit instead of blocking
    the FIFO queue forever."""
    model, params = tiny_model
    eng = ServingEngine(model, params, max_batch=4, max_len=64, kv_pages=2,
                        page_size=16)
    with pytest.raises(ValueError, match="KV pages"):
        eng.submit(Request(0, np.arange(40).astype(np.int32),
                           max_new_tokens=16))
    assert not eng.waiting
    seng = EndCloudServingEngine(
        model, params,
        end_profile=PROFILES["a100"], cloud_profile=PROFILES["a100"],
        max_batch=4, max_len=64, force_split=2, kv_pages=2, page_size=16,
    )
    with pytest.raises(ValueError, match="KV pages"):
        seng.submit(Request(0, np.arange(40).astype(np.int32),
                            max_new_tokens=16))
    # a fitting request still serves
    seng.submit(Request(1, np.arange(12).astype(np.int32), max_new_tokens=4))
    done = seng.run()
    assert len(done) == 1 and len(done[0].generated) == 4


# --------------------------------- (c) chunked == whole-prompt prefill parity


def test_chunked_prefill_matches_whole_prompt(tiny_model):
    model, params = tiny_model
    prompts = _prompts(6, seed=2, lo=8, hi=24)
    tokens = {}
    for chunk in (4, 32):  # 32 >= every prompt: single-chunk == whole-prompt
        eng = ServingEngine(
            model, params, max_batch=4, max_len=64, prefill_chunk=chunk
        )
        reqs = [Request(i, p, max_new_tokens=6) for i, p in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        eng.run()
        tokens[chunk] = {r.request_id: r.generated for r in reqs}
    assert tokens[4] == tokens[32]


# ------------------------------------------------- (d) skewed-batch KV bytes


def test_skewed_batch_uses_fewer_kv_bytes(tiny_model):
    model, params = tiny_model
    rng = np.random.default_rng(5)
    long_prompt = rng.integers(0, 500, size=100).astype(np.int32)
    shorts = _prompts(7, seed=6, lo=6, hi=10)
    eng = EndCloudServingEngine(
        model, params,
        end_profile=PROFILES["a100"], cloud_profile=PROFILES["a100"],
        max_batch=8, max_len=128, force_split=2,
    )
    eng.submit(Request(0, long_prompt, max_new_tokens=8))
    for i, p in enumerate(shorts):
        eng.submit(Request(1 + i, p, max_new_tokens=8))
    done = eng.run()
    assert len(done) == 8
    m = eng.metrics()
    # 1 long + 7 short: peak paged footprint must be well under the dense
    # max_batch x max_len layout (the long request pays for its pages, the
    # short ones only for theirs)
    assert m["kv_bytes_peak"] > 0
    assert m["kv_bytes_peak"] <= m["kv_bytes_dense_equiv"] / 2, m
    # every page returned once the batch drained
    assert m["kv_pages_in_use"] == 0


# --------------------------------------- (e) no leaks across finish + replan


def test_no_page_leak_across_finish_and_replan(tiny_model):
    model, params = tiny_model
    prompts = _prompts(6)
    # same-arithmetic reference: the paged single-tier engine (greedy decode
    # across a replan re-split must be bit-identical to a split-free run)
    ref = ServingEngine(model, params, max_batch=4, max_len=64,
                        prefill_chunk=8)
    for i, p in enumerate(prompts):
        ref.submit(Request(i, p, max_new_tokens=8))
    ref.run()
    want = {r.request_id: r.generated for r in ref.finished}
    weak_end = DeviceProfile("weak-end", peak_gflops=2.0, mem_gb=8.0,
                             mem_bw_gbs=50.0, net_gbps=0.3)
    eng = EndCloudServingEngine(
        model, params,
        end_profile=weak_end, cloud_profile=PROFILES["a100"],
        max_batch=4, max_len=64, force_split=model.cfg.block_repeat,
        prefill_chunk=8,
    )
    reqs = [Request(i, p, max_new_tokens=8) for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    for _ in range(3):
        eng.step()
    eng.observe_bandwidth(weak_end.net_gbps)  # forces the off-optimal replan
    eng.run()
    assert len(eng.replan_events) >= 1
    assert eng.replan_events[0]["new_split"] != model.cfg.block_repeat
    # token parity held across the re-split page move + defrag
    assert {r.request_id: r.generated for r in reqs} == want
    # and every page of both tier pools came back
    assert eng.end_pool.pages_in_use == 0
    assert eng.cloud_pool.pages_in_use == 0
    assert eng.end_pool.pages_reserved == 0
    assert eng.cloud_pool.pages_reserved == 0


def test_fleet_shared_cloud_pool_drains(tiny_model):
    model, params = tiny_model
    fleet = FleetServingEngine(
        model, params,
        end_profiles=[PROFILES["a100"], PROFILES["a100"]],
        cloud_profile=PROFILES["a100"],
        cloud_servers=1, max_batch=2, max_len=64,
    )
    for i, p in enumerate(_prompts(6, seed=9)):
        fleet.submit(Request(i, p, max_new_tokens=6))
    done = fleet.run()
    assert len(done) == 6
    m = fleet.metrics()
    assert m["kv_pages_in_use"] == 0
    assert fleet.cloud_pool.pages_in_use == 0
    assert m["kv_bytes_peak"] > 0
    # both lanes drew their cloud pages from the one shared pool
    assert fleet.lanes[0].cloud_pool is fleet.cloud_pool
    assert fleet.lanes[1].cloud_pool is fleet.cloud_pool
    assert fleet.lanes[0]._cloud_base != fleet.lanes[1]._cloud_base


# ------------------------------------------------ (f) no stop-the-world admit


def test_long_prompt_prefill_does_not_stall_decode(tiny_model):
    model, params = tiny_model
    rng = np.random.default_rng(11)
    eng = EndCloudServingEngine(
        model, params,
        end_profile=PROFILES["a100"], cloud_profile=PROFILES["a100"],
        max_batch=4, max_len=128, force_split=2, prefill_chunk=8,
    )
    # warm in-flight generations in both groups, one slot left free for
    # the long prompt (its prefill must interleave with LIVE decodes)
    for i, p in enumerate(_prompts(3, seed=10)):
        eng.submit(Request(i, p, max_new_tokens=64))
    for _ in range(4):
        eng.step()
    counts_before = {r.request_id: len(r.generated) for r in eng.slots if r}
    assert counts_before

    def emitted_total():
        live = sum(len(r.generated) for r in eng.slots if r)
        return live + sum(len(r.generated) for r in eng.finished)

    long_req = Request(99, rng.integers(0, 500, 96).astype(np.int32),
                       max_new_tokens=4)
    eng.submit(long_req)
    chunks_seen = 0
    stalled_ticks = 0
    while 99 in {j.req.request_id for j in eng._jobs.values()} or eng.waiting:
        before = emitted_total()
        eng.step()
        if emitted_total() == before:
            stalled_ticks += 1
        chunks_seen = eng.n_prefill_chunks
    # 96-token prompt at chunk 8 = 12 chunks, streamed over >= 12 ticks
    assert chunks_seen >= 12
    # in-flight decode kept emitting on every tick of the prefill
    assert stalled_ticks == 0
    # prefill chunks are visible as StageTimeline occupancy alongside decode
    assert eng._prefill_busy["end"] > 0 and eng._prefill_busy["cloud"] > 0
    assert eng.timeline.busy_s["end"] == pytest.approx(
        eng._stage_busy["end"] + eng._prefill_busy["end"]
    )
    eng.run()
    assert long_req.done and len(long_req.generated) == 4


# -------------------------------------------------- (g) bounded trace counts


def test_trace_count_bounded_by_shapes_not_prompt_lengths(tiny_model):
    model, params = tiny_model
    # 12 requests covering 12 distinct prompt lengths
    prompts = [np.arange(4 + i).astype(np.int32) % 500 for i in range(12)]
    eng = EndCloudServingEngine(
        model, params,
        end_profile=PROFILES["a100"], cloud_profile=PROFILES["a100"],
        max_batch=4, max_len=64, force_split=2, prefill_chunk=8,
    )
    for i, p in enumerate(prompts):
        eng.submit(Request(i, p, max_new_tokens=4))
    eng.run()
    counts = eng.stage_trace_counts()
    # one decode trace per tier (single group shape) and one chunk trace per
    # tier (single chunk shape) — NOT one per distinct prompt length
    assert counts == {
        "end_step": 1,
        "cloud_step": 1,
        "end_prefill_chunk": 1,
        "cloud_prefill_chunk": 1,
    }, counts

    single = ServingEngine(model, params, max_batch=4, max_len=64,
                           prefill_chunk=8)
    for i, p in enumerate(prompts):
        single.submit(Request(i, p, max_new_tokens=4))
    single.run()
    assert single.stage_trace_counts() == {"decode": 1, "prefill_chunk": 1}


# ------------------------------------------- (h) download metering regression


def test_record_down_meters_only_active_slots(tiny_model):
    """A half-empty group must not be charged token-id downlink bytes for
    its inactive slots: every generated token crosses the wire down exactly
    once, so bytes_down == 4 * total tokens."""
    model, params = tiny_model
    eng = EndCloudServingEngine(
        model, params,
        end_profile=PROFILES["a100"], cloud_profile=PROFILES["a100"],
        max_batch=4, n_groups=2, max_len=64, force_split=2,
    )
    # one request -> group 0 runs half-empty, group 1 never runs
    req = Request(0, np.arange(8).astype(np.int32), max_new_tokens=10)
    eng.submit(req)
    eng.run()
    total_tokens = len(req.generated)
    assert total_tokens == 10
    assert eng.link.bytes_down == 4 * total_tokens


# ------------------------------------------------- (i) equal-sized groups


def test_groups_are_equal_sized_with_padding(tiny_model):
    model, params = tiny_model
    eng = EndCloudServingEngine(
        model, params,
        end_profile=PROFILES["a100"], cloud_profile=PROFILES["a100"],
        max_batch=5, n_groups=2, max_len=64, force_split=2,
    )
    sizes = {ge - gs for gs, ge in eng._group_slices}
    assert sizes == {3}  # ceil(5/2), padded batch = 6
    assert eng.max_batch == 6 and eng.request_capacity == 5
    assert not eng._slot_usable(5)  # the padding slot never admits
    reqs = [Request(i, p, max_new_tokens=6) for i, p in enumerate(_prompts(7))]
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert len(done) == 7
    assert eng.slots[5] is None
    # equal groups -> exactly one compiled decode trace per tier
    assert eng.stage_trace_counts()["end_step"] == 1
