"""Heterogeneous multi-end fleet serving (serving.fleet.FleetServingEngine)
plus the fleet-level planning entry points (core.pipeline).

Covers:
  (a) single-device fleet is greedy-token-identical to the standalone
      EndCloudServingEngine at the same plan;
  (b) a heterogeneous fleet completes every request, places across all
      devices, and models cloud contention on the shared timeline;
  (c) per-device drift (bandwidth cut on one lane) replans ONLY that lane,
      at its own drained safe point, without disturbing the others;
  (d) plan_fleet_splits gives a weak device a more cloud-heavy split than a
      strong one; place_fleet respects capacity and prefers good links.
"""

import jax
import numpy as np
import pytest

from repro.configs import get_config, smoke_config
from repro.core.hardware import PROFILES, Capability, DeviceProfile, DeviceState
from repro.core.pipeline import (
    SchedulerConfig,
    Task,
    fleet_cloud_share,
    place_fleet,
    plan_fleet_splits,
)
from repro.core.selection import fleet_device_mask, shard_masks_for_fleet
from repro.models.model import build_model
from repro.serving.common import Request
from repro.serving.fleet import FleetServingEngine
from repro.serving.stream import EndCloudServingEngine


@pytest.fixture(scope="module")
def tiny_model():
    cfg = smoke_config(get_config("tinyllama-1.1b")).replace(num_layers=4)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _prompts(n, seed=0):
    rng = np.random.default_rng(seed)
    return [
        rng.integers(0, 500, size=int(rng.integers(4, 16))).astype(np.int32)
        for _ in range(n)
    ]


WEAK = DeviceProfile("weak-end", peak_gflops=0.5, mem_gb=4.0,
                     mem_bw_gbs=25.0, net_gbps=0.25)
MID = DeviceProfile("mid-end", peak_gflops=2.0, mem_gb=8.0,
                    mem_bw_gbs=50.0, net_gbps=1.0)
STRONG = DeviceProfile("strong-end", peak_gflops=4.0, mem_gb=16.0,
                       mem_bw_gbs=100.0, net_gbps=2.0)
CLOUD = DeviceProfile("cloud-sim", peak_gflops=24.0, mem_gb=80.0,
                      mem_bw_gbs=500.0, net_gbps=2.0)


# ------------------------------------------------------------ fleet planning

def test_plan_fleet_splits_weak_device_offloads_more():
    """Each device plans against its share of the cloud; a weak end keeps
    fewer blocks local than a strong one (eq. 9-11, fleet reading)."""
    layer_gflops = [1.0] * 8
    weak = Capability(gflop_budget=0.1, mem_budget_gb=4.0, net_gbps=1.0)
    strong = Capability(gflop_budget=10.0, mem_budget_gb=16.0, net_gbps=1.0)
    cloud = Capability(gflop_budget=50.0, mem_budget_gb=80.0, net_gbps=1.0)
    plans = plan_fleet_splits(
        layer_gflops, 1e4, [weak, strong], cloud, cloud_servers=2,
        edge_boundary=True,
    )
    assert plans[0].split_layer <= plans[1].split_layer
    # per-device cloud share halves the cloud rate seen by each device
    share = fleet_cloud_share(cloud, 2, 2)
    assert share.gflop_budget == pytest.approx(cloud.gflop_budget)
    share = fleet_cloud_share(cloud, 1, 4)
    assert share.gflop_budget == pytest.approx(cloud.gflop_budget / 4)


def test_place_fleet_prefers_fast_links_and_respects_capacity():
    cfg = SchedulerConfig(alpha=0.5, t_end=1e9)
    caps = [
        Capability(gflop_budget=1.0, mem_budget_gb=8.0, net_gbps=0.01),
        Capability(gflop_budget=1.0, mem_budget_gb=8.0, net_gbps=1.0),
    ]
    tasks = [Task(i, gflops=1.0, comm_bytes=1e6) for i in range(3)]
    # equal compute: everything should go to the fast link until its
    # capacity runs out, then spill to the slow one
    assignment, stats = place_fleet(tasks, caps, cfg, capacity=[2, 2])
    assert sorted(assignment) == [0, 1, 1]
    assert stats["n_unplaced"] == 0
    # capacity exhausted -> unplaced (-1), not mis-placed
    assignment, stats = place_fleet(tasks, caps, cfg, capacity=[0, 1])
    assert sorted(assignment) == [-1, -1, 1]
    assert stats["n_unplaced"] == 2


def test_place_fleet_load_balances_equal_devices():
    """With identical devices and links, accumulated load spreads tasks."""
    cfg = SchedulerConfig(alpha=1.0, t_end=1e9)
    caps = [Capability(1.0, 8.0, 1.0), Capability(1.0, 8.0, 1.0)]
    tasks = [Task(i, gflops=5.0, comm_bytes=10.0) for i in range(4)]
    assignment, _ = place_fleet(tasks, caps, cfg)
    assert sorted(assignment) == [0, 0, 1, 1]


def test_place_fleet_explicit_order_overrides_eq10_ranking():
    """The eq. 10 ranking picks compute-heavy tasks first; an explicit
    ``order`` must be used verbatim instead (serving frontends pass
    (SLO class, arrival) order), and a non-permutation must be rejected."""
    cfg = SchedulerConfig(alpha=0.5, t_end=1e9)
    caps = [Capability(gflop_budget=1.0, mem_budget_gb=8.0, net_gbps=1.0)]
    small = Task(0, gflops=1.0, comm_bytes=10.0)
    big = Task(1, gflops=50.0, comm_bytes=10.0)
    # one admission slot: only the first-ranked task lands
    assignment, _ = place_fleet([small, big], caps, cfg, capacity=[1])
    assert assignment == [-1, 0], "eq. 10 must rank the big task first"
    assignment, _ = place_fleet(
        [small, big], caps, cfg, capacity=[1], order=[0, 1]
    )
    assert assignment == [0, -1], "explicit order must be used verbatim"
    with pytest.raises(ValueError, match="permutation"):
        place_fleet([small, big], caps, cfg, order=[0, 0])


def test_fleet_placement_keeps_submission_order_within_class(tiny_model):
    """Regression: the frontend used to rank by eq. 10, so a later large
    request jumped an earlier small one of the same SLO class.  Placement
    must be a stable (priority class, arrival seq) sort: equal-priority
    requests keep submission order, lower classes still yield to higher."""
    model, params = tiny_model

    def build():
        return FleetServingEngine(
            model, params,
            end_profiles=[STRONG], cloud_profile=CLOUD,
            max_batch=1, max_len=128, timing="modeled",
        )

    # same class, wildly different size: eq. 10 would place the big one
    # first (priority ~ gflops/eps); arrival order must win instead
    small = Request(0, np.arange(4).astype(np.int32), max_new_tokens=4)
    big = Request(1, np.arange(60).astype(np.int32), max_new_tokens=16)
    eng = build()
    eng.submit(small)
    eng.submit(big)
    done = eng.run()
    assert len(done) == 2
    assert [ev["request_id"] for ev in eng.placed] == [0, 1]

    # across classes: the later interactive request outranks the earlier
    # batch one
    batch = Request(0, np.arange(60).astype(np.int32), max_new_tokens=16,
                    priority=2)
    inter = Request(1, np.arange(4).astype(np.int32), max_new_tokens=4,
                    priority=0)
    eng = build()
    eng.submit(batch)
    eng.submit(inter)
    done = eng.run()
    assert len(done) == 2
    assert [ev["request_id"] for ev in eng.placed] == [1, 0]
    assert eng.placed[0]["priority"] == 0


def test_fleet_device_mask_never_empty():
    """A device too weak for any expert still exposes its first one (the
    shard_masks_for_fleet guarantee, single-device form)."""
    cfg = smoke_config(get_config("llama4-scout-17b-16e")).replace(num_layers=2)
    moe = cfg.moe
    dead = DeviceProfile("dead-end", peak_gflops=1e-6, mem_gb=1e-9,
                         mem_bw_gbs=1.0, net_gbps=0.01)
    m = fleet_device_mask(
        dead, DeviceState(), cfg.d_model, moe.d_ff_expert,
        moe.num_experts, moe.num_groups, gated=cfg.ffn_gated,
    )
    assert m.sum() == 1 and m[0]
    stacked = shard_masks_for_fleet(
        [dead, PROFILES["a100"]], [DeviceState(), DeviceState()],
        cfg.d_model, moe.d_ff_expert, moe.num_experts, moe.num_groups,
        gated=cfg.ffn_gated,
    )
    np.testing.assert_array_equal(stacked[0], m)
    assert stacked.shape == (2, moe.num_experts)


# ------------------------------------------------------------- fleet engine

def test_single_device_fleet_token_parity(tiny_model):
    """(a) N=1 fleet == standalone streaming engine, token for token."""
    model, params = tiny_model
    prompts = _prompts(6)

    ref = EndCloudServingEngine(
        model, params,
        end_profile=PROFILES["a100"], cloud_profile=PROFILES["a100"],
        max_batch=4, max_len=64, force_split=2,
    )
    for i, p in enumerate(prompts):
        ref.submit(Request(i, p, max_new_tokens=8))
    ref.run()
    want = {r.request_id: r.generated for r in ref.finished}

    fleet = FleetServingEngine(
        model, params,
        end_profiles=[PROFILES["a100"]], cloud_profile=PROFILES["a100"],
        cloud_servers=1, max_batch=4, max_len=64, force_splits=[2],
    )
    for i, p in enumerate(prompts):
        fleet.submit(Request(i, p, max_new_tokens=8))
    done = fleet.run()
    assert len(done) == 6
    assert {r.request_id: r.generated for r in done} == want
    assert fleet.lanes[0].split == 2


def test_heterogeneous_fleet_completes_and_spreads(tiny_model):
    """(b) three device classes, one shared cloud: every request finishes,
    placement touches every device, and the shared cloud resource carries
    all lanes' cloud seconds."""
    model, params = tiny_model
    fleet = FleetServingEngine(
        model, params,
        end_profiles=[STRONG, MID, WEAK], cloud_profile=CLOUD,
        cloud_servers=2, max_batch=2, max_len=64,
        # generous spill: this test wants placement to reach even the weak
        # device (the default guard would rightly keep it mostly idle)
        max_spill=10.0,
    )
    prompts = _prompts(9, seed=3)
    for i, p in enumerate(prompts):
        fleet.submit(Request(i, p, max_new_tokens=6))
    done = fleet.run()
    assert len(done) == 9
    assert all(len(r.generated) == 6 for r in done)
    m = fleet.metrics()
    used = {ev["device"] for ev in fleet.placed}
    assert used == {0, 1, 2}
    assert m["n_placed"] == 9
    # cloud busy time on the shared resource == sum of the lanes' own
    # cloud seconds, decode stages AND prefill chunks (chunked prefill
    # streams through the same shared cloud resource as decode)
    lane_cloud = sum(
        l._stage_busy["cloud"] + l._prefill_busy["cloud"] for l in fleet.lanes
    )
    assert m["cloud_busy_s"] == pytest.approx(lane_cloud)
    assert m["fleet_makespan_s"] > 0
    assert m["aggregate_tokens_per_s"] > 0


def test_fleet_bandwidth_cut_replans_only_that_device(tiny_model):
    """(c) cutting one device's link replans that lane at its safe point;
    other lanes keep their plans and all streams finish intact."""
    model, params = tiny_model
    # force an all-end split on every lane so the straggler's replan has an
    # obviously better plan to move to
    R = model.cfg.block_repeat
    fleet = FleetServingEngine(
        model, params,
        end_profiles=[MID, WEAK], cloud_profile=CLOUD,
        cloud_servers=1, max_batch=2, max_len=64,
        force_splits=[R, R],
    )
    for i, p in enumerate(_prompts(6, seed=5)):
        fleet.submit(Request(i, p, max_new_tokens=8))
    for _ in range(3):
        fleet.step()
    fleet.observe_bandwidth(1, WEAK.net_gbps * 0.05)
    done = fleet.run()
    assert len(done) == 6 and all(len(r.generated) == 8 for r in done)
    events = fleet.replan_events
    assert events and all(ev["device"] == 1 for ev in events)
    assert fleet.lanes[1].split != R  # straggler offloaded blocks
    assert fleet.lanes[0].split == R  # untouched lane kept its plan
    assert fleet.lanes[0].replan_events == []


def test_fleet_rejects_overlong_request(tiny_model):
    model, params = tiny_model
    fleet = FleetServingEngine(
        model, params,
        end_profiles=[PROFILES["a100"]], cloud_profile=PROFILES["a100"],
        max_batch=2, max_len=32,
    )
    bad = Request(0, np.arange(20).astype(np.int32), max_new_tokens=20)
    with pytest.raises(ValueError, match="max_len"):
        fleet.submit(bad)
    assert fleet.waiting == []
