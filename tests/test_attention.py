"""Flash attention vs O(S^2) oracle: forward + gradients, all mask modes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep: deterministic fallback grid
    from _hypothesis_compat import given, settings, st

from repro.models.attention import (
    decode_attention,
    flash_attention,
    make_flash_attention,
    reference_attention,
    rope_angles,
    apply_rope,
)


def _qkv(B, S, H, KV, hd, seed=0, skv=None):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    skv = skv or S
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, skv, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, skv, KV, hd), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("causal,window", [(True, None), (True, 40), (False, None)])
@pytest.mark.parametrize("H,KV", [(4, 4), (8, 2)])
def test_flash_forward_matches_reference(causal, window, H, KV):
    q, k, v = _qkv(2, 128, H, KV, 16)
    o1 = flash_attention(q, k, v, causal=causal, window=window,
                         q_chunk=32, kv_chunk=32)
    o2 = reference_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-5, atol=2e-5)


def test_flash_gradients_match_reference():
    q, k, v = _qkv(1, 64, 4, 2, 16)

    def loss_flash(q, k, v):
        return (flash_attention(q, k, v, q_chunk=16, kv_chunk=16) ** 2).sum()

    def loss_ref(q, k, v):
        return (reference_attention(q, k, v) ** 2).sum()

    g1 = jax.grad(loss_flash, (0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ref, (0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


def test_cross_attention_rectangular():
    q, _, _ = _qkv(2, 48, 4, 4, 16)
    _, k, v = _qkv(2, 48, 4, 4, 16, seed=7, skv=96)
    o1 = flash_attention(q, k, v, causal=False, q_chunk=16, kv_chunk=32)
    o2 = reference_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-5, atol=2e-5)


def test_non_divisible_seq_chunk_fit():
    """seq 60 with chunk 32 -> auto-fitted divisor chunk."""
    q, k, v = _qkv(1, 60, 2, 2, 8)
    o1 = flash_attention(q, k, v, q_chunk=32, kv_chunk=32)
    o2 = reference_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(pos=st.integers(1, 62), window=st.sampled_from([None, 16]))
def test_decode_matches_reference_row(pos, window):
    """Single-token decode over a ring cache == the pos-th row of full
    attention."""
    B, S, H, KV, hd = 1, 64, 4, 2, 16
    q_full, k, v = _qkv(B, S, H, KV, hd, seed=3)
    ref = reference_attention(q_full, k, v, causal=True, window=window)
    q_tok = q_full[:, pos : pos + 1]
    key_pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    out = decode_attention(
        q_tok, k, v, jnp.full((B,), pos, jnp.int32), key_pos, window=window
    )
    np.testing.assert_allclose(
        np.asarray(out)[:, 0], np.asarray(ref)[:, pos], rtol=3e-5, atol=3e-5
    )


def test_mrope_sections_match_standard_when_uniform():
    """With identical t/h/w positions, M-RoPE == standard RoPE."""
    B, S, hd = 2, 16, 32
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    pos3 = jnp.broadcast_to(pos[:, None], (B, 3, S))
    a1 = rope_angles(pos, hd, 1e4)
    a2 = rope_angles(pos3, hd, 1e4, mrope_sections=(6, 5, 5))
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a2), rtol=1e-6)


def test_rope_preserves_norm():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 4, 32))
    pos = jnp.broadcast_to(jnp.arange(8)[None], (2, 8))
    y = apply_rope(x, rope_angles(pos, 32, 1e4))
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1),
        rtol=1e-5,
    )
