"""End-cloud simulator invariants + policy ordering (paper figs. 5-8)."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep: deterministic fallback grid
    from _hypothesis_compat import given, settings, st

from repro.configs.switch_base import with_experts
from repro.sim.policies import PolicyConfig, make_requests
from repro.sim.simulator import (
    Link,
    SimRequest,
    Stage,
    poisson_arrivals,
    simulate,
)


def test_latency_at_least_service_time():
    reqs = [SimRequest(0, 0.0, [Stage("end", 0.5), Stage("cloud", 0.25)])]
    m = simulate(reqs, link=Link(1.0))
    assert m["latency_mean_s"] >= 0.75 - 1e-9


def test_queueing_fifo_single_server():
    reqs = [SimRequest(i, 0.0, [Stage("end", 1.0)]) for i in range(3)]
    m = simulate(reqs, end_servers=1, link=Link(1.0))
    assert abs(m["makespan_s"] - 3.0) < 1e-9


def test_parallel_servers_cut_makespan():
    reqs = lambda: [SimRequest(i, 0.0, [Stage("end", 1.0)]) for i in range(4)]
    m1 = simulate(reqs(), end_servers=1, link=Link(1.0))
    m4 = simulate(reqs(), end_servers=4, link=Link(1.0))
    assert m4["makespan_s"] < m1["makespan_s"] / 2


def test_pipeline_overlap_beats_serial():
    """Two-stage requests overlap across requests (PO-ECC's pipelining)."""
    def reqs():
        return [
            SimRequest(i, 0.0, [Stage("end", 1.0), Stage("cloud", 1.0)])
            for i in range(4)
        ]
    m = simulate(reqs(), end_servers=1, cloud_servers=1, link=Link(1.0))
    assert m["makespan_s"] <= 5.0 + 1e-9  # serial would be 8


@settings(max_examples=20, deadline=None)
@given(fl=st.floats(0.0, 0.4), seed=st.integers(0, 5))
def test_bandwidth_fluctuation_bounded(fl, seed):
    link = Link(0.3, fluctuation=fl, seed=seed)
    for t in np.linspace(0, 10, 50):
        bw = link.bandwidth(float(t))
        assert 0.3 * (1 - fl) - 1e-9 <= bw <= 0.3 * (1 + fl) + 1e-9


def test_policy_ordering_matches_paper():
    """EC2MoE >= BrownoutServe >= EdgeMoE in saturation throughput (E=64)."""
    cfg = with_experts(64)
    pc = PolicyConfig()
    arr = poisson_arrivals(60, 300, 0)
    tput = {}
    for sysname in ("ec2moe", "brownoutserve", "edgemoe"):
        m = simulate(
            make_requests(sysname, cfg, pc, arr),
            link=Link(0.3, fluctuation=0.2, seed=0),
            end_servers=pc.n_end_devices, cloud_servers=pc.n_cloud_gpus,
        )
        tput[sysname] = m["throughput_rps"]
    assert tput["ec2moe"] > tput["brownoutserve"] > tput["edgemoe"]


def test_edgemoe_degrades_with_experts():
    pc = PolicyConfig()
    arr = poisson_arrivals(60, 200, 0)
    caps = []
    for E in (8, 64):
        m = simulate(
            make_requests("edgemoe", with_experts(E), pc, arr),
            link=Link(0.3, seed=0),
            end_servers=pc.n_end_devices, cloud_servers=pc.n_cloud_gpus,
        )
        caps.append(m["throughput_rps"])
    assert caps[1] < caps[0]


def test_ec2moe_load_adaptive_split():
    """Route-aware planning: low offered load -> latency-lean plan (less end
    compute per request than the saturation plan)."""
    from repro.sim.policies import ec2moe_stages

    cfg = with_experts(16)
    pc = PolicyConfig()
    sat = ec2moe_stages(cfg, pc, offered_rps=0)
    low = ec2moe_stages(cfg, pc, offered_rps=2)
    end_t = lambda stages: sum(s.service_s for s in stages if s.resource == "end")
    assert end_t(low) <= end_t(sat)


def test_stream_policy_pipelines_decode_tokens():
    """The streaming-decode policy emits per-token (end, link, cloud)
    triples; the queueing model overlaps them across requests, so makespan
    beats the serial stage sum."""
    from repro.sim.policies import ec2moe_stream_stages

    cfg = with_experts(16)
    pc = PolicyConfig()
    proto = ec2moe_stream_stages(cfg, pc, n_decode_tokens=8)
    assert proto and {s.resource for s in proto} <= {"end", "link", "cloud"}
    reqs = make_requests("ec2moe-stream", cfg, pc, poisson_arrivals(20, 40, 0))
    m = simulate(reqs, link=Link(0.3, seed=0),
                 end_servers=pc.n_end_devices, cloud_servers=pc.n_cloud_gpus)
    serial = sum(r.latency_s for r in reqs)
    assert 0 < m["makespan_s"] < serial


def test_ec2moe_less_jitter_sensitive():
    cfg = with_experts(16)
    pc = PolicyConfig()
    arr = poisson_arrivals(6, 150, 0)
    drop = {}
    for sysname in ("ec2moe", "brownoutserve"):
        lat = []
        for fl in (0.0, 0.4):
            m = simulate(
                make_requests(sysname, cfg, pc, arr, offered_rps=6),
                link=Link(0.3, fluctuation=fl, seed=0),
                end_servers=pc.n_end_devices, cloud_servers=pc.n_cloud_gpus,
            )
            lat.append(m["latency_mean_s"])
        drop[sysname] = lat[1] / lat[0]
    assert drop["ec2moe"] < drop["brownoutserve"]
