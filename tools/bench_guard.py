"""Bench-trajectory guard: fresh benchmark ratios vs committed baselines.

The committed ``BENCH_decode_pipeline.json`` / ``BENCH_fleet.json`` are
the repo's performance trajectory — each PR regenerates them, so a
silent regression shows up as a drifted ratio.  Absolute times are
host-dependent and excluded; the guard compares only **scale-invariant
ratio metrics** (byte ratios, capacity ratios, overlap gain, hit /
acceptance rates) between a freshly produced report and the committed
baseline, within a relative tolerance that absorbs smoke-vs-full shape
differences (CI runs reduced layer counts):

    PYTHONPATH=src python tools/bench_guard.py \
        --fresh-decode bench_decode_pipeline_smoke.json \
        --fresh-fleet bench_fleet_smoke.json [--tol 0.35]

A guarded key missing from the *fresh* report fails loudly (a deleted
metric is a regression too); keys missing from the committed baseline
are skipped with a note, so a PR that *adds* metrics regenerates the
baseline without chicken-and-egg.  Floor keys (speculative speedup /
acceptance) additionally enforce the benchmark's own acceptance bar, so
a baseline regen can never quietly lower it.
"""

from __future__ import annotations

import argparse
import json
import sys

# (key, hard floor or None) per logical row.  Floors mirror the asserts
# inside the benchmarks themselves.
DECODE_GUARDS = {
    "overlap": [("overlap_gain", 1.0), ("attn_bytes_ratio", None),
                ("kv_utilization", None)],
    "expert": [("expert_bytes_ratio", None), ("expert_hit_rate", 0.95)],
    "quant": [("boundary_bytes_ratio", None),
              ("boundary_bytes_ratio_compressed", None),
              ("attn_bytes_quant_ratio", None),
              ("expert_bytes_quant_ratio", None),
              ("kv_capacity_ratio", 1.9), ("expert_capacity_ratio", 1.9),
              ("greedy_match_rate", 0.85)],
    "spec": [("spec_speedup", 1.4), ("spec_acceptance_rate", 0.6),
             ("greedy_parity", 1.0), ("computebound_plan_k", None)],
}

# nested section -> guarded keys of the single fleet report row
FLEET_GUARDS = {
    "quantized_streams": [("boundary_bytes_ratio", None),
                          ("expert_bytes_ratio", None),
                          ("kv_capacity_ratio", 1.9)],
    "fleet_expert_store": [("dedup_ratio", 1.0), ("fleet_hit_rate", None)],
}


def _decode_row_kind(row):
    phase = row.get("phase")
    if phase == "speculative_decode":
        return "spec"
    if phase == "quantized_streams" or "attn_bytes_quant_ratio" in row:
        return "quant"
    if "expert_bytes_step_dense" in row:
        return "expert"
    if "overlap_gain" in row:
        return "overlap"
    return None


def _index_decode(rows):
    out = {}
    for row in rows:
        kind = _decode_row_kind(row)
        if kind is not None:
            out[kind] = row
    return out


def _check(label, key, fresh, base, floor, tol, failures, skipped):
    if fresh is None:
        failures.append(f"{label}.{key}: missing from the fresh report")
        return
    fresh = float(fresh)
    if floor is not None and fresh < floor:
        failures.append(
            f"{label}.{key}: fresh {fresh:.4f} below hard floor {floor}"
        )
    if base is None:
        skipped.append(f"{label}.{key} (no committed baseline yet)")
        return
    base = float(base)
    if abs(fresh - base) > tol * max(abs(base), 1e-9):
        failures.append(
            f"{label}.{key}: fresh {fresh:.4f} vs baseline {base:.4f} "
            f"drifts past {tol:.0%}"
        )


def guard_decode(fresh_rows, base_rows, tol, failures, skipped):
    fresh, base = _index_decode(fresh_rows), _index_decode(base_rows)
    for kind, guards in DECODE_GUARDS.items():
        if kind not in fresh:
            failures.append(f"decode.{kind}: row missing from fresh report")
            continue
        brow = base.get(kind, {})
        for key, floor in guards:
            _check(f"decode.{kind}", key, fresh[kind].get(key),
                   brow.get(key), floor, tol, failures, skipped)


def guard_fleet(fresh_rows, base_rows, tol, failures, skipped):
    fresh, base = fresh_rows[0], base_rows[0] if base_rows else {}
    for section, guards in FLEET_GUARDS.items():
        fsec = fresh.get(section)
        if not isinstance(fsec, dict):
            failures.append(f"fleet.{section}: missing from fresh report")
            continue
        bsec = base.get(section) or {}
        for key, floor in guards:
            _check(f"fleet.{section}", key, fsec.get(key),
                   bsec.get(key), floor, tol, failures, skipped)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh-decode", help="freshly produced decode report")
    ap.add_argument("--fresh-fleet", help="freshly produced fleet report")
    ap.add_argument("--baseline-decode", default="BENCH_decode_pipeline.json")
    ap.add_argument("--baseline-fleet", default="BENCH_fleet.json")
    ap.add_argument("--tol", type=float, default=0.35,
                    help="relative drift tolerance vs the baseline")
    args = ap.parse_args(argv)
    if not args.fresh_decode and not args.fresh_fleet:
        ap.error("give at least one of --fresh-decode / --fresh-fleet")

    failures, skipped = [], []
    if args.fresh_decode:
        with open(args.fresh_decode) as f:
            fresh = json.load(f)
        with open(args.baseline_decode) as f:
            base = json.load(f)
        guard_decode(fresh, base, args.tol, failures, skipped)
    if args.fresh_fleet:
        with open(args.fresh_fleet) as f:
            fresh = json.load(f)
        with open(args.baseline_fleet) as f:
            base = json.load(f)
        guard_fleet(fresh, base, args.tol, failures, skipped)

    for s in skipped:
        print(f"[bench_guard] skipped {s}")
    if failures:
        for msg in failures:
            print(f"[bench_guard] FAIL {msg}", file=sys.stderr)
        return 1
    print(f"[bench_guard] OK — ratio metrics within {args.tol:.0%} "
          f"of committed baselines ({len(skipped)} skipped)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
