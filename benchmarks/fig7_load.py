"""Figure 7: scalability under task-load changes (request rate 2..10 req/s)."""

from __future__ import annotations

import argparse
import json
from typing import Dict, List

from repro.configs.switch_base import with_experts
from repro.sim.policies import PolicyConfig, make_requests
from repro.sim.simulator import Link, poisson_arrivals, simulate

from benchmarks.common import SYSTEMS


def run(rates=(2, 4, 6, 8, 10), experts: int = 16, n_requests: int = 240,
        seed: int = 0) -> List[Dict]:
    rows = []
    cfg = with_experts(experts)
    pc = PolicyConfig()
    for rate in rates:
        arrivals = poisson_arrivals(rate, n_requests, seed)
        for system in SYSTEMS:
            m = simulate(
                make_requests(system, cfg, pc, arrivals, offered_rps=rate),
                link=Link(0.3, seed=seed),
                end_servers=pc.n_end_devices, cloud_servers=pc.n_cloud_gpus,
            )
            rows.append(
                dict(rate_rps=rate, system=system,
                     throughput_rps=round(m["throughput_rps"], 3),
                     latency_mean_s=round(m["latency_mean_s"], 4))
            )
            print(f"[fig7] rate={rate} {system}: tput={m['throughput_rps']:.2f}"
                  f" lat={m['latency_mean_s']*1e3:.0f}ms", flush=True)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="bench_fig7.json")
    args = ap.parse_args()
    rows = run()
    json.dump(rows, open(args.out, "w"), indent=1)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
