"""Streaming end-cloud decode benchmark: pipelined vs serial step time.

Runs the same decode workload through

  * the single-tier continuous-batching ``ServingEngine`` (baseline), and
  * the streaming ``EndCloudServingEngine`` at the route-aware split, with
    the boundary double-buffered across two micro-batch groups,

and reports steady-state step times.  Stage compute times are measured on
this host; link times are modeled from the metered boundary bytes at the
configured bandwidth; the pipelined schedule is the resource-occupancy
timeline (same queueing model as ``repro.sim.simulator``).  The headline
check is the PO-ECC pipelining claim:

    pipelined_step_s  <  serial_step_s = t_end + t_comm + t_cloud
    pipelined_step_s  ->  max(t_end, t_comm, t_cloud)   (steady state)

A second phase degrades the end device's state mid-run to exercise dynamic
replanning (paper fig. 7's changing-load scenario): the engine re-splits
params and KV caches at a request-safe boundary and keeps decoding.  (A pure
bandwidth change with the codec off does not move the split here: with the
boundary shipped at every split, wire cost is split-independent, and the
replan hysteresis correctly refuses a drain that buys nothing.)

    PYTHONPATH=src python -m benchmarks.decode_pipeline [--out bench_decode_pipeline.json]
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Dict

import jax
import numpy as np

from repro.configs import get_config, smoke_config
from repro.core.hardware import DeviceProfile, DeviceState
from repro.models.model import build_model
from repro.serving.engine import Request, ServingEngine
from repro.serving.stream import EndCloudServingEngine

# Device profiles calibrated to smoke-model scale (the paper-testbed profiles
# paired with a ~100k-param smoke model put every split in the all-cloud
# corner; these keep the planner in the interior regime the paper studies:
# end ~3x weaker than cloud, link fast enough that an interior split wins
# until the mid-run bandwidth drop).
END_SIM = DeviceProfile("end-sim", peak_gflops=2.0, mem_gb=8.0,
                        mem_bw_gbs=50.0, net_gbps=2.0)
CLOUD_SIM = DeviceProfile("cloud-sim", peak_gflops=6.0, mem_gb=80.0,
                          mem_bw_gbs=500.0, net_gbps=2.0)


def _requests(n: int, max_new_tokens: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return [
        Request(i, rng.integers(0, 500, size=int(rng.integers(8, 24))).astype(np.int32),
                max_new_tokens=max_new_tokens)
        for i in range(n)
    ]


def run(
    *,
    arch: str = "tinyllama-1.1b",
    num_layers: int = 4,
    n_requests: int = 12,
    max_new_tokens: int = 24,
    max_batch: int = 8,
    compression_rank: int = 0,
    seed: int = 0,
) -> Dict:
    cfg = smoke_config(get_config(arch)).replace(num_layers=num_layers)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))

    # -- baseline: single-tier continuous batching ---------------------------
    base = ServingEngine(model, params, max_batch=max_batch, max_len=128)
    for r in _requests(n_requests, max_new_tokens, seed):
        base.submit(r)
    t0 = time.perf_counter()
    base_done = base.run()
    base_wall = time.perf_counter() - t0
    base_tokens = sum(len(r.generated) for r in base_done)

    # -- streaming two-tier pipeline -----------------------------------------
    eng = EndCloudServingEngine(
        model, params,
        end_profile=END_SIM,
        cloud_profile=CLOUD_SIM,
        max_batch=max_batch, max_len=128,
        compression_rank=compression_rank,
    )
    reqs = _requests(n_requests, max_new_tokens, seed)
    for r in reqs:
        eng.submit(r)
    t0 = time.perf_counter()
    done = eng.run()
    wall = time.perf_counter() - t0
    stream_tokens = sum(len(r.generated) for r in done)
    m = eng.metrics()

    # -- dynamic load: the end device gets busy mid-run (fig. 7 scenario);
    # -- the replanner offloads blocks to the cloud at a safe point ----------
    replan_reqs = _requests(n_requests, max_new_tokens, seed + 1)
    for r in replan_reqs:
        eng.submit(r)
    for _ in range(4):
        eng.step()
    eng.update_device_state(DeviceState(cpu_free=0.05, power_free=0.1))
    eng.run()
    m2 = eng.metrics()

    row = {
        "arch": cfg.name,
        "block_repeat": cfg.block_repeat,
        "split": m["split"],
        "compressed": m["compressed"],
        "n_groups": m["n_groups"],
        "tokens_baseline": base_tokens,
        "tokens_streamed": stream_tokens,
        "baseline_wall_s": round(base_wall, 4),
        "stream_wall_s": round(wall, 4),
        "mean_t_end_s": round(m["mean_t_end_s"], 6),
        "mean_t_comm_s": round(m["mean_t_comm_s"], 6),
        "mean_t_cloud_s": round(m["mean_t_cloud_s"], 6),
        "serial_step_s": round(m["serial_step_s"], 6),
        "pipelined_step_s": round(m["pipelined_step_s"], 6),
        "max_stage_s": round(
            max(m["mean_t_end_s"], m["mean_t_comm_s"], m["mean_t_cloud_s"]), 6
        ),
        "plan_est_step_s": round(m["plan_est_step_s"], 6),
        "boundary_bytes_up": m["bytes_up"],
        "overlap_gain": round(m["serial_step_s"] / max(m["pipelined_step_s"], 1e-12), 3),
        "replan_events": m2["replan_events"],
        "split_after_load_spike": m2["split"],
    }
    print(
        f"[decode_pipeline] split={row['split']}/{cfg.block_repeat} "
        f"serial={row['serial_step_s']*1e3:.2f}ms "
        f"pipelined={row['pipelined_step_s']*1e3:.2f}ms "
        f"(max stage {row['max_stage_s']*1e3:.2f}ms, x{row['overlap_gain']} overlap) "
        f"replans={row['replan_events']} -> split {row['split_after_load_spike']}",
        flush=True,
    )
    assert row["pipelined_step_s"] < row["serial_step_s"], (
        "pipelined decode must beat the serial sum of stage times"
    )
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="bench_decode_pipeline.json")
    ap.add_argument("--rank", type=int, default=0)
    args = ap.parse_args()
    rows = [run(compression_rank=args.rank)]
    json.dump(rows, open(args.out, "w"), indent=1)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
